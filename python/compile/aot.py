"""AOT lowering: JAX (L2) + Pallas (L1) -> HLO text artifacts for Rust.

Emits HLO *text* (NOT `.serialize()`): jax >= 0.5 writes HloModuleProto
with 64-bit instruction ids which xla_extension 0.5.1 (the version the
published `xla` crate binds) rejects; the text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/README.md.

Artifacts:
  artifacts/<name>.hlo.txt   one per (operator, shape, micro-batch) variant
                             and one for the TinyCNN serving model
  artifacts/manifest.json    entry name -> {inputs, outputs, dtype, meta}
  artifacts/goldens.json     deterministic input/output samples for Rust
                             integration tests (numeric parity with JAX)

Python runs ONCE at build time (`make artifacts`); the Rust binary is
self-contained afterwards.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as model_lib
from . import ops

# Micro-batch variants lowered for the chunkable dense operator so the Rust
# PlanExecutor can realize any GACER list_B split with compiled code.
CHUNK_VARIANTS = (1, 2, 4, 8, 16, 32)
SERVE_BATCHES = (1, 2, 4, 8, 16, 32)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _shape_of(s):
    return {"shape": list(s.shape), "dtype": str(s.dtype)}


class Emitter:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.manifest: dict[str, dict] = {}
        os.makedirs(out_dir, exist_ok=True)

    def emit(self, name: str, fn, arg_specs: list, meta: dict | None = None):
        lowered = jax.jit(fn).lower(*arg_specs)
        text = to_hlo_text(lowered)
        path = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, path), "w") as f:
            f.write(text)
        out_avals = jax.eval_shape(fn, *arg_specs)
        if not isinstance(out_avals, (list, tuple)):
            out_avals = (out_avals,)
        self.manifest[name] = {
            "path": path,
            "inputs": [_shape_of(s) for s in arg_specs],
            "outputs": [_shape_of(s) for s in out_avals],
            "meta": meta or {},
        }

    def write_manifest(self):
        with open(os.path.join(self.out_dir, "manifest.json"), "w") as f:
            json.dump(self.manifest, f, indent=2, sort_keys=True)


def emit_operator_artifacts(em: Emitter):
    """Per-operator entries at the micro-batch variants GACER can issue."""
    # Chunkable dense layer: (B, 512) @ (512, 128) — each chunk its own HLO.
    F_IN, F_OUT = 512, 128
    w = _spec((F_IN, F_OUT))
    b = _spec((F_OUT,))
    for bsz in SERVE_BATCHES:
        em.emit(
            f"linear_b{bsz}",
            lambda x, w, b: ops.linear(x, w, b, relu=True),
            [_spec((bsz, F_IN)), w, b],
            meta={"op": "linear", "batch": bsz, "relu": True},
        )
    for chunk in CHUNK_VARIANTS:
        bsz = 32  # full batch the chunking decomposes
        if bsz % chunk:
            continue
        em.emit(
            f"linear_chunked_b{bsz}_c{chunk}",
            lambda x, w, b, _c=chunk: ops.linear_chunked(x, w, b, chunk=_c),
            [_spec((bsz, F_IN)), w, b],
            meta={"op": "linear_chunked", "batch": bsz, "chunk": chunk},
        )
    # Conv operator at several batches (16x16x16 -> 16x16x32, the paper's
    # high-occupancy class).
    for bsz in (1, 2, 4, 8):
        em.emit(
            f"conv3x3_b{bsz}",
            lambda x, w, b: ops.conv2d(x, w, b, stride=1, pad=1, relu=True),
            [_spec((bsz, 16, 16, 16)), _spec((3, 3, 16, 32)), _spec((32,))],
            meta={"op": "conv3x3", "batch": bsz},
        )
    # Batchnorm (bandwidth-bound class).
    for bsz in (1, 8):
        em.emit(
            f"batchnorm_b{bsz}",
            ops.batchnorm,
            [
                _spec((bsz, 16, 16, 32)),
                _spec((32,)),
                _spec((32,)),
                _spec((32,)),
                _spec((32,)),
            ],
            meta={"op": "batchnorm", "batch": bsz},
        )
    # LSTM cell (language tenant).
    H, I = 128, 64
    em.emit(
        "lstm_cell_b16",
        ops.lstm_cell,
        [
            _spec((16, I)),
            _spec((16, H)),
            _spec((16, H)),
            _spec((I, 4 * H)),
            _spec((H, 4 * H)),
            _spec((4 * H,)),
        ],
        meta={"op": "lstm_cell", "batch": 16},
    )
    # Attention block (recommendation tenant).
    S, D = 16, 64
    em.emit(
        "attention_b8",
        ops.attention,
        [_spec((8, S, D))] + [_spec((D, D))] * 4,
        meta={"op": "attention", "batch": 8, "seq": S},
    )


def emit_model_artifacts(em: Emitter):
    """TinyCNN forward at every serving batch size."""
    params = model_lib.tiny_cnn_init(jax.random.PRNGKey(0))
    flat = model_lib.flatten_params(params)
    param_specs = [_spec(p.shape) for p in flat]

    def fwd(x, *ps):
        return model_lib.tiny_cnn_forward(model_lib.TinyCNNParams(*ps), x)

    for bsz in SERVE_BATCHES:
        em.emit(
            f"tiny_cnn_b{bsz}",
            fwd,
            [_spec((bsz, 32, 32, 3))] + param_specs,
            meta={"op": "tiny_cnn", "batch": bsz, "n_params": len(flat)},
        )
    # Persist the concrete parameters for the Rust server (JSON keeps the
    # Rust side dependency-free; sizes are small for the serving model).
    params_doc = [
        {"shape": list(p.shape), "data": np.asarray(p).ravel().tolist()}
        for p in flat
    ]
    with open(os.path.join(em.out_dir, "tiny_cnn_params.json"), "w") as f:
        json.dump(params_doc, f)
    return params, flat


def emit_goldens(em: Emitter, params, flat):
    """Deterministic numeric goldens for Rust integration tests."""
    goldens = {}
    # TinyCNN b=2
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 3), jnp.float32)
    y = model_lib.tiny_cnn_forward(params, x)
    goldens["tiny_cnn_b2"] = {
        "x": np.asarray(x).ravel().tolist(),
        "y": np.asarray(y).ravel().tolist(),
    }
    # Linear b=4
    k = jax.random.PRNGKey(2)
    xk, wk, bk = jax.random.split(k, 3)
    xl = jax.random.normal(xk, (4, 512), jnp.float32)
    wl = jax.random.normal(wk, (512, 128), jnp.float32) * 0.05
    bl = jax.random.normal(bk, (128,), jnp.float32)
    yl = ops.linear(xl, wl, bl, relu=True)
    goldens["linear_b4"] = {
        "x": np.asarray(xl).ravel().tolist(),
        "w": np.asarray(wl).ravel().tolist(),
        "b": np.asarray(bl).ravel().tolist(),
        "y": np.asarray(yl).ravel().tolist(),
    }
    with open(os.path.join(em.out_dir, "goldens.json"), "w") as f:
        json.dump(goldens, f)


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts/model.hlo.txt",
                        help="primary artifact path; siblings land next to it")
    args = parser.parse_args()
    out_dir = os.path.dirname(os.path.abspath(args.out)) or "."

    em = Emitter(out_dir)
    emit_operator_artifacts(em)
    params, flat = emit_model_artifacts(em)
    emit_goldens(em, params, flat)
    em.write_manifest()

    # The Makefile's primary target: alias the b8 serving model.
    primary = em.manifest["tiny_cnn_b8"]["path"]
    src = os.path.join(out_dir, primary)
    with open(src) as f, open(args.out if os.path.isabs(args.out)
                              else os.path.abspath(args.out), "w") as g:
        g.write(f.read())
    print(f"emitted {len(em.manifest)} artifacts to {out_dir}")


if __name__ == "__main__":
    main()
