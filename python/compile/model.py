"""Layer-2 model definitions for the GACER compile path.

`TinyCNN` is the e2e serving model: a small conv net whose forward pass is
AOT-lowered to a single HLO artifact served by the Rust coordinator. The
per-operator entry points below it are lowered separately so the coordinator
can also issue operator-granular plans (the paper's operator-level
regulation) with compiled code.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import ops


class TinyCNNParams(NamedTuple):
    """Parameters of the 3-conv + 2-fc serving model (NHWC, 32x32x3 in)."""

    conv1_w: jax.Array  # (3,3,3,16)
    conv1_b: jax.Array
    bn1_gamma: jax.Array
    bn1_beta: jax.Array
    bn1_mean: jax.Array
    bn1_var: jax.Array
    conv2_w: jax.Array  # (3,3,16,32)
    conv2_b: jax.Array
    conv3_w: jax.Array  # (3,3,32,32)
    conv3_b: jax.Array
    fc1_w: jax.Array  # (512, 128)
    fc1_b: jax.Array
    fc2_w: jax.Array  # (128, 10)
    fc2_b: jax.Array


def tiny_cnn_init(key: jax.Array) -> TinyCNNParams:
    ks = jax.random.split(key, 7)

    def he(k, shape, fan_in):
        return jax.random.normal(k, shape, jnp.float32) * jnp.sqrt(2.0 / fan_in)

    return TinyCNNParams(
        conv1_w=he(ks[0], (3, 3, 3, 16), 27),
        conv1_b=jnp.zeros(16),
        bn1_gamma=jnp.ones(16),
        bn1_beta=jnp.zeros(16),
        bn1_mean=jnp.zeros(16),
        bn1_var=jnp.ones(16),
        conv2_w=he(ks[1], (3, 3, 16, 32), 144),
        conv2_b=jnp.zeros(32),
        conv3_w=he(ks[2], (3, 3, 32, 32), 288),
        conv3_b=jnp.zeros(32),
        fc1_w=he(ks[3], (512, 128), 512),
        fc1_b=jnp.zeros(128),
        fc2_w=he(ks[4], (128, 10), 128),
        fc2_b=jnp.zeros(10),
    )


def tiny_cnn_forward(params: TinyCNNParams, x: jax.Array) -> jax.Array:
    """Forward pass: (B, 32, 32, 3) -> (B, 10) logits."""
    h = ops.conv2d(x, params.conv1_w, params.conv1_b, stride=1, pad=1, relu=True)
    h = ops.batchnorm(h, params.bn1_gamma, params.bn1_beta, params.bn1_mean, params.bn1_var)
    h = ops.maxpool2d(h)  # 16x16x16
    h = ops.conv2d(h, params.conv2_w, params.conv2_b, stride=1, pad=1, relu=True)
    h = ops.maxpool2d(h)  # 8x8x32
    h = ops.conv2d(h, params.conv3_w, params.conv3_b, stride=1, pad=1, relu=True)
    h = ops.maxpool2d(h)  # 4x4x32
    h = h.reshape(h.shape[0], -1)  # (B, 512)
    h = ops.linear(h, params.fc1_w, params.fc1_b, relu=True)
    return ops.linear(h, params.fc2_w, params.fc2_b, relu=False)


def flatten_params(params: TinyCNNParams) -> list[jax.Array]:
    """Deterministic argument order used by aot.py and the Rust runtime."""
    return list(params)
