"""Layer-2 JAX operator library for the GACER compile path.

Every operator the Rust coordinator can issue is defined here as a jittable
JAX function whose GEMM hot-spots route through the Layer-1 Pallas kernels.
`aot.py` lowers each (operator, shape, micro-batch) variant to HLO text so
the Rust `PlanExecutor` can realize any GACER `list_B` chunking with
AOT-compiled code — Python never runs on the request path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import batchnorm_inference, bias_relu, chunked_matmul, matmul

# Kernels are lowered interpret=True (CPU PJRT cannot run Mosaic calls).
INTERPRET = True


# ---------------------------------------------------------------------------
# Convolution (the paper's dominant, high-SM-occupancy operator class)
# ---------------------------------------------------------------------------

def _im2col(x: jax.Array, kh: int, kw: int, stride: int, pad: int) -> jax.Array:
    """(B, H, W, C) -> (B*OH*OW, KH*KW*C) patch matrix."""
    B, H, W, C = x.shape
    if pad:
        x = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    OH = (H + 2 * pad - kh) // stride + 1
    OW = (W + 2 * pad - kw) // stride + 1
    # Gather patches: (B, OH, OW, KH, KW, C)
    patches = jax.lax.conv_general_dilated_patches(
        x.transpose(0, 3, 1, 2),  # NCHW for patches helper
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding="VALID",
    )  # (B, C*KH*KW, OH, OW)
    patches = patches.transpose(0, 2, 3, 1)  # (B, OH, OW, C*KH*KW)
    return patches.reshape(B * OH * OW, C * kh * kw), OH, OW


def conv2d(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    *,
    stride: int = 1,
    pad: int = 1,
    relu: bool = True,
) -> jax.Array:
    """Conv2D (NHWC x HWIO) via im2col + Pallas matmul, fused bias(+ReLU).

    x: (B, H, W, Cin), w: (KH, KW, Cin, Cout), b: (Cout,).
    """
    B = x.shape[0]
    KH, KW, Cin, Cout = w.shape
    cols, OH, OW = _im2col(x, KH, KW, stride, pad)
    # conv_general_dilated_patches emits channel-major (C, KH, KW) features;
    # reorder the weight matrix to match.
    wmat = w.transpose(2, 0, 1, 3).reshape(Cin * KH * KW, Cout)
    out = matmul(cols, wmat, interpret=INTERPRET)
    if relu:
        out = bias_relu(out, b, interpret=INTERPRET)
    else:
        out = out + b[None, :]
    return out.reshape(B, OH, OW, Cout)


# ---------------------------------------------------------------------------
# Dense / FC (chunkable along batch — GACER's spatial knob)
# ---------------------------------------------------------------------------

def linear(x: jax.Array, w: jax.Array, b: jax.Array, *, relu: bool = False) -> jax.Array:
    """(B, F) @ (F, N) + b, optional fused ReLU epilogue."""
    out = matmul(x, w, interpret=INTERPRET)
    if relu:
        return bias_relu(out, b, interpret=INTERPRET)
    return out + b[None, :]


def linear_chunked(x: jax.Array, w: jax.Array, b: jax.Array, *, chunk: int) -> jax.Array:
    """Batch-chunked dense layer: the AOT realization of Eq. 5.

    x: (B, F) viewed as (B, 1, F) micro-batch slabs through the chunked
    Pallas kernel; the chunk is a build-time constant so each variant
    compiles to its own artifact.
    """
    B, F = x.shape
    out = chunked_matmul(x[:, None, :], w, chunk=chunk, interpret=INTERPRET)
    return out.reshape(B, -1) + b[None, :]


# ---------------------------------------------------------------------------
# Normalization / pooling / activations (bandwidth-bound class)
# ---------------------------------------------------------------------------

def batchnorm(x: jax.Array, gamma, beta, mean, var) -> jax.Array:
    """Inference BN over NHWC via the fused Pallas FMA kernel."""
    B, H, W, C = x.shape
    flat = batchnorm_inference(
        x.reshape(B * H * W, C), gamma, beta, mean, var, interpret=INTERPRET
    )
    return flat.reshape(B, H, W, C)


def relu(x: jax.Array) -> jax.Array:
    return jnp.maximum(x, 0.0)


def maxpool2d(x: jax.Array, *, window: int = 2, stride: int = 2) -> jax.Array:
    return jax.lax.reduce_window(
        x,
        -jnp.inf,
        jax.lax.max,
        (1, window, window, 1),
        (1, stride, stride, 1),
        "VALID",
    )


def avgpool_global(x: jax.Array) -> jax.Array:
    """Global average pool (B, H, W, C) -> (B, C)."""
    return jnp.mean(x, axis=(1, 2))


# ---------------------------------------------------------------------------
# LSTM cell (the language-model tenant's repeated operator)
# ---------------------------------------------------------------------------

def lstm_cell(
    x: jax.Array,
    h: jax.Array,
    c: jax.Array,
    w_ih: jax.Array,
    w_hh: jax.Array,
    b: jax.Array,
):
    """One LSTM step. x: (B, I), h/c: (B, H), w_ih: (I, 4H), w_hh: (H, 4H)."""
    gates = matmul(x, w_ih, interpret=INTERPRET) + matmul(
        h, w_hh, interpret=INTERPRET
    ) + b[None, :]
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    c_new = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h_new = jax.nn.sigmoid(o) * jnp.tanh(c_new)
    return h_new, c_new


# ---------------------------------------------------------------------------
# Attention block (the BST recommendation tenant's operator)
# ---------------------------------------------------------------------------

def attention(
    x: jax.Array,
    wq: jax.Array,
    wk: jax.Array,
    wv: jax.Array,
    wo: jax.Array,
) -> jax.Array:
    """Single-head self-attention over (B, S, D) with Pallas GEMMs."""
    B, S, D = x.shape
    flat = x.reshape(B * S, D)
    q = matmul(flat, wq, interpret=INTERPRET).reshape(B, S, -1)
    k = matmul(flat, wk, interpret=INTERPRET).reshape(B, S, -1)
    v = matmul(flat, wv, interpret=INTERPRET).reshape(B, S, -1)
    scores = jnp.einsum("bsd,btd->bst", q, k) / jnp.sqrt(q.shape[-1]).astype(x.dtype)
    attn = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bst,btd->bsd", attn, v).reshape(B * S, -1)
    return matmul(ctx, wo, interpret=INTERPRET).reshape(B, S, D)
