"""Layer-1 Pallas kernels for the GACER compile path (build-time only)."""

from .chunked_matmul import chunk_vmem_bytes, chunked_matmul
from .fused_ops import batchnorm_inference, bias_relu
from .matmul import matmul, vmem_footprint_bytes

__all__ = [
    "matmul",
    "chunked_matmul",
    "bias_relu",
    "batchnorm_inference",
    "vmem_footprint_bytes",
    "chunk_vmem_bytes",
]
