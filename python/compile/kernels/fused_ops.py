"""Layer-1 Pallas element-wise / normalization kernels.

These are the bandwidth-bound operator class of the paper's Fig. 4 (low SM
occupancy, short duration): bias+ReLU epilogue fusion and inference-mode
batchnorm. Fusing the epilogue into one VMEM pass avoids a second HBM
round-trip — the TPU analogue of the paper's concern that small operators
underutilize the SM pool.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bias_relu_kernel(x_ref, b_ref, o_ref):
    o_ref[...] = jnp.maximum(x_ref[...] + b_ref[...], 0.0).astype(o_ref.dtype)


def bias_relu(x: jax.Array, b: jax.Array, *, block_rows: int | None = None,
              interpret: bool = True) -> jax.Array:
    """Fused y = relu(x + b) over (R, C) with b broadcast along rows."""
    R, C = x.shape
    assert b.shape == (C,), f"bias shape {b.shape} != ({C},)"
    br = block_rows or R
    while R % br:
        br -= 1
    return pl.pallas_call(
        _bias_relu_kernel,
        grid=(R // br,),
        in_specs=[
            pl.BlockSpec((br, C), lambda i: (i, 0)),
            pl.BlockSpec((C,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, C), x.dtype),
        interpret=interpret,
    )(x, b)


def _batchnorm_kernel(x_ref, scale_ref, shift_ref, o_ref):
    # scale/shift are precomputed: scale = gamma / sqrt(var + eps),
    # shift = beta - mean * scale. One fused multiply-add per element.
    o_ref[...] = (x_ref[...] * scale_ref[...] + shift_ref[...]).astype(o_ref.dtype)


def batchnorm_inference(
    x: jax.Array,
    gamma: jax.Array,
    beta: jax.Array,
    mean: jax.Array,
    var: jax.Array,
    *,
    eps: float = 1e-5,
    block_rows: int | None = None,
    interpret: bool = True,
) -> jax.Array:
    """Inference batchnorm over (R, C): per-column statistics.

    Statistics are folded into a single scale/shift outside the kernel (a
    build-time constant fold), so the kernel is one FMA per element — the
    minimal-bandwidth form.
    """
    R, C = x.shape
    scale = gamma * jax.lax.rsqrt(var + eps)
    shift = beta - mean * scale
    br = block_rows or R
    while R % br:
        br -= 1
    return pl.pallas_call(
        _batchnorm_kernel,
        grid=(R // br,),
        in_specs=[
            pl.BlockSpec((br, C), lambda i: (i, 0)),
            pl.BlockSpec((C,), lambda i: (0,)),
            pl.BlockSpec((C,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, C), x.dtype),
        interpret=interpret,
    )(x, scale, shift)
