"""Pure-jnp correctness oracles for every Layer-1 Pallas kernel.

pytest asserts allclose(kernel(...), ref(...)) — this is the core
correctness signal for the compile path (DESIGN.md §7).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(x: jax.Array, y: jax.Array) -> jax.Array:
    out_dtype = jnp.promote_types(x.dtype, y.dtype)
    return jnp.dot(x, y, preferred_element_type=jnp.float32).astype(out_dtype)


def chunked_matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    out_dtype = jnp.promote_types(x.dtype, w.dtype)
    return jnp.einsum(
        "bmk,kn->bmn", x, w, preferred_element_type=jnp.float32
    ).astype(out_dtype)


def bias_relu_ref(x: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.maximum(x + b[None, :], 0.0).astype(x.dtype)


def batchnorm_inference_ref(
    x: jax.Array,
    gamma: jax.Array,
    beta: jax.Array,
    mean: jax.Array,
    var: jax.Array,
    *,
    eps: float = 1e-5,
) -> jax.Array:
    inv = gamma / jnp.sqrt(var + eps)
    return ((x - mean[None, :]) * inv[None, :] + beta[None, :]).astype(x.dtype)
