"""Layer-1 Pallas matmul kernels — the GEMM hot-spot backing conv (im2col)
and fully-connected operators in the GACER operator library.

Hardware-adaptation note (DESIGN.md §3): the paper chunks GPU threadblock
work; here the tile is the unit of HBM->VMEM staging expressed with
`BlockSpec`, and accumulation targets the MXU (`preferred_element_type=
jnp.float32`). Kernels are lowered with `interpret=True` so they execute on
the CPU PJRT backend (real-TPU lowering emits Mosaic custom-calls that the
CPU plugin cannot run); TPU performance is estimated analytically from the
VMEM footprint + MXU utilization of the chosen tile shapes (DESIGN.md §8).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Default tile shapes. 128x128 matches the MXU systolic array; the K tile is
# sized so x-tile + y-tile + fp32 accumulator stay well under ~16 MiB VMEM:
#   vmem_bytes = (bm*bk + bk*bn) * in_bytes + bm*bn * 4
DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def vmem_footprint_bytes(bm: int, bn: int, bk: int, in_dtype=jnp.float32) -> int:
    """Analytic VMEM residency of one grid step (double-buffered inputs)."""
    in_bytes = jnp.dtype(in_dtype).itemsize
    # x tile + y tile (x2 for double buffering) + fp32 accumulator scratch.
    return 2 * (bm * bk + bk * bn) * in_bytes + bm * bn * 4


def _matmul_kernel(x_ref, y_ref, o_ref):
    """Simple variant: full-K blocks, one dot per (i, j) grid step."""
    o_ref[...] = jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _matmul_ktiled_kernel(x_ref, y_ref, o_ref, acc_ref, *, nk: int):
    """K-tiled variant: fp32 VMEM accumulator, sequential K grid axis."""

    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _pick_tile(dim: int, pref: int) -> int:
    """Largest divisor of `dim` that is <= pref (keeps grids exact)."""
    t = min(pref, dim)
    while dim % t:
        t -= 1
    return t


def matmul(
    x: jax.Array,
    y: jax.Array,
    *,
    bm: int | None = None,
    bn: int | None = None,
    bk: int | None = None,
    interpret: bool = True,
) -> jax.Array:
    """Tiled Pallas matmul: (M, K) @ (K, N) -> (M, N).

    If a K tile smaller than K is selected, the K-tiled kernel with a VMEM
    accumulator is used; otherwise the full-K single-dot kernel.
    """
    M, K = x.shape
    K2, N = y.shape
    assert K == K2, f"inner dims mismatch: {K} vs {K2}"
    bm = _pick_tile(M, bm or DEFAULT_BM)
    bn = _pick_tile(N, bn or DEFAULT_BN)
    bk = _pick_tile(K, bk or DEFAULT_BK)
    out_dtype = jnp.promote_types(x.dtype, y.dtype)
    out_shape = jax.ShapeDtypeStruct((M, N), out_dtype)

    if bk == K:
        return pl.pallas_call(
            _matmul_kernel,
            grid=(M // bm, N // bn),
            in_specs=[
                pl.BlockSpec((bm, K), lambda i, j: (i, 0)),
                pl.BlockSpec((K, bn), lambda i, j: (0, j)),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            out_shape=out_shape,
            interpret=interpret,
        )(x, y)

    nk = K // bk
    return pl.pallas_call(
        functools.partial(_matmul_ktiled_kernel, nk=nk),
        grid=(M // bm, N // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, y)
