"""Layer-1 Pallas chunked (micro-batch) matmul — GACER's spatial-regulation
knob expressed as a kernel.

The paper resizes an operator O^B into micro-batches [B^1..B^j] (Eq. 5) so
partial workloads fit SM residues. Here the micro-batch is the *grid*
dimension: each grid step stages one (chunk, M, K) slab of activations into
VMEM and runs it against the resident weights. Smaller chunks -> smaller
per-step VMEM residency -> more co-residency headroom, exactly the paper's
chunk-size <-> SM-occupancy trade-off re-expressed for a scratchpad machine
(DESIGN.md §3).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _chunked_kernel(x_ref, w_ref, o_ref):
    # One micro-batch per grid step; einsum contracts on the MXU.
    o_ref[...] = jnp.einsum(
        "bmk,kn->bmn",
        x_ref[...],
        w_ref[...],
        preferred_element_type=jnp.float32,
    ).astype(o_ref.dtype)


def chunk_vmem_bytes(chunk: int, m: int, k: int, n: int, itemsize: int = 4) -> int:
    """Per-grid-step VMEM residency: activation slab + weights + output slab."""
    return (chunk * m * k + k * n + chunk * m * n) * itemsize


def chunked_matmul(
    x: jax.Array,
    w: jax.Array,
    *,
    chunk: int | None = None,
    interpret: bool = True,
) -> jax.Array:
    """Batched matmul (B, M, K) @ (K, N) -> (B, M, N), grid over B-chunks.

    `chunk` must divide B; defaults to B (single step, no decomposition) —
    the GACER coordinator selects the chunk per its `list_B` regulation.
    """
    B, M, K = x.shape
    K2, N = w.shape
    assert K == K2, f"inner dims mismatch: {K} vs {K2}"
    chunk = chunk or B
    assert B % chunk == 0, f"chunk {chunk} must divide batch {B}"
    out_dtype = jnp.promote_types(x.dtype, w.dtype)

    return pl.pallas_call(
        _chunked_kernel,
        grid=(B // chunk,),
        in_specs=[
            pl.BlockSpec((chunk, M, K), lambda b: (b, 0, 0)),
            pl.BlockSpec((K, N), lambda b: (0, 0)),
        ],
        out_specs=pl.BlockSpec((chunk, M, N), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, M, N), out_dtype),
        interpret=interpret,
    )(x, w)
