"""Layer-2 operator library correctness vs plain-JAX references."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import ops


def _rand(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


def _conv_ref(x, w, b, stride, pad, relu):
    out = jax.lax.conv_general_dilated(
        x, w,
        window_strides=(stride, stride),
        padding=((pad, pad), (pad, pad)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    ) + b[None, None, None, :]
    return jnp.maximum(out, 0.0) if relu else out


class TestConv2d:
    def test_same_padding(self):
        x, w, b = _rand(0, (2, 8, 8, 3)), _rand(1, (3, 3, 3, 8), 0.2), _rand(2, (8,))
        np.testing.assert_allclose(
            ops.conv2d(x, w, b, stride=1, pad=1, relu=True),
            _conv_ref(x, w, b, 1, 1, True),
            atol=1e-3,
        )

    def test_stride2_no_relu(self):
        x, w, b = _rand(3, (1, 16, 16, 4)), _rand(4, (3, 3, 4, 8), 0.2), _rand(5, (8,))
        np.testing.assert_allclose(
            ops.conv2d(x, w, b, stride=2, pad=1, relu=False),
            _conv_ref(x, w, b, 2, 1, False),
            atol=1e-3,
        )

    def test_1x1_conv(self):
        x, w, b = _rand(6, (2, 4, 4, 8)), _rand(7, (1, 1, 8, 16), 0.3), _rand(8, (16,))
        np.testing.assert_allclose(
            ops.conv2d(x, w, b, stride=1, pad=0, relu=True),
            _conv_ref(x, w, b, 1, 0, True),
            atol=1e-3,
        )

    @settings(max_examples=10, deadline=None)
    @given(
        b=st.integers(1, 3),
        hw=st.sampled_from([4, 6, 8]),
        cin=st.sampled_from([1, 3, 4]),
        cout=st.sampled_from([2, 4]),
        stride=st.sampled_from([1, 2]),
    )
    def test_property_conv_sweep(self, b, hw, cin, cout, stride):
        x = _rand(b * 100 + hw, (b, hw, hw, cin))
        w = _rand(cin * 10 + cout, (3, 3, cin, cout), 0.2)
        bias = _rand(cout, (cout,))
        np.testing.assert_allclose(
            ops.conv2d(x, w, bias, stride=stride, pad=1, relu=True),
            _conv_ref(x, w, bias, stride, 1, True),
            atol=1e-3,
        )


class TestLinear:
    def test_linear(self):
        x, w, b = _rand(10, (4, 32)), _rand(11, (32, 16), 0.2), _rand(12, (16,))
        np.testing.assert_allclose(
            ops.linear(x, w, b), x @ w + b[None, :], atol=1e-4
        )

    def test_linear_relu(self):
        x, w, b = _rand(13, (4, 32)), _rand(14, (32, 16), 0.2), _rand(15, (16,))
        np.testing.assert_allclose(
            ops.linear(x, w, b, relu=True),
            jnp.maximum(x @ w + b[None, :], 0.0),
            atol=1e-4,
        )

    @settings(max_examples=12, deadline=None)
    @given(chunk=st.sampled_from([1, 2, 4, 8, 16, 32]))
    def test_property_chunked_linear_matches_full(self, chunk):
        """Eq. 5: any chunking of the batch gives the same result."""
        x, w, b = _rand(16, (32, 64)), _rand(17, (64, 16), 0.2), _rand(18, (16,))
        np.testing.assert_allclose(
            ops.linear_chunked(x, w, b, chunk=chunk),
            x @ w + b[None, :],
            atol=1e-4,
        )


class TestNormPool:
    def test_batchnorm_nhwc(self):
        x = _rand(20, (2, 4, 4, 8))
        g, be = jnp.ones(8) * 1.5, jnp.ones(8) * 0.25
        m, v = _rand(21, (8,), 0.1), jnp.abs(_rand(22, (8,))) + 0.5
        expect = (x - m) / jnp.sqrt(v + 1e-5) * g + be
        np.testing.assert_allclose(ops.batchnorm(x, g, be, m, v), expect, atol=1e-4)

    def test_maxpool(self):
        x = jnp.arange(16.0).reshape(1, 4, 4, 1)
        out = ops.maxpool2d(x)
        np.testing.assert_allclose(out.ravel(), [5.0, 7.0, 13.0, 15.0])

    def test_avgpool_global(self):
        x = jnp.ones((2, 4, 4, 3)) * 2.0
        np.testing.assert_allclose(ops.avgpool_global(x), jnp.full((2, 3), 2.0))

    def test_relu(self):
        x = jnp.array([-1.0, 0.0, 2.0])
        np.testing.assert_allclose(ops.relu(x), [0.0, 0.0, 2.0])


class TestSequenceOps:
    def test_lstm_cell_shapes_and_range(self):
        B, I, H = 4, 8, 16
        h, c = ops.lstm_cell(
            _rand(30, (B, I)), jnp.zeros((B, H)), jnp.zeros((B, H)),
            _rand(31, (I, 4 * H), 0.2), _rand(32, (H, 4 * H), 0.2),
            _rand(33, (4 * H,)),
        )
        assert h.shape == (B, H) and c.shape == (B, H)
        assert float(jnp.max(jnp.abs(h))) <= 1.0  # tanh*sigmoid bound

    def test_lstm_cell_vs_manual(self):
        B, I, H = 2, 4, 4
        x = _rand(34, (B, I))
        h0, c0 = _rand(35, (B, H)), _rand(36, (B, H))
        wih, whh = _rand(37, (I, 4 * H), 0.3), _rand(38, (H, 4 * H), 0.3)
        b = _rand(39, (4 * H,))
        gates = x @ wih + h0 @ whh + b[None, :]
        i, f, g, o = jnp.split(gates, 4, axis=-1)
        c_ref = jax.nn.sigmoid(f) * c0 + jax.nn.sigmoid(i) * jnp.tanh(g)
        h_ref = jax.nn.sigmoid(o) * jnp.tanh(c_ref)
        h, c = ops.lstm_cell(x, h0, c0, wih, whh, b)
        np.testing.assert_allclose(h, h_ref, atol=1e-5)
        np.testing.assert_allclose(c, c_ref, atol=1e-5)

    def test_attention_shape_and_rowsum(self):
        B, S, D = 2, 8, 16
        x = _rand(40, (B, S, D))
        ws = [_rand(41 + i, (D, D), 0.2) for i in range(4)]
        out = ops.attention(x, *ws)
        assert out.shape == (B, S, D)

    def test_attention_uniform_when_keys_equal(self):
        # If all sequence positions are identical, attention output is the
        # same at every position.
        B, S, D = 1, 4, 8
        x = jnp.broadcast_to(_rand(50, (B, 1, D)), (B, S, D))
        ws = [_rand(51 + i, (D, D), 0.2) for i in range(4)]
        out = ops.attention(x, *ws)
        np.testing.assert_allclose(out[0, 0], out[0, -1], atol=1e-5)
