"""Kernel-vs-reference correctness: the CORE signal for the compile path.

Every Layer-1 Pallas kernel is checked against its pure-jnp oracle in
`kernels/ref.py`, both at fixed shapes and under hypothesis-driven sweeps
of shapes, dtypes, tile sizes, and chunk partitions.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import (
    batchnorm_inference,
    bias_relu,
    chunk_vmem_bytes,
    chunked_matmul,
    matmul,
    vmem_footprint_bytes,
)
from compile.kernels import ref

jax.config.update("jax_enable_x64", False)


def _rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(jax.random.PRNGKey(key), shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------

class TestMatmul:
    def test_basic(self):
        x, y = _rand(0, (64, 48)), _rand(1, (48, 96))
        np.testing.assert_allclose(matmul(x, y), ref.matmul_ref(x, y), atol=1e-4)

    def test_full_k_path(self):
        x, y = _rand(2, (32, 16)), _rand(3, (16, 32))
        out = matmul(x, y, bk=16)  # bk == K -> single-dot kernel
        np.testing.assert_allclose(out, ref.matmul_ref(x, y), atol=1e-4)

    def test_k_tiled_path(self):
        x, y = _rand(4, (64, 128)), _rand(5, (128, 64))
        out = matmul(x, y, bk=32)  # forces the scratch-accumulator kernel
        np.testing.assert_allclose(out, ref.matmul_ref(x, y), atol=1e-3)

    def test_non_square(self):
        x, y = _rand(6, (8, 384)), _rand(7, (384, 24))
        np.testing.assert_allclose(matmul(x, y), ref.matmul_ref(x, y), atol=1e-3)

    def test_awkward_tile_dims(self):
        # 6, 10, 14 force _pick_tile to fall back to small divisors.
        x, y = _rand(8, (6, 10)), _rand(9, (10, 14))
        np.testing.assert_allclose(matmul(x, y), ref.matmul_ref(x, y), atol=1e-4)

    def test_bf16_inputs(self):
        x = _rand(10, (32, 32), jnp.bfloat16)
        y = _rand(11, (32, 32), jnp.bfloat16)
        out = matmul(x, y)
        assert out.dtype == jnp.bfloat16
        np.testing.assert_allclose(
            out.astype(jnp.float32),
            ref.matmul_ref(x, y).astype(jnp.float32),
            atol=0.25,
        )

    def test_identity(self):
        x = _rand(12, (16, 16))
        np.testing.assert_allclose(matmul(x, jnp.eye(16)), x, atol=1e-5)

    @settings(max_examples=25, deadline=None)
    @given(
        m=st.integers(1, 12),
        k=st.integers(1, 12),
        n=st.integers(1, 12),
        bm=st.integers(1, 12),
        bn=st.integers(1, 12),
        bk=st.integers(1, 12),
    )
    def test_property_shape_tile_sweep(self, m, k, n, bm, bn, bk):
        m, k, n = m * 4, k * 4, n * 4
        x = _rand(m * 131 + k, (m, k))
        y = _rand(n * 137 + k, (k, n))
        out = matmul(x, y, bm=bm, bn=bn, bk=bk)
        assert out.shape == (m, n)
        np.testing.assert_allclose(out, ref.matmul_ref(x, y), atol=1e-3)

    def test_vmem_footprint_monotone_in_tiles(self):
        assert vmem_footprint_bytes(128, 128, 128) > vmem_footprint_bytes(64, 64, 64)
        # Documented default stays under a 16 MiB VMEM budget.
        assert vmem_footprint_bytes(128, 128, 128) < 16 * 2**20


# ---------------------------------------------------------------------------
# chunked_matmul — the spatial-regulation kernel
# ---------------------------------------------------------------------------

class TestChunkedMatmul:
    def test_basic(self):
        x, w = _rand(20, (8, 16, 24)), _rand(21, (24, 32))
        np.testing.assert_allclose(
            chunked_matmul(x, w, chunk=4), ref.chunked_matmul_ref(x, w), atol=1e-4
        )

    def test_chunk_equals_batch_is_identity_partition(self):
        x, w = _rand(22, (8, 4, 8)), _rand(23, (8, 16))
        np.testing.assert_allclose(
            chunked_matmul(x, w, chunk=8), ref.chunked_matmul_ref(x, w), atol=1e-4
        )

    def test_chunk_one_finest_granularity(self):
        x, w = _rand(24, (6, 4, 8)), _rand(25, (8, 8))
        np.testing.assert_allclose(
            chunked_matmul(x, w, chunk=1), ref.chunked_matmul_ref(x, w), atol=1e-4
        )

    def test_invalid_chunk_rejected(self):
        x, w = _rand(26, (8, 4, 8)), _rand(27, (8, 8))
        with pytest.raises(AssertionError):
            chunked_matmul(x, w, chunk=3)

    @settings(max_examples=20, deadline=None)
    @given(
        b_factors=st.sampled_from([(1, 1), (2, 1), (2, 2), (4, 2), (8, 4), (6, 3), (12, 4)]),
        m=st.integers(1, 8),
        k=st.integers(1, 8),
        n=st.integers(1, 8),
    )
    def test_property_chunk_partition_invariance(self, b_factors, m, k, n):
        """concat(chunks) == full computation — Eq. 5's correctness claim."""
        b, chunk = b_factors
        m, k, n = m * 2, k * 2, n * 2
        x = _rand(b * 17 + m, (b, m, k))
        w = _rand(n * 19 + k, (k, n))
        full = chunked_matmul(x, w, chunk=b)
        split = chunked_matmul(x, w, chunk=chunk)
        np.testing.assert_allclose(split, full, atol=1e-4)
        np.testing.assert_allclose(split, ref.chunked_matmul_ref(x, w), atol=1e-3)

    def test_vmem_scales_with_chunk(self):
        small = chunk_vmem_bytes(1, 16, 64, 32)
        large = chunk_vmem_bytes(8, 16, 64, 32)
        assert large > small  # the paper's occupancy<->chunk trade-off


# ---------------------------------------------------------------------------
# fused element-wise kernels
# ---------------------------------------------------------------------------

class TestFusedOps:
    def test_bias_relu(self):
        x, b = _rand(30, (32, 16)), _rand(31, (16,))
        np.testing.assert_allclose(
            bias_relu(x, b), ref.bias_relu_ref(x, b), atol=1e-6
        )

    def test_bias_relu_clamps_negative(self):
        x = -jnp.ones((8, 4))
        b = jnp.zeros(4)
        assert float(jnp.max(bias_relu(x, b))) == 0.0

    def test_bias_relu_blocked(self):
        x, b = _rand(32, (64, 8)), _rand(33, (8,))
        np.testing.assert_allclose(
            bias_relu(x, b, block_rows=16), ref.bias_relu_ref(x, b), atol=1e-6
        )

    def test_batchnorm(self):
        x = _rand(34, (48, 12))
        gamma, beta = _rand(35, (12,)), _rand(36, (12,))
        mean, var = _rand(37, (12,), scale=0.1), jnp.abs(_rand(38, (12,))) + 0.5
        np.testing.assert_allclose(
            batchnorm_inference(x, gamma, beta, mean, var),
            ref.batchnorm_inference_ref(x, gamma, beta, mean, var),
            atol=1e-4,
        )

    def test_batchnorm_identity_stats(self):
        x = _rand(39, (16, 8))
        out = batchnorm_inference(
            x, jnp.ones(8), jnp.zeros(8), jnp.zeros(8), jnp.ones(8) - 1e-5
        )
        np.testing.assert_allclose(out, x, atol=1e-4)

    @settings(max_examples=15, deadline=None)
    @given(r=st.integers(1, 16), c=st.integers(1, 16), br=st.integers(1, 16))
    def test_property_bias_relu_block_sweep(self, r, c, br):
        x = _rand(r * 31 + c, (r * 2, c))
        b = _rand(c * 7, (c,))
        np.testing.assert_allclose(
            bias_relu(x, b, block_rows=br), ref.bias_relu_ref(x, b), atol=1e-6
        )
