"""AOT pipeline integrity: HLO-text emission + manifest round-trip.

Runs the Emitter into a temp dir on a reduced artifact set (fast), and
validates the manifest schema the Rust runtime consumes.
"""

import json
import os

import jax
import jax.numpy as jnp

from compile import aot, ops


def test_to_hlo_text_produces_parsable_module():
    lowered = jax.jit(lambda x, y: (jnp.dot(x, y),)).lower(
        jax.ShapeDtypeStruct((4, 4), jnp.float32),
        jax.ShapeDtypeStruct((4, 4), jnp.float32),
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text


def test_hlo_text_for_pallas_kernel_has_no_custom_call_to_mosaic():
    """interpret=True must lower to plain HLO the CPU PJRT client can run."""
    lowered = jax.jit(
        lambda x, w, b: ops.linear(x, w, b, relu=True)
    ).lower(
        jax.ShapeDtypeStruct((4, 64), jnp.float32),
        jax.ShapeDtypeStruct((64, 32), jnp.float32),
        jax.ShapeDtypeStruct((32,), jnp.float32),
    )
    text = aot.to_hlo_text(lowered)
    assert "tpu_custom_call" not in text.lower()


def test_emitter_manifest_schema(tmp_path):
    em = aot.Emitter(str(tmp_path))
    em.emit(
        "linear_b2",
        lambda x, w, b: ops.linear(x, w, b, relu=True),
        [
            jax.ShapeDtypeStruct((2, 64), jnp.float32),
            jax.ShapeDtypeStruct((64, 32), jnp.float32),
            jax.ShapeDtypeStruct((32,), jnp.float32),
        ],
        meta={"op": "linear", "batch": 2},
    )
    em.write_manifest()

    with open(os.path.join(tmp_path, "manifest.json")) as f:
        manifest = json.load(f)
    entry = manifest["linear_b2"]
    assert entry["path"] == "linear_b2.hlo.txt"
    assert entry["inputs"][0] == {"shape": [2, 64], "dtype": "float32"}
    assert entry["outputs"][0] == {"shape": [2, 32], "dtype": "float32"}
    assert entry["meta"]["batch"] == 2
    assert os.path.exists(os.path.join(tmp_path, entry["path"]))


def test_emitter_multiple_entries_sorted_manifest(tmp_path):
    em = aot.Emitter(str(tmp_path))
    for bsz in (1, 2):
        em.emit(
            f"lin_b{bsz}",
            lambda x, w, b: ops.linear(x, w, b),
            [
                jax.ShapeDtypeStruct((bsz, 8), jnp.float32),
                jax.ShapeDtypeStruct((8, 4), jnp.float32),
                jax.ShapeDtypeStruct((4,), jnp.float32),
            ],
        )
    em.write_manifest()
    with open(os.path.join(tmp_path, "manifest.json")) as f:
        manifest = json.load(f)
    assert set(manifest) == {"lin_b1", "lin_b2"}


def test_chunked_variant_emission(tmp_path):
    """Chunk variants must lower distinct modules (different grids)."""
    em = aot.Emitter(str(tmp_path))
    for chunk in (1, 4):
        em.emit(
            f"lc_c{chunk}",
            lambda x, w, b, _c=chunk: ops.linear_chunked(x, w, b, chunk=_c),
            [
                jax.ShapeDtypeStruct((4, 16), jnp.float32),
                jax.ShapeDtypeStruct((16, 8), jnp.float32),
                jax.ShapeDtypeStruct((8,), jnp.float32),
            ],
            meta={"chunk": chunk},
        )
    em.write_manifest()
    t1 = open(os.path.join(tmp_path, "lc_c1.hlo.txt")).read()
    t4 = open(os.path.join(tmp_path, "lc_c4.hlo.txt")).read()
    assert t1 != t4
