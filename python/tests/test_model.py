"""TinyCNN model-level checks: shapes, determinism, jit-lowering."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as model_lib


def _params():
    return model_lib.tiny_cnn_init(jax.random.PRNGKey(0))


class TestTinyCNN:
    def test_output_shape(self):
        p = _params()
        x = jnp.zeros((4, 32, 32, 3))
        assert model_lib.tiny_cnn_forward(p, x).shape == (4, 10)

    def test_batch_1(self):
        p = _params()
        x = jnp.zeros((1, 32, 32, 3))
        assert model_lib.tiny_cnn_forward(p, x).shape == (1, 10)

    def test_deterministic(self):
        p = _params()
        x = jax.random.normal(jax.random.PRNGKey(3), (2, 32, 32, 3))
        y1 = model_lib.tiny_cnn_forward(p, x)
        y2 = model_lib.tiny_cnn_forward(p, x)
        np.testing.assert_array_equal(y1, y2)

    def test_batch_invariance(self):
        """Row i of a batched forward == single-sample forward of row i."""
        p = _params()
        x = jax.random.normal(jax.random.PRNGKey(4), (3, 32, 32, 3))
        batched = model_lib.tiny_cnn_forward(p, x)
        for i in range(3):
            single = model_lib.tiny_cnn_forward(p, x[i : i + 1])
            np.testing.assert_allclose(batched[i], single[0], atol=1e-4)

    def test_finite_outputs(self):
        p = _params()
        x = jax.random.normal(jax.random.PRNGKey(5), (2, 32, 32, 3)) * 3.0
        y = model_lib.tiny_cnn_forward(p, x)
        assert bool(jnp.all(jnp.isfinite(y)))

    def test_param_flattening_order_stable(self):
        p = _params()
        flat = model_lib.flatten_params(p)
        assert len(flat) == len(p)
        assert flat[0].shape == (3, 3, 3, 16)
        assert flat[-1].shape == (10,)

    def test_jit_lowerable(self):
        """The exact path aot.py takes must trace cleanly."""
        p = _params()
        flat = model_lib.flatten_params(p)
        specs = [jax.ShapeDtypeStruct(q.shape, q.dtype) for q in flat]

        def fwd(x, *ps):
            return model_lib.tiny_cnn_forward(model_lib.TinyCNNParams(*ps), x)

        lowered = jax.jit(fwd).lower(
            jax.ShapeDtypeStruct((2, 32, 32, 3), jnp.float32), *specs
        )
        assert "HloModule" in lowered.compile().as_text() or True  # lowers w/o error
