//! SLO-driven regulation, end to end — the code companion of
//! `docs/SLO.md` (the guide's stages match the sections below).
//!
//! Walk the SLO loop: a saturated cluster where tier-major issue holds
//! the interactive p99 that fair sharing violates (the `gacer-bench slo`
//! experiment), then the engine side — SLO-tracked tenants burn their
//! error budget, admission control locks out lower tiers, and sustained
//! burn triggers `maybe_regulate` (migration or re-search). The decision
//! half runs on the simulator substrate and needs nothing but this repo
//! — CI executes it on every push; the serving half needs AOT artifacts
//! (`make artifacts`) and is skipped with a notice otherwise.
//!
//!     cargo run --release --example slo_serving

use std::time::Duration;

use gacer::bench_util::slo_sim::{run_slo_sim, saturated_mix, SloSimConfig};
use gacer::coordinator::BatchPolicy;
use gacer::models::zoo;
use gacer::prelude::*;

/// Shrunk search budget so the example runs in seconds; drop it to use
/// `SearchConfig::default()` at deployment quality.
fn quick_cfg() -> SearchConfig {
    SearchConfig {
        max_pointers: 2,
        rounds_per_level: 1,
        positions_per_coordinate: 6,
        spatial_steps_per_level: 2,
        ..Default::default()
    }
}

fn main() -> gacer::Result<()> {
    // ---- Stage 1: why tiers — the saturation experiment ----------------
    // One interactive tenant shares a saturated device with batch
    // tenants. Fair sharing gives it less than its arrival rate, so its
    // backlog (and p99) grows without bound; tier-major issue plus
    // bounded batch queues hold the target by shedding batch arrivals.
    let cfg = SloSimConfig::default();
    let regulated = run_slo_sim(&saturated_mix(), &cfg, true);
    let fair = run_slo_sim(&saturated_mix(), &cfg, false);
    println!("== saturation: tier-major issue vs fair sharing ==");
    println!(
        "  interactive p99: {:.0}us regulated vs {:.0}us fair (target {:.0}us)",
        regulated.interactive_p99_us(),
        fair.interactive_p99_us(),
        cfg.target.target_us
    );
    assert!(regulated.interactive_p99_us() <= cfg.target.target_us);
    assert!(fair.interactive_p99_us() > cfg.target.target_us);

    // ---- Stage 2: the engine's SLO loop --------------------------------
    // An interactive tenant carries an SloTarget; latency windows feed
    // the burn monitor through `record_latencies`.
    let target = SloTarget::p99_ms(1.0);
    let engine_builder = GacerEngine::builder()
        .platform(Platform::titan_v())
        .devices(2)
        .search(quick_cfg())
        .tenant_with_slo(
            zoo::build_default("R50").unwrap(),
            SloPolicy::new(Tier::Interactive),
            Some(target),
        )?
        .tenant(zoo::build_default("V16").unwrap())
        .tenant(zoo::build_default("M3").unwrap());
    let mut engine = engine_builder.build()?;
    let ids = engine.tenant_ids();

    // Serving turns out hot: every window of the interactive tenant's
    // latencies blows the 1ms target.
    let needed = engine.slo_monitor().config().sustained_page_windows;
    for _ in 0..needed {
        engine.record_latencies(&[vec![5_000.0; 100], Vec::new(), Vec::new()])?;
    }
    let pressure = engine.slo_pressure(ids[0]).expect("tracked tenant");
    println!("\n== error-budget burn ==");
    println!(
        "  tenant {}: health {} (fast burn {:.0}x, {} paging windows)",
        ids[0],
        pressure.health.label(),
        pressure.burn_fast,
        pressure.page_streak
    );
    assert_eq!(pressure.health, SloHealth::Page);

    // While the interactive tier burns, admission control refuses
    // lower-tier newcomers — the burning tier keeps its headroom.
    let refused = engine.admit(zoo::build_default("Alex").unwrap());
    assert!(matches!(refused, Err(Error::Overloaded(_))));
    println!("  admission of a standard-tier newcomer refused while paging");

    // Sustained burn is a regulation trigger: the engine migrates the
    // burning tenant to the least-loaded device (or re-searches its
    // shard at finer granularity when it is alone).
    let action = engine
        .maybe_regulate(&MigrationPolicy::default())?
        .expect("sustained burn must trigger regulation");
    println!("\n== regulation ==");
    match action {
        RegulationAction::Migrated(m) => println!(
            "  migrated burning tenant {} from device {} to {}",
            m.tenant, m.from, m.to
        ),
        RegulationAction::Resharded { device } => {
            println!("  re-searched device {device} at finer granularity")
        }
    }
    // One burn episode, one action: the monitor history restarts, so
    // the burn trigger stays quiet until violations re-accumulate...
    let after = engine.slo_pressure(ids[0]).expect("still tracked after acting");
    assert_eq!(after.page_streak, 0, "burn history restarted");
    // ...and the admission gate opens again.
    let admitted = engine.admit(zoo::build_default("Alex").unwrap())?;
    println!(
        "  burn history restarted; admission gate open again (Alex -> device {})",
        engine.device_of(admitted)?
    );

    // ---- Stage 3: tiered serving on real artifacts ---------------------
    // Requires AOT artifacts; everything above this line is the decision
    // path CI executes on the simulator substrate.
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("\n(serving half skipped: run `make artifacts` first)");
        return Ok(());
    }
    let policy = BatchPolicy::new(8, Duration::from_millis(2), vec![1, 2, 4, 8, 16, 32]);
    let mut serving = GacerEngine::builder()
        .platform(Platform::titan_v())
        .devices(2)
        .search(quick_cfg())
        .artifacts("artifacts")
        .serving_tenant_with_slo(
            "chat",
            "tiny_cnn",
            policy.clone(),
            SloPolicy::new(Tier::Interactive).with_deadline(Duration::from_millis(200)),
            Some(SloTarget::p99_ms(50.0)),
        )?
        .serving_tenant_with_slo(
            "batch",
            "tiny_cnn",
            policy,
            SloPolicy::new(Tier::Batch).with_queue_cap(64),
            None,
        )?
        .build()?;
    let cluster = serving.serve_cluster()?;
    let input: Vec<f32> =
        (0..32 * 32 * 3).map(|k| ((k % 97) as f32 / 97.0) - 0.5).collect();
    println!("\n== tiered serving ==");
    let mut samples: Vec<Vec<f64>> = vec![Vec::new(); 2];
    for _ in 0..16 {
        for (t, window) in samples.iter_mut().enumerate() {
            let t0 = std::time::Instant::now();
            match cluster.infer(t, input.clone()) {
                Ok(out) => {
                    assert_eq!(out.len(), 10);
                    window.push(t0.elapsed().as_secs_f64() * 1e6);
                }
                // Shed requests are the scheduler doing its job under
                // overload, not failures.
                Err(Error::Overloaded(_)) | Err(Error::DeadlineExceeded(_)) => {}
                Err(e) => return Err(e),
            }
        }
    }
    serving.record_latencies(&samples)?;
    for (id, p) in serving.slo_pressures() {
        println!(
            "  tenant {id}: health {} (fast burn {:.2}, slow burn {:.2})",
            p.health.label(),
            p.burn_fast,
            p.burn_slow
        );
    }
    println!("  interactive issues first; late or over-cap requests shed typed errors");
    Ok(())
}
