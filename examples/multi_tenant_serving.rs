//! End-to-end serving driver (DESIGN.md's e2e validation): load the real
//! AOT-compiled TinyCNN artifacts, serve batched requests for three
//! tenants through the coordinator under two deployment policies —
//! unregulated vs GACER-informed (priority order + micro-batch chunking) —
//! and report latency/throughput. Results are recorded in EXPERIMENTS.md.
//!
//! Requires `make artifacts` first.
//!
//!     cargo run --release --example multi_tenant_serving [-- --requests 64]

use std::sync::Arc;
use std::time::{Duration, Instant};

use gacer::coordinator::{BatchPolicy, Server, ServerConfig, TenantSpec};
use gacer::metrics::LatencyHistogram;
use gacer::util::cli::Args;

fn tenant(name: &str, max_batch: usize, chunk: Option<usize>) -> TenantSpec {
    TenantSpec {
        name: name.to_string(),
        family: "tiny_cnn".to_string(),
        policy: BatchPolicy::new(max_batch, Duration::from_millis(2), vec![1, 2, 4, 8, 16, 32]),
        chunk,
    }
}

fn drive(server: Arc<Server>, n_tenants: usize, requests: usize) -> (Vec<LatencyHistogram>, f64) {
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for t in 0..n_tenants {
        let server = Arc::clone(&server);
        handles.push(std::thread::spawn(move || {
            let mut hist = LatencyHistogram::new();
            for i in 0..requests {
                let x: Vec<f32> = (0..32 * 32 * 3)
                    .map(|k| (((t * 7919 + i * 131 + k) % 97) as f32 / 97.0) - 0.5)
                    .collect();
                let q0 = Instant::now();
                let out = server.infer(t, x).expect("inference failed");
                hist.record(q0.elapsed());
                assert_eq!(out.len(), 10);
                assert!(out.iter().all(|v| v.is_finite()));
            }
            hist
        }));
    }
    let hists: Vec<LatencyHistogram> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let elapsed = t0.elapsed().as_secs_f64();
    let total = (n_tenants * requests) as f64;
    (hists, total / elapsed)
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let requests = args.opt_usize("requests", 48);
    let artifacts = args.opt_or("artifacts", "artifacts").to_string();
    if !std::path::Path::new(&artifacts).join("manifest.json").exists() {
        anyhow::bail!("artifacts not found — run `make artifacts` first");
    }

    println!("== multi-tenant serving: 3 x TinyCNN tenants, {requests} requests each ==\n");

    // Policy A: unregulated (arrival order, no chunking) — the
    // Stream-Parallel analogue on the real path.
    let plain = Arc::new(Server::start(
        &artifacts,
        vec![tenant("t0", 8, None), tenant("t1", 8, None), tenant("t2", 8, None)],
        ServerConfig::default(),
    )?);
    // Warm the executor (first batch pays PJRT compilation for its size).
    let _ = plain.infer(0, vec![0.0; 32 * 32 * 3]);
    let (hists_a, rps_a) = drive(Arc::clone(&plain), 3, requests);

    // Policy B: GACER-informed — tenant 0 is decomposed into micro-batches
    // of 4 (the plan's list_B realized with compiled variants) and the
    // issue order prioritizes the latency-sensitive tenants.
    let gacer = Arc::new(Server::start(
        &artifacts,
        vec![tenant("t0", 16, Some(4)), tenant("t1", 8, None), tenant("t2", 4, None)],
        ServerConfig { issue_order: vec![2, 1, 0], ..Default::default() },
    )?);
    let _ = gacer.infer(0, vec![0.0; 32 * 32 * 3]);
    let (hists_b, rps_b) = drive(Arc::clone(&gacer), 3, requests);

    println!(
        "note: on the CPU-PJRT substrate micro-batching trades throughput for\n\
         issue-granularity (the regulated policy's win on a real GPU is\n\
         occupancy packing, which a CPU backend cannot express) — this driver\n\
         validates the MECHANISM end to end: chunked plans produce identical\n\
         numerics with bounded latency cost.\n"
    );
    println!("policy             throughput      per-tenant latency");
    println!(
        "unregulated        {rps_a:>7.1} req/s   p50 {:?}",
        hists_a.iter().map(|h| format!("{:.1}ms", h.percentile_us(0.5) / 1e3)).collect::<Vec<_>>()
    );
    println!(
        "gacer-informed     {rps_b:>7.1} req/s   p50 {:?}",
        hists_b.iter().map(|h| format!("{:.1}ms", h.percentile_us(0.5) / 1e3)).collect::<Vec<_>>()
    );
    for (label, hists) in [("unregulated", &hists_a), ("gacer-informed", &hists_b)] {
        for (t, h) in hists.iter().enumerate() {
            println!("  {label:<15} tenant {t}: {}", h.summary());
        }
    }
    Ok(())
}
