//! End-to-end serving driver (DESIGN.md's e2e validation): build a
//! [`GacerEngine`] over three TinyCNN tenants, let the granularity-aware
//! search produce the deployment plan, and serve batched requests through
//! the coordinator under two deployments — the unregulated plan vs the
//! searched plan — both lowered by the engine (no hand-set `chunk` or
//! `issue_order` anywhere). For the multi-device variant of this flow see
//! `examples/sharded_serving.rs`.
//!
//! Requires `make artifacts` first.
//!
//!     cargo run --release --example multi_tenant_serving [-- --requests 64]

use std::sync::Arc;
use std::time::{Duration, Instant};

use gacer::coordinator::{BatchPolicy, Server};
use gacer::metrics::LatencyHistogram;
use gacer::plan::DeploymentPlan;
use gacer::prelude::*;
use gacer::util::cli::Args;

fn drive(server: Arc<Server>, n_tenants: usize, requests: usize) -> (Vec<LatencyHistogram>, f64) {
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for t in 0..n_tenants {
        let server = Arc::clone(&server);
        handles.push(std::thread::spawn(move || {
            let mut hist = LatencyHistogram::new();
            for i in 0..requests {
                let x: Vec<f32> = (0..32 * 32 * 3)
                    .map(|k| (((t * 7919 + i * 131 + k) % 97) as f32 / 97.0) - 0.5)
                    .collect();
                let q0 = Instant::now();
                let out = server.infer(t, x).expect("inference failed");
                hist.record(q0.elapsed());
                assert_eq!(out.len(), 10);
                assert!(out.iter().all(|v| v.is_finite()));
            }
            hist
        }));
    }
    let hists: Vec<LatencyHistogram> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let elapsed = t0.elapsed().as_secs_f64();
    let total = (n_tenants * requests) as f64;
    (hists, total / elapsed)
}

fn main() -> gacer::Result<()> {
    let args = Args::from_env();
    let requests = args.opt_usize("requests", 48);
    let artifacts = args.opt_or("artifacts", "artifacts").to_string();
    if !std::path::Path::new(&artifacts).join("manifest.json").exists() {
        return Err(gacer::Error::Artifact(
            "artifacts not found — run `make artifacts` first".into(),
        ));
    }

    println!("== multi-tenant serving: 3 x TinyCNN tenants, {requests} requests each ==\n");

    // One engine owns the tenant set; the search runs once at build time.
    let mut builder = GacerEngine::builder()
        .platform(Platform::titan_v())
        .artifacts(artifacts.as_str());
    for (i, max_batch) in [16usize, 8, 4].into_iter().enumerate() {
        builder = builder.serving_tenant(
            format!("t{i}"),
            "tiny_cnn",
            BatchPolicy::new(max_batch, Duration::from_millis(2), vec![1, 2, 4, 8, 16, 32]),
        )?;
    }
    let engine = builder.build()?;

    // Policy A: the unregulated plan lowered to a deployment — the
    // Stream-Parallel analogue on the real path.
    let unregulated = engine.deployment_of(&DeploymentPlan::unregulated(engine.len()))?;
    // Policy B: the searched plan lowered to a deployment.
    let searched = engine.deployment()?;
    println!(
        "searched plan: {} decomposed ops, issue order {:?}, chunks {:?}, quanta {:?}\n",
        engine.plan().decomposed_ops(),
        searched.config.issue_order,
        searched.tenants.iter().map(|t| t.chunk).collect::<Vec<_>>(),
        searched.config.issue_quanta,
    );

    let plain = Arc::new(Server::start(
        &artifacts,
        unregulated.tenants.clone(),
        unregulated.config.clone(),
    )?);
    // Warm the executor (first batch pays PJRT compilation for its size).
    let _ = plain.infer(0, vec![0.0; 32 * 32 * 3]);
    let (hists_a, rps_a) = drive(Arc::clone(&plain), 3, requests);

    let gacer_server = Arc::new(Server::start(
        &artifacts,
        searched.tenants.clone(),
        searched.config.clone(),
    )?);
    let _ = gacer_server.infer(0, vec![0.0; 32 * 32 * 3]);
    let (hists_b, rps_b) = drive(Arc::clone(&gacer_server), 3, requests);

    println!(
        "note: on the CPU-PJRT substrate micro-batching trades throughput for\n\
         issue-granularity (the regulated policy's win on a real GPU is\n\
         occupancy packing, which a CPU backend cannot express) — this driver\n\
         validates the MECHANISM end to end: the searched plan's chunking and\n\
         issue order reach the scheduler and produce identical numerics with\n\
         bounded latency cost.\n"
    );
    println!("policy             throughput      per-tenant latency");
    println!(
        "unregulated        {rps_a:>7.1} req/s   p50 {:?}",
        hists_a.iter().map(|h| format!("{:.1}ms", h.percentile_us(0.5) / 1e3)).collect::<Vec<_>>()
    );
    println!(
        "gacer-searched     {rps_b:>7.1} req/s   p50 {:?}",
        hists_b.iter().map(|h| format!("{:.1}ms", h.percentile_us(0.5) / 1e3)).collect::<Vec<_>>()
    );
    for (label, hists) in [("unregulated", &hists_a), ("gacer-searched", &hists_b)] {
        for (t, h) in hists.iter().enumerate() {
            println!("  {label:<15} tenant {t}: {}", h.summary());
        }
    }
    Ok(())
}
