//! Live re-deployment, end to end — the code companion of
//! `docs/OPERATIONS.md` (the guide's lifecycle stages match the sections
//! below).
//!
//! Walk the serving lifecycle on a 2-device deployment: build → admit
//! (one shard re-searched) → plan diff (what a redeploy would touch) →
//! load-drift migration (two shards re-searched) → hot swap onto running
//! servers. The decision half runs on the simulator substrate and needs
//! nothing but this repo — CI executes it on every push; the serving
//! half needs AOT artifacts (`make artifacts`) and is skipped with a
//! notice otherwise.
//!
//!     cargo run --release --example live_redeploy

use std::time::Duration;

use gacer::coordinator::BatchPolicy;
use gacer::models::zoo;
use gacer::prelude::*;

/// Shrunk search budget so the example runs in seconds; drop it to use
/// `SearchConfig::default()` at deployment quality.
fn quick_cfg() -> SearchConfig {
    SearchConfig {
        max_pointers: 2,
        rounds_per_level: 1,
        positions_per_coordinate: 6,
        spatial_steps_per_level: 2,
        ..Default::default()
    }
}

fn main() -> gacer::Result<()> {
    // ---- Stage 1: build a sharded deployment ---------------------------
    let mut b = GacerEngine::builder()
        .platform(Platform::titan_v())
        .devices(2)
        .search(quick_cfg());
    for name in ["R50", "V16", "R18", "M3"] {
        b = b.tenant(zoo::build_default(name).unwrap());
    }
    let mut engine = b.build()?;
    println!("== build ==");
    for d in 0..engine.n_devices() {
        println!(
            "  device {d}: tenants {:?}",
            engine.placement().tenants_on(d)
        );
    }

    // ---- Stage 2: admit, and diff what changed -------------------------
    // Admission re-searches ONE shard. The plan diff is exactly what a
    // live redeploy consults: unaffected devices are untouched.
    let before = engine.sharded_plan().clone();
    let id = engine.admit(zoo::build_default("Alex").unwrap())?;
    let changed = engine.sharded_plan().changed_devices(&before);
    println!("\n== admit ==");
    println!(
        "  Alex -> device {}; changed devices: {changed:?} (one shard re-searched)",
        engine.device_of(id)?
    );
    assert_eq!(changed, vec![engine.device_of(id)?]);

    // ---- Stage 3: load drift -> migration ------------------------------
    // Traffic turns out skewed: every tenant on one device runs hot. The
    // MigrationPolicy watches the observed max/min device-load ratio and
    // proposes the single move that best shrinks the bottleneck; the
    // engine executes it as a TWO-shard seeded re-search.
    let hot_device = (0..2)
        .find(|&d| engine.placement().tenants_on(d).len() >= 2)
        .expect("5 tenants on 2 devices: one device shares");
    let hot_slots: Vec<usize> = engine.placement().tenants_on(hot_device).to_vec();
    for (slot, tid) in engine.tenant_ids().into_iter().enumerate() {
        if hot_slots.contains(&slot) {
            engine.record_requests(tid, 10_000)?;
        }
    }
    println!("\n== load drift ==");
    println!(
        "  observed device loads: {:?}",
        engine
            .observed_device_loads()
            .iter()
            .map(|l| format!("{l:.0}"))
            .collect::<Vec<_>>()
    );
    let before = engine.sharded_plan().clone();
    let migration = engine
        .maybe_migrate(&MigrationPolicy::default())?
        .expect("fully skewed load must trigger a migration");
    println!(
        "  migrated {} from device {} to {}; re-searched devices {:?}",
        migration.tenant,
        migration.from,
        migration.to,
        engine.last_searched_devices()
    );
    // Migration records carry stable DeviceIds; the plan diff speaks
    // dense indices — translate through the pool.
    let pool = engine.device_pool();
    let mut expected = vec![
        pool.index_of(migration.from).unwrap(),
        pool.index_of(migration.to).unwrap(),
    ];
    expected.sort_unstable();
    assert_eq!(engine.sharded_plan().changed_devices(&before), expected);
    engine.sharded_plan().validate(engine.tenants())?;

    // ---- Stage 4: hot swap onto running servers ------------------------
    // Requires AOT artifacts; everything above this line is the decision
    // path CI executes on the simulator substrate.
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("\n(serving half skipped: run `make artifacts` first)");
        return Ok(());
    }
    let policy = BatchPolicy::new(8, Duration::from_millis(2), vec![1, 2, 4, 8, 16, 32]);
    let mut b = GacerEngine::builder()
        .platform(Platform::titan_v())
        .devices(2)
        .search(quick_cfg())
        .artifacts("artifacts");
    for i in 0..4 {
        b = b.serving_tenant(format!("tiny-{i}"), "tiny_cnn", policy.clone())?;
    }
    let mut serving = b.build()?;
    let cluster = serving.serve_cluster()?;
    let input = |t: usize| -> Vec<f32> {
        (0..32 * 32 * 3)
            .map(|k| (((t * 7919 + k) % 97) as f32 / 97.0) - 0.5)
            .collect()
    };
    println!("\n== hot swap on a running cluster ==");
    for t in 0..4 {
        assert_eq!(cluster.infer(t, input(t))?.len(), 10);
    }
    // Admit against the RUNNING cluster and swap the plan in: requests
    // keep flowing, only the admitting device is touched, and the new
    // tenant serves immediately after the fence.
    serving.admit_serving("tiny-live", "tiny_cnn", policy)?;
    let touched = serving.redeploy_cluster(&cluster)?;
    println!(
        "  admitted tiny-live; hot-swapped devices {touched:?}; epochs {:?}",
        cluster.epochs()
    );
    for t in 0..5 {
        assert_eq!(cluster.infer(t, input(t))?.len(), 10, "tenant {t} serves");
    }
    println!("  all 5 tenants serving through the swapped deployment — no restart");
    Ok(())
}
