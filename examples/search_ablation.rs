//! Granularity ablation: sweep temporal granularity (Fig. 9 style) and
//! spatial decomposition depth (Table 3 style) on a chosen combo, then
//! compare with what the joint search picks — showing the "sweet zone"
//! and that Algorithm 1 lands inside it.
//!
//!     cargo run --release --example search_ablation [-- --models R50,V16,M3]

use gacer::models::zoo;
use gacer::plan::{DeploymentPlan, TenantSet};
use gacer::profile::{CostModel, Platform};
use gacer::gpu::SimOptions;
use gacer::search::{GacerSearch, SearchConfig};
use gacer::temporal::PointerMatrix;
use gacer::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let names: Vec<String> = args
        .opt_or("models", "R50,V16,M3")
        .split(',')
        .map(|s| s.trim().to_string())
        .collect();
    let refs: Vec<&str> = names.iter().map(String::as_str).collect();
    let platform = Platform::titan_v();
    let cost = CostModel::new(platform);
    let tenants = zoo::build_combo(&refs);
    let ts = TenantSet::new(tenants.clone(), cost.clone());
    let opts = SimOptions::for_platform(&platform);

    println!("== temporal granularity sweep: {} ==", zoo::combo_label(&refs));
    let mut best_fixed = f64::INFINITY;
    for k in [1usize, 2, 3, 4, 6, 8, 12, 16] {
        let plan = DeploymentPlan {
            chunking: vec![Default::default(); tenants.len()],
            pointers: PointerMatrix::equal_segments(&tenants, k),
        };
        let out = ts.simulate(&plan, opts);
        best_fixed = best_fixed.min(out.makespan_us);
        println!(
            "  segment-{k:<3} {:>9.2} ms   util {:>5.1}%   sync idle {:>7.1} us",
            out.makespan_us / 1e3,
            out.avg_utilization,
            out.sync_idle_us
        );
    }
    let op_wise = DeploymentPlan {
        chunking: vec![Default::default(); tenants.len()],
        pointers: PointerMatrix::operator_wise(&tenants),
    };
    let out = ts.simulate(&op_wise, opts);
    println!(
        "  operator-wise {:>7.2} ms   util {:>5.1}%   sync idle {:>7.1} us   <- overhead-dominated",
        out.makespan_us / 1e3,
        out.avg_utilization,
        out.sync_idle_us
    );

    println!("\n== spatial decomposition depth sweep (uniform split of all chunkable convs) ==");
    for pieces in [1usize, 2, 4, 8] {
        let mut plan = DeploymentPlan::unregulated(tenants.len());
        if pieces > 1 {
            for (ti, d) in tenants.iter().enumerate() {
                for op in &d.ops {
                    if op.chunkable() && op.kind.class() == "conv" && op.batch % pieces == 0 {
                        plan.chunking[ti].insert(op.id, vec![op.batch / pieces; pieces]);
                    }
                }
            }
        }
        let out = ts.simulate(&plan, opts);
        println!(
            "  split x{pieces}: {:>9.2} ms   util {:>5.1}%   overhead work {:>8.0} %us",
            out.makespan_us / 1e3,
            out.avg_utilization,
            out.overhead_sm_time
        );
    }

    println!("\n== joint search (Algorithm 1) ==");
    let report = GacerSearch::new(&ts, opts, SearchConfig::default()).run();
    println!(
        "  GACER: {:>9.2} ms  (fixed-granularity best was {:.2} ms; search \
         used {} evaluations, {:?})",
        report.outcome.makespan_us / 1e3,
        best_fixed / 1e3,
        report.evaluations,
        report.elapsed
    );
    assert!(
        report.outcome.makespan_us <= best_fixed * 1.05,
        "the searched plan should land at or inside the sweet zone"
    );
}
