//! Online deployment scenario (§4.4 "Search Cost Analysis"): tenants
//! arrive and leave; the coordinator re-runs the GACER search on each
//! change and reports how quickly near-optimal plans are recovered —
//! demonstrating that the modeling-based search is cheap enough for
//! online use ("acceptable for tasks that care about throughput and are
//! not sensitive to real-time").
//!
//!     cargo run --release --example online_adaptation

use std::time::Instant;

use gacer::gpu::SimOptions;
use gacer::models::zoo;
use gacer::plan::{DeploymentPlan, TenantSet};
use gacer::profile::{CostModel, Platform};
use gacer::search::{GacerSearch, SearchConfig};

fn main() {
    let platform = Platform::titan_v();
    let cost = CostModel::new(platform);
    let opts = SimOptions::for_platform(&platform);

    // A day in the life of a shared GPU: tenants join and leave.
    let timeline: [(&str, Vec<&str>); 6] = [
        ("boot: vision pair", vec!["R18", "M3"]),
        ("V16 arrives", vec!["R18", "M3", "V16"]),
        ("R18 leaves, LSTM arrives", vec!["M3", "V16", "LSTM"]),
        ("recommender joins", vec!["M3", "V16", "LSTM", "BST"]),
        ("V16 leaves", vec!["M3", "LSTM", "BST"]),
        ("heavy vision returns", vec!["R50", "M3", "LSTM"]),
    ];

    println!("== online adaptation: re-search on every tenant change ==\n");
    println!(
        "{:<28} {:>8} {:>12} {:>12} {:>9} {:>12}",
        "event", "tenants", "SP (ms)", "GACER (ms)", "gain", "search time"
    );

    let mut total_search = std::time::Duration::ZERO;
    for (event, names) in timeline {
        let tenants = zoo::build_combo(&names);
        let ts = TenantSet::new(&tenants, &cost);
        let unregulated = ts.simulate(&DeploymentPlan::unregulated(tenants.len()), opts);
        let t0 = Instant::now();
        let report = GacerSearch::new(&ts, opts, SearchConfig::default()).run();
        let took = t0.elapsed();
        total_search += took;
        println!(
            "{:<28} {:>8} {:>12.2} {:>12.2} {:>8.2}x {:>12.2?}",
            event,
            tenants.len(),
            unregulated.makespan_us / 1e3,
            report.outcome.makespan_us / 1e3,
            unregulated.makespan_us / report.outcome.makespan_us,
            took
        );
        // Online requirement: the plan must never be worse than the
        // unregulated deployment we could fall back to.
        assert!(report.outcome.makespan_us <= unregulated.makespan_us * 1.0001);
    }
    println!(
        "\ntotal search time across 6 reconfigurations: {total_search:.2?} \
         (amortized {:.2?} per event — offline-quality plans at online cost)",
        total_search / 6
    );
}
