//! Online deployment scenario (§4.4 "Search Cost Analysis"): tenants
//! arrive and leave; the [`GacerEngine`] re-plans on each change via the
//! incremental seeded re-search (`GacerSearch::run_from`) and reports how
//! quickly near-optimal plans are recovered — demonstrating that the
//! modeling-based search is cheap enough for online use ("acceptable for
//! tasks that care about throughput and are not sensitive to real-time").
//!
//! The timeline runs on a single device; the engine's device dimension
//! makes churn *cheaper still* on a pool — admission control places each
//! newcomer on the least loaded device and re-searches only that shard
//! (see `examples/sharded_serving.rs` and `docs/TUTORIAL.md`). The coda
//! below replays the final tenant mix on a 2-device engine to show the
//! sharded re-plan cost side by side.
//!
//!     cargo run --release --example online_adaptation

use std::time::Instant;

use gacer::models::zoo;
use gacer::prelude::*;

fn report_event(engine: &GacerEngine, event: &str, took: std::time::Duration) {
    // SearchReport::initial is the unregulated (Stream-Parallel) outcome
    // of the current tenant set — the fallback deployment.
    let r = engine.last_report().expect("engine has tenants");
    println!(
        "{:<28} {:>8} {:>12.2} {:>12.2} {:>8.2}x {:>8} {:>12.2?}",
        event,
        engine.len(),
        r.initial.makespan_us / 1e3,
        r.outcome.makespan_us / 1e3,
        r.initial.makespan_us / r.outcome.makespan_us,
        r.evaluations,
        took
    );
    // Online requirement: the plan must never be worse than the
    // unregulated deployment we could fall back to (same slack as the
    // search's own never-worse test).
    assert!(r.outcome.makespan_us <= r.initial.makespan_us * 1.001);
}

fn main() -> gacer::Result<()> {
    // A day in the life of a shared GPU: tenants join and leave. Each
    // event is an engine call; the engine owns the tenant set and re-plans
    // incrementally from the surviving configuration.
    let mut engine = GacerEngine::builder()
        .platform(Platform::titan_v())
        .tenant(zoo::build_default("R18").unwrap())
        .tenant(zoo::build_default("M3").unwrap())
        .build()?;

    println!("== online adaptation: engine admit/evict with incremental re-search ==\n");
    println!(
        "{:<28} {:>8} {:>12} {:>12} {:>9} {:>8} {:>12}",
        "event", "tenants", "SP (ms)", "GACER (ms)", "gain", "evals", "re-plan time"
    );

    let mut ids: Vec<(String, TenantId)> = engine
        .tenants()
        .iter()
        .map(|d| d.name.clone())
        .zip(engine.tenant_ids())
        .collect();

    report_event(&engine, "boot: vision pair", std::time::Duration::ZERO);

    // (event label, evict name, admit name)
    let timeline: [(&str, Option<&str>, Option<&str>); 5] = [
        ("V16 arrives", None, Some("V16")),
        ("R18 leaves", Some("R18"), None),
        ("LSTM arrives", None, Some("LSTM")),
        ("recommender joins", None, Some("BST")),
        ("V16 leaves, R50 returns", Some("V16"), Some("R50")),
    ];

    let mut total = std::time::Duration::ZERO;
    for (event, out_name, in_name) in timeline {
        let t0 = Instant::now();
        if let Some(name) = out_name {
            let pos = ids.iter().position(|(n, _)| n == name).expect("deployed");
            let (_, id) = ids.remove(pos);
            engine.evict(id)?;
        }
        if let Some(name) = in_name {
            let id = engine.admit(zoo::build_default(name).unwrap())?;
            ids.push((name.to_string(), id));
        }
        let took = t0.elapsed();
        total += took;
        report_event(&engine, event, took);
    }

    println!(
        "\ntotal re-plan time across {} reconfigurations: {total:.2?} \
         (amortized {:.2?} per event — offline-quality plans at online cost)",
        timeline.len(),
        total / timeline.len() as u32
    );

    // Coda: the same surviving mix on a 2-device engine. Churn now
    // re-searches one shard only, so each event prices at a fraction of
    // even the single-device incremental re-plan.
    let mut pool = GacerEngine::builder().platform(Platform::titan_v()).devices(2);
    for dfg in engine.tenants() {
        pool = pool.tenant(dfg.clone());
    }
    let mut pool = pool.build()?;
    let t0 = Instant::now();
    let id = pool.admit(zoo::build_default("V16").unwrap())?;
    let took = t0.elapsed();
    let device = pool.device_of(id)?;
    println!(
        "\n2-device coda: V16 admitted to device {device} in {took:.2?} \
         (only that shard re-searched; cluster makespan {:.2} ms)",
        pool.simulate().makespan_us / 1e3
    );
    Ok(())
}
