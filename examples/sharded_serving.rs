//! Multi-GPU sharded deployment, end to end — the code companion of
//! `docs/TUTORIAL.md` (the tutorial's numbered steps match the sections
//! below).
//!
//! Build a tenant set, search it, shard it across 2 simulated devices,
//! exercise cross-device admission control (admit/evict re-search only
//! the affected shard), and — when AOT artifacts are present — serve
//! real inference through one coordinator per device behind the
//! [`ClusterServer`] routing front-end.
//!
//!     cargo run --release --example sharded_serving
//!
//! The simulation half needs nothing but this repo; the serving half
//! requires `make artifacts` and is skipped with a notice otherwise.

use std::time::Duration;

use gacer::coordinator::BatchPolicy;
use gacer::models::zoo;
use gacer::prelude::*;

fn main() -> gacer::Result<()> {
    // ---- Step 1: build a multi-tenant engine on ONE device ------------
    // Four heterogeneous tenants sharing a single simulated Titan V.
    let combo = ["R50", "V16", "R18", "M3"];
    let quick = SearchConfig {
        max_pointers: 2,
        rounds_per_level: 1,
        positions_per_coordinate: 6,
        spatial_steps_per_level: 2,
        ..Default::default()
    };
    let mut single = GacerEngine::builder().platform(Platform::titan_v()).search(quick);
    for name in combo {
        single = single.tenant(zoo::build_default(name).unwrap());
    }
    let single = single.build()?;
    let one_dev = single.simulate();
    println!("== 1 device ==");
    println!(
        "  all {} tenants co-located: makespan {:.2} ms",
        single.len(),
        one_dev.makespan_us / 1e3
    );

    // ---- Step 2: the same tenants sharded across 2 devices ------------
    // `.devices(2)` adds the device dimension: a cost-model-driven
    // placement shards the tenant set, and each device gets its own
    // granularity-aware search (one chunk map + pointer matrix per shard).
    let mut sharded = GacerEngine::builder()
        .platform(Platform::titan_v())
        .devices(2)
        .search(quick);
    for name in combo {
        sharded = sharded.tenant(zoo::build_default(name).unwrap());
    }
    let mut engine = sharded.build()?;
    engine.sharded_plan().validate(engine.tenants()).unwrap();

    println!("\n== 2 devices ==");
    let sims = engine.simulate_devices();
    for (d, sim) in sims.iter().enumerate() {
        let names: Vec<&str> = engine
            .placement()
            .tenants_on(d)
            .iter()
            .map(|&s| engine.tenants()[s].name.as_str())
            .collect();
        println!(
            "  device {d}: {names:?}  makespan {:.2} ms",
            sim.makespan_us / 1e3
        );
    }
    let cluster = engine.simulate();
    println!(
        "  cluster makespan (bottleneck device): {:.2} ms  ({:.2}x vs 1 device)",
        cluster.makespan_us / 1e3,
        one_dev.makespan_us / cluster.makespan_us
    );

    // ---- Step 3: cross-device admission control ------------------------
    // A newcomer lands on the least loaded device; ONLY that shard is
    // re-searched (seeded incremental re-plan), the other shard's plan is
    // untouched.
    let before = engine.sharded_plan().clone();
    let id = engine.admit(zoo::build_default("Alex").unwrap())?;
    let device = engine.device_of(id)?;
    assert_eq!(engine.last_searched_device(), Some(device));
    let other = 1 - device;
    assert_eq!(
        engine.sharded_plan().shards[other], before.shards[other],
        "untouched shard must not be re-searched"
    );
    println!(
        "\nadmit Alex -> device {device} (least loaded); \
         device {other}'s plan untouched"
    );

    // ---- Step 4: evict, including a device's last tenant ---------------
    engine.evict(id)?;
    println!("evict Alex -> device {device} re-planned alone");
    engine.sharded_plan().validate(engine.tenants()).unwrap();

    // ---- Step 5: serve through one coordinator per device --------------
    // Requires AOT artifacts (`make artifacts`); each device runs its own
    // scheduler + executor, and the ClusterServer routes every request to
    // its tenant's device.
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("\n(serving half skipped: run `make artifacts` first)");
        return Ok(());
    }
    let policy = BatchPolicy::new(8, Duration::from_millis(2), vec![1, 2, 4, 8, 16, 32]);
    let mut b = GacerEngine::builder()
        .platform(Platform::titan_v())
        .devices(2)
        .search(quick)
        .artifacts("artifacts");
    for i in 0..4 {
        b = b.serving_tenant(format!("tiny-{i}"), "tiny_cnn", policy.clone())?;
    }
    let mut serving = b.build()?;
    let cluster = serving.serve_cluster()?;
    println!("\nserving 4 tenants on {} devices:", cluster.n_devices());
    let input = |t: usize| -> Vec<f32> {
        (0..32 * 32 * 3)
            .map(|k| (((t * 7919 + k) % 97) as f32 / 97.0) - 0.5)
            .collect()
    };
    for t in 0..4 {
        let out = cluster.infer(t, input(t))?;
        let (d, l) = cluster.route_of(t).unwrap();
        println!("  tenant {t} -> device {d} slot {l}: {} logits", out.len());
    }

    // ---- Step 6: admit against the RUNNING cluster, then redeploy ------
    // No restart: the engine re-searches one shard and `redeploy_cluster`
    // hot-swaps it into the live servers (epoch-fenced; queued requests
    // survive). See docs/OPERATIONS.md for the full lifecycle.
    serving.admit_serving("tiny-late", "tiny_cnn", policy)?;
    let touched = serving.redeploy_cluster(&cluster)?;
    let out = cluster.infer(4, input(4))?;
    println!(
        "\nadmit tiny-late -> hot-swapped device(s) {touched:?}; \
         newcomer serves {} logits through the same servers",
        out.len()
    );
    Ok(())
}
