//! Quickstart: build a three-tenant combo, price it with the cost model,
//! run every baseline and the GACER search, and print a Fig. 7-style row.
//!
//!     cargo run --release --example quickstart

use gacer::prelude::*;
use gacer::bench_util::{fig7_header, fig7_row, run_combo};

fn main() {
    // 1. Pick a platform and a multi-tenant combination (the paper's
    //    heavy-workload combo).
    let platform = Platform::titan_v();
    let combo = ["R50", "V16", "M3"];

    // 2. Run all seven strategies (4 baselines + Spatial/Temporal/GACER).
    let cells = run_combo(&combo, &platform, SearchConfig::default());
    println!("{}", fig7_header(&cells));
    println!("{}", fig7_row(&zoo::combo_label(&combo), &cells));

    // 3. Inspect what the GACER search actually decided.
    let cost = CostModel::new(platform);
    let tenants = zoo::build_combo(&combo);
    let ts = TenantSet::new(tenants.clone(), cost.clone());
    let report = GacerSearch::new(
        &ts,
        SimOptions::for_platform(&platform),
        SearchConfig::default(),
    )
    .run();
    println!(
        "\nGACER plan: {:.2} ms -> {:.2} ms ({:.2}x over Stream-Parallel), \
         {} simulator evaluations in {:?}",
        report.initial.makespan_us / 1e3,
        report.outcome.makespan_us / 1e3,
        report.speedup_vs_initial(),
        report.evaluations,
        report.elapsed,
    );
    for (i, d) in tenants.iter().enumerate() {
        println!(
            "  {:<5} pointers at {:?}, {} operators decomposed",
            d.name,
            report.plan.pointers.list(i),
            report.plan.chunking[i].len()
        );
    }

    // 4. Utilization evidence (Fig. 8 style).
    let out = ts.simulate(
        &report.plan,
        SimOptions::for_platform(&platform).with_trace(),
    );
    let tr = out.trace.unwrap();
    println!(
        "\nGACER mean SM occupancy {:.1}%  |  trace: {}",
        tr.mean_occupancy(),
        tr.sparkline(48)
    );
}
