//! Budgeted anytime re-search with warm-started search state — the code
//! companion of `docs/SEARCH.md` (and of TUTORIAL.md step 7: admit under
//! a re-plan budget). Runs entirely on the simulator substrate; CI
//! executes it on every push.
//!
//! Walkthrough:
//!
//! 1. deploy 8 tenants on 2 devices with a bounded replan budget;
//! 2. admit a 9th tenant: the one-shard re-search is warm-started
//!    (incumbent streams reused) and budget-truncated, yet never worse
//!    than the inherited plan;
//! 3. a no-change re-search short-circuits to the cached plan at zero
//!    evaluations (the warm-start invalidation rules at work);
//! 4. a stale seed is a typed error, not an out-of-bounds panic;
//! 5. cost/gain migration: a marginal skew the ratio rule would chase
//!    is declined when the predicted gain cannot pay the re-plan + swap
//!    bill, and a large skew still migrates.
//!
//!     cargo run --release --example budgeted_replan

use gacer::models::zoo;
use gacer::prelude::*;

/// Shrunk search budget so the example runs in seconds; drop it to use
/// `SearchConfig::default()` at deployment quality.
fn quick_cfg() -> SearchConfig {
    SearchConfig {
        max_pointers: 2,
        rounds_per_level: 1,
        positions_per_coordinate: 6,
        spatial_steps_per_level: 2,
        ..Default::default()
    }
}

fn main() -> gacer::Result<()> {
    // ---- Stage 1: deploy under a replan budget -------------------------
    // The budget applies to every *incremental* re-search (admit, evict,
    // migrate); the initial build stays unbudgeted (offline quality).
    let budget = SearchBudget::evaluations(60);
    let mut b = GacerEngine::builder()
        .platform(Platform::titan_v())
        .devices(2)
        .search(quick_cfg())
        .replan_budget(budget);
    for name in ["R50", "V16", "M3", "Alex", "R18", "R34", "LSTM", "BST"] {
        b = b.tenant(zoo::build_default(name).unwrap());
    }
    let mut engine = b.build()?;
    println!("== build: 8 tenants, 2 devices, replan budget {} ==", budget.label());
    assert!(!engine.last_report().unwrap().truncated, "cold build is unbudgeted");

    // ---- Stage 2: budgeted, warm-started admit -------------------------
    let id = engine.admit(zoo::build_default("D121").unwrap())?;
    let r = engine.last_report().expect("admit ran a search");
    println!("\n== admit D121 -> device {} ==", engine.device_of(id)?);
    println!(
        "  {} evaluations in {:.1}ms under {} ({}); {} incumbent streams \
         reused from the warm state",
        r.evaluations,
        r.elapsed.as_secs_f64() * 1e3,
        r.budget.label(),
        if r.truncated { "truncated" } else { "converged" },
        r.warm_hits
    );
    // The anytime guarantee: truncated or not, never worse than the
    // unregulated fallback (and the plan always validates).
    assert!(r.outcome.objective() <= r.initial.objective() + 1e-6);
    engine.sharded_plan().validate(engine.tenants())?;

    // ---- Stage 3: a no-change re-search costs nothing ------------------
    // Searching a shard again with its own plan as the seed hits the
    // warm state's converged entry: bit-for-bit reproduction, zero
    // evaluations. (Standalone searcher, same mechanism the engine uses.)
    let ts = TenantSet::new(
        vec![zoo::build_default("Alex").unwrap(), zoo::build_default("M3").unwrap()],
        CostModel::new(Platform::titan_v()),
    );
    let opts = SimOptions::for_platform(&Platform::titan_v());
    let search = GacerSearch::new(&ts, opts, quick_cfg());
    let mut state = SearchState::new();
    let cold = search.run_with_state(&mut state);
    let warm = search.run_from_state(cold.plan.clone(), &mut state)?;
    assert_eq!(warm.plan, cold.plan, "bit-for-bit reproduction");
    assert_eq!(warm.evaluations, 0, "short-circuit costs nothing");
    println!(
        "\n== no-change re-search == short-circuited: {} evaluations, plan \
         identical",
        warm.evaluations
    );

    // ---- Stage 4: stale seeds are typed errors -------------------------
    // A seed whose arity predates the last admit/evict is rejected with
    // Error::InvalidPlan instead of indexing out of bounds.
    let stale = DeploymentPlan::unregulated(5);
    match search.run_from(stale) {
        Err(Error::InvalidPlan(msg)) => {
            println!("\n== stale seed == rejected as typed error: {msg}")
        }
        other => panic!("expected InvalidPlan, got {other:?}"),
    }

    // ---- Stage 5: cost/gain migration ----------------------------------
    // Marginal skew: device 0 carries 4.2 of 5.2 load units — the ratio
    // rule (max/min > 2) would chase it, but the best move only shaves
    // 1.2 off the bottleneck. With a predicted bill of 2.0 units the
    // cost/gain policy declines; a large skew still migrates.
    let placement = Placement::from_assignments(vec![vec![0, 1], vec![2]]);
    let marginal = [3.0, 1.2, 1.0];
    let ratio_rule = MigrationPolicy::default();
    let priced = MigrationPolicy::cost_aware(MigrationCost {
        replan_us: 1.5,
        swap_pause_us: 0.25,
        payback_windows: 1.0,
    });
    assert!(ratio_rule.propose(&marginal, &placement).is_some());
    assert!(priced.propose(&marginal, &placement).is_none());
    let big = priced.propose(&[30.0, 12.0, 1.0], &placement).unwrap();
    println!(
        "\n== cost/gain migration ==\n  marginal skew {marginal:?}: ratio rule \
         proposes, cost/gain declines (gain 1.2 < bill 2.0)\n  large skew \
         [30, 12, 1]: migrates slot {} (gain {:.0} >= bill {:.0})",
        big.slot, big.gain, big.cost
    );

    // On the engine, the bill comes from observed telemetry: the EWMA of
    // the budgeted re-searches this very example just ran.
    let cost = engine.migration_cost(1.0);
    println!(
        "  engine telemetry: re-plan {:.0}us + 2x swap pause {:.0}us per move",
        cost.replan_us, cost.swap_pause_us
    );

    println!("\nall budgeted-replan invariants hold");
    Ok(())
}
