//! Heterogeneous elastic device pools, end to end — the code companion
//! of `docs/OPERATIONS.md` §Scale-out / scale-in.
//!
//! Walks the elastic lifecycle on a mixed A100 + T4 pool: build (the
//! pool-aware placement prices each device with its own cost model) →
//! scale-out (`add_device`: warm re-shard onto the joiner) → scale-in
//! (`remove_device`: drain the retiree's tenants to capacity-feasible
//! survivors) → the typed `DrainImpossible` floor → a live synthetic
//! cluster that grows and shrinks its device set by stable id under
//! traffic. Runs everywhere — the planner half is pure simulator, the
//! serving half uses the synthetic backend (no artifacts, no GPU).
//!
//!     cargo run --release --example elastic_cluster

use std::time::Duration;

use gacer::coordinator::{BatchPolicy, ServerConfig, TenantSpec};
use gacer::models::zoo;
use gacer::prelude::*;

/// Shrunk search budget so the example runs in seconds; drop it to use
/// `SearchConfig::default()` at deployment quality.
fn quick_cfg() -> SearchConfig {
    SearchConfig {
        max_pointers: 2,
        rounds_per_level: 1,
        positions_per_coordinate: 6,
        spatial_steps_per_level: 2,
        ..Default::default()
    }
}

fn show(engine: &GacerEngine, banner: &str) {
    let pool = engine.device_pool();
    println!("{banner} (pool {})", pool.label());
    for d in 0..pool.len() {
        println!(
            "  {} ({}): tenant slots {:?}",
            pool.id(d),
            pool.platform(d).name,
            engine.placement().tenants_on(d)
        );
    }
}

fn main() -> gacer::Result<()> {
    // ---- Stage 1: build on a mixed pool --------------------------------
    // `device_pool` replaces `devices(n)` when the devices differ; the
    // first entry is the reference platform. `devices(n)` remains sugar
    // for n identical copies of `.platform(...)`.
    let mut b = GacerEngine::builder()
        .device_pool(vec![Platform::a100(), Platform::t4()])
        .search(quick_cfg());
    for name in ["R50", "V16", "R18", "M3"] {
        b = b.tenant(zoo::build_default(name).unwrap());
    }
    let mut engine = b.build()?;
    show(&engine, "== build ==");

    // ---- Stage 2: scale-out --------------------------------------------
    // A new T4 joins. The pool assigns it the next stable id (ids are
    // never reused) and the engine re-shards warm: placement, per-device
    // Algorithm-1 searches, and routing all rebuilt at the new width.
    let joined = engine.add_device(Platform::t4());
    engine.sharded_plan().validate(engine.tenants())?;
    show(&engine, &format!("\n== scale-out: {joined} joined =="));

    // ---- Stage 3: scale-in ---------------------------------------------
    // Retire the joiner. Its residents drain to the survivors with the
    // most free HBM (validated against each survivor's own capacity
    // BEFORE anything moves), then the affected shards re-search warm.
    let drained = engine.remove_device(joined)?;
    engine.sharded_plan().validate(engine.tenants())?;
    show(&engine, &format!("\n== scale-in: {joined} retired =="));
    for m in &drained {
        println!("  drained tenant {} {} -> {}", m.tenant, m.from, m.to);
    }

    // ---- Stage 4: the DrainImpossible floor ----------------------------
    // Scale-in refuses to strand tenants: retiring the last device (or
    // retiring into survivors without the HBM to hold the residents)
    // fails typed, with the pool left exactly as it was.
    let survivors = engine.device_pool().ids();
    engine.remove_device(survivors[1])?;
    match engine.remove_device(survivors[0]) {
        Err(Error::DrainImpossible(why)) => {
            println!("\n== drain floor ==\n  refused as expected: {why}")
        }
        other => panic!("expected DrainImpossible, got {other:?}"),
    }
    assert_eq!(engine.device_pool().len(), 1, "pool untouched by the refusal");

    // ---- Stage 5: elastic serving by stable id -------------------------
    // The cluster hot-swap path matches devices by stable id, so a
    // deployment may span a different device set than the running
    // cluster: unknown ids join, absent ids retire, and an unchanged
    // surviving shard is never fenced. Tenants a/b keep answering with
    // their own tag through both scale events.
    let tenant = |name: &str| TenantSpec {
        name: name.to_string(),
        family: "synthetic".to_string(),
        policy: BatchPolicy::new(4, Duration::from_micros(200), vec![1, 2, 4]),
        chunk: None,
    };
    let dep = |names: &[&str]| Deployment {
        tenants: names.iter().map(|n| tenant(n)).collect(),
        config: ServerConfig::default(),
    };
    let cluster = ClusterServer::start_sharded_with_backend(
        ServerBackend::Synthetic(SyntheticModel::echo()),
        ShardedDeployment {
            per_device: vec![dep(&["a", "b"])],
            routing: vec![(0, 0), (0, 1)],
            device_ids: vec![DeviceId(0)],
        },
    )?;
    // Scale-out: gpu1 joins and takes tenant b.
    let touched = cluster.apply(ShardedDeployment {
        per_device: vec![dep(&["a"]), dep(&["b"])],
        routing: vec![(0, 0), (1, 0)],
        device_ids: vec![DeviceId(0), DeviceId(1)],
    })?;
    println!("\n== serving scale-out ==\n  devices swapped: {touched:?}");
    // Scale-in: gpu0 retires; gpu1's shard grows to hold both tenants.
    let touched = cluster.apply(ShardedDeployment {
        per_device: vec![dep(&["b", "a"])],
        routing: vec![(0, 1), (0, 0)],
        device_ids: vec![DeviceId(1)],
    })?;
    println!("== serving scale-in ==\n  devices swapped: {touched:?}");
    for (slot, name) in ["a", "b"].iter().enumerate() {
        let out = cluster.infer(slot, vec![42.0, 0.0])?;
        assert_eq!(out[0], 42.0);
        assert_eq!(out[1], gacer::coordinator::name_tag(name));
        println!("  tenant {name} answers from {:?}", cluster.route_of(slot));
    }
    assert_eq!(cluster.device_ids(), vec![DeviceId(1)]);

    println!("\nok: the device set breathed 2 -> 3 -> 1 (planner) and 1 -> 2 -> 1 (serving) without losing a tenant or a request");
    Ok(())
}
