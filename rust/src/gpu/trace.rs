//! Nsight-like utilization traces (the paper's Fig. 8 evidence).


/// One piecewise-constant utilization interval.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct UtilInterval {
    pub start_us: f64,
    pub end_us: f64,
    /// Aggregate SM occupancy during the interval, percent.
    pub occupancy: f64,
}

/// Piecewise-constant SM-utilization trace of one simulation run.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct UtilTrace {
    intervals: Vec<UtilInterval>,
}

impl UtilTrace {
    pub fn new() -> Self {
        Self::default()
    }

    /// Append an interval, merging with the previous one when the
    /// occupancy is unchanged (keeps traces compact).
    pub fn push(&mut self, start_us: f64, end_us: f64, occupancy: f64) {
        if end_us <= start_us {
            return;
        }
        if let Some(last) = self.intervals.last_mut() {
            if (last.occupancy - occupancy).abs() < 1e-9 && (last.end_us - start_us).abs() < 1e-9
            {
                last.end_us = end_us;
                return;
            }
        }
        self.intervals.push(UtilInterval { start_us, end_us, occupancy });
    }

    pub fn intervals(&self) -> &[UtilInterval] {
        &self.intervals
    }

    pub fn makespan_us(&self) -> f64 {
        self.intervals.last().map_or(0.0, |iv| iv.end_us)
    }

    /// Time-weighted mean occupancy, percent.
    pub fn mean_occupancy(&self) -> f64 {
        let span = self.makespan_us();
        if span == 0.0 {
            return 0.0;
        }
        self.intervals
            .iter()
            .map(|iv| iv.occupancy * (iv.end_us - iv.start_us))
            .sum::<f64>()
            / span
    }

    /// Fraction of the makespan with occupancy below `threshold` percent —
    /// the "inefficient intervals" metric of §5.3.
    pub fn idle_fraction(&self, threshold: f64) -> f64 {
        let span = self.makespan_us();
        if span == 0.0 {
            return 0.0;
        }
        self.intervals
            .iter()
            .filter(|iv| iv.occupancy < threshold)
            .map(|iv| iv.end_us - iv.start_us)
            .sum::<f64>()
            / span
    }

    /// Resample to `bins` equal time buckets (mean occupancy per bucket) —
    /// the Fig. 8 bar-series form.
    pub fn resample(&self, bins: usize) -> Vec<f64> {
        let span = self.makespan_us();
        if span == 0.0 || bins == 0 {
            return vec![0.0; bins];
        }
        let width = span / bins as f64;
        let mut out = vec![0.0f64; bins];
        for iv in &self.intervals {
            let mut t = iv.start_us;
            while t < iv.end_us - 1e-12 {
                let bin = ((t / width) as usize).min(bins - 1);
                let bin_end = (bin as f64 + 1.0) * width;
                let seg_end = iv.end_us.min(bin_end);
                if seg_end <= t {
                    // Floating-point edge: the bin boundary landed at (or
                    // before) `t`. Dump the remainder into this bin and
                    // move on — never loop without progress.
                    out[bin] += iv.occupancy * (iv.end_us - t) / width;
                    break;
                }
                out[bin] += iv.occupancy * (seg_end - t) / width;
                t = seg_end;
            }
        }
        out
    }

    /// Render a compact ASCII sparkline of the trace (for logs/reports).
    pub fn sparkline(&self, bins: usize) -> String {
        const GLYPHS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        self.resample(bins)
            .into_iter()
            .map(|v| GLYPHS[((v / 100.0 * 7.0).round() as usize).min(7)])
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t3() -> UtilTrace {
        let mut tr = UtilTrace::new();
        tr.push(0.0, 10.0, 100.0);
        tr.push(10.0, 20.0, 50.0);
        tr.push(20.0, 40.0, 0.0);
        tr
    }

    #[test]
    fn mean_occupancy_weighted() {
        // (100*10 + 50*10 + 0*20) / 40 = 37.5
        assert!((t3().mean_occupancy() - 37.5).abs() < 1e-9);
    }

    #[test]
    fn adjacent_equal_intervals_merge() {
        let mut tr = UtilTrace::new();
        tr.push(0.0, 5.0, 60.0);
        tr.push(5.0, 9.0, 60.0);
        assert_eq!(tr.intervals().len(), 1);
        assert!((tr.makespan_us() - 9.0).abs() < 1e-12);
    }

    #[test]
    fn zero_length_intervals_dropped() {
        let mut tr = UtilTrace::new();
        tr.push(1.0, 1.0, 50.0);
        assert!(tr.intervals().is_empty());
    }

    #[test]
    fn idle_fraction_counts_low_intervals() {
        assert!((t3().idle_fraction(10.0) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn resample_conserves_mean() {
        let tr = t3();
        let bins = tr.resample(8);
        let mean = bins.iter().sum::<f64>() / 8.0;
        assert!((mean - tr.mean_occupancy()).abs() < 1e-6);
    }

    #[test]
    fn sparkline_length_matches_bins() {
        assert_eq!(t3().sparkline(16).chars().count(), 16);
    }

    #[test]
    fn resample_splits_intervals_across_misaligned_bins() {
        // 3 bins over a 40us span (width 13.33us) cut through both
        // interval boundaries of t3: bin 0 mixes 10us at 100% with
        // 3.33us at 50% (= 87.5%), bin 1 mixes the tail of the 50%
        // interval with idle (= 25%), bin 2 is fully idle.
        let bins = t3().resample(3);
        assert_eq!(bins.len(), 3);
        assert!((bins[0] - 87.5).abs() < 1e-6, "{}", bins[0]);
        assert!((bins[1] - 25.0).abs() < 1e-6, "{}", bins[1]);
        assert!(bins[2].abs() < 1e-6, "{}", bins[2]);
        // Mass conservation: bins * width re-integrate to the trace's
        // total work (100*10 + 50*10 = 1500 percent-us).
        let width = t3().makespan_us() / 3.0;
        let work: f64 = bins.iter().map(|b| b * width).sum();
        assert!((work - 1500.0).abs() < 1e-6);
    }

    #[test]
    fn empty_trace_is_zero_everywhere() {
        let tr = UtilTrace::new();
        assert_eq!(tr.makespan_us(), 0.0);
        assert_eq!(tr.mean_occupancy(), 0.0);
        assert_eq!(tr.idle_fraction(50.0), 0.0);
        assert_eq!(tr.resample(5), vec![0.0; 5]);
        assert_eq!(tr.resample(0), Vec::<f64>::new());
        assert_eq!(tr.sparkline(4), "▁▁▁▁");
    }

    #[test]
    fn gapped_or_distinct_intervals_never_merge() {
        let mut tr = UtilTrace::new();
        tr.push(0.0, 5.0, 60.0);
        // A time gap blocks merging even at equal occupancy...
        tr.push(7.0, 9.0, 60.0);
        // ...and adjacency does not merge distinct occupancies.
        tr.push(9.0, 12.0, 30.0);
        assert_eq!(tr.intervals().len(), 3);
        assert!((tr.makespan_us() - 12.0).abs() < 1e-12);
        // The unrecorded [5, 7] gap still dilutes the time-weighted
        // mean: (60*5 + 60*2 + 30*3) / 12 = 42.5.
        assert!((tr.mean_occupancy() - 42.5).abs() < 1e-9);
    }

    #[test]
    fn idle_fraction_threshold_is_strict() {
        // t3 holds 50% occupancy for 10 of 40us: a threshold AT 50 must
        // not count it (strictly below), a nudge above must.
        assert!((t3().idle_fraction(50.0) - 0.5).abs() < 1e-9);
        assert!((t3().idle_fraction(50.1) - 0.75).abs() < 1e-9);
    }
}
