//! Event-driven multi-stream simulator core.
//!
//! Execution model (§4.1 + §2.1's contention analysis):
//!
//! * each tenant stream issues its operators in order; the device runs the
//!   head operator of every stream whose segment is reachable (native
//!   multi-stream issue is greedy — nothing waits for a resource check);
//! * when aggregate demand fits (`ΣW ≤ S_GPU`, `Σm ≤ BW`), every operator
//!   runs at its solo rate — complementary co-location is free;
//! * when demand oversubscribes the pool, the hardware time-slices:
//!   progress scales by `1/r` (`r = ΣW / S_GPU`) **plus** a contention
//!   penalty `1 + α(r−1)` — the cache-thrash / scheduling overhead the
//!   paper blames greedy multi-stream management for (§1, Table 1). The
//!   penalty term is pure waste: it appears as reduced useful occupancy
//!   in the residue accounting, which is what GACER's regulation (keeping
//!   concurrent clusters complementary) recovers;
//! * synchronization pointers impose cross-stream cluster barriers, each
//!   costing the platform's CPU-GPU sync wait `T_SW` (Fig. 6).

use super::trace::UtilTrace;

/// One operator instance as the simulator sees it: resource demands plus
/// the segment (cluster index) temporal regulation assigned it to.
#[derive(Debug, Clone, PartialEq)]
pub struct SimOp {
    /// SM occupancy demand in percent, (0, 100].
    pub occupancy: f64,
    /// Solo execution duration in microseconds.
    pub duration_us: f64,
    /// DRAM bandwidth demand in percent.
    pub mem_util: f64,
    /// Cluster index (number of pointers before this op in its DFG).
    pub segment: usize,
    /// Index of the source operator in its tenant DFG (chunk pieces and
    /// overhead ops share their source op's id).
    pub source_op: usize,
    /// Operator class label for traces ("conv", "bn", "chunk", ...).
    pub class: &'static str,
}

/// One fork-join stage of a tenant stream: its pieces issue concurrently
/// (each on its own sub-stream, as the paper deploys decomposed micro-
/// batches, Table 3) and the stage completes when every piece has.
/// An undecomposed operator is a singleton stage.
#[derive(Debug, Clone, PartialEq)]
pub struct SimStage {
    pub pieces: Vec<SimOp>,
}

impl SimStage {
    pub fn solo(op: SimOp) -> Self {
        SimStage { pieces: vec![op] }
    }

    pub fn segment(&self) -> usize {
        self.pieces.first().map_or(0, |p| p.segment)
    }
}

/// Simulator configuration.
#[derive(Debug, Clone, Copy)]
pub struct SimOptions {
    /// SM pool capacity in percent (the paper's `S_GPU` = 100).
    pub sm_capacity: f64,
    /// Memory-bandwidth capacity in percent.
    pub mem_capacity: f64,
    /// Contention penalty coefficient α: fractional efficiency lost per
    /// unit of oversubscription.
    pub contention_alpha: f64,
    /// Per-kernel scheduling friction β: fractional efficiency lost per
    /// concurrent kernel beyond two (cache pollution + scheduler overhead
    /// grow with the number of co-resident contexts — §2.1's "coordinating
    /// such multi-tenant GPU support is often overwhelming").
    pub kernel_beta: f64,
    /// CPU-GPU synchronization wait per cluster barrier, microseconds
    /// (the platform's `T_SW`).
    pub sync_wait_us: f64,
    /// Record the per-interval utilization trace (Fig. 8).
    pub record_trace: bool,
    /// Record per-op start/end times.
    pub record_ops: bool,
}

impl SimOptions {
    pub fn for_platform(p: &crate::profile::Platform) -> Self {
        SimOptions {
            sm_capacity: 100.0,
            mem_capacity: 100.0,
            contention_alpha: p.contention_alpha,
            kernel_beta: 0.08,
            sync_wait_us: p.sync_wait_us,
            record_trace: false,
            record_ops: false,
        }
    }

    pub fn with_trace(mut self) -> Self {
        self.record_trace = true;
        self
    }

    pub fn with_ops(mut self) -> Self {
        self.record_ops = true;
        self
    }
}

/// Execution record of one simulated operator.
#[derive(Debug, Clone, PartialEq)]
pub struct OpRecord {
    pub stream: usize,
    pub source_op: usize,
    pub class: &'static str,
    pub start_us: f64,
    pub end_us: f64,
    pub occupancy: f64,
}

/// Result of a simulation run.
#[derive(Debug, Clone, PartialEq)]
pub struct SimOutcome {
    /// End-to-end makespan in microseconds.
    pub makespan_us: f64,
    /// Total residue `R` (Eq. 3): integral of *unused-or-wasted* SM
    /// capacity over the makespan, in percent-microseconds. Contention
    /// waste and sync-wait idle both land here, so the value already
    /// carries Eq. 8's overhead terms.
    pub residue: f64,
    /// Integral of useful SM capacity (percent-microseconds).
    pub used_sm_time: f64,
    /// Portion of `used_sm_time` spent on regulation overhead operators
    /// (chunk/concat splits) — work Eq. 8 counts against a plan.
    pub overhead_sm_time: f64,
    /// Average useful SM utilization over the makespan, percent.
    pub avg_utilization: f64,
    /// Idle time spent on cluster-barrier synchronization (microseconds).
    pub sync_idle_us: f64,
    /// HBM-oversubscription pressure in percent-microsecond-comparable
    /// units ([`crate::plan::TenantSet::hbm_pressure_us`]): zero for any
    /// plan whose resident footprint fits the device, positive when the
    /// tenants' weights + chunk-scaled activations exceed HBM capacity.
    /// Stamped by `TenantSet::simulate`; raw `GpuSim` runs leave it `0.0`
    /// (the simulator sees streams, not footprints).
    pub hbm_pressure_us: f64,
    /// Per-interval utilization trace, when requested.
    pub trace: Option<UtilTrace>,
    /// Per-op records, when requested.
    pub op_records: Option<Vec<OpRecord>>,
}

impl SimOutcome {
    /// The search objective: Eq. 8's overhead-aware residue — `S_GPU *
    /// makespan - useful work`, with chunk/concat overhead counted
    /// against the plan — plus the HBM-oversubscription pressure, so a
    /// decomposition that shrinks an over-capacity resident footprint is
    /// rewarded (footprint-vs-occupancy trade; zero for ordinary mixes).
    pub fn objective(&self) -> f64 {
        self.residue + self.overhead_sm_time + self.hbm_pressure_us
    }
}

#[derive(Debug, Clone)]
struct Running {
    stream: usize,
    /// Stage index within the stream.
    op_index: usize,
    /// Piece index within the stage.
    piece: usize,
    /// Remaining solo-execution microseconds.
    remaining_us: f64,
    occupancy: f64,
    mem_util: f64,
    start_us: f64,
    overhead: bool,
}

/// The multi-stream GPU simulator.
pub struct GpuSim {
    opts: SimOptions,
}

impl GpuSim {
    pub fn new(opts: SimOptions) -> Self {
        GpuSim { opts }
    }

    /// Convenience: simulate plain op sequences (each op its own stage).
    pub fn run(&self, streams: &[Vec<SimOp>]) -> SimOutcome {
        let staged: Vec<Vec<SimStage>> = streams
            .iter()
            .map(|s| s.iter().cloned().map(SimStage::solo).collect())
            .collect();
        self.run_staged(&staged)
    }

    /// Simulate staged streams (one stage sequence per tenant) to
    /// completion. Pieces within a stage issue concurrently.
    pub fn run_staged(&self, streams: &[Vec<SimStage>]) -> SimOutcome {
        let n = streams.len();
        // Per-stream cursor: (stage index, next piece within the stage,
        // pieces of the stage still in flight).
        let mut stage_idx: Vec<usize> = vec![0; n];
        let mut piece_idx: Vec<usize> = vec![0; n];
        let mut inflight: Vec<usize> = vec![0; n];
        let mut running: Vec<Running> = Vec::with_capacity(n * 2);
        let mut cluster = 0usize;
        let max_cluster = streams
            .iter()
            .flat_map(|s| s.iter().map(|st| st.segment()))
            .max()
            .unwrap_or(0);

        let mut t = 0.0f64;
        let mut used_sm_time = 0.0f64;
        let mut overhead_sm_time = 0.0f64;
        let mut sync_idle = 0.0f64;
        let mut trace = self.opts.record_trace.then(UtilTrace::new);
        let mut records: Option<Vec<OpRecord>> = self.opts.record_ops.then(Vec::new);
        // Per-interval scratch, hoisted out of the hot loop.
        let mut group: Vec<f64> = vec![0.0; n];
        let mut stream_share: Vec<f64> = vec![1.0; n];

        loop {
            // Admission: every stream whose current stage is open issues
            // all of that stage's remaining pieces concurrently (greedy
            // multi-stream issue; decomposed micro-batches fork).
            for s in 0..n {
                // Advance past completed stages.
                if inflight[s] == 0
                    && stage_idx[s] < streams[s].len()
                    && piece_idx[s] >= streams[s][stage_idx[s]].pieces.len()
                {
                    stage_idx[s] += 1;
                    piece_idx[s] = 0;
                }
                let Some(stage) = streams[s].get(stage_idx[s]) else { continue };
                if inflight[s] == 0 && piece_idx[s] == 0 && stage.segment() > cluster {
                    continue; // blocked behind a pointer barrier
                }
                while piece_idx[s] < stage.pieces.len() {
                    let op = &stage.pieces[piece_idx[s]];
                    running.push(Running {
                        stream: s,
                        op_index: stage_idx[s],
                        piece: piece_idx[s],
                        remaining_us: op.duration_us,
                        occupancy: op.occupancy,
                        mem_util: op.mem_util,
                        start_us: t,
                        overhead: matches!(op.class, "chunk" | "concat"),
                    });
                    inflight[s] += 1;
                    piece_idx[s] += 1;
                }
            }

            if running.is_empty() {
                let all_done = (0..n).all(|s| stage_idx[s] >= streams[s].len());
                if all_done {
                    break;
                }
                // Everything remaining sits behind the cluster barrier:
                // cross it, paying the CPU-GPU sync wait.
                debug_assert!(cluster < max_cluster, "deadlock: no runnable op");
                cluster += 1;
                if self.opts.sync_wait_us > 0.0 {
                    if let Some(tr) = trace.as_mut() {
                        tr.push(t, t + self.opts.sync_wait_us, 0.0);
                    }
                    t += self.opts.sync_wait_us;
                    sync_idle += self.opts.sync_wait_us;
                }
                continue;
            }

            // Contention state for this interval. Same-stream pieces do
            // not contend with each other (a tenant cannot thrash its own
            // cache): each stream's demand is capped at the pool before
            // summing — decomposed micro-batches share their tenant's
            // allocation, cross-tenant oversubscription pays the α waste.
            group.iter_mut().for_each(|g| *g = 0.0);
            let mut mem_sum = 0.0f64;
            for r in &running {
                group[r.stream] += r.occupancy;
                mem_sum += r.mem_util;
            }
            let demand: f64 = group.iter().map(|&g| g.min(self.opts.sm_capacity)).sum();
            let r_sm = (demand / self.opts.sm_capacity).max(1.0);
            let r_mem = (mem_sum / self.opts.mem_capacity).max(1.0);
            let r_eff = r_sm.max(r_mem);
            let penalty = 1.0
                + self.opts.contention_alpha * (r_eff - 1.0)
                + self.opts.kernel_beta * (running.len() as f64 - 2.0).max(0.0);
            let global = r_eff * penalty;
            // Per-piece slowdown: global sharing x within-stream sharing.
            for (share, &g) in stream_share.iter_mut().zip(group.iter()) {
                *share = if g > self.opts.sm_capacity {
                    g / self.opts.sm_capacity
                } else {
                    1.0
                };
            }

            // Useful occupancy: capped at the pool, degraded by waste.
            let useful = demand.min(self.opts.sm_capacity) / penalty;
            let occ_sum: f64 = running.iter().map(|r| r.occupancy).sum();
            let overhead_frac = if occ_sum > 0.0 {
                running
                    .iter()
                    .filter(|r| r.overhead)
                    .map(|r| r.occupancy)
                    .sum::<f64>()
                    / occ_sum
            } else {
                0.0
            };

            // Advance to the next completion (wall time).
            let dt = running
                .iter()
                .map(|r| r.remaining_us * global * stream_share[r.stream])
                .fold(f64::INFINITY, f64::min);
            if let Some(tr) = trace.as_mut() {
                tr.push(t, t + dt, useful);
            }
            used_sm_time += useful * dt;
            overhead_sm_time += useful * overhead_frac * dt;
            t += dt;

            let mut i = 0;
            while i < running.len() {
                let slowdown = global * stream_share[running[i].stream];
                running[i].remaining_us -= dt / slowdown;
                if running[i].remaining_us <= 1e-9 {
                    let r = running.swap_remove(i);
                    inflight[r.stream] -= 1;
                    if let Some(rec) = records.as_mut() {
                        let op = &streams[r.stream][r.op_index].pieces[r.piece];
                        rec.push(OpRecord {
                            stream: r.stream,
                            source_op: op.source_op,
                            class: op.class,
                            start_us: r.start_us,
                            end_us: t,
                            occupancy: r.occupancy,
                        });
                    }
                } else {
                    i += 1;
                }
            }
        }

        let residue = self.opts.sm_capacity * t - used_sm_time;
        SimOutcome {
            makespan_us: t,
            residue,
            used_sm_time,
            overhead_sm_time,
            avg_utilization: if t > 0.0 { used_sm_time / t } else { 0.0 },
            sync_idle_us: sync_idle,
            hbm_pressure_us: 0.0,
            trace,
            op_records: records,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn op(w: f64, t: f64, seg: usize) -> SimOp {
        SimOp {
            occupancy: w,
            duration_us: t,
            mem_util: 10.0,
            segment: seg,
            source_op: 0,
            class: "conv",
        }
    }

    fn opts() -> SimOptions {
        SimOptions {
            sm_capacity: 100.0,
            mem_capacity: 100.0,
            contention_alpha: 0.25,
            kernel_beta: 0.0,
            sync_wait_us: 5.0,
            record_trace: true,
            record_ops: true,
        }
    }

    #[test]
    fn single_op_runs_solo() {
        let out = GpuSim::new(opts()).run(&[vec![op(60.0, 100.0, 0)]]);
        assert!((out.makespan_us - 100.0).abs() < 1e-9);
        assert!((out.residue - 40.0 * 100.0).abs() < 1e-6);
        assert!((out.avg_utilization - 60.0).abs() < 1e-9);
    }

    #[test]
    fn fitting_ops_overlap_for_free() {
        let out = GpuSim::new(opts()).run(&[
            vec![op(60.0, 100.0, 0)],
            vec![op(40.0, 100.0, 0)],
        ]);
        assert!((out.makespan_us - 100.0).abs() < 1e-9, "perfect pairing");
        assert!(out.residue.abs() < 1e-6);
    }

    #[test]
    fn oversubscription_time_slices_with_waste() {
        // 90 + 60 = 150%: r = 1.5, penalty = 1.125, slowdown = 1.6875.
        // Both ops need 100 solo-us -> both finish at 168.75 wall-us.
        // Better than serialization (200) but pays 12.5% waste vs the
        // work-conserving ideal (150).
        let out = GpuSim::new(opts()).run(&[
            vec![op(90.0, 100.0, 0)],
            vec![op(60.0, 100.0, 0)],
        ]);
        assert!((out.makespan_us - 168.75).abs() < 1e-6, "{}", out.makespan_us);
        // Useful occupancy during contention: 100 / 1.125 = 88.9%.
        assert!(out.avg_utilization < 90.0);
    }

    #[test]
    fn contention_free_regulated_pairing_beats_greedy() {
        // The GACER premise in miniature: running (90||60) then (10||40)
        // greedily pays contention; the regulated order (90||10), (60||40)
        // fits both cycles and finishes sooner.
        let greedy = GpuSim::new(opts()).run(&[
            vec![op(90.0, 100.0, 0), op(10.0, 100.0, 0)],
            vec![op(60.0, 100.0, 0), op(40.0, 100.0, 0)],
        ]);
        let regulated = GpuSim::new(opts()).run(&[
            vec![op(90.0, 100.0, 0), op(60.0, 100.0, 0)],
            vec![op(10.0, 100.0, 0), op(40.0, 100.0, 0)],
        ]);
        assert!(
            regulated.makespan_us < greedy.makespan_us,
            "regulated {} vs greedy {}",
            regulated.makespan_us,
            greedy.makespan_us
        );
        assert!((regulated.makespan_us - 200.0).abs() < 1e-6);
    }

    #[test]
    fn memory_is_a_second_contention_resource() {
        let mut a = op(20.0, 100.0, 0);
        let mut b = op(20.0, 100.0, 0);
        a.mem_util = 90.0;
        b.mem_util = 90.0;
        // SM fits (40%), bandwidth oversubscribes (180%): r_mem = 1.8
        // governs the slowdown.
        let out = GpuSim::new(opts()).run(&[vec![a], vec![b]]);
        assert!(out.makespan_us > 150.0, "{}", out.makespan_us);
    }

    #[test]
    fn intra_stream_order_is_sequential() {
        let out = GpuSim::new(opts()).run(&[vec![op(10.0, 50.0, 0), op(10.0, 50.0, 0)]]);
        assert!((out.makespan_us - 100.0).abs() < 1e-9);
    }

    #[test]
    fn barrier_blocks_next_segment_and_costs_sync() {
        // Stream 2's segment-1 op must wait for stream 1's long segment-0
        // op even though resources are free, then pay T_SW.
        let out = GpuSim::new(opts()).run(&[
            vec![op(30.0, 200.0, 0)],
            vec![op(30.0, 50.0, 0), op(30.0, 50.0, 1)],
        ]);
        // makespan = 200 (cluster 0) + 5 (sync) + 50 (cluster 1)
        assert!((out.makespan_us - 255.0).abs() < 1e-9, "{}", out.makespan_us);
        assert!((out.sync_idle_us - 5.0).abs() < 1e-9);
    }

    #[test]
    fn trace_conserves_time_and_work() {
        let out = GpuSim::new(opts()).run(&[
            vec![op(60.0, 100.0, 0), op(40.0, 50.0, 0)],
            vec![op(40.0, 100.0, 0), op(60.0, 50.0, 0)],
        ]);
        let tr = out.trace.as_ref().unwrap();
        let total: f64 = tr.intervals().iter().map(|iv| iv.end_us - iv.start_us).sum();
        assert!((total - out.makespan_us).abs() < 1e-6);
        let work: f64 = tr
            .intervals()
            .iter()
            .map(|iv| iv.occupancy * (iv.end_us - iv.start_us))
            .sum();
        assert!((work - out.used_sm_time).abs() < 1e-6);
    }

    #[test]
    fn op_records_cover_all_ops() {
        let out = GpuSim::new(opts()).run(&[
            vec![op(60.0, 100.0, 0), op(40.0, 50.0, 0)],
            vec![op(40.0, 100.0, 0)],
        ]);
        assert_eq!(out.op_records.unwrap().len(), 3);
    }

    #[test]
    fn residue_identity_holds() {
        // Eq. 2/3: R = S_GPU * makespan - used  (conservation check).
        let out = GpuSim::new(opts()).run(&[
            vec![op(70.0, 80.0, 0), op(20.0, 40.0, 0)],
            vec![op(50.0, 60.0, 0)],
        ]);
        assert!(
            (out.residue - (100.0 * out.makespan_us - out.used_sm_time)).abs() < 1e-6
        );
    }

    #[test]
    fn zero_alpha_is_work_conserving() {
        let mut o = opts();
        o.contention_alpha = 0.0;
        // Two saturated ops: time-sliced with no waste = serial total.
        let out = GpuSim::new(o).run(&[
            vec![op(100.0, 100.0, 0)],
            vec![op(100.0, 100.0, 0)],
        ]);
        assert!((out.makespan_us - 200.0).abs() < 1e-6);
        assert!((out.avg_utilization - 100.0).abs() < 1e-6);
    }

    #[test]
    fn empty_streams_zero_makespan() {
        let out = GpuSim::new(opts()).run(&[vec![], vec![]]);
        assert_eq!(out.makespan_us, 0.0);
        assert_eq!(out.residue, 0.0);
    }

    #[test]
    fn r_mem_pricing_matches_the_analytic_slowdown() {
        // Closed-form check of the bandwidth axis: two 20%-SM ops with
        // 90% memory demand each. SM fits (r_sm = 1.0), bandwidth
        // oversubscribes at 180% (r_mem = 1.8), so r_eff = 1.8 and the
        // penalty is 1 + 0.25 * 0.8 = 1.2 — a global slowdown of
        // 1.8 * 1.2 = 2.16, putting both 100us ops at exactly 216us.
        let mut a = op(20.0, 100.0, 0);
        let mut b = op(20.0, 100.0, 0);
        a.mem_util = 90.0;
        b.mem_util = 90.0;
        let out = GpuSim::new(opts()).run(&[vec![a], vec![b]]);
        assert!((out.makespan_us - 216.0).abs() < 1e-6, "{}", out.makespan_us);
        // And the residue identity still balances under memory pricing.
        assert!(
            (out.residue - (100.0 * out.makespan_us - out.used_sm_time)).abs() < 1e-6
        );
    }

    #[test]
    fn contention_axes_take_the_max_not_the_sum() {
        // 75 + 75 SM (r_sm = 1.5) against 80 + 80 bandwidth
        // (r_mem = 1.6): the roofline governs by the tighter axis only —
        // r_eff = 1.6, penalty 1.15, slowdown 1.84, makespan 184 — not
        // some compounded product of both ratios.
        let mut a = op(75.0, 100.0, 0);
        let mut b = op(75.0, 100.0, 0);
        a.mem_util = 80.0;
        b.mem_util = 80.0;
        let out = GpuSim::new(opts()).run(&[vec![a], vec![b]]);
        assert!((out.makespan_us - 184.0).abs() < 1e-6, "{}", out.makespan_us);
    }

    #[test]
    fn timeline_captures_contention_then_solo_phases() {
        // One long op (60%, 100us) against one short (60%, 50us):
        // interval 1 runs both at demand 120% — r = 1.2, penalty 1.05,
        // slowdown 1.26, useful occupancy 100/1.05 — until the short op
        // finishes at 63us; interval 2 runs the survivor solo at 60%
        // until 113us. The captured timeline must show exactly those two
        // phases, and the op records the exact start/end stamps.
        let out = GpuSim::new(opts()).run(&[
            vec![op(60.0, 100.0, 0)],
            vec![op(60.0, 50.0, 0)],
        ]);
        assert!((out.makespan_us - 113.0).abs() < 1e-6, "{}", out.makespan_us);
        let tr = out.trace.as_ref().unwrap();
        let iv = tr.intervals();
        assert_eq!(iv.len(), 2, "two utilization phases");
        assert!((iv[0].start_us - 0.0).abs() < 1e-9);
        assert!((iv[0].end_us - 63.0).abs() < 1e-6);
        assert!((iv[0].occupancy - 100.0 / 1.05).abs() < 1e-6);
        assert!((iv[1].end_us - 113.0).abs() < 1e-6);
        assert!((iv[1].occupancy - 60.0).abs() < 1e-9);
        let mut recs = out.op_records.unwrap();
        recs.sort_by(|a, b| a.end_us.partial_cmp(&b.end_us).unwrap());
        assert_eq!(recs[0].stream, 1);
        assert!((recs[0].end_us - 63.0).abs() < 1e-6);
        assert_eq!(recs[1].stream, 0);
        assert!((recs[1].end_us - 113.0).abs() < 1e-6);
        assert!(recs.iter().all(|r| r.start_us == 0.0));
    }
}
