//! Multi-stream GPU simulator — the hardware substitute substrate
//! (DESIGN.md §2).
//!
//! Implements exactly the execution model the paper formulates in §4.1:
//! each tenant is a CUDA stream issuing its operators in order; in any
//! interval the aggregate SM occupancy of running operators must stay
//! within the pool (`Σ W(O^B) ≤ S_GPU`, Eq. 1) and aggregate memory
//! pressure within the bandwidth budget; an operator that does not fit
//! waits ("is moved to the next cycle", §3.1). Synchronization pointers
//! (§4.3) impose cross-stream barriers between segment clusters, each
//! costing the CPU-GPU sync wait `T_SW` (Fig. 6). The unused pool integral
//! is the paper's residue `R` (Eq. 2/3).

mod sim;
mod trace;

pub use sim::{GpuSim, OpRecord, SimOp, SimOptions, SimOutcome, SimStage};
pub use trace::UtilTrace;
