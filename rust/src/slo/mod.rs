//! SLO-driven regulation: priority tiers, latency targets, and
//! error-budget burn-rate monitoring.
//!
//! GACER's regulation loop (observe → decide → apply, see
//! `docs/OPERATIONS.md`) historically reacted to one signal: device-load
//! imbalance. Production multi-tenant serving reacts to *latency SLOs* —
//! tail latency under co-location is the binding constraint, not
//! throughput. This module turns per-tenant latency samples into a
//! regulation pressure signal:
//!
//! - [`Tier`] — Interactive / Standard / Batch scheduling priority.
//!   Higher tiers issue first in the coordinator's round
//!   ([`crate::coordinator::ServerConfig`]) and are protected by
//!   admission control in [`crate::engine::GacerEngine`].
//! - [`SloTarget`] — a percentile latency target (`p99 < 20ms`) and an
//!   optional per-request deadline.
//! - [`SloPolicy`] — the *scheduler-side* per-tenant contract: tier,
//!   deadline, and a bound on queue depth. Requests beyond the bound are
//!   shed with [`crate::Error::Overloaded`]; requests whose deadline
//!   passed before issue are shed with [`crate::Error::DeadlineExceeded`].
//! - [`SloMonitor`] — consumes one window of latency samples per tenant
//!   per observe tick and tracks **error-budget burn rate** over dual
//!   windows: a fast window that pages quickly on acute burn and a slow
//!   window that warns on chronic burn. Emits [`SloPressure`] per tenant.
//!
//! # Burn-rate semantics
//!
//! A target `p99 < 20ms` grants an error budget of 1% of requests — the
//! fraction allowed to exceed 20ms. The *burn rate* over a span of
//! windows is `violation_fraction / budget_fraction`: `1.0` means the
//! budget is being consumed exactly at the sustainable rate, `10.0`
//! means ten times too fast. Following SRE multi-window practice, the
//! monitor evaluates burn over a short span (default 3 windows) against
//! a high threshold to catch acute regressions ([`SloHealth::Page`]) and
//! over a long span (default 12 windows) against a low threshold to
//! catch slow leaks ([`SloHealth::Warn`]).

use std::collections::BTreeMap;
use std::collections::VecDeque;
use std::time::Duration;

use crate::{Error, Result};

/// Scheduling priority tier. Ordering is by *priority*: `Interactive`
/// outranks `Standard` outranks `Batch`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Tier {
    /// Latency-critical, user-facing traffic. Issues first, protected by
    /// admission control while its budget burns.
    Interactive,
    /// Ordinary serving traffic.
    #[default]
    Standard,
    /// Throughput-oriented background work: first to queue, first to
    /// shed under overload.
    Batch,
}

impl Tier {
    /// Numeric priority; higher outranks lower.
    pub fn priority(self) -> u8 {
        match self {
            Tier::Interactive => 2,
            Tier::Standard => 1,
            Tier::Batch => 0,
        }
    }

    /// True when `self` strictly outranks `other`.
    pub fn outranks(self, other: Tier) -> bool {
        self.priority() > other.priority()
    }

    pub fn label(self) -> &'static str {
        match self {
            Tier::Interactive => "interactive",
            Tier::Standard => "standard",
            Tier::Batch => "batch",
        }
    }

    /// Parse a CLI spelling (`interactive|standard|batch`).
    pub fn parse(s: &str) -> Option<Tier> {
        match s.trim().to_ascii_lowercase().as_str() {
            "interactive" => Some(Tier::Interactive),
            "standard" => Some(Tier::Standard),
            "batch" => Some(Tier::Batch),
            _ => None,
        }
    }
}

impl std::fmt::Display for Tier {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

/// A per-tenant latency objective: a percentile target (the SLO proper)
/// plus an optional per-request deadline for the scheduler.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloTarget {
    /// Percentile in (0, 1), e.g. `0.99`.
    pub percentile: f64,
    /// Latency bound at that percentile, microseconds.
    pub target_us: f64,
    /// Optional per-request deadline: a request still queued this long
    /// after arrival is shed rather than issued.
    pub deadline: Option<Duration>,
}

impl SloTarget {
    /// `p99 < ms` milliseconds.
    pub fn p99_ms(ms: f64) -> Self {
        SloTarget { percentile: 0.99, target_us: ms * 1e3, deadline: None }
    }

    /// `p95 < ms` milliseconds.
    pub fn p95_ms(ms: f64) -> Self {
        SloTarget { percentile: 0.95, target_us: ms * 1e3, deadline: None }
    }

    /// Attach a per-request deadline.
    pub fn with_deadline(mut self, d: Duration) -> Self {
        self.deadline = Some(d);
        self
    }

    /// The error budget: the fraction of requests allowed to exceed
    /// `target_us` (`0.01` for a p99 target).
    pub fn budget_fraction(&self) -> f64 {
        1.0 - self.percentile
    }

    pub fn validate(&self) -> Result<()> {
        if !(self.percentile > 0.0 && self.percentile < 1.0) {
            return Err(Error::InvalidConfig(format!(
                "SLO percentile must be in (0,1), got {}",
                self.percentile
            )));
        }
        if !(self.target_us.is_finite() && self.target_us > 0.0) {
            return Err(Error::InvalidConfig(format!(
                "SLO target must be a positive latency, got {}us",
                self.target_us
            )));
        }
        Ok(())
    }
}

/// The scheduler-side per-tenant contract lowered into
/// [`crate::coordinator::ServerConfig`]: issue priority, per-request
/// deadline, and a bound on queue depth.
///
/// The default policy (Standard tier, no deadline, unbounded queue) is
/// exactly the pre-SLO scheduler behavior; a config whose tenants all
/// carry the default lowers to "regulation off".
#[derive(Debug, Clone, PartialEq, Default)]
pub struct SloPolicy {
    pub tier: Tier,
    /// Requests still queued this long after arrival are answered with
    /// [`crate::Error::DeadlineExceeded`] instead of occupying a round.
    pub deadline: Option<Duration>,
    /// Maximum queued requests per tenant; arrivals beyond it are
    /// answered with [`crate::Error::Overloaded`]. `None` = unbounded.
    pub queue_cap: Option<usize>,
}

impl SloPolicy {
    pub fn new(tier: Tier) -> Self {
        SloPolicy { tier, deadline: None, queue_cap: None }
    }

    pub fn with_deadline(mut self, d: Duration) -> Self {
        self.deadline = Some(d);
        self
    }

    pub fn with_queue_cap(mut self, cap: usize) -> Self {
        self.queue_cap = Some(cap);
        self
    }

    pub fn validate(&self) -> Result<()> {
        if self.queue_cap == Some(0) {
            return Err(Error::InvalidConfig(
                "SLO queue_cap of 0 would shed every request; use a positive bound".into(),
            ));
        }
        Ok(())
    }
}

/// Dual-window burn-rate thresholds for the monitor. Spans are measured
/// in observe windows (one [`SloMonitor::observe`] call = one window).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BurnConfig {
    /// Short span for acute-burn detection (windows).
    pub fast_windows: usize,
    /// Long span for chronic-burn detection (windows).
    pub slow_windows: usize,
    /// Burn rate over the fast span at or above which health is
    /// [`SloHealth::Page`].
    pub page_burn: f64,
    /// Burn rate over the slow span at or above which health is at
    /// least [`SloHealth::Warn`].
    pub warn_burn: f64,
    /// Consecutive paging windows before the engine treats the burn as
    /// *sustained* and acts (migrate / re-search) in
    /// [`crate::engine::GacerEngine::maybe_regulate`].
    pub sustained_page_windows: usize,
}

impl Default for BurnConfig {
    fn default() -> Self {
        BurnConfig {
            fast_windows: 3,
            slow_windows: 12,
            page_burn: 8.0,
            warn_burn: 2.0,
            sustained_page_windows: 3,
        }
    }
}

impl BurnConfig {
    pub fn validate(&self) -> Result<()> {
        if self.fast_windows == 0 || self.slow_windows < self.fast_windows {
            return Err(Error::InvalidConfig(format!(
                "burn windows must satisfy 0 < fast ({}) <= slow ({})",
                self.fast_windows, self.slow_windows
            )));
        }
        if self.page_burn < self.warn_burn || self.warn_burn <= 0.0 {
            return Err(Error::InvalidConfig(format!(
                "burn thresholds must satisfy 0 < warn ({}) <= page ({})",
                self.warn_burn, self.page_burn
            )));
        }
        if self.sustained_page_windows == 0 {
            return Err(Error::InvalidConfig(
                "sustained_page_windows must be at least 1".into(),
            ));
        }
        Ok(())
    }
}

/// Health verdict for one tenant, ordered by severity.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum SloHealth {
    /// No samples in the slow span — nothing to judge.
    Idle,
    /// Burning within budget on both windows.
    Healthy,
    /// Chronic burn: the slow window exceeds `warn_burn`.
    Warn,
    /// Acute burn: the fast window exceeds `page_burn`.
    Page,
}

impl SloHealth {
    pub fn label(self) -> &'static str {
        match self {
            SloHealth::Idle => "idle",
            SloHealth::Healthy => "healthy",
            SloHealth::Warn => "warn",
            SloHealth::Page => "page",
        }
    }

    /// Budget is being burned faster than sustainable (Warn or Page).
    pub fn is_burning(self) -> bool {
        matches!(self, SloHealth::Warn | SloHealth::Page)
    }
}

/// Per-tenant pressure emitted by the monitor each window: the two burn
/// rates, the health verdict, and how long the tenant has been paging.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloPressure {
    pub tier: Tier,
    /// Burn rate over the fast span (`violations / budget`, `1.0` =
    /// sustainable).
    pub burn_fast: f64,
    /// Burn rate over the slow span.
    pub burn_slow: f64,
    pub health: SloHealth,
    /// Consecutive windows at [`SloHealth::Page`], including the
    /// current one; `0` when not paging.
    pub page_streak: usize,
}

/// Per-tenant tracking state inside the monitor.
#[derive(Debug, Clone)]
struct Tracked {
    tier: Tier,
    target: SloTarget,
    /// Ring of the last `slow_windows` observe windows, oldest first:
    /// `(violations, total_samples)` per window.
    windows: VecDeque<(u64, u64)>,
    page_streak: usize,
}

impl Tracked {
    fn burn_over(&self, span: usize, budget: f64) -> f64 {
        let (mut bad, mut total) = (0u64, 0u64);
        for &(v, n) in self.windows.iter().rev().take(span) {
            bad += v;
            total += n;
        }
        if total == 0 {
            0.0
        } else {
            (bad as f64 / total as f64) / budget
        }
    }

    fn samples_in(&self, span: usize) -> u64 {
        self.windows.iter().rev().take(span).map(|&(_, n)| n).sum()
    }
}

/// Error-budget burn-rate monitor over all SLO-tracked tenants.
///
/// Keyed by a caller-supplied stable id (the engine uses
/// `TenantId.0`). Feed one window of latency samples per tenant per
/// observe tick via [`SloMonitor::observe`]; read the verdict back via
/// [`SloMonitor::pressure`]. Tenants without an [`SloTarget`] are simply
/// never tracked — the monitor only ever judges what it was told to
/// watch.
#[derive(Debug, Clone, Default)]
pub struct SloMonitor {
    cfg: BurnConfig,
    tenants: BTreeMap<u64, Tracked>,
}

impl SloMonitor {
    pub fn new(cfg: BurnConfig) -> Self {
        SloMonitor { cfg, tenants: BTreeMap::new() }
    }

    pub fn config(&self) -> &BurnConfig {
        &self.cfg
    }

    /// Number of tracked tenants.
    pub fn len(&self) -> usize {
        self.tenants.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tenants.is_empty()
    }

    /// Start tracking `key` against `target`. Replaces any existing
    /// tracking state for the key (history restarts).
    pub fn track(&mut self, key: u64, tier: Tier, target: SloTarget) -> Result<()> {
        target.validate()?;
        self.tenants.insert(
            key,
            Tracked { tier, target, windows: VecDeque::new(), page_streak: 0 },
        );
        Ok(())
    }

    /// Stop tracking `key` (evicted tenant). Unknown keys are a no-op.
    pub fn forget(&mut self, key: u64) {
        self.tenants.remove(&key);
    }

    /// Close one observe window for `key` with that window's latency
    /// samples (microseconds). Untracked keys are ignored — callers can
    /// feed every tenant's samples without filtering.
    pub fn observe(&mut self, key: u64, samples_us: &[f64]) {
        let (fast, slow) = (self.cfg.fast_windows, self.cfg.slow_windows);
        let page = self.cfg.page_burn;
        let Some(t) = self.tenants.get_mut(&key) else { return };
        let violations =
            samples_us.iter().filter(|&&s| s.is_finite() && s > t.target.target_us).count() as u64;
        let total = samples_us.iter().filter(|&&s| s.is_finite()).count() as u64;
        t.windows.push_back((violations, total));
        while t.windows.len() > slow {
            t.windows.pop_front();
        }
        let budget = t.target.budget_fraction();
        let paging = t.samples_in(fast) > 0 && t.burn_over(fast, budget) >= page;
        t.page_streak = if paging { t.page_streak + 1 } else { 0 };
    }

    /// The current pressure verdict for `key`, or `None` if untracked.
    pub fn pressure(&self, key: u64) -> Option<SloPressure> {
        let t = self.tenants.get(&key)?;
        let budget = t.target.budget_fraction();
        let burn_fast = t.burn_over(self.cfg.fast_windows, budget);
        let burn_slow = t.burn_over(self.cfg.slow_windows, budget);
        let health = if t.samples_in(self.cfg.slow_windows) == 0 {
            SloHealth::Idle
        } else if t.samples_in(self.cfg.fast_windows) > 0 && burn_fast >= self.cfg.page_burn {
            SloHealth::Page
        } else if burn_slow >= self.cfg.warn_burn {
            SloHealth::Warn
        } else {
            SloHealth::Healthy
        };
        Some(SloPressure {
            tier: t.tier,
            burn_fast,
            burn_slow,
            health,
            page_streak: if health == SloHealth::Page { t.page_streak } else { 0 },
        })
    }

    /// All tracked tenants' pressures, keyed.
    pub fn pressures(&self) -> Vec<(u64, SloPressure)> {
        self.tenants
            .keys()
            .filter_map(|&k| self.pressure(k).map(|p| (k, p)))
            .collect()
    }

    /// True when any tracked tenant whose tier strictly outranks `tier`
    /// is currently burning budget (Warn or Page) — the admission-control
    /// gate: while it holds, newcomers at `tier` are rejected so the
    /// burning higher tier keeps its headroom.
    pub fn any_burning_above(&self, tier: Tier) -> bool {
        self.tenants.keys().any(|&k| {
            self.pressure(k)
                .map(|p| p.tier.outranks(tier) && p.health.is_burning())
                .unwrap_or(false)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn target() -> SloTarget {
        // p99 < 1ms => budget fraction 0.01.
        SloTarget::p99_ms(1.0)
    }

    /// 100 samples with `bad` of them over the 1ms target.
    fn window(bad: usize) -> Vec<f64> {
        let mut v = vec![100.0; 100 - bad];
        v.extend(vec![5_000.0; bad]);
        v
    }

    #[test]
    fn tier_ordering_and_parse() {
        assert!(Tier::Interactive.outranks(Tier::Standard));
        assert!(Tier::Standard.outranks(Tier::Batch));
        assert!(!Tier::Batch.outranks(Tier::Batch));
        assert_eq!(Tier::parse("Interactive"), Some(Tier::Interactive));
        assert_eq!(Tier::parse("batch"), Some(Tier::Batch));
        assert_eq!(Tier::parse("gold"), None);
        assert_eq!(Tier::default(), Tier::Standard);
    }

    #[test]
    fn target_validation() {
        assert!(target().validate().is_ok());
        assert!(SloTarget { percentile: 1.0, target_us: 10.0, deadline: None }
            .validate()
            .is_err());
        assert!(SloTarget { percentile: 0.99, target_us: f64::NAN, deadline: None }
            .validate()
            .is_err());
        assert!((target().budget_fraction() - 0.01).abs() < 1e-12);
    }

    #[test]
    fn policy_validation_rejects_zero_cap() {
        assert!(SloPolicy::default().validate().is_ok());
        assert!(SloPolicy::new(Tier::Batch).with_queue_cap(0).validate().is_err());
        assert!(SloPolicy::new(Tier::Batch).with_queue_cap(1).validate().is_ok());
    }

    #[test]
    fn burn_config_validation() {
        assert!(BurnConfig::default().validate().is_ok());
        assert!(BurnConfig { fast_windows: 0, ..Default::default() }.validate().is_err());
        assert!(BurnConfig { slow_windows: 1, ..Default::default() }.validate().is_err());
        assert!(BurnConfig { warn_burn: 10.0, ..Default::default() }.validate().is_err());
    }

    #[test]
    fn healthy_within_budget() {
        let mut m = SloMonitor::new(BurnConfig::default());
        m.track(1, Tier::Interactive, target()).unwrap();
        for _ in 0..12 {
            m.observe(1, &window(0));
        }
        let p = m.pressure(1).unwrap();
        assert_eq!(p.health, SloHealth::Healthy);
        assert_eq!(p.burn_fast, 0.0);
        assert_eq!(p.page_streak, 0);
    }

    #[test]
    fn idle_without_samples() {
        let mut m = SloMonitor::new(BurnConfig::default());
        m.track(1, Tier::Standard, target()).unwrap();
        assert_eq!(m.pressure(1).unwrap().health, SloHealth::Idle);
        m.observe(1, &[]);
        assert_eq!(m.pressure(1).unwrap().health, SloHealth::Idle);
        assert!(m.pressure(99).is_none(), "untracked key has no pressure");
    }

    #[test]
    fn acute_burn_pages_on_the_fast_window() {
        let mut m = SloMonitor::new(BurnConfig::default());
        m.track(1, Tier::Interactive, target()).unwrap();
        // 10% violations against a 1% budget = burn 10 >= page_burn 8.
        m.observe(1, &window(10));
        let p = m.pressure(1).unwrap();
        assert_eq!(p.health, SloHealth::Page);
        assert!((p.burn_fast - 10.0).abs() < 1e-9);
        assert_eq!(p.page_streak, 1);
        m.observe(1, &window(10));
        assert_eq!(m.pressure(1).unwrap().page_streak, 2);
        // Recovery clears the streak.
        for _ in 0..3 {
            m.observe(1, &window(0));
        }
        let p = m.pressure(1).unwrap();
        assert_ne!(p.health, SloHealth::Page);
        assert_eq!(p.page_streak, 0);
    }

    #[test]
    fn chronic_burn_warns_on_the_slow_window() {
        let mut m = SloMonitor::new(BurnConfig::default());
        m.track(1, Tier::Standard, target()).unwrap();
        // 3% violations: burn 3 — under page_burn 8, over warn_burn 2.
        for _ in 0..12 {
            m.observe(1, &window(3));
        }
        let p = m.pressure(1).unwrap();
        assert_eq!(p.health, SloHealth::Warn);
        assert!(p.health.is_burning());
        assert!((p.burn_slow - 3.0).abs() < 1e-9);
    }

    #[test]
    fn old_windows_age_out_of_the_slow_span() {
        let mut m = SloMonitor::new(BurnConfig::default());
        m.track(1, Tier::Standard, target()).unwrap();
        for _ in 0..12 {
            m.observe(1, &window(10));
        }
        assert_eq!(m.pressure(1).unwrap().health, SloHealth::Page);
        // 12 clean windows push every violation out of the slow ring.
        for _ in 0..12 {
            m.observe(1, &window(0));
        }
        let p = m.pressure(1).unwrap();
        assert_eq!(p.health, SloHealth::Healthy);
        assert_eq!(p.burn_slow, 0.0);
    }

    #[test]
    fn admission_gate_sees_burning_higher_tiers_only() {
        let mut m = SloMonitor::new(BurnConfig::default());
        m.track(1, Tier::Interactive, target()).unwrap();
        m.track(2, Tier::Batch, target()).unwrap();
        // Batch burning does not gate anyone above or beside it.
        m.observe(2, &window(50));
        assert!(!m.any_burning_above(Tier::Batch));
        assert!(!m.any_burning_above(Tier::Interactive));
        // Interactive burning gates Standard and Batch, not Interactive.
        m.observe(1, &window(50));
        assert!(m.any_burning_above(Tier::Batch));
        assert!(m.any_burning_above(Tier::Standard));
        assert!(!m.any_burning_above(Tier::Interactive));
    }

    #[test]
    fn non_finite_samples_are_ignored_by_observe() {
        let mut m = SloMonitor::new(BurnConfig::default());
        m.track(1, Tier::Interactive, target()).unwrap();
        m.observe(1, &[f64::NAN, f64::INFINITY]);
        assert_eq!(m.pressure(1).unwrap().health, SloHealth::Idle);
    }

    #[test]
    fn forget_stops_tracking() {
        let mut m = SloMonitor::new(BurnConfig::default());
        m.track(1, Tier::Interactive, target()).unwrap();
        m.observe(1, &window(50));
        assert!(m.any_burning_above(Tier::Batch));
        m.forget(1);
        assert!(!m.any_burning_above(Tier::Batch));
        assert!(m.pressure(1).is_none());
        assert!(m.is_empty());
    }
}
