//! Multi-tenant serving server: per-tenant queues + dynamic batchers on a
//! scheduler thread, a GACER-ordered issue loop, and the PJRT executor
//! thread. Pure std threading — the deployment binary carries no async
//! runtime.
//!
//! The server never invents its own regulation: `TenantSpec.chunk`, the
//! issue order, and the issue quanta all arrive pre-lowered from a
//! searched [`crate::plan::DeploymentPlan`] by the
//! [`crate::engine::GacerEngine`]. Plans are **hot-swappable**: a running
//! server accepts a freshly lowered [`Deployment`] through
//! [`Server::apply`] — the swap is epoch-fenced at a scheduler round
//! boundary, so the in-flight round drains under the old plan, queued
//! requests survive the swap, and requests submitted after `apply`
//! returns are scheduled under the new plan. No restart, no dropped
//! executor, no recompiled artifacts.
//!
//! Two hot-path design points (measured by `gacer-bench throughput`,
//! see `docs/BENCHMARKS.md`):
//!
//! * **Completion fabric.** Results flow back through sharded completion
//!   queues with batch-granular wakeups ([`super::CompletionMode`],
//!   default `Batched`) instead of one `mpsc::channel` per request;
//!   [`Server::submit`] returns a [`Pending`] handle so open-loop
//!   clients can keep thousands of requests in flight.
//! * **Backends.** Besides the real artifact/PJRT executor, a server can
//!   run a [`SyntheticModel`] ([`Server::start_synthetic`]): an
//!   in-process stand-in that echoes each request's first input element
//!   (request↔response pairing stays verifiable) and tags rows with the
//!   serving tenant's name hash (mis-routing stays detectable). The
//!   scheduler, batchers, SLO shedding, and hot-swap machinery are
//!   byte-for-byte the production path — only the FLOPs are fake — which
//!   is what lets the stress/property tests and the load generator run
//!   without compiled artifacts.
//!
//! [`Deployment`]: crate::engine::Deployment

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc;
use std::sync::{Arc, RwLock};
use std::time::{Duration, Instant};

use super::batcher::{BatchPolicy, Batcher, PendingRequest};
use super::completion::{CompletionMode, CompletionQueues, Pending, Reply};
use super::executor::ExecutorHandle;
use crate::error::{Error, Result};
use crate::metrics::LatencyHistogram;
use crate::plan::PlacementObjective;
use crate::runtime::{load_params, ArtifactManifest};
use crate::search::SearchBudget;
use crate::slo::{SloPolicy, Tier};

/// One tenant of the serving deployment.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantSpec {
    /// Display name. `(name, family)` carries tenant **identity across
    /// hot swaps**: a swap matches old and new tenants by it to decide
    /// which queues survive (a name reused for a different family is a
    /// new tenant). Name uniqueness per deployment is enforced at
    /// [`Server::start`] and [`Server::apply`], and the engine rejects
    /// duplicate serving-tenant names at admission.
    pub name: String,
    /// Artifact operator family (manifest `meta.op`), e.g. `"tiny_cnn"`.
    pub family: String,
    /// Batching policy.
    pub policy: BatchPolicy,
    /// Spatial regulation on the real path: execute batches as
    /// micro-batches of this size (GACER `list_B` realized with the
    /// compiled batch variants). Derived from the searched plan's chunk
    /// maps by the engine lowering — never hand-set.
    pub chunk: Option<usize>,
}

/// Server configuration. Outside tests this is produced by
/// [`crate::engine::GacerEngine::deployment`], not written by hand.
#[derive(Debug, Clone, PartialEq)]
pub struct ServerConfig {
    /// Scheduler tick (batch-deadline polling resolution).
    pub tick: Duration,
    /// Tenant issue order when several batches are ready — GACER's
    /// cross-tenant schedule on the real path (index = priority). Must be
    /// a permutation of `0..tenants.len()` (or empty for arrival order).
    pub issue_order: Vec<usize>,
    /// Per-tenant cap on consecutive batches issued per scheduling round —
    /// the real-path realization of the plan's segment boundaries: a
    /// tenant with finer temporal granularity (more pointers) yields the
    /// issue queue sooner. Empty = unbounded (model-wise granularity).
    pub issue_quanta: Vec<usize>,
    /// Per-tenant SLO scheduling contract (tier priority, per-request
    /// deadline, queue-depth bound), parallel to the tenant list. Empty =
    /// SLO regulation off (the pre-SLO scheduler, exactly). When set, the
    /// scheduler walks the issue order **tier-major**: higher tiers issue
    /// first, the plan's GACER order is preserved within each tier,
    /// deadline-expired requests are answered with
    /// [`Error::DeadlineExceeded`], and arrivals beyond a tenant's
    /// `queue_cap` are answered with [`Error::Overloaded`].
    pub slo: Vec<SloPolicy>,
    /// How results travel back to waiting clients: sharded
    /// batch-notified completion queues (default) or the legacy
    /// per-request channels. A property of the server handle fixed at
    /// start — a hot swap carrying a different mode does not change it
    /// (requests already carry their reply handles).
    pub completion: CompletionMode,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            tick: Duration::from_micros(200),
            issue_order: Vec::new(),
            issue_quanta: Vec::new(),
            slo: Vec::new(),
            completion: CompletionMode::default(),
        }
    }
}

impl ServerConfig {
    /// Check internal consistency against a tenant count: `issue_order`
    /// must be a permutation of `0..n` (an out-of-range index would
    /// otherwise panic deep inside the scheduler loop).
    pub fn validate(&self, n_tenants: usize) -> Result<()> {
        if !self.issue_order.is_empty() {
            let mut seen = vec![false; n_tenants];
            for &t in &self.issue_order {
                if t >= n_tenants {
                    return Err(Error::InvalidConfig(format!(
                        "issue_order references tenant {t}, only {n_tenants} deployed"
                    )));
                }
                if std::mem::replace(&mut seen[t], true) {
                    return Err(Error::InvalidConfig(format!(
                        "issue_order lists tenant {t} twice"
                    )));
                }
            }
            if self.issue_order.len() != n_tenants {
                return Err(Error::InvalidConfig(format!(
                    "issue_order covers {} of {n_tenants} tenants",
                    self.issue_order.len()
                )));
            }
        }
        if !self.issue_quanta.is_empty() {
            if self.issue_quanta.len() != n_tenants {
                return Err(Error::InvalidConfig(format!(
                    "issue_quanta has {} entries for {n_tenants} tenants",
                    self.issue_quanta.len()
                )));
            }
            if self.issue_quanta.contains(&0) {
                return Err(Error::InvalidConfig(
                    "issue_quanta entries must be >= 1".into(),
                ));
            }
        }
        if !self.slo.is_empty() {
            if self.slo.len() != n_tenants {
                return Err(Error::InvalidConfig(format!(
                    "slo has {} entries for {n_tenants} tenants",
                    self.slo.len()
                )));
            }
            for p in &self.slo {
                p.validate()?;
            }
        }
        Ok(())
    }
}

/// The order the scheduler actually walks each round: the plan's GACER
/// issue order, stable-sorted so higher [`Tier`]s issue first. The sort
/// is stable, so the granularity-aware order the search produced is
/// preserved *within* each tier — SLO priority decides between tiers,
/// GACER decides within them. With no SLO policies the plan order passes
/// through unchanged.
fn tiered_issue_order(order: &[usize], slo: &[SloPolicy]) -> Vec<usize> {
    let mut o = order.to_vec();
    if !slo.is_empty() {
        o.sort_by_key(|&t| {
            std::cmp::Reverse(slo.get(t).map_or(Tier::Standard.priority(), |p| p.tier.priority()))
        });
    }
    o
}

struct Incoming {
    tenant: usize,
    input: Vec<f32>,
    reply: Reply,
}

/// What actually executes issued batches.
#[derive(Debug, Clone)]
pub enum ServerBackend {
    /// Compiled AOT artifacts in this directory, run on the dedicated
    /// PJRT executor thread — the production path.
    Artifacts(String),
    /// An in-process synthetic model: no artifacts, no executor thread,
    /// no `xla-runtime` feature. The full scheduler/batcher/SLO/hot-swap
    /// path runs unchanged; only execution is simulated. This is the
    /// backend of the load generator, the stress tests, and any
    /// environment without compiled artifacts.
    Synthetic(SyntheticModel),
}

/// The synthetic execution model of [`ServerBackend::Synthetic`].
///
/// Output contract, per batch row (`output_len` values, zero-padded):
///
/// * `row[0]` echoes the request's first input element — a client that
///   submits a unique marker can verify its response is *its own*
///   (exactly-once pairing across batching, chunking, and hot swaps);
/// * `row[1]` (when `output_len >= 2`) is the serving tenant's
///   [`name_tag`]: a request answered by the wrong tenant's queue —
///   e.g. routed to a stale slot across a swap — is detectable.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SyntheticModel {
    /// Output elements per request row (>= 1).
    pub output_len: usize,
    /// Busy-wait this long per *issued micro-batch*, simulating device
    /// time. `0.0` measures pure scheduling overhead.
    pub service_us_per_batch: f64,
}

impl SyntheticModel {
    /// Echo model: 2-element rows (marker echo + tenant tag), zero
    /// service time — the pure-overhead configuration.
    pub fn echo() -> SyntheticModel {
        SyntheticModel { output_len: 2, service_us_per_batch: 0.0 }
    }

    /// Echo model with a fixed per-batch service time in microseconds.
    pub fn with_service_us(us: f64) -> SyntheticModel {
        SyntheticModel { output_len: 2, service_us_per_batch: us.max(0.0) }
    }
}

/// Stable tag of a tenant name, embedded in synthetic output rows (see
/// [`SyntheticModel`]): a small integer-valued f32, exact under f32
/// round-trips, so tests can assert which tenant's queue answered.
pub fn name_tag(name: &str) -> f32 {
    let h = name
        .bytes()
        .fold(0u32, |acc, b| acc.wrapping_mul(31).wrapping_add(u32::from(b)));
    (h % 8192) as f32
}

/// A validated plan swap, resolved on the caller's thread and handed to
/// the scheduler, which applies it at the next round boundary.
struct ApplyMsg {
    tenants: Vec<TenantSpec>,
    variants: Vec<HashMap<usize, String>>,
    issue_order: Vec<usize>,
    issue_quanta: Vec<usize>,
    slo: Vec<SloPolicy>,
    tick: Duration,
    ack: mpsc::Sender<()>,
}

enum Msg {
    Request(Incoming),
    Apply(ApplyMsg),
}

/// Introspection state mirrored out of the scheduler thread: what plan
/// the scheduler is *currently* executing (updated atomically at each
/// epoch fence) plus per-tenant served/shed counters and the
/// server-observed latency samples an SLO observe loop drains.
struct Shared {
    specs: Vec<TenantSpec>,
    issue_order: Vec<usize>,
    epoch: u64,
    served: Vec<u64>,
    /// Requests answered with a typed shed error (queue cap + deadline),
    /// per local tenant slot. Shed requests are *answered*, never
    /// silently dropped — this counter makes that auditable.
    shed: Vec<u64>,
    /// Arrival→response latency samples (µs) per local tenant slot,
    /// drained by [`Server::take_latencies`]. Bounded at
    /// [`LATENCY_BUFFER_CAP`] per tenant so a deployment that never
    /// drains cannot grow without bound.
    latency_us: Vec<Vec<f64>>,
}

/// Per-tenant bound on buffered latency samples between
/// [`Server::take_latencies`] drains. An observe loop draining once per
/// window stays far below this; a deployment that never drains just
/// stops buffering instead of leaking.
const LATENCY_BUFFER_CAP: usize = 16_384;

fn read_shared(shared: &RwLock<Shared>) -> std::sync::RwLockReadGuard<'_, Shared> {
    shared.read().unwrap_or_else(|e| e.into_inner())
}

fn write_shared(shared: &RwLock<Shared>) -> std::sync::RwLockWriteGuard<'_, Shared> {
    shared.write().unwrap_or_else(|e| e.into_inner())
}

/// Handle to a running server. Cloneable; dropping the last handle stops
/// the scheduler after it drains outstanding work.
#[derive(Clone)]
pub struct Server {
    tx: mpsc::Sender<Msg>,
    shared: Arc<RwLock<Shared>>,
    completions: Arc<CompletionQueues>,
    mode: CompletionMode,
    /// `Some` for artifact backends (preflight resolves variants against
    /// it), `None` for synthetic ones.
    manifest: Option<Arc<ArtifactManifest>>,
    synthetic: Option<SyntheticModel>,
}

/// Resolve the compiled batch variants of every tenant's family, plus the
/// union of artifact entries (the executor warm set).
fn resolve_variants(
    manifest: &ArtifactManifest,
    tenants: &[TenantSpec],
) -> Result<(Vec<HashMap<usize, String>>, Vec<String>)> {
    let mut variants = Vec::with_capacity(tenants.len());
    let mut warm: Vec<String> = Vec::new();
    for t in tenants {
        let v = manifest.variants_of(&t.family);
        if v.is_empty() {
            return Err(Error::MissingFamily(t.family.clone()));
        }
        warm.extend(v.values().cloned());
        variants.push(v.into_iter().collect());
    }
    warm.sort();
    warm.dedup();
    Ok((variants, warm))
}

/// Variant maps for a synthetic backend: every size the tenant's batch
/// policy names is "compiled" (entry names are synthesized; the
/// synthetic executor never looks one up).
fn synthetic_variants(tenants: &[TenantSpec]) -> Vec<HashMap<usize, String>> {
    tenants
        .iter()
        .map(|t| {
            t.policy
                .variants
                .iter()
                .map(|&v| (v, format!("{}#b{v}", t.family)))
                .collect()
        })
        .collect()
}

/// Names are the identity hot swaps match queues by, so a deployment
/// with two tenants sharing a name is rejected up front — both at
/// [`Server::start`] and at every [`Server::apply`].
fn validate_unique_names(tenants: &[TenantSpec]) -> Result<()> {
    let mut seen: std::collections::BTreeSet<&str> = std::collections::BTreeSet::new();
    for t in tenants {
        if !seen.insert(t.name.as_str()) {
            return Err(Error::InvalidConfig(format!(
                "duplicate tenant name {:?}: names identify tenants across hot swaps",
                t.name
            )));
        }
    }
    Ok(())
}

impl Server {
    /// Start a server over compiled artifacts: validates the
    /// configuration, opens the artifact dir, warms the executor, and
    /// spawns the scheduler thread.
    pub fn start(
        artifact_dir: &str,
        tenants: Vec<TenantSpec>,
        cfg: ServerConfig,
    ) -> Result<Server> {
        Server::start_with_backend(
            ServerBackend::Artifacts(artifact_dir.to_string()),
            tenants,
            cfg,
        )
    }

    /// Start a server over a [`SyntheticModel`]: the identical scheduler
    /// pipeline with simulated execution — no artifacts or PJRT needed.
    pub fn start_synthetic(
        model: SyntheticModel,
        tenants: Vec<TenantSpec>,
        cfg: ServerConfig,
    ) -> Result<Server> {
        Server::start_with_backend(ServerBackend::Synthetic(model), tenants, cfg)
    }

    /// Start a server over an explicit [`ServerBackend`].
    pub fn start_with_backend(
        backend: ServerBackend,
        tenants: Vec<TenantSpec>,
        cfg: ServerConfig,
    ) -> Result<Server> {
        cfg.validate(tenants.len())?;
        validate_unique_names(&tenants)?;
        if let ServerBackend::Synthetic(m) = &backend {
            if m.output_len == 0 {
                return Err(Error::InvalidConfig(
                    "synthetic model needs output_len >= 1".into(),
                ));
            }
        }
        let (manifest, synthetic, variants, params, exec) = match &backend {
            ServerBackend::Artifacts(dir) => {
                let manifest =
                    ArtifactManifest::load(std::path::Path::new(dir).join("manifest.json"))?;
                let params = load_params(dir)?;
                let (variants, warm) = resolve_variants(&manifest, &tenants)?;
                let executor = ExecutorHandle::spawn(dir.clone(), warm)?;
                (
                    Some(Arc::new(manifest)),
                    None,
                    variants,
                    params,
                    Exec::Executor(executor),
                )
            }
            ServerBackend::Synthetic(m) => (
                None,
                Some(*m),
                synthetic_variants(&tenants),
                Vec::new(),
                Exec::Synthetic(*m),
            ),
        };
        let params: Arc<Vec<Vec<f32>>> = Arc::new(params);

        let issue_order = if cfg.issue_order.is_empty() {
            (0..tenants.len()).collect()
        } else {
            cfg.issue_order.clone()
        };
        let issue_order = tiered_issue_order(&issue_order, &cfg.slo);
        let shared = Arc::new(RwLock::new(Shared {
            specs: tenants.clone(),
            issue_order: issue_order.clone(),
            epoch: 0,
            served: vec![0; tenants.len()],
            shed: vec![0; tenants.len()],
            latency_us: vec![Vec::new(); tenants.len()],
        }));
        let st = SchedulerState {
            batchers: tenants.iter().map(|t| Batcher::new(t.policy.clone())).collect(),
            tenants,
            variants,
            issue_order,
            issue_quanta: cfg.issue_quanta.clone(),
            slo: cfg.slo.clone(),
            tick: cfg.tick,
        };
        let completions = CompletionQueues::new();
        let thread_shared = Arc::clone(&shared);
        let thread_completions = Arc::clone(&completions);
        let (tx, rx) = mpsc::channel();
        std::thread::Builder::new()
            .name("gacer-scheduler".into())
            .spawn(move || {
                scheduler_loop(rx, st, params, exec, thread_shared, thread_completions)
            })
            .map_err(Error::Io)?;
        Ok(Server {
            tx,
            shared,
            completions,
            mode: cfg.completion,
            manifest,
            synthetic,
        })
    }

    /// Hot-swap the deployment plan of a **running** server — the live
    /// re-deployment path ([`crate::engine::GacerEngine::redeploy`] calls
    /// this with a freshly lowered plan after `admit`/`evict`/`replan`).
    ///
    /// Semantics (the epoch fence):
    ///
    /// * the swap happens at the next scheduler **round boundary** — the
    ///   round in flight drains under the old plan first;
    /// * old and new tenants are matched **by name**: a persisting
    ///   tenant keeps its queued requests (and served counter) across
    ///   the swap, under its new chunk/policy; a tenant present only in
    ///   the new plan starts with an empty queue; a tenant that
    ///   disappears has its queue flushed and answered under the old
    ///   plan at the fence — no request is lost either way;
    /// * `apply` returns once the scheduler acknowledges the fence
    ///   ([`Server::epoch`] has advanced), so every request submitted
    ///   after it returns is scheduled under the new plan.
    ///
    /// The executor thread, compiled artifacts, and loaded parameters
    /// all persist — a swap costs one scheduler round, not a restart.
    ///
    /// Note for direct users: if the swap *removes* tenants, the local
    /// slot indices of later tenants shift, exactly as engine slots do
    /// on `evict`. [`crate::coordinator::ClusterServer::apply`] fences
    /// request routing around the swap for this reason.
    ///
    /// ```no_run
    /// use gacer::coordinator::BatchPolicy;
    /// use gacer::engine::GacerEngine;
    /// use std::time::Duration;
    ///
    /// let policy = BatchPolicy::new(8, Duration::from_millis(2), vec![1, 2, 4, 8]);
    /// let mut engine = GacerEngine::builder()
    ///     .artifacts("artifacts")
    ///     .serving_tenant("t0", "tiny_cnn", policy.clone()).unwrap()
    ///     .build().unwrap();
    /// let server = engine.serve().unwrap();
    /// engine.admit_serving("t1", "tiny_cnn", policy).unwrap(); // re-plans
    /// server.apply(engine.deployment().unwrap()).unwrap();     // hot swap
    /// assert_eq!(server.tenant_specs().len(), 2);
    /// assert_eq!(server.epoch(), 1);
    /// ```
    pub fn apply(&self, deployment: crate::engine::Deployment) -> Result<()> {
        let variants = self.preflight_apply(&deployment)?;
        let crate::engine::Deployment { tenants, config } = deployment;
        let issue_order = if config.issue_order.is_empty() {
            (0..tenants.len()).collect()
        } else {
            config.issue_order.clone()
        };
        let issue_order = tiered_issue_order(&issue_order, &config.slo);
        let (ack_tx, ack_rx) = mpsc::channel();
        self.tx
            .send(Msg::Apply(ApplyMsg {
                tenants,
                variants,
                issue_order,
                issue_quanta: config.issue_quanta,
                slo: config.slo,
                tick: config.tick,
                ack: ack_tx,
            }))
            .map_err(|_| Error::ChannelClosed("server"))?;
        ack_rx
            .recv()
            .map_err(|_| Error::ChannelClosed("server apply fence"))
    }

    /// Everything fallible about a [`Server::apply`] except the fence
    /// itself: tenant-set shape, config validity, name uniqueness, and
    /// variant resolution against this server's manifest. Side-effect
    /// free — [`crate::coordinator::ClusterServer::apply`] runs it for
    /// every device *before* swapping any, so a rejected deployment
    /// leaves the whole cluster untouched.
    pub(crate) fn preflight_apply(
        &self,
        deployment: &crate::engine::Deployment,
    ) -> Result<Vec<HashMap<usize, String>>> {
        if deployment.tenants.is_empty() {
            return Err(Error::InvalidConfig(
                "cannot apply an empty tenant set to a running server; \
                 drop the server instead"
                    .into(),
            ));
        }
        deployment.config.validate(deployment.tenants.len())?;
        validate_unique_names(&deployment.tenants)?;
        let variants = match &self.manifest {
            Some(m) => resolve_variants(m, &deployment.tenants)?.0,
            None => synthetic_variants(&deployment.tenants),
        };
        Ok(variants)
    }

    /// Submit one request without waiting: returns a [`Pending`] handle
    /// to redeem later. This is the open-loop client path — submission
    /// costs one ticket allocation and one channel send, so a load
    /// generator can keep tens of thousands of requests in flight from
    /// a few threads.
    pub fn submit(&self, tenant: usize, input: Vec<f32>) -> Result<Pending> {
        match self.mode {
            CompletionMode::Batched => {
                let id = self.completions.ticket();
                self.tx
                    .send(Msg::Request(Incoming { tenant, input, reply: Reply::Ticket(id) }))
                    .map_err(|_| Error::ChannelClosed("server"))?;
                Ok(Pending::ticket(id, Arc::clone(&self.completions)))
            }
            CompletionMode::PerRequest => {
                let (otx, orx) = mpsc::channel();
                self.tx
                    .send(Msg::Request(Incoming { tenant, input, reply: Reply::Channel(otx) }))
                    .map_err(|_| Error::ChannelClosed("server"))?;
                Ok(Pending::channel(orx))
            }
        }
    }

    /// Submit one request and wait for its output row.
    pub fn infer(&self, tenant: usize, input: Vec<f32>) -> Result<Vec<f32>> {
        self.submit(tenant, input)?.wait()
    }

    /// The completion mode this handle submits under (fixed at start).
    pub fn completion_mode(&self) -> CompletionMode {
        self.mode
    }

    /// The synthetic model this server runs, if its backend is
    /// [`ServerBackend::Synthetic`].
    pub fn synthetic_model(&self) -> Option<SyntheticModel> {
        self.synthetic
    }

    /// The deployed tenant specs (as the scheduler currently sees them —
    /// after a hot swap this is the swapped-in plan).
    pub fn tenant_specs(&self) -> Vec<TenantSpec> {
        read_shared(&self.shared).specs.clone()
    }

    /// The effective cross-tenant issue order the scheduler executes.
    pub fn issue_order(&self) -> Vec<usize> {
        read_shared(&self.shared).issue_order.clone()
    }

    /// Number of plans hot-swapped into this server since start (0 =
    /// still on the start-time plan). Advances exactly when an
    /// [`Server::apply`] fence commits.
    pub fn epoch(&self) -> u64 {
        read_shared(&self.shared).epoch
    }

    /// Requests served so far, per local tenant slot — the observed-load
    /// signal a drift-aware operations loop feeds back into the engine
    /// (see [`crate::engine::MigrationPolicy`]). A tenant that persists
    /// across hot swaps keeps its count; a swapped-in tenant starts at 0.
    pub fn served_counts(&self) -> Vec<u64> {
        read_shared(&self.shared).served.clone()
    }

    /// Requests shed so far per local tenant slot — queue-cap rejections
    /// ([`Error::Overloaded`]) plus deadline expiries
    /// ([`Error::DeadlineExceeded`]). Every shed request was *answered*
    /// with its typed error; this counter is the introspection proof that
    /// nothing was silently dropped. Counters survive hot swaps exactly
    /// like [`Server::served_counts`] (by `(name, family)` identity).
    pub fn shed_counts(&self) -> Vec<u64> {
        read_shared(&self.shared).shed.clone()
    }

    /// Drain the server-observed latency samples per local tenant slot:
    /// arrival→response microseconds for every request answered since the
    /// previous drain. This is the per-window sample feed for
    /// [`crate::slo::SloMonitor::observe`] (via
    /// [`crate::engine::GacerEngine::record_latencies`]). Buffers are
    /// bounded, so an operations loop that never drains costs memory
    /// once, not per request.
    pub fn take_latencies(&self) -> Vec<Vec<f64>> {
        let mut sh = write_shared(&self.shared);
        sh.latency_us.iter_mut().map(std::mem::take).collect()
    }
}

/// Everything the scheduler owns that a hot swap replaces or remaps.
/// (No per-request responder table: each queued request carries its own
/// reply handle, so answering is table-free and slot moves cannot strand
/// a waiter.)
struct SchedulerState {
    tenants: Vec<TenantSpec>,
    variants: Vec<HashMap<usize, String>>,
    batchers: Vec<Batcher>,
    issue_order: Vec<usize>,
    issue_quanta: Vec<usize>,
    slo: Vec<SloPolicy>,
    tick: Duration,
}

/// The execution substrate behind the scheduler: the PJRT executor
/// thread, or an inline synthetic model.
enum Exec {
    Executor(ExecutorHandle),
    Synthetic(SyntheticModel),
}

impl Exec {
    /// Run one issued micro-batch: `x` is the packed `[rows * per_input]`
    /// input buffer, padded to `rows` (the compiled variant size).
    fn run(
        &self,
        entry: &str,
        x: Vec<f32>,
        params: &Arc<Vec<Vec<f32>>>,
        rows: usize,
        per_input: usize,
        tag: f32,
    ) -> Result<Vec<Vec<f32>>> {
        match self {
            Exec::Executor(executor) => {
                executor.submit_blocking(entry.to_string(), x, Arc::clone(params))
            }
            Exec::Synthetic(model) => {
                if model.service_us_per_batch > 0.0 {
                    let until = Instant::now()
                        + Duration::from_nanos((model.service_us_per_batch * 1e3) as u64);
                    while Instant::now() < until {
                        std::hint::spin_loop();
                    }
                }
                let len = model.output_len;
                let mut out = vec![0.0f32; rows * len];
                for i in 0..rows {
                    out[i * len] = if per_input > 0 { x[i * per_input] } else { 0.0 };
                    if len >= 2 {
                        out[i * len + 1] = tag;
                    }
                }
                Ok(vec![out])
            }
        }
    }
}

/// Answer one request's reply outside a batch context (admission errors,
/// queue-cap sheds).
fn answer(reply: Reply, completions: &CompletionQueues, result: Result<Vec<f32>>) {
    match reply {
        Reply::Ticket(id) => completions.complete(id, result),
        Reply::Channel(tx) => {
            let _ = tx.send(result);
        }
        Reply::Detached => {}
    }
}

/// Claim old tenant slots for a new tenant list, by `(name, family)`
/// identity (first unclaimed old slot wins; duplicates claim in order).
/// `None` = a genuinely new tenant; old slots claimed by nobody are
/// being removed. Keying on the family too means a name reused for a
/// *different* model between swaps can never inherit the old tenant's
/// queue — those requests are flushed under the old spec instead of
/// being answered by the wrong model.
fn claim_slots(old: &[TenantSpec], new: &[TenantSpec]) -> Vec<Option<usize>> {
    let mut by_identity: HashMap<(&str, &str), VecDeque<usize>> = HashMap::new();
    for (i, t) in old.iter().enumerate() {
        by_identity
            .entry((t.name.as_str(), t.family.as_str()))
            .or_default()
            .push_back(i);
    }
    new.iter()
        .map(|t| {
            by_identity
                .get_mut(&(t.name.as_str(), t.family.as_str()))
                .and_then(VecDeque::pop_front)
        })
        .collect()
}

fn bump_served(shared: &RwLock<Shared>, tenant: usize, n: usize) {
    let mut sh = write_shared(shared);
    if let Some(c) = sh.served.get_mut(tenant) {
        *c += n as u64;
    }
}

fn bump_shed(shared: &RwLock<Shared>, tenant: usize, n: usize) {
    let mut sh = write_shared(shared);
    if let Some(c) = sh.shed.get_mut(tenant) {
        *c += n as u64;
    }
}

/// Buffer arrival→response latency samples for one tenant, bounded at
/// [`LATENCY_BUFFER_CAP`].
fn record_latency(shared: &RwLock<Shared>, tenant: usize, samples_us: &[f64]) {
    if samples_us.is_empty() {
        return;
    }
    let mut sh = write_shared(shared);
    if let Some(buf) = sh.latency_us.get_mut(tenant) {
        let room = LATENCY_BUFFER_CAP.saturating_sub(buf.len());
        buf.extend(samples_us.iter().take(room));
    }
}

/// Commit a plan swap at the round boundary: flush removed tenants under
/// the old plan, move surviving queues to their new slots, replace the
/// regulation state, publish the new epoch, and release the fence.
fn apply_swap(
    st: &mut SchedulerState,
    swap: ApplyMsg,
    params: &Arc<Vec<Vec<f32>>>,
    exec: &Exec,
    shared: &RwLock<Shared>,
    completions: &CompletionQueues,
) {
    let ApplyMsg { tenants, variants, issue_order, issue_quanta, slo, tick, ack } = swap;
    let claims = claim_slots(&st.tenants, &tenants);

    // Flush (and answer) every request queued for a tenant the new plan
    // drops — still under the old spec/variants, before anything moves.
    let claimed: Vec<bool> = {
        let mut v = vec![false; st.tenants.len()];
        for c in claims.iter().flatten() {
            v[*c] = true;
        }
        v
    };
    for old in 0..st.tenants.len() {
        if claimed[old] {
            continue;
        }
        while let Some((variant, batch)) = st.batchers[old].flush() {
            bump_served(shared, old, batch.len());
            issue_batch(
                &st.tenants[old],
                &st.variants[old],
                params,
                exec,
                completions,
                variant,
                batch,
                shared,
                old,
            );
        }
    }

    // Rebuild per-slot state in new slot order, moving surviving queues
    // (requests carry their reply handles with them — nothing to remap).
    let mut old_batchers: Vec<Option<Batcher>> =
        st.batchers.drain(..).map(Some).collect();
    let (old_served, old_shed) = {
        let sh = read_shared(shared);
        (sh.served.clone(), sh.shed.clone())
    };
    let mut served = Vec::with_capacity(tenants.len());
    let mut shed = Vec::with_capacity(tenants.len());
    for (i, claim) in claims.iter().enumerate() {
        match claim {
            Some(o) => {
                let mut b = old_batchers[*o].take().expect("slot claimed once");
                b.set_policy(tenants[i].policy.clone());
                st.batchers.push(b);
                served.push(old_served.get(*o).copied().unwrap_or(0));
                shed.push(old_shed.get(*o).copied().unwrap_or(0));
            }
            None => {
                st.batchers.push(Batcher::new(tenants[i].policy.clone()));
                served.push(0);
                shed.push(0);
            }
        }
    }
    st.tenants = tenants;
    st.variants = variants;
    st.issue_order = issue_order;
    st.issue_quanta = issue_quanta;
    st.slo = slo;
    st.tick = tick;

    let mut sh = write_shared(shared);
    // Latency buffers follow their tenants like the counters do.
    let mut old_lat: Vec<Vec<f64>> = std::mem::take(&mut sh.latency_us);
    sh.latency_us = claims
        .iter()
        .map(|claim| match claim {
            Some(o) => std::mem::take(&mut old_lat[*o]),
            None => Vec::new(),
        })
        .collect();
    sh.specs = st.tenants.clone();
    sh.issue_order = st.issue_order.clone();
    sh.served = served;
    sh.shed = shed;
    sh.epoch += 1;
    drop(sh);
    // Release the fence: the caller's `apply` returns, and everything it
    // submits from here on runs under the plan just installed.
    let _ = ack.send(());
}

/// Drop guard: whatever path the scheduler exits by (drained shutdown or
/// panic), the completion fabric is closed so no client stays parked on
/// a ticket that will never be answered.
struct CloseOnExit(Arc<CompletionQueues>);

impl Drop for CloseOnExit {
    fn drop(&mut self) {
        self.0.close();
    }
}

fn scheduler_loop(
    rx: mpsc::Receiver<Msg>,
    mut st: SchedulerState,
    params: Arc<Vec<Vec<f32>>>,
    exec: Exec,
    shared: Arc<RwLock<Shared>>,
    completions: Arc<CompletionQueues>,
) {
    let _close_guard = CloseOnExit(Arc::clone(&completions));
    let mut next_id = 0u64;
    let mut open = true;

    while open || st.batchers.iter().any(|b| b.pending() > 0) {
        // Collect requests for up to one tick. Plan swaps arriving here
        // are deferred to the round boundary below (the epoch fence).
        // The channel is FIFO, so every request submitted before an
        // `apply`'s fence message is queued under the pre-swap slot
        // numbering — by the time the swap commits, those requests sit
        // in batchers and move by (name, family) identity.
        let mut pending_swaps: Vec<ApplyMsg> = Vec::new();
        let deadline = Instant::now() + st.tick;
        loop {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(Msg::Request(msg)) => {
                    let n = st.tenants.len();
                    if msg.tenant >= n {
                        answer(
                            msg.reply,
                            &completions,
                            Err(Error::InvalidConfig(format!(
                                "request for tenant {}, only {n} deployed",
                                msg.tenant
                            ))),
                        );
                        continue;
                    }
                    // Overload protection: a bounded queue sheds at
                    // arrival with a typed error — answered, not dropped,
                    // and no unbounded memory behind a slow tenant.
                    if let Some(cap) = st.slo.get(msg.tenant).and_then(|p| p.queue_cap) {
                        let pending = st.batchers[msg.tenant].pending();
                        if pending >= cap {
                            answer(
                                msg.reply,
                                &completions,
                                Err(Error::Overloaded(format!(
                                    "tenant {}: queue full ({pending} pending, cap {cap})",
                                    st.tenants[msg.tenant].name
                                ))),
                            );
                            bump_shed(&shared, msg.tenant, 1);
                            continue;
                        }
                    }
                    let id = next_id;
                    next_id += 1;
                    st.batchers[msg.tenant].push(PendingRequest {
                        id,
                        input: msg.input,
                        enqueued: Instant::now(),
                        reply: msg.reply,
                    });
                }
                Ok(Msg::Apply(a)) => pending_swaps.push(a),
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    open = false;
                    break;
                }
            }
        }

        // Deadline shedding before the round issues: a request already
        // past its per-request deadline is answered with the typed shed
        // error instead of occupying issue capacity it cannot benefit
        // from (late answers would only push the requests behind it past
        // their own deadlines). All of a round's expiries are answered
        // with one batched completion.
        let now = Instant::now();
        let mut shed_replies: Vec<(Reply, Result<Vec<f32>>)> = Vec::new();
        for t in 0..st.batchers.len() {
            let Some(dl) = st.slo.get(t).and_then(|p| p.deadline) else { continue };
            let expired = st.batchers[t].expire(now, dl);
            if expired.is_empty() {
                continue;
            }
            bump_shed(&shared, t, expired.len());
            for r in expired {
                shed_replies.push((
                    r.reply,
                    Err(Error::DeadlineExceeded(format!(
                        "tenant {}: request queued past its {dl:?} deadline",
                        st.tenants[t].name
                    ))),
                ));
            }
        }
        answer_all(shed_replies, &completions);

        // Issue ready batches in (tier-major) GACER order, bounded per
        // tenant by its segment-derived quantum (leftovers go next round —
        // the plan's pointer boundaries realized as issue-queue yields).
        for i in 0..st.issue_order.len() {
            let t = st.issue_order[i];
            let quantum = st.issue_quanta.get(t).copied().unwrap_or(usize::MAX);
            let mut issued = 0usize;
            while issued < quantum {
                let Some((variant, batch)) = st.batchers[t].drain(now) else { break };
                // Count before executing: a client holding its response
                // must already be visible in `served_counts`.
                bump_served(&shared, t, batch.len());
                issue_batch(
                    &st.tenants[t], &st.variants[t], &params, &exec,
                    &completions, variant, batch, &shared, t,
                );
                issued += 1;
            }
        }

        // Round boundary: the in-flight round has drained — commit any
        // swaps that arrived during it, in order.
        for swap in pending_swaps {
            apply_swap(&mut st, swap, &params, &exec, &shared, &completions);
        }

        if !open {
            for i in 0..st.issue_order.len() {
                let t = st.issue_order[i];
                while let Some((variant, batch)) = st.batchers[t].flush() {
                    bump_served(&shared, t, batch.len());
                    issue_batch(
                        &st.tenants[t], &st.variants[t], &params, &exec,
                        &completions, variant, batch, &shared, t,
                    );
                }
            }
            break;
        }
    }
}

/// Answer a set of replies, batching every ticket into one completion
/// call (one lock + one wakeup per touched shard).
fn answer_all(replies: Vec<(Reply, Result<Vec<f32>>)>, completions: &CompletionQueues) {
    let mut tickets: Vec<(u64, Result<Vec<f32>>)> = Vec::with_capacity(replies.len());
    for (reply, result) in replies {
        match reply {
            Reply::Ticket(id) => tickets.push((id, result)),
            Reply::Channel(tx) => {
                let _ = tx.send(result);
            }
            Reply::Detached => {}
        }
    }
    if !tickets.is_empty() {
        completions.complete_batch(tickets);
    }
}

/// Execute one drained batch — possibly as GACER micro-batches — and
/// distribute output rows to the requesters, recording each answered
/// request's arrival→response latency into the tenant's shared buffer
/// (the SLO observe feed). The whole batch is answered with **one**
/// batched completion (per-shard wakeups), not one notification per
/// request; parameters travel by `Arc`, not by clone, so issuing a
/// micro-batch no longer copies every weight buffer.
#[allow(clippy::too_many_arguments)]
fn issue_batch(
    tenant: &TenantSpec,
    variants: &HashMap<usize, String>,
    params: &Arc<Vec<Vec<f32>>>,
    exec: &Exec,
    completions: &CompletionQueues,
    variant: usize,
    batch: Vec<PendingRequest>,
    shared: &RwLock<Shared>,
    slot: usize,
) {
    let per_input = batch[0].input.len();
    // Spatial regulation on the real path: split into chunk-sized
    // micro-batches when the plan asks for it (and a variant exists).
    let chunk = match tenant.chunk {
        Some(c) if c < variant && variants.contains_key(&c) => c,
        _ => batch.len(),
    };
    let tag = name_tag(&tenant.name);

    let mut completed: Vec<(u64, Result<Vec<f32>>)> = Vec::with_capacity(batch.len());
    let mut latencies = Vec::with_capacity(batch.len());
    let mut rest = batch;
    while !rest.is_empty() {
        let take = chunk.min(rest.len()).max(1);
        let tail = rest.split_off(take);
        let piece = std::mem::replace(&mut rest, tail);

        let v = pick_variant(variants, piece.len());
        let entry = &variants[&v];
        let mut x = vec![0.0f32; v * per_input];
        for (i, r) in piece.iter().enumerate() {
            x[i * per_input..(i + 1) * per_input].copy_from_slice(&r.input);
        }

        match exec.run(entry, x, params, v, per_input, tag) {
            Ok(outputs) => {
                let out = &outputs[0];
                let per_out = out.len() / v;
                for (i, r) in piece.into_iter().enumerate() {
                    let row = out[i * per_out..(i + 1) * per_out].to_vec();
                    latencies.push(r.enqueued.elapsed().as_secs_f64() * 1e6);
                    match r.reply {
                        Reply::Ticket(id) => completed.push((id, Ok(row))),
                        Reply::Channel(tx) => {
                            let _ = tx.send(Ok(row));
                        }
                        Reply::Detached => {}
                    }
                }
            }
            Err(e) => {
                for r in piece {
                    let err = Err(Error::Backend(e.to_string()));
                    match r.reply {
                        Reply::Ticket(id) => completed.push((id, err)),
                        Reply::Channel(tx) => {
                            let _ = tx.send(err);
                        }
                        Reply::Detached => {}
                    }
                }
            }
        }
    }
    record_latency(shared, slot, &latencies);
    if !completed.is_empty() {
        completions.complete_batch(completed);
    }
}

fn pick_variant(variants: &HashMap<usize, String>, n: usize) -> usize {
    let mut keys: Vec<usize> = variants.keys().copied().collect();
    keys.sort_unstable();
    keys.iter().copied().find(|&v| v >= n).unwrap_or(*keys.last().unwrap())
}

/// Result of the demo serving run (the e2e driver's report).
#[derive(Debug)]
pub struct ServeReport {
    pub per_tenant: Vec<(String, LatencyHistogram)>,
    pub total_requests: usize,
    pub elapsed: Duration,
}

impl ServeReport {
    pub fn throughput_rps(&self) -> f64 {
        self.total_requests as f64 / self.elapsed.as_secs_f64()
    }
}

fn demo_input(t: usize, i: usize) -> Vec<f32> {
    // Deterministic pseudo-input per (tenant, request).
    (0..32 * 32 * 3)
        .map(|k| (((t * 7919 + i * 131 + k) % 97) as f32 / 97.0) - 0.5)
        .collect()
}

/// Options of the [`serve_demo`] driver beyond the artifact dir and the
/// tenant list (`gacer serve`'s flags).
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Requests served per tenant.
    pub n_requests: usize,
    /// Devices to shard the deployment across (1 = classic single GPU).
    /// Ignored when `device_pool` is non-empty.
    pub n_devices: usize,
    /// Explicit per-device platform list (`--devices a100,t4x2`): the
    /// engine gets a heterogeneous [`DevicePool`] and each device is
    /// costed, searched, and served against its own platform. Empty =
    /// `n_devices` identical devices.
    ///
    /// [`DevicePool`]: crate::profile::DevicePool
    pub device_pool: Vec<crate::profile::Platform>,
    /// Placement objective for the device dimension.
    pub objective: PlacementObjective,
    /// Admit one more tenant of this family against the *running*
    /// cluster and hot-swap the re-searched plan in (no restart).
    pub live_admit: Option<String>,
    /// Budget for the engine's incremental re-searches — bounds the
    /// live-admit re-plan latency (`--replan-budget-ms`).
    pub replan_budget: SearchBudget,
    /// After serving, consult a cost/gain-aware
    /// [`MigrationPolicy`](crate::engine::MigrationPolicy) built from
    /// the engine's observed re-plan telemetry against the served
    /// counts, and report (and hot-swap) the decision
    /// (`--migration-cost-aware`).
    pub cost_aware_migration: bool,
    /// Per-tenant priority tiers, parallel to the tenant list (`--tier`).
    /// Missing entries default to [`Tier::Standard`]. Any entry (or an
    /// `slo_p99_ms`) switches SLO regulation on: issue order becomes
    /// tier-major, batch tenants get bounded queues.
    pub tiers: Vec<Tier>,
    /// p99 latency target in milliseconds for Interactive tenants
    /// (`--slo`). Attaches an [`crate::slo::SloTarget`] (tracked by the
    /// engine's monitor) and a per-request deadline of 4x the target.
    pub slo_p99_ms: Option<f64>,
    /// Attach the online cost-model calibrator (`--calibrate`): the
    /// engine compares predicted against served latencies each observe
    /// window and blends the trusted residual corrections into every
    /// placement, admission, migration, and regulation decision (see
    /// [`crate::calibrate`] and `docs/OPERATIONS.md`). After serving,
    /// the driver feeds one observe window through
    /// [`GacerEngine::record_latencies`] and prints the per-tenant
    /// correction table.
    pub calibrate: bool,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            n_requests: 64,
            n_devices: 1,
            device_pool: Vec::new(),
            objective: PlacementObjective::default(),
            live_admit: None,
            replan_budget: SearchBudget::unbounded(),
            cost_aware_migration: false,
            tiers: Vec::new(),
            slo_p99_ms: None,
            calibrate: false,
        }
    }
}

/// The e2e demo driver (`gacer serve`): build a [`GacerEngine`] over DFG
/// proxies of the requested families, shard them across
/// `opts.n_devices` (1 = the classic single-GPU deployment), let the
/// granularity-aware search produce one plan per device, lower each to
/// its live server config, and serve `opts.n_requests` per tenant of
/// real inference through the cluster front-end
/// ([`crate::coordinator::ClusterServer`] — with a single device this is
/// one scheduler, exactly the old behavior).
///
/// With `opts.live_admit: Some(family)` the driver then demonstrates
/// live re-deployment: it admits one more tenant of that family against
/// the *running* cluster — under `opts.replan_budget`, printing the
/// re-search's budget telemetry — hot-swaps the re-searched plans in
/// with [`GacerEngine::redeploy_cluster`], and serves the newcomer's
/// requests through the same servers, no restart. With
/// `opts.cost_aware_migration` it closes the loop: the served counts
/// feed the engine's demand counters and a cost/gain-aware migration
/// policy decides whether any move pays for its own re-plan + swap
/// disruption.
///
/// [`GacerEngine`]: crate::engine::GacerEngine
/// [`GacerEngine::redeploy_cluster`]: crate::engine::GacerEngine::redeploy_cluster
pub fn serve_demo(
    artifact_dir: &str,
    tenant_models: &[String],
    opts: &ServeOptions,
) -> Result<ServeReport> {
    let n_requests = opts.n_requests;
    let mut builder = crate::engine::GacerEngine::builder()
        .platform(crate::profile::Platform::titan_v())
        .devices(opts.n_devices)
        .placement_objective(opts.objective)
        .replan_budget(opts.replan_budget)
        .artifacts(artifact_dir);
    if !opts.device_pool.is_empty() {
        builder = builder.device_pool(opts.device_pool.clone());
    }
    if opts.calibrate {
        builder = builder.calibration(crate::calibrate::CalibrationConfig::default());
    }
    let slo_on = opts.slo_p99_ms.is_some() || !opts.tiers.is_empty();
    for (i, family) in tenant_models.iter().enumerate() {
        let batch_policy =
            BatchPolicy::new(8, Duration::from_millis(2), vec![1, 2, 4, 8, 16, 32]);
        if slo_on {
            let tier = opts.tiers.get(i).copied().unwrap_or_default();
            let mut slo = SloPolicy::new(tier);
            let mut target = None;
            if let Some(ms) = opts.slo_p99_ms {
                match tier {
                    Tier::Interactive => {
                        slo = slo.with_deadline(Duration::from_micros((ms * 4e3) as u64));
                        target = Some(crate::slo::SloTarget::p99_ms(ms));
                    }
                    Tier::Standard => {}
                    Tier::Batch => slo = slo.with_queue_cap(64),
                }
            }
            builder = builder.serving_tenant_with_slo(
                format!("{family}-{i}"),
                family,
                batch_policy,
                slo,
                target,
            )?;
        } else {
            builder =
                builder.serving_tenant(format!("{family}-{i}"), family, batch_policy)?;
        }
    }
    let mut engine = builder.build()?;
    let deployment = engine.sharded_deployment()?;
    println!(
        "searched plan: {} decomposed ops across {} device(s) [{}]",
        engine.plan().decomposed_ops(),
        engine.n_devices(),
        engine.device_pool().label(),
    );
    for (d, dep) in deployment.per_device.iter().enumerate() {
        println!(
            "  device {d}: tenants {:?}, issue order {:?}, chunks {:?}",
            engine.placement().tenants_on(d),
            dep.config.issue_order,
            dep.tenants.iter().map(|t| t.chunk).collect::<Vec<_>>()
        );
    }
    let n_tenants = tenant_models.len();
    let server = Arc::new(engine.serve_cluster()?);

    let started = Instant::now();
    let mut handles = Vec::new();
    for t in 0..n_tenants {
        let server = Arc::clone(&server);
        handles.push(std::thread::spawn(move || -> Result<LatencyHistogram> {
            let mut hist = LatencyHistogram::new();
            for i in 0..n_requests {
                let x = demo_input(t, i);
                let t0 = Instant::now();
                let out = match server.infer(t, x) {
                    Ok(out) => out,
                    // Typed sheds are the overload protocol working, not
                    // a failure: the client backs off and moves on.
                    Err(Error::Overloaded(_)) | Err(Error::DeadlineExceeded(_)) => continue,
                    Err(e) => return Err(e),
                };
                hist.record(t0.elapsed());
                if out.len() != 10 {
                    return Err(Error::InvalidData(format!(
                        "expected 10 logits, got {}",
                        out.len()
                    )));
                }
                if !out.iter().all(|v| v.is_finite()) {
                    return Err(Error::InvalidData("non-finite logits".into()));
                }
            }
            Ok(hist)
        }));
    }

    let mut per_tenant = Vec::new();
    for (t, h) in handles.into_iter().enumerate() {
        let hist = h
            .join()
            .map_err(|_| Error::ChannelClosed("client thread"))??;
        per_tenant.push((tenant_models[t].clone(), hist));
    }
    let mut total_requests = n_requests * n_tenants;

    // Live re-deployment demo: admit against the RUNNING cluster, hot
    // swap, serve the newcomer. The servers and their executors persist.
    if let Some(family) = opts.live_admit.as_deref() {
        let policy =
            BatchPolicy::new(8, Duration::from_millis(2), vec![1, 2, 4, 8, 16, 32]);
        let id = engine.admit_serving(format!("{family}-live"), family, policy)?;
        let touched = engine.redeploy_cluster(&server)?;
        let slot = engine.len() - 1;
        let (device, _) = server.route_of(slot).ok_or_else(|| {
            Error::InvalidConfig(format!("live tenant {id} not routed"))
        })?;
        println!(
            "live admit {family} -> device {device}; hot-swapped devices {touched:?} \
             (no restart)"
        );
        if let Some(r) = engine.last_report() {
            println!(
                "  admit re-search: {} evaluations in {:.1}ms under budget {} \
                 ({}), {} warm stream hits",
                r.evaluations,
                r.elapsed.as_secs_f64() * 1e3,
                r.budget.label(),
                if r.truncated { "truncated" } else { "converged" },
                r.warm_hits
            );
        }
        let mut hist = LatencyHistogram::new();
        for i in 0..n_requests {
            let t0 = Instant::now();
            let out = server.infer(slot, demo_input(slot, i))?;
            hist.record(t0.elapsed());
            if out.len() != 10 {
                return Err(Error::InvalidData(format!(
                    "expected 10 logits, got {}",
                    out.len()
                )));
            }
        }
        total_requests += n_requests;
        per_tenant.push((format!("{family}-live"), hist));
    }

    // Cost/gain migration consult: close the observe→decide loop once
    // with a policy priced from the engine's own re-plan telemetry.
    if opts.cost_aware_migration {
        engine.record_served(&server.served_counts())?;
        let cost = engine.migration_cost(1.0);
        let policy = crate::engine::MigrationPolicy::cost_aware(cost);
        match engine.maybe_migrate(&policy)? {
            Some(m) => {
                let touched = engine.redeploy_cluster(&server)?;
                println!(
                    "cost/gain migration: moved {} from device {} to {} \
                     (predicted bill {:.0}us); hot-swapped devices {touched:?}",
                    m.tenant,
                    m.from,
                    m.to,
                    cost.total_us()
                );
            }
            None => println!(
                "cost/gain migration: no move pays its predicted bill of \
                 {:.0}us (re-plan {:.0}us + 2x swap pause {:.0}us) — staying put",
                cost.total_us(),
                cost.replan_us,
                cost.swap_pause_us
            ),
        }
    }

    let report = ServeReport {
        per_tenant,
        total_requests,
        elapsed: started.elapsed(),
    };
    println!(
        "served {} requests in {:.2}s  ({:.1} req/s)",
        report.total_requests,
        report.elapsed.as_secs_f64(),
        report.throughput_rps()
    );
    for (name, hist) in &report.per_tenant {
        println!("  tenant {name:<12} {}", hist.summary());
    }
    if slo_on {
        // Close the SLO observe loop once: shed accounting plus one
        // monitor window over the server-observed latencies.
        let shed = server.shed_counts();
        println!("  shed per tenant slot: {shed:?}");
        engine.record_latencies(&server.take_latencies())?;
        for id in engine.tenant_ids() {
            if let Some(p) = engine.slo_pressure(id) {
                println!(
                    "  {id} [{}] slo {}: burn fast {:.2} / slow {:.2}",
                    p.tier,
                    p.health.label(),
                    p.burn_fast,
                    p.burn_slow
                );
            }
        }
    }
    if opts.calibrate {
        // Close the calibration observe loop once. If the SLO block above
        // already drained the latency buffers this drain is empty, which
        // is fine — the calibrator saw the samples on the first drain.
        if !slo_on {
            engine.record_latencies(&server.take_latencies())?;
        }
        let entries = engine.corrections();
        if entries.is_empty() {
            println!("  calibration: no residuals yet (decisions stay analytic)");
        } else {
            for e in &entries {
                println!(
                    "  calibration tenant {} on {}: ratio {:.3} over {} samples \
                     -> correction {:.3}{}",
                    e.tenant,
                    e.platform,
                    e.ratio_ewma,
                    e.samples,
                    e.correction,
                    if e.trusted { "" } else { " (ramping, not yet trusted)" }
                );
            }
        }
        if let Some(us) = engine.observed_fence_pause_us() {
            println!("  calibration: observed swap-pause EWMA {us:.0}us");
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn issue_order_must_be_permutation() {
        let cfg = ServerConfig { issue_order: vec![2, 1, 0], ..Default::default() };
        cfg.validate(3).unwrap();
        // Out of range.
        let cfg = ServerConfig { issue_order: vec![0, 3], ..Default::default() };
        assert!(cfg.validate(2).is_err());
        // Duplicate.
        let cfg = ServerConfig { issue_order: vec![0, 0, 1], ..Default::default() };
        assert!(cfg.validate(3).is_err());
        // Incomplete.
        let cfg = ServerConfig { issue_order: vec![0, 1], ..Default::default() };
        assert!(cfg.validate(3).is_err());
        // Empty = arrival order, always fine.
        ServerConfig::default().validate(5).unwrap();
    }

    #[test]
    fn issue_quanta_validated() {
        let cfg = ServerConfig { issue_quanta: vec![1, 4], ..Default::default() };
        cfg.validate(2).unwrap();
        let cfg = ServerConfig { issue_quanta: vec![1], ..Default::default() };
        assert!(cfg.validate(2).is_err());
        let cfg = ServerConfig { issue_quanta: vec![1, 0], ..Default::default() };
        assert!(cfg.validate(2).is_err());
    }

    #[test]
    fn slo_policies_validated() {
        let cfg = ServerConfig {
            slo: vec![SloPolicy::default(), SloPolicy::new(Tier::Batch).with_queue_cap(8)],
            ..Default::default()
        };
        cfg.validate(2).unwrap();
        // Arity mismatch.
        assert!(cfg.validate(3).is_err());
        // A zero queue cap sheds everything: rejected up front.
        let cfg = ServerConfig {
            slo: vec![SloPolicy::new(Tier::Batch).with_queue_cap(0)],
            ..Default::default()
        };
        assert!(cfg.validate(1).is_err());
        // Empty = SLO off, any tenant count.
        ServerConfig::default().validate(5).unwrap();
    }

    #[test]
    fn tiered_order_is_tier_major_and_stable_within_tiers() {
        use crate::slo::Tier;
        // Plan order 3,1,0,2; tiers: 0=batch 1=interactive 2=standard
        // 3=batch. Tier-major: interactive (1), standard (2), then the
        // batch tenants in their plan order (3 before 0).
        let slo = vec![
            SloPolicy::new(Tier::Batch),
            SloPolicy::new(Tier::Interactive),
            SloPolicy::new(Tier::Standard),
            SloPolicy::new(Tier::Batch),
        ];
        assert_eq!(tiered_issue_order(&[3, 1, 0, 2], &slo), vec![1, 2, 3, 0]);
        // No SLO: the plan order passes through untouched.
        assert_eq!(tiered_issue_order(&[3, 1, 0, 2], &[]), vec![3, 1, 0, 2]);
        // Uniform tiers: plan order preserved exactly (stable sort).
        let uniform = vec![SloPolicy::default(); 4];
        assert_eq!(tiered_issue_order(&[3, 1, 0, 2], &uniform), vec![3, 1, 0, 2]);
    }

    fn spec(name: &str) -> TenantSpec {
        TenantSpec {
            name: name.to_string(),
            family: "tiny_cnn".to_string(),
            policy: BatchPolicy::new(4, Duration::from_millis(1), vec![1, 2, 4]),
            chunk: None,
        }
    }

    #[test]
    fn claim_slots_matches_by_name() {
        let old = vec![spec("a"), spec("b"), spec("c")];
        // b evicted, d admitted, a/c persist (c's slot shifts).
        let new = vec![spec("a"), spec("c"), spec("d")];
        assert_eq!(claim_slots(&old, &new), vec![Some(0), Some(2), None]);
        // Old slot 1 (b) is claimed by nobody: it gets flushed at the
        // fence.
    }

    #[test]
    fn claim_slots_never_crosses_families() {
        // A name reused for a different model is a NEW tenant: the old
        // queue must be flushed, not inherited.
        let old = vec![spec("a")];
        let mut reused = spec("a");
        reused.family = "other_model".to_string();
        assert_eq!(claim_slots(&old, &[reused]), vec![None]);
    }

    #[test]
    fn claim_slots_handles_duplicates_and_reorders() {
        let old = vec![spec("x"), spec("x"), spec("y")];
        let new = vec![spec("y"), spec("x"), spec("x")];
        assert_eq!(claim_slots(&old, &new), vec![Some(2), Some(0), Some(1)]);
        // More duplicates than before: the surplus is new.
        let new = vec![spec("x"), spec("x"), spec("x")];
        assert_eq!(claim_slots(&old, &new), vec![Some(0), Some(1), None]);
    }
}
