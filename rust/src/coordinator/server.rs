//! Multi-tenant serving server: per-tenant queues + dynamic batchers on a
//! scheduler thread, a GACER-ordered issue loop, and the PJRT executor
//! thread. Pure std threading — the deployment binary carries no async
//! runtime.
//!
//! The server never invents its own regulation: `TenantSpec.chunk`, the
//! issue order, and the per-round issue quanta all arrive pre-lowered
//! from a searched [`crate::plan::DeploymentPlan`] by the
//! [`crate::engine::GacerEngine`].

use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::batcher::{BatchPolicy, Batcher, PendingRequest};
use super::executor::ExecutorHandle;
use crate::error::{Error, Result};
use crate::metrics::LatencyHistogram;
use crate::runtime::{load_params, ArtifactManifest};

/// One tenant of the serving deployment.
#[derive(Debug, Clone)]
pub struct TenantSpec {
    /// Display name.
    pub name: String,
    /// Artifact operator family (manifest `meta.op`), e.g. `"tiny_cnn"`.
    pub family: String,
    /// Batching policy.
    pub policy: BatchPolicy,
    /// Spatial regulation on the real path: execute batches as
    /// micro-batches of this size (GACER `list_B` realized with the
    /// compiled batch variants). Derived from the searched plan's chunk
    /// maps by the engine lowering — never hand-set.
    pub chunk: Option<usize>,
}

/// Server configuration. Outside tests this is produced by
/// [`crate::engine::GacerEngine::deployment`], not written by hand.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Scheduler tick (batch-deadline polling resolution).
    pub tick: Duration,
    /// Tenant issue order when several batches are ready — GACER's
    /// cross-tenant schedule on the real path (index = priority). Must be
    /// a permutation of `0..tenants.len()` (or empty for arrival order).
    pub issue_order: Vec<usize>,
    /// Per-tenant cap on consecutive batches issued per scheduling round —
    /// the real-path realization of the plan's segment boundaries: a
    /// tenant with finer temporal granularity (more pointers) yields the
    /// issue queue sooner. Empty = unbounded (model-wise granularity).
    pub issue_quanta: Vec<usize>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            tick: Duration::from_micros(200),
            issue_order: Vec::new(),
            issue_quanta: Vec::new(),
        }
    }
}

impl ServerConfig {
    /// Check internal consistency against a tenant count: `issue_order`
    /// must be a permutation of `0..n` (an out-of-range index would
    /// otherwise panic deep inside the scheduler loop).
    pub fn validate(&self, n_tenants: usize) -> Result<()> {
        if !self.issue_order.is_empty() {
            let mut seen = vec![false; n_tenants];
            for &t in &self.issue_order {
                if t >= n_tenants {
                    return Err(Error::InvalidConfig(format!(
                        "issue_order references tenant {t}, only {n_tenants} deployed"
                    )));
                }
                if std::mem::replace(&mut seen[t], true) {
                    return Err(Error::InvalidConfig(format!(
                        "issue_order lists tenant {t} twice"
                    )));
                }
            }
            if self.issue_order.len() != n_tenants {
                return Err(Error::InvalidConfig(format!(
                    "issue_order covers {} of {n_tenants} tenants",
                    self.issue_order.len()
                )));
            }
        }
        if !self.issue_quanta.is_empty() {
            if self.issue_quanta.len() != n_tenants {
                return Err(Error::InvalidConfig(format!(
                    "issue_quanta has {} entries for {n_tenants} tenants",
                    self.issue_quanta.len()
                )));
            }
            if self.issue_quanta.contains(&0) {
                return Err(Error::InvalidConfig(
                    "issue_quanta entries must be >= 1".into(),
                ));
            }
        }
        Ok(())
    }
}

struct Incoming {
    tenant: usize,
    input: Vec<f32>,
    respond: mpsc::Sender<Result<Vec<f32>>>,
}

/// Handle to a running server. Cloneable; dropping the last handle stops
/// the scheduler after it drains outstanding work.
#[derive(Clone)]
pub struct Server {
    tx: mpsc::Sender<Incoming>,
    /// Effective deployment, kept for introspection (tests assert the
    /// searched plan's lowering is what the scheduler executes).
    specs: Arc<Vec<TenantSpec>>,
    issue_order: Arc<Vec<usize>>,
}

impl Server {
    /// Start the server: validates the configuration, opens the artifact
    /// dir, warms the executor, and spawns the scheduler thread.
    pub fn start(artifact_dir: &str, tenants: Vec<TenantSpec>, cfg: ServerConfig) -> Result<Server> {
        cfg.validate(tenants.len())?;
        let manifest = ArtifactManifest::load(
            std::path::Path::new(artifact_dir).join("manifest.json"),
        )?;
        let params = load_params(artifact_dir)?;

        // Resolve compiled batch variants per tenant family.
        let mut variants: Vec<HashMap<usize, String>> = Vec::new();
        let mut warm: Vec<String> = Vec::new();
        for t in &tenants {
            let v = manifest.variants_of(&t.family);
            if v.is_empty() {
                return Err(Error::MissingFamily(t.family.clone()));
            }
            warm.extend(v.values().cloned());
            variants.push(v.into_iter().collect());
        }
        warm.sort();
        warm.dedup();
        let executor = ExecutorHandle::spawn(artifact_dir.to_string(), warm)?;

        let issue_order = if cfg.issue_order.is_empty() {
            (0..tenants.len()).collect()
        } else {
            cfg.issue_order.clone()
        };
        let specs = Arc::new(tenants.clone());
        let order = Arc::new(issue_order.clone());
        let quanta = cfg.issue_quanta.clone();
        let (tx, rx) = mpsc::channel();
        std::thread::Builder::new()
            .name("gacer-scheduler".into())
            .spawn(move || {
                scheduler_loop(
                    rx, tenants, variants, params, executor, cfg.tick, issue_order,
                    quanta,
                )
            })
            .map_err(Error::Io)?;
        Ok(Server { tx, specs, issue_order: order })
    }

    /// Submit one request and wait for its output row.
    pub fn infer(&self, tenant: usize, input: Vec<f32>) -> Result<Vec<f32>> {
        let (otx, orx) = mpsc::channel();
        self.tx
            .send(Incoming { tenant, input, respond: otx })
            .map_err(|_| Error::ChannelClosed("server"))?;
        orx.recv().map_err(|_| Error::ChannelClosed("server request"))?
    }

    /// The deployed tenant specs (as the scheduler sees them).
    pub fn tenant_specs(&self) -> &[TenantSpec] {
        &self.specs
    }

    /// The effective cross-tenant issue order the scheduler executes.
    pub fn issue_order(&self) -> &[usize] {
        &self.issue_order
    }
}

#[allow(clippy::too_many_arguments)]
fn scheduler_loop(
    rx: mpsc::Receiver<Incoming>,
    tenants: Vec<TenantSpec>,
    variants: Vec<HashMap<usize, String>>,
    params: Vec<Vec<f32>>,
    executor: ExecutorHandle,
    tick: Duration,
    issue_order: Vec<usize>,
    issue_quanta: Vec<usize>,
) {
    let n = tenants.len();
    let mut batchers: Vec<Batcher> =
        tenants.iter().map(|t| Batcher::new(t.policy.clone())).collect();
    let mut responders: Vec<HashMap<u64, mpsc::Sender<Result<Vec<f32>>>>> =
        (0..n).map(|_| HashMap::new()).collect();
    let mut next_id = 0u64;
    let mut open = true;

    while open || batchers.iter().any(|b| b.pending() > 0) {
        // Collect requests for up to one tick.
        let deadline = Instant::now() + tick;
        loop {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(msg) => {
                    if msg.tenant >= n {
                        let _ = msg.respond.send(Err(Error::InvalidConfig(format!(
                            "request for tenant {}, only {n} deployed",
                            msg.tenant
                        ))));
                        continue;
                    }
                    let id = next_id;
                    next_id += 1;
                    responders[msg.tenant].insert(id, msg.respond);
                    batchers[msg.tenant].push(PendingRequest {
                        id,
                        input: msg.input,
                        enqueued: Instant::now(),
                    });
                }
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    open = false;
                    break;
                }
            }
        }

        // Issue ready batches in GACER order, bounded per tenant by its
        // segment-derived quantum (leftovers go next round — the plan's
        // pointer boundaries realized as issue-queue yields).
        let now = Instant::now();
        for &t in &issue_order {
            let quantum = issue_quanta.get(t).copied().unwrap_or(usize::MAX);
            let mut issued = 0usize;
            while issued < quantum {
                let Some((variant, batch)) = batchers[t].drain(now) else { break };
                issue_batch(
                    &tenants[t], &variants[t], &params, &executor,
                    &mut responders[t], variant, batch,
                );
                issued += 1;
            }
        }
        if !open {
            for &t in &issue_order {
                while let Some((variant, batch)) = batchers[t].flush() {
                    issue_batch(
                        &tenants[t], &variants[t], &params, &executor,
                        &mut responders[t], variant, batch,
                    );
                }
            }
            break;
        }
    }
}

/// Execute one drained batch — possibly as GACER micro-batches — and
/// distribute output rows to the requesters.
fn issue_batch(
    tenant: &TenantSpec,
    variants: &HashMap<usize, String>,
    params: &[Vec<f32>],
    executor: &ExecutorHandle,
    responders: &mut HashMap<u64, mpsc::Sender<Result<Vec<f32>>>>,
    variant: usize,
    batch: Vec<PendingRequest>,
) {
    let per_input = batch[0].input.len();
    // Spatial regulation on the real path: split into chunk-sized
    // micro-batches when the plan asks for it (and a variant exists).
    let pieces: Vec<&[PendingRequest]> = match tenant.chunk {
        Some(c) if c < variant && variants.contains_key(&c) => batch.chunks(c).collect(),
        _ => vec![&batch[..]],
    };

    for piece in pieces {
        let v = pick_variant(variants, piece.len());
        let entry = &variants[&v];
        let mut x = vec![0.0f32; v * per_input];
        for (i, r) in piece.iter().enumerate() {
            x[i * per_input..(i + 1) * per_input].copy_from_slice(&r.input);
        }
        let mut inputs = Vec::with_capacity(1 + params.len());
        inputs.push(x);
        inputs.extend(params.iter().cloned());

        match executor.submit_blocking(entry.clone(), inputs) {
            Ok(outputs) => {
                let out = &outputs[0];
                let per_out = out.len() / v;
                for (i, r) in piece.iter().enumerate() {
                    if let Some(tx) = responders.remove(&r.id) {
                        let row = out[i * per_out..(i + 1) * per_out].to_vec();
                        let _ = tx.send(Ok(row));
                    }
                }
            }
            Err(e) => {
                for r in piece {
                    if let Some(tx) = responders.remove(&r.id) {
                        let _ = tx.send(Err(Error::Backend(e.to_string())));
                    }
                }
            }
        }
    }
}

fn pick_variant(variants: &HashMap<usize, String>, n: usize) -> usize {
    let mut keys: Vec<usize> = variants.keys().copied().collect();
    keys.sort_unstable();
    keys.iter().copied().find(|&v| v >= n).unwrap_or(*keys.last().unwrap())
}

/// Result of the demo serving run (the e2e driver's report).
#[derive(Debug)]
pub struct ServeReport {
    pub per_tenant: Vec<(String, LatencyHistogram)>,
    pub total_requests: usize,
    pub elapsed: Duration,
}

impl ServeReport {
    pub fn throughput_rps(&self) -> f64 {
        self.total_requests as f64 / self.elapsed.as_secs_f64()
    }
}

/// The e2e demo driver (`gacer serve`): build a [`GacerEngine`] over DFG
/// proxies of the requested families, shard them across `n_devices`
/// (1 = the classic single-GPU deployment), let the granularity-aware
/// search produce one plan per device, lower each to its live server
/// config, and serve `n_requests` per tenant of real inference through
/// the cluster front-end ([`crate::coordinator::ClusterServer`] — with a
/// single device this is one scheduler, exactly the old behavior).
///
/// [`GacerEngine`]: crate::engine::GacerEngine
pub fn serve_demo(
    artifact_dir: &str,
    tenant_models: &[String],
    n_requests: usize,
    n_devices: usize,
) -> Result<ServeReport> {
    let mut builder = crate::engine::GacerEngine::builder()
        .platform(crate::profile::Platform::titan_v())
        .devices(n_devices)
        .artifacts(artifact_dir);
    for (i, family) in tenant_models.iter().enumerate() {
        builder = builder.serving_tenant(
            format!("{family}-{i}"),
            family,
            BatchPolicy::new(8, Duration::from_millis(2), vec![1, 2, 4, 8, 16, 32]),
        )?;
    }
    let engine = builder.build()?;
    let deployment = engine.sharded_deployment()?;
    println!(
        "searched plan: {} decomposed ops across {} device(s)",
        engine.plan().decomposed_ops(),
        engine.n_devices(),
    );
    for (d, dep) in deployment.per_device.iter().enumerate() {
        println!(
            "  device {d}: tenants {:?}, issue order {:?}, chunks {:?}",
            engine.placement().tenants_on(d),
            dep.config.issue_order,
            dep.tenants.iter().map(|t| t.chunk).collect::<Vec<_>>()
        );
    }
    let n_tenants = tenant_models.len();
    let server = Arc::new(engine.serve_cluster()?);

    let started = Instant::now();
    let mut handles = Vec::new();
    for t in 0..n_tenants {
        let server = Arc::clone(&server);
        handles.push(std::thread::spawn(move || -> Result<LatencyHistogram> {
            let mut hist = LatencyHistogram::new();
            for i in 0..n_requests {
                // Deterministic pseudo-input per (tenant, request).
                let x: Vec<f32> = (0..32 * 32 * 3)
                    .map(|k| (((t * 7919 + i * 131 + k) % 97) as f32 / 97.0) - 0.5)
                    .collect();
                let t0 = Instant::now();
                let out = server.infer(t, x)?;
                hist.record(t0.elapsed());
                if out.len() != 10 {
                    return Err(Error::InvalidData(format!(
                        "expected 10 logits, got {}",
                        out.len()
                    )));
                }
                if !out.iter().all(|v| v.is_finite()) {
                    return Err(Error::InvalidData("non-finite logits".into()));
                }
            }
            Ok(hist)
        }));
    }

    let mut per_tenant = Vec::new();
    for (t, h) in handles.into_iter().enumerate() {
        let hist = h
            .join()
            .map_err(|_| Error::ChannelClosed("client thread"))??;
        per_tenant.push((tenant_models[t].clone(), hist));
    }
    let report = ServeReport {
        per_tenant,
        total_requests: n_requests * n_tenants,
        elapsed: started.elapsed(),
    };
    println!(
        "served {} requests in {:.2}s  ({:.1} req/s)",
        report.total_requests,
        report.elapsed.as_secs_f64(),
        report.throughput_rps()
    );
    for (name, hist) in &report.per_tenant {
        println!("  tenant {name:<12} {}", hist.summary());
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn issue_order_must_be_permutation() {
        let cfg = ServerConfig { issue_order: vec![2, 1, 0], ..Default::default() };
        cfg.validate(3).unwrap();
        // Out of range.
        let cfg = ServerConfig { issue_order: vec![0, 3], ..Default::default() };
        assert!(cfg.validate(2).is_err());
        // Duplicate.
        let cfg = ServerConfig { issue_order: vec![0, 0, 1], ..Default::default() };
        assert!(cfg.validate(3).is_err());
        // Incomplete.
        let cfg = ServerConfig { issue_order: vec![0, 1], ..Default::default() };
        assert!(cfg.validate(3).is_err());
        // Empty = arrival order, always fine.
        ServerConfig::default().validate(5).unwrap();
    }

    #[test]
    fn issue_quanta_validated() {
        let cfg = ServerConfig { issue_quanta: vec![1, 4], ..Default::default() };
        cfg.validate(2).unwrap();
        let cfg = ServerConfig { issue_quanta: vec![1], ..Default::default() };
        assert!(cfg.validate(2).is_err());
        let cfg = ServerConfig { issue_quanta: vec![1, 0], ..Default::default() };
        assert!(cfg.validate(2).is_err());
    }
}
