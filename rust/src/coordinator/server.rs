//! Multi-tenant serving server: per-tenant queues + dynamic batchers on a
//! scheduler thread, a GACER-ordered issue loop, and the PJRT executor
//! thread. Pure std threading — the deployment binary carries no async
//! runtime.

use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use super::batcher::{BatchPolicy, Batcher, PendingRequest};
use super::executor::ExecutorHandle;
use crate::metrics::LatencyHistogram;
use crate::runtime::{load_params, ArtifactManifest};

/// One tenant of the serving deployment.
#[derive(Debug, Clone)]
pub struct TenantSpec {
    /// Display name.
    pub name: String,
    /// Artifact operator family (manifest `meta.op`), e.g. `"tiny_cnn"`.
    pub family: String,
    /// Batching policy.
    pub policy: BatchPolicy,
    /// Optional spatial regulation on the real path: execute batches as
    /// micro-batches of this size (GACER `list_B` realized with the
    /// compiled batch variants).
    pub chunk: Option<usize>,
}

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Scheduler tick (batch-deadline polling resolution).
    pub tick: Duration,
    /// Tenant issue order when several batches are ready — GACER's
    /// cross-tenant schedule on the real path (index = priority).
    pub issue_order: Vec<usize>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { tick: Duration::from_micros(200), issue_order: Vec::new() }
    }
}

struct Incoming {
    tenant: usize,
    input: Vec<f32>,
    respond: mpsc::Sender<Result<Vec<f32>>>,
}

/// Handle to a running server. Cloneable; dropping the last handle stops
/// the scheduler after it drains outstanding work.
#[derive(Clone)]
pub struct Server {
    tx: mpsc::Sender<Incoming>,
}

impl Server {
    /// Start the server: opens the artifact dir, warms the executor, and
    /// spawns the scheduler thread.
    pub fn start(artifact_dir: &str, tenants: Vec<TenantSpec>, cfg: ServerConfig) -> Result<Server> {
        let manifest = ArtifactManifest::load(
            std::path::Path::new(artifact_dir).join("manifest.json"),
        )?;
        let params = load_params(artifact_dir)?;

        // Resolve compiled batch variants per tenant family.
        let mut variants: Vec<HashMap<usize, String>> = Vec::new();
        let mut warm: Vec<String> = Vec::new();
        for t in &tenants {
            let v = manifest.variants_of(&t.family);
            if v.is_empty() {
                return Err(anyhow!("no artifacts for family {}", t.family));
            }
            warm.extend(v.values().cloned());
            variants.push(v.into_iter().collect());
        }
        warm.sort();
        warm.dedup();
        let executor = ExecutorHandle::spawn(artifact_dir.to_string(), warm)?;

        let issue_order = if cfg.issue_order.is_empty() {
            (0..tenants.len()).collect()
        } else {
            cfg.issue_order.clone()
        };
        let (tx, rx) = mpsc::channel();
        std::thread::Builder::new()
            .name("gacer-scheduler".into())
            .spawn(move || {
                scheduler_loop(rx, tenants, variants, params, executor, cfg.tick, issue_order)
            })
            .context("spawn scheduler")?;
        Ok(Server { tx })
    }

    /// Submit one request and wait for its output row.
    pub fn infer(&self, tenant: usize, input: Vec<f32>) -> Result<Vec<f32>> {
        let (otx, orx) = mpsc::channel();
        self.tx
            .send(Incoming { tenant, input, respond: otx })
            .map_err(|_| anyhow!("server stopped"))?;
        orx.recv().map_err(|_| anyhow!("server dropped request"))?
    }
}

fn scheduler_loop(
    rx: mpsc::Receiver<Incoming>,
    tenants: Vec<TenantSpec>,
    variants: Vec<HashMap<usize, String>>,
    params: Vec<Vec<f32>>,
    executor: ExecutorHandle,
    tick: Duration,
    issue_order: Vec<usize>,
) {
    let n = tenants.len();
    let mut batchers: Vec<Batcher> =
        tenants.iter().map(|t| Batcher::new(t.policy.clone())).collect();
    let mut responders: Vec<HashMap<u64, mpsc::Sender<Result<Vec<f32>>>>> =
        (0..n).map(|_| HashMap::new()).collect();
    let mut next_id = 0u64;
    let mut open = true;

    while open || batchers.iter().any(|b| b.pending() > 0) {
        // Collect requests for up to one tick.
        let deadline = Instant::now() + tick;
        loop {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(msg) => {
                    let id = next_id;
                    next_id += 1;
                    responders[msg.tenant].insert(id, msg.respond);
                    batchers[msg.tenant].push(PendingRequest {
                        id,
                        input: msg.input,
                        enqueued: Instant::now(),
                    });
                }
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    open = false;
                    break;
                }
            }
        }

        // Issue ready batches in GACER order.
        let now = Instant::now();
        for &t in &issue_order {
            while let Some((variant, batch)) = batchers[t].drain(now) {
                issue_batch(
                    &tenants[t], &variants[t], &params, &executor,
                    &mut responders[t], variant, batch,
                );
            }
        }
        if !open {
            for &t in &issue_order {
                while let Some((variant, batch)) = batchers[t].flush() {
                    issue_batch(
                        &tenants[t], &variants[t], &params, &executor,
                        &mut responders[t], variant, batch,
                    );
                }
            }
            break;
        }
    }
}

/// Execute one drained batch — possibly as GACER micro-batches — and
/// distribute output rows to the requesters.
fn issue_batch(
    tenant: &TenantSpec,
    variants: &HashMap<usize, String>,
    params: &[Vec<f32>],
    executor: &ExecutorHandle,
    responders: &mut HashMap<u64, mpsc::Sender<Result<Vec<f32>>>>,
    variant: usize,
    batch: Vec<PendingRequest>,
) {
    let per_input = batch[0].input.len();
    // Spatial regulation on the real path: split into chunk-sized
    // micro-batches when the plan asks for it (and a variant exists).
    let pieces: Vec<&[PendingRequest]> = match tenant.chunk {
        Some(c) if c < variant && variants.contains_key(&c) => batch.chunks(c).collect(),
        _ => vec![&batch[..]],
    };

    for piece in pieces {
        let v = pick_variant(variants, piece.len());
        let entry = &variants[&v];
        let mut x = vec![0.0f32; v * per_input];
        for (i, r) in piece.iter().enumerate() {
            x[i * per_input..(i + 1) * per_input].copy_from_slice(&r.input);
        }
        let mut inputs = Vec::with_capacity(1 + params.len());
        inputs.push(x);
        inputs.extend(params.iter().cloned());

        match executor.submit_blocking(entry.clone(), inputs) {
            Ok(outputs) => {
                let out = &outputs[0];
                let per_out = out.len() / v;
                for (i, r) in piece.iter().enumerate() {
                    if let Some(tx) = responders.remove(&r.id) {
                        let row = out[i * per_out..(i + 1) * per_out].to_vec();
                        let _ = tx.send(Ok(row));
                    }
                }
            }
            Err(e) => {
                for r in piece {
                    if let Some(tx) = responders.remove(&r.id) {
                        let _ = tx.send(Err(anyhow!("{e}")));
                    }
                }
            }
        }
    }
}

fn pick_variant(variants: &HashMap<usize, String>, n: usize) -> usize {
    let mut keys: Vec<usize> = variants.keys().copied().collect();
    keys.sort_unstable();
    keys.iter().copied().find(|&v| v >= n).unwrap_or(*keys.last().unwrap())
}

/// Result of the demo serving run (the e2e driver's report).
#[derive(Debug)]
pub struct ServeReport {
    pub per_tenant: Vec<(String, LatencyHistogram)>,
    pub total_requests: usize,
    pub elapsed: Duration,
}

impl ServeReport {
    pub fn throughput_rps(&self) -> f64 {
        self.total_requests as f64 / self.elapsed.as_secs_f64()
    }
}

/// The e2e demo driver: serve `n_requests` per tenant of real TinyCNN
/// inference through the coordinator and report latency/throughput.
pub fn serve_demo(
    artifact_dir: &str,
    tenant_models: &[String],
    n_requests: usize,
) -> Result<ServeReport> {
    let tenants: Vec<TenantSpec> = tenant_models
        .iter()
        .enumerate()
        .map(|(i, m)| TenantSpec {
            name: format!("{m}-{i}"),
            family: m.clone(),
            policy: BatchPolicy::new(8, Duration::from_millis(2), vec![1, 2, 4, 8, 16, 32]),
            // Tenant 0 demonstrates GACER chunking on the real path.
            chunk: if i == 0 { Some(4) } else { None },
        })
        .collect();
    let n_tenants = tenants.len();
    let server = Arc::new(Server::start(artifact_dir, tenants, ServerConfig::default())?);

    let started = Instant::now();
    let mut handles = Vec::new();
    for t in 0..n_tenants {
        let server = Arc::clone(&server);
        handles.push(std::thread::spawn(move || -> Result<LatencyHistogram> {
            let mut hist = LatencyHistogram::new();
            for i in 0..n_requests {
                // Deterministic pseudo-input per (tenant, request).
                let x: Vec<f32> = (0..32 * 32 * 3)
                    .map(|k| (((t * 7919 + i * 131 + k) % 97) as f32 / 97.0) - 0.5)
                    .collect();
                let t0 = Instant::now();
                let out = server.infer(t, x)?;
                hist.record(t0.elapsed());
                anyhow::ensure!(out.len() == 10, "expected 10 logits, got {}", out.len());
                anyhow::ensure!(out.iter().all(|v| v.is_finite()), "non-finite logits");
            }
            Ok(hist)
        }));
    }

    let mut per_tenant = Vec::new();
    for (t, h) in handles.into_iter().enumerate() {
        let hist = h.join().map_err(|_| anyhow!("client thread panicked"))??;
        per_tenant.push((tenant_models[t].clone(), hist));
    }
    let report = ServeReport {
        per_tenant,
        total_requests: n_requests * n_tenants,
        elapsed: started.elapsed(),
    };
    println!(
        "served {} requests in {:.2}s  ({:.1} req/s)",
        report.total_requests,
        report.elapsed.as_secs_f64(),
        report.throughput_rps()
    );
    for (name, hist) in &report.per_tenant {
        println!("  tenant {name:<12} {}", hist.summary());
    }
    Ok(report)
}
