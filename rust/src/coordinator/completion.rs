//! Sharded, batch-notified completion queues — the request hot path's
//! reply fabric.
//!
//! The original coordinator answered every request over its own
//! `mpsc::channel`: one allocation, one `HashMap` registration, and one
//! wakeup syscall per request. Under open-loop load (see
//! `bench_util::loadgen`) that per-request machinery is pure scheduling
//! overhead — the multi-tenant serving literature identifies exactly this
//! layer as a first-order throughput ceiling. This module replaces it:
//!
//! * a waiter takes a **ticket** (one atomic increment, no allocation)
//!   and parks on the condvar of the shard its ticket hashes to;
//! * the scheduler answers a whole drained batch with **one lock
//!   acquisition and one `notify_all` per touched shard**
//!   ([`CompletionQueues::complete_batch`]) instead of one channel send
//!   per request;
//! * sharding (power-of-two shard count, ticket id modulo) keeps
//!   concurrent waiters of different requests off each other's locks.
//!
//! The legacy per-request channel path is preserved behind
//! [`CompletionMode::PerRequest`] so the `gacer-bench throughput` sweep
//! can measure both arms from one binary.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};

use crate::error::{Error, Result};

/// How a [`Server`](super::Server) hands request results back to waiting
/// clients. Chosen per server at start time (a hot swap does not change
/// it: the mode is a property of the submit-side handle, not of the
/// plan).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CompletionMode {
    /// Sharded completion queues with batched wakeups (the default):
    /// ticket per request, one notify per shard per drained batch.
    #[default]
    Batched,
    /// One `mpsc::channel` per request — the pre-refactor hot path, kept
    /// as the measured baseline arm of `gacer-bench throughput`.
    PerRequest,
}

impl CompletionMode {
    /// Stable label for reports and `BENCH_throughput.json`.
    pub fn label(&self) -> &'static str {
        match self {
            CompletionMode::Batched => "batched",
            CompletionMode::PerRequest => "per-request",
        }
    }

    /// Parse a CLI spelling (`batched` / `per-request`).
    pub fn parse(s: &str) -> Option<CompletionMode> {
        match s {
            "batched" => Some(CompletionMode::Batched),
            "per-request" | "per_request" | "channel" => Some(CompletionMode::PerRequest),
            _ => None,
        }
    }
}

/// Shard count. Power of two so `id % N_SHARDS` compiles to a mask; 16
/// shards keep dozens of concurrent client threads from contending on
/// one mutex while staying small enough that a batch completion rarely
/// touches more than a few locks.
const N_SHARDS: usize = 16;

struct ShardState {
    /// Results whose waiters have not collected them yet.
    done: HashMap<u64, Result<Vec<f32>>>,
    /// Set once by [`CompletionQueues::close`] when the scheduler exits:
    /// waiters drain any result already posted, then fail fast instead
    /// of parking forever.
    closed: bool,
}

struct Shard {
    state: Mutex<ShardState>,
    cv: Condvar,
}

impl Shard {
    fn new() -> Shard {
        Shard {
            state: Mutex::new(ShardState { done: HashMap::new(), closed: false }),
            cv: Condvar::new(),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, ShardState> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }
}

/// The sharded completion fabric of one running server. Shared between
/// the scheduler thread (producer) and every client thread parked in
/// [`Pending::wait`] (consumers).
pub(crate) struct CompletionQueues {
    shards: [Shard; N_SHARDS],
    next_id: AtomicU64,
}

impl CompletionQueues {
    pub(crate) fn new() -> Arc<CompletionQueues> {
        Arc::new(CompletionQueues {
            shards: std::array::from_fn(|_| Shard::new()),
            next_id: AtomicU64::new(0),
        })
    }

    /// Allocate a fresh ticket id (one relaxed atomic increment — the
    /// whole per-request submit-side cost of the batched path).
    pub(crate) fn ticket(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    fn shard_of(&self, id: u64) -> &Shard {
        &self.shards[(id as usize) % N_SHARDS]
    }

    /// Post one result (degenerate batch of one).
    pub(crate) fn complete(&self, id: u64, result: Result<Vec<f32>>) {
        self.complete_batch(std::iter::once((id, result)));
    }

    /// Post a batch of results: group by shard, then take each touched
    /// shard's lock **once** and wake all of its waiters with **one**
    /// `notify_all` — batch-granular wakeups instead of per-request
    /// notification.
    pub(crate) fn complete_batch<I>(&self, results: I)
    where
        I: IntoIterator<Item = (u64, Result<Vec<f32>>)>,
    {
        let mut per_shard: [Vec<(u64, Result<Vec<f32>>)>; N_SHARDS] =
            std::array::from_fn(|_| Vec::new());
        for (id, r) in results {
            per_shard[(id as usize) % N_SHARDS].push((id, r));
        }
        for (shard, batch) in self.shards.iter().zip(per_shard) {
            if batch.is_empty() {
                continue;
            }
            let mut st = shard.lock();
            for (id, r) in batch {
                st.done.insert(id, r);
            }
            drop(st);
            shard.cv.notify_all();
        }
    }

    /// Block until the result of `id` is posted and take it. Errors with
    /// [`Error::ChannelClosed`] if the scheduler closed the fabric
    /// without answering this ticket (scheduler death — a drained
    /// shutdown answers everything first).
    pub(crate) fn wait(&self, id: u64) -> Result<Vec<f32>> {
        let shard = self.shard_of(id);
        let mut st = shard.lock();
        loop {
            if let Some(r) = st.done.remove(&id) {
                return r;
            }
            if st.closed {
                return Err(Error::ChannelClosed("server completion queue"));
            }
            st = shard.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Mark the fabric closed and wake every parked waiter. Results
    /// already posted stay collectable (waiters check the table before
    /// the closed flag); unanswered tickets fail with
    /// [`Error::ChannelClosed`] instead of hanging.
    pub(crate) fn close(&self) {
        for shard in &self.shards {
            let mut st = shard.lock();
            st.closed = true;
            drop(st);
            shard.cv.notify_all();
        }
    }
}

/// How the scheduler answers one queued request. Carried inside the
/// request itself (`PendingRequest::reply`) so answering needs no
/// side-table lookup and survives hot-swap slot moves by construction.
#[derive(Debug)]
pub(crate) enum Reply {
    /// Batched path: post to the completion fabric under this ticket.
    Ticket(u64),
    /// Legacy path: answer on the request's own channel.
    Channel(mpsc::Sender<Result<Vec<f32>>>),
    /// No waiter (batcher unit tests / detached benchmark requests).
    Detached,
}

/// An in-flight request handle: redeem with [`Pending::wait`] for the
/// output row. Returned by `Server::submit` / `ClusterServer::submit` so
/// open-loop clients can decouple submission from collection — the load
/// generator keeps tens of thousands of these outstanding.
pub struct Pending {
    inner: PendingInner,
}

enum PendingInner {
    Ticket { id: u64, queues: Arc<CompletionQueues> },
    Channel(mpsc::Receiver<Result<Vec<f32>>>),
}

impl Pending {
    pub(crate) fn ticket(id: u64, queues: Arc<CompletionQueues>) -> Pending {
        Pending { inner: PendingInner::Ticket { id, queues } }
    }

    pub(crate) fn channel(rx: mpsc::Receiver<Result<Vec<f32>>>) -> Pending {
        Pending { inner: PendingInner::Channel(rx) }
    }

    /// Block until the request is answered. Every submitted request is
    /// answered exactly once — with its output row or a typed error
    /// (shed, backend failure, or [`Error::ChannelClosed`] if the server
    /// died mid-flight).
    pub fn wait(self) -> Result<Vec<f32>> {
        match self.inner {
            PendingInner::Ticket { id, queues } => queues.wait(id),
            PendingInner::Channel(rx) => {
                rx.recv().map_err(|_| Error::ChannelClosed("server request"))?
            }
        }
    }
}

impl std::fmt::Debug for Pending {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match &self.inner {
            PendingInner::Ticket { id, .. } => write!(f, "Pending::Ticket({id})"),
            PendingInner::Channel(_) => write!(f, "Pending::Channel"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ticket_ids_are_unique_and_dense() {
        let q = CompletionQueues::new();
        let ids: Vec<u64> = (0..100).map(|_| q.ticket()).collect();
        assert_eq!(ids, (0..100).collect::<Vec<u64>>());
    }

    #[test]
    fn complete_then_wait_returns_the_result() {
        let q = CompletionQueues::new();
        let id = q.ticket();
        q.complete(id, Ok(vec![1.0, 2.0]));
        assert_eq!(q.wait(id).unwrap(), vec![1.0, 2.0]);
    }

    #[test]
    fn wait_blocks_until_batch_completion_lands() {
        let q = CompletionQueues::new();
        // Tickets spanning several shards, answered in one batch from
        // another thread while the main thread waits.
        let ids: Vec<u64> = (0..40).map(|_| q.ticket()).collect();
        let producer = {
            let q = Arc::clone(&q);
            let ids = ids.clone();
            std::thread::spawn(move || {
                std::thread::sleep(std::time::Duration::from_millis(10));
                q.complete_batch(ids.into_iter().map(|id| (id, Ok(vec![id as f32]))));
            })
        };
        for id in ids {
            assert_eq!(q.wait(id).unwrap(), vec![id as f32]);
        }
        producer.join().unwrap();
    }

    #[test]
    fn close_fails_unanswered_tickets_but_keeps_posted_results() {
        let q = CompletionQueues::new();
        let answered = q.ticket();
        let orphaned = q.ticket();
        q.complete(answered, Ok(vec![7.0]));
        q.close();
        assert_eq!(q.wait(answered).unwrap(), vec![7.0], "posted result survives close");
        match q.wait(orphaned) {
            Err(Error::ChannelClosed(_)) => {}
            other => panic!("expected ChannelClosed, got {other:?}"),
        }
    }

    #[test]
    fn close_wakes_a_parked_waiter() {
        let q = CompletionQueues::new();
        let id = q.ticket();
        let waiter = {
            let q = Arc::clone(&q);
            std::thread::spawn(move || q.wait(id))
        };
        std::thread::sleep(std::time::Duration::from_millis(10));
        q.close();
        match waiter.join().unwrap() {
            Err(Error::ChannelClosed(_)) => {}
            other => panic!("expected ChannelClosed, got {other:?}"),
        }
    }

    #[test]
    fn completion_mode_parses_labels() {
        assert_eq!(CompletionMode::parse("batched"), Some(CompletionMode::Batched));
        assert_eq!(
            CompletionMode::parse("per-request"),
            Some(CompletionMode::PerRequest)
        );
        assert_eq!(CompletionMode::parse("bogus"), None);
        assert_eq!(CompletionMode::default().label(), "batched");
        assert_eq!(CompletionMode::PerRequest.label(), "per-request");
    }
}
