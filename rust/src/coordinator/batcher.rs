//! Dynamic batcher: groups per-tenant requests into the micro-batch sizes
//! the AOT artifact set provides (GACER's `list_B` realized with compiled
//! code).

use std::time::{Duration, Instant};

/// One queued inference request (payload is the flat f32 input).
#[derive(Debug)]
pub struct PendingRequest {
    pub id: u64,
    pub input: Vec<f32>,
    pub enqueued: Instant,
}

/// Batching policy: how large a batch to wait for, and for how long.
///
/// Invariant: `max_batch` never exceeds the largest compiled variant — a
/// drained batch must fit the variant that runs it (`variant_for` caps at
/// the largest variant, so a larger batch would silently overflow the
/// compiled executable's batch dimension). [`BatchPolicy::new`] clamps at
/// construction and [`Batcher::set_policy`] re-clamps hand-built values.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BatchPolicy {
    /// Preferred (maximum) batch size; at most the largest variant.
    pub max_batch: usize,
    /// Maximum time the oldest request may wait before a partial batch is
    /// flushed.
    pub max_wait: Duration,
    /// Compiled batch variants available (ascending). A drained batch is
    /// padded up to the smallest variant that fits.
    pub variants: Vec<usize>,
}

impl BatchPolicy {
    pub fn new(max_batch: usize, max_wait: Duration, mut variants: Vec<usize>) -> Self {
        variants.sort_unstable();
        variants.retain(|&v| v > 0);
        assert!(!variants.is_empty(), "need at least one compiled variant");
        // Clamp to the executable range: no batch larger than the largest
        // compiled variant, and never 0 (a zero cap would drain empty
        // batches forever).
        let max_batch = max_batch.clamp(1, *variants.last().unwrap());
        BatchPolicy { max_batch, max_wait, variants }
    }

    /// Smallest compiled variant that fits `n` requests, or the largest
    /// variant if `n` exceeds them all.
    pub fn variant_for(&self, n: usize) -> usize {
        self.variants
            .iter()
            .copied()
            .find(|&v| v >= n)
            .unwrap_or(*self.variants.last().unwrap())
    }
}

/// Per-tenant dynamic batcher.
#[derive(Debug)]
pub struct Batcher {
    policy: BatchPolicy,
    queue: Vec<PendingRequest>,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Self {
        Batcher { policy, queue: Vec::new() }
    }

    pub fn push(&mut self, req: PendingRequest) {
        self.queue.push(req);
    }

    /// Swap the batching policy, keeping the queued requests (a hot plan
    /// swap re-policies a tenant without dropping its pending work).
    /// Hand-built values are routed through the same normalization as
    /// [`BatchPolicy::new`] — variants sorted and stripped of zeros,
    /// `max_batch` re-clamped to the largest compiled variant — so the
    /// [`BatchPolicy`] invariant holds however the policy was made.
    pub fn set_policy(&mut self, policy: BatchPolicy) {
        self.policy = BatchPolicy::new(policy.max_batch, policy.max_wait, policy.variants);
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Whether a batch should be issued now.
    pub fn ready(&self, now: Instant) -> bool {
        if self.queue.is_empty() {
            return false;
        }
        self.queue.len() >= self.policy.max_batch
            || now.duration_since(self.queue[0].enqueued) >= self.policy.max_wait
    }

    /// Drain up to `max_batch` requests (FIFO) and report the compiled
    /// variant to run them with. Returns `None` when not ready.
    pub fn drain(&mut self, now: Instant) -> Option<(usize, Vec<PendingRequest>)> {
        if !self.ready(now) {
            return None;
        }
        let n = self.queue.len().min(self.policy.max_batch);
        let batch: Vec<PendingRequest> = self.queue.drain(..n).collect();
        let variant = self.policy.variant_for(batch.len());
        Some((variant, batch))
    }

    /// Remove and return every request that has been queued for at least
    /// `deadline` (FIFO order preserved among survivors). The scheduler
    /// answers each expired request with a typed shed error
    /// ([`crate::Error::DeadlineExceeded`]) instead of letting it occupy
    /// an issue round it can no longer benefit from.
    pub fn expire(&mut self, now: Instant, deadline: Duration) -> Vec<PendingRequest> {
        let (expired, keep): (Vec<PendingRequest>, Vec<PendingRequest>) = self
            .queue
            .drain(..)
            .partition(|r| now.duration_since(r.enqueued) >= deadline);
        self.queue = keep;
        expired
    }

    /// Force-drain everything regardless of readiness (shutdown path).
    pub fn flush(&mut self) -> Option<(usize, Vec<PendingRequest>)> {
        if self.queue.is_empty() {
            return None;
        }
        let n = self.queue.len().min(self.policy.max_batch);
        let batch: Vec<PendingRequest> = self.queue.drain(..n).collect();
        let variant = self.policy.variant_for(batch.len());
        Some((variant, batch))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> BatchPolicy {
        BatchPolicy::new(8, Duration::from_millis(5), vec![1, 2, 4, 8, 16])
    }

    fn req(id: u64) -> PendingRequest {
        PendingRequest { id, input: vec![0.0; 4], enqueued: Instant::now() }
    }

    #[test]
    fn variant_rounds_up() {
        let p = policy();
        assert_eq!(p.variant_for(1), 1);
        assert_eq!(p.variant_for(3), 4);
        assert_eq!(p.variant_for(8), 8);
        assert_eq!(p.variant_for(100), 16);
    }

    #[test]
    fn not_ready_when_empty() {
        let b = Batcher::new(policy());
        assert!(!b.ready(Instant::now()));
    }

    #[test]
    fn ready_at_max_batch() {
        let mut b = Batcher::new(policy());
        for i in 0..8 {
            b.push(req(i));
        }
        assert!(b.ready(Instant::now()));
        let (variant, batch) = b.drain(Instant::now()).unwrap();
        assert_eq!(variant, 8);
        assert_eq!(batch.len(), 8);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn ready_after_deadline() {
        let mut b = Batcher::new(policy());
        b.push(req(0));
        assert!(!b.ready(Instant::now()));
        assert!(b.ready(Instant::now() + Duration::from_millis(6)));
        let (variant, batch) = b.drain(Instant::now() + Duration::from_millis(6)).unwrap();
        assert_eq!((variant, batch.len()), (1, 1));
    }

    #[test]
    fn drain_preserves_fifo_order() {
        let mut b = Batcher::new(policy());
        for i in 0..10 {
            b.push(req(i));
        }
        let (_, batch) = b.drain(Instant::now()).unwrap();
        let ids: Vec<u64> = batch.iter().map(|r| r.id).collect();
        assert_eq!(ids, (0..8).collect::<Vec<_>>());
        assert_eq!(b.pending(), 2);
    }

    #[test]
    fn max_batch_clamped_to_largest_variant() {
        // Regression: a policy asking for batches of 32 over variants
        // [1, 2, 4] used to drain 32-request batches while reporting
        // variant 4 — every batch overflowed the executable it named.
        let p = BatchPolicy::new(32, Duration::from_millis(5), vec![1, 2, 4]);
        assert_eq!(p.max_batch, 4);
        let mut b = Batcher::new(p);
        for i in 0..32 {
            b.push(req(i));
        }
        let mut drained = 0;
        let mut next_id = 0;
        while let Some((variant, batch)) = b.drain(Instant::now()) {
            assert!(batch.len() <= variant, "batch must fit its variant");
            assert_eq!(variant, 4);
            for r in &batch {
                assert_eq!(r.id, next_id, "FIFO preserved across the clamp");
                next_id += 1;
            }
            drained += batch.len();
        }
        assert_eq!(drained, 32);
        // `set_policy` upholds the invariant on hand-built policies too.
        b.set_policy(BatchPolicy {
            max_batch: 99,
            max_wait: Duration::from_millis(5),
            variants: vec![1, 2, 4],
        });
        for i in 0..8 {
            b.push(req(i));
        }
        let (variant, batch) = b.drain(Instant::now()).unwrap();
        assert_eq!((variant, batch.len()), (4, 4));
        // Zero is clamped up to a runnable batch size.
        assert_eq!(BatchPolicy::new(0, Duration::ZERO, vec![2, 4]).max_batch, 1);
    }

    #[test]
    fn expire_sheds_only_overdue_requests_and_keeps_fifo() {
        let mut b = Batcher::new(policy());
        let t0 = Instant::now();
        b.push(PendingRequest { id: 0, input: vec![], enqueued: t0 });
        b.push(PendingRequest { id: 1, input: vec![], enqueued: t0 + Duration::from_millis(3) });
        b.push(PendingRequest { id: 2, input: vec![], enqueued: t0 + Duration::from_millis(9) });
        // At t0+10ms with a 5ms deadline: ids 0 and 1 are overdue.
        let expired = b.expire(t0 + Duration::from_millis(10), Duration::from_millis(5));
        assert_eq!(expired.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(b.pending(), 1);
        let (_, batch) = b.flush().unwrap();
        assert_eq!(batch[0].id, 2, "survivor keeps its place");
        // Nothing overdue: expire is a no-op.
        let mut b = Batcher::new(policy());
        b.push(req(7));
        assert!(b.expire(Instant::now(), Duration::from_secs(60)).is_empty());
        assert_eq!(b.pending(), 1);
    }

    #[test]
    fn flush_drains_partial() {
        let mut b = Batcher::new(policy());
        b.push(req(0));
        b.push(req(1));
        b.push(req(2));
        let (variant, batch) = b.flush().unwrap();
        assert_eq!(variant, 4);
        assert_eq!(batch.len(), 3);
        assert!(b.flush().is_none());
    }
}
