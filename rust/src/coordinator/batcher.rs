//! Dynamic batcher: groups per-tenant requests into the micro-batch sizes
//! the AOT artifact set provides (GACER's `list_B` realized with compiled
//! code).

use std::time::{Duration, Instant};

use super::completion::Reply;

/// One queued inference request (payload is the flat f32 input).
///
/// The request carries its own reply handle (`reply`, crate-internal):
/// wherever the request travels — across batcher drains, deadline
/// expiry, or a hot-swap slot move — the scheduler answers it directly,
/// with no per-request side-table lookup. Tests and benchmarks build
/// waiter-less requests with [`PendingRequest::detached`].
#[derive(Debug)]
pub struct PendingRequest {
    pub id: u64,
    pub input: Vec<f32>,
    pub enqueued: Instant,
    pub(crate) reply: Reply,
}

impl PendingRequest {
    /// A request with no waiter attached — for exercising the batcher in
    /// isolation (unit/property tests, benchmarks). The scheduler
    /// constructs live requests with real reply handles internally.
    pub fn detached(id: u64, input: Vec<f32>) -> PendingRequest {
        PendingRequest::detached_at(id, input, Instant::now())
    }

    /// [`PendingRequest::detached`] with an explicit enqueue time, so
    /// deadline/timeout behavior can be driven deterministically.
    pub fn detached_at(id: u64, input: Vec<f32>, enqueued: Instant) -> PendingRequest {
        PendingRequest { id, input, enqueued, reply: Reply::Detached }
    }
}

/// Batching policy: how large a batch to wait for, and for how long.
///
/// Invariant: `max_batch` never exceeds the largest compiled variant — a
/// drained batch must fit the variant that runs it (`variant_for` caps at
/// the largest variant, so a larger batch would silently overflow the
/// compiled executable's batch dimension). [`BatchPolicy::new`] clamps at
/// construction and [`Batcher::set_policy`] re-clamps hand-built values.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BatchPolicy {
    /// Preferred (maximum) batch size; at most the largest variant.
    pub max_batch: usize,
    /// Maximum time the oldest request may wait before a partial batch is
    /// flushed.
    pub max_wait: Duration,
    /// Compiled batch variants available (ascending). A drained batch is
    /// padded up to the smallest variant that fits.
    pub variants: Vec<usize>,
}

impl BatchPolicy {
    pub fn new(max_batch: usize, max_wait: Duration, mut variants: Vec<usize>) -> Self {
        variants.sort_unstable();
        variants.retain(|&v| v > 0);
        assert!(!variants.is_empty(), "need at least one compiled variant");
        // Clamp to the executable range: no batch larger than the largest
        // compiled variant, and never 0 (a zero cap would drain empty
        // batches forever).
        let max_batch = max_batch.clamp(1, *variants.last().unwrap());
        BatchPolicy { max_batch, max_wait, variants }
    }

    /// Smallest compiled variant that fits `n` requests, or the largest
    /// variant if `n` exceeds them all.
    pub fn variant_for(&self, n: usize) -> usize {
        self.variants
            .iter()
            .copied()
            .find(|&v| v >= n)
            .unwrap_or(*self.variants.last().unwrap())
    }
}

/// Per-tenant dynamic batcher.
#[derive(Debug)]
pub struct Batcher {
    policy: BatchPolicy,
    queue: Vec<PendingRequest>,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Self {
        Batcher { policy, queue: Vec::new() }
    }

    pub fn push(&mut self, req: PendingRequest) {
        self.queue.push(req);
    }

    /// Swap the batching policy, keeping the queued requests (a hot plan
    /// swap re-policies a tenant without dropping its pending work).
    /// Hand-built values are routed through the same normalization as
    /// [`BatchPolicy::new`] — variants sorted and stripped of zeros,
    /// `max_batch` re-clamped to the largest compiled variant — so the
    /// [`BatchPolicy`] invariant holds however the policy was made.
    pub fn set_policy(&mut self, policy: BatchPolicy) {
        self.policy = BatchPolicy::new(policy.max_batch, policy.max_wait, policy.variants);
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Whether a batch should be issued now.
    pub fn ready(&self, now: Instant) -> bool {
        if self.queue.is_empty() {
            return false;
        }
        self.queue.len() >= self.policy.max_batch
            || now.duration_since(self.queue[0].enqueued) >= self.policy.max_wait
    }

    /// Drain up to `max_batch` requests (FIFO) and report the compiled
    /// variant to run them with. Returns `None` when not ready.
    pub fn drain(&mut self, now: Instant) -> Option<(usize, Vec<PendingRequest>)> {
        if !self.ready(now) {
            return None;
        }
        let n = self.queue.len().min(self.policy.max_batch);
        let batch: Vec<PendingRequest> = self.queue.drain(..n).collect();
        let variant = self.policy.variant_for(batch.len());
        Some((variant, batch))
    }

    /// Remove and return every request that has been queued for at least
    /// `deadline` (FIFO order preserved among survivors). The scheduler
    /// answers each expired request with a typed shed error
    /// ([`crate::Error::DeadlineExceeded`]) instead of letting it occupy
    /// an issue round it can no longer benefit from.
    pub fn expire(&mut self, now: Instant, deadline: Duration) -> Vec<PendingRequest> {
        let (expired, keep): (Vec<PendingRequest>, Vec<PendingRequest>) = self
            .queue
            .drain(..)
            .partition(|r| now.duration_since(r.enqueued) >= deadline);
        self.queue = keep;
        expired
    }

    /// Force-drain everything regardless of readiness (shutdown path).
    pub fn flush(&mut self) -> Option<(usize, Vec<PendingRequest>)> {
        if self.queue.is_empty() {
            return None;
        }
        let n = self.queue.len().min(self.policy.max_batch);
        let batch: Vec<PendingRequest> = self.queue.drain(..n).collect();
        let variant = self.policy.variant_for(batch.len());
        Some((variant, batch))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> BatchPolicy {
        BatchPolicy::new(8, Duration::from_millis(5), vec![1, 2, 4, 8, 16])
    }

    fn req(id: u64) -> PendingRequest {
        PendingRequest::detached(id, vec![0.0; 4])
    }

    #[test]
    fn variant_rounds_up() {
        let p = policy();
        assert_eq!(p.variant_for(1), 1);
        assert_eq!(p.variant_for(3), 4);
        assert_eq!(p.variant_for(8), 8);
        assert_eq!(p.variant_for(100), 16);
    }

    #[test]
    fn not_ready_when_empty() {
        let b = Batcher::new(policy());
        assert!(!b.ready(Instant::now()));
    }

    #[test]
    fn ready_at_max_batch() {
        let mut b = Batcher::new(policy());
        for i in 0..8 {
            b.push(req(i));
        }
        assert!(b.ready(Instant::now()));
        let (variant, batch) = b.drain(Instant::now()).unwrap();
        assert_eq!(variant, 8);
        assert_eq!(batch.len(), 8);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn ready_after_deadline() {
        let mut b = Batcher::new(policy());
        b.push(req(0));
        assert!(!b.ready(Instant::now()));
        assert!(b.ready(Instant::now() + Duration::from_millis(6)));
        let (variant, batch) = b.drain(Instant::now() + Duration::from_millis(6)).unwrap();
        assert_eq!((variant, batch.len()), (1, 1));
    }

    #[test]
    fn drain_preserves_fifo_order() {
        let mut b = Batcher::new(policy());
        for i in 0..10 {
            b.push(req(i));
        }
        let (_, batch) = b.drain(Instant::now()).unwrap();
        let ids: Vec<u64> = batch.iter().map(|r| r.id).collect();
        assert_eq!(ids, (0..8).collect::<Vec<_>>());
        assert_eq!(b.pending(), 2);
    }

    #[test]
    fn max_batch_clamped_to_largest_variant() {
        // Regression: a policy asking for batches of 32 over variants
        // [1, 2, 4] used to drain 32-request batches while reporting
        // variant 4 — every batch overflowed the executable it named.
        let p = BatchPolicy::new(32, Duration::from_millis(5), vec![1, 2, 4]);
        assert_eq!(p.max_batch, 4);
        let mut b = Batcher::new(p);
        for i in 0..32 {
            b.push(req(i));
        }
        let mut drained = 0;
        let mut next_id = 0;
        while let Some((variant, batch)) = b.drain(Instant::now()) {
            assert!(batch.len() <= variant, "batch must fit its variant");
            assert_eq!(variant, 4);
            for r in &batch {
                assert_eq!(r.id, next_id, "FIFO preserved across the clamp");
                next_id += 1;
            }
            drained += batch.len();
        }
        assert_eq!(drained, 32);
        // `set_policy` upholds the invariant on hand-built policies too.
        b.set_policy(BatchPolicy {
            max_batch: 99,
            max_wait: Duration::from_millis(5),
            variants: vec![1, 2, 4],
        });
        for i in 0..8 {
            b.push(req(i));
        }
        let (variant, batch) = b.drain(Instant::now()).unwrap();
        assert_eq!((variant, batch.len()), (4, 4));
        // Zero is clamped up to a runnable batch size.
        assert_eq!(BatchPolicy::new(0, Duration::ZERO, vec![2, 4]).max_batch, 1);
    }

    #[test]
    fn expire_sheds_only_overdue_requests_and_keeps_fifo() {
        let mut b = Batcher::new(policy());
        let t0 = Instant::now();
        b.push(PendingRequest::detached_at(0, vec![], t0));
        b.push(PendingRequest::detached_at(1, vec![], t0 + Duration::from_millis(3)));
        b.push(PendingRequest::detached_at(2, vec![], t0 + Duration::from_millis(9)));
        // At t0+10ms with a 5ms deadline: ids 0 and 1 are overdue.
        let expired = b.expire(t0 + Duration::from_millis(10), Duration::from_millis(5));
        assert_eq!(expired.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(b.pending(), 1);
        let (_, batch) = b.flush().unwrap();
        assert_eq!(batch[0].id, 2, "survivor keeps its place");
        // Nothing overdue: expire is a no-op.
        let mut b = Batcher::new(policy());
        b.push(req(7));
        assert!(b.expire(Instant::now(), Duration::from_secs(60)).is_empty());
        assert_eq!(b.pending(), 1);
    }

    #[test]
    fn flush_drains_partial() {
        let mut b = Batcher::new(policy());
        b.push(req(0));
        b.push(req(1));
        b.push(req(2));
        let (variant, batch) = b.flush().unwrap();
        assert_eq!(variant, 4);
        assert_eq!(batch.len(), 3);
        assert!(b.flush().is_none());
    }

    #[test]
    fn prop_drain_expire_flush_answer_every_request_exactly_once() {
        // Seeded property: across random push/drain/expire/flush
        // interleavings under random policies, every request leaves the
        // batcher exactly once — either drained/flushed (FIFO within and
        // across batches) or expired (exactly the overdue set) — and no
        // drained batch ever exceeds the variant that runs it.
        crate::util::rng::check_property("batcher-exactly-once", 80, |rng| {
            let policy = BatchPolicy::new(
                rng.range(1, 12),
                Duration::from_millis(rng.range(1, 6) as u64),
                vec![1, 2, 4, 8, 16],
            );
            let deadline = Duration::from_millis(rng.range(2, 12) as u64);
            let mut b = Batcher::new(policy);
            let t0 = Instant::now();
            let mut pushed = 0u64;
            let mut answered: Vec<u64> = Vec::new(); // drained or flushed
            let mut expired_ids: Vec<u64> = Vec::new();
            for step in 0..rng.range(10, 60) {
                let now = t0 + Duration::from_millis(step as u64);
                match rng.below(4) {
                    0 | 1 => {
                        b.push(PendingRequest::detached_at(pushed, vec![], now));
                        pushed += 1;
                    }
                    2 => {
                        if let Some((variant, batch)) = b.drain(now) {
                            assert!(batch.len() <= variant, "batch overflows variant");
                            answered.extend(batch.iter().map(|r| r.id));
                        }
                    }
                    _ => {
                        // Every expired request must genuinely be overdue,
                        // and no overdue request may survive the sweep.
                        let swept = b.expire(now, deadline);
                        for r in &swept {
                            assert!(
                                now.duration_since(r.enqueued) >= deadline,
                                "expired a request before its deadline"
                            );
                        }
                        expired_ids.extend(swept.iter().map(|r| r.id));
                    }
                }
            }
            while let Some((variant, batch)) = b.flush() {
                assert!(batch.len() <= variant);
                answered.extend(batch.iter().map(|r| r.id));
            }
            // FIFO among issued requests: drains and flushes preserve
            // arrival order end to end (expiry removes, never reorders).
            assert!(
                answered.windows(2).all(|w| w[0] < w[1]),
                "drained requests out of FIFO order"
            );
            // Exactly once overall: issued ∪ expired = pushed, disjoint.
            let mut all: Vec<u64> = answered;
            all.extend(&expired_ids);
            all.sort_unstable();
            let n = all.len();
            all.dedup();
            assert_eq!(all.len(), n, "a request was answered twice");
            assert_eq!(all, (0..pushed).collect::<Vec<u64>>(), "a request was lost");
        });
    }

    #[test]
    fn prop_pending_never_exceeds_pushes_minus_removals() {
        // Seeded property: the queue depth visible to the scheduler's
        // queue-cap check is exact — pushes minus drains/expiries — so a
        // cap enforced against `pending()` can never be overshot by
        // batcher-internal buffering.
        crate::util::rng::check_property("batcher-pending-exact", 40, |rng| {
            let mut b = Batcher::new(BatchPolicy::new(
                rng.range(1, 8),
                Duration::from_millis(1),
                vec![1, 2, 4, 8],
            ));
            let t0 = Instant::now();
            let mut inside = 0usize;
            for step in 0..rng.range(10, 50) {
                let now = t0 + Duration::from_millis(step as u64);
                if rng.f64() < 0.6 {
                    b.push(PendingRequest::detached_at(step as u64, vec![], now));
                    inside += 1;
                }
                if rng.f64() < 0.4 {
                    if let Some((_, batch)) = b.drain(now) {
                        inside -= batch.len();
                    }
                }
                if rng.f64() < 0.2 {
                    inside -= b.expire(now, Duration::from_millis(3)).len();
                }
                assert_eq!(b.pending(), inside, "pending() drifted from truth");
            }
        });
    }
}
