//! Multi-device serving: one [`Server`] per device behind a routing
//! front-end.
//!
//! A sharded deployment runs **independently scheduled** coordinators —
//! each device has its own scheduler thread, batchers, issue order, and
//! executor, all lowered from that device's searched shard plan. The
//! [`ClusterServer`] adds the only cross-device piece the request path
//! needs: a routing table from *global* tenant slots to
//! `(device, local slot)`, fixed by the engine's [`Placement`] at
//! deployment time. Cross-device *admission control* (placing newcomers,
//! re-searching the affected shard) stays in the engine; by the time a
//! configuration reaches this type every decision is already made.
//!
//! Startup cost note: each occupied device's [`Server`] opens the shared
//! artifact directory itself (manifest + parameters are read per device,
//! mirroring per-GPU weight replication); idle devices spawn nothing.
//!
//! [`Placement`]: crate::plan::Placement

use super::server::{Server, ServerConfig, TenantSpec};
use crate::error::{Error, Result};

/// Handle to a running multi-device deployment: per-device [`Server`]s
/// plus the placement-derived routing table. Cloneable, like [`Server`];
/// dropping the last handle stops every device's scheduler after it
/// drains outstanding work.
#[derive(Clone)]
pub struct ClusterServer {
    /// One server per device; `None` for devices the placement left empty
    /// (no scheduler or executor is spawned for an idle device — routing
    /// can never point at one).
    servers: Vec<Option<Server>>,
    routing: Vec<(usize, usize)>,
}

impl ClusterServer {
    /// Check a routing table against per-device tenant counts: every
    /// global slot must map to an in-range `(device, local)` pair and
    /// every per-device slot must be claimed by exactly one global slot —
    /// the serving-side mirror of `Placement::validate`'s
    /// no-overlap/no-missing partition check.
    ///
    /// ```
    /// use gacer::coordinator::ClusterServer;
    ///
    /// // Two devices serving 3 tenants: slots 0/2 on device 0, 1 on 1.
    /// let routing = vec![(0, 0), (1, 0), (0, 1)];
    /// ClusterServer::validate_routing(&routing, &[2, 1]).unwrap();
    /// // Claiming (0, 0) twice leaves (0, 1) unserved: rejected.
    /// assert!(ClusterServer::validate_routing(&[(0, 0), (1, 0), (0, 0)], &[2, 1]).is_err());
    /// ```
    pub fn validate_routing(
        routing: &[(usize, usize)],
        tenants_per_device: &[usize],
    ) -> Result<()> {
        let total: usize = tenants_per_device.iter().sum();
        if routing.len() != total {
            return Err(Error::InvalidConfig(format!(
                "routing covers {} global slots, devices serve {total}",
                routing.len()
            )));
        }
        let mut claimed: Vec<Vec<bool>> =
            tenants_per_device.iter().map(|&n| vec![false; n]).collect();
        for (slot, &(d, l)) in routing.iter().enumerate() {
            let Some(device) = claimed.get_mut(d) else {
                return Err(Error::InvalidConfig(format!(
                    "slot {slot} routed to device {d}, only {} devices",
                    tenants_per_device.len()
                )));
            };
            if l >= device.len() {
                return Err(Error::InvalidConfig(format!(
                    "slot {slot} routed to ({d}, {l}), device {d} serves {} tenants",
                    device.len()
                )));
            }
            if std::mem::replace(&mut device[l], true) {
                return Err(Error::InvalidConfig(format!(
                    "two global slots routed to ({d}, {l})"
                )));
            }
        }
        Ok(())
    }

    /// Start one [`Server`] per *occupied* device (idle devices keep their
    /// index but spawn no threads) and the routing front-end. All servers
    /// share the artifact directory; each consumes its own lowered
    /// `(tenants, config)` pair — produced by
    /// `GacerEngine::sharded_deployment`, not written by hand.
    pub fn start(
        artifact_dir: &str,
        per_device: Vec<(Vec<TenantSpec>, ServerConfig)>,
        routing: Vec<(usize, usize)>,
    ) -> Result<ClusterServer> {
        let sizes: Vec<usize> = per_device.iter().map(|(t, _)| t.len()).collect();
        Self::validate_routing(&routing, &sizes)?;
        let mut servers = Vec::with_capacity(per_device.len());
        for (tenants, cfg) in per_device {
            servers.push(if tenants.is_empty() {
                None
            } else {
                Some(Server::start(artifact_dir, tenants, cfg)?)
            });
        }
        Ok(ClusterServer { servers, routing })
    }

    /// Submit one request for a *global* tenant slot and wait for its
    /// output row; the cluster routes it to the tenant's device.
    pub fn infer(&self, tenant: usize, input: Vec<f32>) -> Result<Vec<f32>> {
        let &(d, l) = self.routing.get(tenant).ok_or_else(|| {
            Error::InvalidConfig(format!(
                "request for tenant {tenant}, only {} deployed",
                self.routing.len()
            ))
        })?;
        // validate_routing guarantees a routed device is occupied.
        let server = self.servers[d].as_ref().ok_or_else(|| {
            Error::InvalidConfig(format!("tenant {tenant} routed to idle device {d}"))
        })?;
        server.infer(l, input)
    }

    /// Number of devices (including idle ones).
    pub fn n_devices(&self) -> usize {
        self.servers.len()
    }

    /// The server of one device, for introspection (each exposes its own
    /// effective `tenant_specs()` / `issue_order()`); `None` for a device
    /// the placement left idle.
    pub fn server(&self, device: usize) -> Option<&Server> {
        self.servers.get(device).and_then(Option::as_ref)
    }

    /// The global-slot routing table.
    pub fn routing(&self) -> &[(usize, usize)] {
        &self.routing
    }

    /// Where a global tenant slot is served: `(device, local slot)`.
    pub fn route_of(&self, tenant: usize) -> Option<(usize, usize)> {
        self.routing.get(tenant).copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routing_must_partition_the_device_slots() {
        // 3 global tenants over devices serving 2 + 1.
        ClusterServer::validate_routing(&[(0, 0), (1, 0), (0, 1)], &[2, 1]).unwrap();
        // Wrong arity.
        assert!(ClusterServer::validate_routing(&[(0, 0)], &[2, 1]).is_err());
        // Device out of range.
        assert!(
            ClusterServer::validate_routing(&[(0, 0), (2, 0), (0, 1)], &[2, 1]).is_err()
        );
        // Local slot out of range.
        assert!(
            ClusterServer::validate_routing(&[(0, 0), (1, 1), (0, 1)], &[2, 1]).is_err()
        );
        // Duplicate claim leaves another slot unserved.
        assert!(
            ClusterServer::validate_routing(&[(0, 0), (1, 0), (0, 0)], &[2, 1]).is_err()
        );
        // Empty devices are legal.
        ClusterServer::validate_routing(&[(1, 0)], &[0, 1]).unwrap();
        ClusterServer::validate_routing(&[], &[0, 0]).unwrap();
    }
}
