//! Multi-device serving: one [`Server`] per device behind a routing
//! front-end.
//!
//! A sharded deployment runs **independently scheduled** coordinators —
//! each device has its own scheduler thread, batchers, issue order, and
//! executor, all lowered from that device's searched shard plan. The
//! [`ClusterServer`] adds the only cross-device pieces the request path
//! needs: a routing table from *global* tenant slots to
//! `(device, local slot)` fixed by the engine's [`Placement`], and — for
//! **live re-deployment** — [`ClusterServer::apply`], which swaps a new
//! [`ShardedDeployment`] into the running device servers and the routing
//! table in one fenced step. Cross-device *decisions* (placing
//! newcomers, migrating tenants, re-searching shards) stay in the
//! engine; by the time a configuration reaches this type every decision
//! is already made.
//!
//! **Lock discipline on the hot path:** the routing `RwLock` is held
//! only long enough to resolve a route and enqueue the request on its
//! device's scheduler ([`ClusterServer::submit`]); waiting for the
//! result happens entirely outside the lock. A concurrent
//! [`ClusterServer::apply`] therefore blocks request *submission* only
//! for the epoch fences themselves — in-flight requests keep completing
//! throughout a swap — where the previous design parked every `infer`
//! for a request's whole lifetime behind any queued writer. Correctness
//! across the shorter fence rests on channel FIFO order: a request
//! enqueued before the fence reaches its scheduler before the swap
//! commits, and survives it under its tenant's `(name, family)`
//! identity.
//!
//! Startup cost note: each occupied device's [`Server`] opens the shared
//! artifact directory itself (manifest + parameters are read per device,
//! mirroring per-GPU weight replication); idle devices spawn nothing.
//! Synthetic backends ([`ServerBackend::Synthetic`], via
//! [`ClusterServer::start_with_backend`]) skip artifact I/O entirely.
//!
//! [`Placement`]: crate::plan::Placement
//! [`ShardedDeployment`]: crate::engine::ShardedDeployment

use std::sync::{Arc, Mutex, RwLock};

use super::completion::Pending;
use super::server::{Server, ServerBackend, ServerConfig, TenantSpec};
use crate::engine::{Deployment, ShardedDeployment};
use crate::error::{Error, Result};
use crate::profile::DeviceId;

/// The mutable half of a running cluster: per-device servers, the last
/// deployment applied to each, and the routing table — everything a hot
/// swap replaces together.
struct ClusterState {
    /// One server per device; `None` for devices the current placement
    /// leaves empty (no scheduler or executor runs on an idle device —
    /// routing can never point at one).
    servers: Vec<Option<Server>>,
    /// The deployment each device currently executes (empty tenant list
    /// for idle devices) — what [`ClusterServer::apply`] diffs against to
    /// leave unchanged devices completely untouched.
    deployments: Vec<Deployment>,
    /// The stable [`DeviceId`] of each dense position — how an elastic
    /// [`ClusterServer::apply`] matches an incoming deployment's devices
    /// against the running servers across scale-out/scale-in (dense
    /// indices shift when a device retires; ids never do).
    device_ids: Vec<DeviceId>,
    routing: Vec<(usize, usize)>,
}

/// Shared innards of a cluster handle: the routing state plus the
/// appliers' serialization lock (held across an `apply`'s preflight so
/// two concurrent appliers cannot both validate against the same
/// snapshot and then clobber each other's commits).
struct ClusterShared {
    state: RwLock<ClusterState>,
    apply_lock: Mutex<()>,
}

/// Handle to a running multi-device deployment: per-device [`Server`]s
/// plus the placement-derived routing table. Cloneable, like [`Server`];
/// dropping the last handle stops every device's scheduler after it
/// drains outstanding work.
#[derive(Clone)]
pub struct ClusterServer {
    backend: ServerBackend,
    shared: Arc<ClusterShared>,
}

fn read_state(shared: &ClusterShared) -> std::sync::RwLockReadGuard<'_, ClusterState> {
    shared.state.read().unwrap_or_else(|e| e.into_inner())
}

impl ClusterServer {
    /// Check a routing table against per-device tenant counts: every
    /// global slot must map to an in-range `(device, local)` pair and
    /// every per-device slot must be claimed by exactly one global slot —
    /// the serving-side mirror of `Placement::validate`'s
    /// no-overlap/no-missing partition check.
    ///
    /// ```
    /// use gacer::coordinator::ClusterServer;
    ///
    /// // Two devices serving 3 tenants: slots 0/2 on device 0, 1 on 1.
    /// let routing = vec![(0, 0), (1, 0), (0, 1)];
    /// ClusterServer::validate_routing(&routing, &[2, 1]).unwrap();
    /// // Claiming (0, 0) twice leaves (0, 1) unserved: rejected.
    /// assert!(ClusterServer::validate_routing(&[(0, 0), (1, 0), (0, 0)], &[2, 1]).is_err());
    /// ```
    pub fn validate_routing(
        routing: &[(usize, usize)],
        tenants_per_device: &[usize],
    ) -> Result<()> {
        let total: usize = tenants_per_device.iter().sum();
        if routing.len() != total {
            return Err(Error::InvalidConfig(format!(
                "routing covers {} global slots, devices serve {total}",
                routing.len()
            )));
        }
        let mut claimed: Vec<Vec<bool>> =
            tenants_per_device.iter().map(|&n| vec![false; n]).collect();
        for (slot, &(d, l)) in routing.iter().enumerate() {
            let Some(device) = claimed.get_mut(d) else {
                return Err(Error::InvalidConfig(format!(
                    "slot {slot} routed to device {d}, only {} devices",
                    tenants_per_device.len()
                )));
            };
            if l >= device.len() {
                return Err(Error::InvalidConfig(format!(
                    "slot {slot} routed to ({d}, {l}), device {d} serves {} tenants",
                    device.len()
                )));
            }
            if std::mem::replace(&mut device[l], true) {
                return Err(Error::InvalidConfig(format!(
                    "two global slots routed to ({d}, {l})"
                )));
            }
        }
        Ok(())
    }

    /// Start one [`Server`] per *occupied* device (idle devices keep their
    /// index but spawn no threads) and the routing front-end. All servers
    /// share the artifact directory; each consumes its own lowered
    /// `(tenants, config)` pair — produced by
    /// `GacerEngine::sharded_deployment`, not written by hand.
    pub fn start(
        artifact_dir: &str,
        per_device: Vec<(Vec<TenantSpec>, ServerConfig)>,
        routing: Vec<(usize, usize)>,
    ) -> Result<ClusterServer> {
        Self::start_with_backend(
            ServerBackend::Artifacts(artifact_dir.to_string()),
            per_device,
            routing,
        )
    }

    /// [`ClusterServer::start`] over an explicit [`ServerBackend`] —
    /// with [`ServerBackend::Synthetic`] the whole cluster (routing,
    /// per-device schedulers, hot swaps) runs without artifacts, which
    /// is how the load generator and the concurrency stress tests drive
    /// the production request path everywhere.
    pub fn start_with_backend(
        backend: ServerBackend,
        per_device: Vec<(Vec<TenantSpec>, ServerConfig)>,
        routing: Vec<(usize, usize)>,
    ) -> Result<ClusterServer> {
        let ids = (0..per_device.len()).map(|d| DeviceId(d as u64)).collect();
        Self::start_inner(backend, per_device, routing, ids)
    }

    /// Start a cluster directly from a lowered [`ShardedDeployment`] —
    /// the id-carrying counterpart of [`ClusterServer::start`]. The
    /// deployment's [`DeviceId`]s seed the cluster's identity table, so
    /// later elastic [`ClusterServer::apply`]s (after
    /// `GacerEngine::add_device` / `remove_device`) match devices by
    /// stable id instead of assuming the device count never changes.
    pub fn start_sharded(
        artifact_dir: &str,
        deployment: ShardedDeployment,
    ) -> Result<ClusterServer> {
        Self::start_sharded_with_backend(
            ServerBackend::Artifacts(artifact_dir.to_string()),
            deployment,
        )
    }

    /// [`ClusterServer::start_sharded`] over an explicit
    /// [`ServerBackend`].
    pub fn start_sharded_with_backend(
        backend: ServerBackend,
        deployment: ShardedDeployment,
    ) -> Result<ClusterServer> {
        let ShardedDeployment { per_device, routing, device_ids } = deployment;
        Self::check_device_ids(&device_ids, per_device.len())?;
        let per_device = per_device.into_iter().map(|d| (d.tenants, d.config)).collect();
        Self::start_inner(backend, per_device, routing, device_ids)
    }

    fn start_inner(
        backend: ServerBackend,
        per_device: Vec<(Vec<TenantSpec>, ServerConfig)>,
        routing: Vec<(usize, usize)>,
        device_ids: Vec<DeviceId>,
    ) -> Result<ClusterServer> {
        let sizes: Vec<usize> = per_device.iter().map(|(t, _)| t.len()).collect();
        Self::validate_routing(&routing, &sizes)?;
        let mut servers = Vec::with_capacity(per_device.len());
        let mut deployments = Vec::with_capacity(per_device.len());
        for (tenants, cfg) in per_device {
            servers.push(if tenants.is_empty() {
                None
            } else {
                Some(Server::start_with_backend(
                    backend.clone(),
                    tenants.clone(),
                    cfg.clone(),
                )?)
            });
            deployments.push(Deployment { tenants, config: cfg });
        }
        Ok(ClusterServer {
            backend,
            shared: Arc::new(ClusterShared {
                state: RwLock::new(ClusterState {
                    servers,
                    deployments,
                    device_ids,
                    routing,
                }),
                apply_lock: Mutex::new(()),
            }),
        })
    }

    /// A deployment's device-id list must name each device exactly once.
    fn check_device_ids(device_ids: &[DeviceId], n_devices: usize) -> Result<()> {
        if device_ids.len() != n_devices {
            return Err(Error::InvalidConfig(format!(
                "deployment lists {} device ids for {n_devices} devices",
                device_ids.len()
            )));
        }
        let mut seen: Vec<u64> = device_ids.iter().map(|id| id.0).collect();
        seen.sort_unstable();
        seen.dedup();
        if seen.len() != device_ids.len() {
            return Err(Error::InvalidConfig(
                "deployment repeats a device id".into(),
            ));
        }
        Ok(())
    }

    /// Hot-swap a freshly lowered [`ShardedDeployment`] into the running
    /// cluster — the multi-device live re-deployment path
    /// ([`crate::engine::GacerEngine::redeploy_cluster`] calls this after
    /// `admit`/`evict`/`replan`/migration). Returns the devices that
    /// actually changed.
    ///
    /// Per device, diffed against the deployment currently executing:
    ///
    /// * **unchanged** — the device's server is not touched at all (no
    ///   fence, no swap): tenant churn re-searches one or two shards, so
    ///   most devices diff empty;
    /// * **changed, occupied → occupied** — [`Server::apply`]: an
    ///   epoch-fenced in-place swap; queued requests of persisting
    ///   tenants survive;
    /// * **idle → occupied** — a fresh [`Server`] starts (this is the
    ///   one case that pays startup cost: manifest + params + executor
    ///   warmup for that device);
    /// * **occupied → idle** — the device's server is dropped after its
    ///   scheduler drains (a migrated-away tenant's queued requests were
    ///   already flushed by the destination-side fence semantics of
    ///   [`Server::apply`], or drain here).
    ///
    /// Devices are matched by stable [`DeviceId`], so the deployment may
    /// span a *different* device set than the running cluster: an id the
    /// cluster has never seen joins (scale-out — idle → occupied rules
    /// apply), and a running id absent from the deployment retires
    /// (scale-in — its server drains and stops once the new routing
    /// table, which can no longer reach it, is committed).
    ///
    /// Concurrency: appliers serialize on a dedicated lock, and all the
    /// *expensive* fallible work — routing validation, per-device
    /// preflight, and bringing fresh servers up — happens **before** the
    /// routing write lock is taken, so request submission keeps flowing
    /// while a swap validates and warms up. The write lock is held only
    /// for the epoch fences and the routing-table swap — exactly the
    /// window the fence semantics require. Requests **in flight** when
    /// the lock is taken are unaffected (waiting happens outside the
    /// lock; their batcher entries survive by tenant identity); requests
    /// submitted during the fence block briefly, then route by the new
    /// table. Nothing is dropped in either case.
    ///
    /// Failure semantics: a malformed deployment or a failed device
    /// bring-up is rejected with the running cluster unchanged (every
    /// fallible step precedes the commit). A swap can then only fail on
    /// a device whose scheduler has already died; the commit finishes
    /// the remaining healthy devices, swaps the routing table so every
    /// living device ends consistent with it, and returns that device's
    /// error (it needs a restart — it was failing requests regardless).
    ///
    /// ```no_run
    /// use gacer::coordinator::BatchPolicy;
    /// use gacer::engine::GacerEngine;
    /// use std::time::Duration;
    ///
    /// let policy = BatchPolicy::new(8, Duration::from_millis(2), vec![1, 2, 4, 8]);
    /// let mut engine = GacerEngine::builder()
    ///     .devices(2)
    ///     .artifacts("artifacts")
    ///     .serving_tenant("t0", "tiny_cnn", policy.clone()).unwrap()
    ///     .serving_tenant("t1", "tiny_cnn", policy.clone()).unwrap()
    ///     .build().unwrap();
    /// let cluster = engine.serve_cluster().unwrap();
    /// engine.admit_serving("t2", "tiny_cnn", policy).unwrap();
    /// // Only the device that received t2 is swapped.
    /// let touched = cluster.apply(engine.sharded_deployment().unwrap()).unwrap();
    /// assert_eq!(touched.len(), 1);
    /// ```
    pub fn apply(&self, deployment: ShardedDeployment) -> Result<Vec<usize>> {
        // One applier at a time: the preflight below validates against a
        // snapshot, and this lock guarantees no other applier commits
        // between that snapshot and ours.
        let _serialized = self.shared.apply_lock.lock().unwrap_or_else(|e| e.into_inner());

        let ShardedDeployment { per_device, routing, device_ids } = deployment;
        Self::check_device_ids(&device_ids, per_device.len())?;
        let sizes: Vec<usize> = per_device.iter().map(|d| d.tenants.len()).collect();
        Self::validate_routing(&routing, &sizes)?;

        // Snapshot under a read lock (server handles are cheap clones);
        // request traffic keeps flowing through everything below until
        // the commit. Devices are matched **by stable id**, not dense
        // position: the incoming deployment may have grown, shrunk, or
        // reordered the pool since this cluster started.
        let (old_servers, old_deployments, old_ids) = {
            let st = read_state(&self.shared);
            (st.servers.clone(), st.deployments.clone(), st.device_ids.clone())
        };
        // Run every fallible step BEFORE touching any running server or
        // taking the write lock: preflight each in-place swap (config,
        // shape, names, variants against that server's backend —
        // server.apply repeats this internally, which is cheap and keeps
        // one code path) and bring devices coming online up (manifest/
        // params I/O, executor warmup, config validation in
        // Server::start). Failing anywhere here leaves the cluster
        // exactly as it was — fresh servers are dropped without ever
        // having been routed to.
        let mut fresh: Vec<(DeviceId, Server)> = Vec::new();
        for (d, dep) in per_device.iter().enumerate() {
            let prev = old_ids.iter().position(|&id| id == device_ids[d]);
            let unchanged = prev.is_some_and(|p| old_deployments[p] == *dep);
            if unchanged || dep.tenants.is_empty() {
                continue;
            }
            match prev.and_then(|p| old_servers[p].clone()) {
                Some(server) => {
                    server.preflight_apply(dep)?;
                }
                None => fresh.push((
                    device_ids[d],
                    Server::start_with_backend(
                        self.backend.clone(),
                        dep.tenants.clone(),
                        dep.config.clone(),
                    )?,
                )),
            }
        }
        // Commit under the write lock: epoch fences + routing swap only.
        // The state vectors are rebuilt in the incoming deployment's
        // order; a surviving unchanged device's server is carried over
        // untouched (no fence, no swap), and a retired id's server is
        // dropped after the lock is released (it drains, then stops).
        // From here on the only possible failure is a device whose
        // scheduler has died (its preflight passed); the loop finishes
        // the remaining healthy devices — a failed device keeps its old
        // plan — and STILL swaps the routing table so every living
        // device ends consistent with it, then reports that error.
        let mut st = self.shared.state.write().unwrap_or_else(|e| e.into_inner());
        let prev_servers = std::mem::take(&mut st.servers);
        let prev_deployments = std::mem::take(&mut st.deployments);
        let prev_ids = std::mem::replace(&mut st.device_ids, device_ids.clone());
        let mut new_servers = Vec::with_capacity(per_device.len());
        let mut new_deployments = Vec::with_capacity(per_device.len());
        let mut touched = Vec::new();
        let mut first_err = None;
        for (d, dep) in per_device.into_iter().enumerate() {
            let prev = prev_ids.iter().position(|&id| id == device_ids[d]);
            let prev_server = prev.and_then(|p| prev_servers[p].clone());
            let prev_dep = prev.map(|p| &prev_deployments[p]);
            if prev_dep.is_some_and(|pd| *pd == dep) {
                // Unchanged surviving device: carried over untouched.
                new_servers.push(prev_server);
                new_deployments.push(dep);
                continue;
            }
            if dep.tenants.is_empty() {
                // Occupied -> idle drains; a brand-new idle device just
                // takes its position (nothing ran, nothing changed).
                if prev_server.is_some() {
                    touched.push(d);
                }
                new_servers.push(None);
                new_deployments.push(dep);
                continue;
            }
            match prev_server {
                Some(server) => {
                    if let Err(e) = server.apply(dep.clone()) {
                        if first_err.is_none() {
                            first_err = Some(e);
                        }
                        new_servers.push(Some(server));
                        new_deployments.push(
                            prev_dep
                                .cloned()
                                .expect("an occupied device has a deployment"),
                        );
                        continue;
                    }
                    new_servers.push(Some(server));
                }
                None => {
                    let at = fresh
                        .iter()
                        .position(|(fid, _)| *fid == device_ids[d])
                        .expect("started above for every idle->occupied device");
                    new_servers.push(Some(fresh.swap_remove(at).1));
                }
            }
            new_deployments.push(dep);
            touched.push(d);
        }
        st.servers = new_servers;
        st.deployments = new_deployments;
        st.routing = routing;
        drop(st);
        // `prev_servers` drops here, outside the routing lock: retired
        // devices' servers drain and stop without stalling submission.
        match first_err {
            Some(e) => Err(e),
            None => Ok(touched),
        }
    }

    /// Submit one request for a *global* tenant slot without waiting:
    /// resolve the route and enqueue on the tenant's device under a
    /// **short** read lock, then return the [`Pending`] handle — waiting
    /// happens entirely outside the routing lock, so a concurrent
    /// [`ClusterServer::apply`] is never stuck behind in-flight
    /// requests (and vice versa). Open-loop clients (the load generator)
    /// keep thousands of these outstanding.
    pub fn submit(&self, tenant: usize, input: Vec<f32>) -> Result<Pending> {
        let st = read_state(&self.shared);
        let &(d, l) = st.routing.get(tenant).ok_or_else(|| {
            Error::InvalidConfig(format!(
                "request for tenant {tenant}, only {} deployed",
                st.routing.len()
            ))
        })?;
        // validate_routing guarantees a routed device is occupied.
        let server = st.servers[d].as_ref().ok_or_else(|| {
            Error::InvalidConfig(format!("tenant {tenant} routed to idle device {d}"))
        })?;
        server.submit(l, input)
        // Read guard drops here: the request is enqueued FIFO ahead of
        // any later fence, so a swap can never strand or re-route it.
    }

    /// Submit one request and wait for its output row (the closed-loop
    /// convenience over [`ClusterServer::submit`]).
    pub fn infer(&self, tenant: usize, input: Vec<f32>) -> Result<Vec<f32>> {
        self.submit(tenant, input)?.wait()
    }

    /// Number of devices (including idle ones).
    pub fn n_devices(&self) -> usize {
        read_state(&self.shared).servers.len()
    }

    /// The stable [`DeviceId`] of each dense device position — parallel
    /// to [`ClusterServer::epochs`] / [`ClusterServer::server`] indices,
    /// and the key [`ClusterServer::apply`] matches devices on across
    /// scale-out/scale-in.
    pub fn device_ids(&self) -> Vec<DeviceId> {
        read_state(&self.shared).device_ids.clone()
    }

    /// The server of one device, for introspection (each exposes its own
    /// effective `tenant_specs()` / `issue_order()` / `epoch()`); `None`
    /// for a device the current placement leaves idle.
    pub fn server(&self, device: usize) -> Option<Server> {
        read_state(&self.shared).servers.get(device).and_then(Clone::clone)
    }

    /// The global-slot routing table currently in effect.
    pub fn routing(&self) -> Vec<(usize, usize)> {
        read_state(&self.shared).routing.clone()
    }

    /// Where a global tenant slot is served: `(device, local slot)`.
    pub fn route_of(&self, tenant: usize) -> Option<(usize, usize)> {
        read_state(&self.shared).routing.get(tenant).copied()
    }

    /// Per-device swap epochs (0 for idle devices and for servers still
    /// on their start-time plan).
    pub fn epochs(&self) -> Vec<u64> {
        read_state(&self.shared)
            .servers
            .iter()
            .map(|s| s.as_ref().map_or(0, Server::epoch))
            .collect()
    }

    /// Requests served so far per *global* tenant slot — the cluster-wide
    /// observed-load signal (aggregated from each device's counters via
    /// the routing table). Feed it to
    /// [`crate::engine::GacerEngine::record_served`] to drive load-drift
    /// migration; the engine diffs successive calls keyed by stable
    /// tenant id, so a counter restarting when its tenant migrates (the
    /// new device starts it fresh) is handled.
    pub fn served_counts(&self) -> Vec<u64> {
        let st = read_state(&self.shared);
        let per_device: Vec<Vec<u64>> = st
            .servers
            .iter()
            .map(|s| s.as_ref().map(Server::served_counts).unwrap_or_default())
            .collect();
        st.routing
            .iter()
            .map(|&(d, l)| per_device[d].get(l).copied().unwrap_or(0))
            .collect()
    }

    /// Requests shed so far per *global* tenant slot (queue-cap +
    /// deadline sheds, aggregated from each device's
    /// [`Server::shed_counts`] via the routing table). The
    /// cluster-wide proof that overload protection answered — rather
    /// than dropped — every rejected request.
    pub fn shed_counts(&self) -> Vec<u64> {
        let st = read_state(&self.shared);
        let per_device: Vec<Vec<u64>> = st
            .servers
            .iter()
            .map(|s| s.as_ref().map(Server::shed_counts).unwrap_or_default())
            .collect();
        st.routing
            .iter()
            .map(|&(d, l)| per_device[d].get(l).copied().unwrap_or(0))
            .collect()
    }

    /// Drain the server-observed latency samples per *global* tenant
    /// slot (each device's [`Server::take_latencies`], reordered by the
    /// routing table) — the per-window feed for
    /// [`crate::engine::GacerEngine::record_latencies`].
    pub fn take_latencies(&self) -> Vec<Vec<f64>> {
        let st = read_state(&self.shared);
        let mut per_device: Vec<Vec<Vec<f64>>> = st
            .servers
            .iter()
            .map(|s| s.as_ref().map(Server::take_latencies).unwrap_or_default())
            .collect();
        st.routing
            .iter()
            .map(|&(d, l)| {
                per_device[d].get_mut(l).map(std::mem::take).unwrap_or_default()
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routing_must_partition_the_device_slots() {
        // 3 global tenants over devices serving 2 + 1.
        ClusterServer::validate_routing(&[(0, 0), (1, 0), (0, 1)], &[2, 1]).unwrap();
        // Wrong arity.
        assert!(ClusterServer::validate_routing(&[(0, 0)], &[2, 1]).is_err());
        // Device out of range.
        assert!(
            ClusterServer::validate_routing(&[(0, 0), (2, 0), (0, 1)], &[2, 1]).is_err()
        );
        // Local slot out of range.
        assert!(
            ClusterServer::validate_routing(&[(0, 0), (1, 1), (0, 1)], &[2, 1]).is_err()
        );
        // Duplicate claim leaves another slot unserved.
        assert!(
            ClusterServer::validate_routing(&[(0, 0), (1, 0), (0, 0)], &[2, 1]).is_err()
        );
        // Empty devices are legal.
        ClusterServer::validate_routing(&[(1, 0)], &[0, 1]).unwrap();
        ClusterServer::validate_routing(&[], &[0, 0]).unwrap();
    }

    #[test]
    fn elastic_apply_matches_devices_by_stable_id() {
        use super::super::server::SyntheticModel;
        use crate::coordinator::BatchPolicy;
        use std::time::Duration;

        fn tenant(name: &str) -> TenantSpec {
            TenantSpec {
                name: name.to_string(),
                family: "synthetic".to_string(),
                policy: BatchPolicy::new(4, Duration::from_micros(200), vec![1, 2, 4]),
                chunk: None,
            }
        }
        fn dep(names: &[&str]) -> Deployment {
            Deployment {
                tenants: names.iter().map(|n| tenant(n)).collect(),
                config: ServerConfig::default(),
            }
        }

        let cluster = ClusterServer::start_sharded_with_backend(
            ServerBackend::Synthetic(SyntheticModel::echo()),
            ShardedDeployment {
                per_device: vec![dep(&["a", "b"]), dep(&["c"])],
                routing: vec![(0, 0), (0, 1), (1, 0)],
                device_ids: vec![DeviceId(0), DeviceId(1)],
            },
        )
        .unwrap();
        assert_eq!(cluster.device_ids(), vec![DeviceId(0), DeviceId(1)]);

        // Scale-out: gpu2 joins and takes tenant b off gpu0; gpu1 is
        // untouched (no fence, same server).
        let touched = cluster
            .apply(ShardedDeployment {
                per_device: vec![dep(&["a"]), dep(&["c"]), dep(&["b"])],
                routing: vec![(0, 0), (2, 0), (1, 0)],
                device_ids: vec![DeviceId(0), DeviceId(1), DeviceId(2)],
            })
            .unwrap();
        assert_eq!(touched, vec![0, 2]);
        assert_eq!(cluster.n_devices(), 3);

        // Scale-in: gpu0 retires, tenant a drains onto gpu2. Dense
        // positions shift but ids keep their meaning — gpu1's server is
        // still carried over untouched at its new position 0.
        let touched = cluster
            .apply(ShardedDeployment {
                per_device: vec![dep(&["c"]), dep(&["b", "a"])],
                routing: vec![(1, 1), (1, 0), (0, 0)],
                device_ids: vec![DeviceId(1), DeviceId(2)],
            })
            .unwrap();
        assert_eq!(touched, vec![1]);
        assert_eq!(cluster.device_ids(), vec![DeviceId(1), DeviceId(2)]);
        // Every tenant still answers on the post-scale routing.
        for t in 0..3 {
            let out = cluster.infer(t, vec![7.0; 4]).unwrap();
            assert!(!out.is_empty());
        }

        // A malformed id list is rejected before any change.
        assert!(cluster
            .apply(ShardedDeployment {
                per_device: vec![dep(&["c"]), dep(&["b", "a"])],
                routing: vec![(1, 1), (1, 0), (0, 0)],
                device_ids: vec![DeviceId(1), DeviceId(1)],
            })
            .is_err());
        assert_eq!(cluster.device_ids(), vec![DeviceId(1), DeviceId(2)]);
    }
}
