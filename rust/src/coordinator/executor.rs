//! Executor thread: sole owner of the PJRT runtime (the GPU-submission
//! thread analogue). Receives compiled-artifact jobs over an mpsc channel,
//! executes them in arrival order, and answers on per-job response
//! channels.
//!
//! Keeping PJRT on one dedicated OS thread keeps the scheduler free of
//! blocking FFI calls and models the paper's single issue queue into the
//! device: the order jobs enter this channel IS the issue order the GACER
//! schedule controls.

use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::error::{Error, Result};
use crate::runtime::Runtime;

/// Response channel for one job.
pub type Responder = mpsc::Sender<Result<Vec<Vec<f32>>>>;

/// One execution job: artifact entry, the packed batch input, and the
/// shared parameter buffers. Parameters are behind an `Arc` — submitting
/// a job costs a refcount bump, not a copy of every weight buffer (the
/// scheduler issues thousands of micro-batches per second against the
/// same parameters).
pub struct ExecJob {
    pub entry: String,
    /// Packed `[variant * per_input]` batch input (argument 0).
    pub x: Vec<f32>,
    /// Loaded parameter buffers (arguments 1..), shared across jobs.
    pub params: Arc<Vec<Vec<f32>>>,
    pub respond: Responder,
}

/// Handle to the executor thread.
pub struct ExecutorHandle {
    tx: mpsc::Sender<ExecJob>,
    join: Option<JoinHandle<()>>,
}

impl ExecutorHandle {
    /// Spawn the executor thread. The PJRT runtime is **created inside the
    /// thread** (the client and its executables are not `Send` — they live
    /// and die on the submission thread, like a CUDA context). Compilation
    /// of the `warmup` entries happens before this returns; a failure to
    /// open/compile is reported here.
    pub fn spawn(artifact_dir: String, warmup: Vec<String>) -> Result<Self> {
        let (tx, rx) = mpsc::channel::<ExecJob>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name("gacer-executor".into())
            .spawn(move || {
                let runtime = match Runtime::new(&artifact_dir) {
                    Ok(r) => r,
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                let warm_refs: Vec<&str> = warmup.iter().map(String::as_str).collect();
                if let Err(e) = runtime.warmup(&warm_refs) {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
                let _ = ready_tx.send(Ok(()));
                while let Ok(job) = rx.recv() {
                    let mut refs: Vec<&[f32]> = Vec::with_capacity(1 + job.params.len());
                    refs.push(job.x.as_slice());
                    refs.extend(job.params.iter().map(Vec::as_slice));
                    let result = runtime.execute_f32(&job.entry, &refs);
                    // Receiver may have given up; dropping the result then
                    // is correct.
                    let _ = job.respond.send(result);
                }
            })?;
        match ready_rx.recv() {
            Ok(Ok(())) => Ok(ExecutorHandle { tx, join: Some(join) }),
            Ok(Err(e)) => {
                let _ = join.join();
                Err(e)
            }
            Err(_) => Err(Error::ChannelClosed("executor thread (during startup)")),
        }
    }

    /// Submit a job; the result arrives on the returned receiver.
    pub fn submit(
        &self,
        entry: String,
        x: Vec<f32>,
        params: Arc<Vec<Vec<f32>>>,
    ) -> Result<mpsc::Receiver<Result<Vec<Vec<f32>>>>> {
        let (otx, orx) = mpsc::channel();
        self.tx
            .send(ExecJob { entry, x, params, respond: otx })
            .map_err(|_| Error::ChannelClosed("executor thread"))?;
        Ok(orx)
    }

    /// Submit and wait (examples/tests and the serial issue loop).
    pub fn submit_blocking(
        &self,
        entry: String,
        x: Vec<f32>,
        params: Arc<Vec<Vec<f32>>>,
    ) -> Result<Vec<Vec<f32>>> {
        let rx = self.submit(entry, x, params)?;
        rx.recv().map_err(|_| Error::ChannelClosed("executor response channel"))?
    }
}

impl Drop for ExecutorHandle {
    fn drop(&mut self) {
        // Replace the sender to close the channel, then join the thread.
        let (tx, _rx) = mpsc::channel();
        drop(std::mem::replace(&mut self.tx, tx));
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}
