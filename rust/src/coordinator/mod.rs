//! The multi-tenant serving coordinator — the paper's L3 contribution on
//! the real-execution path.
//!
//! Topology: tokio tasks own per-tenant request queues and dynamic
//! batchers; a dedicated **executor thread** owns the PJRT runtime (GPU
//! submission thread analogue) and issues compiled artifacts in the order
//! a GACER schedule prescribes. Python never runs here: all compute is
//! AOT-compiled HLO loaded at startup.

mod batcher;
mod executor;
mod server;

pub use batcher::{BatchPolicy, Batcher, PendingRequest};
pub use executor::{ExecJob, ExecutorHandle};
pub use server::{serve_demo, ServeReport, Server, ServerConfig, TenantSpec};
