//! The multi-tenant serving coordinator — the paper's L3 contribution on
//! the real-execution path.
//!
//! Topology (pure std threads; the deployment binary carries no async
//! runtime): a **scheduler thread** owns the per-tenant request queues and
//! dynamic batchers; a dedicated **executor thread** owns the PJRT runtime
//! (GPU submission thread analogue) and issues compiled artifacts in the
//! order a GACER schedule prescribes. The configuration it executes —
//! chunk sizes, issue order, issue quanta — is lowered from a searched
//! [`crate::plan::DeploymentPlan`] by [`crate::engine::GacerEngine`].
//! Python never runs here: all compute is AOT-compiled HLO loaded at
//! startup.

mod batcher;
mod executor;
mod server;

pub use batcher::{BatchPolicy, Batcher, PendingRequest};
pub use executor::{ExecJob, ExecutorHandle};
pub use server::{serve_demo, ServeReport, Server, ServerConfig, TenantSpec};
