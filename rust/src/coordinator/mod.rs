//! The multi-tenant serving coordinator — the paper's L3 contribution on
//! the real-execution path.
//!
//! Topology (pure std threads; the deployment binary carries no async
//! runtime): a **scheduler thread** owns the per-tenant request queues and
//! dynamic batchers; a dedicated **executor thread** owns the PJRT runtime
//! (GPU submission thread analogue) and issues compiled artifacts in the
//! order a GACER schedule prescribes. The configuration it executes —
//! chunk sizes, issue order, issue quanta — is lowered from a searched
//! [`crate::plan::DeploymentPlan`] by [`crate::engine::GacerEngine`].
//! Python never runs here: all compute is AOT-compiled HLO loaded at
//! startup.
//!
//! Multi-device deployments replicate that topology per GPU: one
//! independently scheduled [`Server`] per device, behind a
//! [`ClusterServer`] front-end that routes each request to its tenant's
//! device (the placement the engine's sharded search decided). The
//! scheduler never coordinates across devices at request time — shards
//! are independent by construction.
//!
//! Both layers are **live-reconfigurable**: [`Server::apply`] hot-swaps
//! a freshly lowered plan into a running scheduler (epoch-fenced at a
//! round boundary — queued requests survive, the executor and compiled
//! artifacts persist), and [`ClusterServer::apply`] swaps a sharded
//! deployment plus its routing table across the device pool, touching
//! only the devices whose deployment actually changed. The engine
//! drives both through `GacerEngine::redeploy`/`redeploy_cluster`; the
//! operational model is documented in `docs/OPERATIONS.md`.
//!
//! Two request-path design points matter for throughput (measured by
//! `gacer-bench throughput`, see `docs/BENCHMARKS.md`): results travel
//! back over **sharded, batch-notified completion queues**
//! ([`CompletionMode::Batched`]) rather than one channel per request,
//! and [`Server::submit`] / [`ClusterServer::submit`] return a
//! [`Pending`] handle so open-loop clients decouple submission from
//! collection. A [`SyntheticModel`] backend
//! ([`ServerBackend::Synthetic`]) runs the full path without compiled
//! artifacts for load generation and concurrency tests.
//!
//! ```
//! use gacer::coordinator::ServerConfig;
//!
//! // A lowered config must pass validation before the scheduler runs it:
//! // the issue order is a permutation of the deployed tenants.
//! let cfg = ServerConfig { issue_order: vec![2, 0, 1], ..Default::default() };
//! cfg.validate(3).unwrap();
//! assert!(cfg.validate(2).is_err());
//! ```

mod batcher;
mod cluster;
mod completion;
mod executor;
mod server;

pub use batcher::{BatchPolicy, Batcher, PendingRequest};
pub use cluster::ClusterServer;
pub use completion::{CompletionMode, Pending};
pub use executor::{ExecJob, ExecutorHandle};
pub use server::{
    name_tag, serve_demo, ServeOptions, ServeReport, Server, ServerBackend, ServerConfig,
    SyntheticModel, TenantSpec,
};
