//! GPU platform descriptions used by the cost model and the simulator.
//!
//! Peak numbers match the paper's §5.4 (Titan V 14.9 TFLOPS, P6000 12.6,
//! 1080Ti 10.4); SM counts and bandwidths are the public spec-sheet values.
//! `sync_wait_us` is the paper's `T_SW` — the CPU-GPU synchronization wait
//! a pointer costs (Fig. 6) — and `launch_us` the per-kernel issue cost,
//! both "relatively stable per system, obtained by profiling" (§4.3); here
//! they are fixed per platform.


/// A GPU platform: everything the cost model + simulator need.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Platform {
    pub name: &'static str,
    /// Peak fp32 throughput in TFLOPS.
    pub peak_tflops: f64,
    /// Number of streaming multiprocessors.
    pub sm_count: usize,
    /// Peak memory bandwidth in GB/s.
    pub mem_bw_gbps: f64,
    /// Device memory (HBM/GDDR) capacity in GB — the placement-time
    /// footprint ceiling (weights + resident activations must fit).
    pub hbm_gb: f64,
    /// CPU-GPU synchronization wait `T_SW` in microseconds (per pointer).
    pub sync_wait_us: f64,
    /// Kernel launch/issue overhead in microseconds (per operator).
    pub launch_us: f64,
    /// Contention penalty coefficient: fractional efficiency lost per unit
    /// of SM-pool oversubscription (the paper's "resource contention and
    /// corresponding overhead" of greedy multi-stream issue, §1/§2.1).
    pub contention_alpha: f64,
    /// Whether the platform supports MPS static partitioning (the paper
    /// notes P6000/1080Ti do not, §5.4).
    pub supports_mps: bool,
}

impl Platform {
    /// NVIDIA Titan V — the paper's primary evaluation platform (Fig. 7/8).
    pub fn titan_v() -> Self {
        Platform {
            name: "TitanV",
            peak_tflops: 14.9,
            sm_count: 80,
            mem_bw_gbps: 653.0,
            hbm_gb: 12.0,
            sync_wait_us: 5.0,
            launch_us: 3.0,
            contention_alpha: 0.25,
            supports_mps: true,
        }
    }

    /// NVIDIA Quadro P6000 (Table 2).
    pub fn p6000() -> Self {
        Platform {
            name: "P6000",
            peak_tflops: 12.6,
            sm_count: 60,
            mem_bw_gbps: 432.0,
            hbm_gb: 24.0,
            sync_wait_us: 6.0,
            launch_us: 3.5,
            contention_alpha: 0.28,
            supports_mps: false,
        }
    }

    /// NVIDIA GTX 1080 Ti (Table 2).
    pub fn gtx_1080ti() -> Self {
        Platform {
            name: "1080Ti",
            peak_tflops: 10.4,
            sm_count: 56,
            mem_bw_gbps: 484.0,
            hbm_gb: 11.0,
            sync_wait_us: 7.0,
            launch_us: 4.0,
            contention_alpha: 0.30,
            supports_mps: false,
        }
    }

    /// NVIDIA A100 (SXM, 40 GB) — the datacenter end of the heterogeneous
    /// pool mix. Spec-sheet fp32 peak, SM count, and HBM2e bandwidth.
    pub fn a100() -> Self {
        Platform {
            name: "A100",
            peak_tflops: 19.5,
            sm_count: 108,
            mem_bw_gbps: 1555.0,
            hbm_gb: 40.0,
            sync_wait_us: 4.0,
            launch_us: 2.5,
            contention_alpha: 0.22,
            supports_mps: true,
        }
    }

    /// NVIDIA T4 — the inference-accelerator end of the heterogeneous
    /// pool mix: a quarter of the A100's SMs and a fifth of its
    /// bandwidth, so a placement that treats the two as identical
    /// overloads it badly.
    pub fn t4() -> Self {
        Platform {
            name: "T4",
            peak_tflops: 8.1,
            sm_count: 40,
            mem_bw_gbps: 320.0,
            hbm_gb: 16.0,
            sync_wait_us: 6.0,
            launch_us: 3.5,
            contention_alpha: 0.30,
            supports_mps: true,
        }
    }

    /// All platforms of the paper's evaluation, plus the datacenter pair
    /// (A100/T4) used by the heterogeneous-pool benchmarks.
    pub fn all() -> [Platform; 5] {
        [Self::titan_v(), Self::p6000(), Self::gtx_1080ti(), Self::a100(), Self::t4()]
    }

    /// Look a platform up by (case-insensitive) name.
    pub fn by_name(name: &str) -> Option<Platform> {
        Self::all()
            .into_iter()
            .find(|p| p.name.eq_ignore_ascii_case(name))
    }

    /// Peak fp32 FLOPs per microsecond (the simulator's time unit).
    pub fn flops_per_us(&self) -> f64 {
        self.peak_tflops * 1e12 / 1e6
    }

    /// Peak bytes per microsecond.
    pub fn bytes_per_us(&self) -> f64 {
        self.mem_bw_gbps * 1e9 / 1e6
    }

    /// Device memory capacity in bytes.
    pub fn hbm_bytes(&self) -> f64 {
        self.hbm_gb * 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_by_name() {
        assert_eq!(Platform::by_name("titanv").unwrap().name, "TitanV");
        assert_eq!(Platform::by_name("P6000").unwrap().sm_count, 60);
        assert!(Platform::by_name("H100").is_none());
    }

    #[test]
    fn titan_fastest_of_the_paper_trio() {
        let [t, p, g, ..] = Platform::all();
        assert!(t.peak_tflops > p.peak_tflops);
        assert!(p.peak_tflops > g.peak_tflops);
    }

    #[test]
    fn only_titan_of_the_paper_trio_supports_mps() {
        assert!(Platform::titan_v().supports_mps);
        assert!(!Platform::p6000().supports_mps);
        assert!(!Platform::gtx_1080ti().supports_mps);
    }

    #[test]
    fn a100_and_t4_match_their_spec_sheets() {
        let a = Platform::a100();
        assert_eq!((a.sm_count, a.hbm_gb), (108, 40.0));
        assert_eq!(a.mem_bw_gbps, 1555.0);
        let t = Platform::t4();
        assert_eq!((t.sm_count, t.hbm_gb), (40, 16.0));
        assert_eq!(t.mem_bw_gbps, 320.0);
        // The ratio the heterogeneous placement must respect: the T4 has
        // well under half the A100 on every axis.
        assert!(t.peak_tflops < a.peak_tflops / 2.0);
        assert!((t.sm_count as f64) < a.sm_count as f64 / 2.0);
        assert!(t.mem_bw_gbps < a.mem_bw_gbps / 2.0);
    }

    #[test]
    fn a100_and_t4_roundtrip_by_name() {
        assert_eq!(Platform::by_name("a100").unwrap(), Platform::a100());
        assert_eq!(Platform::by_name("A100").unwrap(), Platform::a100());
        assert_eq!(Platform::by_name("t4").unwrap(), Platform::t4());
        assert_eq!(Platform::by_name("T4").unwrap(), Platform::t4());
    }

    #[test]
    fn unit_conversions() {
        let t = Platform::titan_v();
        assert!((t.flops_per_us() - 14.9e6).abs() < 1.0);
        assert!((t.bytes_per_us() - 653e3).abs() < 1.0);
    }

    #[test]
    fn by_name_roundtrips_every_platform() {
        for p in Platform::all() {
            let found = Platform::by_name(p.name).expect("own name resolves");
            assert_eq!(found, p);
            // Case-insensitive both ways.
            assert_eq!(Platform::by_name(&p.name.to_uppercase()).unwrap(), p);
            assert_eq!(Platform::by_name(&p.name.to_lowercase()).unwrap(), p);
        }
        assert!(Platform::by_name("").is_none());
        assert!(Platform::by_name("titan v").is_none()); // space, not a name
    }

    #[test]
    fn unit_conversions_all_platforms() {
        for p in Platform::all() {
            assert!((p.flops_per_us() - p.peak_tflops * 1e6).abs() < 1e-3);
            assert!((p.bytes_per_us() - p.mem_bw_gbps * 1e3).abs() < 1e-6);
            assert!(p.hbm_bytes() > 10e9, "{} HBM too small", p.name);
        }
    }

    #[test]
    fn hbm_capacity_matches_spec_sheets() {
        assert_eq!(Platform::titan_v().hbm_gb, 12.0);
        assert_eq!(Platform::p6000().hbm_gb, 24.0);
        assert_eq!(Platform::gtx_1080ti().hbm_gb, 11.0);
    }
}
