//! Analytic operator cost model: `W(O^B)` occupancy and `T(O^B)` duration.
//!
//! This is the substitute for the paper's Nsight profiling lookup table
//! (Fig. 4). Model, calibrated to reproduce the table's qualitative shape:
//!
//! * **Occupancy** `W(O^B)`: an operator exposes `p = B * out_elems`
//!   parallel work units; the pool sustains `cap` units; occupancy is
//!   `100 * (p / cap)^0.7`, clipped at 100. The **concave** exponent
//!   matches measured conv curves (occupancy grows sub-linearly in batch
//!   before saturating) — which is what makes the paper's operator
//!   resizing a real trade-off: micro-batch pieces free occupancy for
//!   co-runners at a bounded duration cost.
//! * Bandwidth-bound ops (BN/ReLU/pool: arithmetic intensity below the
//!   machine balance point) keep few SMs busy: their occupancy is scaled
//!   down by `intensity / balance`, reproducing Fig. 4's low flat BN curve.
//! * **Duration** `T(O^B)`: work at full machine rate with a small-kernel
//!   efficiency penalty, `max(flops * pen / (peak * eff), bytes / bw) +
//!   launch` — near-linear in batch above the saturation knee, modestly
//!   sub-linear below it (measured conv shape).
//! * **Memory pressure** `m`: fraction of peak DRAM bandwidth the op uses
//!   while running — the second contention resource of §4.4 claim (2).
//!
//! Results are memoized per (kind, batch): the paper stores its profiles as
//! lookup tables and the search must stay cheap (Table 4).

use std::cell::RefCell;
use std::collections::HashMap;


use crate::dfg::{OpKind, Operator};
use crate::profile::Platform;

/// Parallel-work units (output elements in flight) the SM pool sustains
/// per SM. Calibrated so a mid-network conv (56x56x256 map) saturates
/// around batch 8-16 — the knee the paper's Fig. 4 profile shows — which
/// leaves the deployed combos a wide occupancy spread to regulate.
const CAP_PER_SM: f64 = 2048.0 * 112.0;
/// Concavity of the occupancy-vs-parallelism curve (measured conv shape).
const OCC_EXPONENT: f64 = 0.7;
/// Fraction of allocated-SM peak a tuned library kernel achieves.
const KERNEL_EFFICIENCY: f64 = 0.72;
/// Minimum occupancy: one resident block pins one SM.
const MIN_OCCUPANCY: f64 = 1.5;
/// Small-kernel efficiency penalty: duration follows work at full machine
/// rate, inflated by `(1/parallelism-ratio)^PENALTY_EXP` when the kernel
/// under-fills the pool (tail/quantization effects), capped at
/// `PENALTY_CAP` (tiny kernels are launch-dominated, not slower per FLOP).
/// Measured conv curves are near-linear in batch above ~1/3 pool fill and
/// modestly sub-linear below — this matches.
const PENALTY_EXP: f64 = 0.45;
const PENALTY_CAP: f64 = 4.0;
/// Evenly spaced phase samples [`CostModel::colocation_slowdown`] draws
/// per tenant timeline when integrating SM-pool overflow.
const PHASE_SAMPLES: usize = 64;

/// Cost of one operator at one batch size — one row of the paper's
/// profiling lookup table.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OpCost {
    /// SM-pool occupancy in percent — the paper's `W(O^B)`, in (0, 100].
    pub sm_occupancy: f64,
    /// Execution duration in microseconds — the paper's `T(O^B)`.
    pub duration_us: f64,
    /// DRAM bandwidth utilization in percent while running (second fit
    /// resource).
    pub mem_util: f64,
}

impl OpCost {
    /// SM-time product in percent-microseconds (work for Eq. 2/3 residue
    /// accounting).
    pub fn sm_work(&self) -> f64 {
        self.sm_occupancy * self.duration_us
    }
}

/// Platform-specific cost model with memoized lookups.
#[derive(Debug)]
pub struct CostModel {
    pub platform: Platform,
    cache: RefCell<HashMap<(OpKind, usize), OpCost>>,
}

impl Clone for CostModel {
    fn clone(&self) -> Self {
        CostModel { platform: self.platform, cache: RefCell::new(self.cache.borrow().clone()) }
    }
}

impl CostModel {
    pub fn new(platform: Platform) -> Self {
        CostModel { platform, cache: RefCell::new(HashMap::new()) }
    }

    /// `W(O^B)` + `T(O^B)` for `kind` at batch `b`.
    pub fn cost_of(&self, kind: &OpKind, b: usize) -> OpCost {
        if let Some(c) = self.cache.borrow().get(&(*kind, b)) {
            return *c;
        }
        let c = self.compute(kind, b);
        self.cache.borrow_mut().insert((*kind, b), c);
        c
    }

    /// Cost of a DFG operator at its deployed batch.
    pub fn cost(&self, op: &Operator) -> OpCost {
        self.cost_of(&op.kind, op.batch)
    }

    fn compute(&self, kind: &OpKind, b: usize) -> OpCost {
        let p = &self.platform;
        let flops = kind.flops(b).max(1.0);
        let bytes = kind.bytes(b).max(1.0);

        // --- occupancy W(O^B): concave parallelism curve ---
        let parallelism = b as f64 * kind.out_elems() as f64;
        let cap = p.sm_count as f64 * CAP_PER_SM;
        let ratio = parallelism / cap;
        let mut w = 100.0 * ratio.powf(OCC_EXPONENT).min(1.0);

        // Bandwidth-bound ops hold few SMs (Fig. 4's BN class): scale by
        // arithmetic intensity relative to the machine balance point.
        let intensity = flops / bytes;
        let balance = p.flops_per_us() / p.bytes_per_us(); // flops per byte
        if intensity < balance {
            w *= (intensity / balance).max(0.02);
        }
        let w = w.clamp(MIN_OCCUPANCY, 100.0);

        // --- duration T(O^B): roofline with small-kernel penalty ---
        // Duration follows work (not occupancy): a half-batch kernel does
        // half the FLOPs in a bit over half the time. The penalty term
        // prices under-filled pools; it is what makes operator resizing a
        // trade-off rather than free (§4.2).
        let penalty = (1.0 / ratio.min(1.0)).powf(PENALTY_EXP).min(PENALTY_CAP);
        let t_compute = flops * penalty / (p.flops_per_us() * KERNEL_EFFICIENCY);
        let t_mem = bytes / p.bytes_per_us();
        let t = t_compute.max(t_mem) + p.launch_us;

        OpCost {
            sm_occupancy: w,
            duration_us: t,
            mem_util: (100.0 * (bytes / t) / p.bytes_per_us()).clamp(0.0, 100.0),
        }
    }

    /// Total sequential latency of a DFG (each op alone): the CuDNN-Seq
    /// per-model building block.
    pub fn sequential_latency_us(&self, dfg: &crate::dfg::Dfg) -> f64 {
        dfg.ops.iter().map(|o| self.cost(o).duration_us).sum()
    }

    /// The tenant's occupancy timeline sampled at `k` evenly spaced
    /// phases of its serial execution: entry `j` is `W(O^B)` of the
    /// operator active at time fraction `(j + 0.5) / k` of the DFG's
    /// sequential latency. This is the per-tenant ingredient of the
    /// co-location interference score — it captures *when* a tenant holds
    /// the SM pool, not just how much of it on average.
    pub fn occupancy_phases(&self, dfg: &crate::dfg::Dfg, k: usize) -> Vec<f64> {
        self.sample_phases(dfg, k, |c| c.sm_occupancy)
    }

    /// The tenant's bandwidth-demand timeline sampled at `k` evenly spaced
    /// phases, in percent of the platform's peak `bytes_per_us` — the
    /// memory axis of the two-dimensional contention roofline. Same
    /// sampling walk as [`CostModel::occupancy_phases`], reading
    /// `mem_util` instead of `sm_occupancy`.
    pub fn bandwidth_phases(&self, dfg: &crate::dfg::Dfg, k: usize) -> Vec<f64> {
        self.sample_phases(dfg, k, |c| c.mem_util)
    }

    fn sample_phases(
        &self,
        dfg: &crate::dfg::Dfg,
        k: usize,
        metric: impl Fn(&OpCost) -> f64,
    ) -> Vec<f64> {
        let costs: Vec<OpCost> = dfg.ops.iter().map(|o| self.cost(o)).collect();
        let total: f64 = costs.iter().map(|c| c.duration_us).sum();
        if costs.is_empty() || total <= 0.0 {
            return vec![0.0; k];
        }
        let mut samples = Vec::with_capacity(k);
        let mut op = 0usize;
        let mut cum_end = costs[0].duration_us;
        for j in 0..k {
            let t = (j as f64 + 0.5) / k as f64 * total;
            while t > cum_end && op + 1 < costs.len() {
                op += 1;
                cum_end += costs[op].duration_us;
            }
            samples.push(metric(&costs[op]));
        }
        samples
    }

    /// [`CostModel::occupancy_phases`] at the resolution
    /// [`CostModel::colocation_slowdown`] integrates over — the
    /// pre-sampled per-tenant timeline a placement search computes once
    /// and then scores many candidate groups with
    /// ([`slowdown_from_phases`]).
    pub fn occupancy_profile(&self, dfg: &crate::dfg::Dfg) -> Vec<f64> {
        self.occupancy_phases(dfg, PHASE_SAMPLES)
    }

    /// [`CostModel::bandwidth_phases`] at the same resolution as
    /// [`CostModel::occupancy_profile`] — the pre-sampled memory-axis
    /// timeline placement computes once per tenant.
    pub fn bandwidth_profile(&self, dfg: &crate::dfg::Dfg) -> Vec<f64> {
        self.bandwidth_phases(dfg, PHASE_SAMPLES)
    }

    /// Predicted co-location slowdown of a tenant set sharing one GPU —
    /// the interference half of a VELTAIR-style placement objective,
    /// generalized to a two-dimensional compute+memory roofline
    /// (MoCA-style: arxiv 2305.05843).
    ///
    /// Each tenant's occupancy and bandwidth-demand timelines are sampled
    /// at 64 evenly spaced normalized phases; per phase the slowdown is
    /// the **max** of SM-pool overflow (`max(0, Σ W − 100)`) and
    /// bandwidth oversubscription (`max(0, Σ m − 100)`, with the
    /// platform's `bytes_per_us` as the 100 % ceiling) — whichever
    /// resource is the bottleneck serializes the excess. `1.0` means the
    /// set saturates neither dimension in any phase; two pool- (or
    /// bandwidth-) saturating tenants score `≈ 2.0`.
    pub fn colocation_slowdown(&self, tenants: &[&crate::dfg::Dfg]) -> f64 {
        let occ: Vec<Vec<f64>> = tenants.iter().map(|d| self.occupancy_profile(d)).collect();
        let mem: Vec<Vec<f64>> = tenants.iter().map(|d| self.bandwidth_profile(d)).collect();
        let occ_refs: Vec<&[f64]> = occ.iter().map(Vec::as_slice).collect();
        let mem_refs: Vec<&[f64]> = mem.iter().map(Vec::as_slice).collect();
        roofline_slowdown(&occ_refs, &mem_refs)
    }

    /// The occupancy-only slowdown — [`CostModel::colocation_slowdown`]
    /// before the memory axis existed. Kept as the comparison arm (the
    /// `gacer-bench memory` baseline) and as the compute half of the
    /// roofline invariants in the property suite.
    pub fn occupancy_slowdown(&self, tenants: &[&crate::dfg::Dfg]) -> f64 {
        let phases: Vec<Vec<f64>> = tenants.iter().map(|d| self.occupancy_profile(d)).collect();
        let refs: Vec<&[f64]> = phases.iter().map(Vec::as_slice).collect();
        slowdown_from_phases(&refs)
    }

    /// The analytic prediction of `dfg`'s *served* latency while
    /// co-resident with `cotenants` on this device: serial latency
    /// ([`CostModel::sequential_latency_us`]) × the group's
    /// two-dimensional roofline slowdown
    /// ([`CostModel::colocation_slowdown`] over `dfg` + `cotenants`).
    /// This is the predicted half of the online calibration loop
    /// ([`crate::calibrate`]): each observe window the engine divides the
    /// served latency by this value and folds the residual into the
    /// tenant's correction EWMA. Alone on the device (`cotenants` empty)
    /// the slowdown is `1.0` and this reduces to the serial latency.
    pub fn predicted_colocated_latency_us(
        &self,
        dfg: &crate::dfg::Dfg,
        cotenants: &[&crate::dfg::Dfg],
    ) -> f64 {
        let mut group: Vec<&crate::dfg::Dfg> = Vec::with_capacity(cotenants.len() + 1);
        group.push(dfg);
        group.extend_from_slice(cotenants);
        self.sequential_latency_us(dfg) * self.colocation_slowdown(&group)
    }
}

/// [`CostModel::colocation_slowdown`] over pre-sampled tenant timelines
/// (equal-length phase vectors from [`CostModel::occupancy_profile`]).
/// Placement search and the migration policy sample each tenant **once**
/// per decision and score all candidate groups through this, instead of
/// re-walking every DFG per candidate.
pub fn slowdown_from_phases(phases: &[&[f64]]) -> f64 {
    if phases.len() < 2 {
        return 1.0;
    }
    let k = phases.iter().map(|p| p.len()).min().unwrap_or(0);
    if k == 0 {
        return 1.0;
    }
    let mut overflow = 0.0;
    for j in 0..k {
        let demand: f64 = phases.iter().map(|p| p[j]).sum();
        overflow += (demand - 100.0).max(0.0);
    }
    1.0 + overflow / (k as f64 * 100.0)
}

/// Two-dimensional roofline slowdown over pre-sampled per-tenant
/// timelines: `occupancy[i]` and `bandwidth[i]` are tenant `i`'s SM and
/// memory-bandwidth demand curves (percent of the respective ceiling,
/// from [`CostModel::occupancy_profile`] / [`CostModel::bandwidth_profile`]).
/// Per phase the integrated overflow is
/// `max(max(0, Σ W − 100), max(0, Σ m − 100))` — the binding resource
/// serializes the excess; the other rides along for free. Reduces to
/// [`slowdown_from_phases`] when no tenant moves memory.
pub fn roofline_slowdown(occupancy: &[&[f64]], bandwidth: &[&[f64]]) -> f64 {
    if occupancy.len() < 2 {
        return 1.0;
    }
    let k = occupancy
        .iter()
        .chain(bandwidth.iter())
        .map(|p| p.len())
        .min()
        .unwrap_or(0);
    if k == 0 {
        return 1.0;
    }
    let mut overflow = 0.0;
    for j in 0..k {
        let sm: f64 = occupancy.iter().map(|p| p[j]).sum();
        let mem: f64 = bandwidth.iter().map(|p| p[j]).sum();
        overflow += (sm - 100.0).max(0.0).max((mem - 100.0).max(0.0));
    }
    1.0 + overflow / (k as f64 * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> CostModel {
        CostModel::new(Platform::titan_v())
    }

    /// A mid-network conv (56x56x256 from 256 channels): the class whose
    /// occupancy curve Fig. 4 plots.
    fn conv_mid() -> OpKind {
        OpKind::Conv { h: 56, w: 56, cin: 256, cout: 256, k: 3, stride: 1 }
    }

    #[test]
    fn predicted_colocated_latency_is_serial_times_group_slowdown() {
        let m = model();
        let a = crate::models::zoo::build_default("R18").unwrap();
        let b = crate::models::zoo::build_default("V16").unwrap();
        // Alone: exactly the serial latency.
        assert_eq!(
            m.predicted_colocated_latency_us(&a, &[]),
            m.sequential_latency_us(&a)
        );
        // Co-resident: serial latency scaled by the pair's roofline.
        let expect = m.sequential_latency_us(&a) * m.colocation_slowdown(&[&a, &b]);
        let got = m.predicted_colocated_latency_us(&a, &[&b]);
        assert!((got - expect).abs() < 1e-9, "got {got}, expected {expect}");
        assert!(got >= m.sequential_latency_us(&a));
    }

    #[test]
    fn conv_occupancy_grows_and_saturates() {
        let m = model();
        let w: Vec<f64> = [1, 2, 4, 8, 16, 32, 64]
            .iter()
            .map(|&b| m.cost_of(&conv_mid(), b).sm_occupancy)
            .collect();
        for pair in w.windows(2) {
            assert!(pair[1] >= pair[0], "occupancy must be monotone: {w:?}");
        }
        assert_eq!(*w.last().unwrap(), 100.0, "saturates at large batch: {w:?}");
    }

    #[test]
    fn conv_occupancy_concave_in_batch() {
        // w(2B) < 2*w(B) below saturation — the resizing trade-off's basis.
        let m = model();
        let k = OpKind::Conv { h: 14, w: 14, cin: 512, cout: 512, k: 3, stride: 1 };
        let w1 = m.cost_of(&k, 1).sm_occupancy;
        let w2 = m.cost_of(&k, 2).sm_occupancy;
        if w2 < 100.0 {
            assert!(w2 < 2.0 * w1, "w1={w1} w2={w2}");
            assert!(w2 > w1);
        }
    }

    #[test]
    fn duration_sublinear_in_batch() {
        // t(8) << 8 * t(1): measured conv behaviour that the concave
        // occupancy model reproduces.
        let m = model();
        let t1 = m.cost_of(&conv_mid(), 1).duration_us;
        let t8 = m.cost_of(&conv_mid(), 8).duration_us;
        assert!(t8 < 8.0 * t1, "t1={t1} t8={t8}");
        assert!(t8 > t1);
    }

    #[test]
    fn bn_low_occupancy_high_mem() {
        // Fig. 4's contrast: BN occupies few SMs but saturates bandwidth.
        let m = model();
        let bn = m.cost_of(&OpKind::BatchNorm { elems: 56 * 56 * 256 }, 8);
        let cv = m.cost_of(&conv_mid(), 8);
        assert!(bn.sm_occupancy < 15.0, "bn w = {}", bn.sm_occupancy);
        assert!(cv.sm_occupancy > 40.0, "conv w = {}", cv.sm_occupancy);
        assert!(bn.mem_util > 60.0, "bn m = {}", bn.mem_util);
        assert!(bn.mem_util > cv.mem_util);
    }

    #[test]
    fn duration_includes_launch_overhead() {
        let m = model();
        let c = m.cost_of(&OpKind::ReLU { elems: 16 }, 1);
        assert!(c.duration_us >= m.platform.launch_us);
    }

    #[test]
    fn chunking_frees_occupancy_but_stretches_duration() {
        // The §4.2 trade-off in one assertion: two half-batch chunks hold
        // less occupancy each, while their summed duration slightly exceeds
        // the full op's.
        let m = model();
        let k = conv_mid();
        let full = m.cost_of(&k, 8);
        let half = m.cost_of(&k, 4);
        if full.sm_occupancy < 100.0 {
            assert!(half.sm_occupancy < full.sm_occupancy);
            assert!(2.0 * half.duration_us >= full.duration_us);
            // ...but not catastrophically (< 2x stretch incl. launch).
            assert!(2.0 * half.duration_us < 2.0 * full.duration_us);
        }
    }

    #[test]
    fn slower_platform_longer_duration() {
        let t = CostModel::new(Platform::titan_v());
        let g = CostModel::new(Platform::gtx_1080ti());
        assert!(
            g.cost_of(&conv_mid(), 8).duration_us > t.cost_of(&conv_mid(), 8).duration_us
        );
    }

    #[test]
    fn memoization_returns_identical_cost() {
        let m = model();
        let a = m.cost_of(&conv_mid(), 8);
        let b = m.cost_of(&conv_mid(), 8);
        assert_eq!(a, b);
        assert_eq!(m.cache.borrow().len(), 1);
    }

    #[test]
    fn vgg_scale_sanity() {
        // VGG16 fwd ≈ 15.5 GFLOPs/image; batch-8 sequential latency on
        // Titan V must land in the Table-2 band (combos total ~12-45 ms).
        let m = model();
        let vgg = crate::models::zoo::build("V16", 8).unwrap();
        let ms = m.sequential_latency_us(&vgg) / 1e3;
        assert!(ms > 4.0 && ms < 60.0, "VGG16 b8 seq = {ms} ms");
    }

    fn conv_net(name: &str, batch: usize, n: usize) -> crate::dfg::Dfg {
        let mut d = crate::dfg::Dfg::new(name);
        for i in 0..n {
            d.push(conv_mid(), batch, format!("conv{i}"));
        }
        d
    }

    fn bn_net(name: &str, n: usize) -> crate::dfg::Dfg {
        let mut d = crate::dfg::Dfg::new(name);
        for i in 0..n {
            d.push(OpKind::BatchNorm { elems: 56 * 56 * 256 }, 8, format!("bn{i}"));
        }
        d
    }

    #[test]
    fn occupancy_phases_sample_the_timeline() {
        let m = model();
        // A uniform net samples to a constant timeline at the op's W.
        let net = conv_net("uniform", 8, 3);
        let w = m.cost_of(&conv_mid(), 8).sm_occupancy;
        let samples = m.occupancy_phases(&net, 16);
        assert_eq!(samples.len(), 16);
        assert!(samples.iter().all(|&s| (s - w).abs() < 1e-9));
        // A mixed net's samples cover both classes, duration-weighted.
        let mut mixed = crate::dfg::Dfg::new("mixed");
        mixed.push(conv_mid(), 8, "c");
        mixed.push(OpKind::BatchNorm { elems: 56 * 56 * 256 }, 8, "b");
        let samples = m.occupancy_phases(&mixed, 64);
        let conv_w = m.cost_of(&conv_mid(), 8).sm_occupancy;
        let bn_w = m
            .cost_of(&OpKind::BatchNorm { elems: 56 * 56 * 256 }, 8)
            .sm_occupancy;
        assert!(samples.contains(&conv_w));
        assert!(samples.contains(&bn_w));
        // Empty DFG: an all-zero timeline, never a panic.
        let empty = crate::dfg::Dfg::new("empty");
        assert_eq!(m.occupancy_phases(&empty, 4), vec![0.0; 4]);
    }

    #[test]
    fn bandwidth_axis_prices_what_occupancy_misses() {
        let m = model();
        // Two bandwidth-saturating tenants hold a few percent of the SM
        // pool each — the occupancy-only model calls co-location free —
        // but together they oversubscribe DRAM bandwidth ~2x, and the
        // roofline prices that.
        let a = bn_net("bn-a", 6);
        let b = bn_net("bn-b", 4);
        assert_eq!(m.occupancy_slowdown(&[&a, &b]), 1.0);
        let roofline = m.colocation_slowdown(&[&a, &b]);
        assert!(roofline > 1.5, "bandwidth pair = {roofline}");
        assert!(roofline <= 2.0 + 1e-9);
        // A single tenant is free by definition, in both models.
        let c = conv_net("conv", 32, 4);
        assert_eq!(m.colocation_slowdown(&[&c]), 1.0);
        assert_eq!(m.colocation_slowdown(&[]), 1.0);
        assert_eq!(m.occupancy_slowdown(&[&c]), 1.0);
        assert_eq!(m.occupancy_slowdown(&[]), 1.0);
    }

    #[test]
    fn bandwidth_phases_mirror_occupancy_sampling() {
        let m = model();
        // Uniform BN net: constant bandwidth timeline at the op's mem_util.
        let net = bn_net("bn", 3);
        let mu = m.cost_of(&OpKind::BatchNorm { elems: 56 * 56 * 256 }, 8).mem_util;
        let samples = m.bandwidth_phases(&net, 16);
        assert_eq!(samples.len(), 16);
        assert!(samples.iter().all(|&s| (s - mu).abs() < 1e-9));
        // Empty DFG: all-zero timeline, never a panic.
        let empty = crate::dfg::Dfg::new("empty");
        assert_eq!(m.bandwidth_phases(&empty, 4), vec![0.0; 4]);
        assert_eq!(m.bandwidth_profile(&empty).len(), 64);
    }

    #[test]
    fn roofline_reduces_to_occupancy_without_memory_demand() {
        let occ: Vec<&[f64]> = vec![&[80.0, 60.0], &[50.0, 20.0]];
        let mem: Vec<&[f64]> = vec![&[0.0, 0.0], &[0.0, 0.0]];
        assert!(
            (roofline_slowdown(&occ, &mem) - slowdown_from_phases(&occ)).abs() < 1e-12
        );
        // Memory binds in phase 0 (150 > 130), occupancy in phase 1.
        let mem2: Vec<&[f64]> = vec![&[90.0, 10.0], &[60.0, 10.0]];
        let expect = 1.0 + (50.0 + 0.0).max(0.0) / 200.0;
        assert!((roofline_slowdown(&occ, &mem2) - expect).abs() < 1e-12);
    }

    #[test]
    fn sm_work_edge_cases() {
        let m = model();
        // Batch-1 weight-dominated Linear: duration is memory-bound on the
        // weight stream, occupancy pinned at the floor, sm_work tiny but
        // positive.
        let lin = m.cost_of(&OpKind::Linear { fin: 4096, fout: 4096 }, 1);
        assert!(lin.sm_occupancy >= MIN_OCCUPANCY);
        assert!(lin.sm_work() > 0.0);
        assert!(lin.mem_util > 50.0, "weight-stream bound: {}", lin.mem_util);
        // Degenerate 1-element op: floor occupancy, launch-dominated
        // duration, sm_work ≈ MIN_OCCUPANCY * launch.
        let tiny = m.cost_of(&OpKind::ReLU { elems: 1 }, 1);
        assert_eq!(tiny.sm_occupancy, MIN_OCCUPANCY);
        assert!(tiny.sm_work() >= MIN_OCCUPANCY * m.platform.launch_us);
        // Zero-op DFG: sequential latency 0, phases all-zero.
        let empty = crate::dfg::Dfg::new("empty");
        assert_eq!(m.sequential_latency_us(&empty), 0.0);
    }

    #[test]
    fn colocation_prices_saturating_pairs() {
        let m = model();
        // Two tenants that each saturate the pool roughly halve each
        // other's speed; a saturating tenant beside a bandwidth-bound one
        // barely overflows.
        let hi_a = conv_net("hi-a", 32, 4);
        let hi_b = conv_net("hi-b", 32, 2);
        let lo = bn_net("lo", 6);
        let both_hi = m.colocation_slowdown(&[&hi_a, &hi_b]);
        let mixed = m.colocation_slowdown(&[&hi_a, &lo]);
        assert!(both_hi > 1.8, "saturating pair = {both_hi}");
        assert!(both_hi <= 2.0 + 1e-9);
        assert!(mixed > 1.0 && mixed < 1.3, "mixed pair = {mixed}");
        assert!(mixed < both_hi);
    }

    #[test]
    fn occupancy_heterogeneity_across_zoo() {
        // The multi-tenant premise: deployed models expose a wide spread of
        // per-op occupancies for the regulator to pack.
        let m = model();
        let combo = crate::models::zoo::build_combo(&["R50", "V16", "M3"]);
        let mut lo = f64::MAX;
        let mut hi: f64 = 0.0;
        for d in &combo {
            for o in &d.ops {
                let w = m.cost(o).sm_occupancy;
                lo = lo.min(w);
                hi = hi.max(w);
            }
        }
        assert!(lo < 10.0, "min occupancy {lo}");
        assert!(hi == 100.0, "max occupancy {hi}");
    }
}
