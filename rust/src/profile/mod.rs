//! Operator cost model — the lookup table `W(O^B)`, `T(O^B)` of §4.1.
//!
//! The paper builds this table by profiling each operator on the target
//! GPU with Nsight (Fig. 4). Without NVIDIA hardware we substitute an
//! analytic model per platform (DESIGN.md §2) that preserves the table's
//! qualitative shape: compute-heavy convs saturate SM occupancy as batch
//! grows; BN/ReLU stay bandwidth-bound and small; duration follows a
//! roofline `max(flops/achievable-compute, bytes/bandwidth)` plus a fixed
//! kernel-launch overhead.

mod cost;
mod platform;
mod pool;

pub use cost::{roofline_slowdown, slowdown_from_phases, CostModel, OpCost};
pub use platform::Platform;
pub use pool::{DeviceId, DevicePool};
