//! The device dimension as a first-class value: an ordered pool of
//! per-device [`Platform`] profiles with **stable identities**.
//!
//! Every multi-device decision in the crate — placement, per-shard
//! search, admission, migration, serving — used to take a bare
//! `n_devices: usize` and price every device with one shared
//! [`CostModel`], which silently assumes a homogeneous fleet. A
//! [`DevicePool`] replaces that: each device carries its own cost model
//! (built from its own [`Platform`]), so a T4 beside an A100 is priced
//! as a T4 — smaller SM pool, lower bandwidth peak, its own HBM
//! capacity.
//!
//! **DeviceId stability contract:** a [`DeviceId`] is assigned once when
//! the device joins the pool and never reused. Dense indices (positions
//! in the pool, what [`crate::plan::Placement`] partitions over) shift
//! when a device is removed; ids never do. Everything that must survive
//! scale-in — the cluster server's per-device diff, migration records,
//! operator-facing APIs — is keyed by id; everything positional
//! (placement bins, shard vectors, routing tables) is keyed by dense
//! index and rebuilt from the pool's current order.

use super::{CostModel, Platform};
use std::fmt;

/// Stable identity of one device in a [`DevicePool`] — assigned at join,
/// never reused, unchanged by the removal of other devices.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct DeviceId(pub u64);

impl fmt::Display for DeviceId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "gpu{}", self.0)
    }
}

#[derive(Debug, Clone)]
struct PoolDevice {
    id: DeviceId,
    cost: CostModel,
}

/// An ordered, elastic pool of devices, each with its own [`Platform`]
/// profile and [`CostModel`].
///
/// ```
/// use gacer::profile::{DevicePool, Platform};
///
/// let mut pool = DevicePool::from_platforms([Platform::a100(), Platform::t4()]);
/// assert_eq!(pool.len(), 2);
/// assert_eq!(pool.platform(1).name, "T4");
///
/// // Scale out: the new device gets a fresh id.
/// let id = pool.add(Platform::t4());
/// assert_eq!(pool.index_of(id), Some(2));
///
/// // Scale in the middle device: ids of the survivors are stable even
/// // though their dense indices shift.
/// let t4 = pool.id(1);
/// pool.remove(0);
/// assert_eq!(pool.index_of(t4), Some(0));
/// assert_eq!(pool.index_of(id), Some(1));
/// ```
#[derive(Debug, Clone)]
pub struct DevicePool {
    devices: Vec<PoolDevice>,
    next_id: u64,
}

impl DevicePool {
    /// A pool of `n` identical devices — the sugar behind every
    /// `n_devices: usize` API (`n` is clamped to at least 1).
    pub fn uniform(platform: Platform, n: usize) -> Self {
        Self::from_platforms(std::iter::repeat(platform).take(n.max(1)))
    }

    /// A pool from an explicit per-device platform list, ids `0..n`.
    pub fn from_platforms(platforms: impl IntoIterator<Item = Platform>) -> Self {
        let devices: Vec<PoolDevice> = platforms
            .into_iter()
            .enumerate()
            .map(|(i, p)| PoolDevice { id: DeviceId(i as u64), cost: CostModel::new(p) })
            .collect();
        let next_id = devices.len() as u64;
        DevicePool { devices, next_id }
    }

    pub fn len(&self) -> usize {
        self.devices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    /// The platform profile of the device at dense index `d`.
    pub fn platform(&self, d: usize) -> &Platform {
        &self.devices[d].cost.platform
    }

    /// The cost model of the device at dense index `d` (cached per
    /// device, so repeated pricing against the same platform is cheap).
    pub fn cost(&self, d: usize) -> &CostModel {
        &self.devices[d].cost
    }

    /// The stable id of the device at dense index `d`.
    pub fn id(&self, d: usize) -> DeviceId {
        self.devices[d].id
    }

    /// Stable ids in dense order.
    pub fn ids(&self) -> Vec<DeviceId> {
        self.devices.iter().map(|d| d.id).collect()
    }

    /// Platform profiles in dense order.
    pub fn platforms(&self) -> Vec<Platform> {
        self.devices.iter().map(|d| d.cost.platform).collect()
    }

    /// The current dense index of a stable id, `None` once removed.
    pub fn index_of(&self, id: DeviceId) -> Option<usize> {
        self.devices.iter().position(|d| d.id == id)
    }

    /// Whether every device runs the same platform — when true, every
    /// heterogeneous code path reduces exactly to the homogeneous one.
    pub fn is_uniform(&self) -> bool {
        self.devices
            .windows(2)
            .all(|w| w[0].cost.platform == w[1].cost.platform)
    }

    /// Scale out: append a device, returning its fresh (never-reused) id.
    pub fn add(&mut self, platform: Platform) -> DeviceId {
        let id = DeviceId(self.next_id);
        self.next_id += 1;
        self.devices.push(PoolDevice { id, cost: CostModel::new(platform) });
        id
    }

    /// Scale in: remove the device at dense index `d` (later devices
    /// shift down; their ids do not change). Returns the removed id.
    pub fn remove(&mut self, d: usize) -> DeviceId {
        self.devices.remove(d).id
    }

    /// Short human label, e.g. `A100+T4x2`.
    pub fn label(&self) -> String {
        let mut parts: Vec<(String, usize)> = Vec::new();
        for d in &self.devices {
            match parts.last_mut() {
                Some((name, n)) if *name == d.cost.platform.name => *n += 1,
                _ => parts.push((d.cost.platform.name.to_string(), 1)),
            }
        }
        parts
            .into_iter()
            .map(|(name, n)| if n == 1 { name } else { format!("{name}x{n}") })
            .collect::<Vec<_>>()
            .join("+")
    }

    /// Parse a CLI device spec: a comma list of platform names with an
    /// optional `xN` repeat — `titanv,p6000x2` is a Titan V plus two
    /// P6000s. Returns a descriptive error for unknown names or counts.
    ///
    /// ```
    /// use gacer::profile::DevicePool;
    ///
    /// let platforms = DevicePool::parse_spec("a100,t4x2").unwrap();
    /// assert_eq!(platforms.len(), 3);
    /// assert_eq!(platforms[1].name, "T4");
    /// assert!(DevicePool::parse_spec("h100").is_err());
    /// ```
    pub fn parse_spec(spec: &str) -> Result<Vec<Platform>, String> {
        let mut out = Vec::new();
        for item in spec.split(',') {
            let item = item.trim();
            if item.is_empty() {
                return Err(format!("empty device entry in spec {spec:?}"));
            }
            let (name, count) = match item.rsplit_once(['x', 'X']) {
                Some((name, n)) if !name.is_empty() && n.chars().all(|c| c.is_ascii_digit()) && !n.is_empty() => {
                    (name, n.parse::<usize>().map_err(|e| e.to_string())?)
                }
                _ => (item, 1),
            };
            if count == 0 {
                return Err(format!("device count 0 in entry {item:?}"));
            }
            let platform = Platform::by_name(name).ok_or_else(|| {
                format!(
                    "unknown platform {name:?}; expected one of {}",
                    Platform::all().map(|p| p.name).join("|")
                )
            })?;
            out.extend(std::iter::repeat(platform).take(count));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_pool_is_uniform_and_clamped() {
        let pool = DevicePool::uniform(Platform::titan_v(), 0);
        assert_eq!(pool.len(), 1, "clamped to at least one device");
        let pool = DevicePool::uniform(Platform::titan_v(), 3);
        assert!(pool.is_uniform());
        assert_eq!(pool.ids(), vec![DeviceId(0), DeviceId(1), DeviceId(2)]);
    }

    #[test]
    fn mixed_pool_is_not_uniform() {
        let pool = DevicePool::from_platforms([Platform::a100(), Platform::t4()]);
        assert!(!pool.is_uniform());
        assert_eq!(pool.label(), "A100+T4");
    }

    #[test]
    fn ids_are_stable_and_never_reused_across_scale_events() {
        let mut pool = DevicePool::uniform(Platform::titan_v(), 2);
        let added = pool.add(Platform::t4());
        assert_eq!(added, DeviceId(2));
        let removed = pool.remove(1);
        assert_eq!(removed, DeviceId(1));
        // Survivors keep their ids at shifted dense indices.
        assert_eq!(pool.index_of(DeviceId(0)), Some(0));
        assert_eq!(pool.index_of(added), Some(1));
        assert_eq!(pool.index_of(removed), None);
        // The freed id is never handed out again.
        assert_eq!(pool.add(Platform::t4()), DeviceId(3));
    }

    #[test]
    fn per_device_cost_models_price_their_own_platform() {
        let pool = DevicePool::from_platforms([Platform::a100(), Platform::t4()]);
        assert_eq!(pool.cost(0).platform.name, "A100");
        assert_eq!(pool.cost(1).platform.name, "T4");
        assert!(pool.platform(0).sm_count > pool.platform(1).sm_count);
    }

    #[test]
    fn spec_parsing_expands_repeats_and_rejects_junk() {
        let p = DevicePool::parse_spec("titanv,p6000x2").unwrap();
        assert_eq!(
            p.iter().map(|p| p.name).collect::<Vec<_>>(),
            vec!["TitanV", "P6000", "P6000"]
        );
        assert!(DevicePool::parse_spec("").is_err());
        assert!(DevicePool::parse_spec("titanv,,t4").is_err());
        assert!(DevicePool::parse_spec("t4x0").is_err());
        assert!(DevicePool::parse_spec("warpdrive").is_err());
        // A bare count with no name is rejected, not parsed as repeat.
        assert!(DevicePool::parse_spec("x3").is_err());
    }

    #[test]
    fn labels_group_adjacent_runs() {
        let pool = DevicePool::from_platforms([
            Platform::t4(),
            Platform::t4(),
            Platform::a100(),
            Platform::t4(),
        ]);
        assert_eq!(pool.label(), "T4x2+A100+T4");
    }
}
