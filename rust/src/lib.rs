//! # GACER — Granularity-Aware ConcurrEncy Regulation for Multi-Tenant Deep Learning
//!
//! A production reproduction of the GACER paper (cs.DC 2023) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the multi-tenant deployment engine: DFG
//!   representation, operator cost model, a multi-stream GPU simulator
//!   substrate, the paper's spatial (operator resizing, §4.2) and temporal
//!   (sync-pointer segmentation, §4.3) regulation, the granularity-aware
//!   joint search (Algorithm 1), all evaluation baselines, the
//!   [`engine::GacerEngine`] that compiles searched plans into live server
//!   configurations, and a std-thread serving coordinator that executes
//!   those plans against real AOT-compiled XLA artifacts via PJRT.
//! * **Layer 2** — JAX operator library / models (`python/compile/`),
//!   lowered once to HLO text (`make artifacts`); never on the request path.
//! * **Layer 1** — Pallas kernels (tiled matmul, chunked micro-batch matmul,
//!   fused element-wise) inside the Layer-2 functions.
//!
//! The deployment flow is `GacerEngine::builder().platform(..)
//! .artifacts(..).devices(..).tenant(..).build()` → placement
//! ([`plan::Placement`]) → per-device search → [`plan::ShardedDeploymentPlan`]
//! → [`engine::ShardedDeployment`] → one [`coordinator::Server`] per device
//! behind a [`coordinator::ClusterServer`]. With the default single device
//! this collapses to the classic pipeline: search →
//! [`engine::Deployment`] → [`coordinator::Server`]. Deployments are
//! **live**: re-searched plans hot-swap into running servers
//! ([`engine::GacerEngine::redeploy_cluster`], epoch-fenced — no
//! restart), an [`engine::MigrationPolicy`] moves tenants between
//! devices when observed load drifts, and the [`slo`] subsystem turns
//! per-tenant latency into regulation pressure: priority [`slo::Tier`]s
//! issue first, deadline-expired or over-cap requests are shed with
//! typed errors, and an [`slo::SloMonitor`] tracks error-budget burn
//! rate so sustained burn triggers migration/re-search
//! ([`engine::GacerEngine::maybe_regulate`]). The request path itself is
//! measured, not assumed: requests complete through sharded, batch-notified
//! completion queues ([`coordinator::CompletionMode`]), clients can overlap
//! submissions via [`coordinator::Server::submit`] /
//! [`coordinator::Pending`], and [`bench_util::loadgen`] drives the whole
//! stack open-loop against the artifact-free
//! [`coordinator::SyntheticModel`] backend (`gacer-bench throughput`,
//! `docs/BENCHMARKS.md`). See `DESIGN.md` for the layer map
//! and the engine↔server lowering contract, `docs/OPERATIONS.md` for the
//! serving lifecycle (mirrored by `examples/live_redeploy.rs`), and
//! `docs/TUTORIAL.md` for an end-to-end walkthrough (mirrored by
//! `examples/sharded_serving.rs`). Errors at every public boundary are
//! the typed [`Error`] enum.

pub mod baselines;
pub mod bench_util;
pub mod calibrate;
pub mod coordinator;
pub mod dfg;
pub mod engine;
mod error;
pub mod gpu;
pub mod metrics;
pub mod models;
pub mod plan;
pub mod profile;
pub mod runtime;
pub mod search;
pub mod slo;
pub mod spatial;
pub mod temporal;
pub mod util;

pub use error::{Error, Result};

/// Convenience re-exports for the common "build combo → search → deploy"
/// flow used by examples, benches, and the CLI.
pub mod prelude {
    pub use crate::baselines::{Baseline, BaselineKind};
    pub use crate::calibrate::{CalibrationConfig, CalibrationEntry, Calibrator};
    pub use crate::coordinator::{
        ClusterServer, CompletionMode, Pending, ServerBackend, SyntheticModel,
    };
    pub use crate::dfg::{Dfg, OpId, OpKind, Operator};
    pub use crate::engine::{
        Deployment, EngineBuilder, GacerEngine, Migration, MigrationCost,
        MigrationPolicy, MigrationProposal, RegulationAction, ShardedDeployment,
        TenantId,
    };
    pub use crate::error::{Error, Result};
    pub use crate::gpu::{GpuSim, SimOutcome, SimOptions};
    pub use crate::models::zoo;
    pub use crate::plan::{
        DeploymentPlan, Placement, PlacementObjective, ShardedDeploymentPlan, TenantSet,
    };
    pub use crate::profile::{CostModel, DeviceId, DevicePool, Platform};
    pub use crate::search::{
        GacerSearch, SearchBudget, SearchConfig, SearchReport, SearchState,
        ShardedSearch, ShardedSearchReport,
    };
    pub use crate::slo::{
        BurnConfig, SloHealth, SloMonitor, SloPolicy, SloPressure, SloTarget, Tier,
    };
    pub use crate::spatial::SpatialRegulator;
    pub use crate::temporal::PointerMatrix;
}
