//! Temporal granularity regulation (§4.3): synchronization pointers.
//!
//! A pointer at position `p` in tenant `n`'s DFG forces a CPU-GPU
//! synchronization before operator `p` issues: all operators of the
//! current cross-tenant cluster must finish first (Eq. 6). The pointer
//! matrix `Matrix_P = [P_1 .. P_n]` (Eq. 7) holds one sorted position list
//! per tenant; the paper keeps `|P|` equal across tenants and so do we.


use crate::dfg::Dfg;
use crate::error::{Error, Result};

/// The pointer matrix `Matrix_P` (Eq. 7).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct PointerMatrix {
    /// One sorted pointer-position list per tenant. Position `p` means the
    /// pointer sits immediately before operator `p` (so op `p` starts
    /// segment `k+1`). Valid positions are `1..len` (a pointer at 0 or at
    /// `len` would create an empty segment).
    lists: Vec<Vec<usize>>,
}

impl PointerMatrix {
    /// No pointers: every tenant is a single segment (Stream-Parallel).
    pub fn empty(n_tenants: usize) -> Self {
        PointerMatrix { lists: vec![Vec::new(); n_tenants] }
    }

    pub fn from_lists(lists: Vec<Vec<usize>>) -> Self {
        let mut m = PointerMatrix { lists };
        for l in &mut m.lists {
            l.sort_unstable();
            l.dedup();
        }
        m
    }

    pub fn n_tenants(&self) -> usize {
        self.lists.len()
    }

    /// Pointer positions of tenant `i`.
    pub fn list(&self, i: usize) -> &[usize] {
        self.lists.get(i).map_or(&[], |l| l.as_slice())
    }

    /// Replace tenant `i`'s pointer list (kept sorted + deduped).
    pub fn set_list(&mut self, i: usize, mut list: Vec<usize>) {
        list.sort_unstable();
        list.dedup();
        self.lists[i] = list;
    }

    /// Append a pointer list for a newly admitted tenant (kept sorted +
    /// deduped).
    pub fn push_tenant(&mut self, mut list: Vec<usize>) {
        list.sort_unstable();
        list.dedup();
        self.lists.push(list);
    }

    /// Insert a pointer list at tenant position `i` (kept sorted +
    /// deduped) — a migrated tenant's global slot can fall anywhere in
    /// its destination device's local order, unlike an admission.
    pub fn insert_tenant(&mut self, i: usize, mut list: Vec<usize>) {
        list.sort_unstable();
        list.dedup();
        self.lists.insert(i, list);
    }

    /// Drop tenant `i`'s pointer list (eviction; later tenants shift down).
    pub fn remove_tenant(&mut self, i: usize) -> Vec<usize> {
        self.lists.remove(i)
    }

    /// Move tenant `i`'s `j`-th pointer to `pos` (kept sorted).
    pub fn set_pointer(&mut self, i: usize, j: usize, pos: usize) {
        self.lists[i][j] = pos;
        self.lists[i].sort_unstable();
    }

    /// `|P_n|` — pointers per tenant (the paper keeps them equal; we report
    /// the max for mixed states during search).
    pub fn pointers_per_tenant(&self) -> usize {
        self.lists.iter().map(Vec::len).max().unwrap_or(0)
    }

    /// Total pointer count across tenants.
    pub fn total_pointers(&self) -> usize {
        self.lists.iter().map(Vec::len).sum()
    }

    /// Number of segments each tenant is divided into.
    pub fn segments(&self, i: usize) -> usize {
        self.list(i).len() + 1
    }

    /// Split each tenant's DFG into `k` equal segments — the "segment-k"
    /// scheduling granularity of Fig. 9.
    pub fn equal_segments(tenants: &[Dfg], k: usize) -> Self {
        assert!(k >= 1);
        let lists = tenants
            .iter()
            .map(|d| {
                let n = d.len();
                (1..k)
                    .map(|j| (j * n).div_ceil(k).clamp(1, n.saturating_sub(1).max(1)))
                    .collect::<Vec<_>>()
            })
            .collect();
        Self::from_lists(lists)
    }

    /// Operator-wise granularity: a pointer before every op (Fig. 9's
    /// finest point).
    pub fn operator_wise(tenants: &[Dfg]) -> Self {
        let lists = tenants.iter().map(|d| (1..d.len()).collect()).collect();
        PointerMatrix { lists }
    }

    /// Check positions are within each tenant's DFG.
    pub fn validate(&self, tenants: &[Dfg]) -> Result<()> {
        if self.lists.len() != tenants.len() {
            return Err(Error::InvalidPlan(format!(
                "pointer matrix has {} lists for {} tenants",
                self.lists.len(),
                tenants.len()
            )));
        }
        for (i, (l, d)) in self.lists.iter().zip(tenants).enumerate() {
            for &p in l {
                if p == 0 || p >= d.len() {
                    return Err(Error::InvalidPlan(format!(
                        "tenant {i}: pointer at {p} outside 1..{}",
                        d.len()
                    )));
                }
            }
        }
        Ok(())
    }

    /// The segment structure as (start, end) op-index ranges per tenant —
    /// `Seg(M_n)` of Eq. 7.
    pub fn segments_of(&self, i: usize, n_ops: usize) -> Vec<(usize, usize)> {
        let mut out = Vec::with_capacity(self.segments(i));
        let mut start = 0usize;
        for &p in self.list(i) {
            out.push((start, p));
            start = p;
        }
        out.push((start, n_ops));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;

    #[test]
    fn paper_eq7_example() {
        // M1 with 12 ops + P1 = (2, 8) -> segments [0,2), [2,8), [8,12).
        let m = PointerMatrix::from_lists(vec![vec![2, 8]]);
        assert_eq!(m.segments_of(0, 12), vec![(0, 2), (2, 8), (8, 12)]);
        assert_eq!(m.segments(0), 3);
    }

    #[test]
    fn equal_segments_cover_all_ops() {
        let tenants = zoo::build_combo(&["Alex", "V16", "R18"]);
        for k in 1..=8 {
            let m = PointerMatrix::equal_segments(&tenants, k);
            for (i, d) in tenants.iter().enumerate() {
                let segs = m.segments_of(i, d.len());
                assert_eq!(segs.first().unwrap().0, 0);
                assert_eq!(segs.last().unwrap().1, d.len());
                for pair in segs.windows(2) {
                    assert_eq!(pair[0].1, pair[1].0, "contiguous");
                }
                m.validate(&tenants).unwrap();
            }
        }
    }

    #[test]
    fn operator_wise_one_op_per_segment() {
        let tenants = zoo::build_combo(&["Alex", "V16", "R18"]);
        let m = PointerMatrix::operator_wise(&tenants);
        assert_eq!(m.segments(0), tenants[0].len());
    }

    #[test]
    fn from_lists_sorts_and_dedups() {
        let m = PointerMatrix::from_lists(vec![vec![8, 2, 8, 5]]);
        assert_eq!(m.list(0), &[2, 5, 8]);
    }

    #[test]
    fn set_pointer_keeps_sorted() {
        let mut m = PointerMatrix::from_lists(vec![vec![2, 8]]);
        m.set_pointer(0, 0, 10);
        assert_eq!(m.list(0), &[8, 10]);
    }

    #[test]
    fn validate_rejects_out_of_range() {
        let tenants = zoo::build_combo(&["Alex", "V16", "R18"]);
        let m = PointerMatrix::from_lists(vec![vec![0], vec![], vec![]]);
        assert!(m.validate(&tenants).is_err());
        let m = PointerMatrix::from_lists(vec![vec![tenants[0].len()], vec![], vec![]]);
        assert!(m.validate(&tenants).is_err());
    }
}
