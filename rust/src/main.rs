//! `gacer` — the GACER leader binary: simulate combos, run the regulation
//! search (optionally sharded across devices), and serve multi-tenant
//! inference over real AOT artifacts on one GPU or a device pool.
//!
//! Subcommands:
//!   gacer simulate [--models R50,V16,M3] [--platform TitanV]
//!   gacer search   [--models R50,V16,M3] [--platform TitanV] [--max-pointers 6] [--devices 1]
//!                  [--placement balanced|interference|memory] [--replan-budget-ms N]
//!   gacer serve    [--artifacts artifacts] [--requests 64] [--tenants tiny_cnn,...] [--devices 1]
//!                  [--placement balanced|interference|memory] [--live-admit tiny_cnn]
//!                  [--replan-budget-ms N] [--migration-cost-aware] [--calibrate]
//!                  [--tier interactive,batch,...] [--slo MS]
//!   gacer loadtest [--rate 4000] [--duration-ms 1000] [--trace poisson|bursty|diurnal]
//!                  [--tenants 4] [--seed 7] [--queue-cap N] [--completion batched|per-request]
//!                  [--service-us F] [--submitters 4]
//!
//! `loadtest` drives the production request path (scheduler, batchers,
//! SLO shedding, completion fabric) with the open-loop load generator
//! against a synthetic backend — no artifacts or GPU needed, runs
//! anywhere (`docs/BENCHMARKS.md`).
//!
//! `--devices N` gives the deployment a device dimension: tenants are
//! placed across N devices (cost-model bin-packing), each device gets its
//! own granularity-aware search, and `serve` runs one coordinator per
//! device behind a routing front-end. `--placement interference` swaps
//! the placement objective from plain load balance to the
//! interference-aware one: co-location is priced with the cost model's
//! occupancy curves, so two SM-pool-saturating tenants land on different
//! devices even when their latency totals would balance.
//! `--placement memory` goes one dimension further: co-location is priced
//! on the full compute+memory roofline and admission enforces the device
//! HBM capacity (a tenant whose resident footprint fits nowhere is
//! refused with a typed error, see docs/OPERATIONS.md). `--live-admit FAMILY` then admits
//! one more tenant against the *running* cluster and hot-swaps the
//! re-searched plan in (no restart) — the live re-deployment path of
//! `docs/OPERATIONS.md`.

use gacer::baselines::BaselineKind;
use gacer::bench_util::{fig7_header, fig7_row, run_combo};
use gacer::coordinator::ServeOptions;
use gacer::gpu::SimOptions;
use gacer::models::zoo;
use gacer::plan::{PlacementObjective, TenantSet};
use gacer::profile::{CostModel, Platform};
use gacer::search::{GacerSearch, SearchBudget, SearchConfig, ShardedSearch};
use gacer::util::cli::Args;

const USAGE: &str = "usage: gacer <simulate|search|serve|loadtest> [options]
  simulate --models R50,V16,M3 --platform TitanV
  search   --models R50,V16,M3 --platform TitanV --max-pointers 6 --devices 1
           [--placement balanced|interference|memory] [--replan-budget-ms N]
  serve    --artifacts artifacts --requests 64 --tenants tiny_cnn,tiny_cnn,tiny_cnn --devices 1
           [--placement balanced|interference|memory] [--live-admit tiny_cnn]
           [--replan-budget-ms N] [--migration-cost-aware] [--calibrate]
           [--tier interactive,batch,...] [--slo MS]
  loadtest --rate 4000 --duration-ms 1000 [--trace poisson|bursty|diurnal]
           [--tenants 4] [--seed 7] [--queue-cap N]
           [--completion batched|per-request] [--service-us F] [--submitters 4]
           open-loop load against the production request path on a
           synthetic backend (no artifacts/GPU); reports achieved
           throughput, latency quantiles, and shed rate

  --devices N   shard the deployment across N devices: tenants are placed
                by cost-model bin-packing, each device is searched
                independently, and serving runs one coordinator per device
                behind a placement-routing front-end (default 1). Under
                `serve`, also accepts a heterogeneous pool spec — a comma
                list of platform names with optional xN repeats, e.g.
                `--devices a100,t4x2` — and each device is then costed and
                searched against its own platform
  --placement balanced|interference|memory
                placement objective for the device dimension: 'balanced'
                equalizes summed serial latency (LPT); 'interference'
                minimizes the max per-device load x predicted co-location
                slowdown from the cost model's occupancy curves, keeping
                pool-saturating tenants apart; 'memory' prices the full
                compute+memory roofline and enforces device HBM capacity
                (bandwidth hogs are separated, oversized tenants refused)
                (default balanced)
  --live-admit FAMILY
                after serving the initial tenants, admit one more FAMILY
                tenant against the running cluster and hot-swap the
                re-searched plan in without a restart (live re-deployment)
  --replan-budget-ms N
                wall-clock budget for re-search: under `search`, bound the
                search itself; under `serve`, bound each incremental
                re-search (e.g. the live admit). The anytime search returns
                its best-so-far plan and reports truncation (0 = unbounded,
                the default; see docs/SEARCH.md for tuning)
  --migration-cost-aware
                under `serve`: after serving, consult a cost/gain-aware
                migration policy priced from the engine's observed re-plan
                telemetry (a move must pay for its re-plan + swap pause)
                and hot-swap the decision in
  --calibrate   under `serve`: attach the online cost-model calibrator —
                the engine compares predicted against served latencies
                each observe window, keeps bounded per-(tenant, platform)
                residual EWMAs, and blends the trusted corrections into
                placement, admission, migration, and regulation decisions
                (trust ramps from zero, so a cold engine behaves exactly
                like the analytic one; see docs/OPERATIONS.md)
  --tier interactive,standard,batch
                under `serve`: per-tenant SLO tier, comma list parallel to
                --tenants (missing entries default to standard). Higher
                tiers issue first each scheduling round; see docs/SLO.md
  --slo MS
                under `serve`: p99 latency target in milliseconds for
                interactive-tier tenants. Interactive tenants get the
                target plus a 4xMS per-request deadline (late requests are
                shed with a typed error), batch tenants get a bounded
                queue, and the engine reports per-tenant error-budget
                burn after serving";

fn parse_models(s: &str) -> Vec<String> {
    s.split(',').map(|m| m.trim().to_string()).collect()
}

fn platform_or_exit(name: &str) -> Platform {
    Platform::by_name(name).unwrap_or_else(|| {
        eprintln!("unknown platform {name}; expected TitanV|P6000|1080Ti|A100|T4");
        std::process::exit(2);
    })
}

/// `--devices` accepts either a plain count (`--devices 2`: that many
/// copies of `--platform`) or a heterogeneous pool spec
/// (`--devices a100,t4x2`: per-device platforms, see
/// [`gacer::profile::DevicePool::parse_spec`]). Returns
/// `(count, explicit platforms)` — the platform list is empty for a
/// plain count.
fn devices_or_exit(args: &Args) -> (usize, Vec<Platform>) {
    let spec = args.opt_or("devices", "1");
    if let Ok(n) = spec.parse::<usize>() {
        return (n.max(1), Vec::new());
    }
    match gacer::profile::DevicePool::parse_spec(spec) {
        Ok(platforms) => (platforms.len(), platforms),
        Err(e) => {
            eprintln!("--devices expects a count or a pool spec like a100,t4x2: {e}");
            std::process::exit(2);
        }
    }
}

fn placement_or_exit(name: &str) -> PlacementObjective {
    PlacementObjective::parse(name).unwrap_or_else(|| {
        eprintln!(
            "unknown placement objective {name}; expected balanced|interference|memory"
        );
        std::process::exit(2);
    })
}

/// `--tier interactive,standard,batch` — a comma list parallel to
/// `--tenants` (unknown names abort; absent = no tiers).
fn parse_tiers(s: Option<&str>) -> Vec<gacer::slo::Tier> {
    let Some(s) = s else { return Vec::new() };
    s.split(',')
        .map(|t| {
            gacer::slo::Tier::parse(t.trim()).unwrap_or_else(|| {
                eprintln!("unknown tier {t:?}; expected interactive|standard|batch");
                std::process::exit(2);
            })
        })
        .collect()
}

/// `--slo MS` — p99 target in milliseconds (absent = no SLO target).
fn parse_slo_ms(s: Option<&str>) -> Option<f64> {
    let s = s?;
    match s.parse::<f64>() {
        Ok(ms) if ms.is_finite() && ms > 0.0 => Some(ms),
        _ => {
            eprintln!("--slo expects a positive latency in milliseconds, got {s:?}");
            std::process::exit(2);
        }
    }
}

/// `--replan-budget-ms N` (0 or absent = unbounded).
fn replan_budget(args: &Args) -> SearchBudget {
    match args.opt_usize("replan-budget-ms", 0) {
        0 => SearchBudget::unbounded(),
        ms => SearchBudget::deadline_ms(ms as u64),
    }
}

fn main() -> gacer::Result<()> {
    let args = Args::from_env();
    let Some(cmd) = args.positional.first().map(String::as_str) else {
        eprintln!("{USAGE}");
        std::process::exit(2);
    };
    match cmd {
        "simulate" => {
            let platform = platform_or_exit(args.opt_or("platform", "TitanV"));
            let names = parse_models(args.opt_or("models", "R50,V16,M3"));
            let refs: Vec<&str> = names.iter().map(String::as_str).collect();
            let cells = run_combo(&refs, &platform, SearchConfig::default());
            println!("{}", fig7_header(&cells));
            println!("{}", fig7_row(&zoo::combo_label(&refs), &cells));
        }
        "search" => {
            let platform = platform_or_exit(args.opt_or("platform", "TitanV"));
            let names = parse_models(args.opt_or("models", "R50,V16,M3"));
            let refs: Vec<&str> = names.iter().map(String::as_str).collect();
            let cost = CostModel::new(platform);
            let tenants = zoo::build_combo(&refs);
            let ts = TenantSet::new(tenants.clone(), cost.clone());
            let cfg = SearchConfig {
                max_pointers: args.opt_usize("max-pointers", 6),
                ..Default::default()
            };
            let devices = args.opt_usize("devices", 1).max(1);
            let objective = placement_or_exit(args.opt_or("placement", "balanced"));
            let budget = replan_budget(&args);
            if devices > 1 {
                let report = ShardedSearch::new(&ts, SimOptions::for_platform(&platform), cfg)
                    .objective(objective)
                    .budget(budget)
                    .run(devices);
                println!(
                    "combo {} on {} x{} ({}): cluster makespan {:.2}ms \
                     (bottleneck device {}), {} evaluations in {:?}{}",
                    zoo::combo_label(&refs),
                    platform.name,
                    devices,
                    objective.label(),
                    report.cluster_makespan_us() / 1e3,
                    report.bottleneck_device().unwrap_or(0),
                    report.total_evaluations(),
                    report.elapsed,
                    if report.truncated() {
                        format!(" (budget {} truncated convergence)", budget.label())
                    } else {
                        String::new()
                    }
                );
                let slowdowns = report.plan.placement.predicted_slowdowns(&ts);
                for d in 0..devices {
                    let slots = report.plan.placement.tenants_on(d);
                    let names: Vec<&str> =
                        slots.iter().map(|&s| tenants[s].name.as_str()).collect();
                    match &report.reports[d] {
                        Some(r) => println!(
                            "  device {d}: {names:?}  {:.2}ms -> {:.2}ms ({:.2}x), \
                             predicted co-location slowdown {:.2}x",
                            r.initial.makespan_us / 1e3,
                            r.outcome.makespan_us / 1e3,
                            r.speedup_vs_initial(),
                            slowdowns[d]
                        ),
                        None => println!("  device {d}: idle"),
                    }
                }
                return Ok(());
            }
            let report = GacerSearch::new(&ts, SimOptions::for_platform(&platform), cfg)
                .budget(budget)
                .run();
            println!(
                "combo {} on {}: {:.2}ms -> {:.2}ms ({:.2}x), {} evaluations in {:?}{}",
                zoo::combo_label(&refs),
                platform.name,
                report.initial.makespan_us / 1e3,
                report.outcome.makespan_us / 1e3,
                report.speedup_vs_initial(),
                report.evaluations,
                report.elapsed,
                if report.truncated {
                    format!(" (budget {} truncated convergence)", budget.label())
                } else {
                    String::new()
                }
            );
            for (i, d) in tenants.iter().enumerate() {
                println!(
                    "  {}: pointers {:?}, {} decomposed ops",
                    d.name,
                    report.plan.pointers.list(i),
                    report.plan.chunking[i].len()
                );
            }
            // Context for the reader: where the baselines sit.
            let base =
                gacer::baselines::Baseline::new(&ts, SimOptions::for_platform(&platform));
            for kind in BaselineKind::all() {
                let o = base.run(kind);
                println!("  baseline {:<16} {:.2} ms", kind.label(), o.makespan_us / 1e3);
            }
        }
        "serve" => {
            let artifacts = args.opt_or("artifacts", "artifacts").to_string();
            let tenants = parse_models(args.opt_or("tenants", "tiny_cnn,tiny_cnn,tiny_cnn"));
            let (n_devices, device_pool) = devices_or_exit(&args);
            let opts = ServeOptions {
                n_requests: args.opt_usize("requests", 64),
                n_devices,
                device_pool,
                objective: placement_or_exit(args.opt_or("placement", "balanced")),
                live_admit: args.opt("live-admit").map(String::from),
                replan_budget: replan_budget(&args),
                cost_aware_migration: args.flag("migration-cost-aware"),
                tiers: parse_tiers(args.opt("tier")),
                slo_p99_ms: parse_slo_ms(args.opt("slo")),
                calibrate: args.flag("calibrate"),
            };
            gacer::coordinator::serve_demo(&artifacts, &tenants, &opts)?;
        }
        "loadtest" => {
            use gacer::bench_util::loadgen::{run_loadgen, LoadgenOptions, TraceShape};
            use gacer::coordinator::CompletionMode;

            let opt_f64 = |key: &str, default: f64| {
                args.opt(key).and_then(|v| v.parse::<f64>().ok()).unwrap_or(default)
            };
            let rate = opt_f64("rate", 4000.0);
            let trace = args.opt_or("trace", "poisson");
            let shape = TraceShape::parse(trace, rate).unwrap_or_else(|| {
                eprintln!("unknown trace shape {trace:?}; expected poisson|bursty|diurnal");
                std::process::exit(2);
            });
            let mode_name = args.opt_or("completion", "batched");
            let mode = CompletionMode::parse(mode_name).unwrap_or_else(|| {
                eprintln!("unknown completion mode {mode_name:?}; expected batched|per-request");
                std::process::exit(2);
            });
            let opts = LoadgenOptions {
                n_tenants: args.opt_usize("tenants", 4).max(1),
                duration_ms: opt_f64("duration-ms", 1000.0),
                shape,
                seed: args.opt_usize("seed", 7) as u64,
                queue_cap: args.opt_usize("queue-cap", 0),
                mode,
                submitters: args.opt_usize("submitters", 4).max(1),
                service_us_per_batch: opt_f64("service-us", 0.0),
                ..LoadgenOptions::default()
            };
            let r = run_loadgen(&opts)?;
            println!(
                "{} trace, {} completions: offered {:.0} req/s over {:.0}ms, {} tenants",
                shape.label(),
                mode.label(),
                r.offered_rps,
                opts.duration_ms,
                opts.n_tenants
            );
            println!(
                "  submitted {}  completed {}  shed {} ({:.2}%)  errors {}",
                r.submitted,
                r.completed,
                r.shed,
                r.shed_rate() * 100.0,
                r.errors
            );
            println!(
                "  achieved {:.0} req/s  p50 {:.0}us  p95 {:.0}us  p99 {:.0}us  max {:.0}us",
                r.achieved_rps(),
                r.latency.p50_us,
                r.latency.p95_us,
                r.latency.p99_us,
                r.latency.max_us
            );
        }
        other => {
            eprintln!("unknown command: {other}\n{USAGE}");
            std::process::exit(2);
        }
    }
    Ok(())
}
