//! Baseline deployment strategies of the paper's §5.1 evaluation.
//!
//! * **CuDNN-Seq** — PyTorch + cuDNN default: models run one after another,
//!   each operator alone on the device.
//! * **TVM-Seq** — per-operator kernel tuning (compute-bound kernels get a
//!   tuned-kernel speedup) but still strictly sequential execution.
//! * **Stream-Parallel** — native multi-stream: one stream per tenant,
//!   greedy issue, no regulation.
//! * **MPS** — static FLOPS-proportional SM partition per tenant (§5.1:
//!   "we distribute the resources to each model based on the models'
//!   FLOPS"); within its partition each tenant runs sequentially, all
//!   tenants in parallel.
//!
//! All baselines are priced by the same cost model + simulator that the
//! GACER plans use, so comparisons are apples-to-apples.

use crate::dfg::Dfg;
use crate::gpu::{GpuSim, SimOp, SimOptions, SimOutcome};
use crate::plan::TenantSet;

/// TVM kernel-tuning speedup for compute-bound ops (measured TVM-vs-cuDNN
/// gains are typically 10-25% on convs; we use a conservative midpoint).
const TVM_COMPUTE_SPEEDUP: f64 = 0.85;
/// TVM speedup for bandwidth-bound ops (little to gain at the DRAM wall).
const TVM_MEM_SPEEDUP: f64 = 0.97;

/// Which baseline to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BaselineKind {
    CudnnSeq,
    TvmSeq,
    StreamParallel,
    Mps,
}

impl BaselineKind {
    pub fn label(&self) -> &'static str {
        match self {
            BaselineKind::CudnnSeq => "CuDNN-Seq",
            BaselineKind::TvmSeq => "TVM-Seq",
            BaselineKind::StreamParallel => "Stream-Parallel",
            BaselineKind::Mps => "MPS",
        }
    }

    pub fn all() -> [BaselineKind; 4] {
        [
            BaselineKind::CudnnSeq,
            BaselineKind::TvmSeq,
            BaselineKind::StreamParallel,
            BaselineKind::Mps,
        ]
    }
}

/// Baseline runner over a tenant set.
pub struct Baseline<'a> {
    ts: &'a TenantSet,
    opts: SimOptions,
}

impl<'a> Baseline<'a> {
    pub fn new(ts: &'a TenantSet, opts: SimOptions) -> Self {
        Baseline { ts, opts }
    }

    pub fn run(&self, kind: BaselineKind) -> SimOutcome {
        match kind {
            BaselineKind::CudnnSeq => self.sequential(1.0, 1.0),
            BaselineKind::TvmSeq => self.sequential(TVM_COMPUTE_SPEEDUP, TVM_MEM_SPEEDUP),
            BaselineKind::StreamParallel => self.stream_parallel(),
            BaselineKind::Mps => self.mps(),
        }
    }

    /// Sequential execution: one logical stream concatenating all tenants
    /// (each op solo — matching a single-process PyTorch loop).
    fn sequential(&self, compute_scale: f64, mem_scale: f64) -> SimOutcome {
        let streams = self.ts.compile_unregulated();
        let mut seq: Vec<SimOp> = Vec::new();
        for s in streams {
            for mut op in s {
                let scale = if op.mem_util > 50.0 { mem_scale } else { compute_scale };
                op.duration_us *= scale;
                op.segment = 0;
                seq.push(op);
            }
        }
        let mut opts = self.opts;
        opts.sync_wait_us = 0.0;
        GpuSim::new(opts).run(&[seq])
    }

    /// Native multi-stream concurrency (the unregulated plan).
    fn stream_parallel(&self) -> SimOutcome {
        let streams = self.ts.compile_unregulated();
        GpuSim::new(self.opts).run(&streams)
    }

    /// MPS: static FLOPS-proportional partition. Each tenant's ops are
    /// clamped to the tenant's share; an op demanding more occupancy than
    /// its partition stretches proportionally (it simply cannot spread
    /// wider). Tenants never contend (disjoint partitions), which we model
    /// by giving each op its clamped occupancy — all partitions sum to the
    /// pool, so concurrent admission always fits.
    fn mps(&self) -> SimOutcome {
        let flops: Vec<f64> = self.ts.tenants.iter().map(Dfg::total_flops).collect();
        let total: f64 = flops.iter().sum();
        let streams = self.ts.compile_unregulated();
        let shared: Vec<Vec<SimOp>> = streams
            .into_iter()
            .zip(&flops)
            .map(|(s, &f)| {
                let share = (100.0 * f / total).max(1.0);
                s.into_iter()
                    .map(|mut op| {
                        if op.occupancy > share {
                            let stretch = op.occupancy / share;
                            op.duration_us *= stretch;
                            op.occupancy = share;
                        }
                        op
                    })
                    .collect()
            })
            .collect();
        let mut opts = self.opts;
        opts.sync_wait_us = 0.0;
        GpuSim::new(opts).run(&shared)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;
    use crate::profile::{CostModel, Platform};

    fn outcome(names: &[&str], kind: BaselineKind) -> SimOutcome {
        let platform = Platform::titan_v();
        let cost = CostModel::new(platform);
        let tenants = zoo::build_combo(names);
        let ts = TenantSet::new(tenants.clone(), cost.clone());
        Baseline::new(&ts, SimOptions::for_platform(&platform)).run(kind)
    }

    #[test]
    fn stream_parallel_beats_sequential() {
        for combo in zoo::PAPER_COMBOS {
            let seq = outcome(&combo, BaselineKind::CudnnSeq);
            let par = outcome(&combo, BaselineKind::StreamParallel);
            assert!(
                par.makespan_us < seq.makespan_us,
                "{}: par {} vs seq {}",
                zoo::combo_label(&combo),
                par.makespan_us,
                seq.makespan_us
            );
        }
    }

    #[test]
    fn tvm_beats_cudnn_but_stays_sequential() {
        let seq = outcome(&["Alex", "V16", "R18"], BaselineKind::CudnnSeq);
        let tvm = outcome(&["Alex", "V16", "R18"], BaselineKind::TvmSeq);
        assert!(tvm.makespan_us < seq.makespan_us);
        // Still far from the parallel bound: the TVM-Seq gap of Fig. 7.
        assert!(tvm.makespan_us > seq.makespan_us * 0.8);
    }

    #[test]
    fn sequential_latency_is_sum_of_ops() {
        let platform = Platform::titan_v();
        let cost = CostModel::new(platform);
        let tenants = zoo::build_combo(&["Alex", "V16", "R18"]);
        let expected: f64 = tenants.iter().map(|d| cost.sequential_latency_us(d)).sum();
        let ts = TenantSet::new(tenants.clone(), cost.clone());
        let out = Baseline::new(&ts, SimOptions::for_platform(&platform))
            .run(BaselineKind::CudnnSeq);
        assert!((out.makespan_us - expected).abs() / expected < 1e-9);
    }

    #[test]
    fn mps_unstable_across_combos() {
        // The paper: "MPS acceleration is very unstable" — for at least one
        // combo it should underperform Stream-Parallel, as static shares
        // starve skewed tenants.
        let mut worse_somewhere = false;
        for combo in zoo::PAPER_COMBOS {
            let mps = outcome(&combo, BaselineKind::Mps);
            let sp = outcome(&combo, BaselineKind::StreamParallel);
            if mps.makespan_us > sp.makespan_us * 1.02 {
                worse_somewhere = true;
            }
        }
        assert!(worse_somewhere, "MPS should lose to Stream-Parallel somewhere");
    }

    #[test]
    fn mps_beats_sequential_on_balanced_combo() {
        let seq = outcome(&["Alex", "V16", "R18"], BaselineKind::CudnnSeq);
        let mps = outcome(&["Alex", "V16", "R18"], BaselineKind::Mps);
        assert!(mps.makespan_us < seq.makespan_us);
    }

    #[test]
    fn labels_stable() {
        assert_eq!(BaselineKind::CudnnSeq.label(), "CuDNN-Seq");
        assert_eq!(BaselineKind::all().len(), 4);
    }
}
