//! Granularity-aware joint optimization (§4.4, Algorithm 1).
//!
//! Coordinate-descent search over the pointer matrix `Matrix_P`,
//! alternated with spatial decomposition steps:
//!
//! 1. start with `|P| = 0` (Stream-Parallel);
//! 2. at each pointer level, run `X` rounds of coordinate descent — for
//!    each tenant `i`, for each pointer `j` of `P_i`, scan candidate
//!    positions, evaluate the overhead-aware residue (Eq. 8) through the
//!    simulator, and keep the argmin while all other coordinates hold;
//! 3. after the temporal rounds, run spatial regulation steps (§4.2) and
//!    update the DFG — decomposed operators land between the existing
//!    pointers without disturbing `Matrix_P`;
//! 4. add one pointer per tenant and repeat; stop when the best residue at
//!    `|P|` is no better than at `|P| - 1` (Algorithm 1 line 9) and return
//!    the `|P| - 1` optimum.
//!
//! The evaluation is modeling-based (simulator, memoized cost lookups) —
//! no per-candidate hardware profiling — which is what keeps the search in
//! the seconds-to-minutes band the paper reports in Table 4.
//!
//! Multi-GPU deployments add an outer stage: [`ShardedSearch`] places the
//! tenant set across devices ([`crate::plan::Placement`]) and runs one
//! independent Algorithm-1 search per device — see the [`sharded`] module.
//!
//! ```
//! use gacer::models::zoo;
//! use gacer::plan::TenantSet;
//! use gacer::profile::{CostModel, Platform};
//! use gacer::gpu::SimOptions;
//! use gacer::search::{GacerSearch, SearchConfig};
//!
//! let platform = Platform::titan_v();
//! let set = TenantSet::new(
//!     zoo::build_combo(&["Alex", "M3"]),
//!     CostModel::new(platform),
//! );
//! let cfg = SearchConfig {
//!     max_pointers: 1,
//!     rounds_per_level: 1,
//!     positions_per_coordinate: 4,
//!     spatial_steps_per_level: 1,
//!     ..Default::default()
//! };
//! let report = GacerSearch::new(&set, SimOptions::for_platform(&platform), cfg).run();
//! report.plan.validate(&set.tenants).unwrap();
//! // Algorithm 1 never returns a plan worse than Stream-Parallel.
//! assert!(report.outcome.objective() <= report.initial.objective() + 1e-6);
//! ```

pub mod sharded;

pub use sharded::{ShardedSearch, ShardedSearchReport};

use std::time::Instant;

use crate::gpu::{SimOptions, SimOutcome};
use crate::plan::{DeploymentPlan, TenantSet};
use crate::spatial::SpatialRegulator;
use crate::temporal::PointerMatrix;

/// Search hyper-parameters.
#[derive(Debug, Clone, Copy)]
pub struct SearchConfig {
    /// Maximum pointers per tenant (`|P|` cap).
    pub max_pointers: usize,
    /// Coordinate-descent rounds per pointer level (Algorithm 1's `X`).
    pub rounds_per_level: usize,
    /// Candidate positions scanned per coordinate update.
    pub positions_per_coordinate: usize,
    /// Spatial decomposition steps attempted after each level's descent.
    pub spatial_steps_per_level: usize,
    /// Enable the spatial knob (disable for the `Temporal`-only ablation).
    pub enable_spatial: bool,
    /// Enable the temporal knob (disable for the `Spatial`-only ablation).
    pub enable_temporal: bool,
}

impl Default for SearchConfig {
    fn default() -> Self {
        SearchConfig {
            max_pointers: 6,
            rounds_per_level: 3,
            positions_per_coordinate: 12,
            spatial_steps_per_level: 4,
            enable_spatial: true,
            enable_temporal: true,
        }
    }
}

impl SearchConfig {
    /// The paper's `Spatial` ablation arm.
    pub fn spatial_only() -> Self {
        SearchConfig { enable_temporal: false, ..Default::default() }
    }

    /// The paper's `Temporal` ablation arm.
    pub fn temporal_only() -> Self {
        SearchConfig { enable_spatial: false, ..Default::default() }
    }
}

/// Search result: the chosen plan plus bookkeeping for Tables 4 / Fig. 9.
#[derive(Debug, Clone)]
pub struct SearchReport {
    pub plan: DeploymentPlan,
    pub outcome: SimOutcome,
    pub initial: SimOutcome,
    /// Simulator evaluations performed (the search's unit cost).
    pub evaluations: usize,
    /// Best objective found at each pointer level (index = |P|).
    pub level_best: Vec<f64>,
    /// Wall-clock search time.
    pub elapsed: std::time::Duration,
}

impl SearchReport {
    pub fn speedup_vs_initial(&self) -> f64 {
        self.initial.makespan_us / self.outcome.makespan_us
    }
}

/// The GACER searcher.
pub struct GacerSearch<'a> {
    ts: &'a TenantSet,
    opts: SimOptions,
    cfg: SearchConfig,
}

impl<'a> GacerSearch<'a> {
    pub fn new(ts: &'a TenantSet, opts: SimOptions, cfg: SearchConfig) -> Self {
        GacerSearch { ts, opts, cfg }
    }

    /// Run Algorithm 1 to completion from the unregulated plan.
    pub fn run(&self) -> SearchReport {
        self.run_from(DeploymentPlan::unregulated(self.ts.tenants.len()))
    }

    /// Run Algorithm 1 starting from an existing plan — the incremental
    /// re-search the engine triggers on tenant admission/eviction. The
    /// seed's pointers are refined by coordinate descent before any new
    /// pointer level is added, so a near-optimal prior plan converges in a
    /// fraction of a cold search's evaluations. `report.initial` always
    /// refers to the unregulated deployment, keeping speedup reporting
    /// comparable between cold and seeded runs.
    pub fn run_from(&self, seed: DeploymentPlan) -> SearchReport {
        let start = Instant::now();
        let n = self.ts.tenants.len();
        let mut evals = 0usize;

        let mut plan = seed;
        let initial = self.ts.simulate(&DeploymentPlan::unregulated(n), self.opts);
        evals += 1;
        let seeded = plan.decomposed_ops() > 0 || plan.pointers.total_pointers() > 0;
        let mut best_obj = if seeded {
            evals += 1;
            self.ts.simulate(&plan, self.opts).objective()
        } else {
            initial.objective()
        };

        let mut spatial = SpatialRegulator::new(self.opts);
        let mut best_plan = plan.clone();
        let mut level_best = vec![best_obj];

        // The starting level may already benefit from spatial-only
        // regulation.
        if self.cfg.enable_spatial {
            let (p, o, e) = self.spatial_phase(&mut spatial, plan.clone());
            evals += e;
            if o < best_obj {
                best_obj = o;
                best_plan = p.clone();
                level_best[0] = o;
            }
            plan = p;
        }

        if self.cfg.enable_temporal {
            // Compiled-stream cache for pointer-only evaluations: pricing
            // depends on chunking alone, so it is rebuilt only after
            // spatial phases mutate the plan.
            let mut cache = self.ts.compile(&plan);

            // Seeded path: refine the pre-existing pointers in place
            // before opening new levels.
            if plan.pointers.total_pointers() > 0 {
                let mut refined = f64::INFINITY;
                for _ in 0..self.cfg.rounds_per_level {
                    let mut improved = false;
                    for i in 0..n {
                        for j in 0..plan.pointers.list(i).len() {
                            let (obj, e) =
                                self.descend_coordinate(&mut plan, &mut cache, i, j);
                            evals += e;
                            if obj < refined - 1e-9 {
                                refined = obj;
                                improved = true;
                            }
                        }
                    }
                    if !improved {
                        break;
                    }
                }
                if refined < best_obj {
                    best_obj = refined;
                    best_plan = plan.clone();
                }
            }

            let first_level = plan.pointers.pointers_per_tenant() + 1;
            for _level in first_level..=self.cfg.max_pointers {
                // Add one pointer per tenant, seeded mid-largest-segment.
                for i in 0..n {
                    let seed = self.seed_position(&plan.pointers, i);
                    let mut list = plan.pointers.list(i).to_vec();
                    list.push(seed);
                    plan.pointers.set_list(i, list);
                }

                // Coordinate descent rounds.
                let mut level_obj = f64::INFINITY;
                for _ in 0..self.cfg.rounds_per_level {
                    let mut improved = false;
                    for i in 0..n {
                        for j in 0..plan.pointers.list(i).len() {
                            let (obj, e) =
                                self.descend_coordinate(&mut plan, &mut cache, i, j);
                            evals += e;
                            if obj < level_obj - 1e-9 {
                                level_obj = obj;
                                improved = true;
                            }
                        }
                    }
                    if !improved {
                        break;
                    }
                }

                // Spatial alternation: decomposed ops slot between pointers.
                if self.cfg.enable_spatial {
                    spatial.reset_memory();
                    let (p, o, e) = self.spatial_phase(&mut spatial, plan.clone());
                    evals += e;
                    let chunking_changed = p.chunking != plan.chunking;
                    plan = p;
                    level_obj = level_obj.min(o);
                    if chunking_changed {
                        cache = self.ts.compile(&plan);
                    }
                }

                level_best.push(level_obj);
                if level_obj < best_obj - 1e-9 {
                    best_obj = level_obj;
                    best_plan = plan.clone();
                } else {
                    // Algorithm 1 line 9: this level is no better — return
                    // the previous level's optimum.
                    break;
                }
            }
        }

        // The unregulated deployment is always available as a fallback: a
        // re-search seeded with a stale plan (e.g. tuned for a tenant set
        // that has since shrunk) must never return something worse than no
        // regulation at all — coordinate descent can move inherited
        // pointers but never remove them.
        if best_obj > initial.objective() + 1e-9 {
            best_plan = DeploymentPlan::unregulated(n);
        }

        let outcome = self.ts.simulate(&best_plan, self.opts);
        SearchReport {
            plan: best_plan,
            outcome,
            initial,
            evaluations: evals,
            level_best,
            elapsed: start.elapsed(),
        }
    }

    /// Greedy spatial phase: apply improving decompositions until none.
    fn spatial_phase(
        &self,
        reg: &mut SpatialRegulator,
        mut plan: DeploymentPlan,
    ) -> (DeploymentPlan, f64, usize) {
        let mut evals = 0usize;
        let mut obj = {
            evals += 1;
            self.ts.simulate(&plan, self.opts).objective()
        };
        for _ in 0..self.cfg.spatial_steps_per_level {
            match reg.step(self.ts, &plan) {
                Some(step) => {
                    evals += reg.candidates_per_step + 1;
                    obj = step.outcome.objective();
                    plan = step.plan;
                }
                None => break,
            }
        }
        (plan, obj, evals)
    }

    /// Optimize pointer (i, j) by scanning a position grid while all other
    /// coordinates hold (the inner loop of Algorithm 1).
    ///
    /// Hot path: pointer moves do not change operator pricing, only
    /// segment assignment — so candidates are evaluated by restamping the
    /// cached compiled streams in place instead of recompiling the plan
    /// (`cargo bench --bench hotpath` times exactly this loop).
    fn descend_coordinate(
        &self,
        plan: &mut DeploymentPlan,
        cache: &mut Vec<Vec<crate::gpu::SimStage>>,
        i: usize,
        j: usize,
    ) -> (f64, usize) {
        let len = self.ts.tenants[i].len();
        let mut evals = 0usize;
        let mut best_pos = plan.pointers.list(i)[j];
        let mut best_obj = {
            evals += 1;
            self.eval_pointers(cache, &plan.pointers)
        };
        let step = (len / self.cfg.positions_per_coordinate).max(1);
        let mut pointers = plan.pointers.clone();
        let mut pos = 1;
        while pos < len {
            if pos != best_pos {
                pointers.set_pointer(i, j, pos);
                evals += 1;
                let obj = self.eval_pointers(cache, &pointers);
                if obj < best_obj - 1e-9 {
                    best_obj = obj;
                    best_pos = pos;
                }
                // Restore for the next candidate (set_pointer re-sorts).
                pointers = plan.pointers.clone();
            }
            pos += step;
        }
        plan.pointers.set_pointer(i, j, best_pos);
        self.restamp(cache, &plan.pointers);
        (best_obj, evals)
    }

    /// Restamp cached streams' segments from `pointers` and simulate.
    fn eval_pointers(
        &self,
        cache: &mut Vec<Vec<crate::gpu::SimStage>>,
        pointers: &PointerMatrix,
    ) -> f64 {
        self.restamp(cache, pointers);
        crate::gpu::GpuSim::new(self.opts).run_staged(cache).objective()
    }

    fn restamp(&self, cache: &mut [Vec<crate::gpu::SimStage>], pointers: &PointerMatrix) {
        for (ti, stream) in cache.iter_mut().enumerate() {
            let plist = pointers.list(ti);
            for stage in stream.iter_mut() {
                let src = stage.pieces[0].source_op;
                let seg = plist.iter().filter(|&&p| p <= src).count();
                for piece in &mut stage.pieces {
                    piece.segment = seg;
                }
            }
        }
    }

    /// Seed a new pointer in the middle of tenant `i`'s largest segment.
    fn seed_position(&self, pointers: &PointerMatrix, i: usize) -> usize {
        let len = self.ts.tenants[i].len();
        let segs = pointers.segments_of(i, len);
        let (s, e) = segs
            .iter()
            .copied()
            .max_by_key(|(s, e)| e - s)
            .unwrap_or((0, len));
        ((s + e) / 2).clamp(1, len.saturating_sub(1).max(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;
    use crate::profile::{CostModel, Platform};

    fn quick_cfg() -> SearchConfig {
        SearchConfig {
            max_pointers: 2,
            rounds_per_level: 1,
            positions_per_coordinate: 6,
            spatial_steps_per_level: 2,
            ..Default::default()
        }
    }

    fn run_combo(names: &[&str], cfg: SearchConfig) -> SearchReport {
        let platform = Platform::titan_v();
        let cost = CostModel::new(platform);
        let tenants = zoo::build_combo(names);
        let ts = TenantSet::new(tenants.clone(), cost.clone());
        GacerSearch::new(&ts, SimOptions::for_platform(&platform), cfg).run()
    }

    #[test]
    fn search_never_worse_than_stream_parallel() {
        let r = run_combo(&["Alex", "V16", "R18"], quick_cfg());
        assert!(r.outcome.objective() <= r.initial.objective() + 1e-6);
        assert!(r.outcome.makespan_us <= r.initial.makespan_us * 1.001);
    }

    #[test]
    fn search_improves_heavy_combo() {
        let r = run_combo(&["R50", "V16", "M3"], quick_cfg());
        assert!(
            r.speedup_vs_initial() > 1.0,
            "expected improvement, got {}",
            r.speedup_vs_initial()
        );
    }

    #[test]
    fn returned_plan_validates() {
        let platform = Platform::titan_v();
        let cost = CostModel::new(platform);
        let tenants = zoo::build_combo(&["R34", "LSTM", "BST"]);
        let ts = TenantSet::new(tenants.clone(), cost.clone());
        let r = GacerSearch::new(&ts, SimOptions::for_platform(&platform), quick_cfg()).run();
        r.plan.validate(&tenants).unwrap();
    }

    #[test]
    fn ablations_are_subsets() {
        // Joint search must be at least as good as either ablation arm
        // (same budget) on the big combo.
        let joint = run_combo(&["R101", "D121", "M3"], quick_cfg());
        let spatial = run_combo(&["R101", "D121", "M3"], SearchConfig {
            enable_temporal: false,
            ..quick_cfg()
        });
        let temporal = run_combo(&["R101", "D121", "M3"], SearchConfig {
            enable_spatial: false,
            ..quick_cfg()
        });
        assert!(joint.outcome.makespan_us <= spatial.outcome.makespan_us * 1.02);
        assert!(joint.outcome.makespan_us <= temporal.outcome.makespan_us * 1.02);
    }

    #[test]
    fn evaluation_count_reported() {
        let r = run_combo(&["Alex", "V16", "R18"], quick_cfg());
        assert!(r.evaluations > 1);
        assert!(!r.level_best.is_empty());
    }
}
