//! Granularity-aware joint optimization (§4.4, Algorithm 1).
//!
//! Coordinate-descent search over the pointer matrix `Matrix_P`,
//! alternated with spatial decomposition steps:
//!
//! 1. start with `|P| = 0` (Stream-Parallel);
//! 2. at each pointer level, run `X` rounds of coordinate descent — for
//!    each tenant `i`, for each pointer `j` of `P_i`, scan candidate
//!    positions, evaluate the overhead-aware residue (Eq. 8) through the
//!    simulator, and keep the argmin while all other coordinates hold;
//! 3. after the temporal rounds, run spatial regulation steps (§4.2) and
//!    update the DFG — decomposed operators land between the existing
//!    pointers without disturbing `Matrix_P`;
//! 4. add one pointer per tenant and repeat; stop when the best residue at
//!    `|P|` is no better than at `|P| - 1` (Algorithm 1 line 9) and return
//!    the `|P| - 1` optimum.
//!
//! The evaluation is modeling-based (simulator, memoized cost lookups) —
//! no per-candidate hardware profiling — which is what keeps the search in
//! the seconds-to-minutes band the paper reports in Table 4.
//!
//! Online serving adds two requirements the offline algorithm does not
//! have, both implemented here (the internals guide is `docs/SEARCH.md`):
//!
//! * **Anytime budgets** ([`SearchBudget`]): a wall-clock deadline and/or
//!   an evaluation cap threaded through [`GacerSearch::run`]/
//!   [`GacerSearch::run_from`]. The search checkpoints its best-so-far
//!   plan between atomic steps, so truncation returns a plan never worse
//!   than the seed; [`SearchReport::truncated`] records whether the
//!   budget cut convergence short.
//! * **Warm starts** ([`SearchState`]): a persistent cache of compiled
//!   tenant streams (keyed by per-tenant fingerprints), the last
//!   converged plan/objective, and the descent cursor. Re-searches seeded
//!   from it recompile only the tenants whose chunking actually changed,
//!   and a re-search whose seed equals the cached converged plan
//!   short-circuits to the cached result at zero evaluations.
//!
//! Multi-GPU deployments add an outer stage: [`ShardedSearch`] places the
//! tenant set across devices ([`crate::plan::Placement`]) and runs one
//! independent Algorithm-1 search per device — see the [`sharded`] module.
//!
//! ```
//! use gacer::models::zoo;
//! use gacer::plan::TenantSet;
//! use gacer::profile::{CostModel, Platform};
//! use gacer::gpu::SimOptions;
//! use gacer::search::{GacerSearch, SearchConfig};
//!
//! let platform = Platform::titan_v();
//! let set = TenantSet::new(
//!     zoo::build_combo(&["Alex", "M3"]),
//!     CostModel::new(platform),
//! );
//! let cfg = SearchConfig {
//!     max_pointers: 1,
//!     rounds_per_level: 1,
//!     positions_per_coordinate: 4,
//!     spatial_steps_per_level: 1,
//!     ..Default::default()
//! };
//! let report = GacerSearch::new(&set, SimOptions::for_platform(&platform), cfg).run();
//! report.plan.validate(&set.tenants).unwrap();
//! // Algorithm 1 never returns a plan worse than Stream-Parallel.
//! assert!(report.outcome.objective() <= report.initial.objective() + 1e-6);
//! ```

pub mod sharded;

pub use sharded::{ShardedSearch, ShardedSearchReport};

use std::collections::hash_map::DefaultHasher;
use std::hash::{Hash, Hasher};
use std::time::{Duration, Instant};

use crate::dfg::Dfg;
use crate::error::{Error, Result};
use crate::gpu::{SimOptions, SimOutcome, SimStage};
use crate::plan::{ChunkMap, DeploymentPlan, TenantSet};
use crate::spatial::SpatialRegulator;
use crate::temporal::PointerMatrix;

/// Search hyper-parameters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SearchConfig {
    /// Maximum pointers per tenant (`|P|` cap).
    pub max_pointers: usize,
    /// Coordinate-descent rounds per pointer level (Algorithm 1's `X`).
    pub rounds_per_level: usize,
    /// Candidate positions scanned per coordinate update.
    pub positions_per_coordinate: usize,
    /// Spatial decomposition steps attempted after each level's descent.
    pub spatial_steps_per_level: usize,
    /// Enable the spatial knob (disable for the `Temporal`-only ablation).
    pub enable_spatial: bool,
    /// Enable the temporal knob (disable for the `Spatial`-only ablation).
    pub enable_temporal: bool,
}

impl Default for SearchConfig {
    fn default() -> Self {
        SearchConfig {
            max_pointers: 6,
            rounds_per_level: 3,
            positions_per_coordinate: 12,
            spatial_steps_per_level: 4,
            enable_spatial: true,
            enable_temporal: true,
        }
    }
}

impl SearchConfig {
    /// The paper's `Spatial` ablation arm.
    pub fn spatial_only() -> Self {
        SearchConfig { enable_temporal: false, ..Default::default() }
    }

    /// The paper's `Temporal` ablation arm.
    pub fn temporal_only() -> Self {
        SearchConfig { enable_spatial: false, ..Default::default() }
    }
}

/// Resource budget for one Algorithm-1 run — what turns the search into
/// an **anytime** algorithm. The coordinate-descent loop checkpoints its
/// best-so-far plan between atomic steps (one coordinate scan, one
/// spatial decomposition step) and consults the budget before starting
/// the next one, so a truncated run still returns a valid plan that is
/// never worse than its seed. Because checks sit *between* steps, the
/// reported evaluation count can overshoot `max_evaluations` by at most
/// one step's worth of evaluations.
///
/// The default is [`SearchBudget::unbounded`]: run Algorithm 1 to its own
/// convergence criterion, exactly the pre-budget behavior.
///
/// ```
/// use gacer::search::SearchBudget;
/// use std::time::Duration;
///
/// let b = SearchBudget::evaluations(100);
/// assert!(!b.exhausted(99, Duration::ZERO));
/// assert!(b.exhausted(100, Duration::ZERO));
///
/// let d = SearchBudget::deadline_ms(5);
/// assert!(d.exhausted(0, Duration::from_millis(5)));
///
/// assert!(SearchBudget::unbounded().is_unbounded());
/// assert_eq!(SearchBudget::default(), SearchBudget::unbounded());
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct SearchBudget {
    /// Cap on simulator evaluations (the search's unit cost; `None` =
    /// unlimited). Evaluation-count budgets are deterministic: the same
    /// seed and budget always return the same plan, and a larger cap
    /// never returns a worse one (monotone-anytime, property-tested).
    pub max_evaluations: Option<usize>,
    /// Wall-clock deadline for the run (`None` = unlimited). Deadlines
    /// bound re-plan latency on the serving path (`--replan-budget-ms`),
    /// at the price of machine-dependent truncation points.
    pub max_elapsed: Option<Duration>,
}

impl SearchBudget {
    /// No limits: Algorithm 1 runs to its own convergence criterion.
    pub fn unbounded() -> Self {
        SearchBudget::default()
    }

    /// Cap the number of simulator evaluations.
    pub fn evaluations(n: usize) -> Self {
        SearchBudget { max_evaluations: Some(n), max_elapsed: None }
    }

    /// Cap the wall-clock time of the run.
    pub fn deadline(d: Duration) -> Self {
        SearchBudget { max_evaluations: None, max_elapsed: Some(d) }
    }

    /// Convenience spelling of [`SearchBudget::deadline`] in milliseconds
    /// (the CLI's `--replan-budget-ms`).
    pub fn deadline_ms(ms: u64) -> Self {
        Self::deadline(Duration::from_millis(ms))
    }

    /// Whether neither limit is set.
    pub fn is_unbounded(&self) -> bool {
        self.max_evaluations.is_none() && self.max_elapsed.is_none()
    }

    /// Whether a run that has spent `evaluations` / `elapsed` must stop.
    pub fn exhausted(&self, evaluations: usize, elapsed: Duration) -> bool {
        self.max_evaluations.is_some_and(|m| evaluations >= m)
            || self.max_elapsed.is_some_and(|d| elapsed >= d)
    }

    /// Human-readable form for reports and bench tables.
    pub fn label(&self) -> String {
        match (self.max_evaluations, self.max_elapsed) {
            (None, None) => "unbounded".to_string(),
            (Some(n), None) => format!("<={n} evals"),
            (None, Some(d)) => format!("<={:.1}ms", d.as_secs_f64() * 1e3),
            (Some(n), Some(d)) => {
                format!("<={n} evals, <={:.1}ms", d.as_secs_f64() * 1e3)
            }
        }
    }
}

/// Budget accounting for one run: charges evaluations and latches the
/// truncation flag the first time the budget is consulted after being
/// exceeded. Natural convergence never consults it again, so a search
/// that finishes on its own terms is not flagged.
struct Meter {
    start: Instant,
    budget: SearchBudget,
    evals: usize,
    truncated: bool,
}

impl Meter {
    fn new(budget: SearchBudget) -> Self {
        Meter { start: Instant::now(), budget, evals: 0, truncated: false }
    }

    fn charge(&mut self, n: usize) {
        self.evals += n;
    }

    /// Consult the budget before the next atomic step; latches
    /// `truncated` once exhausted.
    fn exhausted(&mut self) -> bool {
        if !self.truncated && self.budget.exhausted(self.evals, self.start.elapsed()) {
            self.truncated = true;
        }
        self.truncated
    }
}

/// Fingerprint of one tenant as the compiled-stream cache sees it: the
/// DFG (name, ops, batches) plus the plan's chunk map for it. Pointer
/// positions are deliberately excluded — segment stamps are refreshed by
/// `restamp` on every evaluation, so a cached stream survives arbitrary
/// pointer movement and is invalidated only when *chunking* changes.
fn tenant_fingerprint(dfg: &Dfg, chunks: &ChunkMap) -> u64 {
    let mut h = DefaultHasher::new();
    dfg.name.hash(&mut h);
    dfg.len().hash(&mut h);
    for op in &dfg.ops {
        op.id.hash(&mut h);
        op.batch.hash(&mut h);
        op.kind.hash(&mut h);
    }
    chunks.hash(&mut h);
    h.finish()
}

/// Fingerprint of the whole tenant set (what the unregulated baseline
/// and the converged-plan cache depend on).
fn set_fingerprint(ts: &TenantSet) -> u64 {
    let mut h = DefaultHasher::new();
    for dfg in &ts.tenants {
        tenant_fingerprint(dfg, &ChunkMap::new()).hash(&mut h);
    }
    h.finish()
}

/// The last completed search recorded in a [`SearchState`]: a re-search
/// whose seed equals `plan` (same tenant set, same config, previous run
/// not truncated) short-circuits to this result without evaluating
/// anything.
#[derive(Debug, Clone)]
struct Converged {
    set_fingerprint: u64,
    cfg: SearchConfig,
    plan: DeploymentPlan,
    outcome: SimOutcome,
    initial: SimOutcome,
    truncated: bool,
}

/// Persistent warm-start state for incremental re-search — the cache a
/// [`GacerSearch`] reads and refreshes across admit/evict/migrate events
/// (`docs/SEARCH.md` documents the invalidation rules).
///
/// Contents:
///
/// * **compiled tenant streams** of the last returned plan, keyed by a
///   per-tenant fingerprint of (DFG, chunk map) — a warm re-search
///   recompiles only the tenants whose chunking actually changed;
/// * **the last converged plan + outcome** — a re-search seeded with
///   exactly that plan on an unchanged tenant set returns it bit-for-bit
///   at zero evaluations;
/// * **the unregulated baseline outcome** — reused whenever the tenant
///   set is unchanged (it does not depend on the plan);
/// * **the descent cursor** — a budget-truncated re-search resumes its
///   coordinate-descent rotation at the tenant it was refining, instead
///   of re-descending tenant 0 on every event.
///
/// A state belongs to one logical device of one deployment: the engine
/// owns one per device and never shares them across platforms or
/// simulator options (fingerprints cover tenants and plans, not the cost
/// model).
///
/// ```
/// use gacer::models::zoo;
/// use gacer::plan::TenantSet;
/// use gacer::profile::{CostModel, Platform};
/// use gacer::gpu::SimOptions;
/// use gacer::search::{GacerSearch, SearchConfig, SearchState};
///
/// let platform = Platform::titan_v();
/// let set = TenantSet::new(
///     zoo::build_combo(&["Alex", "M3"]),
///     CostModel::new(platform),
/// );
/// let cfg = SearchConfig {
///     max_pointers: 1,
///     rounds_per_level: 1,
///     positions_per_coordinate: 4,
///     spatial_steps_per_level: 1,
///     ..Default::default()
/// };
/// let search = GacerSearch::new(&set, SimOptions::for_platform(&platform), cfg);
/// let mut state = SearchState::new();
/// let cold = search.run_with_state(&mut state);
/// assert_eq!(state.cached_tenants(), 2);
/// // Nothing changed: the warm re-search short-circuits, bit-for-bit.
/// let warm = search.run_from_state(cold.plan.clone(), &mut state).unwrap();
/// assert_eq!(warm.plan, cold.plan);
/// assert_eq!(warm.evaluations, 0);
/// ```
#[derive(Debug, Clone, Default)]
pub struct SearchState {
    /// Per-tenant compiled streams of the last returned plan, keyed by
    /// the (DFG, chunk map) fingerprint.
    streams: Vec<(u64, Vec<SimStage>)>,
    converged: Option<Converged>,
    /// Tenant index the next warm refine pass starts at.
    cursor: usize,
}

impl SearchState {
    /// An empty (cold) state.
    pub fn new() -> Self {
        SearchState::default()
    }

    /// Whether the state holds nothing reusable yet.
    pub fn is_empty(&self) -> bool {
        self.streams.is_empty() && self.converged.is_none()
    }

    /// Number of tenant streams currently cached.
    pub fn cached_tenants(&self) -> usize {
        self.streams.len()
    }

    /// Drop everything (e.g. the deployment this state described is
    /// gone). Equivalent to replacing the state with a fresh one.
    pub fn invalidate(&mut self) {
        *self = SearchState::default();
    }

    fn stream_for(&self, fingerprint: u64) -> Option<&Vec<SimStage>> {
        self.streams.iter().find(|(f, _)| *f == fingerprint).map(|(_, s)| s)
    }
}

/// Search result: the chosen plan plus bookkeeping for Tables 4 / Fig. 9
/// and the anytime/warm-start telemetry the serving path consumes.
///
/// The truncation fields make budgeted runs auditable:
///
/// ```
/// use gacer::models::zoo;
/// use gacer::plan::TenantSet;
/// use gacer::profile::{CostModel, Platform};
/// use gacer::gpu::SimOptions;
/// use gacer::search::{GacerSearch, SearchBudget, SearchConfig};
///
/// let platform = Platform::titan_v();
/// let set = TenantSet::new(
///     zoo::build_combo(&["Alex", "M3"]),
///     CostModel::new(platform),
/// );
/// let cfg = SearchConfig {
///     max_pointers: 1,
///     rounds_per_level: 1,
///     positions_per_coordinate: 4,
///     spatial_steps_per_level: 1,
///     ..Default::default()
/// };
/// let report = GacerSearch::new(&set, SimOptions::for_platform(&platform), cfg)
///     .budget(SearchBudget::evaluations(3))
///     .run();
/// // The budget cut convergence short — flagged, and the checkpointed
/// // plan is still never worse than the unregulated start.
/// assert!(report.truncated);
/// assert_eq!(report.budget, SearchBudget::evaluations(3));
/// assert!(report.outcome.objective() <= report.initial.objective() + 1e-6);
/// ```
#[derive(Debug, Clone)]
pub struct SearchReport {
    pub plan: DeploymentPlan,
    pub outcome: SimOutcome,
    pub initial: SimOutcome,
    /// Simulator evaluations performed (the search's unit cost). May
    /// overshoot an evaluation budget by at most one atomic step — see
    /// [`SearchBudget`].
    pub evaluations: usize,
    /// Best objective found at each pointer level (index = |P|).
    pub level_best: Vec<f64>,
    /// Wall-clock search time.
    pub elapsed: std::time::Duration,
    /// The budget this run was under ([`SearchBudget::unbounded`] when
    /// none was set).
    pub budget: SearchBudget,
    /// `true` when the budget stopped the run before Algorithm 1's own
    /// convergence criterion (line 9's level comparison). The returned
    /// plan is the best-so-far checkpoint: never worse than the seed,
    /// never worse than the unregulated fallback. `false` means the
    /// search converged — re-running with a larger budget changes
    /// nothing.
    pub truncated: bool,
    /// Tenant streams reused from a warm [`SearchState`] instead of
    /// being recompiled (0 on cold runs; `n_tenants` on a short-circuited
    /// no-change re-search).
    pub warm_hits: usize,
}

impl SearchReport {
    pub fn speedup_vs_initial(&self) -> f64 {
        self.initial.makespan_us / self.outcome.makespan_us
    }
}

/// The GACER searcher.
pub struct GacerSearch<'a> {
    ts: &'a TenantSet,
    opts: SimOptions,
    cfg: SearchConfig,
    budget: SearchBudget,
}

impl<'a> GacerSearch<'a> {
    pub fn new(ts: &'a TenantSet, opts: SimOptions, cfg: SearchConfig) -> Self {
        GacerSearch { ts, opts, cfg, budget: SearchBudget::unbounded() }
    }

    /// Budget the run under ([`SearchBudget::unbounded`] by default): the
    /// search becomes anytime — it checkpoints the best-so-far plan and
    /// returns it when the budget runs out, flagging
    /// [`SearchReport::truncated`].
    pub fn budget(mut self, budget: SearchBudget) -> Self {
        self.budget = budget;
        self
    }

    /// Run Algorithm 1 from the unregulated plan (to completion, or to
    /// the configured [`SearchBudget`]).
    pub fn run(&self) -> SearchReport {
        self.run_from(DeploymentPlan::unregulated(self.ts.tenants.len()))
            .expect("the unregulated seed always matches the tenant set")
    }

    /// [`GacerSearch::run`], reading and refreshing a warm
    /// [`SearchState`] so a later incremental re-search starts from this
    /// run's compiled streams and converged plan.
    pub fn run_with_state(&self, state: &mut SearchState) -> SearchReport {
        self.run_from_state(DeploymentPlan::unregulated(self.ts.tenants.len()), state)
            .expect("the unregulated seed always matches the tenant set")
    }

    /// Run Algorithm 1 starting from an existing plan — the incremental
    /// re-search the engine triggers on tenant admission/eviction. The
    /// seed's pointers are refined by coordinate descent before any new
    /// pointer level is added, so a near-optimal prior plan converges in a
    /// fraction of a cold search's evaluations. `report.initial` always
    /// refers to the unregulated deployment, keeping speedup reporting
    /// comparable between cold and seeded runs.
    ///
    /// The seed is validated against the tenant set first: a stale seed
    /// (wrong tenant arity, out-of-range pointers, chunk lists that no
    /// longer sum to their op's batch) is a typed
    /// [`Error::InvalidPlan`](crate::Error::InvalidPlan), not an
    /// out-of-bounds panic.
    pub fn run_from(&self, seed: DeploymentPlan) -> Result<SearchReport> {
        self.run_from_state(seed, &mut SearchState::default())
    }

    /// [`GacerSearch::run_from`] with a warm [`SearchState`]: compiled
    /// tenant streams are reused for every tenant whose chunking is
    /// unchanged since the state's last run, the unregulated baseline is
    /// reused when the tenant set is unchanged, and a seed equal to the
    /// state's converged plan short-circuits to the cached result at
    /// zero evaluations. The state is refreshed with this run's result
    /// before returning.
    pub fn run_from_state(
        &self,
        seed: DeploymentPlan,
        state: &mut SearchState,
    ) -> Result<SearchReport> {
        let start = Instant::now();
        let n = self.ts.tenants.len();
        seed.validate(&self.ts.tenants).map_err(|e| {
            Error::InvalidPlan(format!("re-search seed rejected: {e}"))
        })?;
        let set_fp = set_fingerprint(self.ts);

        // Warm short-circuit: the seed IS the plan the last completed
        // search on this state returned, and nothing else changed — the
        // cached result is the answer, bit-for-bit.
        if let Some(c) = &state.converged {
            if !c.truncated
                && c.set_fingerprint == set_fp
                && c.cfg == self.cfg
                && c.plan == seed
            {
                return Ok(SearchReport {
                    plan: c.plan.clone(),
                    outcome: c.outcome.clone(),
                    initial: c.initial.clone(),
                    evaluations: 0,
                    level_best: vec![c.outcome.objective()],
                    elapsed: start.elapsed(),
                    budget: self.budget,
                    truncated: false,
                    warm_hits: n,
                });
            }
        }

        let mut meter = Meter::new(self.budget);
        let mut warm_hits = 0usize;
        let mut plan = seed;

        // Baseline outcomes. The unregulated baseline depends only on the
        // tenant set, so an unchanged set reuses the cached one; a seed
        // equal to a cached (possibly truncated) result reuses its
        // objective — that is how a budget-truncated search *resumes*.
        let initial = match &state.converged {
            Some(c) if c.set_fingerprint == set_fp => c.initial.clone(),
            _ => {
                meter.charge(1);
                self.ts.simulate(&DeploymentPlan::unregulated(n), self.opts)
            }
        };
        let seeded = plan.decomposed_ops() > 0 || plan.pointers.total_pointers() > 0;
        let mut best_obj = match &state.converged {
            Some(c)
                if c.set_fingerprint == set_fp && c.cfg == self.cfg && c.plan == plan =>
            {
                c.outcome.objective()
            }
            _ if seeded => {
                meter.charge(1);
                self.ts.simulate(&plan, self.opts).objective()
            }
            _ => initial.objective(),
        };

        let mut spatial = SpatialRegulator::new(self.opts);
        let mut best_plan = plan.clone();
        let mut level_best = vec![best_obj];

        // The starting level may already benefit from spatial-only
        // regulation.
        if self.cfg.enable_spatial && !meter.exhausted() {
            let (p, o) = self.spatial_phase(&mut spatial, plan.clone(), &mut meter);
            if o < best_obj {
                best_obj = o;
                best_plan = p.clone();
                level_best[0] = o;
            }
            plan = p;
        }

        if self.cfg.enable_temporal && !meter.exhausted() {
            // Compiled-stream cache for pointer-only evaluations: pricing
            // depends on chunking alone, so it is rebuilt only after
            // spatial phases mutate the plan — and warm entries cover
            // every tenant whose chunking matches the state's last run.
            let (mut cache, hits) = self.compile_warm(&plan, state);
            warm_hits += hits;

            // Seeded path: refine the pre-existing pointers in place
            // before opening new levels, resuming the tenant rotation at
            // the state's cursor (where a truncated run left off).
            if plan.pointers.total_pointers() > 0 {
                let start_at = if state.cursor < n { state.cursor } else { 0 };
                let mut refined = f64::INFINITY;
                'refine: for _ in 0..self.cfg.rounds_per_level {
                    let mut improved = false;
                    for k in 0..n {
                        let i = (start_at + k) % n;
                        for j in 0..plan.pointers.list(i).len() {
                            if meter.exhausted() {
                                state.cursor = i;
                                break 'refine;
                            }
                            let (obj, e) =
                                self.descend_coordinate(&mut plan, &mut cache, i, j);
                            meter.charge(e);
                            if obj < refined - 1e-9 {
                                refined = obj;
                                improved = true;
                            }
                        }
                    }
                    if !improved {
                        break;
                    }
                }
                if refined < best_obj {
                    best_obj = refined;
                    best_plan = plan.clone();
                }
            }

            let first_level = plan.pointers.pointers_per_tenant() + 1;
            for _level in first_level..=self.cfg.max_pointers {
                if meter.exhausted() {
                    break;
                }
                // Add one pointer per tenant, seeded mid-largest-segment.
                for i in 0..n {
                    let pos = self.seed_position(&plan.pointers, i);
                    let mut list = plan.pointers.list(i).to_vec();
                    list.push(pos);
                    plan.pointers.set_list(i, list);
                }

                // Coordinate descent rounds.
                let mut level_obj = f64::INFINITY;
                'rounds: for _ in 0..self.cfg.rounds_per_level {
                    let mut improved = false;
                    for i in 0..n {
                        for j in 0..plan.pointers.list(i).len() {
                            if meter.exhausted() {
                                // Resume the next warm re-search's refine
                                // rotation at the tenant being descended,
                                // exactly as the 'refine break does.
                                state.cursor = i;
                                break 'rounds;
                            }
                            let (obj, e) =
                                self.descend_coordinate(&mut plan, &mut cache, i, j);
                            meter.charge(e);
                            if obj < level_obj - 1e-9 {
                                level_obj = obj;
                                improved = true;
                            }
                        }
                    }
                    if !improved {
                        break;
                    }
                }

                // Spatial alternation: decomposed ops slot between pointers.
                if self.cfg.enable_spatial && !meter.exhausted() {
                    spatial.reset_memory();
                    let (p, o) =
                        self.spatial_phase(&mut spatial, plan.clone(), &mut meter);
                    let chunking_changed = p.chunking != plan.chunking;
                    plan = p;
                    level_obj = level_obj.min(o);
                    if chunking_changed {
                        let (c, hits) = self.compile_warm(&plan, state);
                        cache = c;
                        warm_hits += hits;
                    }
                }

                if !level_obj.is_finite() {
                    // The budget cut this level before any candidate was
                    // evaluated: the partially opened level never beat
                    // the checkpoint, which is what gets returned.
                    break;
                }
                level_best.push(level_obj);
                if level_obj < best_obj - 1e-9 {
                    best_obj = level_obj;
                    best_plan = plan.clone();
                } else {
                    // Algorithm 1 line 9: this level is no better — return
                    // the previous level's optimum.
                    break;
                }
            }
        }

        // The unregulated deployment is always available as a fallback: a
        // re-search seeded with a stale plan (e.g. tuned for a tenant set
        // that has since shrunk) must never return something worse than no
        // regulation at all — coordinate descent can move inherited
        // pointers but never remove them.
        if best_obj > initial.objective() + 1e-9 {
            best_plan = DeploymentPlan::unregulated(n);
        }

        // Final outcome, compiled once — the same streams then refresh
        // the warm state for the next event (uncharged, like the final
        // simulation always was).
        let streams = self.ts.compile(&best_plan);
        let mut outcome = crate::gpu::GpuSim::new(self.opts).run_staged(&streams);
        outcome.hbm_pressure_us = self.ts.hbm_pressure_us(&best_plan);
        state.streams = streams
            .into_iter()
            .enumerate()
            .map(|(ti, s)| {
                let empty = ChunkMap::new();
                let chunks = best_plan.chunking.get(ti).unwrap_or(&empty);
                (tenant_fingerprint(&self.ts.tenants[ti], chunks), s)
            })
            .collect();
        state.converged = Some(Converged {
            set_fingerprint: set_fp,
            cfg: self.cfg,
            plan: best_plan.clone(),
            outcome: outcome.clone(),
            initial: initial.clone(),
            truncated: meter.truncated,
        });
        if !meter.truncated {
            state.cursor = 0;
        }

        Ok(SearchReport {
            plan: best_plan,
            outcome,
            initial,
            evaluations: meter.evals,
            level_best,
            elapsed: start.elapsed(),
            budget: self.budget,
            truncated: meter.truncated,
            warm_hits,
        })
    }

    /// Compile `plan` into per-tenant simulator streams, reusing every
    /// tenant whose (DFG, chunk map) fingerprint is cached in `state` —
    /// the warm-start path recompiles only the tenants whose chunking
    /// actually changed. Returns the streams and the cache-hit count.
    fn compile_warm(
        &self,
        plan: &DeploymentPlan,
        state: &SearchState,
    ) -> (Vec<Vec<SimStage>>, usize) {
        let mut hits = 0usize;
        let empty = ChunkMap::new();
        let streams = self
            .ts
            .tenants
            .iter()
            .enumerate()
            .map(|(ti, dfg)| {
                let chunks = plan.chunking.get(ti).unwrap_or(&empty);
                match state.stream_for(tenant_fingerprint(dfg, chunks)) {
                    Some(s) => {
                        hits += 1;
                        s.clone()
                    }
                    None => self.ts.compile_tenant(ti, plan),
                }
            })
            .collect();
        (streams, hits)
    }

    /// Greedy spatial phase: apply improving decompositions until none is
    /// left or the budget runs out (each decomposition step is one atomic
    /// budget unit).
    fn spatial_phase(
        &self,
        reg: &mut SpatialRegulator,
        mut plan: DeploymentPlan,
        meter: &mut Meter,
    ) -> (DeploymentPlan, f64) {
        meter.charge(1);
        let mut obj = self.ts.simulate(&plan, self.opts).objective();
        for _ in 0..self.cfg.spatial_steps_per_level {
            if meter.exhausted() {
                break;
            }
            match reg.step(self.ts, &plan) {
                Some(step) => {
                    meter.charge(reg.candidates_per_step + 1);
                    obj = step.outcome.objective();
                    plan = step.plan;
                }
                None => break,
            }
        }
        (plan, obj)
    }

    /// Optimize pointer (i, j) by scanning a position grid while all other
    /// coordinates hold (the inner loop of Algorithm 1).
    ///
    /// Hot path: pointer moves do not change operator pricing, only
    /// segment assignment — so candidates are evaluated by restamping the
    /// cached compiled streams in place instead of recompiling the plan
    /// (`cargo bench --bench hotpath` times exactly this loop).
    fn descend_coordinate(
        &self,
        plan: &mut DeploymentPlan,
        cache: &mut Vec<Vec<crate::gpu::SimStage>>,
        i: usize,
        j: usize,
    ) -> (f64, usize) {
        let len = self.ts.tenants[i].len();
        let mut evals = 0usize;
        let mut best_pos = plan.pointers.list(i)[j];
        // Pointer moves never change chunking, so the plan's HBM-pressure
        // term is a per-descent constant — added so pointer objectives stay
        // comparable with the simulate-based objectives of other phases.
        let pressure = self.ts.hbm_pressure_us(plan);
        let mut best_obj = {
            evals += 1;
            self.eval_pointers(cache, &plan.pointers, pressure)
        };
        let step = (len / self.cfg.positions_per_coordinate).max(1);
        let mut pointers = plan.pointers.clone();
        let mut pos = 1;
        while pos < len {
            if pos != best_pos {
                pointers.set_pointer(i, j, pos);
                evals += 1;
                let obj = self.eval_pointers(cache, &pointers, pressure);
                if obj < best_obj - 1e-9 {
                    best_obj = obj;
                    best_pos = pos;
                }
                // Restore for the next candidate (set_pointer re-sorts).
                pointers = plan.pointers.clone();
            }
            pos += step;
        }
        plan.pointers.set_pointer(i, j, best_pos);
        self.restamp(cache, &plan.pointers);
        (best_obj, evals)
    }

    /// Restamp cached streams' segments from `pointers` and simulate.
    /// `pressure` is the plan's chunking-determined HBM-pressure term
    /// ([`crate::plan::TenantSet::hbm_pressure_us`]), constant across
    /// pointer candidates.
    fn eval_pointers(
        &self,
        cache: &mut Vec<Vec<crate::gpu::SimStage>>,
        pointers: &PointerMatrix,
        pressure: f64,
    ) -> f64 {
        self.restamp(cache, pointers);
        crate::gpu::GpuSim::new(self.opts).run_staged(cache).objective() + pressure
    }

    fn restamp(&self, cache: &mut [Vec<crate::gpu::SimStage>], pointers: &PointerMatrix) {
        for (ti, stream) in cache.iter_mut().enumerate() {
            let plist = pointers.list(ti);
            for stage in stream.iter_mut() {
                let src = stage.pieces[0].source_op;
                let seg = plist.iter().filter(|&&p| p <= src).count();
                for piece in &mut stage.pieces {
                    piece.segment = seg;
                }
            }
        }
    }

    /// Seed a new pointer in the middle of tenant `i`'s largest segment.
    fn seed_position(&self, pointers: &PointerMatrix, i: usize) -> usize {
        let len = self.ts.tenants[i].len();
        let segs = pointers.segments_of(i, len);
        let (s, e) = segs
            .iter()
            .copied()
            .max_by_key(|(s, e)| e - s)
            .unwrap_or((0, len));
        ((s + e) / 2).clamp(1, len.saturating_sub(1).max(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;
    use crate::profile::{CostModel, Platform};

    fn quick_cfg() -> SearchConfig {
        SearchConfig {
            max_pointers: 2,
            rounds_per_level: 1,
            positions_per_coordinate: 6,
            spatial_steps_per_level: 2,
            ..Default::default()
        }
    }

    fn run_combo(names: &[&str], cfg: SearchConfig) -> SearchReport {
        let platform = Platform::titan_v();
        let cost = CostModel::new(platform);
        let tenants = zoo::build_combo(names);
        let ts = TenantSet::new(tenants.clone(), cost.clone());
        GacerSearch::new(&ts, SimOptions::for_platform(&platform), cfg).run()
    }

    #[test]
    fn search_never_worse_than_stream_parallel() {
        let r = run_combo(&["Alex", "V16", "R18"], quick_cfg());
        assert!(r.outcome.objective() <= r.initial.objective() + 1e-6);
        assert!(r.outcome.makespan_us <= r.initial.makespan_us * 1.001);
    }

    #[test]
    fn search_improves_heavy_combo() {
        let r = run_combo(&["R50", "V16", "M3"], quick_cfg());
        assert!(
            r.speedup_vs_initial() > 1.0,
            "expected improvement, got {}",
            r.speedup_vs_initial()
        );
    }

    #[test]
    fn returned_plan_validates() {
        let platform = Platform::titan_v();
        let cost = CostModel::new(platform);
        let tenants = zoo::build_combo(&["R34", "LSTM", "BST"]);
        let ts = TenantSet::new(tenants.clone(), cost.clone());
        let r = GacerSearch::new(&ts, SimOptions::for_platform(&platform), quick_cfg()).run();
        r.plan.validate(&tenants).unwrap();
    }

    #[test]
    fn ablations_are_subsets() {
        // Joint search must be at least as good as either ablation arm
        // (same budget) on the big combo.
        let joint = run_combo(&["R101", "D121", "M3"], quick_cfg());
        let spatial = run_combo(&["R101", "D121", "M3"], SearchConfig {
            enable_temporal: false,
            ..quick_cfg()
        });
        let temporal = run_combo(&["R101", "D121", "M3"], SearchConfig {
            enable_spatial: false,
            ..quick_cfg()
        });
        assert!(joint.outcome.makespan_us <= spatial.outcome.makespan_us * 1.02);
        assert!(joint.outcome.makespan_us <= temporal.outcome.makespan_us * 1.02);
    }

    #[test]
    fn evaluation_count_reported() {
        let r = run_combo(&["Alex", "V16", "R18"], quick_cfg());
        assert!(r.evaluations > 1);
        assert!(!r.level_best.is_empty());
        // Unbudgeted runs converge: never flagged as truncated.
        assert!(!r.truncated);
        assert!(r.budget.is_unbounded());
        assert_eq!(r.warm_hits, 0, "cold run has no warm state to hit");
    }

    fn tenant_set(names: &[&str]) -> TenantSet {
        let platform = Platform::titan_v();
        TenantSet::new(zoo::build_combo(names), CostModel::new(platform))
    }

    #[test]
    fn budgeted_run_truncates_but_never_regresses() {
        let ts = tenant_set(&["R50", "V16", "M3"]);
        let opts = SimOptions::for_platform(&Platform::titan_v());
        let search = GacerSearch::new(&ts, opts, quick_cfg())
            .budget(SearchBudget::evaluations(4));
        let r = search.run();
        assert!(r.truncated, "a 4-eval budget must interrupt the search");
        assert!(r.evaluations >= 4);
        assert!(r.outcome.objective() <= r.initial.objective() + 1e-6);
        r.plan.validate(&ts.tenants).unwrap();
    }

    #[test]
    fn budget_labels_render() {
        assert_eq!(SearchBudget::unbounded().label(), "unbounded");
        assert_eq!(SearchBudget::evaluations(100).label(), "<=100 evals");
        assert!(SearchBudget::deadline_ms(5).label().contains("ms"));
    }

    #[test]
    fn stale_seed_is_a_typed_error_not_a_panic() {
        let ts = tenant_set(&["Alex", "V16", "R18"]);
        let opts = SimOptions::for_platform(&Platform::titan_v());
        let search = GacerSearch::new(&ts, opts, quick_cfg());
        // Wrong arity: a seed from before an eviction/admission.
        let stale = DeploymentPlan::unregulated(5);
        assert!(matches!(
            search.run_from(stale),
            Err(crate::error::Error::InvalidPlan(_))
        ));
        // Out-of-range pointer: a seed tuned for a longer DFG.
        let mut bad = DeploymentPlan::unregulated(3);
        bad.pointers.set_list(0, vec![ts.tenants[0].len() + 5]);
        assert!(matches!(
            search.run_from(bad),
            Err(crate::error::Error::InvalidPlan(_))
        ));
    }

    #[test]
    fn warm_state_short_circuits_unchanged_research() {
        let ts = tenant_set(&["Alex", "R18"]);
        let opts = SimOptions::for_platform(&Platform::titan_v());
        let search = GacerSearch::new(&ts, opts, quick_cfg());
        let mut state = SearchState::new();
        assert!(state.is_empty());
        let cold = search.run_with_state(&mut state);
        assert_eq!(state.cached_tenants(), 2);
        // Nothing changed: bit-for-bit reproduction at zero evaluations.
        let warm = search.run_from_state(cold.plan.clone(), &mut state).unwrap();
        assert_eq!(warm.plan, cold.plan);
        assert_eq!(warm.outcome, cold.outcome);
        assert_eq!(warm.evaluations, 0);
        assert_eq!(warm.warm_hits, 2);
        assert!(!warm.truncated);
        // Invalidation drops everything.
        state.invalidate();
        assert!(state.is_empty());
    }

    #[test]
    fn warm_state_reuses_streams_across_an_admit() {
        // Deploy 2 tenants with spatial off (chunking stays empty, so
        // stream fingerprints survive the event), then admit a third:
        // the two incumbents' streams come from the warm cache.
        let cfg = SearchConfig { enable_spatial: false, ..quick_cfg() };
        let platform = Platform::titan_v();
        let opts = SimOptions::for_platform(&platform);
        let cost = CostModel::new(platform);
        let mut tenants = zoo::build_combo(&["Alex", "R18"]);
        let ts = TenantSet::new(tenants.clone(), cost.clone());
        let mut state = SearchState::new();
        let deployed = GacerSearch::new(&ts, opts, cfg).run_with_state(&mut state);

        tenants.push(zoo::build_default("M3").unwrap());
        let grown = TenantSet::new(tenants.clone(), cost);
        let mut seed = deployed.plan.clone();
        seed.push_tenant(
            tenants.last().unwrap().len(),
            seed.pointers.pointers_per_tenant(),
        );
        let warm = GacerSearch::new(&grown, opts, cfg)
            .run_from_state(seed.clone(), &mut state)
            .unwrap();
        assert!(warm.warm_hits >= 2, "incumbent streams reused, got {}", warm.warm_hits);
        // Anytime guarantee: never worse than the inherited seed.
        let seed_obj = grown.simulate(&seed, opts).objective();
        assert!(warm.outcome.objective() <= seed_obj + 1e-6);
        warm.plan.validate(&grown.tenants).unwrap();
    }
}
