//! Device-aware search: placement + one Algorithm-1 run per device.
//!
//! GACER regulates concurrency *within* one GPU; a device pool adds an
//! outer decision — which tenants share a GPU at all. [`ShardedSearch`]
//! stages the two: first a cost-model-driven [`Placement`] shards the
//! tenant set across devices (bin-packing with a load-balance objective),
//! then an independent [`GacerSearch`] runs per shard, producing a
//! [`ShardedDeploymentPlan`] — one chunk map + pointer matrix per device.
//! Shards never interact during search (device memory is private and the
//! simulator models one SM pool), so per-device runs are exact, not an
//! approximation.
//!
//! ```
//! use gacer::models::zoo;
//! use gacer::plan::TenantSet;
//! use gacer::profile::{CostModel, Platform};
//! use gacer::gpu::SimOptions;
//! use gacer::search::{SearchConfig, ShardedSearch};
//!
//! let platform = Platform::titan_v();
//! let set = TenantSet::new(
//!     zoo::build_combo(&["Alex", "M3"]),
//!     CostModel::new(platform),
//! );
//! let cfg = SearchConfig {
//!     max_pointers: 1,
//!     rounds_per_level: 1,
//!     positions_per_coordinate: 4,
//!     spatial_steps_per_level: 1,
//!     ..Default::default()
//! };
//! let report = ShardedSearch::new(&set, SimOptions::for_platform(&platform), cfg).run(2);
//! report.plan.validate(&set.tenants).unwrap();
//! assert_eq!(report.plan.n_devices(), 2);
//! assert!(report.cluster_makespan_us() > 0.0);
//! ```

use std::time::{Duration, Instant};

use crate::gpu::SimOptions;
use crate::plan::{
    DeploymentPlan, Placement, PlacementObjective, ShardedDeploymentPlan, TenantSet,
};

use super::{GacerSearch, SearchConfig, SearchReport};

/// Result of a sharded search: the device-dimensioned plan plus the
/// per-device Algorithm-1 bookkeeping.
#[derive(Debug, Clone)]
pub struct ShardedSearchReport {
    /// The searched multi-device plan.
    pub plan: ShardedDeploymentPlan,
    /// One [`SearchReport`] per device; `None` for devices the placement
    /// left empty (more devices than tenants).
    pub reports: Vec<Option<SearchReport>>,
    /// Wall-clock time across all per-device searches.
    pub elapsed: Duration,
}

impl ShardedSearchReport {
    /// Cluster makespan: the bottleneck device's searched makespan (empty
    /// devices finish at 0).
    pub fn cluster_makespan_us(&self) -> f64 {
        self.reports
            .iter()
            .flatten()
            .map(|r| r.outcome.makespan_us)
            .fold(0.0, f64::max)
    }

    /// The device that bounds the cluster makespan, if any tenant is
    /// deployed.
    pub fn bottleneck_device(&self) -> Option<usize> {
        self.reports
            .iter()
            .enumerate()
            .filter_map(|(d, r)| r.as_ref().map(|r| (d, r.outcome.makespan_us)))
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap_or(std::cmp::Ordering::Equal))
            .map(|(d, _)| d)
    }

    /// Total simulator evaluations across the per-device searches.
    pub fn total_evaluations(&self) -> usize {
        self.reports.iter().flatten().map(|r| r.evaluations).sum()
    }
}

/// The placement-then-regulate searcher for multi-GPU deployments.
pub struct ShardedSearch<'a> {
    set: &'a TenantSet,
    opts: SimOptions,
    cfg: SearchConfig,
    objective: PlacementObjective,
}

impl<'a> ShardedSearch<'a> {
    pub fn new(set: &'a TenantSet, opts: SimOptions, cfg: SearchConfig) -> Self {
        ShardedSearch { set, opts, cfg, objective: PlacementObjective::default() }
    }

    /// Placement objective [`ShardedSearch::run`] shards with (default
    /// [`PlacementObjective::LoadBalance`]).
    pub fn objective(mut self, objective: PlacementObjective) -> Self {
        self.objective = objective;
        self
    }

    /// Cold sharded search: compute a placement across `n_devices` under
    /// the configured objective, then run Algorithm 1 per device.
    pub fn run(&self, n_devices: usize) -> ShardedSearchReport {
        self.run_placed(Placement::with_objective(self.set, n_devices, self.objective))
    }

    /// Cold per-device searches under a caller-fixed placement.
    pub fn run_placed(&self, placement: Placement) -> ShardedSearchReport {
        let start = Instant::now();
        let mut shards = Vec::with_capacity(placement.n_devices());
        let mut reports = Vec::with_capacity(placement.n_devices());
        for d in 0..placement.n_devices() {
            let sub = self.set.shard(&placement, d);
            if sub.is_empty() {
                shards.push(DeploymentPlan::unregulated(0));
                reports.push(None);
                continue;
            }
            let report = GacerSearch::new(&sub, self.opts, self.cfg).run();
            shards.push(report.plan.clone());
            reports.push(Some(report));
        }
        ShardedSearchReport {
            plan: ShardedDeploymentPlan { placement, shards },
            reports,
            elapsed: start.elapsed(),
        }
    }

    /// Incremental single-shard re-search: run Algorithm 1 on `device`'s
    /// tenants only, seeded with that shard's current (already re-shaped)
    /// plan — the admit/evict path of a sharded engine. Returns `None`
    /// when the device is empty (e.g. its last tenant was just evicted).
    pub fn research_device(
        &self,
        placement: &Placement,
        device: usize,
        seed: DeploymentPlan,
    ) -> Option<SearchReport> {
        let sub = self.set.shard(placement, device);
        if sub.is_empty() {
            return None;
        }
        Some(GacerSearch::new(&sub, self.opts, self.cfg).run_from(seed))
    }

    /// Seeded re-search of several shards in one event — tenant
    /// **migration** re-plans exactly two devices (source and
    /// destination) and nothing else. One seed per entry of `devices`,
    /// in order; the result has one report slot per entry (`None` for a
    /// device the event left empty, e.g. a source device that lost its
    /// last tenant).
    pub fn research_devices(
        &self,
        placement: &Placement,
        devices: &[usize],
        seeds: Vec<DeploymentPlan>,
    ) -> Vec<Option<SearchReport>> {
        assert_eq!(devices.len(), seeds.len(), "one seed per re-searched device");
        devices
            .iter()
            .zip(seeds)
            .map(|(&d, seed)| self.research_device(placement, d, seed))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;
    use crate::profile::{CostModel, Platform};

    fn quick_cfg() -> SearchConfig {
        SearchConfig {
            max_pointers: 1,
            rounds_per_level: 1,
            positions_per_coordinate: 4,
            spatial_steps_per_level: 1,
            ..Default::default()
        }
    }

    fn set(names: &[&str]) -> TenantSet {
        TenantSet::new(zoo::build_combo(names), CostModel::new(Platform::titan_v()))
    }

    #[test]
    fn sharded_run_produces_valid_per_device_plans() {
        let ts = set(&["Alex", "V16", "R18"]);
        let opts = SimOptions::for_platform(&Platform::titan_v());
        let r = ShardedSearch::new(&ts, opts, quick_cfg()).run(2);
        r.plan.validate(&ts.tenants).unwrap();
        assert_eq!(r.plan.n_devices(), 2);
        // Every occupied device carries a report that is never worse than
        // its own unregulated start.
        for (d, rep) in r.reports.iter().enumerate() {
            let occupied = !r.plan.placement.tenants_on(d).is_empty();
            assert_eq!(rep.is_some(), occupied);
            if let Some(rep) = rep {
                assert!(rep.outcome.objective() <= rep.initial.objective() + 1e-6);
            }
        }
        assert!(r.total_evaluations() > 0);
        assert!(r.cluster_makespan_us() > 0.0);
        assert!(r.bottleneck_device().is_some());
    }

    #[test]
    fn one_device_matches_plain_search_shape() {
        let ts = set(&["Alex", "R18"]);
        let opts = SimOptions::for_platform(&Platform::titan_v());
        let r = ShardedSearch::new(&ts, opts, quick_cfg()).run(1);
        assert_eq!(r.plan.n_devices(), 1);
        assert_eq!(r.plan.placement.tenants_on(0), &[0, 1]);
        // The single shard is a full-set plan: its merged projection is
        // the shard itself.
        assert_eq!(r.plan.merged().unwrap(), r.plan.shards[0]);
    }

    #[test]
    fn empty_devices_get_empty_plans_and_no_reports() {
        let ts = set(&["Alex"]);
        let opts = SimOptions::for_platform(&Platform::titan_v());
        let r = ShardedSearch::new(&ts, opts, quick_cfg()).run(3);
        r.plan.validate(&ts.tenants).unwrap();
        assert_eq!(r.reports.iter().flatten().count(), 1);
        assert_eq!(r.plan.shards.iter().filter(|s| s.chunking.is_empty()).count(), 2);
    }

    #[test]
    fn objective_threads_through_to_the_placement() {
        let ts = set(&["Alex", "V16", "R18"]);
        let opts = SimOptions::for_platform(&Platform::titan_v());
        let r = ShardedSearch::new(&ts, opts, quick_cfg())
            .objective(PlacementObjective::InterferenceAware)
            .run(2);
        assert_eq!(r.plan.placement, Placement::interference_aware(&ts, 2));
        r.plan.validate(&ts.tenants).unwrap();
        // The default objective is load balance.
        let r = ShardedSearch::new(&ts, opts, quick_cfg()).run(2);
        assert_eq!(r.plan.placement, Placement::balanced(&ts, 2));
    }

    #[test]
    fn research_devices_runs_one_seeded_search_per_entry() {
        let ts = set(&["Alex", "V16", "R18"]);
        let opts = SimOptions::for_platform(&Platform::titan_v());
        let search = ShardedSearch::new(&ts, opts, quick_cfg());
        // The migration shape: re-search both devices, one seed each; a
        // device emptied by the event yields None.
        let reports = search.research_devices(
            &Placement::from_assignments(vec![vec![0, 1, 2], vec![]]),
            &[0, 1],
            vec![DeploymentPlan::unregulated(3), DeploymentPlan::unregulated(0)],
        );
        assert!(reports[0].is_some());
        assert!(reports[1].is_none());
    }

    #[test]
    fn research_device_touches_one_shard() {
        let ts = set(&["Alex", "V16", "R18"]);
        let opts = SimOptions::for_platform(&Platform::titan_v());
        let search = ShardedSearch::new(&ts, opts, quick_cfg());
        let cold = search.run(2);
        let d = cold.bottleneck_device().unwrap();
        let seeded = search
            .research_device(&cold.plan.placement, d, cold.plan.shards[d].clone())
            .unwrap();
        // Seeded re-search of an already-searched shard must not regress.
        let coldd = cold.reports[d].as_ref().unwrap();
        assert!(seeded.outcome.objective() <= coldd.outcome.objective() + 1e-6);
        // An empty device yields no report.
        let empty = Placement::from_assignments(vec![vec![0, 1, 2], vec![]]);
        assert!(search
            .research_device(&empty, 1, DeploymentPlan::unregulated(0))
            .is_none());
    }
}
