//! Device-aware search: placement + one Algorithm-1 run per device.
//!
//! GACER regulates concurrency *within* one GPU; a device pool adds an
//! outer decision — which tenants share a GPU at all. [`ShardedSearch`]
//! stages the two: first a cost-model-driven [`Placement`] shards the
//! tenant set across devices (bin-packing with a load-balance objective),
//! then an independent [`GacerSearch`] runs per shard, producing a
//! [`ShardedDeploymentPlan`] — one chunk map + pointer matrix per device.
//! Shards never interact during search (device memory is private and the
//! simulator models one SM pool), so per-device runs are exact, not an
//! approximation.
//!
//! ```
//! use gacer::models::zoo;
//! use gacer::plan::TenantSet;
//! use gacer::profile::{CostModel, Platform};
//! use gacer::gpu::SimOptions;
//! use gacer::search::{SearchConfig, ShardedSearch};
//!
//! let platform = Platform::titan_v();
//! let set = TenantSet::new(
//!     zoo::build_combo(&["Alex", "M3"]),
//!     CostModel::new(platform),
//! );
//! let cfg = SearchConfig {
//!     max_pointers: 1,
//!     rounds_per_level: 1,
//!     positions_per_coordinate: 4,
//!     spatial_steps_per_level: 1,
//!     ..Default::default()
//! };
//! let report = ShardedSearch::new(&set, SimOptions::for_platform(&platform), cfg).run(2);
//! report.plan.validate(&set.tenants).unwrap();
//! assert_eq!(report.plan.n_devices(), 2);
//! assert!(report.cluster_makespan_us() > 0.0);
//! ```

use std::time::{Duration, Instant};

use crate::error::Result;
use crate::gpu::SimOptions;
use crate::plan::{
    DeploymentPlan, Placement, PlacementObjective, ShardedDeploymentPlan, TenantSet,
};
use crate::profile::DevicePool;

use super::{GacerSearch, SearchBudget, SearchConfig, SearchReport, SearchState};

/// Result of a sharded search: the device-dimensioned plan plus the
/// per-device Algorithm-1 bookkeeping.
#[derive(Debug, Clone)]
pub struct ShardedSearchReport {
    /// The searched multi-device plan.
    pub plan: ShardedDeploymentPlan,
    /// One [`SearchReport`] per device; `None` for devices the placement
    /// left empty (more devices than tenants).
    pub reports: Vec<Option<SearchReport>>,
    /// Wall-clock time across all per-device searches.
    pub elapsed: Duration,
}

impl ShardedSearchReport {
    /// Cluster makespan: the bottleneck device's searched makespan (empty
    /// devices finish at 0).
    pub fn cluster_makespan_us(&self) -> f64 {
        self.reports
            .iter()
            .flatten()
            .map(|r| r.outcome.makespan_us)
            .fold(0.0, f64::max)
    }

    /// The device that bounds the cluster makespan, if any tenant is
    /// deployed.
    pub fn bottleneck_device(&self) -> Option<usize> {
        self.reports
            .iter()
            .enumerate()
            .filter_map(|(d, r)| r.as_ref().map(|r| (d, r.outcome.makespan_us)))
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap_or(std::cmp::Ordering::Equal))
            .map(|(d, _)| d)
    }

    /// Total simulator evaluations across the per-device searches.
    pub fn total_evaluations(&self) -> usize {
        self.reports.iter().flatten().map(|r| r.evaluations).sum()
    }

    /// Whether any device's search was cut short by its
    /// [`SearchBudget`] (budgets apply **per device search**, not to the
    /// whole sharded run).
    pub fn truncated(&self) -> bool {
        self.reports.iter().flatten().any(|r| r.truncated)
    }
}

/// The placement-then-regulate searcher for multi-GPU deployments.
pub struct ShardedSearch<'a> {
    set: &'a TenantSet,
    opts: SimOptions,
    cfg: SearchConfig,
    objective: PlacementObjective,
    budget: SearchBudget,
    pool: Option<&'a DevicePool>,
}

impl<'a> ShardedSearch<'a> {
    pub fn new(set: &'a TenantSet, opts: SimOptions, cfg: SearchConfig) -> Self {
        ShardedSearch {
            set,
            opts,
            cfg,
            objective: PlacementObjective::default(),
            budget: SearchBudget::unbounded(),
            pool: None,
        }
    }

    /// Placement objective [`ShardedSearch::run`] shards with (default
    /// [`PlacementObjective::LoadBalance`]).
    pub fn objective(mut self, objective: PlacementObjective) -> Self {
        self.objective = objective;
        self
    }

    /// Search against a heterogeneous [`DevicePool`]: placement scores
    /// candidates per device ([`Placement::with_objective_pool`]), and
    /// each device's Algorithm-1 run prices and simulates its shard on
    /// **its own** platform ([`SimOptions::for_platform`] + the device's
    /// cost model) instead of the constructor's shared `opts`/cost. The
    /// device-count arguments of [`ShardedSearch::run`]/
    /// [`ShardedSearch::run_warm`] must equal `pool.len()`. On a uniform
    /// pool matching the set's cost model this is behaviour-identical to
    /// the pool-less searcher.
    pub fn pool(mut self, pool: &'a DevicePool) -> Self {
        self.pool = Some(pool);
        self
    }

    /// Per-device simulator options: the device's own platform when a
    /// pool is set, the shared constructor `opts` otherwise.
    fn device_opts(&self, device: usize) -> SimOptions {
        match self.pool {
            Some(pool) => SimOptions::for_platform(pool.platform(device)),
            None => self.opts,
        }
    }

    /// Per-device shard input: priced with the device's own cost model
    /// when a pool is set.
    fn device_shard(&self, placement: &Placement, device: usize) -> TenantSet {
        match self.pool {
            Some(pool) => self.set.shard_on(placement, device, pool.cost(device)),
            None => self.set.shard(placement, device),
        }
    }

    fn make_placement(&self, n_devices: usize) -> Placement {
        match self.pool {
            Some(pool) => {
                debug_assert_eq!(pool.len(), n_devices, "pool size vs n_devices");
                Placement::with_objective_pool(self.set, pool, self.objective)
            }
            None => Placement::with_objective(self.set, n_devices, self.objective),
        }
    }

    /// Budget for **each per-device search** (default
    /// [`SearchBudget::unbounded`]). Shards search independently, so the
    /// budget bounds one device's run, not their sum;
    /// [`ShardedSearchReport::truncated`] reports whether any shard was
    /// cut short.
    pub fn budget(mut self, budget: SearchBudget) -> Self {
        self.budget = budget;
        self
    }

    /// Cold sharded search: compute a placement across `n_devices` under
    /// the configured objective, then run Algorithm 1 per device.
    pub fn run(&self, n_devices: usize) -> ShardedSearchReport {
        self.run_placed(self.make_placement(n_devices))
    }

    /// [`ShardedSearch::run`], also (re)filling one warm [`SearchState`]
    /// per device so later incremental re-searches
    /// ([`ShardedSearch::research_device_warm`]) start from this run's
    /// compiled streams and converged plans. `states.len()` must equal
    /// `n_devices`.
    pub fn run_warm(
        &self,
        n_devices: usize,
        states: &mut [SearchState],
    ) -> ShardedSearchReport {
        self.run_placed_warm(self.make_placement(n_devices), states)
    }

    /// Cold per-device searches under a caller-fixed placement.
    pub fn run_placed(&self, placement: Placement) -> ShardedSearchReport {
        let mut states = vec![SearchState::default(); placement.n_devices()];
        self.run_placed_warm(placement, &mut states)
    }

    /// [`ShardedSearch::run_placed`] with caller-owned warm states (one
    /// per device; reset for devices the placement leaves empty).
    pub fn run_placed_warm(
        &self,
        placement: Placement,
        states: &mut [SearchState],
    ) -> ShardedSearchReport {
        assert_eq!(states.len(), placement.n_devices(), "one warm state per device");
        let start = Instant::now();
        let mut shards = Vec::with_capacity(placement.n_devices());
        let mut reports = Vec::with_capacity(placement.n_devices());
        for d in 0..placement.n_devices() {
            let sub = self.device_shard(&placement, d);
            if sub.is_empty() {
                states[d].invalidate();
                shards.push(DeploymentPlan::unregulated(0));
                reports.push(None);
                continue;
            }
            let report = GacerSearch::new(&sub, self.device_opts(d), self.cfg)
                .budget(self.budget)
                .run_with_state(&mut states[d]);
            shards.push(report.plan.clone());
            reports.push(Some(report));
        }
        ShardedSearchReport {
            plan: ShardedDeploymentPlan { placement, shards },
            reports,
            elapsed: start.elapsed(),
        }
    }

    /// Incremental single-shard re-search: run Algorithm 1 on `device`'s
    /// tenants only, seeded with that shard's current (already re-shaped)
    /// plan — the admit/evict path of a sharded engine. Returns
    /// `Ok(None)` when the device is empty (e.g. its last tenant was just
    /// evicted) and [`Error::InvalidPlan`](crate::Error::InvalidPlan)
    /// when the seed does not match the shard's tenants (a stale seed
    /// must not index out of bounds).
    pub fn research_device(
        &self,
        placement: &Placement,
        device: usize,
        seed: DeploymentPlan,
    ) -> Result<Option<SearchReport>> {
        self.research_device_warm(placement, device, seed, &mut SearchState::default())
    }

    /// [`ShardedSearch::research_device`] with the device's persistent
    /// warm [`SearchState`]: compiled streams are reused for tenants
    /// whose chunking is unchanged, and a no-change re-search
    /// short-circuits to the cached plan. An emptied device invalidates
    /// its state.
    pub fn research_device_warm(
        &self,
        placement: &Placement,
        device: usize,
        seed: DeploymentPlan,
        state: &mut SearchState,
    ) -> Result<Option<SearchReport>> {
        let sub = self.device_shard(placement, device);
        if sub.is_empty() {
            state.invalidate();
            return Ok(None);
        }
        let report = GacerSearch::new(&sub, self.device_opts(device), self.cfg)
            .budget(self.budget)
            .run_from_state(seed, state)?;
        Ok(Some(report))
    }

    /// Seeded re-search of several shards in one event — tenant
    /// **migration** re-plans exactly two devices (source and
    /// destination) and nothing else. One seed per entry of `devices`,
    /// in order; the result has one report slot per entry (`None` for a
    /// device the event left empty, e.g. a source device that lost its
    /// last tenant).
    pub fn research_devices(
        &self,
        placement: &Placement,
        devices: &[usize],
        seeds: Vec<DeploymentPlan>,
    ) -> Result<Vec<Option<SearchReport>>> {
        assert_eq!(devices.len(), seeds.len(), "one seed per re-searched device");
        devices
            .iter()
            .zip(seeds)
            .map(|(&d, seed)| self.research_device(placement, d, seed))
            .collect()
    }

    /// [`ShardedSearch::research_devices`] with the deployment's warm
    /// states, indexed by device id (`states.len()` must cover every
    /// entry of `devices`).
    pub fn research_devices_warm(
        &self,
        placement: &Placement,
        devices: &[usize],
        seeds: Vec<DeploymentPlan>,
        states: &mut [SearchState],
    ) -> Result<Vec<Option<SearchReport>>> {
        assert_eq!(devices.len(), seeds.len(), "one seed per re-searched device");
        let mut out = Vec::with_capacity(devices.len());
        for (&d, seed) in devices.iter().zip(seeds) {
            out.push(self.research_device_warm(placement, d, seed, &mut states[d])?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;
    use crate::profile::{CostModel, Platform};

    fn quick_cfg() -> SearchConfig {
        SearchConfig {
            max_pointers: 1,
            rounds_per_level: 1,
            positions_per_coordinate: 4,
            spatial_steps_per_level: 1,
            ..Default::default()
        }
    }

    fn set(names: &[&str]) -> TenantSet {
        TenantSet::new(zoo::build_combo(names), CostModel::new(Platform::titan_v()))
    }

    #[test]
    fn sharded_run_produces_valid_per_device_plans() {
        let ts = set(&["Alex", "V16", "R18"]);
        let opts = SimOptions::for_platform(&Platform::titan_v());
        let r = ShardedSearch::new(&ts, opts, quick_cfg()).run(2);
        r.plan.validate(&ts.tenants).unwrap();
        assert_eq!(r.plan.n_devices(), 2);
        // Every occupied device carries a report that is never worse than
        // its own unregulated start.
        for (d, rep) in r.reports.iter().enumerate() {
            let occupied = !r.plan.placement.tenants_on(d).is_empty();
            assert_eq!(rep.is_some(), occupied);
            if let Some(rep) = rep {
                assert!(rep.outcome.objective() <= rep.initial.objective() + 1e-6);
            }
        }
        assert!(r.total_evaluations() > 0);
        assert!(r.cluster_makespan_us() > 0.0);
        assert!(r.bottleneck_device().is_some());
    }

    #[test]
    fn one_device_matches_plain_search_shape() {
        let ts = set(&["Alex", "R18"]);
        let opts = SimOptions::for_platform(&Platform::titan_v());
        let r = ShardedSearch::new(&ts, opts, quick_cfg()).run(1);
        assert_eq!(r.plan.n_devices(), 1);
        assert_eq!(r.plan.placement.tenants_on(0), &[0, 1]);
        // The single shard is a full-set plan: its merged projection is
        // the shard itself.
        assert_eq!(r.plan.merged().unwrap(), r.plan.shards[0]);
    }

    #[test]
    fn empty_devices_get_empty_plans_and_no_reports() {
        let ts = set(&["Alex"]);
        let opts = SimOptions::for_platform(&Platform::titan_v());
        let r = ShardedSearch::new(&ts, opts, quick_cfg()).run(3);
        r.plan.validate(&ts.tenants).unwrap();
        assert_eq!(r.reports.iter().flatten().count(), 1);
        assert_eq!(r.plan.shards.iter().filter(|s| s.chunking.is_empty()).count(), 2);
    }

    #[test]
    fn objective_threads_through_to_the_placement() {
        let ts = set(&["Alex", "V16", "R18"]);
        let opts = SimOptions::for_platform(&Platform::titan_v());
        let r = ShardedSearch::new(&ts, opts, quick_cfg())
            .objective(PlacementObjective::InterferenceAware)
            .run(2);
        assert_eq!(r.plan.placement, Placement::interference_aware(&ts, 2));
        r.plan.validate(&ts.tenants).unwrap();
        // The default objective is load balance.
        let r = ShardedSearch::new(&ts, opts, quick_cfg()).run(2);
        assert_eq!(r.plan.placement, Placement::balanced(&ts, 2));
    }

    #[test]
    fn research_devices_runs_one_seeded_search_per_entry() {
        let ts = set(&["Alex", "V16", "R18"]);
        let opts = SimOptions::for_platform(&Platform::titan_v());
        let search = ShardedSearch::new(&ts, opts, quick_cfg());
        // The migration shape: re-search both devices, one seed each; a
        // device emptied by the event yields None.
        let reports = search
            .research_devices(
                &Placement::from_assignments(vec![vec![0, 1, 2], vec![]]),
                &[0, 1],
                vec![DeploymentPlan::unregulated(3), DeploymentPlan::unregulated(0)],
            )
            .unwrap();
        assert!(reports[0].is_some());
        assert!(reports[1].is_none());
        // A stale seed (arity from before the event) is a typed error.
        let err = search.research_devices(
            &Placement::from_assignments(vec![vec![0, 1, 2], vec![]]),
            &[0],
            vec![DeploymentPlan::unregulated(7)],
        );
        assert!(matches!(err, Err(crate::error::Error::InvalidPlan(_))));
    }

    #[test]
    fn research_device_touches_one_shard() {
        let ts = set(&["Alex", "V16", "R18"]);
        let opts = SimOptions::for_platform(&Platform::titan_v());
        let search = ShardedSearch::new(&ts, opts, quick_cfg());
        let cold = search.run(2);
        let d = cold.bottleneck_device().unwrap();
        let seeded = search
            .research_device(&cold.plan.placement, d, cold.plan.shards[d].clone())
            .unwrap()
            .unwrap();
        // Seeded re-search of an already-searched shard must not regress.
        let coldd = cold.reports[d].as_ref().unwrap();
        assert!(seeded.outcome.objective() <= coldd.outcome.objective() + 1e-6);
        // An empty device yields no report.
        let empty = Placement::from_assignments(vec![vec![0, 1, 2], vec![]]);
        assert!(search
            .research_device(&empty, 1, DeploymentPlan::unregulated(0))
            .unwrap()
            .is_none());
    }

    #[test]
    fn warm_states_fill_on_cold_runs_and_short_circuit_research() {
        let ts = set(&["Alex", "V16", "R18"]);
        let opts = SimOptions::for_platform(&Platform::titan_v());
        let search = ShardedSearch::new(&ts, opts, quick_cfg());
        let mut states = vec![SearchState::default(); 2];
        let cold = search.run_warm(2, &mut states);
        for d in 0..2 {
            let occupied = !cold.plan.placement.tenants_on(d).is_empty();
            assert_eq!(!states[d].is_empty(), occupied);
        }
        // Re-searching an unchanged shard off its warm state costs zero
        // evaluations and reproduces the shard plan bit-for-bit.
        let d = cold.bottleneck_device().unwrap();
        let warm = search
            .research_device_warm(
                &cold.plan.placement,
                d,
                cold.plan.shards[d].clone(),
                &mut states[d],
            )
            .unwrap()
            .unwrap();
        assert_eq!(warm.plan, cold.plan.shards[d]);
        assert_eq!(warm.evaluations, 0);
        // An emptied device invalidates its state.
        let empty = Placement::from_assignments(vec![vec![0, 1, 2], vec![]]);
        assert!(search
            .research_device_warm(
                &empty,
                1,
                DeploymentPlan::unregulated(0),
                &mut states[d]
            )
            .unwrap()
            .is_none());
        assert!(states[d].is_empty());
    }

    #[test]
    fn pool_searches_each_device_on_its_own_platform() {
        use crate::profile::DevicePool;
        // Heterogeneous pool: the placement is the pool-aware one and
        // every shard still searches to a valid, non-regressing plan.
        let ts = TenantSet::new(
            zoo::build_combo(&["Alex", "V16", "R18"]),
            CostModel::new(Platform::a100()),
        );
        let pool = DevicePool::from_platforms([Platform::a100(), Platform::t4()]);
        let opts = SimOptions::for_platform(&Platform::a100());
        let r = ShardedSearch::new(&ts, opts, quick_cfg()).pool(&pool).run(2);
        r.plan.validate(&ts.tenants).unwrap();
        assert_eq!(r.plan.placement, Placement::balanced_pool(&ts, &pool));
        for rep in r.reports.iter().flatten() {
            assert!(rep.outcome.objective() <= rep.initial.objective() + 1e-6);
        }
        // A uniform pool matching the set's platform reproduces the
        // pool-less searcher bit-for-bit.
        let uni = DevicePool::uniform(Platform::titan_v(), 2);
        let ts2 = set(&["Alex", "V16", "R18"]);
        let o2 = SimOptions::for_platform(&Platform::titan_v());
        let with_pool = ShardedSearch::new(&ts2, o2, quick_cfg()).pool(&uni).run(2);
        let without = ShardedSearch::new(&ts2, o2, quick_cfg()).run(2);
        assert_eq!(with_pool.plan, without.plan);
    }

    #[test]
    fn per_device_budget_flags_sharded_truncation() {
        let ts = set(&["R50", "V16", "R18", "M3"]);
        let opts = SimOptions::for_platform(&Platform::titan_v());
        let r = ShardedSearch::new(&ts, opts, quick_cfg())
            .budget(SearchBudget::evaluations(4))
            .run(2);
        assert!(r.truncated(), "4 evals per device must truncate");
        r.plan.validate(&ts.tenants).unwrap();
        // Every occupied device still never regresses vs unregulated.
        for rep in r.reports.iter().flatten() {
            assert!(rep.outcome.objective() <= rep.initial.objective() + 1e-6);
        }
        // Unbudgeted sharded runs never truncate.
        let r = ShardedSearch::new(&ts, opts, quick_cfg()).run(2);
        assert!(!r.truncated());
    }
}
