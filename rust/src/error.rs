//! Typed errors for every public API boundary of the crate.
//!
//! The engine, the serving coordinator, and the PJRT runtime all return
//! [`Error`] instead of stringly ad-hoc errors, so callers can match on
//! the failure class (reject a bad plan vs. retry a backend hiccup) and
//! the `gacer` binary can map classes to exit codes.

use std::fmt;

/// Crate-wide result alias. The error type defaults to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Failure classes at the crate's API boundaries.
#[derive(Debug)]
#[non_exhaustive]
pub enum Error {
    /// A [`crate::plan::DeploymentPlan`] failed validation against its
    /// tenant set (chunk sums, pointer ranges, tenant-count mismatches).
    InvalidPlan(String),
    /// An engine/server configuration is internally inconsistent (e.g. an
    /// `issue_order` that is not a permutation of the tenant indices).
    InvalidConfig(String),
    /// A tenant DFG failed structural validation on admission.
    InvalidTenant(crate::dfg::DfgError),
    /// An engine call referenced a tenant id that is not deployed.
    UnknownTenant(u64),
    /// A model name the zoo does not know.
    UnknownModel(String),
    /// The artifact manifest is missing, unreadable, or malformed.
    Artifact(String),
    /// An artifact entry name absent from the manifest.
    UnknownArtifact(String),
    /// A tenant family with no compiled batch variants in the manifest.
    MissingFamily(String),
    /// Input/output data failed a shape or content check.
    InvalidData(String),
    /// The PJRT backend failed (compile/execute), or the crate was built
    /// without the `xla-runtime` feature.
    Backend(String),
    /// A coordinator channel closed: the named component stopped.
    ChannelClosed(&'static str),
    /// A request was shed by overload protection: the tenant's bounded
    /// queue ([`crate::slo::SloPolicy::queue_cap`]) was full at arrival,
    /// or SLO admission control refused a new tenant while a higher
    /// [`crate::slo::Tier`] was burning its error budget. The request
    /// was answered, not dropped — clients can back off and retry.
    Overloaded(String),
    /// A request was shed because its per-request deadline
    /// ([`crate::slo::SloPolicy::deadline`]) expired while it was still
    /// queued: answering it late would only burn budget for the requests
    /// behind it.
    DeadlineExceeded(String),
    /// Admission/placement refused because no device has enough free HBM
    /// for the tenant's resident footprint (weights + chunk-scaled
    /// activations), even if it would fit by compute. The message names
    /// the tenant, its footprint, and the tightest device's free bytes.
    MemoryCapacity(String),
    /// Scale-in refused: draining the device would leave at least one of
    /// its resident tenants with no capacity-feasible surviving device
    /// (every survivor's free HBM is smaller than the tenant's resident
    /// footprint). The pool is left exactly as it was — the operator can
    /// evict tenants, add capacity, or retry; see docs/OPERATIONS.md.
    DrainImpossible(String),
    /// Filesystem failure (artifact/param loading, spawn).
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::InvalidPlan(m) => write!(f, "invalid deployment plan: {m}"),
            Error::InvalidConfig(m) => write!(f, "invalid configuration: {m}"),
            Error::InvalidTenant(e) => write!(f, "invalid tenant DFG: {e}"),
            Error::UnknownTenant(id) => write!(f, "unknown tenant id {id}"),
            Error::UnknownModel(m) => write!(f, "unknown model {m}"),
            Error::Artifact(m) => write!(f, "artifact manifest: {m}"),
            Error::UnknownArtifact(m) => write!(f, "unknown artifact {m}"),
            Error::MissingFamily(m) => {
                write!(f, "no compiled artifacts for family {m}")
            }
            Error::InvalidData(m) => write!(f, "invalid data: {m}"),
            Error::Backend(m) => write!(f, "backend: {m}"),
            Error::ChannelClosed(who) => write!(f, "{who} stopped"),
            Error::Overloaded(m) => write!(f, "overloaded: {m}"),
            Error::DeadlineExceeded(m) => write!(f, "deadline exceeded: {m}"),
            Error::MemoryCapacity(m) => write!(f, "memory capacity: {m}"),
            Error::DrainImpossible(m) => write!(f, "drain impossible: {m}"),
            Error::Io(e) => write!(f, "io: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            Error::InvalidTenant(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<crate::dfg::DfgError> for Error {
    fn from(e: crate::dfg::DfgError) -> Self {
        Error::InvalidTenant(e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_carries_class_and_detail() {
        let e = Error::InvalidPlan("chunk sums to 7, batch is 8".into());
        let s = e.to_string();
        assert!(s.contains("invalid deployment plan"));
        assert!(s.contains("batch is 8"));
    }

    #[test]
    fn io_errors_convert_and_chain() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(std::error::Error::source(&e).is_some());
    }

    #[test]
    fn shed_errors_are_matchable_and_descriptive() {
        let e = Error::Overloaded("tenant a: queue full (cap 8)".into());
        assert!(matches!(e, Error::Overloaded(_)));
        assert!(e.to_string().contains("overloaded"));
        let e = Error::DeadlineExceeded("tenant a: queued past 5ms deadline".into());
        assert!(matches!(e, Error::DeadlineExceeded(_)));
        assert!(e.to_string().contains("deadline exceeded"));
    }

    #[test]
    fn memory_capacity_is_matchable_and_descriptive() {
        let e = Error::MemoryCapacity(
            "tenant big: footprint 14.4 GB exceeds 12.0 GB free on device 0".into(),
        );
        assert!(matches!(e, Error::MemoryCapacity(_)));
        let s = e.to_string();
        assert!(s.contains("memory capacity"));
        assert!(s.contains("14.4 GB"));
    }

    #[test]
    fn drain_impossible_is_matchable_and_descriptive() {
        let e = Error::DrainImpossible(
            "device gpu1: tenant big (14.4 GB) fits no surviving device".into(),
        );
        assert!(matches!(e, Error::DrainImpossible(_)));
        let s = e.to_string();
        assert!(s.contains("drain impossible"));
        assert!(s.contains("gpu1"));
    }

    #[test]
    fn is_send_sync_static() {
        fn assert_bounds<T: Send + Sync + 'static>() {}
        assert_bounds::<Error>();
    }
}
