//! Online cost-model calibration: close the predicted-vs-observed loop.
//!
//! Every placement, admission, and migration decision in the engine flows
//! from the analytic roofline cost model ([`crate::profile::CostModel`]).
//! The serving path, however, already *measures* the truth: each observe
//! window [`crate::engine::GacerEngine::record_latencies`] receives the
//! served per-tenant latencies. This module holds the correction layer
//! between the two — a [`Calibrator`] that maintains bounded
//! per-(tenant, device-platform) residual EWMAs of
//! `observed / predicted` latency and exposes a clamped multiplicative
//! correction factor the engine blends back into the weights used by
//! [`crate::plan::Placement`] scorers, admission
//! ([`crate::engine::GacerEngine::admit_with`]), the
//! [`crate::engine::MigrationPolicy`] proposers, and
//! [`crate::engine::GacerEngine::maybe_regulate`].
//!
//! Three properties make the layer safe to leave on in production:
//!
//! 1. **Trust ramp** — a residual is *analytic-only* (correction exactly
//!    `1.0`) until it has accumulated [`CalibrationConfig::min_samples`]
//!    observations, so cold-start decisions are bit-for-bit identical to
//!    the uncalibrated engine (regression-tested in
//!    `rust/tests/prop_invariants.rs`).
//! 2. **Clamping** — trusted corrections are clamped into
//!    `[min_correction, max_correction]`, bounding the damage a
//!    mis-measured window can do.
//! 3. **Bounded state** — at most [`CalibrationConfig::max_entries`]
//!    residuals are retained; the least-recently-touched entry is evicted
//!    first, so a long-lived engine serving a churning tenant population
//!    cannot grow without bound.
//!
//! The calibrator is fully deterministic: no clocks, no RNG — recency is
//! a monotonic touch counter, and the EWMA depends only on the
//! observation sequence. Determinism in (seed, observation order) is one
//! of the seeded properties in the invariant battery.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// Knobs for the online correction layer (`serve --calibrate` runs the
/// defaults; see `docs/OPERATIONS.md` §Calibration for the runbook).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CalibrationConfig {
    /// Trust ramp: a residual contributes no correction (factor `1.0`)
    /// until it has at least this many observations. Keeps cold-start
    /// behavior bit-for-bit analytic.
    pub min_samples: u32,
    /// EWMA blend weight for each new `observed / predicted` ratio
    /// (`ewma = alpha * ratio + (1 - alpha) * ewma`). Must lie in
    /// `(0, 1]`.
    pub alpha: f64,
    /// Lower clamp on the trusted correction factor.
    pub min_correction: f64,
    /// Upper clamp on the trusted correction factor.
    pub max_correction: f64,
    /// Maximum number of (tenant, platform) residuals retained; the
    /// least-recently-observed entry is evicted beyond this.
    pub max_entries: usize,
}

impl Default for CalibrationConfig {
    fn default() -> Self {
        Self {
            min_samples: 3,
            alpha: 0.3,
            min_correction: 0.25,
            max_correction: 4.0,
            max_entries: 256,
        }
    }
}

impl CalibrationConfig {
    /// Validate the knob ranges (typed errors, checked at engine build).
    pub fn validate(&self) -> Result<()> {
        if self.min_samples == 0 {
            return Err(Error::InvalidConfig(
                "calibration min_samples must be >= 1 (0 would trust an \
                 empty residual)"
                    .to_string(),
            ));
        }
        if !(self.alpha > 0.0 && self.alpha <= 1.0) {
            return Err(Error::InvalidConfig(format!(
                "calibration alpha must be in (0, 1], got {}",
                self.alpha
            )));
        }
        if !(self.min_correction > 0.0 && self.min_correction.is_finite()) {
            return Err(Error::InvalidConfig(format!(
                "calibration min_correction must be finite and positive, got {}",
                self.min_correction
            )));
        }
        if !(self.max_correction >= self.min_correction
            && self.max_correction.is_finite())
        {
            return Err(Error::InvalidConfig(format!(
                "calibration max_correction ({}) must be finite and >= \
                 min_correction ({})",
                self.max_correction, self.min_correction
            )));
        }
        if self.max_entries == 0 {
            return Err(Error::InvalidConfig(
                "calibration max_entries must be >= 1".to_string(),
            ));
        }
        Ok(())
    }
}

/// One residual EWMA: the running `observed / predicted` latency ratio
/// for a (tenant, device-platform) pair.
#[derive(Debug, Clone, Copy, PartialEq)]
struct Residual {
    /// EWMA of `observed_us / predicted_us`.
    ratio_ewma: f64,
    /// Observations folded in so far (saturating).
    samples: u32,
    /// Monotonic recency stamp for LRU eviction.
    touch: u64,
}

/// A read-only snapshot of one residual, for introspection
/// ([`Calibrator::entries`], `serve --calibrate` status lines).
#[derive(Debug, Clone, PartialEq)]
pub struct CalibrationEntry {
    /// Engine-assigned tenant id (`TenantId.0`).
    pub tenant: u64,
    /// Device platform name the observations were served on.
    pub platform: String,
    /// Current EWMA of `observed / predicted`.
    pub ratio_ewma: f64,
    /// Observations folded in so far.
    pub samples: u32,
    /// Whether the trust ramp has completed (`samples >= min_samples`).
    pub trusted: bool,
    /// The clamped correction factor decisions would use right now
    /// (`1.0` while untrusted).
    pub correction: f64,
}

/// Bounded store of per-(tenant, device-platform) residual EWMAs with a
/// trust ramp and clamped corrections. See the module docs for the
/// safety contract.
#[derive(Debug, Clone)]
pub struct Calibrator {
    cfg: CalibrationConfig,
    residuals: BTreeMap<(u64, String), Residual>,
    clock: u64,
    /// Total observations accepted (not evicted ones — ever accepted).
    observations: u64,
}

impl Calibrator {
    /// Build a calibrator with validated knobs.
    pub fn new(cfg: CalibrationConfig) -> Result<Self> {
        cfg.validate()?;
        Ok(Self { cfg, residuals: BTreeMap::new(), clock: 0, observations: 0 })
    }

    /// The active knob set.
    pub fn config(&self) -> &CalibrationConfig {
        &self.cfg
    }

    /// Fold one observe-window measurement into the (tenant, platform)
    /// residual. Non-finite or non-positive inputs are dropped (a shed
    /// window or a division by a zero prediction must not poison the
    /// EWMA). Returns whether the observation was accepted.
    pub fn observe(
        &mut self,
        tenant: u64,
        platform: &str,
        predicted_us: f64,
        observed_us: f64,
    ) -> bool {
        if !(predicted_us.is_finite() && predicted_us > 0.0) {
            return false;
        }
        if !(observed_us.is_finite() && observed_us > 0.0) {
            return false;
        }
        let ratio = observed_us / predicted_us;
        if !ratio.is_finite() {
            return false;
        }
        self.clock += 1;
        self.observations += 1;
        let key = (tenant, platform.to_string());
        match self.residuals.get_mut(&key) {
            Some(r) => {
                r.ratio_ewma = self.cfg.alpha * ratio
                    + (1.0 - self.cfg.alpha) * r.ratio_ewma;
                r.samples = r.samples.saturating_add(1);
                r.touch = self.clock;
            }
            None => {
                self.residuals.insert(
                    key,
                    Residual { ratio_ewma: ratio, samples: 1, touch: self.clock },
                );
                self.enforce_bound();
            }
        }
        true
    }

    /// Evict least-recently-touched residuals beyond the bound.
    fn enforce_bound(&mut self) {
        while self.residuals.len() > self.cfg.max_entries {
            let oldest = self
                .residuals
                .iter()
                .min_by_key(|(_, r)| r.touch)
                .map(|(k, _)| k.clone());
            match oldest {
                Some(k) => {
                    self.residuals.remove(&k);
                }
                None => break,
            }
        }
    }

    /// The multiplicative correction decisions should apply to the
    /// analytic score of `tenant` on `platform`: exactly `1.0` until the
    /// trust ramp completes, then the residual EWMA clamped into
    /// `[min_correction, max_correction]`.
    pub fn correction(&self, tenant: u64, platform: &str) -> f64 {
        match self.residuals.get(&(tenant, platform.to_string())) {
            Some(r) if r.samples >= self.cfg.min_samples => {
                r.ratio_ewma.clamp(self.cfg.min_correction, self.cfg.max_correction)
            }
            _ => 1.0,
        }
    }

    /// Whether `tenant` has any residual past the trust ramp (on any
    /// platform). Engines skip the blend entirely when no tenant is
    /// trusted, preserving the bit-for-bit analytic path.
    pub fn is_trusted(&self, tenant: u64, platform: &str) -> bool {
        self.residuals
            .get(&(tenant, platform.to_string()))
            .is_some_and(|r| r.samples >= self.cfg.min_samples)
    }

    /// Drop every residual for `tenant` (all platforms). Called by the
    /// engine on [`crate::engine::GacerEngine::evict`] so a readmitted
    /// tenant restarts its trust ramp from zero.
    pub fn forget(&mut self, tenant: u64) {
        self.residuals.retain(|(t, _), _| *t != tenant);
    }

    /// Number of residuals currently retained.
    pub fn len(&self) -> usize {
        self.residuals.len()
    }

    /// Whether the calibrator holds no residuals at all.
    pub fn is_empty(&self) -> bool {
        self.residuals.is_empty()
    }

    /// Number of residuals past the trust ramp.
    pub fn trusted_count(&self) -> usize {
        self.residuals
            .values()
            .filter(|r| r.samples >= self.cfg.min_samples)
            .count()
    }

    /// Total observations ever accepted.
    pub fn observations(&self) -> u64 {
        self.observations
    }

    /// Snapshot every residual (deterministic key order) for
    /// introspection and status printing.
    pub fn entries(&self) -> Vec<CalibrationEntry> {
        self.residuals
            .iter()
            .map(|((tenant, platform), r)| {
                let trusted = r.samples >= self.cfg.min_samples;
                CalibrationEntry {
                    tenant: *tenant,
                    platform: platform.clone(),
                    ratio_ewma: r.ratio_ewma,
                    samples: r.samples,
                    trusted,
                    correction: if trusted {
                        r.ratio_ewma
                            .clamp(self.cfg.min_correction, self.cfg.max_correction)
                    } else {
                        1.0
                    },
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn calib() -> Calibrator {
        Calibrator::new(CalibrationConfig::default()).unwrap()
    }

    #[test]
    fn untrusted_correction_is_exactly_one() {
        let mut c = calib();
        // min_samples = 3: two observations stay analytic-only.
        c.observe(1, "titan-v", 100.0, 400.0);
        c.observe(1, "titan-v", 100.0, 400.0);
        assert_eq!(c.correction(1, "titan-v"), 1.0);
        assert!(!c.is_trusted(1, "titan-v"));
        // Third observation completes the ramp.
        c.observe(1, "titan-v", 100.0, 400.0);
        assert!(c.is_trusted(1, "titan-v"));
        assert!(c.correction(1, "titan-v") > 1.0);
    }

    #[test]
    fn unknown_pair_is_analytic() {
        let c = calib();
        assert_eq!(c.correction(42, "a100"), 1.0);
        assert!(!c.is_trusted(42, "a100"));
        assert!(c.is_empty());
    }

    #[test]
    fn correction_converges_to_constant_bias_and_clamps() {
        let mut c = calib();
        for _ in 0..64 {
            c.observe(7, "titan-v", 100.0, 250.0);
        }
        let k = c.correction(7, "titan-v");
        assert!((k - 2.5).abs() < 1e-9, "EWMA of a constant converges: {k}");
        // A 100x bias clamps at max_correction.
        for _ in 0..64 {
            c.observe(8, "titan-v", 1.0, 100.0);
        }
        assert_eq!(c.correction(8, "titan-v"), c.config().max_correction);
        // A 100x speedup clamps at min_correction.
        for _ in 0..64 {
            c.observe(9, "titan-v", 100.0, 1.0);
        }
        assert_eq!(c.correction(9, "titan-v"), c.config().min_correction);
    }

    #[test]
    fn residuals_are_per_platform() {
        let mut c = calib();
        for _ in 0..4 {
            c.observe(1, "a100", 100.0, 300.0);
        }
        assert!(c.correction(1, "a100") > 1.0);
        // Same tenant, different platform: still on the analytic path.
        assert_eq!(c.correction(1, "t4"), 1.0);
    }

    #[test]
    fn bad_observations_are_dropped() {
        let mut c = calib();
        assert!(!c.observe(1, "titan-v", 0.0, 100.0));
        assert!(!c.observe(1, "titan-v", -5.0, 100.0));
        assert!(!c.observe(1, "titan-v", f64::NAN, 100.0));
        assert!(!c.observe(1, "titan-v", 100.0, 0.0));
        assert!(!c.observe(1, "titan-v", 100.0, f64::INFINITY));
        assert!(c.is_empty());
        assert_eq!(c.observations(), 0);
    }

    #[test]
    fn forget_resets_the_trust_ramp() {
        let mut c = calib();
        for _ in 0..8 {
            c.observe(3, "titan-v", 100.0, 600.0);
            c.observe(3, "t4", 100.0, 600.0);
        }
        assert!(c.is_trusted(3, "titan-v"));
        assert!(c.is_trusted(3, "t4"));
        c.forget(3);
        assert_eq!(c.correction(3, "titan-v"), 1.0);
        assert_eq!(c.correction(3, "t4"), 1.0);
        assert_eq!(c.len(), 0);
    }

    #[test]
    fn lru_bound_evicts_the_coldest_entry() {
        let mut c = Calibrator::new(CalibrationConfig {
            max_entries: 2,
            ..CalibrationConfig::default()
        })
        .unwrap();
        c.observe(1, "titan-v", 100.0, 200.0);
        c.observe(2, "titan-v", 100.0, 200.0);
        // Touch tenant 1 so tenant 2 is the LRU victim.
        c.observe(1, "titan-v", 100.0, 200.0);
        c.observe(3, "titan-v", 100.0, 200.0);
        assert_eq!(c.len(), 2);
        let tenants: Vec<u64> = c.entries().iter().map(|e| e.tenant).collect();
        assert_eq!(tenants, vec![1, 3], "tenant 2 was least recently touched");
    }

    #[test]
    fn entries_snapshot_reports_trust_and_clamp() {
        let mut c = calib();
        for _ in 0..5 {
            c.observe(1, "titan-v", 1.0, 1000.0);
        }
        c.observe(2, "titan-v", 100.0, 150.0);
        let e = c.entries();
        assert_eq!(e.len(), 2);
        assert!(e[0].trusted);
        assert_eq!(e[0].correction, c.config().max_correction);
        assert!(!e[1].trusted);
        assert_eq!(e[1].correction, 1.0);
        assert!((e[1].ratio_ewma - 1.5).abs() < 1e-12);
    }

    #[test]
    fn config_validation_rejects_bad_knobs() {
        let bad = |cfg: CalibrationConfig| Calibrator::new(cfg).is_err();
        assert!(bad(CalibrationConfig { min_samples: 0, ..Default::default() }));
        assert!(bad(CalibrationConfig { alpha: 0.0, ..Default::default() }));
        assert!(bad(CalibrationConfig { alpha: 1.5, ..Default::default() }));
        assert!(bad(CalibrationConfig { min_correction: 0.0, ..Default::default() }));
        assert!(bad(CalibrationConfig {
            min_correction: 2.0,
            max_correction: 1.0,
            ..Default::default()
        }));
        assert!(bad(CalibrationConfig { max_entries: 0, ..Default::default() }));
        assert!(Calibrator::new(CalibrationConfig::default()).is_ok());
    }
}
