//! The paper-experiment implementations (one per table/figure of §5).
//!
//! Shared by the `gacer-bench` binary and the cargo bench targets; each
//! prints the same rows/series the paper reports (DESIGN.md §6 indexes
//! them).

use crate::baselines::BaselineKind;
use super::{
    compare_placements, fig7_header, fig7_row, interference_demo_mix,
    memory_demo_mix, run_combo, run_replan, run_strategy, PlacementArm, ReplanCell,
    Strategy,
};
use crate::dfg::{Dfg, OpKind};
use crate::gpu::SimOptions;
use crate::models::zoo;
use crate::plan::{DeploymentPlan, TenantSet};
use crate::profile::{CostModel, Platform};
use crate::search::{GacerSearch, SearchBudget, SearchConfig};
use crate::temporal::PointerMatrix;

fn cfg() -> SearchConfig {
    SearchConfig::default()
}

/// Fig. 4: operator occupancy/duration vs batch (conv + BN classes).
pub fn fig4() {
    println!("== Fig. 4: operator resource/time profiles (Titan V) ==");
    let m = CostModel::new(Platform::titan_v());
    let conv = OpKind::Conv { h: 56, w: 56, cin: 256, cout: 256, k: 3, stride: 1 };
    let bn = OpKind::BatchNorm { elems: 56 * 56 * 256 };
    println!(
        "{:<8} {:>12} {:>12} {:>12} {:>12}",
        "batch", "conv W%", "conv T(us)", "bn W%", "bn T(us)"
    );
    for b in [1usize, 2, 4, 8, 16, 32, 64] {
        let c = m.cost_of(&conv, b);
        let n = m.cost_of(&bn, b);
        println!(
            "{:<8} {:>12.1} {:>12.1} {:>12.1} {:>12.1}",
            b, c.sm_occupancy, c.duration_us, n.sm_occupancy, n.duration_us
        );
    }
}

/// Fig. 7: normalized speedups, 5 combos x 7 strategies, Titan V.
pub fn fig7() {
    println!("== Fig. 7: runtime performance (Titan V), normalized to CuDNN-Seq ==");
    let platform = Platform::titan_v();
    let mut first = true;
    for combo in zoo::PAPER_COMBOS {
        let cells = run_combo(&combo, &platform, cfg());
        if first {
            println!("{}", fig7_header(&cells));
            first = false;
        }
        println!("{}", fig7_row(&zoo::combo_label(&combo), &cells));
    }
}

/// Fig. 8: utilization trace comparison on R101+D121+M3.
pub fn fig8() {
    println!("== Fig. 8: GPU utilization, R101+D121+M3 (Titan V) ==");
    let platform = Platform::titan_v();
    let combo = ["R101", "D121", "M3"];
    for strat in [
        Strategy::Baseline(BaselineKind::CudnnSeq),
        Strategy::Baseline(BaselineKind::StreamParallel),
        Strategy::Gacer,
    ] {
        let cost = CostModel::new(platform);
        let tenants = zoo::build_combo(&combo);
        let ts = TenantSet::new(tenants.clone(), cost.clone());
        let opts = SimOptions::for_platform(&platform).with_trace();
        let outcome = match strat {
            Strategy::Gacer => {
                let plan =
                    GacerSearch::new(&ts, SimOptions::for_platform(&platform), cfg())
                        .run()
                        .plan;
                ts.simulate(&plan, opts)
            }
            Strategy::Baseline(b) => crate::baselines::Baseline::new(&ts, opts).run(b),
            _ => unreachable!(),
        };
        let tr = outcome.trace.as_ref().unwrap();
        println!(
            "{:<16} mean SM occupancy {:>5.1}%   makespan {:>8.2} ms",
            strat.label(),
            tr.mean_occupancy(),
            outcome.makespan_us / 1e3
        );
        println!("    {}", tr.sparkline(64));
    }
}

/// Table 2: absolute latencies on P6000 / 1080Ti.
pub fn table2() {
    println!("== Table 2: GPU generality (ms; speedup vs CuDNN-Seq) ==");
    println!(
        "{:<16} {:>9} {:>9} {:>16} {:>16} {:>18} {:>18}",
        "Models", "C-P6000", "C-1080Ti", "S-P6000", "S-1080Ti", "GACER-P6000",
        "GACER-1080Ti"
    );
    for combo in zoo::PAPER_COMBOS {
        let mut cols: Vec<String> = Vec::new();
        let mut seq_ms = [0.0f64; 2];
        for (pi, platform) in
            [Platform::p6000(), Platform::gtx_1080ti()].iter().enumerate()
        {
            let c = run_strategy(
                &combo,
                platform,
                Strategy::Baseline(BaselineKind::CudnnSeq),
                cfg(),
            );
            seq_ms[pi] = c.latency_ms();
            cols.push(format!("{:.2}", c.latency_ms()));
        }
        for strat in [Strategy::Baseline(BaselineKind::StreamParallel), Strategy::Gacer] {
            for (pi, platform) in
                [Platform::p6000(), Platform::gtx_1080ti()].iter().enumerate()
            {
                let c = run_strategy(&combo, platform, strat, cfg());
                cols.push(format!(
                    "{:.2}({:.2}x)",
                    c.latency_ms(),
                    seq_ms[pi] / c.latency_ms()
                ));
            }
        }
        println!(
            "{:<16} {:>9} {:>9} {:>16} {:>16} {:>18} {:>18}",
            zoo::combo_label(&combo),
            cols[0],
            cols[1],
            cols[2],
            cols[3],
            cols[4],
            cols[5]
        );
    }
}

/// Fig. 9: temporal granularity sweep (model-wise -> operator-wise).
pub fn fig9() {
    println!("== Fig. 9: temporal granularity sweep (Titan V, ms) ==");
    let platform = Platform::titan_v();
    let combos =
        [["Alex", "V16", "R18"], ["R50", "V16", "M3"], ["R101", "D121", "M3"]];
    let granularities: [(&str, Option<usize>); 6] = [
        ("model-wise", Some(1)),
        ("segment-2", Some(2)),
        ("segment-4", Some(4)),
        ("segment-8", Some(8)),
        ("segment-16", Some(16)),
        ("operator-wise", None),
    ];
    print!("{:<16}", "combo");
    for (label, _) in &granularities {
        print!(" {label:>14}");
    }
    println!();
    for combo in combos {
        let cost = CostModel::new(platform);
        let tenants = zoo::build_combo(&combo);
        let ts = TenantSet::new(tenants.clone(), cost.clone());
        let opts = SimOptions::for_platform(&platform);
        print!("{:<16}", zoo::combo_label(&combo));
        for (_, segs) in &granularities {
            let pointers = match segs {
                Some(k) => PointerMatrix::equal_segments(&tenants, *k),
                None => PointerMatrix::operator_wise(&tenants),
            };
            let plan = DeploymentPlan {
                chunking: vec![Default::default(); tenants.len()],
                pointers,
            };
            let out = ts.simulate(&plan, opts);
            print!(" {:>14.2}", out.makespan_us / 1e3);
        }
        println!();
    }
}

/// Table 3: spatial granularity cases for V16(32) || R18(32).
pub fn table3() {
    println!("== Table 3: spatial granularity, V16(32) || R18(32) (Titan V, ms) ==");
    let platform = Platform::titan_v();
    let cost = CostModel::new(platform);
    let opts = SimOptions::for_platform(&platform);
    // Case encoding: (label, V16 chunk list, R18 chunk list).
    let cases: [(&str, Vec<usize>, Vec<usize>); 5] = [
        ("(1) V16(32)|R18(32)", vec![32], vec![32]),
        ("(2) V16(16,16)|R18(32)", vec![16, 16], vec![32]),
        ("(3) V16(24,8)|R18(32)", vec![24, 8], vec![32]),
        ("(4) V16(32)|R18(16,16)", vec![32], vec![16, 16]),
        ("(5) V16(8,8,8,8)|R18(32)", vec![8, 8, 8, 8], vec![32]),
    ];
    for (label, v16_split, r18_split) in cases {
        let tenants =
            vec![zoo::build("V16", 32).unwrap(), zoo::build("R18", 32).unwrap()];
        let ts = TenantSet::new(tenants.clone(), cost.clone());
        let mut plan = DeploymentPlan::unregulated(2);
        if v16_split.len() > 1 {
            for op in &tenants[0].ops {
                if op.chunkable()
                    && matches!(op.kind, OpKind::Conv { .. } | OpKind::ReLU { .. })
                {
                    plan.chunking[0].insert(op.id, v16_split.clone());
                }
            }
        }
        if r18_split.len() > 1 {
            for op in &tenants[1].ops {
                if op.chunkable()
                    && matches!(op.kind, OpKind::Conv { .. } | OpKind::ReLU { .. })
                {
                    plan.chunking[1].insert(op.id, r18_split.clone());
                }
            }
        }
        let out = ts.simulate(&plan, opts);
        println!("{label:<28} {:>8.2} ms", out.makespan_us / 1e3);
    }
}

/// Table 4: search wall-time vs rounds.
pub fn table4(base_rounds: usize) {
    println!("== Table 4: GACER search overhead ==");
    let platform = Platform::titan_v();
    let combos =
        [["R34", "V16", "LSTM"], ["R50", "V16", "M3"], ["R34", "LSTM", "BST"]];
    let round_settings = [100usize, 500, 1000, 2000, 10000];
    print!("{:<16}", "combo");
    for r in round_settings {
        print!(" {r:>10}");
    }
    println!("   (simulator-evaluation budget)");
    for combo in combos {
        let cost = CostModel::new(platform);
        let tenants = zoo::build_combo(&combo);
        let ts = TenantSet::new(tenants.clone(), cost.clone());
        print!("{:<16}", zoo::combo_label(&combo));
        for rounds in round_settings {
            let cfg = SearchConfig {
                rounds_per_level: (rounds / 100).max(base_rounds),
                positions_per_coordinate: 12,
                ..SearchConfig::default()
            };
            let t0 = std::time::Instant::now();
            let mut evals = 0usize;
            // Re-run the search until the evaluation budget is met
            // (models repeated offline searches).
            while evals < rounds {
                let r = GacerSearch::new(
                    &ts,
                    SimOptions::for_platform(&platform),
                    cfg,
                )
                .run();
                evals += r.evaluations;
            }
            print!(" {:>9.2}s", t0.elapsed().as_secs_f64());
        }
        println!();
    }
}

/// Placement objectives: LoadBalance vs InterferenceAware over
/// heterogeneous tenant mixes on 2 devices (decision-level comparison —
/// per-device load, predicted co-location slowdown, and the max
/// `load × slowdown` score each objective commits to).
pub fn placement_objectives() {
    println!(
        "== Placement objectives: LoadBalance vs InterferenceAware vs MemoryAware \
         (2 devices) =="
    );
    let platform = Platform::titan_v();
    let mixes: Vec<(&str, Vec<Dfg>)> = vec![
        // The canonical disagreement: two pool-saturating tenants whose
        // serial weights trick LPT into pairing them.
        ("2 saturating + 2 bandwidth-light", interference_demo_mix(&platform)),
        // Heterogeneous zoo mixes: large-batch vision tenants saturate,
        // the mobile/sequence tenants keep the occupancy spread wide.
        (
            "V16(32)+R18(32)+M3+LSTM",
            vec![
                zoo::build("V16", 32).unwrap(),
                zoo::build("R18", 32).unwrap(),
                zoo::build_default("M3").unwrap(),
                zoo::build_default("LSTM").unwrap(),
            ],
        ),
        ("R50+V16+M3+Alex", zoo::build_combo(&["R50", "V16", "M3", "Alex"])),
        (
            "R101(16)+D121(16)+M3+BST",
            vec![
                zoo::build("R101", 16).unwrap(),
                zoo::build("D121", 16).unwrap(),
                zoo::build_default("M3").unwrap(),
                zoo::build_default("BST").unwrap(),
            ],
        ),
    ];
    for (label, tenants) in mixes {
        println!("-- {label}");
        let arms = compare_placements(tenants, &platform, 2);
        for arm in &arms {
            println!(
                "  {:<17} max score {:>8.2} ms  (max load {:>8.2} ms, max slowdown {:.2}x)",
                arm.objective.label(),
                arm.max_score_ms,
                arm.max_load_ms(),
                arm.max_slowdown()
            );
            for (d, tenants) in arm.per_device.iter().enumerate() {
                println!(
                    "      device {d}: {tenants:?}  load {:.2} ms, slowdown {:.2}x",
                    arm.loads_ms[d], arm.slowdowns[d]
                );
            }
        }
        let (lb, ia) = (&arms[0], &arms[1]);
        println!(
            "  => interference-aware lowers the predicted bottleneck score by {:.1}% \
             (slowdown {:.2}x -> {:.2}x)",
            (1.0 - ia.max_score_ms / lb.max_score_ms.max(f64::MIN_POSITIVE)) * 100.0,
            lb.max_slowdown(),
            ia.max_slowdown()
        );
    }
}

/// `gacer-bench memory` — memory-bandwidth contention as a second cost
/// dimension (docs/BENCHMARKS.md): on a bandwidth-bound mix
/// ([`memory_demo_mix`]: two HBM-saturating BatchNorm tenants + two
/// low-bandwidth conv fillers) every memory-blind objective — LPT *and*
/// the occupancy-only interference objective — pairs the hogs, while
/// the two-dimensional roofline ([`PlacementObjective::MemoryAware`])
/// separates them. Each arm's committed placement is then simulated
/// per device (the simulator prices bandwidth oversubscription via
/// `r_mem`), and the contrast — predicted roofline slowdown, simulated
/// cluster makespan, per-device HBM residency — is recorded in
/// `BENCH_memory.json`.
///
/// [`PlacementObjective::MemoryAware`]:
///     crate::plan::PlacementObjective::MemoryAware
pub fn memory() {
    use crate::util::json::Json;
    use std::collections::BTreeMap;

    println!(
        "== Memory: bandwidth-bound placement, occupancy-only vs memory-aware \
         (Titan V, 2 devices) =="
    );
    let platform = Platform::titan_v();
    let mix = memory_demo_mix(&platform);
    let arms = compare_placements(mix.clone(), &platform, 2);
    let cost = CostModel::new(platform);
    let opts = SimOptions::for_platform(&platform);

    // Simulated cluster makespan of each committed placement: every
    // device's tenant group runs unregulated on its own simulated GPU;
    // the cluster finishes with its bottleneck device.
    let simulate_arm = |arm: &PlacementArm| -> Vec<f64> {
        arm.per_device
            .iter()
            .map(|names| {
                if names.is_empty() {
                    return 0.0;
                }
                let tenants: Vec<Dfg> = names
                    .iter()
                    .map(|n| {
                        mix.iter().find(|d| &d.name == n).expect("mix tenant").clone()
                    })
                    .collect();
                let n = tenants.len();
                let ts = TenantSet::new(tenants, cost.clone());
                ts.simulate(&DeploymentPlan::unregulated(n), opts).makespan_us / 1e3
            })
            .collect()
    };

    let mut sim_ms: Vec<Vec<f64>> = Vec::new();
    for arm in &arms {
        let per_device = simulate_arm(arm);
        let cluster = per_device.iter().copied().fold(0.0f64, f64::max);
        println!(
            "{:<17} roofline slowdown {:.2}x (occupancy-only sees {:.2}x)  \
             simulated cluster {:.2} ms",
            arm.objective.label(),
            arm.max_slowdown(),
            arm.max_occupancy_slowdown(),
            cluster
        );
        for (d, tenants) in arm.per_device.iter().enumerate() {
            println!(
                "    device {d}: {tenants:?}  load {:.2} ms, slowdown {:.2}x, \
                 HBM {:.2} GB, simulated {:.2} ms",
                arm.loads_ms[d], arm.slowdowns[d], arm.hbm_gb[d], per_device[d]
            );
        }
        sim_ms.push(per_device);
    }

    let cluster = |i: usize| sim_ms[i].iter().copied().fold(0.0f64, f64::max);
    let (ia, ma) = (&arms[1], &arms[2]);
    println!(
        "\n=> memory-aware placement cuts the predicted bottleneck slowdown \
         {:.2}x -> {:.2}x and the simulated cluster makespan {:.2} ms -> {:.2} ms \
         on a mix the occupancy axis prices as contention-free",
        ia.max_slowdown(),
        ma.max_slowdown(),
        cluster(1),
        cluster(2)
    );
    assert!(
        ma.max_slowdown() < ia.max_slowdown(),
        "memory-aware must strictly reduce the predicted max slowdown"
    );

    let arm_json = |arm: &PlacementArm, per_device: &[f64]| {
        let mut m = BTreeMap::new();
        m.insert("objective".to_string(), Json::Str(arm.objective.label().to_string()));
        m.insert(
            "per_device".to_string(),
            Json::Arr(
                arm.per_device
                    .iter()
                    .map(|names| {
                        Json::Arr(
                            names.iter().map(|n| Json::Str(n.clone())).collect(),
                        )
                    })
                    .collect(),
            ),
        );
        let nums = |v: &[f64]| Json::Arr(v.iter().map(|&x| Json::Num(x)).collect());
        m.insert("loads_ms".to_string(), nums(&arm.loads_ms));
        m.insert("roofline_slowdowns".to_string(), nums(&arm.slowdowns));
        m.insert("occupancy_slowdowns".to_string(), nums(&arm.occupancy_slowdowns));
        m.insert("hbm_gb".to_string(), nums(&arm.hbm_gb));
        m.insert("simulated_ms".to_string(), nums(per_device));
        m.insert(
            "simulated_cluster_ms".to_string(),
            Json::Num(per_device.iter().copied().fold(0.0f64, f64::max)),
        );
        m.insert("max_roofline_slowdown".to_string(), Json::Num(arm.max_slowdown()));
        m.insert(
            "max_occupancy_slowdown".to_string(),
            Json::Num(arm.max_occupancy_slowdown()),
        );
        Json::Obj(m)
    };
    let mut headline = BTreeMap::new();
    headline.insert(
        "occupancy_only_max_slowdown".to_string(),
        Json::Num(ia.max_slowdown()),
    );
    headline.insert(
        "memory_aware_max_slowdown".to_string(),
        Json::Num(ma.max_slowdown()),
    );
    headline.insert(
        "memory_aware_strictly_better".to_string(),
        Json::Bool(ma.max_slowdown() < ia.max_slowdown()),
    );
    headline.insert(
        "occupancy_only_simulated_cluster_ms".to_string(),
        Json::Num(cluster(1)),
    );
    headline.insert(
        "memory_aware_simulated_cluster_ms".to_string(),
        Json::Num(cluster(2)),
    );
    headline.insert(
        "simulated_makespan_reduced".to_string(),
        Json::Bool(cluster(2) < cluster(1)),
    );
    let mut root = BTreeMap::new();
    root.insert("experiment".to_string(), Json::Str("memory".to_string()));
    root.insert("platform".to_string(), Json::Str(platform.name.to_string()));
    root.insert("devices".to_string(), Json::Num(2.0));
    root.insert(
        "tenants".to_string(),
        Json::Arr(mix.iter().map(|d| Json::Str(d.name.clone())).collect()),
    );
    root.insert(
        "arms".to_string(),
        Json::Arr(
            arms.iter().zip(&sim_ms).map(|(a, s)| arm_json(a, s)).collect(),
        ),
    );
    root.insert("headline".to_string(), Json::Obj(headline));
    let json = Json::Obj(root).to_string_compact();
    match std::fs::write("BENCH_memory.json", &json) {
        Ok(()) => println!("wrote BENCH_memory.json ({} bytes)", json.len()),
        Err(e) => eprintln!("could not write BENCH_memory.json: {e}"),
    }
}

/// Re-plan latency & plan quality vs budget, cold vs warm — the
/// budgeted anytime re-search experiment (`docs/SEARCH.md`): an
/// 8-tenant deployment admits a 9th tenant, and the admit re-search runs
/// cold (Algorithm 1 from scratch on the grown set) and warm-started
/// from the deployment's [`crate::search::SearchState`] under a sweep of
/// evaluation budgets. Demonstrates (a) warm admit re-search evaluates
/// far fewer candidates than cold for comparable final plan quality, and
/// (b) under any eval budget the returned plan is never worse than the
/// inherited seed, with truncation correctly flagged.
pub fn replan() {
    println!("== Re-plan: budgeted anytime re-search, cold vs warm (Titan V) ==");
    let platform = Platform::titan_v();
    let base = ["R50", "V16", "M3", "Alex", "R18", "R34", "LSTM", "BST"];
    let budgets = [
        SearchBudget::evaluations(50),
        SearchBudget::evaluations(200),
        SearchBudget::evaluations(1000),
        SearchBudget::unbounded(),
    ];
    let (seed_obj, cold, warm) =
        run_replan(&base, "D121", &platform, SearchConfig::default(), &budgets);
    println!(
        "8-tenant deployment ({}) admits D121; inherited seed objective {seed_obj:.0}",
        base.join("+")
    );
    println!(
        "{:<24} {:>8} {:>14} {:>9} {:>10} {:>10} {:>12}",
        "arm", "evals", "objective", "vs seed", "truncated", "warm hits", "elapsed"
    );
    let row = |c: &ReplanCell| {
        println!(
            "{:<24} {:>8} {:>14.0} {:>9} {:>10} {:>10} {:>10.1}ms",
            c.label,
            c.evaluations,
            c.objective,
            format!("{:.3}x", c.objective / seed_obj),
            if c.truncated { "yes" } else { "no" },
            c.warm_hits,
            c.elapsed_ms
        );
    };
    row(&cold);
    for c in &warm {
        row(c);
        let ok = c.objective <= seed_obj * (1.0 + 1e-9);
        assert!(ok, "anytime guarantee violated: {} > seed {seed_obj}", c.objective);
    }
    let full = warm.last().expect("unbounded arm");
    println!(
        "\n=> warm admit re-search: {:.1}x fewer evaluations than cold \
         ({} vs {}), final objective {:.1}% of cold's; every budgeted arm \
         stayed at or below the inherited seed (anytime guarantee)",
        cold.evaluations as f64 / full.evaluations.max(1) as f64,
        full.evaluations,
        cold.evaluations,
        full.objective / cold.objective * 100.0
    );
}

/// Ablation: calibration-constant sensitivity (DESIGN.md §2).
///
/// The substitute substrate has two free contention constants (α:
/// oversubscription waste, β: per-kernel friction). The paper-shape
/// conclusions must not hinge on their exact values: this sweep re-runs
/// the Fig. 7 headline comparison (CuDNN-Seq vs Stream-Parallel vs GACER
/// on R50+V16+M3) across a grid and reports whether the ordering
/// Seq > SP > GACER (in latency) survives every cell.
pub fn ablation_sensitivity() {
    use crate::baselines::{Baseline, BaselineKind};
    use crate::plan::TenantSet as TS;

    println!("== Ablation: contention-constant sensitivity (R50+V16+M3, Titan V) ==");
    println!(
        "{:<8} {:<8} {:>12} {:>12} {:>12} {:>10}",
        "alpha", "beta", "Seq (ms)", "SP (ms)", "GACER (ms)", "ordering"
    );
    let platform = Platform::titan_v();
    let mut all_hold = true;
    for alpha in [0.10, 0.25, 0.40] {
        for beta in [0.0, 0.08, 0.16] {
            let cost = CostModel::new(platform);
            let tenants = zoo::build_combo(&["R50", "V16", "M3"]);
            let ts = TS::new(tenants.clone(), cost.clone());
            let mut opts = SimOptions::for_platform(&platform);
            opts.contention_alpha = alpha;
            opts.kernel_beta = beta;
            let b = Baseline::new(&ts, opts);
            let seq = b.run(BaselineKind::CudnnSeq).makespan_us / 1e3;
            let sp = b.run(BaselineKind::StreamParallel).makespan_us / 1e3;
            let gacer = GacerSearch::new(&ts, opts, cfg()).run().outcome.makespan_us / 1e3;
            let holds = seq > sp && sp > gacer;
            all_hold &= holds;
            println!(
                "{alpha:<8} {beta:<8} {seq:>12.2} {sp:>12.2} {gacer:>12.2} {:>10}",
                if holds { "holds" } else { "BROKEN" }
            );
        }
    }
    println!(
        "\nconclusion: Seq > Stream-Parallel > GACER {} across the grid",
        if all_hold { "HOLDS" } else { "does NOT hold" }
    );
}

/// `gacer-bench slo` — SLO-driven regulation on a saturated two-device
/// cluster (docs/SLO.md): one interactive tenant co-resident with batch
/// tenants whose combined demand exceeds device capacity. The regulated
/// arm issues tier-major and sheds over-cap batch arrivals; the
/// unregulated arm is fair round-robin with unbounded queues. The
/// interactive p99 holds its target only under regulation; both arms are
/// recorded in `BENCH_slo.json`.
pub fn slo() {
    use super::slo_sim::{
        run_slo_sim, saturated_mix, slo_report_json, SloSimConfig, SloSimOutcome,
    };

    let cfg = SloSimConfig::default();
    println!(
        "== SLO: interactive p99 under saturation ({} rounds, {} req/round/device, \
         target p99 {:.1}ms) ==",
        cfg.rounds,
        cfg.capacity_per_round,
        cfg.target.target_us / 1e3
    );
    let mix = saturated_mix();
    let arms = [
        ("slo-regulated", run_slo_sim(&mix, &cfg, true)),
        ("unregulated", run_slo_sim(&mix, &cfg, false)),
    ];
    for (label, out) in &arms {
        println!("{label}:");
        println!(
            "  {:<12} {:>3} {:>11} {:>7} {:>6} {:>9} {:>9} {:>9}  {}",
            "tenant", "dev", "tier", "served", "shed", "p50(us)", "p99(us)", "max(us)",
            "health"
        );
        for t in &out.tenants {
            println!(
                "  {:<12} {:>3} {:>11} {:>7} {:>6} {:>9.0} {:>9.0} {:>9.0}  {}",
                t.name,
                t.device,
                t.tier.label(),
                t.served,
                t.shed,
                t.latency.p50_us,
                t.latency.p99_us,
                t.latency.max_us,
                t.pressure.map_or("-", |p| p.health.label())
            );
        }
    }
    let p99 = |o: &SloSimOutcome| o.interactive_p99_us();
    let (reg, unreg) = (&arms[0].1, &arms[1].1);
    println!(
        "interactive p99: {:.0}us regulated vs {:.0}us unregulated (target {:.0}us)",
        p99(reg),
        p99(unreg),
        cfg.target.target_us
    );
    assert!(
        p99(reg) <= cfg.target.target_us,
        "regulated interactive p99 must hold the target"
    );
    assert!(
        p99(unreg) > cfg.target.target_us,
        "unregulated interactive p99 must violate the target"
    );
    let json = slo_report_json(&cfg, reg, unreg).to_string_compact();
    match std::fs::write("BENCH_slo.json", &json) {
        Ok(()) => println!("wrote BENCH_slo.json ({} bytes)", json.len()),
        Err(e) => eprintln!("could not write BENCH_slo.json: {e}"),
    }
}

/// `gacer-bench throughput` — request-path throughput under open-loop
/// load (docs/BENCHMARKS.md): sweep offered rates through the load
/// generator against a synthetic-backend cluster, once per
/// [`CompletionMode`] arm, and record achieved throughput, p50/p99
/// latency, and shed rate per point in `BENCH_throughput.json`. With
/// `--min-throughput R`, exits non-zero if the batched arm fails to
/// achieve `R` req/s at the highest offered rate — the CI smoke floor.
///
/// [`CompletionMode`]: crate::coordinator::CompletionMode
pub fn throughput(args: &crate::util::cli::Args) {
    use super::loadgen::{run_loadgen, LoadgenOptions, LoadgenReport, TraceShape};
    use crate::coordinator::CompletionMode;
    use crate::util::json::Json;
    use std::collections::BTreeMap;

    let opt_f64 = |key: &str, default: f64| {
        args.opt(key).and_then(|v| v.parse::<f64>().ok()).unwrap_or(default)
    };
    let duration_ms = opt_f64("duration-ms", 800.0);
    let seed = args.opt_usize("seed", 7) as u64;
    let n_tenants = args.opt_usize("tenants", 4).max(1);
    let queue_cap = args.opt_usize("queue-cap", 0);
    let submitters = args.opt_usize("submitters", 4);
    let trace = args.opt_or("trace", "poisson").to_string();
    let min_throughput = opt_f64("min-throughput", 0.0);
    let rates: Vec<f64> = args
        .opt_or("rates", "2000,8000,20000")
        .split(',')
        .filter_map(|r| r.trim().parse::<f64>().ok())
        .filter(|&r| r > 0.0)
        .collect();
    if rates.is_empty() {
        eprintln!("--rates must name at least one positive req/s value");
        std::process::exit(2);
    }
    if TraceShape::parse(&trace, 1.0).is_none() {
        eprintln!("unknown trace shape {trace:?} (poisson|bursty|diurnal)");
        std::process::exit(2);
    }

    println!(
        "== Throughput: open-loop {trace} sweep, {n_tenants} tenants, {duration_ms:.0}ms \
         per point, per-request vs batched completions =="
    );
    let run_point = |mode: CompletionMode, rate: f64| -> LoadgenReport {
        let shape = TraceShape::parse(&trace, rate).expect("validated above");
        run_loadgen(&LoadgenOptions {
            n_tenants,
            duration_ms,
            shape,
            seed,
            queue_cap,
            mode,
            submitters,
            ..LoadgenOptions::default()
        })
        .expect("synthetic loadgen run")
    };
    println!(
        "{:<12} {:>10} {:>10} {:>9} {:>9} {:>9} {:>7}",
        "arm", "offered", "achieved", "p50(us)", "p99(us)", "max(us)", "shed%"
    );
    let mut arms: Vec<(CompletionMode, Vec<LoadgenReport>)> = Vec::new();
    for mode in [CompletionMode::PerRequest, CompletionMode::Batched] {
        let mut points = Vec::with_capacity(rates.len());
        for &rate in &rates {
            let r = run_point(mode, rate);
            println!(
                "{:<12} {:>10.0} {:>10.0} {:>9.0} {:>9.0} {:>9.0} {:>7.2}",
                mode.label(),
                r.offered_rps,
                r.achieved_rps(),
                r.latency.p50_us,
                r.latency.p99_us,
                r.latency.max_us,
                r.shed_rate() * 100.0
            );
            points.push(r);
        }
        arms.push((mode, points));
    }

    // Headline: both arms at the highest offered rate.
    let last = |mode: CompletionMode| -> &LoadgenReport {
        &arms.iter().find(|(m, _)| *m == mode).expect("both arms ran").1[rates.len() - 1]
    };
    let (pr, ba) = (last(CompletionMode::PerRequest), last(CompletionMode::Batched));
    println!(
        "at {:.0} req/s offered: batched {:.0} req/s p99 {:.0}us vs per-request {:.0} req/s \
         p99 {:.0}us",
        ba.offered_rps,
        ba.achieved_rps(),
        ba.latency.p99_us,
        pr.achieved_rps(),
        pr.latency.p99_us
    );

    let arm_json = |points: &[LoadgenReport]| {
        Json::Arr(
            points
                .iter()
                .map(|r| {
                    let mut m = BTreeMap::new();
                    m.insert("offered_rps".to_string(), Json::Num(r.offered_rps));
                    m.insert("achieved_rps".to_string(), Json::Num(r.achieved_rps()));
                    m.insert("submitted".to_string(), Json::Num(r.submitted as f64));
                    m.insert("completed".to_string(), Json::Num(r.completed as f64));
                    m.insert("shed".to_string(), Json::Num(r.shed as f64));
                    m.insert("errors".to_string(), Json::Num(r.errors as f64));
                    m.insert("shed_rate".to_string(), Json::Num(r.shed_rate()));
                    m.insert("p50_us".to_string(), Json::Num(r.latency.p50_us));
                    m.insert("p99_us".to_string(), Json::Num(r.latency.p99_us));
                    m.insert("max_us".to_string(), Json::Num(r.latency.max_us));
                    m.insert(
                        "elapsed_ms".to_string(),
                        Json::Num(r.elapsed.as_secs_f64() * 1e3),
                    );
                    Json::Obj(m)
                })
                .collect(),
        )
    };
    let mut headline = BTreeMap::new();
    headline.insert("offered_rps".to_string(), Json::Num(ba.offered_rps));
    headline.insert("batched_rps".to_string(), Json::Num(ba.achieved_rps()));
    headline.insert("per_request_rps".to_string(), Json::Num(pr.achieved_rps()));
    headline.insert("batched_p99_us".to_string(), Json::Num(ba.latency.p99_us));
    headline.insert("per_request_p99_us".to_string(), Json::Num(pr.latency.p99_us));
    headline.insert(
        "batched_sustains_higher_throughput".to_string(),
        Json::Bool(ba.achieved_rps() >= pr.achieved_rps()),
    );
    // 10% slack: wall-clock p99 on shared CI hardware jitters; the claim
    // is "no worse", not "identical to the microsecond".
    headline.insert(
        "batched_p99_no_worse".to_string(),
        Json::Bool(ba.latency.p99_us <= pr.latency.p99_us * 1.10),
    );
    let mut root = BTreeMap::new();
    root.insert("experiment".to_string(), Json::Str("throughput".to_string()));
    root.insert("trace".to_string(), Json::Str(trace));
    root.insert("seed".to_string(), Json::Num(seed as f64));
    root.insert("tenants".to_string(), Json::Num(n_tenants as f64));
    root.insert("duration_ms".to_string(), Json::Num(duration_ms));
    root.insert("queue_cap".to_string(), Json::Num(queue_cap as f64));
    root.insert("offered_rps".to_string(), Json::Arr(rates.iter().map(|&r| Json::Num(r)).collect()));
    for (mode, points) in &arms {
        let key = match mode {
            CompletionMode::Batched => "batched",
            CompletionMode::PerRequest => "per_request",
        };
        root.insert(key.to_string(), arm_json(points));
    }
    root.insert("headline".to_string(), Json::Obj(headline));
    let json = Json::Obj(root).to_string_compact();
    match std::fs::write("BENCH_throughput.json", &json) {
        Ok(()) => println!("wrote BENCH_throughput.json ({} bytes)", json.len()),
        Err(e) => eprintln!("could not write BENCH_throughput.json: {e}"),
    }

    if min_throughput > 0.0 && ba.achieved_rps() < min_throughput {
        eprintln!(
            "FAIL: batched arm achieved {:.0} req/s, below the --min-throughput floor {:.0}",
            ba.achieved_rps(),
            min_throughput
        );
        std::process::exit(1);
    }
    if min_throughput > 0.0 {
        println!(
            "floor: batched {:.0} req/s >= {:.0} req/s required",
            ba.achieved_rps(),
            min_throughput
        );
    }
}
