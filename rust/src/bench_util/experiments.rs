//! The paper-experiment implementations (one per table/figure of §5).
//!
//! Shared by the `gacer-bench` binary and the cargo bench targets; each
//! prints the same rows/series the paper reports (DESIGN.md §6 indexes
//! them).

use crate::baselines::BaselineKind;
use super::{
    compare_placements, fig7_header, fig7_row, hetero_demo_mix,
    interference_demo_mix, memory_demo_mix, run_combo, run_replan, run_strategy,
    PlacementArm, ReplanCell, Strategy,
};
use crate::dfg::{Dfg, OpKind};
use crate::gpu::SimOptions;
use crate::models::zoo;
use crate::plan::{DeploymentPlan, TenantSet};
use crate::profile::{CostModel, Platform};
use crate::search::{GacerSearch, SearchBudget, SearchConfig};
use crate::temporal::PointerMatrix;

fn cfg() -> SearchConfig {
    SearchConfig::default()
}

/// Fig. 4: operator occupancy/duration vs batch (conv + BN classes).
pub fn fig4() {
    println!("== Fig. 4: operator resource/time profiles (Titan V) ==");
    let m = CostModel::new(Platform::titan_v());
    let conv = OpKind::Conv { h: 56, w: 56, cin: 256, cout: 256, k: 3, stride: 1 };
    let bn = OpKind::BatchNorm { elems: 56 * 56 * 256 };
    println!(
        "{:<8} {:>12} {:>12} {:>12} {:>12}",
        "batch", "conv W%", "conv T(us)", "bn W%", "bn T(us)"
    );
    for b in [1usize, 2, 4, 8, 16, 32, 64] {
        let c = m.cost_of(&conv, b);
        let n = m.cost_of(&bn, b);
        println!(
            "{:<8} {:>12.1} {:>12.1} {:>12.1} {:>12.1}",
            b, c.sm_occupancy, c.duration_us, n.sm_occupancy, n.duration_us
        );
    }
}

/// Fig. 7: normalized speedups, 5 combos x 7 strategies, Titan V.
pub fn fig7() {
    println!("== Fig. 7: runtime performance (Titan V), normalized to CuDNN-Seq ==");
    let platform = Platform::titan_v();
    let mut first = true;
    for combo in zoo::PAPER_COMBOS {
        let cells = run_combo(&combo, &platform, cfg());
        if first {
            println!("{}", fig7_header(&cells));
            first = false;
        }
        println!("{}", fig7_row(&zoo::combo_label(&combo), &cells));
    }
}

/// Fig. 8: utilization trace comparison on R101+D121+M3.
pub fn fig8() {
    println!("== Fig. 8: GPU utilization, R101+D121+M3 (Titan V) ==");
    let platform = Platform::titan_v();
    let combo = ["R101", "D121", "M3"];
    for strat in [
        Strategy::Baseline(BaselineKind::CudnnSeq),
        Strategy::Baseline(BaselineKind::StreamParallel),
        Strategy::Gacer,
    ] {
        let cost = CostModel::new(platform);
        let tenants = zoo::build_combo(&combo);
        let ts = TenantSet::new(tenants.clone(), cost.clone());
        let opts = SimOptions::for_platform(&platform).with_trace();
        let outcome = match strat {
            Strategy::Gacer => {
                let plan =
                    GacerSearch::new(&ts, SimOptions::for_platform(&platform), cfg())
                        .run()
                        .plan;
                ts.simulate(&plan, opts)
            }
            Strategy::Baseline(b) => crate::baselines::Baseline::new(&ts, opts).run(b),
            _ => unreachable!(),
        };
        let tr = outcome.trace.as_ref().unwrap();
        println!(
            "{:<16} mean SM occupancy {:>5.1}%   makespan {:>8.2} ms",
            strat.label(),
            tr.mean_occupancy(),
            outcome.makespan_us / 1e3
        );
        println!("    {}", tr.sparkline(64));
    }
}

/// Table 2: absolute latencies on P6000 / 1080Ti.
pub fn table2() {
    println!("== Table 2: GPU generality (ms; speedup vs CuDNN-Seq) ==");
    println!(
        "{:<16} {:>9} {:>9} {:>16} {:>16} {:>18} {:>18}",
        "Models", "C-P6000", "C-1080Ti", "S-P6000", "S-1080Ti", "GACER-P6000",
        "GACER-1080Ti"
    );
    for combo in zoo::PAPER_COMBOS {
        let mut cols: Vec<String> = Vec::new();
        let mut seq_ms = [0.0f64; 2];
        for (pi, platform) in
            [Platform::p6000(), Platform::gtx_1080ti()].iter().enumerate()
        {
            let c = run_strategy(
                &combo,
                platform,
                Strategy::Baseline(BaselineKind::CudnnSeq),
                cfg(),
            );
            seq_ms[pi] = c.latency_ms();
            cols.push(format!("{:.2}", c.latency_ms()));
        }
        for strat in [Strategy::Baseline(BaselineKind::StreamParallel), Strategy::Gacer] {
            for (pi, platform) in
                [Platform::p6000(), Platform::gtx_1080ti()].iter().enumerate()
            {
                let c = run_strategy(&combo, platform, strat, cfg());
                cols.push(format!(
                    "{:.2}({:.2}x)",
                    c.latency_ms(),
                    seq_ms[pi] / c.latency_ms()
                ));
            }
        }
        println!(
            "{:<16} {:>9} {:>9} {:>16} {:>16} {:>18} {:>18}",
            zoo::combo_label(&combo),
            cols[0],
            cols[1],
            cols[2],
            cols[3],
            cols[4],
            cols[5]
        );
    }
}

/// Fig. 9: temporal granularity sweep (model-wise -> operator-wise).
pub fn fig9() {
    println!("== Fig. 9: temporal granularity sweep (Titan V, ms) ==");
    let platform = Platform::titan_v();
    let combos =
        [["Alex", "V16", "R18"], ["R50", "V16", "M3"], ["R101", "D121", "M3"]];
    let granularities: [(&str, Option<usize>); 6] = [
        ("model-wise", Some(1)),
        ("segment-2", Some(2)),
        ("segment-4", Some(4)),
        ("segment-8", Some(8)),
        ("segment-16", Some(16)),
        ("operator-wise", None),
    ];
    print!("{:<16}", "combo");
    for (label, _) in &granularities {
        print!(" {label:>14}");
    }
    println!();
    for combo in combos {
        let cost = CostModel::new(platform);
        let tenants = zoo::build_combo(&combo);
        let ts = TenantSet::new(tenants.clone(), cost.clone());
        let opts = SimOptions::for_platform(&platform);
        print!("{:<16}", zoo::combo_label(&combo));
        for (_, segs) in &granularities {
            let pointers = match segs {
                Some(k) => PointerMatrix::equal_segments(&tenants, *k),
                None => PointerMatrix::operator_wise(&tenants),
            };
            let plan = DeploymentPlan {
                chunking: vec![Default::default(); tenants.len()],
                pointers,
            };
            let out = ts.simulate(&plan, opts);
            print!(" {:>14.2}", out.makespan_us / 1e3);
        }
        println!();
    }
}

/// Table 3: spatial granularity cases for V16(32) || R18(32).
pub fn table3() {
    println!("== Table 3: spatial granularity, V16(32) || R18(32) (Titan V, ms) ==");
    let platform = Platform::titan_v();
    let cost = CostModel::new(platform);
    let opts = SimOptions::for_platform(&platform);
    // Case encoding: (label, V16 chunk list, R18 chunk list).
    let cases: [(&str, Vec<usize>, Vec<usize>); 5] = [
        ("(1) V16(32)|R18(32)", vec![32], vec![32]),
        ("(2) V16(16,16)|R18(32)", vec![16, 16], vec![32]),
        ("(3) V16(24,8)|R18(32)", vec![24, 8], vec![32]),
        ("(4) V16(32)|R18(16,16)", vec![32], vec![16, 16]),
        ("(5) V16(8,8,8,8)|R18(32)", vec![8, 8, 8, 8], vec![32]),
    ];
    for (label, v16_split, r18_split) in cases {
        let tenants =
            vec![zoo::build("V16", 32).unwrap(), zoo::build("R18", 32).unwrap()];
        let ts = TenantSet::new(tenants.clone(), cost.clone());
        let mut plan = DeploymentPlan::unregulated(2);
        if v16_split.len() > 1 {
            for op in &tenants[0].ops {
                if op.chunkable()
                    && matches!(op.kind, OpKind::Conv { .. } | OpKind::ReLU { .. })
                {
                    plan.chunking[0].insert(op.id, v16_split.clone());
                }
            }
        }
        if r18_split.len() > 1 {
            for op in &tenants[1].ops {
                if op.chunkable()
                    && matches!(op.kind, OpKind::Conv { .. } | OpKind::ReLU { .. })
                {
                    plan.chunking[1].insert(op.id, r18_split.clone());
                }
            }
        }
        let out = ts.simulate(&plan, opts);
        println!("{label:<28} {:>8.2} ms", out.makespan_us / 1e3);
    }
}

/// Table 4: search wall-time vs rounds.
pub fn table4(base_rounds: usize) {
    println!("== Table 4: GACER search overhead ==");
    let platform = Platform::titan_v();
    let combos =
        [["R34", "V16", "LSTM"], ["R50", "V16", "M3"], ["R34", "LSTM", "BST"]];
    let round_settings = [100usize, 500, 1000, 2000, 10000];
    print!("{:<16}", "combo");
    for r in round_settings {
        print!(" {r:>10}");
    }
    println!("   (simulator-evaluation budget)");
    for combo in combos {
        let cost = CostModel::new(platform);
        let tenants = zoo::build_combo(&combo);
        let ts = TenantSet::new(tenants.clone(), cost.clone());
        print!("{:<16}", zoo::combo_label(&combo));
        for rounds in round_settings {
            let cfg = SearchConfig {
                rounds_per_level: (rounds / 100).max(base_rounds),
                positions_per_coordinate: 12,
                ..SearchConfig::default()
            };
            let t0 = std::time::Instant::now();
            let mut evals = 0usize;
            // Re-run the search until the evaluation budget is met
            // (models repeated offline searches).
            while evals < rounds {
                let r = GacerSearch::new(
                    &ts,
                    SimOptions::for_platform(&platform),
                    cfg,
                )
                .run();
                evals += r.evaluations;
            }
            print!(" {:>9.2}s", t0.elapsed().as_secs_f64());
        }
        println!();
    }
}

/// Placement objectives: LoadBalance vs InterferenceAware over
/// heterogeneous tenant mixes on 2 devices (decision-level comparison —
/// per-device load, predicted co-location slowdown, and the max
/// `load × slowdown` score each objective commits to).
pub fn placement_objectives() {
    println!(
        "== Placement objectives: LoadBalance vs InterferenceAware vs MemoryAware \
         (2 devices) =="
    );
    let platform = Platform::titan_v();
    let mixes: Vec<(&str, Vec<Dfg>)> = vec![
        // The canonical disagreement: two pool-saturating tenants whose
        // serial weights trick LPT into pairing them.
        ("2 saturating + 2 bandwidth-light", interference_demo_mix(&platform)),
        // Heterogeneous zoo mixes: large-batch vision tenants saturate,
        // the mobile/sequence tenants keep the occupancy spread wide.
        (
            "V16(32)+R18(32)+M3+LSTM",
            vec![
                zoo::build("V16", 32).unwrap(),
                zoo::build("R18", 32).unwrap(),
                zoo::build_default("M3").unwrap(),
                zoo::build_default("LSTM").unwrap(),
            ],
        ),
        ("R50+V16+M3+Alex", zoo::build_combo(&["R50", "V16", "M3", "Alex"])),
        (
            "R101(16)+D121(16)+M3+BST",
            vec![
                zoo::build("R101", 16).unwrap(),
                zoo::build("D121", 16).unwrap(),
                zoo::build_default("M3").unwrap(),
                zoo::build_default("BST").unwrap(),
            ],
        ),
    ];
    for (label, tenants) in mixes {
        println!("-- {label}");
        let arms = compare_placements(tenants, &platform, 2);
        for arm in &arms {
            println!(
                "  {:<17} max score {:>8.2} ms  (max load {:>8.2} ms, max slowdown {:.2}x)",
                arm.objective.label(),
                arm.max_score_ms,
                arm.max_load_ms(),
                arm.max_slowdown()
            );
            for (d, tenants) in arm.per_device.iter().enumerate() {
                println!(
                    "      device {d}: {tenants:?}  load {:.2} ms, slowdown {:.2}x",
                    arm.loads_ms[d], arm.slowdowns[d]
                );
            }
        }
        let (lb, ia) = (&arms[0], &arms[1]);
        println!(
            "  => interference-aware lowers the predicted bottleneck score by {:.1}% \
             (slowdown {:.2}x -> {:.2}x)",
            (1.0 - ia.max_score_ms / lb.max_score_ms.max(f64::MIN_POSITIVE)) * 100.0,
            lb.max_slowdown(),
            ia.max_slowdown()
        );
    }
}

/// `gacer-bench memory` — memory-bandwidth contention as a second cost
/// dimension (docs/BENCHMARKS.md): on a bandwidth-bound mix
/// ([`memory_demo_mix`]: two HBM-saturating BatchNorm tenants + two
/// low-bandwidth conv fillers) every memory-blind objective — LPT *and*
/// the occupancy-only interference objective — pairs the hogs, while
/// the two-dimensional roofline ([`PlacementObjective::MemoryAware`])
/// separates them. Each arm's committed placement is then simulated
/// per device (the simulator prices bandwidth oversubscription via
/// `r_mem`), and the contrast — predicted roofline slowdown, simulated
/// cluster makespan, per-device HBM residency — is recorded in
/// `BENCH_memory.json`.
///
/// [`PlacementObjective::MemoryAware`]:
///     crate::plan::PlacementObjective::MemoryAware
pub fn memory() {
    use crate::util::json::Json;
    use std::collections::BTreeMap;

    println!(
        "== Memory: bandwidth-bound placement, occupancy-only vs memory-aware \
         (Titan V, 2 devices) =="
    );
    let platform = Platform::titan_v();
    let mix = memory_demo_mix(&platform);
    let arms = compare_placements(mix.clone(), &platform, 2);
    let cost = CostModel::new(platform);
    let opts = SimOptions::for_platform(&platform);

    // Simulated cluster makespan of each committed placement: every
    // device's tenant group runs unregulated on its own simulated GPU;
    // the cluster finishes with its bottleneck device.
    let simulate_arm = |arm: &PlacementArm| -> Vec<f64> {
        arm.per_device
            .iter()
            .map(|names| {
                if names.is_empty() {
                    return 0.0;
                }
                let tenants: Vec<Dfg> = names
                    .iter()
                    .map(|n| {
                        mix.iter().find(|d| &d.name == n).expect("mix tenant").clone()
                    })
                    .collect();
                let n = tenants.len();
                let ts = TenantSet::new(tenants, cost.clone());
                ts.simulate(&DeploymentPlan::unregulated(n), opts).makespan_us / 1e3
            })
            .collect()
    };

    let mut sim_ms: Vec<Vec<f64>> = Vec::new();
    for arm in &arms {
        let per_device = simulate_arm(arm);
        let cluster = per_device.iter().copied().fold(0.0f64, f64::max);
        println!(
            "{:<17} roofline slowdown {:.2}x (occupancy-only sees {:.2}x)  \
             simulated cluster {:.2} ms",
            arm.objective.label(),
            arm.max_slowdown(),
            arm.max_occupancy_slowdown(),
            cluster
        );
        for (d, tenants) in arm.per_device.iter().enumerate() {
            println!(
                "    device {d}: {tenants:?}  load {:.2} ms, slowdown {:.2}x, \
                 HBM {:.2} GB, simulated {:.2} ms",
                arm.loads_ms[d], arm.slowdowns[d], arm.hbm_gb[d], per_device[d]
            );
        }
        sim_ms.push(per_device);
    }

    let cluster = |i: usize| sim_ms[i].iter().copied().fold(0.0f64, f64::max);
    let (ia, ma) = (&arms[1], &arms[2]);
    println!(
        "\n=> memory-aware placement cuts the predicted bottleneck slowdown \
         {:.2}x -> {:.2}x and the simulated cluster makespan {:.2} ms -> {:.2} ms \
         on a mix the occupancy axis prices as contention-free",
        ia.max_slowdown(),
        ma.max_slowdown(),
        cluster(1),
        cluster(2)
    );
    assert!(
        ma.max_slowdown() < ia.max_slowdown(),
        "memory-aware must strictly reduce the predicted max slowdown"
    );

    let arm_json = |arm: &PlacementArm, per_device: &[f64]| {
        let mut m = BTreeMap::new();
        m.insert("objective".to_string(), Json::Str(arm.objective.label().to_string()));
        m.insert(
            "per_device".to_string(),
            Json::Arr(
                arm.per_device
                    .iter()
                    .map(|names| {
                        Json::Arr(
                            names.iter().map(|n| Json::Str(n.clone())).collect(),
                        )
                    })
                    .collect(),
            ),
        );
        let nums = |v: &[f64]| Json::Arr(v.iter().map(|&x| Json::Num(x)).collect());
        m.insert("loads_ms".to_string(), nums(&arm.loads_ms));
        m.insert("roofline_slowdowns".to_string(), nums(&arm.slowdowns));
        m.insert("occupancy_slowdowns".to_string(), nums(&arm.occupancy_slowdowns));
        m.insert("hbm_gb".to_string(), nums(&arm.hbm_gb));
        m.insert("simulated_ms".to_string(), nums(per_device));
        m.insert(
            "simulated_cluster_ms".to_string(),
            Json::Num(per_device.iter().copied().fold(0.0f64, f64::max)),
        );
        m.insert("max_roofline_slowdown".to_string(), Json::Num(arm.max_slowdown()));
        m.insert(
            "max_occupancy_slowdown".to_string(),
            Json::Num(arm.max_occupancy_slowdown()),
        );
        Json::Obj(m)
    };
    let mut headline = BTreeMap::new();
    headline.insert(
        "occupancy_only_max_slowdown".to_string(),
        Json::Num(ia.max_slowdown()),
    );
    headline.insert(
        "memory_aware_max_slowdown".to_string(),
        Json::Num(ma.max_slowdown()),
    );
    headline.insert(
        "memory_aware_strictly_better".to_string(),
        Json::Bool(ma.max_slowdown() < ia.max_slowdown()),
    );
    headline.insert(
        "occupancy_only_simulated_cluster_ms".to_string(),
        Json::Num(cluster(1)),
    );
    headline.insert(
        "memory_aware_simulated_cluster_ms".to_string(),
        Json::Num(cluster(2)),
    );
    headline.insert(
        "simulated_makespan_reduced".to_string(),
        Json::Bool(cluster(2) < cluster(1)),
    );
    let mut root = BTreeMap::new();
    root.insert("experiment".to_string(), Json::Str("memory".to_string()));
    root.insert("platform".to_string(), Json::Str(platform.name.to_string()));
    root.insert("devices".to_string(), Json::Num(2.0));
    root.insert(
        "tenants".to_string(),
        Json::Arr(mix.iter().map(|d| Json::Str(d.name.clone())).collect()),
    );
    root.insert(
        "arms".to_string(),
        Json::Arr(
            arms.iter().zip(&sim_ms).map(|(a, s)| arm_json(a, s)).collect(),
        ),
    );
    root.insert("headline".to_string(), Json::Obj(headline));
    let json = Json::Obj(root).to_string_compact();
    match std::fs::write("BENCH_memory.json", &json) {
        Ok(()) => println!("wrote BENCH_memory.json ({} bytes)", json.len()),
        Err(e) => eprintln!("could not write BENCH_memory.json: {e}"),
    }
}

/// Re-plan latency & plan quality vs budget, cold vs warm — the
/// budgeted anytime re-search experiment (`docs/SEARCH.md`): an
/// 8-tenant deployment admits a 9th tenant, and the admit re-search runs
/// cold (Algorithm 1 from scratch on the grown set) and warm-started
/// from the deployment's [`crate::search::SearchState`] under a sweep of
/// evaluation budgets. Demonstrates (a) warm admit re-search evaluates
/// far fewer candidates than cold for comparable final plan quality, and
/// (b) under any eval budget the returned plan is never worse than the
/// inherited seed, with truncation correctly flagged.
pub fn replan() {
    println!("== Re-plan: budgeted anytime re-search, cold vs warm (Titan V) ==");
    let platform = Platform::titan_v();
    let base = ["R50", "V16", "M3", "Alex", "R18", "R34", "LSTM", "BST"];
    let budgets = [
        SearchBudget::evaluations(50),
        SearchBudget::evaluations(200),
        SearchBudget::evaluations(1000),
        SearchBudget::unbounded(),
    ];
    let (seed_obj, cold, warm) =
        run_replan(&base, "D121", &platform, SearchConfig::default(), &budgets);
    println!(
        "8-tenant deployment ({}) admits D121; inherited seed objective {seed_obj:.0}",
        base.join("+")
    );
    println!(
        "{:<24} {:>8} {:>14} {:>9} {:>10} {:>10} {:>12}",
        "arm", "evals", "objective", "vs seed", "truncated", "warm hits", "elapsed"
    );
    let row = |c: &ReplanCell| {
        println!(
            "{:<24} {:>8} {:>14.0} {:>9} {:>10} {:>10} {:>10.1}ms",
            c.label,
            c.evaluations,
            c.objective,
            format!("{:.3}x", c.objective / seed_obj),
            if c.truncated { "yes" } else { "no" },
            c.warm_hits,
            c.elapsed_ms
        );
    };
    row(&cold);
    for c in &warm {
        row(c);
        let ok = c.objective <= seed_obj * (1.0 + 1e-9);
        assert!(ok, "anytime guarantee violated: {} > seed {seed_obj}", c.objective);
    }
    let full = warm.last().expect("unbounded arm");
    println!(
        "\n=> warm admit re-search: {:.1}x fewer evaluations than cold \
         ({} vs {}), final objective {:.1}% of cold's; every budgeted arm \
         stayed at or below the inherited seed (anytime guarantee)",
        cold.evaluations as f64 / full.evaluations.max(1) as f64,
        full.evaluations,
        cold.evaluations,
        full.objective / cold.objective * 100.0
    );
}

/// Ablation: calibration-constant sensitivity (DESIGN.md §2).
///
/// The substitute substrate has two free contention constants (α:
/// oversubscription waste, β: per-kernel friction). The paper-shape
/// conclusions must not hinge on their exact values: this sweep re-runs
/// the Fig. 7 headline comparison (CuDNN-Seq vs Stream-Parallel vs GACER
/// on R50+V16+M3) across a grid and reports whether the ordering
/// Seq > SP > GACER (in latency) survives every cell.
pub fn ablation_sensitivity() {
    use crate::baselines::{Baseline, BaselineKind};
    use crate::plan::TenantSet as TS;

    println!("== Ablation: contention-constant sensitivity (R50+V16+M3, Titan V) ==");
    println!(
        "{:<8} {:<8} {:>12} {:>12} {:>12} {:>10}",
        "alpha", "beta", "Seq (ms)", "SP (ms)", "GACER (ms)", "ordering"
    );
    let platform = Platform::titan_v();
    let mut all_hold = true;
    for alpha in [0.10, 0.25, 0.40] {
        for beta in [0.0, 0.08, 0.16] {
            let cost = CostModel::new(platform);
            let tenants = zoo::build_combo(&["R50", "V16", "M3"]);
            let ts = TS::new(tenants.clone(), cost.clone());
            let mut opts = SimOptions::for_platform(&platform);
            opts.contention_alpha = alpha;
            opts.kernel_beta = beta;
            let b = Baseline::new(&ts, opts);
            let seq = b.run(BaselineKind::CudnnSeq).makespan_us / 1e3;
            let sp = b.run(BaselineKind::StreamParallel).makespan_us / 1e3;
            let gacer = GacerSearch::new(&ts, opts, cfg()).run().outcome.makespan_us / 1e3;
            let holds = seq > sp && sp > gacer;
            all_hold &= holds;
            println!(
                "{alpha:<8} {beta:<8} {seq:>12.2} {sp:>12.2} {gacer:>12.2} {:>10}",
                if holds { "holds" } else { "BROKEN" }
            );
        }
    }
    println!(
        "\nconclusion: Seq > Stream-Parallel > GACER {} across the grid",
        if all_hold { "HOLDS" } else { "does NOT hold" }
    );
}

/// `gacer-bench slo` — SLO-driven regulation on a saturated two-device
/// cluster (docs/SLO.md): one interactive tenant co-resident with batch
/// tenants whose combined demand exceeds device capacity. The regulated
/// arm issues tier-major and sheds over-cap batch arrivals; the
/// unregulated arm is fair round-robin with unbounded queues. The
/// interactive p99 holds its target only under regulation; both arms are
/// recorded in `BENCH_slo.json`.
pub fn slo() {
    use super::slo_sim::{
        run_slo_sim, saturated_mix, slo_report_json, SloSimConfig, SloSimOutcome,
    };

    let cfg = SloSimConfig::default();
    println!(
        "== SLO: interactive p99 under saturation ({} rounds, {} req/round/device, \
         target p99 {:.1}ms) ==",
        cfg.rounds,
        cfg.capacity_per_round,
        cfg.target.target_us / 1e3
    );
    let mix = saturated_mix();
    let arms = [
        ("slo-regulated", run_slo_sim(&mix, &cfg, true)),
        ("unregulated", run_slo_sim(&mix, &cfg, false)),
    ];
    for (label, out) in &arms {
        println!("{label}:");
        println!(
            "  {:<12} {:>3} {:>11} {:>7} {:>6} {:>9} {:>9} {:>9}  {}",
            "tenant", "dev", "tier", "served", "shed", "p50(us)", "p99(us)", "max(us)",
            "health"
        );
        for t in &out.tenants {
            println!(
                "  {:<12} {:>3} {:>11} {:>7} {:>6} {:>9.0} {:>9.0} {:>9.0}  {}",
                t.name,
                t.device,
                t.tier.label(),
                t.served,
                t.shed,
                t.latency.p50_us,
                t.latency.p99_us,
                t.latency.max_us,
                t.pressure.map_or("-", |p| p.health.label())
            );
        }
    }
    let p99 = |o: &SloSimOutcome| o.interactive_p99_us();
    let (reg, unreg) = (&arms[0].1, &arms[1].1);
    println!(
        "interactive p99: {:.0}us regulated vs {:.0}us unregulated (target {:.0}us)",
        p99(reg),
        p99(unreg),
        cfg.target.target_us
    );
    assert!(
        p99(reg) <= cfg.target.target_us,
        "regulated interactive p99 must hold the target"
    );
    assert!(
        p99(unreg) > cfg.target.target_us,
        "unregulated interactive p99 must violate the target"
    );
    let json = slo_report_json(&cfg, reg, unreg).to_string_compact();
    match std::fs::write("BENCH_slo.json", &json) {
        Ok(()) => println!("wrote BENCH_slo.json ({} bytes)", json.len()),
        Err(e) => eprintln!("could not write BENCH_slo.json: {e}"),
    }
}

/// `gacer-bench throughput` — request-path throughput under open-loop
/// load (docs/BENCHMARKS.md): sweep offered rates through the load
/// generator against a synthetic-backend cluster, once per
/// [`CompletionMode`] arm, and record achieved throughput, p50/p99
/// latency, and shed rate per point in `BENCH_throughput.json`. With
/// `--min-throughput R`, exits non-zero if the batched arm fails to
/// achieve `R` req/s at the highest offered rate — the CI smoke floor.
///
/// [`CompletionMode`]: crate::coordinator::CompletionMode
pub fn throughput(args: &crate::util::cli::Args) {
    use super::loadgen::{run_loadgen, LoadgenOptions, LoadgenReport, TraceShape};
    use crate::coordinator::CompletionMode;
    use crate::util::json::Json;
    use std::collections::BTreeMap;

    let opt_f64 = |key: &str, default: f64| {
        args.opt(key).and_then(|v| v.parse::<f64>().ok()).unwrap_or(default)
    };
    let duration_ms = opt_f64("duration-ms", 800.0);
    let seed = args.opt_usize("seed", 7) as u64;
    let n_tenants = args.opt_usize("tenants", 4).max(1);
    let queue_cap = args.opt_usize("queue-cap", 0);
    let submitters = args.opt_usize("submitters", 4);
    let trace = args.opt_or("trace", "poisson").to_string();
    let min_throughput = opt_f64("min-throughput", 0.0);
    let rates: Vec<f64> = args
        .opt_or("rates", "2000,8000,20000")
        .split(',')
        .filter_map(|r| r.trim().parse::<f64>().ok())
        .filter(|&r| r > 0.0)
        .collect();
    if rates.is_empty() {
        eprintln!("--rates must name at least one positive req/s value");
        std::process::exit(2);
    }
    if TraceShape::parse(&trace, 1.0).is_none() {
        eprintln!("unknown trace shape {trace:?} (poisson|bursty|diurnal)");
        std::process::exit(2);
    }

    println!(
        "== Throughput: open-loop {trace} sweep, {n_tenants} tenants, {duration_ms:.0}ms \
         per point, per-request vs batched completions =="
    );
    let run_point = |mode: CompletionMode, rate: f64| -> LoadgenReport {
        let shape = TraceShape::parse(&trace, rate).expect("validated above");
        run_loadgen(&LoadgenOptions {
            n_tenants,
            duration_ms,
            shape,
            seed,
            queue_cap,
            mode,
            submitters,
            ..LoadgenOptions::default()
        })
        .expect("synthetic loadgen run")
    };
    println!(
        "{:<12} {:>10} {:>10} {:>9} {:>9} {:>9} {:>7}",
        "arm", "offered", "achieved", "p50(us)", "p99(us)", "max(us)", "shed%"
    );
    let mut arms: Vec<(CompletionMode, Vec<LoadgenReport>)> = Vec::new();
    for mode in [CompletionMode::PerRequest, CompletionMode::Batched] {
        let mut points = Vec::with_capacity(rates.len());
        for &rate in &rates {
            let r = run_point(mode, rate);
            println!(
                "{:<12} {:>10.0} {:>10.0} {:>9.0} {:>9.0} {:>9.0} {:>7.2}",
                mode.label(),
                r.offered_rps,
                r.achieved_rps(),
                r.latency.p50_us,
                r.latency.p99_us,
                r.latency.max_us,
                r.shed_rate() * 100.0
            );
            points.push(r);
        }
        arms.push((mode, points));
    }

    // Headline: both arms at the highest offered rate.
    let last = |mode: CompletionMode| -> &LoadgenReport {
        &arms.iter().find(|(m, _)| *m == mode).expect("both arms ran").1[rates.len() - 1]
    };
    let (pr, ba) = (last(CompletionMode::PerRequest), last(CompletionMode::Batched));
    println!(
        "at {:.0} req/s offered: batched {:.0} req/s p99 {:.0}us vs per-request {:.0} req/s \
         p99 {:.0}us",
        ba.offered_rps,
        ba.achieved_rps(),
        ba.latency.p99_us,
        pr.achieved_rps(),
        pr.latency.p99_us
    );

    let arm_json = |points: &[LoadgenReport]| {
        Json::Arr(
            points
                .iter()
                .map(|r| {
                    let mut m = BTreeMap::new();
                    m.insert("offered_rps".to_string(), Json::Num(r.offered_rps));
                    m.insert("achieved_rps".to_string(), Json::Num(r.achieved_rps()));
                    m.insert("submitted".to_string(), Json::Num(r.submitted as f64));
                    m.insert("completed".to_string(), Json::Num(r.completed as f64));
                    m.insert("shed".to_string(), Json::Num(r.shed as f64));
                    m.insert("errors".to_string(), Json::Num(r.errors as f64));
                    m.insert("shed_rate".to_string(), Json::Num(r.shed_rate()));
                    m.insert("p50_us".to_string(), Json::Num(r.latency.p50_us));
                    m.insert("p99_us".to_string(), Json::Num(r.latency.p99_us));
                    m.insert("max_us".to_string(), Json::Num(r.latency.max_us));
                    m.insert(
                        "elapsed_ms".to_string(),
                        Json::Num(r.elapsed.as_secs_f64() * 1e3),
                    );
                    Json::Obj(m)
                })
                .collect(),
        )
    };
    let mut headline = BTreeMap::new();
    headline.insert("offered_rps".to_string(), Json::Num(ba.offered_rps));
    headline.insert("batched_rps".to_string(), Json::Num(ba.achieved_rps()));
    headline.insert("per_request_rps".to_string(), Json::Num(pr.achieved_rps()));
    headline.insert("batched_p99_us".to_string(), Json::Num(ba.latency.p99_us));
    headline.insert("per_request_p99_us".to_string(), Json::Num(pr.latency.p99_us));
    headline.insert(
        "batched_sustains_higher_throughput".to_string(),
        Json::Bool(ba.achieved_rps() >= pr.achieved_rps()),
    );
    // 10% slack: wall-clock p99 on shared CI hardware jitters; the claim
    // is "no worse", not "identical to the microsecond".
    headline.insert(
        "batched_p99_no_worse".to_string(),
        Json::Bool(ba.latency.p99_us <= pr.latency.p99_us * 1.10),
    );
    let mut root = BTreeMap::new();
    root.insert("experiment".to_string(), Json::Str("throughput".to_string()));
    root.insert("trace".to_string(), Json::Str(trace));
    root.insert("seed".to_string(), Json::Num(seed as f64));
    root.insert("tenants".to_string(), Json::Num(n_tenants as f64));
    root.insert("duration_ms".to_string(), Json::Num(duration_ms));
    root.insert("queue_cap".to_string(), Json::Num(queue_cap as f64));
    root.insert("offered_rps".to_string(), Json::Arr(rates.iter().map(|&r| Json::Num(r)).collect()));
    for (mode, points) in &arms {
        let key = match mode {
            CompletionMode::Batched => "batched",
            CompletionMode::PerRequest => "per_request",
        };
        root.insert(key.to_string(), arm_json(points));
    }
    root.insert("headline".to_string(), Json::Obj(headline));
    let json = Json::Obj(root).to_string_compact();
    match std::fs::write("BENCH_throughput.json", &json) {
        Ok(()) => println!("wrote BENCH_throughput.json ({} bytes)", json.len()),
        Err(e) => eprintln!("could not write BENCH_throughput.json: {e}"),
    }

    if min_throughput > 0.0 && ba.achieved_rps() < min_throughput {
        eprintln!(
            "FAIL: batched arm achieved {:.0} req/s, below the --min-throughput floor {:.0}",
            ba.achieved_rps(),
            min_throughput
        );
        std::process::exit(1);
    }
    if min_throughput > 0.0 {
        println!(
            "floor: batched {:.0} req/s >= {:.0} req/s required",
            ba.achieved_rps(),
            min_throughput
        );
    }
}

/// `gacer-bench elastic`: heterogeneous elastic device pools, end to
/// end — the three layers the pool refactor touches.
///
/// 1. **Placement** (`BENCH_elastic.json` headline): on a mixed
///    A100 + T4 pool the pool-aware interference objective must beat a
///    homogeneous-assumption placement (both devices priced as the
///    reference A100) — strictly lower bottleneck slowdown when both
///    placements are re-priced with each device's *true* cost model.
/// 2. **Planner**: a live [`crate::engine::GacerEngine`] on the mixed
///    pool scales out (`add_device` re-shards warm onto the joiner) and
///    back in (`remove_device` drains the retiree's tenants to
///    capacity-feasible survivors), with every intermediate plan valid.
/// 3. **Serving**: a synthetic-backend [`ClusterServer`] rides a
///    diurnal autoscale timeline — 1 → 2 → 3 → 2 → 1 devices, four
///    scale events matched by stable device id — under closed-loop
///    client fire. Every submission must be answered with its own
///    echoed marker and its own tenant's tag: nothing lost, duplicated
///    or misrouted across any scale event.
pub fn elastic() {
    use crate::coordinator::{
        name_tag, BatchPolicy, ClusterServer, ServerBackend, ServerConfig,
        SyntheticModel, TenantSpec,
    };
    use crate::engine::{Deployment, GacerEngine, ShardedDeployment};
    use crate::plan::{Placement, PlacementObjective};
    use crate::profile::{DeviceId, DevicePool};
    use crate::util::json::Json;
    use std::collections::BTreeMap;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;
    use std::time::Duration;

    // ---- 1. Heterogeneity-aware placement on an A100 + T4 pool. ----
    let (a100, t4) = (Platform::a100(), Platform::t4());
    let pool = DevicePool::from_platforms([a100, t4]);
    println!("== Elastic: heterogeneous pools + diurnal autoscale ({}) ==", pool.label());
    let mix = hetero_demo_mix();
    let tenant_names: Vec<String> = mix.iter().map(|d| d.name.clone()).collect();
    let set = TenantSet::new(mix, CostModel::new(a100));
    let aware =
        Placement::with_objective_pool(&set, &pool, PlacementObjective::InterferenceAware);
    let blind = Placement::with_objective(&set, 2, PlacementObjective::InterferenceAware);
    aware.validate(set.len()).unwrap();
    blind.validate(set.len()).unwrap();

    let names_on = |p: &Placement, d: usize| -> Vec<String> {
        p.tenants_on(d).iter().map(|&s| tenant_names[s].clone()).collect()
    };
    let fmax = |v: &[f64]| v.iter().copied().fold(0.0f64, f64::max);
    let arms = [("pool-aware", &aware), ("homogeneous-assumption", &blind)];
    for (label, p) in arms {
        // Both placements are priced with each device's TRUE cost model
        // — the blind arm committed to its split believing both devices
        // were the reference A100.
        let slow = p.predicted_slowdowns_pool(&set, &pool);
        println!("{label:<23} true bottleneck slowdown {:.2}x", fmax(&slow));
        for d in 0..pool.len() {
            println!(
                "    {} ({}): {:?}  slowdown {:.2}x",
                pool.id(d),
                pool.platform(d).name,
                names_on(p, d),
                slow[d]
            );
        }
    }
    let aware_slow = aware.predicted_slowdowns_pool(&set, &pool);
    let blind_slow = blind.predicted_slowdowns_pool(&set, &pool);
    let (aware_max, blind_max) = (fmax(&aware_slow), fmax(&blind_slow));
    println!(
        "=> heterogeneity-aware placement cuts the true bottleneck slowdown \
         {blind_max:.2}x -> {aware_max:.2}x on {}",
        pool.label()
    );
    assert!(
        aware_max < blind_max,
        "pool-aware ({aware_max}x) must strictly beat the homogeneous \
         assumption ({blind_max}x) on a mixed pool"
    );

    // ---- 2. Planner-level scale-out / scale-in on the live engine. ----
    let quick = SearchConfig {
        max_pointers: 2,
        rounds_per_level: 1,
        positions_per_coordinate: 6,
        spatial_steps_per_level: 2,
        ..Default::default()
    };
    let mut engine = GacerEngine::builder()
        .device_pool(vec![a100, t4])
        .search(quick)
        .tenant(zoo::build_default("R50").unwrap())
        .tenant(zoo::build_default("R18").unwrap())
        .tenant(zoo::build_default("M3").unwrap())
        .tenant(zoo::build_default("V16").unwrap())
        .build()
        .unwrap();
    let pool_start = engine.device_pool().label();
    let joined = engine.add_device(Platform::t4());
    engine.sharded_plan().validate(engine.tenants()).unwrap();
    let pool_grown = engine.device_pool().label();
    println!(
        "engine scale-out: {pool_start} -> {pool_grown} ({joined} joined, warm re-shard)"
    );
    let retiree = DeviceId(1);
    let drained = engine.remove_device(retiree).unwrap();
    engine.sharded_plan().validate(engine.tenants()).unwrap();
    assert_eq!(engine.tenant_ids().len(), 4, "drain loses no tenant");
    assert!(drained.iter().all(|m| m.from == retiree));
    let pool_shrunk = engine.device_pool().label();
    println!(
        "engine scale-in:  {pool_grown} -> {pool_shrunk} ({retiree} retired, \
         {} tenant(s) drained)",
        drained.len()
    );

    // ---- 3. Serving-path diurnal autoscale under closed-loop fire. ----
    let tenant = |name: &str| TenantSpec {
        name: name.to_string(),
        family: "synthetic".to_string(),
        policy: BatchPolicy::new(8, Duration::from_micros(300), vec![1, 2, 4, 8]),
        chunk: None,
    };
    let dep = |names: &[&str]| Deployment {
        tenants: names.iter().map(|n| tenant(n)).collect(),
        config: ServerConfig::default(),
    };
    let ids = |v: &[u64]| -> Vec<DeviceId> { v.iter().map(|&n| DeviceId(n)).collect() };
    // Global tenant slots stay [a, b, c, d] throughout; only the device
    // set under them breathes. Stable ids mean gpu1's [c, d] shard is
    // carried untouched across the stage-3 retirement of gpu0 even
    // though its dense position shifts.
    let stages: Vec<(&str, ShardedDeployment)> = vec![
        (
            "night start: 1 device",
            ShardedDeployment {
                per_device: vec![dep(&["a", "b", "c", "d"])],
                routing: vec![(0, 0), (0, 1), (0, 2), (0, 3)],
                device_ids: ids(&[0]),
            },
        ),
        (
            "morning ramp: gpu1 joins",
            ShardedDeployment {
                per_device: vec![dep(&["a", "b"]), dep(&["c", "d"])],
                routing: vec![(0, 0), (0, 1), (1, 0), (1, 1)],
                device_ids: ids(&[0, 1]),
            },
        ),
        (
            "midday peak: gpu2 joins",
            ShardedDeployment {
                per_device: vec![dep(&["a"]), dep(&["c", "d"]), dep(&["b"])],
                routing: vec![(0, 0), (2, 0), (1, 0), (1, 1)],
                device_ids: ids(&[0, 1, 2]),
            },
        ),
        (
            "evening: gpu0 retires",
            ShardedDeployment {
                per_device: vec![dep(&["c", "d"]), dep(&["b", "a"])],
                routing: vec![(1, 1), (1, 0), (0, 0), (0, 1)],
                device_ids: ids(&[1, 2]),
            },
        ),
        (
            "night: gpu1 retires",
            ShardedDeployment {
                per_device: vec![dep(&["b", "a", "c", "d"])],
                routing: vec![(0, 1), (0, 0), (0, 2), (0, 3)],
                device_ids: ids(&[2]),
            },
        ),
    ];
    let mut stages = stages.into_iter();
    let (start_label, start) = stages.next().expect("timeline has a start");
    println!("serving timeline: {start_label}");
    let cluster = ClusterServer::start_sharded_with_backend(
        ServerBackend::Synthetic(SyntheticModel::echo()),
        start,
    )
    .unwrap();

    let stop = Arc::new(AtomicBool::new(false));
    let mut producers = Vec::new();
    for (slot, name) in ["a", "b", "c", "d"].iter().enumerate() {
        let cluster = cluster.clone();
        let stop = Arc::clone(&stop);
        let expected_tag = name_tag(name);
        producers.push(std::thread::spawn(move || -> (u64, u64) {
            let (mut oks, mut i) = (0u64, 0u64);
            while !stop.load(Ordering::Relaxed) {
                // Unique marker, exact in f32 (stays far below 2^24).
                let marker = (i % 1_000_000) as f32;
                i += 1;
                let out = cluster.infer(slot, vec![marker, 0.0]).unwrap_or_else(|e| {
                    panic!("tenant {slot} request {i} failed mid-scale: {e:?}")
                });
                assert_eq!(out[0], marker, "response paired with the wrong request");
                assert_eq!(out[1], expected_tag, "response served by the wrong tenant");
                oks += 1;
            }
            (oks, i)
        }));
    }

    let mut events: Vec<(String, usize, usize)> = Vec::new();
    for (label, plan) in stages {
        std::thread::sleep(Duration::from_millis(3));
        let devices = plan.per_device.len();
        let touched = cluster.apply(plan).unwrap();
        println!(
            "  scale event: {label} -> {devices} device(s), {} swapped",
            touched.len()
        );
        events.push((label.to_string(), devices, touched.len()));
    }
    std::thread::sleep(Duration::from_millis(3));
    stop.store(true, Ordering::Relaxed);

    let (mut submitted, mut completed) = (0u64, 0u64);
    for p in producers {
        let (oks, sent) = p.join().expect("producer panicked");
        assert_eq!(oks, sent, "closed loop: every submission answered Ok");
        assert!(oks > 0, "producer made progress across scale events");
        submitted += sent;
        completed += oks;
    }
    assert!(events.len() >= 4, "the diurnal timeline holds 4 scale events");
    assert_eq!(cluster.device_ids(), ids(&[2]), "only the night device survives");
    println!(
        "=> {submitted} submitted / {completed} completed across {} scale \
         events: 0 lost, 0 misrouted, 0 errors",
        events.len()
    );

    // ---- BENCH_elastic.json ----
    let nums = |v: &[f64]| Json::Arr(v.iter().map(|&x| Json::Num(x)).collect());
    let arm_json = |p: &Placement, slow: &[f64]| {
        let mut m = BTreeMap::new();
        m.insert(
            "per_device".to_string(),
            Json::Arr(
                (0..pool.len())
                    .map(|d| {
                        Json::Arr(
                            names_on(p, d).into_iter().map(Json::Str).collect(),
                        )
                    })
                    .collect(),
            ),
        );
        m.insert("true_slowdowns".to_string(), nums(slow));
        m.insert("max_true_slowdown".to_string(), Json::Num(fmax(slow)));
        Json::Obj(m)
    };
    let mut placement = BTreeMap::new();
    placement.insert("pool".to_string(), Json::Str(pool.label()));
    placement.insert(
        "tenants".to_string(),
        Json::Arr(tenant_names.iter().cloned().map(Json::Str).collect()),
    );
    placement.insert("pool_aware".to_string(), arm_json(&aware, &aware_slow));
    placement.insert(
        "homogeneous_assumption".to_string(),
        arm_json(&blind, &blind_slow),
    );
    placement.insert(
        "pool_aware_strictly_better".to_string(),
        Json::Bool(aware_max < blind_max),
    );
    let mut engine_json = BTreeMap::new();
    engine_json.insert("pool_start".to_string(), Json::Str(pool_start));
    engine_json.insert("pool_after_scale_out".to_string(), Json::Str(pool_grown));
    engine_json.insert("pool_after_scale_in".to_string(), Json::Str(pool_shrunk));
    engine_json.insert("joined".to_string(), Json::Str(joined.to_string()));
    engine_json.insert("retired".to_string(), Json::Str(retiree.to_string()));
    engine_json.insert("drained_tenants".to_string(), Json::Num(drained.len() as f64));
    let mut serving = BTreeMap::new();
    serving.insert(
        "stages".to_string(),
        Json::Arr(
            events
                .iter()
                .map(|(label, devices, touched)| {
                    let mut s = BTreeMap::new();
                    s.insert("label".to_string(), Json::Str(label.clone()));
                    s.insert("devices".to_string(), Json::Num(*devices as f64));
                    s.insert("swapped".to_string(), Json::Num(*touched as f64));
                    Json::Obj(s)
                })
                .collect(),
        ),
    );
    serving.insert("scale_events".to_string(), Json::Num(events.len() as f64));
    serving.insert("submitted".to_string(), Json::Num(submitted as f64));
    serving.insert("completed".to_string(), Json::Num(completed as f64));
    serving.insert("lost".to_string(), Json::Num((submitted - completed) as f64));
    serving.insert("errors".to_string(), Json::Num(0.0));
    let mut root = BTreeMap::new();
    root.insert("experiment".to_string(), Json::Str("elastic".to_string()));
    root.insert("placement".to_string(), Json::Obj(placement));
    root.insert("engine".to_string(), Json::Obj(engine_json));
    root.insert("serving".to_string(), Json::Obj(serving));
    let json = Json::Obj(root).to_string_compact();
    match std::fs::write("BENCH_elastic.json", &json) {
        Ok(()) => println!("wrote BENCH_elastic.json ({} bytes)", json.len()),
        Err(e) => eprintln!("could not write BENCH_elastic.json: {e}"),
    }
}

/// `gacer-bench calibration` — the predicted-vs-observed loop closed
/// end to end (docs/OPERATIONS.md §Calibration): four analytically
/// identical tenants on two devices, one of which really runs 6× its
/// predicted latency. The analytic arm balances 2+2 and can never see
/// the skew; the calibrated arm feeds served windows back through
/// [`crate::engine::GacerEngine::record_latencies`], the trust ramp
/// completes, the corrected weights trip the migration policy, and the
/// mispriced tenant is isolated — strictly improving the worst measured
/// per-tenant p99. A third check drives both engines with **zero**
/// observations and asserts every decision is bit-for-bit identical.
/// All three results are asserted here and written to
/// `BENCH_calibration.json`.
pub fn calibration() {
    use super::calibration_sim::{
        calibration_is_noop_without_observations, calibration_report_json,
        run_calibration_sim, CalibSimConfig,
    };

    let cfg = CalibSimConfig::calibrated();
    println!(
        "== Calibration: online correction of a {}x mispriced tenant \
         ({} warmup + {} measured windows, {} samples/window) ==",
        cfg.inflation, cfg.warmup_windows, cfg.measure_windows, cfg.samples_per_window
    );
    let arms = [
        ("calibrated", run_calibration_sim(&cfg)),
        ("analytic", run_calibration_sim(&CalibSimConfig::analytic())),
    ];
    for (label, out) in &arms {
        println!("{label}:");
        println!(
            "  {:<8} {:>3} {:>11} {:>11} {:>11} {:>11}",
            "tenant", "dev", "correction", "p50(us)", "p99(us)", "max(us)"
        );
        for t in &out.tenants {
            println!(
                "  {:<8} {:>3} {:>11.2} {:>11.0} {:>11.0} {:>11.0}",
                t.name,
                t.final_device,
                t.correction,
                t.latency.p50_us,
                t.latency.p99_us,
                t.latency.max_us
            );
        }
        match out.migrated_window {
            Some(w) => println!("  migrated at observe window {w}"),
            None => println!("  never migrated"),
        }
    }
    let (calibrated, analytic) = (&arms[0].1, &arms[1].1);
    println!(
        "worst tenant p99: {:.0}us calibrated vs {:.0}us analytic",
        calibrated.max_p99_us(),
        analytic.max_p99_us()
    );
    // Acceptance criterion 1: with calibration ON, the mispriced mix is
    // re-placed and the measured worst p99 strictly improves.
    assert!(
        calibrated.migrated_window.is_some() && calibrated.mis_isolated,
        "the calibrated arm must isolate the mispriced tenant"
    );
    assert_eq!(
        analytic.migrated_window, None,
        "the analytic arm must never see the skew"
    );
    assert!(
        calibrated.max_p99_us() < analytic.max_p99_us(),
        "calibrated worst p99 {} must strictly beat analytic {}",
        calibrated.max_p99_us(),
        analytic.max_p99_us()
    );
    // Acceptance criterion 2: with zero observations, every decision is
    // identical to the analytic path.
    let zero_obs_identical = calibration_is_noop_without_observations(4);
    assert!(
        zero_obs_identical,
        "an unobserved calibrator must change no decision"
    );
    println!("zero-observation decisions identical: {zero_obs_identical}");
    let json = calibration_report_json(&cfg, calibrated, analytic, zero_obs_identical)
        .to_string_compact();
    match std::fs::write("BENCH_calibration.json", &json) {
        Ok(()) => println!("wrote BENCH_calibration.json ({} bytes)", json.len()),
        Err(e) => eprintln!("could not write BENCH_calibration.json: {e}"),
    }
}
