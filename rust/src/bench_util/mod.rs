//! Shared harness for the paper-reproduction benches: runs every strategy
//! (baselines + GACER arms) on a combo/platform and formats paper-style
//! rows. `experiments` holds the per-table/figure drivers.

pub mod calibration_sim;
pub mod experiments;
pub mod loadgen;
pub mod slo_sim;

use crate::baselines::{Baseline, BaselineKind};
use crate::dfg::{Dfg, OpKind};
use crate::gpu::{SimOptions, SimOutcome};
use crate::models::zoo;
use crate::plan::{Placement, PlacementObjective, TenantSet};
use crate::profile::{CostModel, Platform};
use crate::search::{
    GacerSearch, SearchBudget, SearchConfig, SearchReport, SearchState, ShardedSearch,
};

/// Every strategy of Fig. 7.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Strategy {
    Baseline(BaselineKind),
    /// GACER spatial-regulation-only arm.
    Spatial,
    /// GACER temporal-regulation-only arm.
    Temporal,
    /// Full joint GACER.
    Gacer,
}

impl Strategy {
    pub fn label(&self) -> &'static str {
        match self {
            Strategy::Baseline(b) => b.label(),
            Strategy::Spatial => "Spatial",
            Strategy::Temporal => "Temporal",
            Strategy::Gacer => "GACER",
        }
    }

    /// The Fig. 7 series, in plot order.
    pub fn fig7_set() -> Vec<Strategy> {
        let mut v: Vec<Strategy> =
            BaselineKind::all().into_iter().map(Strategy::Baseline).collect();
        v.extend([Strategy::Spatial, Strategy::Temporal, Strategy::Gacer]);
        v
    }
}

/// One evaluated cell: strategy on combo on platform.
#[derive(Debug, Clone)]
pub struct EvalCell {
    pub strategy: Strategy,
    pub outcome: SimOutcome,
}

impl EvalCell {
    pub fn latency_ms(&self) -> f64 {
        self.outcome.makespan_us / 1e3
    }
}

/// Run one strategy on a combo/platform.
pub fn run_strategy(
    names: &[&str],
    platform: &Platform,
    strategy: Strategy,
    cfg: SearchConfig,
) -> EvalCell {
    let cost = CostModel::new(*platform);
    let tenants = zoo::build_combo(names);
    let ts = TenantSet::new(tenants.clone(), cost.clone());
    let opts = SimOptions::for_platform(platform);
    let outcome = match strategy {
        Strategy::Baseline(b) => Baseline::new(&ts, opts).run(b),
        Strategy::Spatial => {
            GacerSearch::new(&ts, opts, SearchConfig { enable_temporal: false, ..cfg })
                .run()
                .outcome
        }
        Strategy::Temporal => {
            GacerSearch::new(&ts, opts, SearchConfig { enable_spatial: false, ..cfg })
                .run()
                .outcome
        }
        Strategy::Gacer => GacerSearch::new(&ts, opts, cfg).run().outcome,
    };
    EvalCell { strategy, outcome }
}

/// Run the full Fig. 7 strategy set on one combo.
pub fn run_combo(names: &[&str], platform: &Platform, cfg: SearchConfig) -> Vec<EvalCell> {
    Strategy::fig7_set()
        .into_iter()
        .map(|s| run_strategy(names, platform, s, cfg))
        .collect()
}

/// One device of a multi-GPU scaling measurement: who was placed there
/// and how fast its searched shard runs.
#[derive(Debug, Clone)]
pub struct ShardCell {
    pub device: usize,
    pub tenants: Vec<String>,
    /// Searched makespan of this device's shard (0 for idle devices).
    pub makespan_ms: f64,
    /// Predicted co-location slowdown of the device's tenant group under
    /// the cost model's occupancy curves (1.0 = interference-free).
    pub predicted_slowdown: f64,
}

/// Run the sharded GACER search on a combo across `n_devices` and report
/// per-device makespans plus the cluster makespan (the bottleneck
/// device's) — the multi-GPU scaling axis: same tenants, more devices.
pub fn run_sharded(
    names: &[&str],
    platform: &Platform,
    n_devices: usize,
    cfg: SearchConfig,
) -> (Vec<ShardCell>, f64) {
    let tenants = zoo::build_combo(names);
    let ts = TenantSet::new(tenants.clone(), CostModel::new(*platform));
    let report =
        ShardedSearch::new(&ts, SimOptions::for_platform(platform), cfg).run(n_devices);
    let slowdowns = report.plan.placement.predicted_slowdowns(&ts);
    let cells = (0..n_devices)
        .map(|d| ShardCell {
            device: d,
            tenants: report
                .plan
                .placement
                .tenants_on(d)
                .iter()
                .map(|&s| tenants[s].name.clone())
                .collect(),
            makespan_ms: report.reports[d]
                .as_ref()
                .map_or(0.0, |r| r.outcome.makespan_us / 1e3),
            predicted_slowdown: slowdowns[d],
        })
        .collect();
    (cells, report.cluster_makespan_us() / 1e3)
}

/// One arm of a placement-objective comparison: how one objective shards
/// a tenant mix and what contention it predicts.
#[derive(Debug, Clone)]
pub struct PlacementArm {
    pub objective: PlacementObjective,
    /// Tenant names per device.
    pub per_device: Vec<Vec<String>>,
    /// Cost-model load per device (summed serial latency, ms).
    pub loads_ms: Vec<f64>,
    /// Predicted co-location slowdown per device on the full
    /// compute+memory roofline (1.0 = free).
    pub slowdowns: Vec<f64>,
    /// Predicted slowdown per device on the **occupancy axis only** —
    /// what the memory-blind models believe they committed to.
    pub occupancy_slowdowns: Vec<f64>,
    /// Resident HBM footprint per device (GB).
    pub hbm_gb: Vec<f64>,
    /// The interference objective's figure of merit: max per-device
    /// `load × slowdown` (ms).
    pub max_score_ms: f64,
}

impl PlacementArm {
    /// The bottleneck device's predicted roofline slowdown.
    pub fn max_slowdown(&self) -> f64 {
        self.slowdowns.iter().copied().fold(1.0, f64::max)
    }

    /// The bottleneck device's predicted occupancy-only slowdown.
    pub fn max_occupancy_slowdown(&self) -> f64 {
        self.occupancy_slowdowns.iter().copied().fold(1.0, f64::max)
    }

    /// The bottleneck device's raw load (ms).
    pub fn max_load_ms(&self) -> f64 {
        self.loads_ms.iter().copied().fold(0.0, f64::max)
    }
}

/// Compare every placement objective (LoadBalance, InterferenceAware,
/// MemoryAware) on one tenant mix: how each shards it across `n_devices`
/// and the contention each predicts — the decision-level comparison (no
/// per-shard search, so it is cheap enough to sweep mixes). Every arm
/// reports both the occupancy-only and the roofline slowdown, so
/// memory-blindness is visible as a gap between the two.
pub fn compare_placements(
    tenants: Vec<Dfg>,
    platform: &Platform,
    n_devices: usize,
) -> Vec<PlacementArm> {
    let set = TenantSet::new(tenants, CostModel::new(*platform));
    [
        PlacementObjective::LoadBalance,
        PlacementObjective::InterferenceAware,
        PlacementObjective::MemoryAware,
    ]
    .into_iter()
    .map(|objective| {
        let p = Placement::with_objective(&set, n_devices, objective);
        let scores = p.interference_scores(&set);
        PlacementArm {
            objective,
            per_device: (0..p.n_devices())
                .map(|d| {
                    p.tenants_on(d)
                        .iter()
                        .map(|&s| set.tenants[s].name.clone())
                        .collect()
                })
                .collect(),
            loads_ms: p.loads(&set).into_iter().map(|l| l / 1e3).collect(),
            slowdowns: p.predicted_slowdowns(&set),
            occupancy_slowdowns: p.predicted_occupancy_slowdowns(&set),
            hbm_gb: p.hbm_usage(&set).into_iter().map(|b| b / 1e9).collect(),
            max_score_ms: scores.into_iter().fold(0.0, f64::max) / 1e3,
        }
    })
    .collect()
}

/// A heterogeneous tenant mix on which the two placement objectives
/// disagree: two SM-pool-saturating tenants (`hi-a`, `hi-b`, batch-32
/// convs) whose serial weights trick plain LPT into co-locating them,
/// plus two low-occupancy tenants (`lo-a`, `lo-b`, batch-1 convs at
/// ~10% pool occupancy) that idle the other device's SMs. Op counts are
/// calibrated against the platform's cost model (weights ≈
/// `[4, 2.4, 2.2, 2] ×` one batch-32 conv), so the shape survives
/// calibration changes: LPT packs `hi-a` with `hi-b`; the
/// interference-aware objective keeps them apart.
pub fn interference_demo_mix(platform: &Platform) -> Vec<Dfg> {
    let cost = CostModel::new(*platform);
    let conv = OpKind::Conv { h: 56, w: 56, cin: 256, cout: 256, k: 3, stride: 1 };
    let d_hi = cost.cost_of(&conv, 32).duration_us;
    let d_lo = cost.cost_of(&conv, 1).duration_us;
    let net = |name: &str, batch: usize, n: usize| {
        let mut d = Dfg::new(name);
        for i in 0..n.max(1) {
            d.push(conv, batch, format!("conv{i}"));
        }
        d
    };
    vec![
        net("hi-a", 32, 4),
        net("lo-a", 1, (2.4 * d_hi / d_lo).round() as usize),
        net("lo-b", 1, (2.2 * d_hi / d_lo).round() as usize),
        net("hi-b", 32, 2),
    ]
}

/// A **bandwidth-bound** tenant mix on which even the occupancy-aware
/// objective fails: two HBM-saturating tenants (`hog-a`, `hog-b`,
/// batch-8 BatchNorm chains at ~96% of peak bandwidth but floor SM
/// occupancy) plus two low-bandwidth conv fillers (`lo-a`, `lo-b`,
/// batch-1 convs at <1% bandwidth). Serial weights are calibrated to
/// ≈ `[4, 2.8, 2.8, 2] × u`, so LPT pairs the hogs — and the
/// occupancy-only interference objective, seeing slowdown 1.0
/// everywhere (the hogs barely hold SMs), pairs them too. Only the
/// two-dimensional roofline ([`PlacementObjective::MemoryAware`])
/// prices the paired ~192% bandwidth demand and separates them.
pub fn memory_demo_mix(platform: &Platform) -> Vec<Dfg> {
    let cost = CostModel::new(*platform);
    let bn = OpKind::BatchNorm { elems: 56 * 56 * 256 };
    let conv = OpKind::Conv { h: 56, w: 56, cin: 256, cout: 256, k: 3, stride: 1 };
    let d_bn = cost.cost_of(&bn, 8).duration_us;
    let d_conv = cost.cost_of(&conv, 1).duration_us;
    let bn_net = |name: &str, n: usize| {
        let mut d = Dfg::new(name);
        for i in 0..n.max(1) {
            d.push(bn, 8, format!("bn{i}"));
        }
        d
    };
    let conv_net = |name: &str, n: usize| {
        let mut d = Dfg::new(name);
        for i in 0..n.max(1) {
            d.push(conv, 1, format!("conv{i}"));
        }
        d
    };
    let u = 12.0 * d_bn;
    vec![
        bn_net("hog-a", 48),
        conv_net("lo-a", (2.8 * u / d_conv).round().max(1.0) as usize),
        conv_net("lo-b", (2.8 * u / d_conv).round().max(1.0) as usize),
        bn_net("hog-b", 24),
    ]
}

/// A tenant mix that only a **heterogeneity-aware** placement prices
/// correctly on a mixed A100 + T4 pool: four batch-8 mid-network conv
/// chains (56×56×256, 3×3). The per-tenant SM demand of that conv class
/// is ~39% of an A100's 108-SM pool but ~78% of a T4's 40 SMs — so any
/// *pair* co-located on the T4 oversubscribes it (~156%) while the same
/// pair fits the A100 with headroom, and even a *trio* on the A100
/// (~117%) interferes less than a T4 pair. A homogeneous-assumption
/// placement that prices both devices as the reference A100 sees every
/// pair as contention-free and happily splits 2+2, parking a pair on
/// the T4; the pool-aware objective, pricing each device with its own
/// cost model, drains the T4 down to one tenant. Op counts are unequal
/// (24..=48) so the LPT orderings are deterministic.
pub fn hetero_demo_mix() -> Vec<Dfg> {
    let conv = OpKind::Conv { h: 56, w: 56, cin: 256, cout: 256, k: 3, stride: 1 };
    let net = |name: &str, n: usize| {
        let mut d = Dfg::new(name);
        for i in 0..n {
            d.push(conv, 8, format!("conv{i}"));
        }
        d
    };
    vec![net("res-a", 48), net("res-b", 40), net("res-c", 32), net("res-d", 24)]
}

/// One measured arm of the re-plan experiment (`gacer-bench replan`):
/// how an admit re-search behaved under one budget, cold vs warm.
#[derive(Debug, Clone)]
pub struct ReplanCell {
    /// Arm label (e.g. `"cold (from scratch)"`, `"warm, <=200 evals"`).
    pub label: String,
    /// Simulator evaluations the search spent.
    pub evaluations: usize,
    /// Objective of the returned plan (Eq. 8 residue; lower is better).
    pub objective: f64,
    /// Whether the budget truncated convergence.
    pub truncated: bool,
    /// Tenant streams reused from the warm [`SearchState`].
    pub warm_hits: usize,
    /// Wall-clock search time (ms).
    pub elapsed_ms: f64,
}

impl ReplanCell {
    fn of(label: impl Into<String>, r: &SearchReport) -> Self {
        ReplanCell {
            label: label.into(),
            evaluations: r.evaluations,
            objective: r.outcome.objective(),
            truncated: r.truncated,
            warm_hits: r.warm_hits,
            elapsed_ms: r.elapsed.as_secs_f64() * 1e3,
        }
    }
}

/// The admit re-plan experiment: deploy `names` (cold search, filling a
/// warm [`SearchState`]), then admit `newcomer` and re-search the grown
/// set three ways — cold from scratch, and warm-started from the
/// deployment's state under each of `budgets`. Returns the inherited
/// seed's objective (the anytime floor every warm arm must stay at or
/// below), the cold cell, and one warm cell per budget.
pub fn run_replan(
    names: &[&str],
    newcomer: &str,
    platform: &Platform,
    cfg: SearchConfig,
    budgets: &[SearchBudget],
) -> (f64, ReplanCell, Vec<ReplanCell>) {
    let cost = CostModel::new(*platform);
    let opts = SimOptions::for_platform(platform);
    let mut tenants = zoo::build_combo(names);
    let ts = TenantSet::new(tenants.clone(), cost.clone());
    let mut state = SearchState::new();
    let deployed = GacerSearch::new(&ts, opts, cfg).run_with_state(&mut state);

    // The admit event: the newcomer joins at the deployment's pointer
    // level, exactly as `GacerEngine::admit` reshapes the shard plan.
    tenants.push(zoo::build_default(newcomer).expect("zoo model"));
    let grown = TenantSet::new(tenants.clone(), cost);
    let mut seed = deployed.plan.clone();
    seed.push_tenant(
        tenants.last().unwrap().len(),
        seed.pointers.pointers_per_tenant(),
    );
    let seed_objective = grown.simulate(&seed, opts).objective();

    let cold = ReplanCell::of(
        "cold (from scratch)",
        &GacerSearch::new(&grown, opts, cfg).run(),
    );
    let warm = budgets
        .iter()
        .map(|&budget| {
            let mut s = state.clone();
            let r = GacerSearch::new(&grown, opts, cfg)
                .budget(budget)
                .run_from_state(seed.clone(), &mut s)
                .expect("the admit seed matches the grown tenant set");
            ReplanCell::of(format!("warm, {}", budget.label()), &r)
        })
        .collect();
    (seed_objective, cold, warm)
}

/// Format a Fig. 7-style row: speedups normalized to CuDNN-Seq.
pub fn fig7_row(label: &str, cells: &[EvalCell]) -> String {
    let seq = cells
        .iter()
        .find(|c| c.strategy == Strategy::Baseline(BaselineKind::CudnnSeq))
        .expect("CuDNN-Seq cell required")
        .outcome
        .makespan_us;
    let mut row = format!("{label:<16}");
    for c in cells {
        row.push_str(&format!(
            " {:>15}",
            format!("{:.2}x ({:.2}ms)", seq / c.outcome.makespan_us, c.latency_ms())
        ));
    }
    row
}

/// Header matching [`fig7_row`].
pub fn fig7_header(cells: &[EvalCell]) -> String {
    let mut row = format!("{:<16}", "combo");
    for c in cells {
        row.push_str(&format!(" {:>15}", c.strategy.label()));
    }
    row
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg() -> SearchConfig {
        SearchConfig {
            max_pointers: 1,
            rounds_per_level: 1,
            positions_per_coordinate: 4,
            spatial_steps_per_level: 1,
            ..Default::default()
        }
    }

    #[test]
    fn full_strategy_set_runs() {
        let cells = run_combo(&["Alex", "V16", "R18"], &Platform::titan_v(), quick_cfg());
        assert_eq!(cells.len(), 7);
        for c in &cells {
            assert!(c.outcome.makespan_us > 0.0, "{}", c.strategy.label());
        }
    }

    #[test]
    fn sharded_scaling_reports_per_device_cells() {
        let (cells, cluster_ms) =
            run_sharded(&["Alex", "V16", "R18"], &Platform::titan_v(), 2, quick_cfg());
        assert_eq!(cells.len(), 2);
        let placed: usize = cells.iter().map(|c| c.tenants.len()).sum();
        assert_eq!(placed, 3, "every tenant placed exactly once");
        let bottleneck = cells.iter().map(|c| c.makespan_ms).fold(0.0f64, f64::max);
        assert!((cluster_ms - bottleneck).abs() < 1e-9);
        assert!(cluster_ms > 0.0);
        assert!(cells.iter().all(|c| c.predicted_slowdown >= 1.0));
    }

    #[test]
    fn placement_comparison_separates_saturating_tenants() {
        let platform = Platform::titan_v();
        let arms = compare_placements(interference_demo_mix(&platform), &platform, 2);
        assert_eq!(arms.len(), 3);
        let (lb, ia) = (&arms[0], &arms[1]);
        assert_eq!(lb.objective, PlacementObjective::LoadBalance);
        assert_eq!(ia.objective, PlacementObjective::InterferenceAware);
        assert_eq!(arms[2].objective, PlacementObjective::MemoryAware);
        let together = |arm: &PlacementArm| {
            arm.per_device.iter().any(|d| {
                d.contains(&"hi-a".to_string()) && d.contains(&"hi-b".to_string())
            })
        };
        assert!(together(lb), "LPT co-locates the saturating pair");
        assert!(!together(ia), "interference-aware separates it");
        assert!(ia.max_slowdown() < lb.max_slowdown());
        assert!(ia.max_score_ms < lb.max_score_ms);
    }

    #[test]
    fn memory_mix_defeats_every_memory_blind_objective() {
        let platform = Platform::titan_v();
        let arms = compare_placements(memory_demo_mix(&platform), &platform, 2);
        let hogs_together = |arm: &PlacementArm| {
            arm.per_device.iter().any(|d| {
                d.contains(&"hog-a".to_string()) && d.contains(&"hog-b".to_string())
            })
        };
        let (lb, ia, ma) = (&arms[0], &arms[1], &arms[2]);
        assert!(hogs_together(lb), "LPT pairs the bandwidth hogs");
        assert!(hogs_together(ia), "occupancy scoring is blind to the hogs");
        assert!(!hogs_together(ma), "the roofline separates them");
        // Both blind arms report occupancy slowdown 1.0 — the roofline
        // exposes the contention they actually committed to.
        assert!(lb.max_occupancy_slowdown() < 1.01);
        assert!(lb.max_slowdown() > 1.5);
        assert!(ma.max_slowdown() < lb.max_slowdown());
        assert!(arms.iter().all(|a| a.hbm_gb.iter().all(|&g| g >= 0.0)));
    }

    #[test]
    fn hetero_mix_defeats_the_homogeneous_assumption_on_a_mixed_pool() {
        use crate::plan::{Placement, PlacementObjective};
        use crate::profile::DevicePool;

        let (a100, t4) = (Platform::a100(), Platform::t4());
        let mix = hetero_demo_mix();
        // Premises the mix's doc comment claims: a T4 pair oversubscribes
        // its SM pool, an A100 pair does not.
        let occ = |p: &Platform| {
            CostModel::new(*p)
                .occupancy_profile(&mix[0])
                .iter()
                .copied()
                .fold(0.0f64, f64::max)
        };
        assert!(2.0 * occ(&t4) > 100.0, "a T4 pair must overflow 40 SMs");
        assert!(2.0 * occ(&a100) < 100.0, "an A100 pair must fit 108 SMs");

        let pool = DevicePool::from_platforms([a100, t4]);
        let set = TenantSet::new(mix, CostModel::new(a100));
        let aware =
            Placement::with_objective_pool(&set, &pool, PlacementObjective::InterferenceAware);
        let blind =
            Placement::with_objective(&set, 2, PlacementObjective::InterferenceAware);
        aware.validate(set.len()).unwrap();
        blind.validate(set.len()).unwrap();
        // Priced with each device's true cost model, the pool-aware
        // placement's bottleneck slowdown is strictly lower: the blind
        // arm parked a tenant pair on the T4.
        let max = |v: Vec<f64>| v.into_iter().fold(0.0f64, f64::max);
        let aware_max = max(aware.predicted_slowdowns_pool(&set, &pool));
        let blind_max = max(blind.predicted_slowdowns_pool(&set, &pool));
        assert!(blind.tenants_on(1).len() >= 2, "blind splits 2+2 onto the T4");
        assert!(
            aware_max < blind_max,
            "pool-aware {aware_max} must beat homogeneous-assumption {blind_max}"
        );
    }

    #[test]
    fn replan_arms_respect_the_anytime_floor() {
        let platform = Platform::titan_v();
        let budgets = [SearchBudget::evaluations(5), SearchBudget::unbounded()];
        let (seed_obj, cold, warm) = run_replan(
            &["Alex", "V16", "R18", "M3"],
            "R18",
            &platform,
            quick_cfg(),
            &budgets,
        );
        assert!(cold.evaluations > 0);
        assert!(!cold.truncated);
        assert_eq!(warm.len(), 2);
        for cell in &warm {
            // The anytime guarantee: never worse than the inherited seed.
            assert!(
                cell.objective <= seed_obj + 1e-6,
                "{}: {} > seed {seed_obj}",
                cell.label,
                cell.objective
            );
        }
        // 5 evaluations cannot finish an admit re-search on 5 tenants.
        assert!(warm[0].truncated);
        assert!(!warm[1].truncated);
        // The unbounded warm arm reuses the deployment's streams.
        assert!(warm[1].label.contains("unbounded"));
    }

    #[test]
    fn rows_render() {
        let cells = run_combo(&["Alex", "V16", "R18"], &Platform::titan_v(), quick_cfg());
        let row = fig7_row("ALEX+V16+R18", &cells);
        assert!(row.contains('x'));
        assert_eq!(
            fig7_header(&cells).split_whitespace().count(),
            8 // "combo" + 7 strategies
        );
    }
}
