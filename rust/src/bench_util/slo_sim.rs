//! Deterministic discrete-time queueing simulator behind `gacer-bench
//! slo`: a saturated multi-device cluster serving an interactive tenant
//! next to batch tenants, with and without SLO regulation.
//!
//! The simulator is intentionally tiny — FIFO queues, fixed per-round
//! device capacity, no randomness — so the bench is reproducible
//! bit-for-bit and the effect it demonstrates is structural, not
//! statistical: under saturation, *fair* sharing starves the interactive
//! tenant (its backlog and therefore its latency grow without bound),
//! while tier-major issue plus bounded batch queues (the
//! [`crate::slo`] policies) hold the interactive p99 at the cost of
//! shedding batch arrivals. The same [`crate::slo::SloMonitor`] the
//! engine runs is fed one observe window per simulated interval, so the
//! bench also exercises the burn-rate verdicts end to end.

use std::collections::{BTreeMap, VecDeque};

use crate::metrics::{LatencyHistogram, Quantiles};
use crate::slo::{BurnConfig, SloMonitor, SloPressure, SloTarget, Tier};
use crate::util::json::Json;

/// Wall-clock length of one simulated scheduling round, microseconds.
/// A request served in its arrival round costs one round of latency.
pub const SLO_ROUND_US: f64 = 1_000.0;

/// One tenant of the simulated cluster.
#[derive(Debug, Clone)]
pub struct SloSimTenant {
    pub name: String,
    pub device: usize,
    pub tier: Tier,
    /// New requests arriving at the head of every round.
    pub arrivals_per_round: usize,
    /// Queue bound honored only by the regulated arm: arrivals beyond it
    /// are shed (the simulator's stand-in for
    /// [`crate::slo::SloPolicy::queue_cap`]).
    pub queue_cap: Option<usize>,
}

/// Knobs for one simulation run.
#[derive(Debug, Clone)]
pub struct SloSimConfig {
    /// Scheduling rounds to simulate.
    pub rounds: usize,
    /// Requests each device can serve per round.
    pub capacity_per_round: usize,
    /// Rounds per [`SloMonitor::observe`] window.
    pub window_rounds: usize,
    /// Latency target tracked for interactive tenants.
    pub target: SloTarget,
}

impl Default for SloSimConfig {
    fn default() -> Self {
        SloSimConfig {
            rounds: 400,
            capacity_per_round: 8,
            window_rounds: 50,
            target: SloTarget::p99_ms(2.0),
        }
    }
}

/// Per-tenant result of one arm.
#[derive(Debug, Clone)]
pub struct SloTenantOutcome {
    pub name: String,
    pub device: usize,
    pub tier: Tier,
    /// Requests served over the whole run.
    pub served: u64,
    /// Arrivals shed at the queue cap (always `0` in the unregulated arm).
    pub shed: u64,
    /// Latency distribution of the served requests.
    pub latency: Quantiles,
    /// Final burn-monitor verdict (tracked tenants only — the monitor
    /// watches the interactive tier).
    pub pressure: Option<SloPressure>,
}

/// One arm of the experiment: the whole cluster, regulated or not.
#[derive(Debug, Clone)]
pub struct SloSimOutcome {
    pub regulated: bool,
    pub tenants: Vec<SloTenantOutcome>,
}

impl SloSimOutcome {
    pub fn tenant(&self, name: &str) -> Option<&SloTenantOutcome> {
        self.tenants.iter().find(|t| t.name == name)
    }

    /// The interactive tenant's p99 (the experiment's headline number).
    pub fn interactive_p99_us(&self) -> f64 {
        self.tenants
            .iter()
            .filter(|t| t.tier == Tier::Interactive)
            .map(|t| t.latency.p99_us)
            .fold(0.0, f64::max)
    }
}

/// The saturated two-device mix of `gacer-bench slo`: device 0 hosts one
/// interactive tenant (3 req/round) against two batch analytics tenants
/// (5 req/round each) — demand 13 against capacity 8 — while device 1
/// runs two batch tenants at milder oversubscription. Fair sharing gives
/// the interactive tenant 8/3 ≈ 2.67 req/round, below its arrival rate,
/// so its backlog grows without bound; tier-major issue serves it first.
pub fn saturated_mix() -> Vec<SloSimTenant> {
    let t = |name: &str, device, tier, arrivals_per_round, queue_cap| SloSimTenant {
        name: name.to_string(),
        device,
        tier,
        arrivals_per_round,
        queue_cap,
    };
    vec![
        t("chat", 0, Tier::Interactive, 3, None),
        t("analytics-a", 0, Tier::Batch, 5, Some(32)),
        t("analytics-b", 0, Tier::Batch, 5, Some(32)),
        t("train-a", 1, Tier::Batch, 5, Some(32)),
        t("train-b", 1, Tier::Batch, 5, Some(32)),
    ]
}

/// Run one arm. `regulated` turns on the two SLO mechanisms the engine's
/// lowered [`crate::coordinator::ServerConfig`] applies: tier-major
/// issue order (higher tiers drain first each round, mirroring
/// `tiered_issue_order`) and bounded batch queues (over-cap arrivals
/// shed). The unregulated arm is fair round-robin with unbounded queues
/// — the pre-SLO server. Both arms feed an interactive-tier
/// [`SloMonitor`] so the final outcome carries a burn verdict.
pub fn run_slo_sim(
    tenants: &[SloSimTenant],
    cfg: &SloSimConfig,
    regulated: bool,
) -> SloSimOutcome {
    let n = tenants.len();
    let n_devices = tenants.iter().map(|t| t.device + 1).max().unwrap_or(0);
    let mut queues: Vec<VecDeque<usize>> = vec![VecDeque::new(); n];
    let mut served = vec![0u64; n];
    let mut shed = vec![0u64; n];
    let mut hist: Vec<LatencyHistogram> = vec![LatencyHistogram::new(); n];
    let mut window: Vec<Vec<f64>> = vec![Vec::new(); n];
    let mut monitor = SloMonitor::new(BurnConfig::default());
    for (i, t) in tenants.iter().enumerate() {
        if t.tier == Tier::Interactive {
            monitor.track(i as u64, t.tier, cfg.target).expect("sim target is valid");
        }
    }

    // Issue groups per device: the regulated arm drains tiers in
    // descending priority (tier-major, like `tiered_issue_order`); the
    // unregulated arm treats every tenant as one fair-share group.
    // Within a group, service is round-robin one request per tenant,
    // rotated by round so no tenant owns the leftover slot.
    let groups: Vec<Vec<Vec<usize>>> = (0..n_devices)
        .map(|d| {
            let on_dev: Vec<usize> =
                (0..n).filter(|&i| tenants[i].device == d).collect();
            if !regulated {
                return vec![on_dev];
            }
            let mut prios: Vec<u8> =
                on_dev.iter().map(|&i| tenants[i].tier.priority()).collect();
            prios.sort_unstable();
            prios.dedup();
            prios.reverse();
            prios
                .into_iter()
                .map(|p| {
                    on_dev
                        .iter()
                        .copied()
                        .filter(|&i| tenants[i].tier.priority() == p)
                        .collect()
                })
                .collect()
        })
        .collect();

    for round in 0..cfg.rounds {
        // Arrivals (shed at the cap only under regulation).
        for (i, t) in tenants.iter().enumerate() {
            for _ in 0..t.arrivals_per_round {
                if regulated && t.queue_cap.is_some_and(|cap| queues[i].len() >= cap) {
                    shed[i] += 1;
                } else {
                    queues[i].push_back(round);
                }
            }
        }
        // Service: each device spends its capacity group by group.
        for device_groups in &groups {
            let mut budget = cfg.capacity_per_round;
            for group in device_groups {
                if group.is_empty() {
                    continue;
                }
                while budget > 0 {
                    let mut progressed = false;
                    for k in 0..group.len() {
                        if budget == 0 {
                            break;
                        }
                        let i = group[(k + round) % group.len()];
                        if let Some(arrived) = queues[i].pop_front() {
                            let us = (round - arrived + 1) as f64 * SLO_ROUND_US;
                            hist[i].record_us(us);
                            window[i].push(us);
                            served[i] += 1;
                            budget -= 1;
                            progressed = true;
                        }
                    }
                    if !progressed {
                        break;
                    }
                }
            }
        }
        // Close an observe window for the burn monitor.
        if (round + 1) % cfg.window_rounds == 0 {
            for (i, t) in tenants.iter().enumerate() {
                if t.tier == Tier::Interactive {
                    monitor.observe(i as u64, &window[i]);
                }
                window[i].clear();
            }
        }
    }
    // Flush a trailing partial window so no samples escape the verdict.
    for (i, t) in tenants.iter().enumerate() {
        if t.tier == Tier::Interactive && !window[i].is_empty() {
            monitor.observe(i as u64, &window[i]);
        }
    }

    let tenants = tenants
        .iter()
        .enumerate()
        .map(|(i, t)| SloTenantOutcome {
            name: t.name.clone(),
            device: t.device,
            tier: t.tier,
            served: served[i],
            shed: shed[i],
            latency: hist[i].quantiles(),
            pressure: monitor.pressure(i as u64),
        })
        .collect();
    SloSimOutcome { regulated, tenants }
}

/// Serialize both arms into the `BENCH_slo.json` payload: per-tenant
/// rows for each arm plus an `interactive` headline block recording the
/// p99 of each arm and whether it held the target.
pub fn slo_report_json(
    cfg: &SloSimConfig,
    regulated: &SloSimOutcome,
    unregulated: &SloSimOutcome,
) -> Json {
    let arm = |o: &SloSimOutcome| {
        Json::Arr(
            o.tenants
                .iter()
                .map(|t| {
                    let mut m = BTreeMap::new();
                    m.insert("name".to_string(), Json::Str(t.name.clone()));
                    m.insert("device".to_string(), Json::Num(t.device as f64));
                    m.insert("tier".to_string(), Json::Str(t.tier.label().to_string()));
                    m.insert("served".to_string(), Json::Num(t.served as f64));
                    m.insert("shed".to_string(), Json::Num(t.shed as f64));
                    m.insert("p50_us".to_string(), Json::Num(t.latency.p50_us));
                    m.insert("p99_us".to_string(), Json::Num(t.latency.p99_us));
                    m.insert("max_us".to_string(), Json::Num(t.latency.max_us));
                    if let Some(p) = t.pressure {
                        m.insert(
                            "health".to_string(),
                            Json::Str(p.health.label().to_string()),
                        );
                        m.insert("burn_slow".to_string(), Json::Num(p.burn_slow));
                    }
                    Json::Obj(m)
                })
                .collect(),
        )
    };
    let target_us = cfg.target.target_us;
    let mut headline = BTreeMap::new();
    headline.insert(
        "regulated_p99_us".to_string(),
        Json::Num(regulated.interactive_p99_us()),
    );
    headline.insert(
        "unregulated_p99_us".to_string(),
        Json::Num(unregulated.interactive_p99_us()),
    );
    headline.insert(
        "regulated_holds_target".to_string(),
        Json::Bool(regulated.interactive_p99_us() <= target_us),
    );
    headline.insert(
        "unregulated_holds_target".to_string(),
        Json::Bool(unregulated.interactive_p99_us() <= target_us),
    );
    let mut root = BTreeMap::new();
    root.insert("experiment".to_string(), Json::Str("slo".to_string()));
    root.insert("round_us".to_string(), Json::Num(SLO_ROUND_US));
    root.insert("rounds".to_string(), Json::Num(cfg.rounds as f64));
    root.insert(
        "capacity_per_round".to_string(),
        Json::Num(cfg.capacity_per_round as f64),
    );
    root.insert("target_p99_us".to_string(), Json::Num(target_us));
    root.insert("regulated".to_string(), arm(regulated));
    root.insert("unregulated".to_string(), arm(unregulated));
    root.insert("interactive".to_string(), Json::Obj(headline));
    Json::Obj(root)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::slo::SloHealth;

    #[test]
    fn regulated_arm_holds_interactive_p99_and_sheds_batch() {
        let cfg = SloSimConfig::default();
        let out = run_slo_sim(&saturated_mix(), &cfg, true);
        let chat = out.tenant("chat").expect("interactive tenant");
        assert!(
            chat.latency.p99_us <= cfg.target.target_us,
            "tier-major issue must hold the interactive p99: {} > {}",
            chat.latency.p99_us,
            cfg.target.target_us
        );
        assert_eq!(chat.shed, 0, "interactive requests are never shed");
        let batch_shed: u64 = out
            .tenants
            .iter()
            .filter(|t| t.tier == Tier::Batch)
            .map(|t| t.shed)
            .sum();
        assert!(batch_shed > 0, "saturated batch tenants shed at their cap");
        assert_eq!(chat.pressure.expect("tracked").health, SloHealth::Healthy);
    }

    #[test]
    fn unregulated_arm_blows_the_interactive_budget() {
        let cfg = SloSimConfig::default();
        let out = run_slo_sim(&saturated_mix(), &cfg, false);
        let chat = out.tenant("chat").expect("interactive tenant");
        assert!(
            chat.latency.p99_us > cfg.target.target_us,
            "fair sharing under saturation must violate the target"
        );
        let total_shed: u64 = out.tenants.iter().map(|t| t.shed).sum();
        assert_eq!(total_shed, 0, "no queue caps without regulation");
        assert!(chat.pressure.expect("tracked").health.is_burning());
    }

    #[test]
    fn every_request_is_served_or_shed_or_queued() {
        let cfg = SloSimConfig { rounds: 60, ..Default::default() };
        let mix = saturated_mix();
        for regulated in [true, false] {
            let out = run_slo_sim(&mix, &cfg, regulated);
            for (t, spec) in out.tenants.iter().zip(&mix) {
                let arrived = (spec.arrivals_per_round * cfg.rounds) as u64;
                assert!(
                    t.served + t.shed <= arrived,
                    "{}: served {} + shed {} > arrived {arrived}",
                    t.name,
                    t.served,
                    t.shed
                );
                assert_eq!(t.latency.n as u64, t.served);
            }
        }
    }

    #[test]
    fn report_json_round_trips() {
        let cfg = SloSimConfig { rounds: 100, ..Default::default() };
        let reg = run_slo_sim(&saturated_mix(), &cfg, true);
        let unreg = run_slo_sim(&saturated_mix(), &cfg, false);
        let json = slo_report_json(&cfg, &reg, &unreg);
        let text = json.to_string_compact();
        assert!(text.contains("\"experiment\":\"slo\""));
        assert!(text.contains("\"regulated_holds_target\":true"));
        assert!(text.contains("\"unregulated_holds_target\":false"));
        let back = Json::parse(&text).expect("self-emitted JSON parses");
        assert_eq!(back.to_string_compact(), text);
    }
}
