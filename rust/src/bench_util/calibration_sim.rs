//! Deterministic engine-in-the-loop simulator behind `gacer-bench
//! calibration`: a tenant mix the analytic cost model misprices, served
//! with and without the online correction layer ([`crate::calibrate`]).
//!
//! The setup is deliberately minimal so the effect is structural, not
//! statistical: four tenants whose DFGs are **identical** — so the
//! analytic model prices them identically and balances them 2+2 across
//! two devices — but one of which (`mis`) *actually* runs
//! [`CalibSimConfig::inflation`]× slower than predicted (the stand-in
//! for any systematic model error: an unprofiled kernel, a quantized
//! peer, a thermally throttled part). Served latency follows a
//! processor-sharing model: a tenant's window latency is its true base
//! latency times the number of tenants sharing its device.
//!
//! The analytic arm can never react: its weights come from the cost
//! model alone, the mispriced co-location looks perfectly balanced, and
//! `maybe_migrate` declines forever while `mis` serves at
//! `inflation × 2` its predicted latency. The calibrated arm feeds the
//! same served windows through [`GacerEngine::record_latencies`]; once
//! the trust ramp completes, the residual-scaled weights expose the
//! hidden imbalance, the load-ratio policy fires, and the engine
//! isolates the mispriced tenant — the measured steady-state p99 drops
//! by roughly `inflation / (tenants - 1)`.
//!
//! Everything is seeded ([`CalibSimConfig::seed`]) and clock-free, so
//! both arms reproduce bit-for-bit; the jitter exists only to prove the
//! EWMA tolerates noisy windows.

use std::collections::BTreeMap;

use crate::calibrate::CalibrationConfig;
use crate::dfg::{Dfg, OpKind};
use crate::engine::{GacerEngine, MigrationPolicy};
use crate::metrics::{LatencyHistogram, Quantiles};
use crate::profile::{CostModel, Platform};
use crate::search::SearchConfig;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Knobs for one simulated serving run (one arm).
#[derive(Debug, Clone)]
pub struct CalibSimConfig {
    /// Observe windows before measurement starts — the calibration
    /// warm-up, discarded from the latency report (standard bench
    /// hygiene; it also contains the migration transient).
    pub warmup_windows: usize,
    /// Observe windows measured into the per-tenant histograms.
    pub measure_windows: usize,
    /// Latency samples served per tenant per window.
    pub samples_per_window: usize,
    /// Hidden truth: the mispriced tenant's real latency is this factor
    /// times the analytic prediction.
    pub inflation: f64,
    /// Multiplicative sample jitter (`±jitter`, uniform, seeded).
    pub jitter: f64,
    /// Seed for the jitter stream.
    pub seed: u64,
    /// `Some` = the calibrated arm; `None` = the analytic arm.
    pub calibration: Option<CalibrationConfig>,
}

impl CalibSimConfig {
    /// The analytic (calibration-off) arm of the experiment.
    pub fn analytic() -> Self {
        CalibSimConfig {
            warmup_windows: 6,
            measure_windows: 6,
            samples_per_window: 32,
            inflation: 6.0,
            jitter: 0.02,
            seed: 0xCA11B,
            calibration: None,
        }
    }

    /// The calibrated arm: identical serving, corrections on.
    pub fn calibrated() -> Self {
        CalibSimConfig {
            calibration: Some(bench_calibration_config()),
            ..Self::analytic()
        }
    }
}

/// The calibration knobs the bench arms run: defaults except a wider
/// `max_correction` clamp — the demo's hidden 6× inflation lands at a
/// raw co-located ratio of ~12, and a 4.0 clamp would still fire the
/// migration but mask how large the residual really is.
pub fn bench_calibration_config() -> CalibrationConfig {
    CalibrationConfig { max_correction: 8.0, ..CalibrationConfig::default() }
}

/// Four **analytically identical** tenants (batch-1 conv chains, low
/// occupancy so co-location is nearly interference-free): `mis` is the
/// one whose real latency the model underprices; the three `peer-*`
/// tenants behave as predicted. Identical DFGs are the point — no
/// analytic objective can tell them apart, only measurement can.
pub fn mis_modeled_mix() -> Vec<Dfg> {
    let conv = OpKind::Conv { h: 56, w: 56, cin: 256, cout: 256, k: 3, stride: 1 };
    let net = |name: &str| {
        let mut d = Dfg::new(name);
        for i in 0..6 {
            d.push(conv, 1, format!("conv{i}"));
        }
        d
    };
    vec![net("mis"), net("peer-a"), net("peer-b"), net("peer-c")]
}

fn sim_search_cfg() -> SearchConfig {
    SearchConfig {
        max_pointers: 1,
        rounds_per_level: 1,
        positions_per_coordinate: 4,
        spatial_steps_per_level: 1,
        ..Default::default()
    }
}

fn build_engine(calibration: Option<CalibrationConfig>) -> GacerEngine {
    let mut b = GacerEngine::builder().devices(2).search(sim_search_cfg());
    if let Some(cfg) = calibration {
        b = b.calibration(cfg);
    }
    for t in mis_modeled_mix() {
        b = b.tenant(t);
    }
    b.build().expect("the demo mix always builds")
}

/// Per-tenant result of one arm.
#[derive(Debug, Clone)]
pub struct CalibTenantOutcome {
    pub name: String,
    /// Device the tenant ended the run on.
    pub final_device: usize,
    /// Final correction factor the engine applied (`1.0` on the
    /// analytic arm, and until trust).
    pub correction: f64,
    /// Measured latency over the measurement windows only.
    pub latency: Quantiles,
}

/// One arm of the experiment.
#[derive(Debug, Clone)]
pub struct CalibSimOutcome {
    pub calibrated: bool,
    /// First observe window (0-based) whose consultation executed a
    /// migration; `None` when the arm never moved anything.
    pub migrated_window: Option<usize>,
    /// Whether the mispriced tenant ended the run alone on its device.
    pub mis_isolated: bool,
    pub tenants: Vec<CalibTenantOutcome>,
}

impl CalibSimOutcome {
    pub fn tenant(&self, name: &str) -> Option<&CalibTenantOutcome> {
        self.tenants.iter().find(|t| t.name == name)
    }

    /// The experiment's headline number: the worst tenant's measured
    /// p99 (µs) over the measurement windows.
    pub fn max_p99_us(&self) -> f64 {
        self.tenants.iter().map(|t| t.latency.p99_us).fold(0.0, f64::max)
    }
}

/// Run one arm: deploy the mix, then serve
/// `warmup_windows + measure_windows` observe windows. Each window
/// synthesizes every tenant's served latencies from the hidden truth
/// (`true base × tenants sharing the device`, ±jitter), feeds them to
/// [`GacerEngine::record_latencies`], and consults
/// [`GacerEngine::maybe_migrate`] — exactly the operations loop of
/// `docs/OPERATIONS.md`, minus the real servers.
pub fn run_calibration_sim(cfg: &CalibSimConfig) -> CalibSimOutcome {
    let mut engine = build_engine(cfg.calibration);
    let policy = MigrationPolicy::default();
    let n = engine.len();
    let ids = engine.tenant_ids();
    // The hidden truth the analytic model cannot see: slot 0 (`mis`)
    // really costs `inflation ×` its predicted serial latency.
    let cost = CostModel::new(Platform::titan_v());
    let true_base: Vec<f64> = engine
        .tenants()
        .iter()
        .enumerate()
        .map(|(slot, dfg)| {
            let s = cost.sequential_latency_us(dfg);
            if slot == 0 {
                cfg.inflation * s
            } else {
                s
            }
        })
        .collect();

    let mut rng = Rng::new(cfg.seed);
    let mut hist: Vec<LatencyHistogram> = vec![LatencyHistogram::new(); n];
    let mut migrated_window = None;
    let total = cfg.warmup_windows + cfg.measure_windows;
    for window in 0..total {
        let mut samples: Vec<Vec<f64>> = vec![Vec::new(); n];
        for slot in 0..n {
            let (device, _) = engine
                .placement()
                .locate(slot)
                .expect("every tenant stays placed");
            let sharing = engine.placement().tenants_on(device).len() as f64;
            let base = true_base[slot] * sharing;
            for _ in 0..cfg.samples_per_window {
                let f = 2.0 * rng.f64() - 1.0;
                let us = base * (1.0 + cfg.jitter * f);
                samples[slot].push(us);
                if window >= cfg.warmup_windows {
                    hist[slot].record_us(us);
                }
            }
        }
        engine
            .record_latencies(&samples)
            .expect("samples are in slot order");
        if engine
            .maybe_migrate(&policy)
            .expect("the demo moves never fail")
            .is_some()
            && migrated_window.is_none()
        {
            migrated_window = Some(window);
        }
    }

    let mis_device = engine
        .placement()
        .locate(0)
        .expect("the mispriced tenant is placed")
        .0;
    let mis_isolated = engine.placement().tenants_on(mis_device).len() == 1;
    let tenants = (0..n)
        .map(|slot| CalibTenantOutcome {
            name: engine.tenants()[slot].name.clone(),
            final_device: engine.placement().locate(slot).expect("placed").0,
            correction: engine
                .correction_of(ids[slot])
                .expect("ids stay valid — nothing is evicted"),
            latency: hist[slot].quantiles(),
        })
        .collect();
    CalibSimOutcome {
        calibrated: cfg.calibration.is_some(),
        migrated_window,
        mis_isolated,
        tenants,
    }
}

/// The zero-observation regression arm: drive an analytic engine and a
/// calibration-enabled engine through the same decision sequence
/// **without ever feeding a latency window** and check every decision is
/// bit-for-bit identical — build placement, per-shard plans, migration
/// consultations, a cold re-plan, and an admission. This is the
/// acceptance criterion that turning the feature on changes nothing
/// until something is observed.
pub fn calibration_is_noop_without_observations(windows: usize) -> bool {
    let mut analytic = build_engine(None);
    let mut calibrated = build_engine(Some(bench_calibration_config()));
    let policy = MigrationPolicy::default();
    if calibrated.sharded_plan() != analytic.sharded_plan() {
        return false;
    }
    for _ in 0..windows {
        let a = analytic.maybe_migrate(&policy).expect("consultation succeeds");
        let c = calibrated.maybe_migrate(&policy).expect("consultation succeeds");
        if a != c || calibrated.sharded_plan() != analytic.sharded_plan() {
            return false;
        }
    }
    // A cold re-plan takes the scaled path on the calibrated engine —
    // with no trusted residual it must delegate to the analytic search.
    analytic.replan();
    calibrated.replan();
    if calibrated.sharded_plan() != analytic.sharded_plan() {
        return false;
    }
    // Admission prices the newcomer through the scaled choosers.
    let extra = &mis_modeled_mix()[1];
    let mut newcomer = extra.clone();
    newcomer.name = "late".to_string();
    let da = analytic.admit(newcomer.clone()).and_then(|id| analytic.device_of(id));
    let dc = calibrated.admit(newcomer).and_then(|id| calibrated.device_of(id));
    matches!((da, dc), (Ok(a), Ok(c)) if a == c)
        && calibrated.sharded_plan() == analytic.sharded_plan()
}

/// Serialize both arms into the `BENCH_calibration.json` payload:
/// per-tenant rows for each arm plus a `headline` block with the two
/// max-p99s, the improvement verdict, the calibrated arm's migration
/// window, and the zero-observation identity check.
pub fn calibration_report_json(
    cfg: &CalibSimConfig,
    calibrated: &CalibSimOutcome,
    analytic: &CalibSimOutcome,
    zero_obs_identical: bool,
) -> Json {
    let arm = |o: &CalibSimOutcome| {
        Json::Arr(
            o.tenants
                .iter()
                .map(|t| {
                    let mut m = BTreeMap::new();
                    m.insert("name".to_string(), Json::Str(t.name.clone()));
                    m.insert(
                        "final_device".to_string(),
                        Json::Num(t.final_device as f64),
                    );
                    m.insert("correction".to_string(), Json::Num(t.correction));
                    m.insert("p50_us".to_string(), Json::Num(t.latency.p50_us));
                    m.insert("p99_us".to_string(), Json::Num(t.latency.p99_us));
                    m.insert("max_us".to_string(), Json::Num(t.latency.max_us));
                    Json::Obj(m)
                })
                .collect(),
        )
    };
    let mut headline = BTreeMap::new();
    headline.insert(
        "analytic_max_p99_us".to_string(),
        Json::Num(analytic.max_p99_us()),
    );
    headline.insert(
        "calibrated_max_p99_us".to_string(),
        Json::Num(calibrated.max_p99_us()),
    );
    headline.insert(
        "improved".to_string(),
        Json::Bool(calibrated.max_p99_us() < analytic.max_p99_us()),
    );
    headline.insert(
        "migrated_window".to_string(),
        match calibrated.migrated_window {
            Some(w) => Json::Num(w as f64),
            None => Json::Bool(false),
        },
    );
    headline.insert(
        "mis_isolated".to_string(),
        Json::Bool(calibrated.mis_isolated),
    );
    headline.insert(
        "zero_obs_identical".to_string(),
        Json::Bool(zero_obs_identical),
    );
    let mut root = BTreeMap::new();
    root.insert("experiment".to_string(), Json::Str("calibration".to_string()));
    root.insert("inflation".to_string(), Json::Num(cfg.inflation));
    root.insert(
        "warmup_windows".to_string(),
        Json::Num(cfg.warmup_windows as f64),
    );
    root.insert(
        "measure_windows".to_string(),
        Json::Num(cfg.measure_windows as f64),
    );
    root.insert(
        "samples_per_window".to_string(),
        Json::Num(cfg.samples_per_window as f64),
    );
    root.insert("calibrated".to_string(), arm(calibrated));
    root.insert("analytic".to_string(), arm(analytic));
    root.insert("headline".to_string(), Json::Obj(headline));
    Json::Obj(root)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn analytic_arm_never_migrates_off_the_mispriced_colocation() {
        let out = run_calibration_sim(&CalibSimConfig::analytic());
        assert!(!out.calibrated);
        assert_eq!(out.migrated_window, None, "identical analytic weights");
        assert!(!out.mis_isolated, "the 2+2 split never changes");
        for t in &out.tenants {
            assert_eq!(t.correction, 1.0);
            assert_eq!(t.latency.n, 6 * 32);
        }
    }

    #[test]
    fn calibrated_arm_migrates_and_strictly_improves_the_worst_p99() {
        let analytic = run_calibration_sim(&CalibSimConfig::analytic());
        let calibrated = run_calibration_sim(&CalibSimConfig::calibrated());
        assert!(calibrated.calibrated);
        let w = calibrated.migrated_window.expect("trusted residuals fire");
        assert!(
            w < CalibSimConfig::calibrated().warmup_windows,
            "the move lands inside the warm-up, window {w}"
        );
        assert!(calibrated.mis_isolated, "the mispriced tenant ends alone");
        assert!(
            calibrated.max_p99_us() < analytic.max_p99_us(),
            "calibrated {} must beat analytic {}",
            calibrated.max_p99_us(),
            analytic.max_p99_us()
        );
        // The correction the engine settled on reflects the hidden
        // truth: well above 1 for `mis`, modest for the peers.
        let mis = calibrated.tenant("mis").unwrap();
        assert!(mis.correction > 2.0, "mis correction {}", mis.correction);
    }

    #[test]
    fn zero_observation_arms_take_identical_decisions() {
        assert!(calibration_is_noop_without_observations(4));
    }

    #[test]
    fn report_json_round_trips() {
        let analytic = run_calibration_sim(&CalibSimConfig::analytic());
        let calibrated = run_calibration_sim(&CalibSimConfig::calibrated());
        let json =
            calibration_report_json(&CalibSimConfig::calibrated(), &calibrated, &analytic, true);
        let text = json.to_string_compact();
        assert!(text.contains("\"experiment\":\"calibration\""));
        assert!(text.contains("\"improved\":true"));
        assert!(text.contains("\"zero_obs_identical\":true"));
        let back = Json::parse(&text).expect("self-emitted JSON parses");
        assert_eq!(back.to_string_compact(), text);
    }
}
