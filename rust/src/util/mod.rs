//! In-tree utility substrates. The build environment is fully offline with
//! a minimal vendored crate set, so the small infrastructure pieces a
//! serving framework normally pulls from crates.io are implemented here:
//! a JSON parser/serializer (artifact manifest + parameters + goldens), a
//! deterministic RNG (workload generation + property tests), and a tiny
//! CLI argument parser.

pub mod cli;
pub mod json;
pub mod rng;
