//! Minimal JSON parser + writer.
//!
//! Parses the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null); good enough for the artifact manifest,
//! parameter dumps, and golden files `python/compile/aot.py` emits — and
//! strict about trailing garbage. Not performance-critical: parsing the
//! 1.5 MB parameter file takes a few milliseconds.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let bytes = text.as_bytes();
        let mut p = Parser { b: bytes, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // --- typed accessors ---

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Array of numbers as f32s (the parameter/golden payloads).
    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        let arr = self.as_arr()?;
        let mut out = Vec::with_capacity(arr.len());
        for v in arr {
            out.push(v.as_f64()? as f32);
        }
        Some(out)
    }

    /// Array of non-negative integers (shapes).
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        let arr = self.as_arr()?;
        let mut out = Vec::with_capacity(arr.len());
        for v in arr {
            out.push(v.as_usize()?);
        }
        Some(out)
    }

    /// Serialize (compact).
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, message: &str) -> JsonError {
        JsonError { offset: self.i, message: message.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).map_err(|_| self.err("utf8"))?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| JsonError { offset: start, message: format!("bad number '{s}'") })
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000C}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy a run of plain UTF-8 bytes.
                    let start = self.i;
                    while self
                        .peek()
                        .is_some_and(|c| c != b'"' && c != b'\\')
                    {
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf8"))?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let val = self.value()?;
            out.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{
            "linear_b2": {
                "path": "linear_b2.hlo.txt",
                "inputs": [{"shape": [2, 64], "dtype": "float32"}],
                "outputs": [{"shape": [2, 32], "dtype": "float32"}],
                "meta": {"op": "linear", "batch": 2, "relu": true}
            }
        }"#;
        let v = Json::parse(doc).unwrap();
        let e = v.get("linear_b2").unwrap();
        assert_eq!(e.get("path").unwrap().as_str(), Some("linear_b2.hlo.txt"));
        let shape = e.get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_usize_vec()
            .unwrap();
        assert_eq!(shape, vec![2, 64]);
        assert_eq!(e.get("meta").unwrap().get("batch").unwrap().as_usize(), Some(2));
        assert_eq!(e.get("meta").unwrap().get("relu").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn parses_numbers() {
        for (s, v) in [
            ("0", 0.0),
            ("-1", -1.0),
            ("3.5", 3.5),
            ("1e3", 1000.0),
            ("-2.5e-2", -0.025),
        ] {
            assert_eq!(Json::parse(s).unwrap().as_f64(), Some(v), "{s}");
        }
    }

    #[test]
    fn parses_escapes() {
        let v = Json::parse(r#""a\nb\t\"c\" A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"c\" A"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("{'a': 1}").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn roundtrip_compact() {
        let doc = r#"{"a":[1,2.5,true,null],"b":"x"}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(Json::parse(&v.to_string_compact()).unwrap(), v);
    }

    #[test]
    fn f32_vec_extraction() {
        let v = Json::parse("[0.5, -1, 2]").unwrap();
        assert_eq!(v.as_f32_vec(), Some(vec![0.5, -1.0, 2.0]));
        assert_eq!(Json::parse("[1, \"x\"]").unwrap().as_f32_vec(), None);
    }

    #[test]
    fn nested_arrays_deep() {
        let v = Json::parse("[[[[1]]]]").unwrap();
        assert_eq!(
            v.as_arr().unwrap()[0].as_arr().unwrap()[0].as_arr().unwrap()[0]
                .as_arr()
                .unwrap()[0]
                .as_f64(),
            Some(1.0)
        );
    }
}
