//! Tiny CLI argument parser: `--key value`, `--flag`, and positionals.

use std::collections::BTreeMap;

/// Parsed arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (not including argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().is_some_and(|n| !n.starts_with("--")) {
                    out.options.insert(key.to_string(), it.next().unwrap());
                } else {
                    out.flags.push(key.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    pub fn opt_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.opt(key).unwrap_or(default)
    }

    pub fn opt_usize(&self, key: &str, default: usize) -> usize {
        self.opt(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn positional_and_options() {
        let a = parse("simulate --models R50,V16 --rounds 5 --verbose");
        assert_eq!(a.positional, vec!["simulate"]);
        assert_eq!(a.opt("models"), Some("R50,V16"));
        assert_eq!(a.opt_usize("rounds", 1), 5);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn equals_syntax() {
        let a = parse("--platform=TitanV run");
        assert_eq!(a.opt("platform"), Some("TitanV"));
        assert_eq!(a.positional, vec!["run"]);
    }

    #[test]
    fn defaults() {
        let a = parse("");
        assert_eq!(a.opt_or("x", "d"), "d");
        assert_eq!(a.opt_usize("n", 7), 7);
    }
}
