//! Deterministic RNG (xorshift64*): workload generation + the in-tree
//! property-test harness. Seeded runs reproduce exactly across platforms.

/// xorshift64* — tiny, fast, good-enough statistical quality for workload
/// shuffling and property-test case generation.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // Avoid the all-zero fixed point.
        Rng { state: seed.wrapping_mul(0x9E3779B97F4A7C15) | 1 }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in `[0, n)`; n must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in [-1, 1).
    pub fn f32_signed(&mut self) -> f32 {
        (self.f64() * 2.0 - 1.0) as f32
    }

    /// Pick one element of a non-empty slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len())]
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            items.swap(i, self.below(i + 1));
        }
    }
}

/// Run a property over `cases` generated cases. On failure, panics with the
/// case's seed so it can be replayed exactly.
pub fn check_property<F: Fn(&mut Rng)>(name: &str, cases: usize, f: F) {
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case as u64).wrapping_mul(0x9E3779B1);
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&mut rng);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "panic".to_string());
            panic!("property '{name}' failed on case {case} (seed {seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..4).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..4).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
            let x = r.range(5, 8);
            assert!((5..=8).contains(&x));
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(9);
        let mut sum = 0.0;
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        // Mean ~0.5 (crude uniformity check).
        assert!((sum / 1000.0 - 0.5).abs() < 0.05);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn property_harness_reports_seed() {
        let result = std::panic::catch_unwind(|| {
            check_property("always-fails", 1, |_| panic!("boom"));
        });
        let err = result.unwrap_err();
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains("always-fails"), "{msg}");
        assert!(msg.contains("seed"), "{msg}");
    }
}
