//! `gacer-bench` — regenerates every table and figure of the paper's
//! evaluation section (see DESIGN.md §6 for the experiment index).
//!
//! Usage: `gacer-bench
//! <fig4|fig7|fig8|table2|fig9|table3|table4|placement|memory|replan|slo|throughput|elastic|calibration|all>
//! [--rounds N]`
//!
//! `placement` is this repo's multi-GPU extension: LoadBalance vs
//! InterferenceAware vs MemoryAware placement objectives over
//! heterogeneous tenant mixes. `memory` isolates the second cost
//! dimension: on a bandwidth-bound mix, occupancy-only placement pairs
//! two HBM-saturating tenants that the two-dimensional roofline
//! separates, recorded in `BENCH_memory.json` (`docs/BENCHMARKS.md`).
//! `replan` is the online-serving extension: re-plan latency and plan
//! quality vs search budget on an admit event, cold vs warm-started
//! (`docs/SEARCH.md`). `slo` is the SLO-regulation extension: interactive
//! p99 on a saturated cluster with and without tier-major issue and
//! overload shedding, recorded in `BENCH_slo.json` (`docs/SLO.md`).
//! `throughput` is the request-path extension: an open-loop offered-load
//! sweep comparing per-request vs batched completion fabrics, recorded in
//! `BENCH_throughput.json` (`docs/BENCHMARKS.md`); it takes
//! `--duration-ms`, `--rates R1,R2,...`, `--trace poisson|bursty|diurnal`,
//! `--tenants N`, `--queue-cap N`, `--seed S`, `--submitters N`, and a CI
//! floor `--min-throughput R` (exit 1 if the batched arm achieves less).
//! `elastic` is the heterogeneous-pool extension: pool-aware vs
//! homogeneous-assumption placement on a mixed A100 + T4 pool, engine
//! scale-out/scale-in, and a diurnal cluster autoscale under closed-loop
//! fire, recorded in `BENCH_elastic.json` (`docs/OPERATIONS.md`).
//! `calibration` is the online cost-model calibration extension: a
//! mis-modeled tenant mix served with and without the residual-EWMA
//! correction loop, asserting that the calibrated arm strictly improves
//! the worst per-tenant p99 and that zero observations leave every
//! decision bit-for-bit analytic, recorded in `BENCH_calibration.json`
//! (`docs/BENCHMARKS.md`).

use gacer::bench_util::experiments;
use gacer::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let experiment = args
        .positional
        .first()
        .cloned()
        .unwrap_or_else(|| "all".to_string());
    let rounds = args.opt_usize("rounds", 3);
    let ids: Vec<&str> = if experiment == "all" {
        vec![
            "fig4", "fig7", "fig8", "table2", "fig9", "table3", "table4",
            "placement", "memory", "replan", "slo", "throughput", "elastic",
            "calibration",
        ]
    } else {
        vec![experiment.as_str()]
    };
    for id in ids {
        match id {
            "fig4" => experiments::fig4(),
            "fig7" => experiments::fig7(),
            "fig8" => experiments::fig8(),
            "table2" => experiments::table2(),
            "fig9" => experiments::fig9(),
            "table3" => experiments::table3(),
            "table4" => experiments::table4(rounds),
            "placement" => experiments::placement_objectives(),
            "memory" => experiments::memory(),
            "replan" => experiments::replan(),
            "slo" => experiments::slo(),
            "throughput" => experiments::throughput(&args),
            "elastic" => experiments::elastic(),
            "calibration" => experiments::calibration(),
            other => {
                eprintln!("unknown experiment: {other}");
                std::process::exit(2);
            }
        }
        println!();
    }
}
