//! Data-flow-graph (DFG) representation of a DNN tenant.
//!
//! The paper compiles each tenant model into a DFG — an ordered list of
//! operators `M_n = [O_{n,1} .. O_{n,i}]` (§4.1) — whose per-operator
//! resource demand `W(O^B)` and duration `T(O^B)` drive all regulation.
//! Within a model, operators execute in list order (layer dependency);
//! cross-model order is what GACER regulates.

mod kind;
mod validate;

pub use kind::OpKind;
pub use validate::{validate, DfgError};


/// Identifier of an operator within one model's DFG (its list index).
pub type OpId = usize;

/// One operator instance of a tenant DFG: a kind (shape parameters) plus
/// the batch size it is deployed with. The batch is the spatial knob
/// GACER's operator-resizing regulates (Eq. 5).
#[derive(Debug, Clone, PartialEq)]
pub struct Operator {
    /// Index within the owning model's operator list.
    pub id: OpId,
    /// Layer type + static shape parameters.
    pub kind: OpKind,
    /// Deployed batch size `B` for this operator.
    pub batch: usize,
    /// Human-readable layer label (e.g. `"conv3_2"`).
    pub name: String,
}

impl Operator {
    pub fn new(id: OpId, kind: OpKind, batch: usize, name: impl Into<String>) -> Self {
        Self { id, kind, batch, name: name.into() }
    }

    /// Forward FLOPs of this operator at its deployed batch.
    pub fn flops(&self) -> f64 {
        self.kind.flops(self.batch)
    }

    /// HBM/DRAM bytes moved by this operator at its deployed batch.
    pub fn bytes(&self) -> f64 {
        self.kind.bytes(self.batch)
    }

    /// Whether the spatial regulator may decompose this operator along the
    /// batch dimension. Ops whose semantics couple examples (none in our
    /// zoo) or overhead-only ops are not chunkable.
    pub fn chunkable(&self) -> bool {
        self.batch > 1 && self.kind.chunkable()
    }
}

/// A tenant model compiled to an ordered operator list.
#[derive(Debug, Clone, PartialEq)]
pub struct Dfg {
    /// Model name (e.g. `"VGG16"`).
    pub name: String,
    /// Operators in execution (layer) order.
    pub ops: Vec<Operator>,
}

impl Dfg {
    pub fn new(name: impl Into<String>) -> Self {
        Self { name: name.into(), ops: Vec::new() }
    }

    /// Append an operator, assigning it the next id. Returns the id.
    pub fn push(&mut self, kind: OpKind, batch: usize, name: impl Into<String>) -> OpId {
        let id = self.ops.len();
        self.ops.push(Operator::new(id, kind, batch, name));
        id
    }

    pub fn len(&self) -> usize {
        self.ops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// Total forward FLOPs of the model at its deployed batches.
    pub fn total_flops(&self) -> f64 {
        self.ops.iter().map(Operator::flops).sum()
    }

    /// Total bytes moved.
    pub fn total_bytes(&self) -> f64 {
        self.ops.iter().map(Operator::bytes).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dfg {
        let mut d = Dfg::new("tiny");
        d.push(OpKind::Conv { h: 8, w: 8, cin: 3, cout: 16, k: 3, stride: 1 }, 4, "c1");
        d.push(OpKind::ReLU { elems: 8 * 8 * 16 }, 4, "r1");
        d.push(OpKind::Linear { fin: 1024, fout: 10 }, 4, "fc");
        d
    }

    #[test]
    fn push_assigns_sequential_ids() {
        let d = tiny();
        assert_eq!(d.ops.iter().map(|o| o.id).collect::<Vec<_>>(), vec![0, 1, 2]);
    }

    #[test]
    fn flops_positive_and_additive() {
        let d = tiny();
        assert!(d.total_flops() > 0.0);
        let sum: f64 = d.ops.iter().map(|o| o.flops()).sum();
        assert_eq!(d.total_flops(), sum);
    }

    #[test]
    fn conv_flops_scale_with_batch() {
        let k = OpKind::Conv { h: 8, w: 8, cin: 3, cout: 16, k: 3, stride: 1 };
        assert!((k.flops(8) / k.flops(4) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn chunkable_requires_batch_gt_one() {
        let mut d = Dfg::new("b1");
        d.push(OpKind::Linear { fin: 8, fout: 8 }, 1, "fc");
        assert!(!d.ops[0].chunkable());
    }

    #[test]
    fn validates_clean_model() {
        assert!(validate(&tiny()).is_ok());
    }
}
