//! DFG structural validation.
//!
//! Catches malformed model definitions before they reach the simulator or
//! the serving coordinator: id gaps, zero batches, empty graphs, and
//! degenerate shapes.

use super::{Dfg, OpKind};

/// Validation failure for a tenant DFG.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DfgError {
    Empty { model: String },
    IdMismatch { model: String, index: usize, id: usize },
    ZeroBatch { model: String, op: usize },
    DegenerateShape { model: String, op: usize, detail: String },
}

impl std::fmt::Display for DfgError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DfgError::Empty { model } => write!(f, "model {model}: empty DFG"),
            DfgError::IdMismatch { model, index, id } => {
                write!(f, "model {model}: op at index {index} has id {id}")
            }
            DfgError::ZeroBatch { model, op } => {
                write!(f, "model {model}: op {op} has batch 0")
            }
            DfgError::DegenerateShape { model, op, detail } => {
                write!(f, "model {model}: op {op} degenerate shape: {detail}")
            }
        }
    }
}

impl std::error::Error for DfgError {}

fn shape_ok(kind: &OpKind) -> Result<(), String> {
    let bad = |what: &str| Err(what.to_string());
    match *kind {
        OpKind::Conv { h, w, cin, cout, k, stride } => {
            if h == 0 || w == 0 || cin == 0 || cout == 0 || k == 0 || stride == 0 {
                return bad("zero conv dim");
            }
            Ok(())
        }
        OpKind::DwConv { h, w, c, k } => {
            if h == 0 || w == 0 || c == 0 || k == 0 {
                return bad("zero dwconv dim");
            }
            Ok(())
        }
        OpKind::Linear { fin, fout } => {
            if fin == 0 || fout == 0 {
                return bad("zero linear dim");
            }
            Ok(())
        }
        OpKind::BatchNorm { elems }
        | OpKind::ReLU { elems }
        | OpKind::Add { elems }
        | OpKind::Softmax { elems }
        | OpKind::Chunk { elems }
        | OpKind::Concat { elems } => {
            if elems == 0 {
                return bad("zero element count");
            }
            Ok(())
        }
        OpKind::Pool { h, w, c, k } => {
            if h == 0 || w == 0 || c == 0 || k == 0 {
                return bad("zero pool dim");
            }
            Ok(())
        }
        OpKind::Embed { seq, dim } => {
            if seq == 0 || dim == 0 {
                return bad("zero embed dim");
            }
            Ok(())
        }
        OpKind::LstmCell { i, h } => {
            if i == 0 || h == 0 {
                return bad("zero lstm dim");
            }
            Ok(())
        }
        OpKind::Attention { seq, dim } => {
            if seq == 0 || dim == 0 {
                return bad("zero attention dim");
            }
            Ok(())
        }
    }
}

/// Validate a tenant DFG. Returns the first violation found.
pub fn validate(dfg: &Dfg) -> Result<(), DfgError> {
    if dfg.ops.is_empty() {
        return Err(DfgError::Empty { model: dfg.name.clone() });
    }
    for (index, op) in dfg.ops.iter().enumerate() {
        if op.id != index {
            return Err(DfgError::IdMismatch { model: dfg.name.clone(), index, id: op.id });
        }
        if op.batch == 0 {
            return Err(DfgError::ZeroBatch { model: dfg.name.clone(), op: index });
        }
        if let Err(detail) = shape_ok(&op.kind) {
            return Err(DfgError::DegenerateShape { model: dfg.name.clone(), op: index, detail });
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::Operator;

    #[test]
    fn empty_rejected() {
        let d = Dfg::new("e");
        assert!(matches!(validate(&d), Err(DfgError::Empty { .. })));
    }

    #[test]
    fn id_gap_rejected() {
        let mut d = Dfg::new("g");
        d.ops.push(Operator::new(1, OpKind::ReLU { elems: 4 }, 1, "r"));
        assert!(matches!(validate(&d), Err(DfgError::IdMismatch { .. })));
    }

    #[test]
    fn zero_batch_rejected() {
        let mut d = Dfg::new("z");
        d.ops.push(Operator::new(0, OpKind::ReLU { elems: 4 }, 0, "r"));
        assert!(matches!(validate(&d), Err(DfgError::ZeroBatch { .. })));
    }

    #[test]
    fn degenerate_conv_rejected() {
        let mut d = Dfg::new("d");
        d.push(OpKind::Conv { h: 0, w: 1, cin: 1, cout: 1, k: 1, stride: 1 }, 1, "c");
        assert!(matches!(validate(&d), Err(DfgError::DegenerateShape { .. })));
    }

    #[test]
    fn error_display_mentions_model() {
        let e = DfgError::Empty { model: "m".into() };
        assert!(e.to_string().contains('m'));
    }
}
