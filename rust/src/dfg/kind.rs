//! Operator kinds and their analytic FLOP / byte counts.
//!
//! The cost model (`crate::profile`) maps these counts to SM occupancy
//! `W(O^B)` and duration `T(O^B)` per platform — the lookup-table role of
//! the paper's Fig. 4 profiling.


const F32: f64 = 4.0; // bytes per element, fp32 serving

/// Layer type with static (batch-independent) shape parameters.
///
/// Spatial sizes are *output* spatial dims for convs; `elems` counts are
/// per-example.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpKind {
    /// 2-D convolution producing an `h x w x cout` map from `cin` channels
    /// with a `k x k` kernel (stride already folded into `h`/`w`).
    Conv { h: usize, w: usize, cin: usize, cout: usize, k: usize, stride: usize },
    /// Depthwise convolution (MobileNet class): one filter per channel.
    DwConv { h: usize, w: usize, c: usize, k: usize },
    /// Fully connected `fin -> fout`.
    Linear { fin: usize, fout: usize },
    /// Inference batchnorm over `elems` per-example elements.
    BatchNorm { elems: usize },
    /// Element-wise activation over `elems` per-example elements.
    ReLU { elems: usize },
    /// Pooling over an `h x w x c` input map, `k x k` window.
    Pool { h: usize, w: usize, c: usize, k: usize },
    /// Residual/element-wise add over `elems` per-example elements.
    Add { elems: usize },
    /// Embedding lookup: `seq` tokens into `dim`-wide vectors.
    Embed { seq: usize, dim: usize },
    /// One LSTM step: input `i`, hidden `h` (4 gates).
    LstmCell { i: usize, h: usize },
    /// Single-head self-attention over `seq` tokens of width `dim`.
    Attention { seq: usize, dim: usize },
    /// Softmax over `elems` per-example elements.
    Softmax { elems: usize },
    /// Batch-dim split overhead op introduced by spatial regulation
    /// (`torch.chunk` analogue): moves `elems` per-example elements.
    Chunk { elems: usize },
    /// Batch-dim concat overhead op (`torch.cat` analogue).
    Concat { elems: usize },
}

impl OpKind {
    /// Forward FLOPs at batch `b` (multiply-accumulate = 2 FLOPs).
    pub fn flops(&self, b: usize) -> f64 {
        let b = b as f64;
        match *self {
            OpKind::Conv { h, w, cin, cout, k, .. } => {
                b * 2.0 * (h * w * cout * cin * k * k) as f64
            }
            OpKind::DwConv { h, w, c, k } => b * 2.0 * (h * w * c * k * k) as f64,
            OpKind::Linear { fin, fout } => b * 2.0 * (fin * fout) as f64,
            OpKind::BatchNorm { elems } => b * 2.0 * elems as f64,
            OpKind::ReLU { elems } => b * elems as f64,
            OpKind::Pool { h, w, c, k } => b * (h * w * c * k * k) as f64,
            OpKind::Add { elems } => b * elems as f64,
            OpKind::Embed { seq, dim } => b * (seq * dim) as f64,
            OpKind::LstmCell { i, h } => b * 2.0 * (4 * h * (i + h)) as f64,
            OpKind::Attention { seq, dim } => {
                // 4 projections + QK^T + AV.
                b * 2.0 * ((4 * seq * dim * dim) + 2 * seq * seq * dim) as f64
            }
            OpKind::Softmax { elems } => b * 5.0 * elems as f64,
            OpKind::Chunk { elems } | OpKind::Concat { elems } => b * elems as f64,
        }
    }

    /// HBM bytes moved at batch `b` (activations in+out plus weights).
    pub fn bytes(&self, b: usize) -> f64 {
        self.weight_bytes() + self.activation_bytes(b)
    }

    /// Resident parameter bytes (batch-independent: weights live in HBM
    /// for the lifetime of the tenant).
    pub fn weight_bytes(&self) -> f64 {
        match *self {
            OpKind::Conv { cin, cout, k, .. } => (k * k * cin * cout) as f64 * F32,
            OpKind::DwConv { c, k, .. } => (k * k * c) as f64 * F32,
            OpKind::Linear { fin, fout } => (fin * fout) as f64 * F32,
            OpKind::LstmCell { i, h } => (4 * h * (i + h)) as f64 * F32,
            OpKind::Attention { dim, .. } => (4 * dim * dim) as f64 * F32,
            OpKind::BatchNorm { .. }
            | OpKind::ReLU { .. }
            | OpKind::Pool { .. }
            | OpKind::Add { .. }
            | OpKind::Embed { .. }
            | OpKind::Softmax { .. }
            | OpKind::Chunk { .. }
            | OpKind::Concat { .. } => 0.0,
        }
    }

    /// Activation bytes moved at batch `b` (input + output working set;
    /// scales with the executed micro-batch, so chunking shrinks it).
    pub fn activation_bytes(&self, b: usize) -> f64 {
        let bf = b as f64;
        match *self {
            OpKind::Conv { h, w, cin, cout, k: _, stride } => {
                let input = (h * stride * w * stride * cin) as f64;
                let output = (h * w * cout) as f64;
                bf * (input + output) * F32
            }
            OpKind::DwConv { h, w, c, .. } => bf * (2 * h * w * c) as f64 * F32,
            OpKind::Linear { fin, fout } => bf * (fin + fout) as f64 * F32,
            OpKind::BatchNorm { elems } | OpKind::ReLU { elems } | OpKind::Add { elems } => {
                bf * (2 * elems) as f64 * F32
            }
            OpKind::Pool { h, w, c, k } => bf * ((h * w * c * k * k) + h * w * c) as f64 * F32,
            OpKind::Embed { seq, dim } => bf * (2 * seq * dim) as f64 * F32,
            OpKind::LstmCell { i, h } => bf * (i + 5 * h) as f64 * F32,
            OpKind::Attention { seq, dim } => bf * (6 * seq * dim + seq * seq) as f64 * F32,
            OpKind::Softmax { elems } => bf * (2 * elems) as f64 * F32,
            OpKind::Chunk { elems } | OpKind::Concat { elems } => {
                bf * (2 * elems) as f64 * F32
            }
        }
    }

    /// Output elements per example (drives the occupancy estimate).
    pub fn out_elems(&self) -> usize {
        match *self {
            OpKind::Conv { h, w, cout, .. } => h * w * cout,
            OpKind::DwConv { h, w, c, .. } => h * w * c,
            OpKind::Linear { fout, .. } => fout,
            OpKind::BatchNorm { elems }
            | OpKind::ReLU { elems }
            | OpKind::Add { elems }
            | OpKind::Softmax { elems }
            | OpKind::Chunk { elems }
            | OpKind::Concat { elems } => elems,
            OpKind::Pool { h, w, c, .. } => h * w * c,
            OpKind::Embed { seq, dim } => seq * dim,
            // The cell's parallel output is the 4-gate GEMM, not just h.
            OpKind::LstmCell { h, .. } => 4 * h,
            OpKind::Attention { seq, dim } => seq * dim,
        }
    }

    /// Whether batch-dim decomposition preserves semantics cheaply.
    pub fn chunkable(&self) -> bool {
        !matches!(self, OpKind::Chunk { .. } | OpKind::Concat { .. })
    }

    /// Short class label used in traces and reports.
    pub fn class(&self) -> &'static str {
        match self {
            OpKind::Conv { .. } => "conv",
            OpKind::DwConv { .. } => "dwconv",
            OpKind::Linear { .. } => "linear",
            OpKind::BatchNorm { .. } => "bn",
            OpKind::ReLU { .. } => "relu",
            OpKind::Pool { .. } => "pool",
            OpKind::Add { .. } => "add",
            OpKind::Embed { .. } => "embed",
            OpKind::LstmCell { .. } => "lstm",
            OpKind::Attention { .. } => "attn",
            OpKind::Softmax { .. } => "softmax",
            OpKind::Chunk { .. } => "chunk",
            OpKind::Concat { .. } => "concat",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_flops_formula() {
        // 1x1 output, 1 cin, 1 cout, 1x1 kernel, batch 1 => 2 FLOPs.
        let k = OpKind::Conv { h: 1, w: 1, cin: 1, cout: 1, k: 1, stride: 1 };
        assert_eq!(k.flops(1), 2.0);
    }

    #[test]
    fn linear_bytes_include_weights() {
        let k = OpKind::Linear { fin: 100, fout: 10 };
        // weights dominate at batch 1: 1000 * 4 bytes.
        assert!(k.bytes(1) > 4000.0);
    }

    #[test]
    fn dwconv_much_cheaper_than_conv() {
        let c = OpKind::Conv { h: 16, w: 16, cin: 64, cout: 64, k: 3, stride: 1 };
        let d = OpKind::DwConv { h: 16, w: 16, c: 64, k: 3 };
        assert!(c.flops(1) / d.flops(1) > 32.0);
    }

    #[test]
    fn relu_is_bandwidth_bound() {
        let k = OpKind::ReLU { elems: 1 << 20 };
        // bytes/flops ratio >> 1: the Fig. 4 "BN/ReLU" class.
        assert!(k.bytes(1) / k.flops(1) > 4.0);
    }

    #[test]
    fn overhead_ops_not_chunkable() {
        assert!(!OpKind::Chunk { elems: 8 }.chunkable());
        assert!(!OpKind::Concat { elems: 8 }.chunkable());
        assert!(OpKind::Conv { h: 1, w: 1, cin: 1, cout: 1, k: 1, stride: 1 }.chunkable());
    }

    #[test]
    fn bytes_is_weights_plus_activations() {
        let kinds = [
            OpKind::Conv { h: 8, w: 8, cin: 32, cout: 64, k: 3, stride: 2 },
            OpKind::DwConv { h: 8, w: 8, c: 32, k: 3 },
            OpKind::Linear { fin: 128, fout: 64 },
            OpKind::BatchNorm { elems: 512 },
            OpKind::LstmCell { i: 64, h: 128 },
            OpKind::Attention { seq: 32, dim: 16 },
            OpKind::Pool { h: 8, w: 8, c: 32, k: 2 },
            OpKind::Chunk { elems: 256 },
        ];
        for k in kinds {
            for b in [1usize, 4, 32] {
                let total = k.bytes(b);
                let split = k.weight_bytes() + k.activation_bytes(b);
                assert!((total - split).abs() < 1e-9, "{k:?} b={b}");
            }
        }
    }

    #[test]
    fn weight_bytes_batch_independent_elementwise_weightless() {
        assert_eq!(OpKind::ReLU { elems: 1 << 20 }.weight_bytes(), 0.0);
        assert_eq!(OpKind::BatchNorm { elems: 1 << 20 }.weight_bytes(), 0.0);
        let lin = OpKind::Linear { fin: 100, fout: 10 };
        // weights don't scale with batch; activations do.
        assert_eq!(lin.weight_bytes(), 4000.0);
        assert!(lin.activation_bytes(8) > lin.activation_bytes(1) * 7.9);
    }

    #[test]
    fn attention_flops_grow_quadratic_in_seq() {
        let a1 = OpKind::Attention { seq: 16, dim: 8 };
        let a2 = OpKind::Attention { seq: 32, dim: 8 };
        assert!(a2.flops(1) / a1.flops(1) > 2.0);
    }
}
