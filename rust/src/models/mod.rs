//! Tenant model zoo — the ten models of the paper's §5.1 evaluation,
//! compiled to operator-level DFGs with layer-accurate shapes:
//!
//! vision (224×224×3): AlexNet, VGG16, ResNet18/34/50/101, MobileNetV3,
//! DenseNet121; language: LSTM; recommendation: BST (behavior-sequence
//! transformer).
//!
//! These DFGs drive the cost model, the simulator, and the regulation
//! search exactly as the paper's PyTorch-exported graphs drive its runtime.

mod builder;
mod sequence;
mod vision;
pub mod zoo;

pub use builder::VisionBuilder;
