//! Vision model builders (224×224×3 inputs, ImageNet-shaped heads).
//!
//! Layer structures follow the torchvision reference implementations the
//! paper collects its tenants from (§5.1); shapes are layer-accurate so the
//! cost model sees the real occupancy/duration heterogeneity each
//! combination exhibits.

use super::builder::VisionBuilder;
use crate::dfg::Dfg;

/// AlexNet: 5 convs + 3 FCs (the paper's "Alex").
pub fn alexnet(batch: usize) -> Dfg {
    let mut b = VisionBuilder::new("Alex", batch, 224, 224, 3);
    b.conv(11, 96, 4).relu().pool(2);
    b.conv(5, 256, 1).relu().pool(2);
    b.conv(3, 384, 1).relu();
    b.conv(3, 384, 1).relu();
    b.conv(3, 256, 1).relu().pool(2);
    b.fc(4096).relu().fc(4096).relu().fc(1000);
    b.finish()
}

/// VGG16: 13 convs + 3 FCs ("V16").
pub fn vgg16(batch: usize) -> Dfg {
    let mut b = VisionBuilder::new("V16", batch, 224, 224, 3);
    for (reps, cout) in [(2, 64), (2, 128), (3, 256), (3, 512), (3, 512)] {
        for _ in 0..reps {
            b.conv(3, cout, 1).relu();
        }
        b.pool(2);
    }
    b.fc(4096).relu().fc(4096).relu().fc(1000);
    b.finish()
}

/// ResNet basic block: conv-bn-relu-conv-bn-add-relu.
fn basic_block(b: &mut VisionBuilder, cout: usize, stride: usize) {
    b.conv(3, cout, stride).bn().relu();
    b.conv(3, cout, 1).bn().add().relu();
}

/// ResNet bottleneck block: 1x1 down, 3x3, 1x1 up (4x).
fn bottleneck(b: &mut VisionBuilder, width: usize, stride: usize) {
    b.conv(1, width, 1).bn().relu();
    b.conv(3, width, stride).bn().relu();
    b.conv(1, width * 4, 1).bn().add().relu();
}

fn resnet_stem(name: &str, batch: usize) -> VisionBuilder {
    let mut b = VisionBuilder::new(name, batch, 224, 224, 3);
    b.conv(7, 64, 2).bn().relu().pool(2);
    b
}

/// ResNet-18 ("R18"): [2, 2, 2, 2] basic blocks.
pub fn resnet18(batch: usize) -> Dfg {
    let mut b = resnet_stem("R18", batch);
    for (i, (n, c)) in [(2usize, 64), (2, 128), (2, 256), (2, 512)].iter().enumerate() {
        for j in 0..*n {
            basic_block(&mut b, *c, if i > 0 && j == 0 { 2 } else { 1 });
        }
    }
    b.gap().fc(1000);
    b.finish()
}

/// ResNet-34 ("R34"): [3, 4, 6, 3] basic blocks.
pub fn resnet34(batch: usize) -> Dfg {
    let mut b = resnet_stem("R34", batch);
    for (i, (n, c)) in [(3usize, 64), (4, 128), (6, 256), (3, 512)].iter().enumerate() {
        for j in 0..*n {
            basic_block(&mut b, *c, if i > 0 && j == 0 { 2 } else { 1 });
        }
    }
    b.gap().fc(1000);
    b.finish()
}

/// ResNet-50 ("R50"): [3, 4, 6, 3] bottleneck blocks.
pub fn resnet50(batch: usize) -> Dfg {
    let mut b = resnet_stem("R50", batch);
    for (i, (n, w)) in [(3usize, 64), (4, 128), (6, 256), (3, 512)].iter().enumerate() {
        for j in 0..*n {
            bottleneck(&mut b, *w, if i > 0 && j == 0 { 2 } else { 1 });
        }
    }
    b.gap().fc(1000);
    b.finish()
}

/// ResNet-101 ("R101"): [3, 4, 23, 3] bottleneck blocks.
pub fn resnet101(batch: usize) -> Dfg {
    let mut b = resnet_stem("R101", batch);
    for (i, (n, w)) in [(3usize, 64), (4, 128), (23, 256), (3, 512)].iter().enumerate() {
        for j in 0..*n {
            bottleneck(&mut b, *w, if i > 0 && j == 0 { 2 } else { 1 });
        }
    }
    b.gap().fc(1000);
    b.finish()
}

/// MobileNetV3-Large ("M3"): inverted-residual bnecks with depthwise convs.
pub fn mobilenet_v3(batch: usize) -> Dfg {
    let mut b = VisionBuilder::new("M3", batch, 224, 224, 3);
    b.conv(3, 16, 2).bn().relu();
    // (expand, out, kernel, stride) per bneck — MobileNetV3-Large table.
    let bnecks: &[(usize, usize, usize, usize)] = &[
        (16, 16, 3, 1),
        (64, 24, 3, 2),
        (72, 24, 3, 1),
        (72, 40, 5, 2),
        (120, 40, 5, 1),
        (120, 40, 5, 1),
        (240, 80, 3, 2),
        (200, 80, 3, 1),
        (184, 80, 3, 1),
        (184, 80, 3, 1),
        (480, 112, 3, 1),
        (672, 112, 3, 1),
        (672, 160, 5, 2),
        (960, 160, 5, 1),
        (960, 160, 5, 1),
    ];
    for &(expand, out, k, stride) in bnecks {
        b.conv(1, expand, 1).bn().relu(); // expand
        b.dwconv(k, stride).bn().relu(); // depthwise
        b.conv(1, out, 1).bn(); // project
        if stride == 1 {
            b.add();
        }
    }
    b.conv(1, 960, 1).bn().relu();
    b.gap().fc(1280).relu().fc(1000);
    b.finish()
}

/// DenseNet-121 ("D121"): dense blocks [6, 12, 24, 16], growth 32.
pub fn densenet121(batch: usize) -> Dfg {
    const GROWTH: usize = 32;
    let mut b = VisionBuilder::new("D121", batch, 224, 224, 3);
    b.conv(7, 64, 2).bn().relu().pool(2);
    let mut channels = 64usize;
    for (bi, layers) in [6usize, 12, 24, 16].iter().enumerate() {
        for _ in 0..*layers {
            // bn-relu-1x1(4k)-bn-relu-3x3(k)-concat
            b.bn().relu().conv(1, 4 * GROWTH, 1);
            b.bn().relu().conv(3, GROWTH, 1);
            channels += GROWTH;
            b.concat_to(channels);
        }
        if bi < 3 {
            // transition: bn-1x1(half)-pool
            channels /= 2;
            b.bn().conv(1, channels, 1).pool(2);
        }
    }
    b.bn().relu().gap().fc(1000);
    b.finish()
}

/// TinyCNN — the e2e serving model (`python/compile/model.py`): 3 convs
/// with BN/pool on a 32×32×3 input plus 2 FCs to 10 logits. This DFG is
/// the cost-model proxy the engine searches over when deploying the real
/// AOT-compiled `tiny_cnn` artifacts.
pub fn tiny_cnn(batch: usize) -> Dfg {
    let mut b = VisionBuilder::new("TinyCNN", batch, 32, 32, 3);
    b.conv(3, 16, 1).relu().bn().pool(2); // 16x16x16
    b.conv(3, 32, 1).relu().pool(2); // 8x8x32
    b.conv(3, 32, 1).relu().pool(2); // 4x4x32
    b.fc(64).relu().fc(10);
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::validate;

    #[test]
    fn all_vision_models_validate() {
        for d in [
            alexnet(8),
            vgg16(8),
            resnet18(8),
            resnet34(8),
            resnet50(8),
            resnet101(8),
            mobilenet_v3(8),
            densenet121(8),
        ] {
            validate(&d).unwrap_or_else(|e| panic!("{}: {e}", d.name));
        }
    }

    #[test]
    fn op_count_ordering_matches_depth() {
        // Paper: ALEX+V16+R18 is 10~30 ops per model; R101/D121 exceed 100.
        assert!(alexnet(8).len() >= 10 && alexnet(8).len() <= 30);
        assert!(vgg16(8).len() >= 20 && vgg16(8).len() <= 40);
        assert!(resnet101(8).len() > resnet50(8).len());
        assert!(densenet121(8).len() > 100);
    }

    #[test]
    fn r101_d121_m3_combo_exceeds_200_ops() {
        let total = resnet101(8).len() + densenet121(8).len() + mobilenet_v3(8).len();
        assert!(total > 200, "combo ops = {total}");
    }

    #[test]
    fn vgg_flops_in_published_band() {
        // ~15.5 GMACs/image published (commonly quoted as "15.5 GFLOPs");
        // we count 2 FLOPs per MAC.
        let gmacs = vgg16(1).total_flops() / 2e9;
        assert!((10.0..20.0).contains(&gmacs), "VGG16 = {gmacs} GMACs");
    }

    #[test]
    fn resnet50_flops_in_published_band() {
        // ~4.1 GMACs/image published (conv core; FC/downsample variance
        // tolerated).
        let gmacs = resnet50(1).total_flops() / 2e9;
        assert!((2.5..6.5).contains(&gmacs), "R50 = {gmacs} GMACs");
    }

    #[test]
    fn mobilenet_much_lighter_than_vgg() {
        assert!(vgg16(1).total_flops() / mobilenet_v3(1).total_flops() > 10.0);
    }

    #[test]
    fn batch_propagates_to_all_ops() {
        let d = resnet18(4);
        assert!(d.ops.iter().all(|o| o.batch == 4));
    }
}
