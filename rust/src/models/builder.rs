//! Small DSL for assembling vision DFGs while tracking spatial shape.

use crate::dfg::{Dfg, OpKind};

/// Tracks the activation shape (h, w, c) while appending layers.
pub struct VisionBuilder {
    pub dfg: Dfg,
    pub batch: usize,
    pub h: usize,
    pub w: usize,
    pub c: usize,
    layer: usize,
}

impl VisionBuilder {
    pub fn new(name: &str, batch: usize, h: usize, w: usize, c: usize) -> Self {
        VisionBuilder { dfg: Dfg::new(name), batch, h, w, c, layer: 0 }
    }

    fn next(&mut self, prefix: &str) -> String {
        self.layer += 1;
        format!("{prefix}{}", self.layer)
    }

    /// `k x k` convolution to `cout` channels, SAME padding, given stride.
    pub fn conv(&mut self, k: usize, cout: usize, stride: usize) -> &mut Self {
        self.h = self.h.div_ceil(stride);
        self.w = self.w.div_ceil(stride);
        let kind = OpKind::Conv { h: self.h, w: self.w, cin: self.c, cout, k, stride };
        self.c = cout;
        let name = self.next("conv");
        self.dfg.push(kind, self.batch, name);
        self
    }

    /// Depthwise `k x k` convolution, SAME padding.
    pub fn dwconv(&mut self, k: usize, stride: usize) -> &mut Self {
        self.h = self.h.div_ceil(stride);
        self.w = self.w.div_ceil(stride);
        let kind = OpKind::DwConv { h: self.h, w: self.w, c: self.c, k };
        let name = self.next("dwconv");
        self.dfg.push(kind, self.batch, name);
        self
    }

    pub fn bn(&mut self) -> &mut Self {
        let kind = OpKind::BatchNorm { elems: self.h * self.w * self.c };
        let name = self.next("bn");
        self.dfg.push(kind, self.batch, name);
        self
    }

    pub fn relu(&mut self) -> &mut Self {
        let kind = OpKind::ReLU { elems: self.h * self.w * self.c };
        let name = self.next("relu");
        self.dfg.push(kind, self.batch, name);
        self
    }

    /// `k x k` max/avg pool with stride `k`.
    pub fn pool(&mut self, k: usize) -> &mut Self {
        let kind = OpKind::Pool { h: self.h / k, w: self.w / k, c: self.c, k };
        self.h /= k;
        self.w /= k;
        let name = self.next("pool");
        self.dfg.push(kind, self.batch, name);
        self
    }

    /// Residual add at the current shape.
    pub fn add(&mut self) -> &mut Self {
        let kind = OpKind::Add { elems: self.h * self.w * self.c };
        let name = self.next("add");
        self.dfg.push(kind, self.batch, name);
        self
    }

    /// Channel concat to `c_new` total channels (DenseNet).
    pub fn concat_to(&mut self, c_new: usize) -> &mut Self {
        self.c = c_new;
        let kind = OpKind::Concat { elems: self.h * self.w * self.c };
        let name = self.next("cat");
        self.dfg.push(kind, self.batch, name);
        self
    }

    /// Global average pool to a `c`-vector.
    pub fn gap(&mut self) -> &mut Self {
        let kind = OpKind::Pool { h: 1, w: 1, c: self.c, k: self.h };
        self.h = 1;
        self.w = 1;
        let name = self.next("gap");
        self.dfg.push(kind, self.batch, name);
        self
    }

    /// Fully connected layer from the flattened activation.
    pub fn fc(&mut self, fout: usize) -> &mut Self {
        let fin = self.h * self.w * self.c;
        self.h = 1;
        self.w = 1;
        self.c = fout;
        let kind = OpKind::Linear { fin, fout };
        let name = self.next("fc");
        self.dfg.push(kind, self.batch, name);
        self
    }

    pub fn finish(self) -> Dfg {
        self.dfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_stride_updates_shape() {
        let mut b = VisionBuilder::new("t", 1, 224, 224, 3);
        b.conv(7, 64, 2);
        assert_eq!((b.h, b.w, b.c), (112, 112, 64));
    }

    #[test]
    fn pool_halves() {
        let mut b = VisionBuilder::new("t", 1, 8, 8, 4);
        b.pool(2);
        assert_eq!((b.h, b.w), (4, 4));
    }

    #[test]
    fn fc_flattens() {
        let mut b = VisionBuilder::new("t", 1, 4, 4, 8);
        b.fc(10);
        match b.dfg.ops.last().unwrap().kind {
            OpKind::Linear { fin, fout } => {
                assert_eq!(fin, 128);
                assert_eq!(fout, 10);
            }
            _ => panic!("expected linear"),
        }
    }

    #[test]
    fn names_are_sequential() {
        let mut b = VisionBuilder::new("t", 1, 8, 8, 3);
        b.conv(3, 4, 1).relu().conv(3, 8, 1);
        let names: Vec<_> = b.dfg.ops.iter().map(|o| o.name.clone()).collect();
        assert_eq!(names, vec!["conv1", "relu2", "conv3"]);
    }
}
