//! Sequence-model tenants: the LSTM language model (ML2020spring emotion
//! classification) and the BST transformer recommender (Amazon *Book*) of
//! §5.1. Both are dominated by small, low-occupancy GEMMs — the "low SM
//! occupation" models whose combos stress temporal (not spatial) regulation
//! in the paper's analysis of Fig. 7.

use crate::dfg::{Dfg, OpKind};

/// LSTM emotion classifier: embedding + `seq_len` recurrent steps + FC
/// head (a compact text classifier, per the ML2020spring emotion task).
/// Default serving batch in the paper's runs is 128.
pub fn lstm(batch: usize) -> Dfg {
    lstm_with(batch, 32, 192, 512)
}

/// Parameterized LSTM: `seq_len` steps, embed width `embed`, hidden `h`.
pub fn lstm_with(batch: usize, seq_len: usize, embed: usize, h: usize) -> Dfg {
    let mut d = Dfg::new("LSTM");
    d.push(OpKind::Embed { seq: seq_len, dim: embed }, batch, "embed");
    for t in 0..seq_len {
        d.push(OpKind::LstmCell { i: embed, h }, batch, format!("lstm_t{t}"));
    }
    d.push(OpKind::Linear { fin: h, fout: 64 }, batch, "fc1");
    d.push(OpKind::ReLU { elems: 64 }, batch, "relu1");
    d.push(OpKind::Linear { fin: 64, fout: 2 }, batch, "fc_out");
    d
}

/// Behavior-Sequence Transformer recommender: item embedding + transformer
/// block(s) + 3-layer MLP head (the Alibaba BST architecture). Default
/// serving batch is 64.
pub fn bst(batch: usize) -> Dfg {
    bst_with(batch, 48, 128, 2)
}

/// Parameterized BST: `seq` behavior length, `dim` embedding width,
/// `blocks` transformer blocks.
pub fn bst_with(batch: usize, seq: usize, dim: usize, blocks: usize) -> Dfg {
    let mut d = Dfg::new("BST");
    d.push(OpKind::Embed { seq, dim }, batch, "embed");
    for blk in 0..blocks {
        d.push(OpKind::Attention { seq, dim }, batch, format!("attn{blk}"));
        d.push(OpKind::Add { elems: seq * dim }, batch, format!("res{blk}a"));
        d.push(OpKind::BatchNorm { elems: seq * dim }, batch, format!("ln{blk}a"));
        d.push(OpKind::Linear { fin: dim, fout: 4 * dim }, batch, format!("ffn{blk}_up"));
        d.push(OpKind::ReLU { elems: 4 * dim }, batch, format!("ffn{blk}_act"));
        d.push(OpKind::Linear { fin: 4 * dim, fout: dim }, batch, format!("ffn{blk}_down"));
        d.push(OpKind::Add { elems: seq * dim }, batch, format!("res{blk}b"));
        d.push(OpKind::BatchNorm { elems: seq * dim }, batch, format!("ln{blk}b"));
    }
    // MLP head over the flattened sequence (BST: leaky-relu stack).
    d.push(OpKind::Linear { fin: seq * dim, fout: 1024 }, batch, "head_fc1");
    d.push(OpKind::ReLU { elems: 1024 }, batch, "head_act1");
    d.push(OpKind::Linear { fin: 1024, fout: 512 }, batch, "head_fc2");
    d.push(OpKind::ReLU { elems: 512 }, batch, "head_act2");
    d.push(OpKind::Linear { fin: 512, fout: 1 }, batch, "head_out");
    d
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::validate;
    use crate::profile::{CostModel, Platform};

    #[test]
    fn sequence_models_validate() {
        validate(&lstm(128)).unwrap();
        validate(&bst(64)).unwrap();
    }

    #[test]
    fn lstm_has_one_cell_per_timestep() {
        let d = lstm_with(8, 16, 64, 128);
        let cells = d.ops.iter().filter(|o| o.kind.class() == "lstm").count();
        assert_eq!(cells, 16);
    }

    #[test]
    fn bst_block_count_scales() {
        let ops1 = bst_with(8, 16, 32, 1).len();
        let ops3 = bst_with(8, 16, 32, 3).len();
        assert_eq!(ops3 - ops1, 2 * 8); // 8 ops per block
    }

    #[test]
    fn sequence_models_have_low_occupancy() {
        // The paper's premise for R34+LSTM+BST: these tenants occupy few
        // SMs, leaving residue that spatial decomposition cannot fill.
        let m = CostModel::new(Platform::titan_v());
        let d = lstm(128);
        let max_w = d
            .ops
            .iter()
            .map(|o| m.cost(o).sm_occupancy)
            .fold(0.0f64, f64::max);
        assert!(max_w < 100.0, "LSTM max occupancy {max_w}");
    }
}
