//! Named model registry + the paper's five evaluation combinations.

use crate::dfg::Dfg;

use super::{sequence, vision};

/// All model names the zoo can build (the paper's §5.1 selection).
pub const MODEL_NAMES: [&str; 10] =
    ["Alex", "V16", "R18", "R34", "R50", "R101", "M3", "D121", "LSTM", "BST"];

/// Build a model DFG by its paper abbreviation at the given batch size.
pub fn build(name: &str, batch: usize) -> Option<Dfg> {
    Some(match name {
        "Alex" => vision::alexnet(batch),
        "V16" => vision::vgg16(batch),
        "R18" => vision::resnet18(batch),
        "R34" => vision::resnet34(batch),
        "R50" => vision::resnet50(batch),
        "R101" => vision::resnet101(batch),
        "M3" => vision::mobilenet_v3(batch),
        "D121" => vision::densenet121(batch),
        "LSTM" => sequence::lstm(batch),
        "BST" => sequence::bst(batch),
        _ => return None,
    })
}

/// Default serving batch per model class (§5.4: vision 8, language 128,
/// recommendation 64).
pub fn default_batch(name: &str) -> usize {
    match name {
        "LSTM" => 128,
        "BST" => 64,
        _ => 8,
    }
}

/// Build a model at its default batch.
pub fn build_default(name: &str) -> Option<Dfg> {
    build(name, default_batch(name))
}

/// The five multi-tenant combinations of Fig. 7 / Table 2.
pub const PAPER_COMBOS: [[&str; 3]; 5] = [
    ["Alex", "V16", "R18"],
    ["D121", "V16", "LSTM"],
    ["R50", "V16", "M3"],
    ["R101", "D121", "M3"],
    ["R34", "LSTM", "BST"],
];

/// Build one paper combo (default batches) as a tenant list.
pub fn build_combo(names: &[&str]) -> Vec<Dfg> {
    names
        .iter()
        .map(|n| build_default(n).unwrap_or_else(|| panic!("unknown model {n}")))
        .collect()
}

/// Display name of a combo (`"R50+V16+M3"`).
pub fn combo_label(names: &[&str]) -> String {
    names.join("+")
}

/// DFG proxy for a serving artifact family (manifest `meta.op`): the
/// model the engine prices and searches when deploying that family's
/// AOT-compiled artifacts.
pub fn serving_proxy(family: &str, batch: usize) -> Option<Dfg> {
    match family {
        "tiny_cnn" => Some(vision::tiny_cnn(batch)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::validate;

    #[test]
    fn every_registered_model_builds_and_validates() {
        for name in MODEL_NAMES {
            let d = build_default(name).unwrap();
            validate(&d).unwrap();
            assert_eq!(d.name, name);
        }
    }

    #[test]
    fn unknown_model_is_none() {
        assert!(build("GPT4", 8).is_none());
    }

    #[test]
    fn default_batches_match_paper() {
        assert_eq!(default_batch("V16"), 8);
        assert_eq!(default_batch("LSTM"), 128);
        assert_eq!(default_batch("BST"), 64);
    }

    #[test]
    fn all_paper_combos_build() {
        for combo in PAPER_COMBOS {
            let tenants = build_combo(&combo);
            assert_eq!(tenants.len(), 3);
        }
    }

    #[test]
    fn combo_label_format() {
        assert_eq!(combo_label(&PAPER_COMBOS[2]), "R50+V16+M3");
    }
}
