//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime. One entry per HLO-text artifact with input/output specs.

use std::collections::BTreeMap;
use std::path::Path;

use crate::error::{Error, Result};
use crate::util::json::Json;

fn malformed(msg: impl Into<String>) -> Error {
    Error::Artifact(msg.into())
}

/// Tensor shape + dtype as the manifest records them.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(v: &Json) -> Result<Self> {
        Ok(TensorSpec {
            shape: v
                .get("shape")
                .and_then(Json::as_usize_vec)
                .ok_or_else(|| malformed("spec missing shape"))?,
            dtype: v
                .get("dtype")
                .and_then(Json::as_str)
                .ok_or_else(|| malformed("spec missing dtype"))?
                .to_string(),
        })
    }
}

/// One artifact entry.
#[derive(Debug, Clone, PartialEq)]
pub struct ManifestEntry {
    /// Artifact file name relative to the manifest's directory.
    pub path: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// Operator family ("tiny_cnn", "linear", ...), from `meta.op`.
    pub op: Option<String>,
    /// Batch size, from `meta.batch`.
    pub batch: Option<usize>,
    /// Chunk size for chunked variants, from `meta.chunk`.
    pub chunk: Option<usize>,
}

impl ManifestEntry {
    fn from_json(v: &Json) -> Result<Self> {
        let specs = |key: &str| -> Result<Vec<TensorSpec>> {
            v.get(key)
                .and_then(Json::as_arr)
                .ok_or_else(|| malformed(format!("entry missing {key}")))?
                .iter()
                .map(TensorSpec::from_json)
                .collect()
        };
        let meta = v.get("meta");
        Ok(ManifestEntry {
            path: v
                .get("path")
                .and_then(Json::as_str)
                .ok_or_else(|| malformed("entry missing path"))?
                .to_string(),
            inputs: specs("inputs")?,
            outputs: specs("outputs")?,
            op: meta
                .and_then(|m| m.get("op"))
                .and_then(Json::as_str)
                .map(str::to_string),
            batch: meta.and_then(|m| m.get("batch")).and_then(Json::as_usize),
            chunk: meta.and_then(|m| m.get("chunk")).and_then(Json::as_usize),
        })
    }
}

/// The full manifest (sorted map: deterministic iteration for tests/logs).
#[derive(Debug, Clone, Default)]
pub struct ArtifactManifest {
    entries: BTreeMap<String, ManifestEntry>,
}

impl ArtifactManifest {
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| malformed(format!("reading {}: {e}", path.display())))?;
        Self::parse(&text)
            .map_err(|e| malformed(format!("parsing {}: {e}", path.display())))
    }

    pub fn parse(text: &str) -> Result<Self> {
        let doc = Json::parse(text).map_err(|e| malformed(format!("{e}")))?;
        let obj = doc.as_obj().ok_or_else(|| malformed("manifest not an object"))?;
        let mut entries = BTreeMap::new();
        for (name, v) in obj {
            entries.insert(name.clone(), ManifestEntry::from_json(v)?);
        }
        Ok(ArtifactManifest { entries })
    }

    pub fn get(&self, name: &str) -> Option<&ManifestEntry> {
        self.entries.get(name)
    }

    pub fn names(&self) -> Vec<String> {
        self.entries.keys().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// All entries of an operator family, keyed by batch size — used by
    /// the batcher to pick the compiled variant for a batch. Chunked
    /// variants (meta.chunk set) are excluded; they are selected via
    /// [`Self::chunked_variants_of`].
    pub fn variants_of(&self, op: &str) -> BTreeMap<usize, String> {
        self.entries
            .iter()
            .filter(|(_, e)| e.op.as_deref() == Some(op) && e.chunk.is_none())
            .filter_map(|(name, e)| e.batch.map(|b| (b, name.clone())))
            .collect()
    }

    /// Chunked variants of a family, keyed by (batch, chunk).
    pub fn chunked_variants_of(&self, op: &str) -> BTreeMap<(usize, usize), String> {
        self.entries
            .iter()
            .filter(|(_, e)| e.op.as_deref() == Some(op))
            .filter_map(|(name, e)| {
                Some(((e.batch?, e.chunk?), name.clone()))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> ArtifactManifest {
        ArtifactManifest::parse(
            r#"{
                "tiny_cnn_b2": {
                    "path": "tiny_cnn_b2.hlo.txt",
                    "inputs": [{"shape": [2, 32, 32, 3], "dtype": "float32"}],
                    "outputs": [{"shape": [2, 10], "dtype": "float32"}],
                    "meta": {"op": "tiny_cnn", "batch": 2}
                },
                "tiny_cnn_b8": {
                    "path": "tiny_cnn_b8.hlo.txt",
                    "inputs": [{"shape": [8, 32, 32, 3], "dtype": "float32"}],
                    "outputs": [{"shape": [8, 10], "dtype": "float32"}],
                    "meta": {"op": "tiny_cnn", "batch": 8}
                },
                "linear_chunked_b32_c4": {
                    "path": "linear_chunked_b32_c4.hlo.txt",
                    "inputs": [{"shape": [32, 512], "dtype": "float32"}],
                    "outputs": [{"shape": [32, 128], "dtype": "float32"}],
                    "meta": {"op": "linear_chunked", "batch": 32, "chunk": 4}
                }
            }"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_entries() {
        let m = sample();
        assert_eq!(m.len(), 3);
        let e = m.get("tiny_cnn_b2").unwrap();
        assert_eq!(e.batch, Some(2));
        assert_eq!(e.op.as_deref(), Some("tiny_cnn"));
        assert_eq!(e.inputs[0].elems(), 2 * 32 * 32 * 3);
    }

    #[test]
    fn variants_keyed_by_batch() {
        let m = sample();
        let v = m.variants_of("tiny_cnn");
        assert_eq!(v.keys().copied().collect::<Vec<_>>(), vec![2, 8]);
        assert_eq!(v[&8], "tiny_cnn_b8");
    }

    #[test]
    fn chunked_variants_separate() {
        let m = sample();
        assert!(m.variants_of("linear_chunked").is_empty());
        let cv = m.chunked_variants_of("linear_chunked");
        assert_eq!(cv[&(32, 4)], "linear_chunked_b32_c4");
    }

    #[test]
    fn missing_entry_is_none() {
        assert!(sample().get("nope").is_none());
    }

    #[test]
    fn rejects_malformed() {
        assert!(ArtifactManifest::parse("[1,2]").is_err());
        assert!(ArtifactManifest::parse(r#"{"x": {"path": 3}}"#).is_err());
    }
}
