//! PJRT runtime: loads the HLO-text artifacts `make artifacts` produced
//! and executes them on the CPU PJRT client. This is the only place the
//! `xla` crate is touched; Python never runs on the request path.
//!
//! Interchange is HLO **text** (not serialized `HloModuleProto`): jax ≥0.5
//! emits protos with 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

mod manifest;

pub use manifest::{ArtifactManifest, ManifestEntry, TensorSpec};

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

/// Artifact-backed executor: manifest + lazily compiled executables.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: ArtifactManifest,
    cache: RefCell<HashMap<String, xla::PjRtLoadedExecutable>>,
}

impl Runtime {
    /// Open an artifact directory (must contain `manifest.json`).
    pub fn new(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = ArtifactManifest::load(dir.join("manifest.json"))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime { client, dir, manifest, cache: RefCell::new(HashMap::new()) })
    }

    pub fn manifest(&self) -> &ArtifactManifest {
        &self.manifest
    }

    /// Names of all loadable entries.
    pub fn entries(&self) -> Vec<String> {
        self.manifest.names()
    }

    pub fn entry(&self, name: &str) -> Option<&ManifestEntry> {
        self.manifest.get(name)
    }

    /// Compile (and cache) the executable for `name`.
    fn executable(&self, name: &str) -> Result<()> {
        if self.cache.borrow().contains_key(name) {
            return Ok(());
        }
        let entry = self
            .manifest
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name}"))?;
        let path = self.dir.join(&entry.path);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        self.cache.borrow_mut().insert(name.to_string(), exe);
        Ok(())
    }

    /// Eagerly compile a set of entries (server startup).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.executable(n)?;
        }
        Ok(())
    }

    /// Number of compiled executables currently cached.
    pub fn compiled_count(&self) -> usize {
        self.cache.borrow().len()
    }

    /// Execute entry `name` with f32 inputs (one flat buffer per input, in
    /// manifest order); returns the flat f32 outputs.
    ///
    /// Shape checking happens against the manifest before touching PJRT.
    pub fn execute_f32(&self, name: &str, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        let entry = self
            .manifest
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name}"))?
            .clone();
        if inputs.len() != entry.inputs.len() {
            return Err(anyhow!(
                "{name}: got {} inputs, manifest expects {}",
                inputs.len(),
                entry.inputs.len()
            ));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (buf, spec) in inputs.iter().zip(&entry.inputs) {
            if buf.len() != spec.elems() {
                return Err(anyhow!(
                    "{name}: input length {} != spec {:?}",
                    buf.len(),
                    spec.shape
                ));
            }
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(buf)
                .reshape(&dims)
                .map_err(|e| anyhow!("reshape input: {e:?}"))?;
            literals.push(lit);
        }

        self.executable(name)?;
        let cache = self.cache.borrow();
        let exe = cache.get(name).expect("just compiled");
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;

        // aot.py lowers with return_tuple=True: unpack the tuple elements.
        let n_out = entry.outputs.len();
        let elems = result
            .to_tuple()
            .map_err(|e| anyhow!("decompose tuple: {e:?}"))?;
        if elems.len() != n_out {
            return Err(anyhow!("{name}: {} outputs, manifest says {n_out}", elems.len()));
        }
        let mut out = Vec::with_capacity(n_out);
        for lit in elems {
            out.push(lit.to_vec::<f32>().map_err(|e| anyhow!("read output: {e:?}"))?);
        }
        Ok(out)
    }
}

/// Load the TinyCNN serving parameters emitted by aot.py
/// (`tiny_cnn_params.json`): flat f32 buffers in `flatten_params` order.
pub fn load_params(dir: impl AsRef<Path>) -> Result<Vec<Vec<f32>>> {
    use crate::util::json::Json;
    let path = dir.as_ref().join("tiny_cnn_params.json");
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {}", path.display()))?;
    let doc = Json::parse(&text).map_err(|e| anyhow!("{e}"))?;
    let arr = doc.as_arr().ok_or_else(|| anyhow!("params not an array"))?;
    let mut out = Vec::with_capacity(arr.len());
    for p in arr {
        let shape = p
            .get("shape")
            .and_then(Json::as_usize_vec)
            .ok_or_else(|| anyhow!("param missing shape"))?;
        let data = p
            .get("data")
            .and_then(Json::as_f32_vec)
            .ok_or_else(|| anyhow!("param missing data"))?;
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(anyhow!("param shape/data mismatch: {n} vs {}", data.len()));
        }
        out.push(data);
    }
    Ok(out)
}
