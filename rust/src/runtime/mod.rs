//! PJRT runtime: loads the HLO-text artifacts `make artifacts` produced
//! and executes them on the CPU PJRT client. This is the only place the
//! `xla` crate is touched; Python never runs on the request path.
//!
//! Interchange is HLO **text** (not serialized `HloModuleProto`): jax ≥0.5
//! emits protos with 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids.
//!
//! The `xla` FFI is gated behind the **`xla-runtime`** cargo feature so
//! the default build is hermetic (no external crates): manifest handling
//! and shape checking work everywhere, while `execute_f32`/`warmup`
//! return [`Error::Backend`] until the feature (and the vendored
//! `xla_extension` toolchain it needs) is enabled. See DESIGN.md §5.

mod manifest;

pub use manifest::{ArtifactManifest, ManifestEntry, TensorSpec};

#[cfg(feature = "xla-runtime")]
use std::cell::RefCell;
#[cfg(feature = "xla-runtime")]
use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};

/// Artifact-backed executor: manifest + lazily compiled executables.
pub struct Runtime {
    #[cfg(feature = "xla-runtime")]
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: ArtifactManifest,
    #[cfg(feature = "xla-runtime")]
    cache: RefCell<HashMap<String, xla::PjRtLoadedExecutable>>,
}

impl Runtime {
    /// Open an artifact directory (must contain `manifest.json`).
    #[cfg(feature = "xla-runtime")]
    pub fn new(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = ArtifactManifest::load(dir.join("manifest.json"))?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| Error::Backend(format!("PJRT cpu client: {e:?}")))?;
        Ok(Runtime { client, dir, manifest, cache: RefCell::new(HashMap::new()) })
    }

    /// Open an artifact directory (must contain `manifest.json`).
    ///
    /// Without the `xla-runtime` feature the manifest still loads (shape
    /// checks, variant lookups) but execution is unavailable.
    #[cfg(not(feature = "xla-runtime"))]
    pub fn new(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = ArtifactManifest::load(dir.join("manifest.json"))?;
        Ok(Runtime { dir, manifest })
    }

    pub fn manifest(&self) -> &ArtifactManifest {
        &self.manifest
    }

    /// Names of all loadable entries.
    pub fn entries(&self) -> Vec<String> {
        self.manifest.names()
    }

    pub fn entry(&self, name: &str) -> Option<&ManifestEntry> {
        self.manifest.get(name)
    }

    /// Compile (and cache) the executable for `name`.
    #[cfg(feature = "xla-runtime")]
    fn executable(&self, name: &str) -> Result<()> {
        if self.cache.borrow().contains_key(name) {
            return Ok(());
        }
        let entry = self
            .manifest
            .get(name)
            .ok_or_else(|| Error::UnknownArtifact(name.to_string()))?;
        let path = self.dir.join(&entry.path);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| Error::Artifact("non-utf8 path".into()))?,
        )
        .map_err(|e| Error::Backend(format!("parse {}: {e:?}", path.display())))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| Error::Backend(format!("compile {name}: {e:?}")))?;
        self.cache.borrow_mut().insert(name.to_string(), exe);
        Ok(())
    }

    #[cfg(not(feature = "xla-runtime"))]
    fn executable(&self, name: &str) -> Result<()> {
        self.manifest
            .get(name)
            .ok_or_else(|| Error::UnknownArtifact(name.to_string()))?;
        Err(Error::Backend(format!(
            "cannot compile {name} from {}: built without the `xla-runtime` \
             feature",
            self.dir.display()
        )))
    }

    /// Eagerly compile a set of entries (server startup).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.executable(n)?;
        }
        Ok(())
    }

    /// Number of compiled executables currently cached.
    pub fn compiled_count(&self) -> usize {
        #[cfg(feature = "xla-runtime")]
        {
            self.cache.borrow().len()
        }
        #[cfg(not(feature = "xla-runtime"))]
        {
            0
        }
    }

    /// Execute entry `name` with f32 inputs (one flat buffer per input, in
    /// manifest order); returns the flat f32 outputs.
    ///
    /// Shape checking happens against the manifest before touching PJRT.
    pub fn execute_f32(&self, name: &str, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        let entry = self
            .manifest
            .get(name)
            .ok_or_else(|| Error::UnknownArtifact(name.to_string()))?
            .clone();
        if inputs.len() != entry.inputs.len() {
            return Err(Error::InvalidData(format!(
                "{name}: got {} inputs, manifest expects {}",
                inputs.len(),
                entry.inputs.len()
            )));
        }
        for (buf, spec) in inputs.iter().zip(&entry.inputs) {
            if buf.len() != spec.elems() {
                return Err(Error::InvalidData(format!(
                    "{name}: input length {} != spec {:?}",
                    buf.len(),
                    spec.shape
                )));
            }
        }
        self.execute_checked(name, &entry, inputs)
    }

    #[cfg(feature = "xla-runtime")]
    fn execute_checked(
        &self,
        name: &str,
        entry: &ManifestEntry,
        inputs: &[&[f32]],
    ) -> Result<Vec<Vec<f32>>> {
        let mut literals = Vec::with_capacity(inputs.len());
        for (buf, spec) in inputs.iter().zip(&entry.inputs) {
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(buf)
                .reshape(&dims)
                .map_err(|e| Error::Backend(format!("reshape input: {e:?}")))?;
            literals.push(lit);
        }

        self.executable(name)?;
        let cache = self.cache.borrow();
        let exe = cache.get(name).expect("just compiled");
        let result_set = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| Error::Backend(format!("execute {name}: {e:?}")))?;
        // PJRT returns one buffer list per device: never index blindly — a
        // backend mismatch can yield an empty set.
        let buffer = result_set
            .first()
            .and_then(|per_device| per_device.first())
            .ok_or_else(|| {
                Error::Backend(format!("execute {name}: PJRT returned no result buffers"))
            })?;
        let result = buffer
            .to_literal_sync()
            .map_err(|e| Error::Backend(format!("fetch result: {e:?}")))?;

        // aot.py lowers with return_tuple=True: unpack the tuple elements.
        let n_out = entry.outputs.len();
        let elems = result
            .to_tuple()
            .map_err(|e| Error::Backend(format!("decompose tuple: {e:?}")))?;
        if elems.len() != n_out {
            return Err(Error::InvalidData(format!(
                "{name}: {} outputs, manifest says {n_out}",
                elems.len()
            )));
        }
        let mut out = Vec::with_capacity(n_out);
        for lit in elems {
            out.push(
                lit.to_vec::<f32>()
                    .map_err(|e| Error::Backend(format!("read output: {e:?}")))?,
            );
        }
        Ok(out)
    }

    #[cfg(not(feature = "xla-runtime"))]
    fn execute_checked(
        &self,
        name: &str,
        _entry: &ManifestEntry,
        _inputs: &[&[f32]],
    ) -> Result<Vec<Vec<f32>>> {
        self.executable(name).map(|_| Vec::new())
    }
}

/// Load the TinyCNN serving parameters emitted by aot.py
/// (`tiny_cnn_params.json`): flat f32 buffers in `flatten_params` order.
pub fn load_params(dir: impl AsRef<Path>) -> Result<Vec<Vec<f32>>> {
    use crate::util::json::Json;
    let path = dir.as_ref().join("tiny_cnn_params.json");
    let text = std::fs::read_to_string(&path)
        .map_err(|e| Error::Artifact(format!("reading {}: {e}", path.display())))?;
    let doc = Json::parse(&text).map_err(|e| Error::Artifact(format!("{e}")))?;
    let arr = doc
        .as_arr()
        .ok_or_else(|| Error::InvalidData("params not an array".into()))?;
    let mut out = Vec::with_capacity(arr.len());
    for p in arr {
        let shape = p
            .get("shape")
            .and_then(Json::as_usize_vec)
            .ok_or_else(|| Error::InvalidData("param missing shape".into()))?;
        let data = p
            .get("data")
            .and_then(Json::as_f32_vec)
            .ok_or_else(|| Error::InvalidData("param missing data".into()))?;
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(Error::InvalidData(format!(
                "param shape/data mismatch: {n} vs {}",
                data.len()
            )));
        }
        out.push(data);
    }
    Ok(out)
}
