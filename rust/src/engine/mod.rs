//! The GACER deployment engine — one API from tenant admission to a live
//! serving configuration.
//!
//! The paper's point (§4.4, Algorithm 1) is that the granularity-aware
//! search *produces the plan the runtime executes*. [`GacerEngine`] closes
//! that loop: it owns the tenant set, runs the joint search, and compiles
//! the resulting [`DeploymentPlan`] into the live server configuration —
//! `chunking` lowers to per-tenant micro-batch variants
//! ([`TenantSpec::chunk`]) and the pointer matrix lowers to the
//! scheduler's cross-tenant issue order and per-round issue quanta
//! (segment boundaries on the real path).
//!
//! ```no_run
//! use gacer::engine::GacerEngine;
//! use gacer::models::zoo;
//!
//! let mut engine = GacerEngine::builder()
//!     .tenant(zoo::build_default("R50").unwrap())
//!     .tenant(zoo::build_default("V16").unwrap())
//!     .build()
//!     .unwrap();
//! let outcome = engine.simulate();
//! let id = engine.admit(zoo::build_default("M3").unwrap()).unwrap(); // re-plans
//! engine.evict(id).unwrap(); // re-plans again
//! # let _ = outcome;
//! ```
//!
//! Tenants are addressed by stable [`TenantId`]s (slot indices shift on
//! eviction; ids never do). Admission and eviction trigger an
//! **incremental re-search** ([`crate::search::GacerSearch::run_from`])
//! seeded with the surviving plan, so reconfiguration costs a fraction of
//! a cold search.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::Duration;

use crate::coordinator::{BatchPolicy, Server, ServerConfig, TenantSpec};
use crate::dfg::Dfg;
use crate::error::{Error, Result};
use crate::gpu::{SimOptions, SimOutcome};
use crate::models::zoo;
use crate::plan::{ChunkMap, DeploymentPlan, TenantSet};
use crate::profile::{CostModel, Platform};
use crate::runtime::ArtifactManifest;
use crate::search::{GacerSearch, SearchConfig, SearchReport};

/// Stable identifier of a deployed tenant (survives other tenants'
/// evictions, unlike slot indices).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct TenantId(pub u64);

impl std::fmt::Display for TenantId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "tenant#{}", self.0)
    }
}

/// Per-tenant serving metadata kept alongside the DFG.
#[derive(Debug, Clone)]
struct TenantMeta {
    id: TenantId,
    name: String,
    /// Artifact family (manifest `meta.op`); simulation-only tenants have
    /// none and cannot be lowered to a serving deployment.
    family: Option<String>,
    policy: BatchPolicy,
}

fn default_policy() -> BatchPolicy {
    BatchPolicy::new(8, Duration::from_millis(2), vec![1, 2, 4, 8, 16, 32])
}

/// A plan lowered to the serving coordinator's configuration: what
/// [`Server::start`] consumes.
#[derive(Debug, Clone)]
pub struct Deployment {
    pub tenants: Vec<TenantSpec>,
    pub config: ServerConfig,
}

/// Builder for [`GacerEngine`] — `GacerEngine::builder().platform(..)
/// .artifacts(..).tenant(..).build()`.
pub struct EngineBuilder {
    platform: Platform,
    artifact_dir: Option<PathBuf>,
    search: SearchConfig,
    tick: Duration,
    tenants: Vec<(Dfg, TenantMeta)>,
    next_id: u64,
}

impl EngineBuilder {
    fn new() -> Self {
        EngineBuilder {
            platform: Platform::titan_v(),
            artifact_dir: None,
            search: SearchConfig::default(),
            tick: Duration::from_micros(200),
            tenants: Vec::new(),
            next_id: 0,
        }
    }

    /// Target platform for the cost model and simulator.
    pub fn platform(mut self, p: Platform) -> Self {
        self.platform = p;
        self
    }

    /// AOT artifact directory (enables [`GacerEngine::serve`]).
    pub fn artifacts(mut self, dir: impl Into<PathBuf>) -> Self {
        self.artifact_dir = Some(dir.into());
        self
    }

    /// Search hyper-parameters (defaults to [`SearchConfig::default`]).
    pub fn search(mut self, cfg: SearchConfig) -> Self {
        self.search = cfg;
        self
    }

    /// Scheduler tick of the lowered server config.
    pub fn tick(mut self, tick: Duration) -> Self {
        self.tick = tick;
        self
    }

    fn push(&mut self, dfg: Dfg, family: Option<String>, policy: BatchPolicy) {
        let id = TenantId(self.next_id);
        self.next_id += 1;
        let name = dfg.name.clone();
        self.tenants.push((dfg, TenantMeta { id, name, family, policy }));
    }

    /// Add a simulation/search tenant (no serving artifacts).
    pub fn tenant(mut self, dfg: Dfg) -> Self {
        self.push(dfg, None, default_policy());
        self
    }

    /// Add a serving tenant of an artifact `family`: the engine searches
    /// over the family's cost-model proxy DFG at the policy's preferred
    /// batch and lowers the result onto the family's compiled variants.
    pub fn serving_tenant(
        mut self,
        name: impl Into<String>,
        family: &str,
        policy: BatchPolicy,
    ) -> Result<Self> {
        let mut dfg = zoo::serving_proxy(family, policy.max_batch)
            .ok_or_else(|| Error::UnknownModel(format!("serving family {family}")))?;
        dfg.name = name.into();
        self.push(dfg, Some(family.to_string()), policy);
        Ok(self)
    }

    /// Validate the tenants, open the artifact manifest (when configured),
    /// and run the initial granularity-aware search.
    pub fn build(self) -> Result<GacerEngine> {
        let manifest = match &self.artifact_dir {
            Some(dir) => Some(ArtifactManifest::load(dir.join("manifest.json"))?),
            None => None,
        };
        let mut engine = GacerEngine {
            opts: SimOptions::for_platform(&self.platform),
            platform: self.platform,
            search_cfg: self.search,
            tick: self.tick,
            set: TenantSet::new(Vec::new(), CostModel::new(self.platform)),
            meta: Vec::new(),
            next_id: self.next_id,
            plan: DeploymentPlan::unregulated(0),
            last_report: None,
            artifact_dir: self.artifact_dir,
            manifest,
        };
        for (dfg, meta) in self.tenants {
            engine.check_admissible(&dfg, meta.family.as_deref())?;
            engine.set.admit(dfg);
            engine.meta.push(meta);
        }
        // replan() starts from the unregulated plan of the full set, so no
        // per-tenant plan reshaping is needed here.
        engine.replan();
        Ok(engine)
    }
}

/// The deployment engine: tenant set + searched plan + lowering to the
/// live serving configuration.
pub struct GacerEngine {
    platform: Platform,
    opts: SimOptions,
    search_cfg: SearchConfig,
    tick: Duration,
    set: TenantSet,
    meta: Vec<TenantMeta>,
    next_id: u64,
    plan: DeploymentPlan,
    last_report: Option<SearchReport>,
    artifact_dir: Option<PathBuf>,
    manifest: Option<ArtifactManifest>,
}

impl GacerEngine {
    pub fn builder() -> EngineBuilder {
        EngineBuilder::new()
    }

    /// Number of deployed tenants.
    pub fn len(&self) -> usize {
        self.set.len()
    }

    pub fn is_empty(&self) -> bool {
        self.set.is_empty()
    }

    /// The deployed tenant DFGs, in slot order.
    pub fn tenants(&self) -> &[Dfg] {
        &self.set.tenants
    }

    /// Stable ids, in slot order.
    pub fn tenant_ids(&self) -> Vec<TenantId> {
        self.meta.iter().map(|m| m.id).collect()
    }

    /// The platform the engine prices against.
    pub fn platform(&self) -> &Platform {
        &self.platform
    }

    /// The current searched deployment plan.
    pub fn plan(&self) -> &DeploymentPlan {
        &self.plan
    }

    /// Bookkeeping of the most recent (cold or incremental) search.
    pub fn last_report(&self) -> Option<&SearchReport> {
        self.last_report.as_ref()
    }

    /// Simulate the current plan on the engine's platform.
    pub fn simulate(&self) -> SimOutcome {
        self.set.simulate(&self.plan, self.opts)
    }

    fn index_of(&self, id: TenantId) -> Result<usize> {
        self.meta
            .iter()
            .position(|m| m.id == id)
            .ok_or(Error::UnknownTenant(id.0))
    }

    fn check_admissible(&self, dfg: &Dfg, family: Option<&str>) -> Result<()> {
        crate::dfg::validate(dfg)?;
        if let (Some(m), Some(f)) = (&self.manifest, family) {
            if m.variants_of(f).is_empty() {
                return Err(Error::MissingFamily(f.to_string()));
            }
        }
        Ok(())
    }

    /// Admit a simulation/search tenant at runtime. Triggers an
    /// incremental re-search seeded with the current plan (the newcomer
    /// starts at the deployment's pointer level, Algorithm 1 resumes from
    /// there).
    pub fn admit(&mut self, dfg: Dfg) -> Result<TenantId> {
        self.admit_with(dfg, None, default_policy())
    }

    /// Admit a serving tenant of an artifact family at runtime.
    pub fn admit_serving(
        &mut self,
        name: impl Into<String>,
        family: &str,
        policy: BatchPolicy,
    ) -> Result<TenantId> {
        let mut dfg = zoo::serving_proxy(family, policy.max_batch)
            .ok_or_else(|| Error::UnknownModel(format!("serving family {family}")))?;
        dfg.name = name.into();
        self.admit_with(dfg, Some(family.to_string()), policy)
    }

    fn admit_with(
        &mut self,
        dfg: Dfg,
        family: Option<String>,
        policy: BatchPolicy,
    ) -> Result<TenantId> {
        self.check_admissible(&dfg, family.as_deref())?;
        let id = TenantId(self.next_id);
        self.next_id += 1;
        let name = dfg.name.clone();
        let level = self.plan.pointers.pointers_per_tenant();
        self.plan.push_tenant(dfg.len(), level);
        self.set.admit(dfg);
        self.meta.push(TenantMeta { id, name, family, policy });
        self.research_from_current();
        Ok(id)
    }

    /// Evict a tenant by id; the surviving tenants are incrementally
    /// re-planned. Returns the evicted DFG.
    pub fn evict(&mut self, id: TenantId) -> Result<Dfg> {
        let idx = self.index_of(id)?;
        self.meta.remove(idx);
        self.plan.remove_tenant(idx);
        let dfg = self.set.evict(idx);
        self.research_from_current();
        Ok(dfg)
    }

    /// Run a full cold search (Algorithm 1 from the unregulated plan),
    /// replacing the current plan.
    pub fn replan(&mut self) {
        if self.set.is_empty() {
            self.plan = DeploymentPlan::unregulated(0);
            self.last_report = None;
            return;
        }
        let report = GacerSearch::new(&self.set, self.opts, self.search_cfg).run();
        self.plan = report.plan.clone();
        self.last_report = Some(report);
    }

    /// Incremental re-search seeded with the current (already re-shaped)
    /// plan.
    fn research_from_current(&mut self) {
        if self.set.is_empty() {
            self.plan = DeploymentPlan::unregulated(0);
            self.last_report = None;
            return;
        }
        let report = GacerSearch::new(&self.set, self.opts, self.search_cfg)
            .run_from(self.plan.clone());
        self.plan = report.plan.clone();
        self.last_report = Some(report);
    }

    fn family_variants(&self) -> Result<Vec<Vec<usize>>> {
        let manifest = self
            .manifest
            .as_ref()
            .ok_or_else(|| Error::InvalidConfig("engine has no artifact dir".into()))?;
        self.meta
            .iter()
            .map(|m| {
                let family = m.family.as_deref().ok_or_else(|| {
                    Error::InvalidConfig(format!(
                        "tenant {} ({}) has no artifact family",
                        m.id, m.name
                    ))
                })?;
                let v: Vec<usize> = manifest.variants_of(family).into_keys().collect();
                if v.is_empty() {
                    return Err(Error::MissingFamily(family.to_string()));
                }
                Ok(v)
            })
            .collect()
    }

    /// Lower the current searched plan to the serving configuration.
    pub fn deployment(&self) -> Result<Deployment> {
        self.deployment_of(&self.plan)
    }

    /// Lower an arbitrary plan (e.g. the unregulated baseline) to the
    /// serving configuration — useful for A/B deployment comparisons.
    pub fn deployment_of(&self, plan: &DeploymentPlan) -> Result<Deployment> {
        let specs: Vec<(String, String, BatchPolicy)> = self
            .meta
            .iter()
            .map(|m| {
                Ok((
                    m.name.clone(),
                    m.family
                        .clone()
                        .ok_or_else(|| {
                            Error::InvalidConfig(format!(
                                "tenant {} ({}) has no artifact family",
                                m.id, m.name
                            ))
                        })?,
                    m.policy.clone(),
                ))
            })
            .collect::<Result<_>>()?;
        lower_plan(plan, &self.set.tenants, &specs, &self.family_variants()?, self.tick)
    }

    /// Start the serving coordinator off the searched plan: the single
    /// call that takes "tenants admitted" to "requests served under
    /// granularity regulation".
    pub fn serve(&self) -> Result<Server> {
        let dir = self
            .artifact_dir
            .as_ref()
            .ok_or_else(|| Error::InvalidConfig("engine has no artifact dir".into()))?;
        let deployment = self.deployment()?;
        Server::start(&dir.to_string_lossy(), deployment.tenants, deployment.config)
    }
}

/// Max consecutive batches per scheduling round for a single-segment
/// tenant; tenants with finer temporal granularity get proportionally
/// smaller quanta (more pointers → yield the issue queue sooner).
const BASE_ISSUE_QUANTUM: usize = 4;

/// Compile a deployment plan into the live server configuration — the
/// plan→server lowering at the heart of the engine:
///
/// * **chunking → [`TenantSpec::chunk`]**: the modal micro-batch piece
///   size of the tenant's searched `list_B`s, clamped to the largest
///   compiled batch variant that does not exceed it (the real path can
///   only execute batches that were AOT-compiled);
/// * **pointer matrix → issue order**: tenants with finer temporal
///   granularity (shorter mean segments) issue first — they are the ones
///   the search decided must synchronize most often;
/// * **pointer matrix → issue quanta**: per-round batch caps shrink as a
///   tenant's segment count grows (segment boundaries realized as issue-
///   queue yields).
pub fn lower_plan(
    plan: &DeploymentPlan,
    tenants: &[Dfg],
    specs: &[(String, String, BatchPolicy)],
    variants: &[Vec<usize>],
    tick: Duration,
) -> Result<Deployment> {
    plan.validate(tenants)?;
    let n = tenants.len();
    if specs.len() != n || variants.len() != n {
        return Err(Error::InvalidConfig(format!(
            "lowering arity mismatch: {n} tenants, {} specs, {} variant sets",
            specs.len(),
            variants.len()
        )));
    }

    let mut tenant_specs = Vec::with_capacity(n);
    for (i, (name, family, policy)) in specs.iter().enumerate() {
        let chunk = modal_chunk(&plan.chunking[i]).and_then(|m| {
            let mut avail = variants[i].clone();
            avail.sort_unstable();
            avail.into_iter().rev().find(|&v| v <= m)
        });
        tenant_specs.push(TenantSpec {
            name: name.clone(),
            family: family.clone(),
            policy: policy.clone(),
            chunk,
        });
    }

    let mean_segment =
        |i: usize| tenants[i].len() as f64 / plan.pointers.segments(i) as f64;
    let mut issue_order: Vec<usize> = (0..n).collect();
    issue_order.sort_by(|&a, &b| {
        mean_segment(a)
            .partial_cmp(&mean_segment(b))
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });

    let issue_quanta: Vec<usize> = (0..n)
        .map(|i| (BASE_ISSUE_QUANTUM / plan.pointers.segments(i)).max(1))
        .collect();

    let config = ServerConfig { tick, issue_order, issue_quanta };
    config.validate(n)?;
    Ok(Deployment { tenants: tenant_specs, config })
}

/// Most frequent micro-batch piece size across a tenant's searched
/// decompositions (ties break toward the coarser piece — less chunk/concat
/// overhead). `None` when the plan decomposes nothing for this tenant.
fn modal_chunk(map: &ChunkMap) -> Option<usize> {
    let mut counts: BTreeMap<usize, usize> = BTreeMap::new();
    for list in map.values().filter(|l| l.len() > 1) {
        for &b in *list {
            *counts.entry(b).or_default() += 1;
        }
    }
    counts.into_iter().max_by_key(|&(size, n)| (n, size)).map(|(size, _)| size)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg() -> SearchConfig {
        SearchConfig {
            max_pointers: 2,
            rounds_per_level: 1,
            positions_per_coordinate: 5,
            spatial_steps_per_level: 2,
            ..Default::default()
        }
    }

    fn demo_engine(names: &[&str]) -> GacerEngine {
        let mut b = GacerEngine::builder().search(quick_cfg());
        for n in names {
            b = b.tenant(zoo::build_default(n).unwrap());
        }
        b.build().unwrap()
    }

    #[test]
    fn build_runs_the_search_and_plan_validates() {
        let engine = demo_engine(&["Alex", "V16", "R18"]);
        assert_eq!(engine.len(), 3);
        engine.plan().validate(engine.tenants()).unwrap();
        assert!(engine.last_report().is_some());
        let r = engine.last_report().unwrap();
        assert!(r.outcome.objective() <= r.initial.objective() + 1e-6);
    }

    #[test]
    fn admit_replans_and_extends_the_plan() {
        let mut engine = demo_engine(&["Alex", "R18"]);
        let before = engine.tenant_ids();
        let id = engine.admit(zoo::build_default("M3").unwrap()).unwrap();
        assert!(!before.contains(&id));
        assert_eq!(engine.len(), 3);
        engine.plan().validate(engine.tenants()).unwrap();
        // The re-planned deployment can never be worse than unregulated.
        let r = engine.last_report().unwrap();
        assert!(r.outcome.objective() <= r.initial.objective() + 1e-6);
    }

    #[test]
    fn evict_shrinks_the_plan_and_keeps_ids_stable() {
        let mut engine = demo_engine(&["Alex", "V16", "R18"]);
        let ids = engine.tenant_ids();
        let evicted = engine.evict(ids[1]).unwrap();
        assert_eq!(evicted.name, "V16");
        assert_eq!(engine.len(), 2);
        assert_eq!(engine.tenant_ids(), vec![ids[0], ids[2]]);
        engine.plan().validate(engine.tenants()).unwrap();
        assert!(engine.evict(ids[1]).is_err(), "double-evict must fail");
    }

    #[test]
    fn evict_to_empty_then_admit_again() {
        let mut engine = demo_engine(&["Alex"]);
        let ids = engine.tenant_ids();
        engine.evict(ids[0]).unwrap();
        assert!(engine.is_empty());
        engine.admit(zoo::build_default("R18").unwrap()).unwrap();
        assert_eq!(engine.len(), 1);
        engine.plan().validate(engine.tenants()).unwrap();
    }

    #[test]
    fn unknown_serving_family_rejected() {
        let b = GacerEngine::builder();
        assert!(b.serving_tenant("x", "no_such_family", default_policy()).is_err());
    }

    #[test]
    fn serve_without_artifacts_is_typed_error() {
        let engine = demo_engine(&["Alex"]);
        match engine.serve() {
            Err(Error::InvalidConfig(_)) => {}
            Err(other) => panic!("expected InvalidConfig, got {other:?}"),
            Ok(_) => panic!("expected InvalidConfig, got a running server"),
        }
    }

    // ---- lowering ----

    fn lower_fixture(
        plan: &DeploymentPlan,
        tenants: &[Dfg],
        variants: Vec<Vec<usize>>,
    ) -> Deployment {
        let specs: Vec<(String, String, BatchPolicy)> = tenants
            .iter()
            .map(|d| (d.name.clone(), "tiny_cnn".to_string(), default_policy()))
            .collect();
        lower_plan(plan, tenants, &specs, &variants, Duration::from_micros(200))
            .unwrap()
    }

    #[test]
    fn lowering_maps_searched_chunks_to_compiled_variants() {
        let tenants = zoo::build_combo(&["Alex", "V16"]);
        let mut plan = DeploymentPlan::unregulated(2);
        // The search split two of V16's convs into micro-batches of 4.
        plan.chunking[1].insert(0, vec![4, 4]);
        plan.chunking[1].insert(2, vec![4, 4]);
        let d = lower_fixture(&plan, &tenants, vec![vec![1, 2, 4, 8], vec![1, 2, 4, 8]]);
        assert_eq!(d.tenants[0].chunk, None, "undecomposed tenant stays whole");
        assert_eq!(d.tenants[1].chunk, Some(4), "searched piece size reaches the spec");
    }

    #[test]
    fn lowering_clamps_chunk_to_available_variants() {
        let tenants = zoo::build_combo(&["Alex"]);
        let mut plan = DeploymentPlan::unregulated(1);
        plan.chunking[0].insert(0, vec![3, 5]);
        // Modal piece ties 3 vs 5 -> 5 (coarser); only variants 1/2/4 exist
        // -> clamped down to 4.
        let d = lower_fixture(&plan, &tenants, vec![vec![1, 2, 4]]);
        assert_eq!(d.tenants[0].chunk, Some(4));
    }

    #[test]
    fn lowering_orders_fine_grained_tenants_first() {
        let tenants = zoo::build_combo(&["Alex", "V16", "R18"]);
        let mut plan = DeploymentPlan::unregulated(3);
        // V16 gets 3 pointers (4 segments): finest granularity -> first.
        plan.pointers.set_list(1, vec![8, 16, 24]);
        let d =
            lower_fixture(&plan, &tenants, vec![vec![8], vec![8], vec![8]]);
        assert_eq!(d.config.issue_order[0], 1);
        let mut sorted = d.config.issue_order.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2], "issue order is a permutation");
        // Segment-derived quanta: 4 segments -> 1, 1 segment -> 4.
        assert_eq!(d.config.issue_quanta[1], 1);
        assert_eq!(d.config.issue_quanta[0], 4);
    }

    #[test]
    fn lowering_rejects_invalid_plans() {
        let tenants = zoo::build_combo(&["Alex"]);
        let plan = DeploymentPlan::unregulated(2); // tenant-count mismatch
        let specs =
            vec![("a".to_string(), "tiny_cnn".to_string(), default_policy())];
        let err = lower_plan(
            &plan,
            &tenants,
            &specs,
            &[vec![8]],
            Duration::from_micros(200),
        );
        assert!(matches!(err, Err(Error::InvalidPlan(_))));
    }

    #[test]
    fn modal_chunk_prefers_frequent_then_coarse() {
        let mut map = ChunkMap::new();
        map.insert(0, vec![4, 4]);
        map.insert(1, vec![4, 4]);
        map.insert(2, vec![2, 2, 2, 2]);
        // Piece counts tie (4x each) -> the coarser piece wins.
        assert_eq!(modal_chunk(&map), Some(4));
        map.insert(3, vec![2, 2, 2, 2]);
        assert_eq!(modal_chunk(&map), Some(2), "2 now strictly more frequent");
        // Singleton lists are not splits and don't vote.
        let mut whole = ChunkMap::new();
        whole.insert(0, vec![8]);
        assert_eq!(modal_chunk(&whole), None);
        assert_eq!(modal_chunk(&ChunkMap::new()), None);
    }
}
