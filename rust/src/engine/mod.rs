//! The GACER deployment engine — one API from tenant admission to a live
//! serving configuration.
//!
//! The paper's point (§4.4, Algorithm 1) is that the granularity-aware
//! search *produces the plan the runtime executes*. [`GacerEngine`] closes
//! that loop: it owns the tenant set, runs the joint search, and compiles
//! the resulting [`DeploymentPlan`] into the live server configuration —
//! `chunking` lowers to per-tenant micro-batch variants
//! ([`TenantSpec::chunk`]) and the pointer matrix lowers to the
//! scheduler's cross-tenant issue order and per-round issue quanta
//! (segment boundaries on the real path).
//!
//! ```no_run
//! use gacer::engine::GacerEngine;
//! use gacer::models::zoo;
//!
//! let mut engine = GacerEngine::builder()
//!     .tenant(zoo::build_default("R50").unwrap())
//!     .tenant(zoo::build_default("V16").unwrap())
//!     .build()
//!     .unwrap();
//! let outcome = engine.simulate();
//! let id = engine.admit(zoo::build_default("M3").unwrap()).unwrap(); // re-plans
//! engine.evict(id).unwrap(); // re-plans again
//! # let _ = outcome;
//! ```
//!
//! Tenants are addressed by stable [`TenantId`]s (slot indices shift on
//! eviction; ids never do). Admission and eviction trigger an
//! **incremental re-search** ([`crate::search::GacerSearch::run_from`])
//! seeded with the surviving plan, so reconfiguration costs a fraction of
//! a cold search. The re-search is **warm-started and budgeted**: the
//! engine keeps one [`crate::search::SearchState`] per device (compiled
//! tenant streams are reused; only tenants whose chunking changed
//! recompile) and [`EngineBuilder::replan_budget`] caps each event's
//! re-plan latency — the anytime search returns its best-so-far plan and
//! flags truncation on the event's report. Internals:
//! `docs/SEARCH.md`.
//!
//! # Multi-GPU sharding
//!
//! [`EngineBuilder::devices`] gives the deployment a device dimension: the
//! engine shards the tenant set across `n` devices with a cost-model-driven
//! [`Placement`], runs one granularity-aware search per device, and keeps a
//! [`ShardedDeploymentPlan`] — one chunk map + pointer matrix per shard.
//! Cross-device admission control places a newcomer on the least loaded
//! device and re-searches **only the affected shard** (seeded via
//! `run_from`); eviction likewise re-plans just the shard that lost the
//! tenant. Serving lowers to one [`coordinator::Server`] per device behind
//! a [`ClusterServer`] front-end ([`GacerEngine::serve_cluster`]) that
//! routes requests by tenant placement.
//!
//! The device dimension is a first-class [`DevicePool`]: each device
//! carries its own [`Platform`] profile (SM pool, bandwidth peak, HBM
//! capacity) and a stable [`DeviceId`] that survives scale events.
//! [`EngineBuilder::device_pool`] builds a heterogeneous engine (e.g. an
//! A100 beside two T4s) where placement, per-shard search, and
//! simulation all price each device with its own cost model;
//! [`EngineBuilder::devices`] stays as sugar for `n` identical devices.
//! At runtime [`GacerEngine::add_device`] scales the pool out (warm
//! re-shard onto the grown pool) and [`GacerEngine::remove_device`]
//! drains a device onto capacity-feasible survivors — refusing with
//! [`Error::DrainImpossible`], pool untouched, when some resident tenant
//! fits no surviving device.
//!
//! ```
//! use gacer::engine::GacerEngine;
//! use gacer::models::zoo;
//! use gacer::search::SearchConfig;
//!
//! let quick = SearchConfig {
//!     max_pointers: 1,
//!     rounds_per_level: 1,
//!     positions_per_coordinate: 4,
//!     spatial_steps_per_level: 1,
//!     ..Default::default()
//! };
//! let mut engine = GacerEngine::builder()
//!     .devices(2)
//!     .search(quick)
//!     .tenant(zoo::build_default("Alex").unwrap())
//!     .tenant(zoo::build_default("M3").unwrap())
//!     .build()
//!     .unwrap();
//! engine.sharded_plan().validate(engine.tenants()).unwrap();
//! // Admission re-searches only the shard that received the newcomer.
//! let id = engine.admit(zoo::build_default("R18").unwrap()).unwrap();
//! let device = engine.device_of(id).unwrap();
//! assert_eq!(engine.last_searched_device(), Some(device));
//! ```
//!
//! # Live re-deployment and load-drift migration
//!
//! Searched plans reach **running** servers without a restart:
//! [`GacerEngine::redeploy`] / [`GacerEngine::redeploy_cluster`] lower
//! the current plan and hot-swap it in ([`Server::apply`] /
//! [`ClusterServer::apply`] — epoch-fenced at a scheduler round
//! boundary, queued requests survive). When observed traffic drifts
//! away from the placement's assumptions, a [`MigrationPolicy`] over
//! the engine's demand counters ([`GacerEngine::record_requests`])
//! proposes moving a tenant between devices;
//! [`GacerEngine::maybe_migrate`] executes it as a **two-shard**
//! seeded re-search, and the next `redeploy_cluster` makes it live.
//! The full operational loop is documented in `docs/OPERATIONS.md`.
//!
//! [`coordinator::Server`]: crate::coordinator::Server
//! [`Server::apply`]: crate::coordinator::Server::apply
//! [`ClusterServer`]: crate::coordinator::ClusterServer
//! [`ClusterServer::apply`]: crate::coordinator::ClusterServer::apply

mod migration;

pub use migration::{Migration, MigrationCost, MigrationPolicy, MigrationProposal};

use std::cell::Cell;
use std::collections::{BTreeMap, BTreeSet};
use std::path::PathBuf;
use std::time::{Duration, Instant};

use crate::calibrate::{CalibrationConfig, CalibrationEntry, Calibrator};
use crate::coordinator::{BatchPolicy, ClusterServer, Server, ServerConfig, TenantSpec};
use crate::dfg::Dfg;
use crate::error::{Error, Result};
use crate::gpu::{SimOptions, SimOutcome};
use crate::models::zoo;
use crate::plan::{
    ChunkMap, DeploymentPlan, Placement, PlacementObjective, ShardedDeploymentPlan,
    TenantSet,
};
use crate::profile::{CostModel, DeviceId, DevicePool, Platform};
use crate::runtime::ArtifactManifest;
use crate::search::{SearchBudget, SearchConfig, SearchReport, SearchState, ShardedSearch};
use crate::slo::{BurnConfig, SloMonitor, SloPolicy, SloPressure, SloTarget};

/// Stable identifier of a deployed tenant (survives other tenants'
/// evictions, unlike slot indices).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct TenantId(pub u64);

impl std::fmt::Display for TenantId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "tenant#{}", self.0)
    }
}

/// One executed migration remembered for the policy cooldown: while
/// `remaining > 0`, proposals moving `tenant` back onto `from` (the
/// device it migrated off) are suppressed.
#[derive(Debug, Clone, Copy)]
struct Cooldown {
    tenant: TenantId,
    /// Stable id of the device the tenant migrated off (ids stay valid
    /// across scale events; dense indices would not).
    from: DeviceId,
    remaining: usize,
}

/// Per-tenant serving metadata kept alongside the DFG.
#[derive(Debug, Clone)]
struct TenantMeta {
    id: TenantId,
    name: String,
    /// Artifact family (manifest `meta.op`); simulation-only tenants have
    /// none and cannot be lowered to a serving deployment.
    family: Option<String>,
    policy: BatchPolicy,
    /// Observed demand (accumulated request count fed back by the
    /// operations loop via [`GacerEngine::record_requests`]); 0 until
    /// traffic is observed. Drives load-drift migration.
    demand: f64,
    /// SLO scheduling contract lowered into the server config (tier
    /// priority, deadline, queue cap). Defaults to
    /// [`SloPolicy::default`] — regulation off for this tenant.
    slo: SloPolicy,
    /// Latency objective the [`SloMonitor`] judges this tenant against;
    /// `None` = not SLO-tracked.
    target: Option<SloTarget>,
}

fn default_policy() -> BatchPolicy {
    BatchPolicy::new(8, Duration::from_millis(2), vec![1, 2, 4, 8, 16, 32])
}

/// A plan lowered to the serving coordinator's configuration: what
/// [`Server::start`] consumes and what [`Server::apply`] hot-swaps into
/// a running server. `PartialEq` is part of the contract: live
/// re-deployment diffs lowered deployments to leave unchanged devices
/// untouched.
#[derive(Debug, Clone, PartialEq)]
pub struct Deployment {
    /// Per-tenant serving specs, in (device-local) slot order.
    pub tenants: Vec<TenantSpec>,
    /// Scheduler configuration (tick, issue order, issue quanta).
    pub config: ServerConfig,
}

/// A sharded plan lowered per device: what [`ClusterServer::start`]
/// consumes and what [`ClusterServer::apply`] hot-swaps into a running
/// cluster. One independent [`Deployment`] per device, plus the routing
/// table that maps every global tenant slot to its `(device, local slot)`.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardedDeployment {
    /// One lowered deployment per device (empty devices get an empty
    /// tenant list and a default scheduler config).
    pub per_device: Vec<Deployment>,
    /// Global tenant slot → `(device, local slot)` — the cluster front-end
    /// routes requests with this table.
    pub routing: Vec<(usize, usize)>,
    /// Stable id of each device, in `per_device` order — how
    /// [`ClusterServer::apply`] matches a freshly lowered deployment
    /// against the running servers when a scale event changed the device
    /// count or order. Same length as `per_device`.
    pub device_ids: Vec<DeviceId>,
}

/// Builder for [`GacerEngine`] — `GacerEngine::builder().platform(..)
/// .artifacts(..).tenant(..).build()`.
pub struct EngineBuilder {
    platform: Platform,
    artifact_dir: Option<PathBuf>,
    search: SearchConfig,
    replan_budget: SearchBudget,
    tick: Duration,
    n_devices: usize,
    /// Explicit per-device platform list; `None` means `n_devices`
    /// copies of `platform` (the classic homogeneous engine).
    pool: Option<Vec<Platform>>,
    objective: PlacementObjective,
    burn: BurnConfig,
    calibration: Option<CalibrationConfig>,
    tenants: Vec<(Dfg, TenantMeta)>,
    next_id: u64,
}

impl EngineBuilder {
    fn new() -> Self {
        EngineBuilder {
            platform: Platform::titan_v(),
            artifact_dir: None,
            search: SearchConfig::default(),
            replan_budget: SearchBudget::unbounded(),
            tick: Duration::from_micros(200),
            n_devices: 1,
            pool: None,
            objective: PlacementObjective::default(),
            burn: BurnConfig::default(),
            calibration: None,
            tenants: Vec::new(),
            next_id: 0,
        }
    }

    /// Target platform for the cost model and simulator. With an
    /// explicit [`EngineBuilder::device_pool`] the pool wins and this is
    /// ignored (the engine's reference platform becomes the pool's first
    /// device).
    pub fn platform(mut self, p: Platform) -> Self {
        self.platform = p;
        self
    }

    /// Number of devices to shard the deployment across (default 1 —
    /// the classic single-GPU engine; values below 1 are clamped to 1).
    /// Sugar for a [`DevicePool`] of `n` identical copies of the
    /// builder's platform: a homogeneous pool prices, places, and
    /// searches exactly as the pre-pool engine did. With `n > 1` the
    /// engine places tenants with [`Placement::balanced`], searches each
    /// shard independently, and serves through one coordinator per
    /// device ([`GacerEngine::serve_cluster`]).
    pub fn devices(mut self, n: usize) -> Self {
        self.n_devices = n.max(1);
        self.pool = None;
        self
    }

    /// Shard across an explicit, possibly heterogeneous device pool —
    /// one [`Platform`] profile per device (an empty list falls back to
    /// one device of the builder's platform). Placement weighs each
    /// candidate device with its own cost model (a T4 absorbs less than
    /// an A100 before it saturates), each shard's Algorithm-1 search and
    /// simulation run against that device's platform, and admission/
    /// migration re-price the moving tenant per device. The engine's
    /// reference platform ([`GacerEngine::platform`], global cost
    /// pricing) becomes the pool's first device.
    pub fn device_pool(mut self, platforms: Vec<Platform>) -> Self {
        self.n_devices = platforms.len().max(1);
        self.pool = if platforms.is_empty() { None } else { Some(platforms) };
        self
    }

    /// Placement objective for the device dimension (default
    /// [`PlacementObjective::LoadBalance`]). With
    /// [`PlacementObjective::InterferenceAware`] the whole
    /// observe→decide→apply loop is objective-consistent: the initial
    /// placement and every cold `replan` minimize the max per-device
    /// `load × predicted slowdown`, cross-device admission places through
    /// [`Placement::least_interfering`], and
    /// [`GacerEngine::maybe_migrate`] scores migration destinations with
    /// [`MigrationPolicy::propose_interference_aware`].
    /// [`PlacementObjective::MemoryAware`] extends the loop to the
    /// two-dimensional roofline: slowdowns price bandwidth as well as
    /// occupancy, admission routes through
    /// [`Placement::fit_memory_aware`] — refusing with
    /// [`Error::MemoryCapacity`] when no device has the HBM headroom —
    /// and migration uses [`MigrationPolicy::propose_memory_aware`].
    pub fn placement_objective(mut self, objective: PlacementObjective) -> Self {
        self.objective = objective;
        self
    }

    /// AOT artifact directory (enables [`GacerEngine::serve`]).
    pub fn artifacts(mut self, dir: impl Into<PathBuf>) -> Self {
        self.artifact_dir = Some(dir.into());
        self
    }

    /// Search hyper-parameters (defaults to [`SearchConfig::default`]).
    pub fn search(mut self, cfg: SearchConfig) -> Self {
        self.search = cfg;
        self
    }

    /// Budget for every **incremental** re-search the engine triggers at
    /// runtime — `admit`/`evict` (one shard) and `migrate` (two shards).
    /// Default [`SearchBudget::unbounded`]. A bounded budget (e.g.
    /// [`SearchBudget::deadline_ms`], the CLI's `--replan-budget-ms`)
    /// caps re-plan latency per re-searched shard: the anytime search
    /// returns its best-so-far plan, never worse than the inherited
    /// seed, and flags [`SearchReport::truncated`] on the event's report
    /// ([`GacerEngine::last_report`]).
    ///
    /// The initial build and explicit [`GacerEngine::replan`] calls stay
    /// unbudgeted — a cold re-plan is the offline-quality path; the
    /// budget exists to keep the *online* regulation loop responsive.
    ///
    /// [`SearchReport::truncated`]: crate::search::SearchReport::truncated
    pub fn replan_budget(mut self, budget: SearchBudget) -> Self {
        self.replan_budget = budget;
        self
    }

    /// Scheduler tick of the lowered server config.
    pub fn tick(mut self, tick: Duration) -> Self {
        self.tick = tick;
        self
    }

    /// Burn-rate thresholds for the engine's [`SloMonitor`] (defaults to
    /// [`BurnConfig::default`] — the classic fast/slow dual-window
    /// page/warn pair). Validated at [`EngineBuilder::build`].
    pub fn slo_burn(mut self, cfg: BurnConfig) -> Self {
        self.burn = cfg;
        self
    }

    /// Enable the online cost-model calibration stage
    /// ([`crate::calibrate`]): each [`GacerEngine::record_latencies`]
    /// window compares the served per-tenant latency against the analytic
    /// prediction ([`CostModel::predicted_colocated_latency_us`]) and
    /// folds the residual into a bounded per-(tenant, device-platform)
    /// EWMA; the clamped correction factors then scale the weights behind
    /// placement, admission, migration, and
    /// [`GacerEngine::maybe_regulate`]. Until a residual passes the trust
    /// ramp ([`CalibrationConfig::min_samples`]) every decision is
    /// bit-for-bit the analytic path. Knobs are validated at
    /// [`EngineBuilder::build`]. Off by default.
    ///
    /// [`CostModel::predicted_colocated_latency_us`]:
    ///     crate::profile::CostModel::predicted_colocated_latency_us
    pub fn calibration(mut self, cfg: CalibrationConfig) -> Self {
        self.calibration = Some(cfg);
        self
    }

    fn push(
        &mut self,
        dfg: Dfg,
        family: Option<String>,
        policy: BatchPolicy,
        slo: SloPolicy,
        target: Option<SloTarget>,
    ) {
        let id = TenantId(self.next_id);
        self.next_id += 1;
        let name = dfg.name.clone();
        self.tenants.push((
            dfg,
            TenantMeta { id, name, family, policy, demand: 0.0, slo, target },
        ));
    }

    /// Add a simulation/search tenant (no serving artifacts).
    pub fn tenant(mut self, dfg: Dfg) -> Self {
        self.push(dfg, None, default_policy(), SloPolicy::default(), None);
        self
    }

    /// Add a simulation/search tenant with an SLO contract. `target`,
    /// when set, registers the tenant with the engine's [`SloMonitor`]
    /// so [`GacerEngine::record_latencies`] feeds its error-budget burn
    /// and [`GacerEngine::maybe_regulate`] reacts to sustained burn —
    /// the decision half of the SLO loop, no artifacts required.
    pub fn tenant_with_slo(
        mut self,
        dfg: Dfg,
        slo: SloPolicy,
        target: Option<SloTarget>,
    ) -> Result<Self> {
        slo.validate()?;
        if let Some(t) = &target {
            t.validate()?;
        }
        self.push(dfg, None, default_policy(), slo, target);
        Ok(self)
    }

    /// Add a serving tenant of an artifact `family`: the engine searches
    /// over the family's cost-model proxy DFG at the policy's preferred
    /// batch and lowers the result onto the family's compiled variants.
    pub fn serving_tenant(
        mut self,
        name: impl Into<String>,
        family: &str,
        policy: BatchPolicy,
    ) -> Result<Self> {
        self.serving_tenant_with_slo(
            name,
            family,
            policy,
            SloPolicy::default(),
            None,
        )
    }

    /// Add a serving tenant with an SLO contract: `slo` lowers into the
    /// scheduler (tier-priority issue order, deadline shedding, queue
    /// cap) and `target`, when set, registers the tenant with the
    /// engine's [`SloMonitor`] so [`GacerEngine::record_latencies`]
    /// feeds its error-budget burn rate.
    pub fn serving_tenant_with_slo(
        mut self,
        name: impl Into<String>,
        family: &str,
        policy: BatchPolicy,
        slo: SloPolicy,
        target: Option<SloTarget>,
    ) -> Result<Self> {
        slo.validate()?;
        if let Some(t) = &target {
            t.validate()?;
        }
        let mut dfg = zoo::serving_proxy(family, policy.max_batch)
            .ok_or_else(|| Error::UnknownModel(format!("serving family {family}")))?;
        dfg.name = name.into();
        self.push(dfg, Some(family.to_string()), policy, slo, target);
        Ok(self)
    }

    /// Validate the tenants, open the artifact manifest (when configured),
    /// and run the initial granularity-aware search.
    pub fn build(self) -> Result<GacerEngine> {
        let manifest = match &self.artifact_dir {
            Some(dir) => Some(ArtifactManifest::load(dir.join("manifest.json"))?),
            None => None,
        };
        self.burn.validate()?;
        let calibrator = match self.calibration {
            Some(cfg) => Some(Calibrator::new(cfg)?),
            None => None,
        };
        let pool = match self.pool {
            Some(platforms) => DevicePool::from_platforms(platforms),
            None => DevicePool::uniform(self.platform, self.n_devices),
        };
        // The reference platform (global cost model, single-device
        // simulate) is the pool's first device; for the homogeneous
        // builder path this is exactly the builder's platform.
        let platform = *pool.platform(0);
        let n_devices = pool.len();
        let empty = Placement::from_assignments(vec![Vec::new(); n_devices]);
        let mut engine = GacerEngine {
            opts: SimOptions::for_platform(&platform),
            platform,
            search_cfg: self.search,
            replan_budget: self.replan_budget,
            tick: self.tick,
            pool,
            objective: self.objective,
            set: TenantSet::new(Vec::new(), CostModel::new(platform)),
            meta: Vec::new(),
            next_id: self.next_id,
            sharded: ShardedDeploymentPlan::unregulated(empty),
            merged: DeploymentPlan::unregulated(0),
            reports: (0..n_devices).map(|_| None).collect(),
            search_states: vec![SearchState::default(); n_devices],
            replan_cost_ewma_us: None,
            last_report: None,
            last_searched_device: None,
            last_searched_devices: Vec::new(),
            served_window: crate::metrics::DemandWindow::new(),
            cooldowns: Vec::new(),
            slo_monitor: SloMonitor::new(self.burn),
            pending_baseline_seed: BTreeSet::new(),
            evicted_serving: Vec::new(),
            calibrator,
            fence_pause_ewma_us: Cell::new(None),
            artifact_dir: self.artifact_dir,
            manifest,
        };
        for (dfg, meta) in self.tenants {
            engine.check_admissible(&dfg, meta.family.as_deref())?;
            if let Some(t) = meta.target {
                engine.slo_monitor.track(meta.id.0, meta.slo.tier, t)?;
            }
            engine.set.admit(dfg);
            engine.meta.push(meta);
        }
        // replan() computes the placement and searches every shard cold,
        // so no per-tenant plan reshaping is needed here.
        engine.replan();
        Ok(engine)
    }
}

/// The deployment engine: tenant set + placement + per-device searched
/// plans + lowering to the live serving configuration.
pub struct GacerEngine {
    /// Reference platform (the pool's first device at build time): the
    /// global cost model and single-device simulate price against it.
    platform: Platform,
    opts: SimOptions,
    search_cfg: SearchConfig,
    /// Budget for incremental (admit/evict/migrate) re-searches; cold
    /// re-plans stay unbounded ([`EngineBuilder::replan_budget`]).
    replan_budget: SearchBudget,
    tick: Duration,
    /// The device pool the deployment is sharded across (>= 1 device):
    /// one [`Platform`] profile + stable [`DeviceId`] per device. Grows
    /// and shrinks at runtime ([`GacerEngine::add_device`] /
    /// [`GacerEngine::remove_device`]); dense indices shift on removal,
    /// ids never do.
    pool: DevicePool,
    /// Placement objective for placement, admission, and migration.
    objective: PlacementObjective,
    set: TenantSet,
    meta: Vec<TenantMeta>,
    next_id: u64,
    /// The device-dimensioned plan: placement + one plan per shard.
    sharded: ShardedDeploymentPlan,
    /// The shards projected back onto global slot order (cached; what
    /// [`GacerEngine::plan`] exposes).
    merged: DeploymentPlan,
    /// Per-device bookkeeping of the most recent search that touched the
    /// device (`None` for empty devices).
    reports: Vec<Option<SearchReport>>,
    /// One persistent warm-start cache per device
    /// ([`crate::search::SearchState`]): compiled tenant streams, last
    /// converged plan, descent cursor. Filled by the cold build/replan
    /// searches and reused by every incremental re-search, which
    /// recompiles only the tenants whose chunking actually changed.
    search_states: Vec<SearchState>,
    /// EWMA of recent incremental re-search wall-times (µs) — the
    /// observed-telemetry input to cost/gain-aware migration
    /// ([`GacerEngine::migration_cost`]).
    replan_cost_ewma_us: Option<f64>,
    last_report: Option<SearchReport>,
    /// Device affected by the most recent admit/evict/replan event (for
    /// a migration: the receiving device).
    last_searched_device: Option<usize>,
    /// Every device the most recent event re-searched: one for
    /// admit/evict, the source and destination pair for a migration,
    /// all occupied devices for a cold `replan`.
    last_searched_devices: Vec<usize>,
    /// Cumulative-counter window behind [`GacerEngine::record_served`],
    /// keyed by stable tenant id.
    served_window: crate::metrics::DemandWindow,
    /// Executed-migration memory for the policy cooldown
    /// ([`MigrationPolicy::cooldown_windows`]): while an entry's
    /// `remaining > 0`, a proposal moving its tenant back onto the device
    /// it left is suppressed. Aged by one window per
    /// [`GacerEngine::maybe_migrate`] consultation.
    cooldowns: Vec<Cooldown>,
    /// Error-budget burn monitor over SLO-tracked tenants, keyed by
    /// stable id. Fed by [`GacerEngine::record_latencies`], read by
    /// [`GacerEngine::slo_pressure`] and the admission gate, and acted
    /// on by [`GacerEngine::maybe_regulate`].
    slo_monitor: SloMonitor,
    /// Tenant ids whose served-counter baseline must be seeded at the
    /// next [`GacerEngine::record_served`]: a readmitted serving tenant
    /// inherits its predecessor's cumulative server counter (the server
    /// matches counters by name/family across hot swaps), and none of
    /// that history belongs to the new tenant.
    pending_baseline_seed: BTreeSet<u64>,
    /// `(name, family)` of recently evicted serving tenants — how
    /// [`GacerEngine::admit_with`] recognizes an evict→readmit of the
    /// same serving identity. Bounded at `EVICTED_SERVING_MEMORY`
    /// entries (oldest dropped).
    evicted_serving: Vec<(String, String)>,
    /// The online predicted-vs-observed correction layer
    /// ([`EngineBuilder::calibration`]); `None` = calibration off, every
    /// decision purely analytic. Fed by
    /// [`GacerEngine::record_latencies`], read through
    /// [`GacerEngine::correction_scale`] by placement, admission,
    /// migration, and regulation.
    calibrator: Option<Calibrator>,
    /// 50/50 EWMA of observed epoch-fence commit latencies (µs) from
    /// [`GacerEngine::redeploy`] / [`GacerEngine::redeploy_cluster`] —
    /// the measured swap-pause input to [`GacerEngine::migration_cost`]
    /// (falls back to one scheduler tick until a fence is observed).
    /// Interior-mutable because redeploys take `&self` (the plan is
    /// read, not changed).
    fence_pause_ewma_us: Cell<Option<f64>>,
    artifact_dir: Option<PathBuf>,
    manifest: Option<ArtifactManifest>,
}

impl GacerEngine {
    pub fn builder() -> EngineBuilder {
        EngineBuilder::new()
    }

    /// Number of deployed tenants.
    pub fn len(&self) -> usize {
        self.set.len()
    }

    pub fn is_empty(&self) -> bool {
        self.set.is_empty()
    }

    /// The deployed tenant DFGs, in slot order.
    pub fn tenants(&self) -> &[Dfg] {
        &self.set.tenants
    }

    /// Stable ids, in slot order.
    pub fn tenant_ids(&self) -> Vec<TenantId> {
        self.meta.iter().map(|m| m.id).collect()
    }

    /// The engine's reference platform (the pool's first device at build
    /// time) — what the global cost model prices against. Per-device
    /// pricing lives in [`GacerEngine::device_pool`].
    pub fn platform(&self) -> &Platform {
        &self.platform
    }

    /// Number of devices the deployment is sharded across (>= 1).
    pub fn n_devices(&self) -> usize {
        self.pool.len()
    }

    /// The device pool: per-device [`Platform`] profiles and stable
    /// [`DeviceId`]s, in dense order.
    pub fn device_pool(&self) -> &DevicePool {
        &self.pool
    }

    /// Stable device ids, in dense order. An id is assigned when its
    /// device joins the pool and is never reused; dense indices shift
    /// when [`GacerEngine::remove_device`] compacts the pool, ids do not.
    pub fn device_ids(&self) -> Vec<DeviceId> {
        self.pool.ids()
    }

    /// The stable id of a deployed tenant's device — the scale-safe
    /// sibling of [`GacerEngine::device_of`].
    pub fn device_id_of(&self, id: TenantId) -> Result<DeviceId> {
        self.device_of(id).map(|d| self.pool.id(d))
    }

    /// The placement objective the engine places, admits, and migrates
    /// under.
    pub fn placement_objective(&self) -> PlacementObjective {
        self.objective
    }

    /// The current searched deployment plan, projected onto global slot
    /// order (for a single-device engine this *is* the searched plan; for
    /// a sharded engine it is the per-tenant view of
    /// [`GacerEngine::sharded_plan`], with the device dimension dropped).
    pub fn plan(&self) -> &DeploymentPlan {
        &self.merged
    }

    /// The device-dimensioned plan: the placement plus one independently
    /// searched [`DeploymentPlan`] per device.
    pub fn sharded_plan(&self) -> &ShardedDeploymentPlan {
        &self.sharded
    }

    /// The current tenant→device placement.
    pub fn placement(&self) -> &Placement {
        &self.sharded.placement
    }

    /// The *dense index* of a deployed tenant's device. Dense indices
    /// shift when a scale-in compacts the pool — hold
    /// [`GacerEngine::device_id_of`] across scale events instead.
    pub fn device_of(&self, id: TenantId) -> Result<usize> {
        let idx = self.index_of(id)?;
        self.sharded
            .placement
            .device_of(idx)
            .ok_or_else(|| Error::InvalidPlan(format!("tenant {id} has no device")))
    }

    /// Bookkeeping of the most recent (cold or incremental) search — on a
    /// sharded engine, the search of the most recently affected shard
    /// (after a cold re-plan: the bottleneck device's). `None` when the
    /// most recent event ran no search (e.g. an eviction emptied its
    /// device); per-device state stays in [`GacerEngine::device_reports`].
    pub fn last_report(&self) -> Option<&SearchReport> {
        self.last_report.as_ref()
    }

    /// Per-device search bookkeeping (`None` for empty devices).
    pub fn device_reports(&self) -> &[Option<SearchReport>] {
        &self.reports
    }

    /// The device the most recent admit/evict/replan event re-searched —
    /// how tests assert that tenant churn touches only the affected shard.
    /// For a migration this is the *receiving* device; the full set is
    /// [`GacerEngine::last_searched_devices`].
    pub fn last_searched_device(&self) -> Option<usize> {
        self.last_searched_device
    }

    /// Every device the most recent event re-searched: one device for
    /// admit/evict, exactly the `[source, destination]` pair for a
    /// migration, all occupied devices for a cold `replan` — how tests
    /// assert a migration re-plans two shards and nothing else.
    pub fn last_searched_devices(&self) -> &[usize] {
        &self.last_searched_devices
    }

    /// Simulate the current deployment on the engine's platform: each
    /// device simulates its own shard, and the cluster outcome is the
    /// bottleneck device's (devices run independently, so the slowest
    /// shard bounds the makespan). For a single-device engine this is
    /// exactly the classic whole-set simulation.
    pub fn simulate(&self) -> SimOutcome {
        if self.pool.len() == 1 && *self.pool.platform(0) == self.platform {
            // Single device on the reference platform: simulate the owned
            // set directly (no per-shard tenant cloning).
            return self.set.simulate(&self.merged, self.opts);
        }
        self.simulate_devices()
            .into_iter()
            .max_by(|a, b| {
                a.makespan_us
                    .partial_cmp(&b.makespan_us)
                    .unwrap_or(std::cmp::Ordering::Equal)
            })
            .unwrap_or_else(|| self.set.simulate(&self.merged, self.opts))
    }

    /// Simulator options for one device: the shared options on a uniform
    /// reference pool (bit-identical to the pre-pool engine), that
    /// device's own platform otherwise.
    fn device_opts(&self, d: usize) -> SimOptions {
        if self.pool.is_uniform() && *self.pool.platform(0) == self.platform {
            self.opts
        } else {
            SimOptions::for_platform(self.pool.platform(d))
        }
    }

    /// One device's shard as a standalone tenant set, priced by that
    /// device's own cost model on a heterogeneous pool.
    fn device_set(&self, d: usize) -> TenantSet {
        if self.pool.is_uniform() && *self.pool.platform(0) == self.platform {
            self.set.shard(&self.sharded.placement, d)
        } else {
            self.set.shard_on(&self.sharded.placement, d, self.pool.cost(d))
        }
    }

    /// Simulate every device's shard independently, each on its own
    /// platform (empty devices report a zero-makespan outcome).
    pub fn simulate_devices(&self) -> Vec<SimOutcome> {
        (0..self.pool.len())
            .map(|d| {
                self.device_set(d)
                    .simulate(&self.sharded.shards[d], self.device_opts(d))
            })
            .collect()
    }

    fn index_of(&self, id: TenantId) -> Result<usize> {
        self.meta
            .iter()
            .position(|m| m.id == id)
            .ok_or(Error::UnknownTenant(id.0))
    }

    fn check_admissible(&self, dfg: &Dfg, family: Option<&str>) -> Result<()> {
        crate::dfg::validate(dfg)?;
        if let Some(f) = family {
            if let Some(m) = &self.manifest {
                if m.variants_of(f).is_empty() {
                    return Err(Error::MissingFamily(f.to_string()));
                }
            }
            // Serving tenants are identified by name on the live path
            // (hot swaps match queues by it), so a deployed serving name
            // cannot be reused while its owner is still deployed.
            // Simulation-only tenants never reach a server and may share
            // names freely (e.g. two "Alex" DFGs in a combo).
            if self
                .meta
                .iter()
                .any(|m| m.family.is_some() && m.name == dfg.name)
            {
                return Err(Error::InvalidConfig(format!(
                    "serving tenant name {:?} is already deployed",
                    dfg.name
                )));
            }
        }
        Ok(())
    }

    /// Admit a simulation/search tenant at runtime. Triggers an
    /// incremental re-search seeded with the current plan (the newcomer
    /// starts at the deployment's pointer level, Algorithm 1 resumes from
    /// there).
    pub fn admit(&mut self, dfg: Dfg) -> Result<TenantId> {
        self.admit_with(dfg, None, default_policy(), SloPolicy::default(), None)
    }

    /// Admit a serving tenant of an artifact family at runtime.
    pub fn admit_serving(
        &mut self,
        name: impl Into<String>,
        family: &str,
        policy: BatchPolicy,
    ) -> Result<TenantId> {
        self.admit_serving_with_slo(
            name,
            family,
            policy,
            SloPolicy::default(),
            None,
        )
    }

    /// Admit a serving tenant with an SLO contract at runtime — the
    /// runtime counterpart of [`EngineBuilder::serving_tenant_with_slo`].
    /// Subject to SLO admission control: while any tracked tenant of a
    /// strictly higher [`crate::slo::Tier`] is burning its error budget,
    /// the newcomer is refused with [`Error::Overloaded`] — capacity
    /// under pressure goes to the tiers already struggling, not to new
    /// load.
    pub fn admit_serving_with_slo(
        &mut self,
        name: impl Into<String>,
        family: &str,
        policy: BatchPolicy,
        slo: SloPolicy,
        target: Option<SloTarget>,
    ) -> Result<TenantId> {
        let mut dfg = zoo::serving_proxy(family, policy.max_batch)
            .ok_or_else(|| Error::UnknownModel(format!("serving family {family}")))?;
        dfg.name = name.into();
        self.admit_with(dfg, Some(family.to_string()), policy, slo, target)
    }

    /// Cross-device admission control: place the newcomer per the
    /// engine's objective — the least loaded device
    /// ([`Placement::least_loaded`]) under
    /// [`PlacementObjective::LoadBalance`], the device whose max
    /// interference score the newcomer least raises
    /// ([`Placement::least_interfering`]) under
    /// [`PlacementObjective::InterferenceAware`], the HBM-fitting device
    /// whose roofline score it least raises
    /// ([`Placement::fit_memory_aware`], refusing with
    /// [`Error::MemoryCapacity`] when the newcomer's resident footprint
    /// fits nowhere) under [`PlacementObjective::MemoryAware`] — grow
    /// that shard's plan, and incrementally re-search **only that
    /// shard**.
    fn admit_with(
        &mut self,
        dfg: Dfg,
        family: Option<String>,
        policy: BatchPolicy,
        slo: SloPolicy,
        target: Option<SloTarget>,
    ) -> Result<TenantId> {
        slo.validate()?;
        self.check_admissible(&dfg, family.as_deref())?;
        // SLO admission control: a burning higher tier keeps its
        // headroom — lower-or-equal tiers wait until the burn clears.
        if self.slo_monitor.any_burning_above(slo.tier) {
            return Err(Error::Overloaded(format!(
                "admission of {:?} (tier {}) refused: a higher tier is \
                 burning its error budget",
                dfg.name, slo.tier
            )));
        }
        // Device selection happens before any engine state mutates: a
        // memory-capacity refusal must leave no trace of the newcomer.
        // The pool-aware choosers price the newcomer per candidate
        // device (and on a uniform reference pool reduce exactly to the
        // homogeneous choosers). Standing tenants' weights carry their
        // calibrated corrections; the newcomer has no residual yet, so
        // it is priced analytically everywhere — and with no trusted
        // residual anywhere the scale is the identity and the scaled
        // choosers delegate to the analytic ones bit-for-bit.
        let scale = self.correction_scale();
        let device = match self.objective {
            PlacementObjective::LoadBalance => self
                .sharded
                .placement
                .least_loaded_pool_scaled(&self.set, &self.pool, &dfg, &scale),
            PlacementObjective::InterferenceAware => self
                .sharded
                .placement
                .least_interfering_pool_scaled(&self.set, &self.pool, &dfg, &scale),
            PlacementObjective::MemoryAware => self
                .sharded
                .placement
                .fit_memory_aware_pool_scaled(&self.set, &self.pool, &dfg, &scale)?,
        };
        let id = TenantId(self.next_id);
        self.next_id += 1;
        let name = dfg.name.clone();
        let dfg_len = dfg.len();
        // Evict→readmit of the same serving identity: the server-side
        // cumulative counter (matched by name/family across hot swaps)
        // survives the churn, but its history belongs to the evicted
        // tenant. Seed the new id's baseline at the next record_served
        // so only post-readmission increments count as its demand.
        if let Some(f) = &family {
            if let Some(pos) = self
                .evicted_serving
                .iter()
                .position(|(n, ef)| n == &name && ef == f)
            {
                self.evicted_serving.remove(pos);
                self.pending_baseline_seed.insert(id.0);
            }
        }
        if let Some(t) = target {
            self.slo_monitor.track(id.0, slo.tier, t)?;
        }
        let slot = self.set.len();
        self.set.admit(dfg);
        self.meta
            .push(TenantMeta { id, name, family, policy, demand: 0.0, slo, target });
        self.sharded.placement.assign(slot, device);
        // The newcomer lands at the end of the device's local order (its
        // global slot is the largest), so push_tenant's slot matches.
        let level = self.sharded.shards[device].pointers.pointers_per_tenant();
        self.sharded.shards[device].push_tenant(dfg_len, level);
        self.research_shard(device)?;
        Ok(id)
    }

    /// Evict a tenant by id; **only the shard that lost the tenant** is
    /// incrementally re-planned (evicting the last tenant on a device
    /// simply leaves that device empty). Returns the evicted DFG.
    pub fn evict(&mut self, id: TenantId) -> Result<Dfg> {
        let idx = self.index_of(id)?;
        let (device, local) = self
            .sharded
            .placement
            .locate(idx)
            .ok_or_else(|| Error::InvalidPlan(format!("tenant {id} has no device")))?;
        let meta = self.meta.remove(idx);
        // Remember the serving identity so a readmission under the same
        // name/family gets its served-counter baseline seeded (the
        // server's cumulative counter survives the churn).
        if let Some(f) = meta.family {
            self.evicted_serving.push((meta.name, f));
            if self.evicted_serving.len() > EVICTED_SERVING_MEMORY {
                self.evicted_serving.remove(0);
            }
        }
        self.slo_monitor.forget(id.0);
        self.served_window.forget(id.0);
        self.pending_baseline_seed.remove(&id.0);
        // The trust ramp resets with the identity: a readmission under a
        // fresh id starts analytic-only, and the dead id's residuals must
        // not linger in the bounded store.
        if let Some(c) = &mut self.calibrator {
            c.forget(id.0);
        }
        let dfg = self.set.evict(idx);
        self.sharded.placement.remove_slot(idx);
        self.sharded.shards[device].remove_tenant(local);
        self.research_shard(device)?;
        Ok(dfg)
    }

    /// Run a full cold re-plan: recompute the placement across all
    /// devices under the engine's [`PlacementObjective`] and run
    /// Algorithm 1 from the unregulated plan on every shard, replacing
    /// the current sharded plan.
    pub fn replan(&mut self) {
        let n_devices = self.pool.len();
        if self.set.is_empty() {
            let empty = Placement::from_assignments(vec![Vec::new(); n_devices]);
            self.sharded = ShardedDeploymentPlan::unregulated(empty);
            self.merged = DeploymentPlan::unregulated(0);
            self.reports = (0..n_devices).map(|_| None).collect();
            self.search_states = vec![SearchState::default(); n_devices];
            self.last_report = None;
            self.last_searched_device = None;
            self.last_searched_devices = Vec::new();
            return;
        }
        // Cold searches also refill the per-device warm states, so the
        // next incremental event starts from this re-plan's compiled
        // streams and converged plans.
        let mut states = vec![SearchState::default(); n_devices];
        let search = ShardedSearch::new(&self.set, self.opts, self.search_cfg)
            .objective(self.objective)
            .pool(&self.pool);
        // A trusted calibration residual re-weights the placement: the
        // mis-modeled tenant is priced at its corrected cost before the
        // per-shard searches run. With no trusted residual the scale is
        // the identity and this is the plain analytic cold re-plan,
        // bit-for-bit.
        let scale = self.correction_scale();
        let report = if scale.iter().all(|&k| k == 1.0) {
            search.run_warm(n_devices, &mut states)
        } else {
            let placement = Placement::with_objective_pool_scaled(
                &self.set,
                &self.pool,
                self.objective,
                &scale,
            );
            search.run_placed_warm(placement, &mut states)
        };
        self.search_states = states;
        let bottleneck = report.bottleneck_device();
        self.last_report =
            bottleneck.and_then(|d| report.reports[d].clone());
        self.last_searched_device = bottleneck;
        self.last_searched_devices = report
            .reports
            .iter()
            .enumerate()
            .filter_map(|(d, r)| r.as_ref().map(|_| d))
            .collect();
        self.reports = report.reports;
        self.sharded = report.plan;
        self.rebuild_merged();
    }

    /// Incremental re-search of one shard, seeded with its current
    /// (already re-shaped) plan, warm-started from the device's
    /// [`SearchState`] and bounded by the engine's replan budget. Other
    /// shards are left untouched.
    fn research_shard(&mut self, device: usize) -> Result<()> {
        let seed = self.sharded.shards[device].clone();
        let report = ShardedSearch::new(&self.set, self.opts, self.search_cfg)
            .pool(&self.pool)
            .budget(self.replan_budget)
            .research_device_warm(
                &self.sharded.placement,
                device,
                seed,
                &mut self.search_states[device],
            );
        let report = match report {
            Ok(r) => r,
            Err(e) => {
                // Unreachable for engine-built seeds (the reshape keeps
                // them valid), but if it ever fires the reshaped
                // un-researched plan is still consistent — keep the
                // merged view coherent before surfacing the error.
                self.rebuild_merged();
                return Err(e);
            }
        };
        match report {
            Some(report) => {
                self.note_replan_cost(report.elapsed);
                self.sharded.shards[device] = report.plan.clone();
                self.reports[device] = Some(report.clone());
                self.last_report = Some(report);
            }
            None => {
                // The device is now empty: no search ran, so there is no
                // report for this event (a stale previous report must not
                // be attributed to it).
                self.sharded.shards[device] = DeploymentPlan::unregulated(0);
                self.reports[device] = None;
                self.last_report = None;
            }
        }
        self.last_searched_device = Some(device);
        self.last_searched_devices = vec![device];
        self.rebuild_merged();
        Ok(())
    }

    /// Fold one incremental re-search's wall-time into the telemetry the
    /// cost/gain migration mode consumes (a 50/50 EWMA: recent events
    /// dominate, one outlier does not).
    fn note_replan_cost(&mut self, elapsed: Duration) {
        let us = elapsed.as_secs_f64() * 1e6;
        self.replan_cost_ewma_us = Some(match self.replan_cost_ewma_us {
            Some(prev) => 0.5 * prev + 0.5 * us,
            None => us,
        });
    }

    /// Observed cost of one incremental shard re-search (µs, EWMA over
    /// the budgeted-search telemetry of recent admit/evict/migrate
    /// events). `None` until the engine has re-searched anything.
    pub fn observed_replan_cost_us(&self) -> Option<f64> {
        self.replan_cost_ewma_us
    }

    /// The budget incremental re-searches run under
    /// ([`EngineBuilder::replan_budget`]).
    pub fn replan_budget(&self) -> SearchBudget {
        self.replan_budget
    }

    /// Build a [`MigrationCost`] from the engine's own observed
    /// telemetry: re-plan cost is twice the EWMA of recent incremental
    /// re-search wall-times (a migration re-searches the source and
    /// destination shards), swap pause is the **observed** epoch-fence
    /// commit latency — an EWMA over the wall-time of recent
    /// [`GacerEngine::redeploy`] / [`GacerEngine::redeploy_cluster`]
    /// calls — falling back to one scheduler tick per affected device
    /// (the analytic guess of `docs/OPERATIONS.md`) until any redeploy
    /// has been measured. Before any incremental event has run, the
    /// re-plan cost likewise falls back to the slowest *cold* per-device
    /// search of the current deployment — a conservative upper bound (a
    /// cold search costs more than a seeded one), so the gate never
    /// prices an unknown re-plan as free. Pair it with
    /// [`MigrationPolicy::cost_aware`] to get a policy that only moves a
    /// tenant when the predicted gain pays for the disruption within
    /// `payback_windows` observe windows.
    pub fn migration_cost(&self, payback_windows: f64) -> MigrationCost {
        let per_shard = self.replan_cost_ewma_us.unwrap_or_else(|| {
            self.reports
                .iter()
                .flatten()
                .map(|r| r.elapsed.as_secs_f64() * 1e6)
                .fold(0.0, f64::max)
        });
        let swap_pause_us = self
            .fence_pause_ewma_us
            .get()
            .unwrap_or(self.tick.as_secs_f64() * 1e6);
        MigrationCost {
            replan_us: 2.0 * per_shard,
            swap_pause_us,
            payback_windows,
        }
    }

    /// Fold one observed epoch-fence commit (a redeploy's wall-time)
    /// into the swap-pause telemetry [`GacerEngine::migration_cost`]
    /// consumes — the same 50/50 EWMA shape as the re-plan cost, held in
    /// a [`Cell`] because redeploys take `&self`.
    fn note_fence_pause(&self, elapsed: Duration) {
        let us = elapsed.as_secs_f64() * 1e6;
        self.fence_pause_ewma_us.set(Some(match self.fence_pause_ewma_us.get() {
            Some(prev) => 0.5 * prev + 0.5 * us,
            None => us,
        }));
    }

    /// Observed epoch-fence commit latency (µs, EWMA over recent
    /// [`GacerEngine::redeploy`] / [`GacerEngine::redeploy_cluster`]
    /// wall-times). `None` until the engine has redeployed anything —
    /// [`GacerEngine::migration_cost`] then falls back to one scheduler
    /// tick.
    pub fn observed_fence_pause_us(&self) -> Option<f64> {
        self.fence_pause_ewma_us.get()
    }

    /// Feed an externally measured fence pause into the swap-pause
    /// telemetry — for operations loops that time the commit themselves
    /// (e.g. around a maintenance drain) instead of going through
    /// [`GacerEngine::redeploy_cluster`].
    pub fn record_fence_pause(&self, elapsed: Duration) {
        self.note_fence_pause(elapsed);
    }

    fn rebuild_merged(&mut self) {
        self.merged = self
            .sharded
            .merged()
            .expect("engine keeps the placement covering every slot");
    }

    fn family_variants(&self) -> Result<Vec<Vec<usize>>> {
        let manifest = self
            .manifest
            .as_ref()
            .ok_or_else(|| Error::InvalidConfig("engine has no artifact dir".into()))?;
        self.meta
            .iter()
            .map(|m| {
                let family = m.family.as_deref().ok_or_else(|| {
                    Error::InvalidConfig(format!(
                        "tenant {} ({}) has no artifact family",
                        m.id, m.name
                    ))
                })?;
                let v: Vec<usize> = manifest.variants_of(family).into_keys().collect();
                if v.is_empty() {
                    return Err(Error::MissingFamily(family.to_string()));
                }
                Ok(v)
            })
            .collect()
    }

    fn serving_specs(&self) -> Result<Vec<(String, String, BatchPolicy, SloPolicy)>> {
        self.meta
            .iter()
            .map(|m| {
                Ok((
                    m.name.clone(),
                    m.family
                        .clone()
                        .ok_or_else(|| {
                            Error::InvalidConfig(format!(
                                "tenant {} ({}) has no artifact family",
                                m.id, m.name
                            ))
                        })?,
                    m.policy.clone(),
                    m.slo.clone(),
                ))
            })
            .collect()
    }

    /// Lower the current searched plan to the serving configuration.
    ///
    /// Single-device engines only: a sharded engine has one configuration
    /// *per device* — use [`GacerEngine::sharded_deployment`].
    pub fn deployment(&self) -> Result<Deployment> {
        if self.pool.len() > 1 {
            return Err(Error::InvalidConfig(format!(
                "engine is sharded across {} devices: use sharded_deployment()",
                self.pool.len()
            )));
        }
        self.deployment_of(&self.merged)
    }

    /// Lower an arbitrary whole-set plan (e.g. the unregulated baseline)
    /// to a single-server configuration — useful for A/B deployment
    /// comparisons.
    pub fn deployment_of(&self, plan: &DeploymentPlan) -> Result<Deployment> {
        let specs = self.serving_specs()?;
        lower_plan(plan, &self.set.tenants, &specs, &self.family_variants()?, self.tick)
    }

    /// Lower the sharded plan per device: one [`Deployment`] per shard
    /// plus the global-slot routing table — what [`ClusterServer::start`]
    /// consumes. Works for any device count (a 1-device engine yields a
    /// 1-entry cluster).
    pub fn sharded_deployment(&self) -> Result<ShardedDeployment> {
        let specs = self.serving_specs()?;
        let variants = self.family_variants()?;
        let placement = &self.sharded.placement;
        let n_devices = self.pool.len();
        let mut per_device = Vec::with_capacity(n_devices);
        for d in 0..n_devices {
            let tenants = placement.select(&self.set.tenants, d);
            let dspecs = placement.select(&specs, d);
            let dvariants = placement.select(&variants, d);
            per_device.push(lower_plan(
                &self.sharded.shards[d],
                &tenants,
                &dspecs,
                &dvariants,
                self.tick,
            )?);
        }
        let routing = (0..self.set.len())
            .map(|slot| {
                placement.locate(slot).ok_or_else(|| {
                    Error::InvalidPlan(format!("slot {slot} has no device"))
                })
            })
            .collect::<Result<_>>()?;
        Ok(ShardedDeployment {
            per_device,
            routing,
            device_ids: self.pool.ids(),
        })
    }

    fn artifact_dir_str(&self) -> Result<String> {
        self.artifact_dir
            .as_ref()
            .map(|d| d.to_string_lossy().into_owned())
            .ok_or_else(|| Error::InvalidConfig("engine has no artifact dir".into()))
    }

    /// Start the serving coordinator off the searched plan: the single
    /// call that takes "tenants admitted" to "requests served under
    /// granularity regulation". Single-device engines only — a sharded
    /// engine serves through [`GacerEngine::serve_cluster`].
    pub fn serve(&self) -> Result<Server> {
        let dir = self.artifact_dir_str()?;
        let deployment = self.deployment()?;
        Server::start(&dir, deployment.tenants, deployment.config)
    }

    /// Start one [`Server`] per device behind a routing [`ClusterServer`]
    /// front-end — the sharded counterpart of [`GacerEngine::serve`].
    pub fn serve_cluster(&self) -> Result<ClusterServer> {
        let dir = self.artifact_dir_str()?;
        ClusterServer::start_sharded(&dir, self.sharded_deployment()?)
    }

    // ---- live re-deployment ----

    /// Propagate the engine's current plan to a **running** single-device
    /// [`Server`] — lower it and hot-swap it in with [`Server::apply`]
    /// (epoch-fenced; no restart). Call after `admit`/`evict`/`replan` to
    /// make the re-searched plan live.
    ///
    /// Single-device engines only, like [`GacerEngine::deployment`]; a
    /// sharded engine redeploys through
    /// [`GacerEngine::redeploy_cluster`]. Note that an `evict` shifts the
    /// local slot indices of later tenants, exactly as it shifts engine
    /// slots — single-server clients address tenants by slot, so quiesce
    /// or re-resolve slots around an evicting redeploy (the cluster path
    /// handles this by fencing its routing table).
    ///
    /// ```no_run
    /// use gacer::coordinator::BatchPolicy;
    /// use gacer::engine::GacerEngine;
    /// use std::time::Duration;
    ///
    /// let policy = BatchPolicy::new(8, Duration::from_millis(2), vec![1, 2, 4, 8]);
    /// let mut engine = GacerEngine::builder()
    ///     .artifacts("artifacts")
    ///     .serving_tenant("t0", "tiny_cnn", policy.clone()).unwrap()
    ///     .build().unwrap();
    /// let server = engine.serve().unwrap();
    /// engine.admit_serving("t1", "tiny_cnn", policy).unwrap();
    /// engine.redeploy(&server).unwrap(); // the admitted tenant goes live
    /// assert_eq!(server.tenant_specs().len(), 2);
    /// ```
    pub fn redeploy(&self, server: &Server) -> Result<()> {
        let deployment = self.deployment()?;
        // Time only the fence commit itself (the lowering above is
        // engine-side work the serving path never pauses for).
        let start = Instant::now();
        let out = server.apply(deployment);
        if out.is_ok() {
            self.note_fence_pause(start.elapsed());
        }
        out
    }

    /// Propagate the engine's current sharded plan to a **running**
    /// [`ClusterServer`]: lower per device and hot-swap through
    /// [`ClusterServer::apply`], which diffs against what each device is
    /// executing and touches only the devices that changed. Returns the
    /// touched devices. Call after `admit`/`evict`/`replan`/
    /// [`GacerEngine::migrate`] to make the re-searched plans live
    /// without restarting anything.
    ///
    /// ```no_run
    /// use gacer::coordinator::BatchPolicy;
    /// use gacer::engine::GacerEngine;
    /// use std::time::Duration;
    ///
    /// let policy = BatchPolicy::new(8, Duration::from_millis(2), vec![1, 2, 4, 8]);
    /// let mut engine = GacerEngine::builder()
    ///     .devices(2)
    ///     .artifacts("artifacts")
    ///     .serving_tenant("t0", "tiny_cnn", policy.clone()).unwrap()
    ///     .serving_tenant("t1", "tiny_cnn", policy.clone()).unwrap()
    ///     .build().unwrap();
    /// let cluster = engine.serve_cluster().unwrap();
    /// engine.admit_serving("t2", "tiny_cnn", policy).unwrap();
    /// let touched = engine.redeploy_cluster(&cluster).unwrap();
    /// assert_eq!(touched.len(), 1, "only the admitting device swaps");
    /// ```
    pub fn redeploy_cluster(&self, cluster: &ClusterServer) -> Result<Vec<usize>> {
        let deployment = self.sharded_deployment()?;
        let start = Instant::now();
        let out = cluster.apply(deployment);
        // A no-op diff pauses nothing — only commits that actually
        // swapped a device teach the swap-pause estimate.
        if let Ok(touched) = &out {
            if !touched.is_empty() {
                self.note_fence_pause(start.elapsed());
            }
        }
        out
    }

    // ---- load-drift migration ----

    /// Feed observed traffic back into the engine: accumulate `n`
    /// requests onto a tenant's demand counter. Tests and simulations
    /// inject synthetic skew here; an operations loop over a live
    /// cluster uses [`GacerEngine::record_served`] instead.
    pub fn record_requests(&mut self, id: TenantId, n: u64) -> Result<()> {
        let idx = self.index_of(id)?;
        self.meta[idx].demand += n as f64;
        Ok(())
    }

    /// The whole observe step in one call: diff the cluster's cumulative
    /// [`ClusterServer::served_counts`] against the previous call (an
    /// internal [`crate::metrics::DemandWindow`] keyed by stable
    /// [`TenantId`], so slot shifts from evictions and counter restarts
    /// from migrations are never misattributed) and accumulate the
    /// per-window deltas onto each tenant's demand counter. `counts`
    /// must be in current slot order.
    pub fn record_served(&mut self, counts: &[u64]) -> Result<()> {
        if counts.len() != self.len() {
            return Err(Error::InvalidConfig(format!(
                "{} served counts for {} tenants",
                counts.len(),
                self.len()
            )));
        }
        let keys: Vec<u64> = self.meta.iter().map(|m| m.id.0).collect();
        // Readmitted serving identities inherit their predecessor's
        // cumulative counter: seed their baseline at the current value so
        // this window attributes none of the inherited history to them.
        if !self.pending_baseline_seed.is_empty() {
            for (idx, key) in keys.iter().enumerate() {
                if self.pending_baseline_seed.remove(key) {
                    self.served_window.seed(*key, counts[idx]);
                }
            }
        }
        for (idx, d) in self.served_window.delta(&keys, counts).into_iter().enumerate() {
            self.meta[idx].demand += d as f64;
        }
        Ok(())
    }

    /// Start a fresh observation window: zero every tenant's demand
    /// counter (stale traffic should not outvote current traffic
    /// forever).
    pub fn reset_demand(&mut self) {
        for m in &mut self.meta {
            m.demand = 0.0;
        }
    }

    // ---- SLO observation ----

    /// Close one SLO observe window: feed each tenant's latency samples
    /// (microseconds, in current slot order — what
    /// [`crate::coordinator::Server::take_latencies`] /
    /// [`crate::coordinator::ClusterServer::take_latencies`] drain) into
    /// the engine's [`SloMonitor`]. Tenants without an [`SloTarget`] are
    /// ignored by the monitor, so the full cluster drain can be fed
    /// unfiltered. The operations loop calls this beside
    /// [`GacerEngine::record_served`] once per observe window.
    ///
    /// When the engine was built with [`EngineBuilder::calibration`],
    /// this is also the **observe→calibrate** step: each tenant's window
    /// mean is compared against the cost model's prediction for its
    /// current co-location
    /// ([`CostModel::predicted_colocated_latency_us`]) and the residual
    /// feeds the [`Calibrator`]. Tenants with an empty sample buffer
    /// this window contribute no observation (their trust ramp neither
    /// advances nor resets).
    pub fn record_latencies(&mut self, samples: &[Vec<f64>]) -> Result<()> {
        if samples.len() != self.len() {
            return Err(Error::InvalidConfig(format!(
                "{} latency buffers for {} tenants",
                samples.len(),
                self.len()
            )));
        }
        for (m, s) in self.meta.iter().zip(samples) {
            self.slo_monitor.observe(m.id.0, s);
        }
        if self.calibrator.is_some() {
            // Price every observed tenant against the *current* plan
            // first (immutable borrows of set/pool/placement), then
            // mutate the calibrator.
            let mut obs: Vec<(u64, &'static str, f64, f64)> = Vec::new();
            for (slot, (m, s)) in self.meta.iter().zip(samples).enumerate() {
                if s.is_empty() {
                    continue;
                }
                let Some((device, _)) = self.sharded.placement.locate(slot) else {
                    continue;
                };
                let cotenants: Vec<&Dfg> = self
                    .sharded
                    .placement
                    .tenants_on(device)
                    .iter()
                    .filter(|&&t| t != slot)
                    .map(|&t| &self.set.tenants[t])
                    .collect();
                let predicted = self
                    .pool
                    .cost(device)
                    .predicted_colocated_latency_us(&self.set.tenants[slot], &cotenants);
                let observed = s.iter().sum::<f64>() / s.len() as f64;
                obs.push((
                    m.id.0,
                    self.pool.platform(device).name,
                    predicted,
                    observed,
                ));
            }
            let calibrator = self.calibrator.as_mut().expect("checked above");
            for (id, platform, predicted, observed) in obs {
                calibrator.observe(id, platform, predicted, observed);
            }
        }
        Ok(())
    }

    /// The current burn-rate verdict for one tenant, or `None` when the
    /// tenant carries no [`SloTarget`] (or the id is unknown).
    pub fn slo_pressure(&self, id: TenantId) -> Option<SloPressure> {
        self.slo_monitor.pressure(id.0)
    }

    /// Every SLO-tracked tenant's pressure, keyed by stable id.
    pub fn slo_pressures(&self) -> Vec<(TenantId, SloPressure)> {
        self.slo_monitor
            .pressures()
            .into_iter()
            .map(|(k, p)| (TenantId(k), p))
            .collect()
    }

    /// The engine's error-budget monitor (read-only introspection).
    pub fn slo_monitor(&self) -> &SloMonitor {
        &self.slo_monitor
    }

    /// Per-tenant observed load weights, in slot order: observed demand
    /// (requests) × the cost model's per-request serial latency — so a
    /// hot light model and a warm heavy model compare fairly. Until any
    /// demand is recorded, falls back to the cost model alone (the same
    /// weights the initial placement balanced, i.e. "assume uniform
    /// traffic").
    ///
    /// Under [`EngineBuilder::calibration`], each weight additionally
    /// carries the tenant's trusted correction factor for the platform
    /// it currently runs on ([`GacerEngine::corrections`]) — a tenant
    /// the cost model underprices 3× weighs 3× heavier to the migration
    /// and regulation thresholds. Untrusted or absent residuals
    /// contribute exactly 1.0, so the analytic weights are unchanged
    /// until the trust ramp fills.
    pub fn observed_tenant_weights(&self) -> Vec<f64> {
        let observed = self.meta.iter().any(|m| m.demand > 0.0);
        let scale = self.correction_scale();
        self.set
            .tenants
            .iter()
            .zip(&self.meta)
            .zip(&scale)
            .map(|((dfg, m), &k)| {
                let per_request = self.set.cost.sequential_latency_us(dfg) * k;
                if observed {
                    m.demand * per_request
                } else {
                    per_request
                }
            })
            .collect()
    }

    // ---- online calibration ----

    /// Per-slot correction factors for the calibrated decision paths:
    /// each tenant's trusted residual for the platform of the device it
    /// currently occupies, 1.0 for untrusted/unknown pairs, unplaced
    /// slots, or an uncalibrated engine. Multiplying by 1.0 is
    /// bit-exact in IEEE 754, so an all-identity scale perturbs
    /// nothing.
    fn correction_scale(&self) -> Vec<f64> {
        let Some(c) = &self.calibrator else {
            return vec![1.0; self.len()];
        };
        self.meta
            .iter()
            .enumerate()
            .map(|(slot, m)| match self.sharded.placement.locate(slot) {
                Some((device, _)) => {
                    c.correction(m.id.0, self.pool.platform(device).name)
                }
                None => 1.0,
            })
            .collect()
    }

    /// The engine's online calibrator (read-only introspection), or
    /// `None` when the engine was built without
    /// [`EngineBuilder::calibration`].
    pub fn calibration(&self) -> Option<&Calibrator> {
        self.calibrator.as_ref()
    }

    /// Snapshot every (tenant, platform) residual the calibrator holds
    /// — trust state, clamped correction, raw ratio EWMA — for dashboards
    /// and the `serve --calibrate` console. Empty when the engine is
    /// uncalibrated or nothing has been observed yet.
    pub fn corrections(&self) -> Vec<CalibrationEntry> {
        self.calibrator.as_ref().map(Calibrator::entries).unwrap_or_default()
    }

    /// One tenant's effective correction factor on its **current**
    /// device (1.0 when untrusted, unplaced, or the engine is
    /// uncalibrated). Errors only on an unknown id.
    pub fn correction_of(&self, id: TenantId) -> Result<f64> {
        let slot = self.index_of(id)?;
        Ok(self.correction_scale()[slot])
    }

    /// Per-device observed load: [`GacerEngine::observed_tenant_weights`]
    /// summed by the current placement — what [`MigrationPolicy`]
    /// thresholds on.
    pub fn observed_device_loads(&self) -> Vec<f64> {
        let weights = self.observed_tenant_weights();
        (0..self.pool.len())
            .map(|d| {
                self.sharded
                    .placement
                    .tenants_on(d)
                    .iter()
                    .map(|&s| weights[s])
                    .sum()
            })
            .collect()
    }

    /// Migrate one tenant to another device — the load-drift correction
    /// a [`MigrationPolicy`] proposes. The tenant keeps its stable id
    /// *and its global slot* (migration never compacts slots, unlike
    /// eviction); only its device changes. Exactly the **two affected
    /// shards** are re-planned, each with an incremental seeded
    /// re-search ([`crate::search::ShardedSearch::research_devices`]);
    /// every other device's plan is left bit-identical. Pair with
    /// [`GacerEngine::redeploy_cluster`] to make the move live.
    ///
    /// Both `to` and the returned origin are stable [`DeviceId`]s, not
    /// dense indices — they keep meaning the same physical device across
    /// [`GacerEngine::add_device`] / [`GacerEngine::remove_device`].
    ///
    /// Returns the device the tenant came from.
    pub fn migrate(&mut self, id: TenantId, to: DeviceId) -> Result<DeviceId> {
        let slot = self.index_of(id)?;
        let to = self.pool.index_of(to).ok_or_else(|| {
            Error::InvalidConfig(format!(
                "cannot migrate {id} to {to}: no such device in pool {}",
                self.pool.label()
            ))
        })?;
        let (from, local) = self
            .sharded
            .placement
            .locate(slot)
            .ok_or_else(|| Error::InvalidPlan(format!("tenant {id} has no device")))?;
        if from == to {
            return Err(Error::InvalidConfig(format!(
                "tenant {id} is already on {}",
                self.pool.id(to)
            )));
        }
        // Reshape: drop from the source shard, insert into the
        // destination shard at the position its global slot sorts to.
        let dfg_len = self.set.tenants[slot].len();
        self.sharded.shards[from].remove_tenant(local);
        self.sharded.placement.move_slot(slot, to);
        let dest_local = self
            .sharded
            .placement
            .tenants_on(to)
            .iter()
            .position(|&s| s == slot)
            .expect("slot was just placed on the destination");
        let level = self.sharded.shards[to].pointers.pointers_per_tenant();
        self.sharded.shards[to].insert_tenant(dest_local, dfg_len, level);

        // Two-shard seeded re-search: source (may now be empty) and
        // destination, nothing else — warm-started from each device's
        // state and bounded by the engine's replan budget.
        let seeds = vec![
            self.sharded.shards[from].clone(),
            self.sharded.shards[to].clone(),
        ];
        let reports = ShardedSearch::new(&self.set, self.opts, self.search_cfg)
            .pool(&self.pool)
            .budget(self.replan_budget)
            .research_devices_warm(
                &self.sharded.placement,
                &[from, to],
                seeds,
                &mut self.search_states,
            );
        let reports = match reports {
            Ok(r) => r,
            Err(e) => {
                // Same contract as research_shard: the reshaped plan is
                // consistent even un-researched; keep views coherent.
                self.rebuild_merged();
                return Err(e);
            }
        };
        for (&d, report) in [from, to].iter().zip(reports) {
            match report {
                Some(report) => {
                    self.note_replan_cost(report.elapsed);
                    self.sharded.shards[d] = report.plan.clone();
                    self.reports[d] = Some(report.clone());
                    self.last_report = Some(report);
                }
                None => {
                    self.sharded.shards[d] = DeploymentPlan::unregulated(0);
                    self.reports[d] = None;
                }
            }
        }
        self.last_searched_device = Some(to);
        self.last_searched_devices = vec![from, to];
        // The tenant's server-side counter restarts on its new device:
        // drop its baseline so the next `record_served` attributes the
        // fresh counter's full value instead of guessing from direction.
        self.served_window.forget(id.0);
        self.rebuild_merged();
        Ok(self.pool.id(from))
    }

    /// Consult a [`MigrationPolicy`] against the observed device loads
    /// and, if it proposes a move, execute it with
    /// [`GacerEngine::migrate`]. Returns the executed migration, `None`
    /// when the cluster is balanced enough (or no single move helps).
    /// The operations loop calls this periodically, then
    /// [`GacerEngine::redeploy_cluster`] when a move happened.
    ///
    /// With a cost/gain policy ([`MigrationPolicy::cost_aware`], fed
    /// from [`GacerEngine::migration_cost`]'s observed telemetry) a
    /// marginal move that would not pay for its own re-plan + swap-pause
    /// disruption is declined even when the imbalance ratio triggers.
    ///
    /// ```
    /// use gacer::engine::{GacerEngine, MigrationPolicy};
    /// use gacer::models::zoo;
    /// use gacer::profile::DeviceId;
    /// use gacer::search::SearchConfig;
    ///
    /// let quick = SearchConfig {
    ///     max_pointers: 1,
    ///     rounds_per_level: 1,
    ///     positions_per_coordinate: 4,
    ///     spatial_steps_per_level: 1,
    ///     ..Default::default()
    /// };
    /// let mut engine = GacerEngine::builder()
    ///     .devices(2)
    ///     .search(quick)
    ///     .tenant(zoo::build_default("Alex").unwrap())
    ///     .tenant(zoo::build_default("M3").unwrap())
    ///     .tenant(zoo::build_default("R18").unwrap())
    ///     .build()
    ///     .unwrap();
    /// // Balanced so far: nothing to do.
    /// assert!(engine.maybe_migrate(&MigrationPolicy::default()).unwrap().is_none());
    /// // Traffic drifts: every tenant on the 2-tenant device runs hot.
    /// let busy: Vec<_> = engine
    ///     .tenant_ids()
    ///     .into_iter()
    ///     .enumerate()
    ///     .filter(|&(slot, _)| engine.placement().tenants_on(0).contains(&slot))
    ///     .collect();
    /// for &(_, id) in &busy {
    ///     engine.record_requests(id, 10_000).unwrap();
    /// }
    /// if busy.len() > 1 {
    ///     let m = engine.maybe_migrate(&MigrationPolicy::default()).unwrap().unwrap();
    ///     assert_eq!((m.from, m.to), (DeviceId(0), DeviceId(1)));
    ///     assert_eq!(engine.last_searched_devices(), &[0, 1]);
    /// }
    /// ```
    pub fn maybe_migrate(
        &mut self,
        policy: &MigrationPolicy,
    ) -> Result<Option<Migration>> {
        let weights = self.observed_tenant_weights();
        let proposal = match self.objective {
            PlacementObjective::LoadBalance => policy.propose(&weights, &self.sharded.placement),
            PlacementObjective::InterferenceAware => policy.propose_interference_aware(
                &weights,
                &self.sharded.placement,
                &self.set,
            ),
            PlacementObjective::MemoryAware => policy.propose_memory_aware(
                &weights,
                &self.sharded.placement,
                &self.set,
            ),
        };
        // Cooldown ([`MigrationPolicy::cooldown_windows`]): a proposal
        // that would move a recently migrated tenant straight back is
        // suppressed, damping A→B→A thrash under alternating skew. One
        // consultation = one observe window; entries age before any new
        // migration is recorded, so a fresh cooldown survives intact
        // until the next consultation.
        let suppressed = proposal.as_ref().is_some_and(|p| {
            let id = self.meta[p.slot].id;
            let to_id = self.pool.id(p.to);
            self.cooldowns
                .iter()
                .any(|c| c.remaining > 0 && c.tenant == id && c.from == to_id)
        });
        for c in &mut self.cooldowns {
            c.remaining = c.remaining.saturating_sub(1);
        }
        self.cooldowns.retain(|c| c.remaining > 0);
        let Some(proposal) = proposal else {
            return Ok(None);
        };
        if suppressed {
            return Ok(None);
        }
        let id = self.meta[proposal.slot].id;
        let (from_id, to_id) = (self.pool.id(proposal.from), self.pool.id(proposal.to));
        self.migrate(id, to_id)?;
        if policy.cooldown_windows > 0 {
            self.cooldowns.push(Cooldown {
                tenant: id,
                from: from_id,
                remaining: policy.cooldown_windows,
            });
        }
        Ok(Some(Migration { tenant: id, from: from_id, to: to_id }))
    }

    /// The SLO-aware regulation step: treat **sustained** error-budget
    /// burn as a placement problem before falling back to load-drift
    /// migration.
    ///
    /// A tenant that has been paging for at least
    /// [`BurnConfig::sustained_page_windows`] consecutive windows (the
    /// highest tier / longest streak first) is acted on directly:
    ///
    /// * sharing its device with other tenants on a multi-device engine —
    ///   **migrate** it to the least-loaded other device (two-shard
    ///   seeded re-search, like [`GacerEngine::migrate`]);
    /// * alone on its device, or single-device engine — **re-search its
    ///   shard** seeded with the current plan, letting the
    ///   granularity-aware search re-cut the schedule around the observed
    ///   pressure.
    ///
    /// After acting, the tenant's burn history restarts so the follow-up
    /// windows judge the *new* plan on fresh evidence (one sustained burn
    /// triggers one action, not one per window). With no sustained burn
    /// the call degrades to exactly [`GacerEngine::maybe_migrate`].
    /// Pair with [`GacerEngine::redeploy_cluster`] to make the action
    /// live.
    pub fn maybe_regulate(
        &mut self,
        policy: &MigrationPolicy,
    ) -> Result<Option<RegulationAction>> {
        let needed = self.slo_monitor.config().sustained_page_windows;
        let burning = self
            .slo_monitor
            .pressures()
            .into_iter()
            .filter(|(_, p)| p.page_streak >= needed)
            .max_by_key(|&(_, p)| (p.tier.priority(), p.page_streak));
        let Some((key, _)) = burning else {
            return self
                .maybe_migrate(policy)
                .map(|m| m.map(RegulationAction::Migrated));
        };
        let id = TenantId(key);
        let slot = self.index_of(id)?;
        let from = self
            .sharded
            .placement
            .device_of(slot)
            .ok_or_else(|| Error::InvalidPlan(format!("tenant {id} has no device")))?;
        let crowded = self.sharded.placement.tenants_on(from).len() > 1;
        let action = if self.pool.len() > 1 && crowded {
            let loads = self.observed_device_loads();
            let to = (0..self.pool.len())
                .filter(|&d| d != from)
                .min_by(|&a, &b| {
                    loads[a]
                        .partial_cmp(&loads[b])
                        .unwrap_or(std::cmp::Ordering::Equal)
                })
                .expect("a multi-device pool leaves at least one other device");
            let (from_id, to_id) = (self.pool.id(from), self.pool.id(to));
            self.migrate(id, to_id)?;
            RegulationAction::Migrated(Migration { tenant: id, from: from_id, to: to_id })
        } else {
            self.research_shard(from)?;
            RegulationAction::Resharded { device: self.pool.id(from) }
        };
        // Restart the acted-on tenant's burn history: the new plan gets a
        // clean slate, so one sustained burn triggers one action.
        if let Some(t) = self.meta[slot].target {
            let tier = self.meta[slot].slo.tier;
            self.slo_monitor.track(key, tier, t)?;
        }
        Ok(Some(action))
    }

    // ---- elastic pool operations ----

    /// Scale-out: join a new device to the pool and re-shard onto it.
    ///
    /// The device gets a fresh stable [`DeviceId`] (monotonic, never
    /// reused even after a later [`GacerEngine::remove_device`]) and its
    /// own [`Platform`] cost model — joining a T4 to an A100 pool is
    /// first-class, not a special case. The whole set is then re-planned
    /// ([`GacerEngine::replan`]) so placement can exploit the new
    /// capacity; pair with [`GacerEngine::redeploy_cluster`] to fence the
    /// expanded plan onto a running cluster (the joined device's server
    /// starts on apply, and the routing table swap is epoch-fenced so no
    /// in-flight request is lost).
    pub fn add_device(&mut self, platform: Platform) -> DeviceId {
        let id = self.pool.add(platform);
        self.replan();
        id
    }

    /// Scale-in: drain every tenant off device `id`, then retire it from
    /// the pool.
    ///
    /// The drain is planned **before any mutation**: each resident is
    /// assigned to the capacity-feasible survivor with the most free HBM
    /// (deterministic greedy, largest-remaining-headroom first). If some
    /// resident fits on no survivor — or `id` is the last device — the
    /// call fails with [`Error::DrainImpossible`] and the engine is left
    /// exactly as it was. On success each destination shard is
    /// incrementally re-searched (seeded, budget-bounded, like
    /// [`GacerEngine::migrate`]) and the executed [`Migration`]s are
    /// returned with stable [`DeviceId`]s; pair with
    /// [`GacerEngine::redeploy_cluster`] to retire the device's server
    /// and fence the shrunk routing table onto a running cluster.
    ///
    /// Dense indices of later devices shift down by one; [`DeviceId`]s
    /// of the survivors do not change — address devices by id across
    /// scale events.
    pub fn remove_device(&mut self, id: DeviceId) -> Result<Vec<Migration>> {
        let d = self.pool.index_of(id).ok_or_else(|| {
            Error::InvalidConfig(format!(
                "cannot remove {id}: no such device in pool {}",
                self.pool.label()
            ))
        })?;
        if self.pool.len() == 1 {
            return Err(Error::DrainImpossible(format!(
                "{id} is the last device in the pool; nowhere to drain its tenants"
            )));
        }
        // Plan the whole drain first: destination = feasible survivor
        // with the most remaining free HBM, accounting for the tenants
        // already re-homed ahead of this one. Any infeasibility aborts
        // before the engine mutates.
        let residents: Vec<usize> = self.sharded.placement.tenants_on(d).to_vec();
        let usage = self.sharded.placement.hbm_usage(&self.set);
        let mut free: Vec<f64> = (0..self.pool.len())
            .map(|s| self.pool.platform(s).hbm_bytes() - usage[s])
            .collect();
        let mut planned: Vec<(usize, usize)> = Vec::with_capacity(residents.len());
        for &slot in &residents {
            let footprint = self.set.hbm_footprint(slot, None);
            let dest = (0..self.pool.len())
                .filter(|&s| s != d && free[s] >= footprint)
                .max_by(|&a, &b| {
                    free[a].partial_cmp(&free[b]).unwrap_or(std::cmp::Ordering::Equal)
                });
            let Some(dest) = dest else {
                let best = (0..self.pool.len())
                    .filter(|&s| s != d)
                    .map(|s| free[s])
                    .fold(f64::NEG_INFINITY, f64::max);
                let tenant = self.meta[slot].id;
                return Err(Error::DrainImpossible(format!(
                    "draining {id} ({}): tenant {tenant} needs {:.2} GB HBM but the \
                     roomiest survivor has only {:.2} GB free; pool left unchanged",
                    self.pool.platform(d).name,
                    footprint / 1e9,
                    best / 1e9,
                )));
            };
            free[dest] -= footprint;
            planned.push((slot, dest));
        }
        // Execute: empty the retiring shard (reverse local order keeps
        // the remaining local indices stable), re-home each resident at
        // the position its global slot sorts to, then compact the device
        // axis everywhere it is mirrored.
        for local in (0..residents.len()).rev() {
            self.sharded.shards[d].remove_tenant(local);
        }
        let mut migrations = Vec::with_capacity(planned.len());
        for &(slot, dest) in &planned {
            self.sharded.placement.move_slot(slot, dest);
            let dest_local = self
                .sharded
                .placement
                .tenants_on(dest)
                .iter()
                .position(|&s| s == slot)
                .expect("slot was just placed on the destination");
            let level = self.sharded.shards[dest].pointers.pointers_per_tenant();
            let dfg_len = self.set.tenants[slot].len();
            self.sharded.shards[dest].insert_tenant(dest_local, dfg_len, level);
            let tenant = self.meta[slot].id;
            // The tenant's server-side counter restarts on its new
            // device — same baseline reset as `migrate`.
            self.served_window.forget(tenant.0);
            migrations.push(Migration { tenant, from: id, to: self.pool.id(dest) });
        }
        self.cooldowns.retain(|c| c.from != id);
        self.pool.remove(d);
        let _ = self.sharded.placement.remove_device(d);
        self.sharded.shards.remove(d);
        self.reports.remove(d);
        self.search_states.remove(d);
        // Seeded re-search of every destination shard, addressed at its
        // post-compaction dense index.
        let mut dests: Vec<usize> = planned
            .iter()
            .map(|&(_, dest)| if dest > d { dest - 1 } else { dest })
            .collect();
        dests.sort_unstable();
        dests.dedup();
        for &dest in &dests {
            self.research_shard(dest)?;
        }
        if dests.is_empty() {
            self.rebuild_merged();
            self.last_searched_device = None;
            self.last_searched_devices = Vec::new();
        } else {
            self.last_searched_devices = dests;
        }
        Ok(migrations)
    }
}

/// The action [`GacerEngine::maybe_regulate`] executed in response to
/// sustained error-budget burn (or plain load drift).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RegulationAction {
    /// A tenant moved between devices (sustained burn on a shared
    /// device, or a load-drift proposal from the fallback
    /// [`GacerEngine::maybe_migrate`] path).
    Migrated(Migration),
    /// The burning tenant's shard was incrementally re-searched in place
    /// (it was alone on its device, or the engine is single-device).
    Resharded {
        /// The re-searched device (stable id, not a dense index).
        device: DeviceId,
    },
}

/// How many evicted serving identities the engine remembers for
/// evict→readmit served-counter seeding (oldest entries are dropped).
const EVICTED_SERVING_MEMORY: usize = 64;

/// Max consecutive batches per scheduling round for a single-segment
/// tenant; tenants with finer temporal granularity get proportionally
/// smaller quanta (more pointers → yield the issue queue sooner).
const BASE_ISSUE_QUANTUM: usize = 4;

/// Compile a deployment plan into the live server configuration — the
/// plan→server lowering at the heart of the engine:
///
/// * **chunking → [`TenantSpec::chunk`]**: the modal micro-batch piece
///   size of the tenant's searched `list_B`s, clamped to the largest
///   compiled batch variant that does not exceed it (the real path can
///   only execute batches that were AOT-compiled);
/// * **pointer matrix → issue order**: tenants with finer temporal
///   granularity (shorter mean segments) issue first — they are the ones
///   the search decided must synchronize most often;
/// * **pointer matrix → issue quanta**: per-round batch caps shrink as a
///   tenant's segment count grows (segment boundaries realized as issue-
///   queue yields);
/// * **SLO contracts → [`ServerConfig::slo`]**: per-tenant
///   [`SloPolicy`]s reach the scheduler (tier-major issue order,
///   deadline shedding, queue caps) — but only when at least one tenant
///   carries a non-default policy, so an SLO-free deployment lowers to
///   the exact pre-SLO configuration (hot-swap diffs stay clean).
pub fn lower_plan(
    plan: &DeploymentPlan,
    tenants: &[Dfg],
    specs: &[(String, String, BatchPolicy, SloPolicy)],
    variants: &[Vec<usize>],
    tick: Duration,
) -> Result<Deployment> {
    plan.validate(tenants)?;
    let n = tenants.len();
    if specs.len() != n || variants.len() != n {
        return Err(Error::InvalidConfig(format!(
            "lowering arity mismatch: {n} tenants, {} specs, {} variant sets",
            specs.len(),
            variants.len()
        )));
    }

    let mut tenant_specs = Vec::with_capacity(n);
    for (i, (name, family, policy, _slo)) in specs.iter().enumerate() {
        let chunk = modal_chunk(&plan.chunking[i]).and_then(|m| {
            let mut avail = variants[i].clone();
            avail.sort_unstable();
            avail.into_iter().rev().find(|&v| v <= m)
        });
        tenant_specs.push(TenantSpec {
            name: name.clone(),
            family: family.clone(),
            policy: policy.clone(),
            chunk,
        });
    }

    let mean_segment =
        |i: usize| tenants[i].len() as f64 / plan.pointers.segments(i) as f64;
    let mut issue_order: Vec<usize> = (0..n).collect();
    issue_order.sort_by(|&a, &b| {
        mean_segment(a)
            .partial_cmp(&mean_segment(b))
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });

    let issue_quanta: Vec<usize> = (0..n)
        .map(|i| (BASE_ISSUE_QUANTUM / plan.pointers.segments(i)).max(1))
        .collect();

    let slo: Vec<SloPolicy> = if specs.iter().any(|s| s.3 != SloPolicy::default()) {
        specs.iter().map(|s| s.3.clone()).collect()
    } else {
        Vec::new()
    };

    let config =
        ServerConfig { tick, issue_order, issue_quanta, slo, ..ServerConfig::default() };
    config.validate(n)?;
    Ok(Deployment { tenants: tenant_specs, config })
}

/// Most frequent micro-batch piece size across a tenant's searched
/// decompositions (ties break toward the coarser piece — less chunk/concat
/// overhead). `None` when the plan decomposes nothing for this tenant.
fn modal_chunk(map: &ChunkMap) -> Option<usize> {
    let mut counts: BTreeMap<usize, usize> = BTreeMap::new();
    for list in map.values().filter(|l| l.len() > 1) {
        for &b in *list {
            *counts.entry(b).or_default() += 1;
        }
    }
    counts.into_iter().max_by_key(|&(size, n)| (n, size)).map(|(size, _)| size)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg() -> SearchConfig {
        SearchConfig {
            max_pointers: 2,
            rounds_per_level: 1,
            positions_per_coordinate: 5,
            spatial_steps_per_level: 2,
            ..Default::default()
        }
    }

    fn demo_engine(names: &[&str]) -> GacerEngine {
        let mut b = GacerEngine::builder().search(quick_cfg());
        for n in names {
            b = b.tenant(zoo::build_default(n).unwrap());
        }
        b.build().unwrap()
    }

    #[test]
    fn build_runs_the_search_and_plan_validates() {
        let engine = demo_engine(&["Alex", "V16", "R18"]);
        assert_eq!(engine.len(), 3);
        engine.plan().validate(engine.tenants()).unwrap();
        assert!(engine.last_report().is_some());
        let r = engine.last_report().unwrap();
        assert!(r.outcome.objective() <= r.initial.objective() + 1e-6);
    }

    #[test]
    fn admit_replans_and_extends_the_plan() {
        let mut engine = demo_engine(&["Alex", "R18"]);
        let before = engine.tenant_ids();
        let id = engine.admit(zoo::build_default("M3").unwrap()).unwrap();
        assert!(!before.contains(&id));
        assert_eq!(engine.len(), 3);
        engine.plan().validate(engine.tenants()).unwrap();
        // The re-planned deployment can never be worse than unregulated.
        let r = engine.last_report().unwrap();
        assert!(r.outcome.objective() <= r.initial.objective() + 1e-6);
    }

    #[test]
    fn evict_shrinks_the_plan_and_keeps_ids_stable() {
        let mut engine = demo_engine(&["Alex", "V16", "R18"]);
        let ids = engine.tenant_ids();
        let evicted = engine.evict(ids[1]).unwrap();
        assert_eq!(evicted.name, "V16");
        assert_eq!(engine.len(), 2);
        assert_eq!(engine.tenant_ids(), vec![ids[0], ids[2]]);
        engine.plan().validate(engine.tenants()).unwrap();
        assert!(engine.evict(ids[1]).is_err(), "double-evict must fail");
    }

    #[test]
    fn evict_to_empty_then_admit_again() {
        let mut engine = demo_engine(&["Alex"]);
        let ids = engine.tenant_ids();
        engine.evict(ids[0]).unwrap();
        assert!(engine.is_empty());
        engine.admit(zoo::build_default("R18").unwrap()).unwrap();
        assert_eq!(engine.len(), 1);
        engine.plan().validate(engine.tenants()).unwrap();
    }

    fn demo_sharded(names: &[&str], devices: usize) -> GacerEngine {
        let mut b = GacerEngine::builder().devices(devices).search(quick_cfg());
        for n in names {
            b = b.tenant(zoo::build_default(n).unwrap());
        }
        b.build().unwrap()
    }

    #[test]
    fn sharded_build_validates_and_merges() {
        let engine = demo_sharded(&["Alex", "V16", "R18"], 2);
        assert_eq!(engine.n_devices(), 2);
        engine.sharded_plan().validate(engine.tenants()).unwrap();
        engine.plan().validate(engine.tenants()).unwrap();
        // Every occupied device carries a search report.
        for d in 0..2 {
            let occupied = !engine.placement().tenants_on(d).is_empty();
            assert_eq!(engine.device_reports()[d].is_some(), occupied);
        }
        assert_eq!(engine.simulate_devices().len(), 2);
    }

    #[test]
    fn one_device_engine_behaves_classically() {
        let engine = demo_sharded(&["Alex", "R18"], 1);
        assert_eq!(engine.n_devices(), 1);
        assert_eq!(engine.placement().tenants_on(0), &[0, 1]);
        // The merged plan IS the single shard.
        assert_eq!(engine.plan(), &engine.sharded_plan().shards[0]);
        // simulate() equals the classic whole-set simulation.
        let classic = engine.simulate();
        assert_eq!(engine.simulate_devices()[0], classic);
    }

    #[test]
    fn admit_researches_only_the_affected_shard() {
        let mut engine = demo_sharded(&["Alex", "V16", "R18"], 2);
        let before = engine.sharded_plan().clone();
        let id = engine.admit(zoo::build_default("M3").unwrap()).unwrap();
        let device = engine.device_of(id).unwrap();
        assert_eq!(engine.last_searched_device(), Some(device));
        // The other device's shard plan is bit-identical: it was not
        // re-searched.
        let other = 1 - device;
        assert_eq!(
            engine.sharded_plan().shards[other], before.shards[other],
            "untouched shard must not change on admit"
        );
        engine.sharded_plan().validate(engine.tenants()).unwrap();
    }

    #[test]
    fn evict_last_tenant_on_a_device_leaves_it_empty() {
        // Two tenants on two devices: each is alone on its device.
        let mut engine = demo_sharded(&["Alex", "R18"], 2);
        let ids = engine.tenant_ids();
        let d0 = engine.device_of(ids[0]).unwrap();
        let d1 = engine.device_of(ids[1]).unwrap();
        assert_ne!(d0, d1, "balanced placement spreads 2 tenants over 2 devices");

        let before = engine.sharded_plan().clone();
        engine.evict(ids[0]).unwrap();
        assert_eq!(engine.len(), 1);
        assert_eq!(engine.last_searched_device(), Some(d0));
        assert!(engine.placement().tenants_on(d0).is_empty());
        assert!(engine.device_reports()[d0].is_none());
        // The surviving device was not re-searched.
        assert_eq!(engine.sharded_plan().shards[d1], before.shards[d1]);
        engine.sharded_plan().validate(engine.tenants()).unwrap();

        // Admission control refills the now-empty device.
        let id = engine.admit(zoo::build_default("M3").unwrap()).unwrap();
        assert_eq!(engine.device_of(id).unwrap(), d0);
        engine.sharded_plan().validate(engine.tenants()).unwrap();
    }

    #[test]
    fn more_devices_than_tenants_is_fine() {
        let engine = demo_sharded(&["Alex"], 4);
        engine.sharded_plan().validate(engine.tenants()).unwrap();
        assert_eq!(engine.n_devices(), 4);
        let occupied: Vec<usize> = (0..4)
            .filter(|&d| !engine.placement().tenants_on(d).is_empty())
            .collect();
        assert_eq!(occupied.len(), 1);
        assert_eq!(engine.device_reports().iter().flatten().count(), 1);
        // Empty devices simulate to a zero makespan; the bottleneck is
        // the occupied one.
        let sims = engine.simulate_devices();
        assert!(sims[occupied[0]].makespan_us > 0.0);
        assert_eq!(engine.simulate().makespan_us, sims[occupied[0]].makespan_us);
    }

    #[test]
    fn migrate_moves_one_tenant_and_researches_both_shards() {
        let mut engine = demo_sharded(&["Alex", "V16", "R18"], 2);
        let ids = engine.tenant_ids();
        let from = engine.device_of(ids[0]).unwrap();
        let to = 1 - from;
        let (from_id, to_id) =
            (engine.device_pool().id(from), engine.device_pool().id(to));
        assert_eq!(engine.migrate(ids[0], to_id).unwrap(), from_id);
        // Same id, same global slot, new device.
        assert_eq!(engine.device_of(ids[0]).unwrap(), to);
        assert_eq!(engine.tenant_ids(), ids, "migration never compacts slots");
        assert_eq!(engine.last_searched_devices(), &[from, to]);
        engine.sharded_plan().validate(engine.tenants()).unwrap();
        engine.plan().validate(engine.tenants()).unwrap();
        // Migrating to the same device or to an unknown id is rejected.
        assert!(engine.migrate(ids[0], to_id).is_err());
        assert!(engine.migrate(ids[0], DeviceId(7)).is_err());
    }

    #[test]
    fn demand_skew_drives_maybe_migrate() {
        let mut engine = demo_sharded(&["Alex", "V16", "R18", "M3"], 2);
        // Balanced placement + no observed traffic: no migration.
        assert!(engine
            .maybe_migrate(&MigrationPolicy::default())
            .unwrap()
            .is_none());
        // Drive all observed load onto one device until the policy acts:
        // pick a device sharing >= 2 tenants (4 tenants on 2 devices
        // guarantees one exists) so a move can actually help.
        let ids = engine.tenant_ids();
        let hot_device = (0..2)
            .find(|&d| engine.placement().tenants_on(d).len() >= 2)
            .unwrap();
        let hot: Vec<TenantId> = ids
            .iter()
            .enumerate()
            .filter(|&(slot, _)| {
                engine.placement().tenants_on(hot_device).contains(&slot)
            })
            .map(|(_, &id)| id)
            .collect();
        assert!(hot.len() >= 2);
        for &id in &hot {
            engine.record_requests(id, 1_000).unwrap();
        }
        let m = engine
            .maybe_migrate(&MigrationPolicy::default())
            .unwrap()
            .expect("fully skewed load must trigger a migration");
        assert_eq!(m.from, engine.device_pool().id(hot_device));
        assert!(hot.contains(&m.tenant));
        assert_eq!(engine.device_id_of(m.tenant).unwrap(), m.to);
        engine.sharded_plan().validate(engine.tenants()).unwrap();
        // A fresh window forgets the skew.
        engine.reset_demand();
        assert!(engine.observed_tenant_weights().iter().all(|&w| w > 0.0));
    }

    /// Drive one A→B→A oscillation attempt: skew one device pair hot so a
    /// tenant migrates, then invert the skew so the policy's best move is
    /// that same tenant straight back. Returns the engine mid-oscillation
    /// (after the first migration and the inverted skew are in place)
    /// plus the first migration.
    fn oscillating_engine(policy: &MigrationPolicy) -> (GacerEngine, Migration) {
        // Four identical tenants: per-request latencies are equal, so
        // observed weights are exactly proportional to recorded demand.
        let mut engine = demo_sharded(&["R18", "R18", "R18", "R18"], 2);
        let ids = engine.tenant_ids();
        let hot: Vec<usize> = engine.placement().tenants_on(0).to_vec();
        let cold: Vec<usize> = engine.placement().tenants_on(1).to_vec();
        assert_eq!((hot.len(), cold.len()), (2, 2), "2/2 split of equals");

        // Window 0: device 0 runs hot; the lighter co-tenant (hot[1])
        // yields the smaller post-move bottleneck and migrates to 1.
        engine.record_requests(ids[hot[0]], 6_000).unwrap();
        engine.record_requests(ids[hot[1]], 4_000).unwrap();
        for &c in &cold {
            engine.record_requests(ids[c], 1_000).unwrap();
        }
        let m1 = engine.maybe_migrate(policy).unwrap().expect("skew migrates");
        assert_eq!((m1.from, m1.to), (DeviceId(0), DeviceId(1)));
        assert_eq!(m1.tenant, ids[hot[1]]);

        // Invert the skew so moving m1.tenant back to device 0 is the
        // policy's best single move (its weight sits between halving the
        // new bottleneck and overloading the old one).
        engine.reset_demand();
        engine.record_requests(m1.tenant, 6_000).unwrap();
        for &c in &cold {
            engine.record_requests(ids[c], 4_000).unwrap();
        }
        engine.record_requests(ids[hot[0]], 1_000).unwrap();
        (engine, m1)
    }

    #[test]
    fn migration_cooldown_damps_oscillation() {
        let policy = MigrationPolicy {
            max_imbalance: 2.0,
            cooldown_windows: 1,
            ..Default::default()
        };
        let (mut engine, m1) = oscillating_engine(&policy);
        // Window 1: the reverse move is proposed but suppressed by the
        // cooldown — the tenant stays put for this window.
        assert!(engine.maybe_migrate(&policy).unwrap().is_none());
        assert_eq!(engine.device_id_of(m1.tenant).unwrap(), m1.to);
        // Window 2: the skew persisted past the cooldown — now the move
        // is real load drift, not thrash, and it executes.
        let m2 = engine.maybe_migrate(&policy).unwrap().expect("cooldown expired");
        assert_eq!(m2.tenant, m1.tenant);
        assert_eq!((m2.from, m2.to), (m1.to, m1.from));
        engine.sharded_plan().validate(engine.tenants()).unwrap();
    }

    #[test]
    fn zero_cooldown_reproduces_the_thrash() {
        // The contrast case: without a cooldown the same alternating skew
        // ping-pongs the tenant straight back in the very next window.
        let policy = MigrationPolicy {
            max_imbalance: 2.0,
            cooldown_windows: 0,
            ..Default::default()
        };
        let (mut engine, m1) = oscillating_engine(&policy);
        let back = engine.maybe_migrate(&policy).unwrap().expect("thrash");
        assert_eq!(back.tenant, m1.tenant);
        assert_eq!((back.from, back.to), (m1.to, m1.from));
    }

    #[test]
    fn replan_budget_bounds_incremental_research() {
        let mut engine = GacerEngine::builder()
            .search(quick_cfg())
            .replan_budget(SearchBudget::evaluations(4))
            .tenant(zoo::build_default("R50").unwrap())
            .tenant(zoo::build_default("V16").unwrap())
            .build()
            .unwrap();
        // The cold build is unbudgeted: never truncated.
        assert!(!engine.last_report().unwrap().truncated);
        assert_eq!(engine.replan_budget(), SearchBudget::evaluations(4));
        engine.admit(zoo::build_default("M3").unwrap()).unwrap();
        let r = engine.last_report().unwrap();
        assert!(r.truncated, "4-eval budget must truncate the admit re-search");
        // Anytime guarantee survives truncation.
        assert!(r.outcome.objective() <= r.initial.objective() + 1e-6);
        engine.plan().validate(engine.tenants()).unwrap();
    }

    #[test]
    fn admit_reuses_warm_search_state() {
        // Spatial off keeps chunking empty, so the incumbents' stream
        // fingerprints survive the admit and hit the warm cache.
        let cfg = SearchConfig { enable_spatial: false, ..quick_cfg() };
        let mut engine = GacerEngine::builder()
            .search(cfg)
            .tenant(zoo::build_default("Alex").unwrap())
            .tenant(zoo::build_default("R18").unwrap())
            .build()
            .unwrap();
        engine.admit(zoo::build_default("M3").unwrap()).unwrap();
        let r = engine.last_report().unwrap();
        assert!(r.warm_hits >= 2, "incumbent streams reused, got {}", r.warm_hits);
    }

    #[test]
    fn replan_cost_telemetry_feeds_the_cost_model() {
        let mut engine = demo_sharded(&["Alex", "V16", "R18"], 2);
        assert!(engine.observed_replan_cost_us().is_none(), "no event yet");
        // Without incremental telemetry the bill falls back to the cold
        // per-device search cost — never pricing a re-plan as free.
        assert!(engine.migration_cost(1.0).replan_us > 0.0);
        engine.admit(zoo::build_default("M3").unwrap()).unwrap();
        let per_shard = engine.observed_replan_cost_us().unwrap();
        assert!(per_shard > 0.0);
        let cost = engine.migration_cost(1.0);
        assert_eq!(cost.replan_us, 2.0 * per_shard, "two shards re-search");
        assert!(cost.swap_pause_us > 0.0, "one tick per fenced device");
        assert!(MigrationPolicy::cost_aware(cost).cost.is_some());
    }

    #[test]
    fn cost_aware_policy_gates_engine_migration() {
        let mut engine = demo_sharded(&["R18", "R18", "R18", "R18"], 2);
        let ids = engine.tenant_ids();
        let hot: Vec<usize> = engine.placement().tenants_on(0).to_vec();
        assert_eq!(hot.len(), 2, "2/2 split of identical tenants");
        for (slot, id) in ids.iter().enumerate() {
            let n = if hot.contains(&slot) { 5_000 } else { 1_000 };
            engine.record_requests(*id, n).unwrap();
        }
        // An exorbitant predicted cost vetoes the triggered move...
        let pricey = MigrationPolicy::cost_aware(MigrationCost {
            replan_us: f64::MAX / 8.0,
            swap_pause_us: 0.0,
            payback_windows: 1.0,
        });
        assert!(engine.maybe_migrate(&pricey).unwrap().is_none());
        // ...while a free cost model lets the same skew migrate.
        let free = MigrationPolicy::cost_aware(MigrationCost {
            replan_us: 0.0,
            swap_pause_us: 0.0,
            payback_windows: 1.0,
        });
        let m = engine.maybe_migrate(&free).unwrap().expect("skew migrates");
        assert_eq!(m.from, DeviceId(0));
        engine.sharded_plan().validate(engine.tenants()).unwrap();
    }

    #[test]
    fn record_served_diffs_cumulative_counters_by_id() {
        let mut engine = demo_sharded(&["Alex", "R18", "M3"], 2);
        let ids = engine.tenant_ids();
        engine.record_served(&[5, 3, 0]).unwrap();
        engine.record_served(&[9, 3, 2]).unwrap();
        assert_eq!(
            engine.meta.iter().map(|m| m.demand).collect::<Vec<_>>(),
            vec![9.0, 3.0, 2.0],
            "cumulative counts diff to their totals"
        );
        // Evict the first tenant: later counters keep their identity even
        // though slots compact.
        engine.evict(ids[0]).unwrap();
        engine.record_served(&[4, 2]).unwrap();
        assert_eq!(
            engine.meta.iter().map(|m| m.demand).collect::<Vec<_>>(),
            vec![3.0 + 1.0, 2.0],
            "no misattribution across the slot shift"
        );
        // Arity must match the deployment.
        assert!(engine.record_served(&[1]).is_err());
    }

    #[test]
    fn observed_loads_fall_back_to_cost_model() {
        let mut engine = demo_sharded(&["Alex", "R18"], 2);
        let static_loads = engine.placement().loads(&engine.set);
        assert_eq!(engine.observed_device_loads(), static_loads);
        // One observation switches to demand weighting.
        let ids = engine.tenant_ids();
        engine.record_requests(ids[0], 5).unwrap();
        let loads = engine.observed_device_loads();
        let idle = engine.device_of(ids[1]).unwrap();
        assert_eq!(loads[idle], 0.0, "unobserved tenant carries no load");
        assert!(engine.record_requests(TenantId(999), 1).is_err());
    }

    #[test]
    fn multi_device_deployment_requires_sharded_api() {
        let engine = demo_sharded(&["Alex", "R18"], 2);
        match engine.deployment() {
            Err(Error::InvalidConfig(_)) => {}
            other => panic!("expected InvalidConfig, got {other:?}"),
        }
    }

    #[test]
    fn duplicate_serving_names_rejected_sim_names_free() {
        // A serving name is a live identity (hot swaps match queues by
        // it): deploying it twice is rejected at admission.
        let b = GacerEngine::builder()
            .search(quick_cfg())
            .serving_tenant("t0", "tiny_cnn", default_policy())
            .unwrap()
            .serving_tenant("t0", "tiny_cnn", default_policy())
            .unwrap();
        assert!(matches!(b.build(), Err(Error::InvalidConfig(_))));
        // Simulation-only tenants never reach a server and may share
        // names freely.
        let mut engine = demo_engine(&["Alex"]);
        engine.admit(zoo::build_default("Alex").unwrap()).unwrap();
        assert_eq!(engine.len(), 2);
    }

    #[test]
    fn unknown_serving_family_rejected() {
        let b = GacerEngine::builder();
        assert!(b.serving_tenant("x", "no_such_family", default_policy()).is_err());
    }

    #[test]
    fn serve_without_artifacts_is_typed_error() {
        let engine = demo_engine(&["Alex"]);
        match engine.serve() {
            Err(Error::InvalidConfig(_)) => {}
            Err(other) => panic!("expected InvalidConfig, got {other:?}"),
            Ok(_) => panic!("expected InvalidConfig, got a running server"),
        }
    }

    // ---- lowering ----

    fn lower_fixture(
        plan: &DeploymentPlan,
        tenants: &[Dfg],
        variants: Vec<Vec<usize>>,
    ) -> Deployment {
        let specs: Vec<(String, String, BatchPolicy, SloPolicy)> = tenants
            .iter()
            .map(|d| {
                (
                    d.name.clone(),
                    "tiny_cnn".to_string(),
                    default_policy(),
                    SloPolicy::default(),
                )
            })
            .collect();
        lower_plan(plan, tenants, &specs, &variants, Duration::from_micros(200))
            .unwrap()
    }

    #[test]
    fn lowering_maps_searched_chunks_to_compiled_variants() {
        let tenants = zoo::build_combo(&["Alex", "V16"]);
        let mut plan = DeploymentPlan::unregulated(2);
        // The search split two of V16's convs into micro-batches of 4.
        plan.chunking[1].insert(0, vec![4, 4]);
        plan.chunking[1].insert(2, vec![4, 4]);
        let d = lower_fixture(&plan, &tenants, vec![vec![1, 2, 4, 8], vec![1, 2, 4, 8]]);
        assert_eq!(d.tenants[0].chunk, None, "undecomposed tenant stays whole");
        assert_eq!(d.tenants[1].chunk, Some(4), "searched piece size reaches the spec");
    }

    #[test]
    fn lowering_clamps_chunk_to_available_variants() {
        let tenants = zoo::build_combo(&["Alex"]);
        let mut plan = DeploymentPlan::unregulated(1);
        plan.chunking[0].insert(0, vec![3, 5]);
        // Modal piece ties 3 vs 5 -> 5 (coarser); only variants 1/2/4 exist
        // -> clamped down to 4.
        let d = lower_fixture(&plan, &tenants, vec![vec![1, 2, 4]]);
        assert_eq!(d.tenants[0].chunk, Some(4));
    }

    #[test]
    fn lowering_orders_fine_grained_tenants_first() {
        let tenants = zoo::build_combo(&["Alex", "V16", "R18"]);
        let mut plan = DeploymentPlan::unregulated(3);
        // V16 gets 3 pointers (4 segments): finest granularity -> first.
        plan.pointers.set_list(1, vec![8, 16, 24]);
        let d =
            lower_fixture(&plan, &tenants, vec![vec![8], vec![8], vec![8]]);
        assert_eq!(d.config.issue_order[0], 1);
        let mut sorted = d.config.issue_order.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2], "issue order is a permutation");
        // Segment-derived quanta: 4 segments -> 1, 1 segment -> 4.
        assert_eq!(d.config.issue_quanta[1], 1);
        assert_eq!(d.config.issue_quanta[0], 4);
    }

    #[test]
    fn lowering_rejects_invalid_plans() {
        let tenants = zoo::build_combo(&["Alex"]);
        let plan = DeploymentPlan::unregulated(2); // tenant-count mismatch
        let specs = vec![(
            "a".to_string(),
            "tiny_cnn".to_string(),
            default_policy(),
            SloPolicy::default(),
        )];
        let err = lower_plan(
            &plan,
            &tenants,
            &specs,
            &[vec![8]],
            Duration::from_micros(200),
        );
        assert!(matches!(err, Err(Error::InvalidPlan(_))));
    }

    #[test]
    fn lowering_emits_slo_policies_only_when_regulating() {
        let tenants = zoo::build_combo(&["Alex", "V16"]);
        let plan = DeploymentPlan::unregulated(2);
        let mut specs: Vec<(String, String, BatchPolicy, SloPolicy)> = tenants
            .iter()
            .map(|d| {
                (
                    d.name.clone(),
                    "tiny_cnn".to_string(),
                    default_policy(),
                    SloPolicy::default(),
                )
            })
            .collect();
        let variants = vec![vec![8], vec![8]];
        let d =
            lower_plan(&plan, &tenants, &specs, &variants, Duration::from_micros(200))
                .unwrap();
        assert!(
            d.config.slo.is_empty(),
            "all-default policies lower to regulation off (pre-SLO config)"
        );
        specs[0].3 = SloPolicy::new(crate::slo::Tier::Interactive);
        let d =
            lower_plan(&plan, &tenants, &specs, &variants, Duration::from_micros(200))
                .unwrap();
        assert_eq!(d.config.slo.len(), 2, "one non-default policy lowers all");
        assert_eq!(d.config.slo[0].tier, crate::slo::Tier::Interactive);
    }

    // ---- SLO regulation ----

    #[test]
    fn admission_gate_rejects_lower_tiers_while_higher_burns() {
        use crate::slo::{SloHealth, Tier};
        let mut engine = GacerEngine::builder()
            .search(quick_cfg())
            .serving_tenant_with_slo(
                "hi",
                "tiny_cnn",
                default_policy(),
                SloPolicy::new(Tier::Interactive),
                Some(SloTarget::p99_ms(1.0)),
            )
            .unwrap()
            .build()
            .unwrap();
        let id = engine.tenant_ids()[0];
        // Healthy monitor: admission at any tier is open.
        engine
            .admit_serving_with_slo(
                "lo",
                "tiny_cnn",
                default_policy(),
                SloPolicy::new(Tier::Batch),
                None,
            )
            .unwrap();
        // Every request in the window blows the 1ms target: instant Page.
        let hot = vec![5_000.0; 100];
        engine.record_latencies(&[hot, Vec::new()]).unwrap();
        assert_eq!(engine.slo_pressure(id).unwrap().health, SloHealth::Page);
        // A lower tier is refused while Interactive burns...
        let err = engine.admit_serving_with_slo(
            "lo2",
            "tiny_cnn",
            default_policy(),
            SloPolicy::new(Tier::Batch),
            None,
        );
        assert!(matches!(err, Err(Error::Overloaded(_))));
        // ...but a peer tier is not (Interactive does not outrank itself).
        engine
            .admit_serving_with_slo(
                "hi2",
                "tiny_cnn",
                default_policy(),
                SloPolicy::new(Tier::Interactive),
                None,
            )
            .unwrap();
        assert_eq!(engine.len(), 3);
    }

    #[test]
    fn sustained_burn_triggers_regulation_once() {
        use crate::slo::Tier;
        let mut engine = GacerEngine::builder()
            .devices(2)
            .search(quick_cfg())
            .serving_tenant_with_slo(
                "a",
                "tiny_cnn",
                default_policy(),
                SloPolicy::new(Tier::Interactive),
                Some(SloTarget::p99_ms(1.0)),
            )
            .unwrap()
            .serving_tenant("b", "tiny_cnn", default_policy())
            .unwrap()
            .serving_tenant("c", "tiny_cnn", default_policy())
            .unwrap()
            .build()
            .unwrap();
        let id = engine.tenant_ids()[0];
        let from = engine.device_id_of(id).unwrap();
        // No burn, no skew: nothing to regulate.
        let policy = MigrationPolicy::default();
        assert!(engine.maybe_regulate(&policy).unwrap().is_none());
        // Page for `sustained_page_windows` consecutive windows.
        let needed = engine.slo_monitor().config().sustained_page_windows;
        for _ in 0..needed {
            let samples =
                vec![vec![5_000.0; 100], Vec::new(), Vec::new()];
            engine.record_latencies(&samples).unwrap();
        }
        let action = engine
            .maybe_regulate(&policy)
            .unwrap()
            .expect("sustained burn must trigger an action");
        match action {
            RegulationAction::Migrated(m) => {
                assert_eq!(m.tenant, id);
                assert_eq!(m.from, from);
                assert_eq!(engine.device_id_of(id).unwrap(), m.to);
            }
            RegulationAction::Resharded { device } => assert_eq!(device, from),
        }
        engine.sharded_plan().validate(engine.tenants()).unwrap();
        // The burn history restarted with the action: the next consult
        // has no sustained page streak (and no demand skew) to act on.
        assert!(engine.maybe_regulate(&policy).unwrap().is_none());
    }

    #[test]
    fn evict_then_readmit_reseeds_served_baseline() {
        let mut engine = GacerEngine::builder()
            .search(quick_cfg())
            .serving_tenant("t0", "tiny_cnn", default_policy())
            .unwrap()
            .serving_tenant("t1", "tiny_cnn", default_policy())
            .unwrap()
            .build()
            .unwrap();
        let ids = engine.tenant_ids();
        engine.record_served(&[10, 4]).unwrap();
        engine.evict(ids[0]).unwrap();
        // Readmit the same serving identity: on the server, t0's
        // cumulative counter survived the churn (claimed by name/family
        // across the hot swaps) — the engine must not bill the new
        // tenant for the evicted tenant's history.
        let id2 = engine
            .admit_serving("t0", "tiny_cnn", default_policy())
            .unwrap();
        assert_eq!(engine.tenant_ids(), vec![ids[1], id2]);
        // First window after readmission: t1 went 4 -> 6, t0's inherited
        // counter reads 12. Seeding pins t0's baseline at 12.
        engine.record_served(&[6, 12]).unwrap();
        assert_eq!(
            engine.meta.iter().map(|m| m.demand).collect::<Vec<_>>(),
            vec![6.0, 0.0],
            "inherited history must not count as the new tenant's demand"
        );
        // From here increments attribute normally.
        engine.record_served(&[6, 15]).unwrap();
        assert_eq!(
            engine.meta.iter().map(|m| m.demand).collect::<Vec<_>>(),
            vec![6.0, 3.0]
        );
    }

    #[test]
    fn record_latencies_checks_arity() {
        let mut engine = demo_engine(&["Alex", "R18"]);
        assert!(engine.record_latencies(&[Vec::new()]).is_err());
        engine.record_latencies(&[Vec::new(), Vec::new()]).unwrap();
        // Untracked tenants never acquire pressure.
        let ids = engine.tenant_ids();
        assert!(engine.slo_pressure(ids[0]).is_none());
        assert!(engine.slo_pressures().is_empty());
    }

    #[test]
    fn modal_chunk_prefers_frequent_then_coarse() {
        let mut map = ChunkMap::new();
        map.insert(0, vec![4, 4]);
        map.insert(1, vec![4, 4]);
        map.insert(2, vec![2, 2, 2, 2]);
        // Piece counts tie (4x each) -> the coarser piece wins.
        assert_eq!(modal_chunk(&map), Some(4));
        map.insert(3, vec![2, 2, 2, 2]);
        assert_eq!(modal_chunk(&map), Some(2), "2 now strictly more frequent");
        // Singleton lists are not splits and don't vote.
        let mut whole = ChunkMap::new();
        whole.insert(0, vec![8]);
        assert_eq!(modal_chunk(&whole), None);
        assert_eq!(modal_chunk(&ChunkMap::new()), None);
    }

    #[test]
    fn device_pool_builder_sets_reference_platform_and_ids() {
        let mut b = GacerEngine::builder()
            .device_pool(vec![Platform::a100(), Platform::t4()])
            .search(quick_cfg());
        for n in ["Alex", "V16", "R18"] {
            b = b.tenant(zoo::build_default(n).unwrap());
        }
        let engine = b.build().unwrap();
        assert_eq!(engine.n_devices(), 2);
        // The reference platform is the first pool entry.
        assert_eq!(*engine.platform(), Platform::a100());
        assert_eq!(engine.device_pool().label(), "A100+T4");
        assert_eq!(engine.device_ids(), vec![DeviceId(0), DeviceId(1)]);
        engine.sharded_plan().validate(engine.tenants()).unwrap();
        let dep = engine.sharded_deployment();
        // No artifacts in unit tests: deployment lowering needs them, but
        // the placement itself must already cover every slot.
        assert!(dep.is_err() || dep.unwrap().device_ids.len() == 2);
    }

    #[test]
    fn uniform_device_pool_matches_devices_sugar() {
        let pooled = {
            let mut b = GacerEngine::builder()
                .device_pool(vec![Platform::titan_v(); 2])
                .search(quick_cfg());
            for n in ["Alex", "V16", "R18"] {
                b = b.tenant(zoo::build_default(n).unwrap());
            }
            b.build().unwrap()
        };
        let sugared = demo_sharded(&["Alex", "V16", "R18"], 2);
        assert_eq!(pooled.sharded_plan(), sugared.sharded_plan());
        assert_eq!(
            pooled.simulate().makespan_us,
            sugared.simulate().makespan_us
        );
    }

    #[test]
    fn add_device_expands_pool_and_replans() {
        let mut engine = demo_sharded(&["Alex", "V16", "R18", "M3"], 2);
        let joined = engine.add_device(Platform::t4());
        assert_eq!(joined, DeviceId(2), "ids are assigned monotonically");
        assert_eq!(engine.n_devices(), 3);
        assert_eq!(
            engine.device_ids(),
            vec![DeviceId(0), DeviceId(1), DeviceId(2)]
        );
        engine.sharded_plan().validate(engine.tenants()).unwrap();
        engine.plan().validate(engine.tenants()).unwrap();
    }

    #[test]
    fn remove_device_drains_tenants_to_survivors() {
        let mut engine = demo_sharded(&["Alex", "V16", "R18", "M3"], 3);
        let ids = engine.tenant_ids();
        let retire = DeviceId(2);
        let resident_count = engine.placement().tenants_on(2).len();
        let migrations = engine.remove_device(retire).unwrap();
        assert_eq!(migrations.len(), resident_count);
        for m in &migrations {
            assert_eq!(m.from, retire);
            assert_ne!(m.to, retire);
            // The tenant landed where the migration says it did.
            assert_eq!(engine.device_id_of(m.tenant).unwrap(), m.to);
        }
        assert_eq!(engine.n_devices(), 2);
        // Survivor ids are untouched; the retired id is gone for good.
        assert_eq!(engine.device_ids(), vec![DeviceId(0), DeviceId(1)]);
        assert_eq!(engine.tenant_ids(), ids, "drain never compacts slots");
        engine.sharded_plan().validate(engine.tenants()).unwrap();
        engine.plan().validate(engine.tenants()).unwrap();
        // Removing an unknown (already retired) id is a config error...
        assert!(matches!(
            engine.remove_device(retire),
            Err(Error::InvalidConfig(_))
        ));
        // ...and ids are never reused: the next join continues the count.
        assert_eq!(engine.add_device(Platform::titan_v()), DeviceId(3));
    }

    #[test]
    fn remove_last_device_is_drain_impossible() {
        let mut engine = demo_engine(&["Alex"]);
        let err = engine.remove_device(DeviceId(0)).unwrap_err();
        assert!(matches!(err, Error::DrainImpossible(_)));
        assert_eq!(engine.n_devices(), 1, "pool left unchanged");
        engine.plan().validate(engine.tenants()).unwrap();
    }

    // ---- online calibration ----

    fn calibrated_sharded(names: &[&str], devices: usize) -> GacerEngine {
        let mut b = GacerEngine::builder()
            .devices(devices)
            .search(quick_cfg())
            .calibration(CalibrationConfig::default());
        for n in names {
            b = b.tenant(zoo::build_default(n).unwrap());
        }
        b.build().unwrap()
    }

    #[test]
    fn uncalibrated_engine_has_no_correction_surface() {
        let engine = demo_sharded(&["Alex", "R18"], 2);
        assert!(engine.calibration().is_none());
        assert!(engine.corrections().is_empty());
        let id = engine.tenant_ids()[0];
        assert_eq!(engine.correction_of(id).unwrap(), 1.0);
    }

    #[test]
    fn zero_observation_calibration_is_bit_for_bit_analytic() {
        let analytic = demo_sharded(&["Alex", "V16", "R18"], 2);
        let mut calibrated = calibrated_sharded(&["Alex", "V16", "R18"], 2);
        // Same build, same plan, same weights — the trust ramp has not
        // even started.
        assert_eq!(calibrated.sharded_plan(), analytic.sharded_plan());
        assert_eq!(
            calibrated.observed_tenant_weights(),
            analytic.observed_tenant_weights()
        );
        // An empty-sample window advances nothing...
        let empty = vec![Vec::new(); 3];
        calibrated.record_latencies(&empty).unwrap();
        assert_eq!(calibrated.calibration().unwrap().observations(), 0);
        // ...and below min_samples every correction stays exactly 1.0,
        // so a cold replan matches the analytic engine bit-for-bit.
        calibrated.replan();
        assert_eq!(calibrated.sharded_plan(), analytic.sharded_plan());
        for id in calibrated.tenant_ids() {
            assert_eq!(calibrated.correction_of(id).unwrap(), 1.0);
        }
    }

    #[test]
    fn observed_windows_ramp_trust_and_scale_the_weights() {
        let mut engine = calibrated_sharded(&["Alex", "R18"], 2);
        let ids = engine.tenant_ids();
        let analytic = engine.observed_tenant_weights();
        // Serve tenant 0 at 4x its predicted latency for enough windows
        // to pass the default trust ramp (min_samples = 3).
        let slot0 = engine.index_of(ids[0]).unwrap();
        let d0 = engine.device_of(ids[0]).unwrap();
        let predicted = engine.pool.cost(d0).predicted_colocated_latency_us(
            &engine.tenants()[slot0],
            &[],
        );
        for _ in 0..4 {
            let samples = vec![vec![4.0 * predicted; 8], Vec::new()];
            engine.record_latencies(&samples).unwrap();
        }
        let k = engine.correction_of(ids[0]).unwrap();
        assert!((k - 4.0).abs() < 1e-9, "constant 4x residual converges: {k}");
        assert_eq!(engine.correction_of(ids[1]).unwrap(), 1.0);
        let scaled = engine.observed_tenant_weights();
        assert!((scaled[0] - 4.0 * analytic[0]).abs() < 1e-6);
        assert_eq!(scaled[1], analytic[1]);
        // The introspection snapshot agrees.
        let entries = engine.corrections();
        assert_eq!(entries.len(), 1);
        assert!(entries[0].trusted);
        assert_eq!(entries[0].tenant, ids[0].0);
    }

    #[test]
    fn evict_forgets_the_residual_and_restarts_the_ramp() {
        let mut engine = calibrated_sharded(&["Alex", "R18"], 2);
        let ids = engine.tenant_ids();
        for _ in 0..4 {
            let samples = vec![vec![1_000_000.0; 4], Vec::new()];
            engine.record_latencies(&samples).unwrap();
        }
        assert!(engine.correction_of(ids[0]).unwrap() > 1.0);
        engine.evict(ids[0]).unwrap();
        assert!(
            engine.corrections().is_empty(),
            "eviction drops the tenant's residuals"
        );
        // A readmission gets a fresh id and a fresh (analytic) ramp.
        let id = engine.admit(zoo::build_default("Alex").unwrap()).unwrap();
        assert_eq!(engine.correction_of(id).unwrap(), 1.0);
    }

    #[test]
    fn fence_pause_telemetry_feeds_migration_cost() {
        let engine = demo_engine(&["Alex"]);
        // Before any redeploy is measured, the swap pause falls back to
        // one scheduler tick.
        let tick_us = engine.tick.as_secs_f64() * 1e6;
        assert!(engine.observed_fence_pause_us().is_none());
        assert_eq!(engine.migration_cost(2.0).swap_pause_us, tick_us);
        // An externally timed fence seeds the EWMA...
        engine.record_fence_pause(Duration::from_micros(400));
        assert_eq!(engine.observed_fence_pause_us(), Some(400.0));
        assert_eq!(engine.migration_cost(2.0).swap_pause_us, 400.0);
        // ...and later fences fold in 50/50.
        engine.record_fence_pause(Duration::from_micros(200));
        assert_eq!(engine.observed_fence_pause_us(), Some(300.0));
    }
}
