//! Load-drift tenant migration policy.
//!
//! A tenant's device is chosen at admission from cost-model load — but
//! traffic drifts, and a placement that was balanced under assumed
//! uniform demand can leave one GPU saturated while another idles (the
//! online workload-drift problem of the multi-tenant serving
//! literature; VELTAIR makes the same argument for adaptive scheduling
//! decisions applied to live services). [`MigrationPolicy`] is the
//! decision rule: it watches the **observed** per-device loads
//! ([`GacerEngine::observed_device_loads`]) and, when the max/min
//! device-load ratio crosses a threshold, proposes moving one tenant
//! from the hottest device to the coolest — the single move that best
//! shrinks the bottleneck. Execution is the engine's job
//! ([`GacerEngine::maybe_migrate`] → [`GacerEngine::migrate`]: two-shard
//! re-search, then a cluster hot swap).
//!
//! A migration is not free: it costs a two-shard seeded re-search plus
//! an epoch-fenced swap pause on both devices. The **cost/gain mode**
//! ([`MigrationCost`], [`MigrationPolicy::cost_aware`]) prices that from
//! observed budgeted-search telemetry
//! ([`GacerEngine::migration_cost`]) and declines a triggered move whose
//! predicted bottleneck reduction would not pay the bill back within the
//! configured number of observe windows — so marginal skew is tolerated
//! and large skew still migrates.
//!
//! [`GacerEngine::observed_device_loads`]: crate::engine::GacerEngine::observed_device_loads
//! [`GacerEngine::maybe_migrate`]: crate::engine::GacerEngine::maybe_migrate
//! [`GacerEngine::migrate`]: crate::engine::GacerEngine::migrate
//! [`GacerEngine::migration_cost`]: crate::engine::GacerEngine::migration_cost

use crate::engine::TenantId;
use crate::metrics::imbalance_ratio;
use crate::plan::{Placement, TenantSet};
use crate::profile::{roofline_slowdown, slowdown_from_phases, DeviceId};

/// Threshold rule for load-drift migration: act when the max/min
/// observed device-load ratio exceeds `max_imbalance`, and only when a
/// single tenant move strictly shrinks the bottleneck device's load.
///
/// ```
/// use gacer::engine::MigrationPolicy;
/// use gacer::plan::Placement;
///
/// let placement = Placement::from_assignments(vec![vec![0, 1], vec![2]]);
/// let policy = MigrationPolicy::default(); // max_imbalance = 2.0
///
/// // Device 0 carries 9.0 of 10.0 total load: ratio 9 > 2. The best
/// // single move is the *lighter* co-tenant (moving the 8.0 tenant
/// // would just flip the skew).
/// let p = policy.propose(&[8.0, 1.0, 1.0], &placement).unwrap();
/// assert_eq!((p.slot, p.from, p.to), (1, 0, 1));
/// assert!(p.imbalance_after < p.imbalance_before);
///
/// // Mild skew stays put.
/// assert!(policy.propose(&[1.0, 1.0, 1.5], &placement).is_none());
///
/// // A hot *singleton* tenant has no useful move: migrating it only
/// // relocates the bottleneck.
/// let lone = Placement::from_assignments(vec![vec![0], vec![1]]);
/// assert!(policy.propose(&[9.0, 1.0], &lone).is_none());
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct MigrationPolicy {
    /// Trigger threshold on the max/min device-load ratio
    /// ([`crate::metrics::imbalance_ratio`]); must be > 1. Idle devices
    /// are excluded from the ratio (a freshly scaled-out or drained
    /// device beside balanced load does not trigger), but once the
    /// *loaded* devices are skewed past the threshold an idle device is
    /// still the preferred destination.
    pub max_imbalance: f64,
    /// Hysteresis against migration thrash: after an executed migration,
    /// proposals that would move the same tenant straight back onto the
    /// device it left are suppressed for this many observe windows (one
    /// window = one [`GacerEngine::maybe_migrate`] consultation). Under
    /// alternating skew this damps the A→B→A ping-pong: the reverse move
    /// only executes once the skew outlives the cooldown. `0` disables
    /// the cooldown.
    ///
    /// [`GacerEngine::maybe_migrate`]: crate::engine::GacerEngine::maybe_migrate
    pub cooldown_windows: usize,
    /// `None` (the default): the classic ratio-threshold rule — every
    /// triggered, bottleneck-shrinking move is proposed. `Some(cost)`:
    /// **cost/gain mode** — the move must additionally pay for itself:
    /// its predicted per-window gain (the bottleneck load/score
    /// reduction) times [`MigrationCost::payback_windows`] must reach
    /// [`MigrationCost::total_us`]. Feed it from observed telemetry with
    /// [`GacerEngine::migration_cost`].
    ///
    /// [`GacerEngine::migration_cost`]: crate::engine::GacerEngine::migration_cost
    pub cost: Option<MigrationCost>,
}

impl Default for MigrationPolicy {
    fn default() -> Self {
        MigrationPolicy { max_imbalance: 2.0, cooldown_windows: 1, cost: None }
    }
}

/// Predicted one-time cost of executing a migration, for
/// [`MigrationPolicy`]'s cost/gain mode. All figures are in
/// microseconds, the same unit as the observed load weights the gain is
/// measured in (demand × per-request latency per observe window).
///
/// The engine derives one from its own telemetry
/// ([`GacerEngine::migration_cost`]): `replan_us` from the EWMA of
/// recent budgeted incremental re-search wall-times (×2 — a migration
/// re-searches the source and the destination shard), `swap_pause_us`
/// from the EWMA of **observed** epoch-fence commit latencies (the
/// pause each affected device pays at `redeploy`/`redeploy_cluster`,
/// see `docs/OPERATIONS.md`), falling back to one scheduler tick until
/// any redeploy has been measured.
///
/// [`GacerEngine::migration_cost`]: crate::engine::GacerEngine::migration_cost
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MigrationCost {
    /// Predicted two-shard re-plan wall-time (µs).
    pub replan_us: f64,
    /// Predicted swap-pause disruption per affected device (µs); charged
    /// twice (source and destination both fence).
    pub swap_pause_us: f64,
    /// How many observe windows the per-window gain may take to pay the
    /// one-time cost back (≥ `total_us / gain` windows decline the
    /// move). `1.0` demands the very next window already break even;
    /// larger values migrate more eagerly on persistent skew.
    pub payback_windows: f64,
}

impl Default for MigrationCost {
    fn default() -> Self {
        MigrationCost { replan_us: 0.0, swap_pause_us: 0.0, payback_windows: 1.0 }
    }
}

impl MigrationCost {
    /// The full predicted bill of one migration: the two-shard re-plan
    /// plus both devices' swap pauses.
    pub fn total_us(&self) -> f64 {
        self.replan_us + 2.0 * self.swap_pause_us
    }
}

/// A concrete move proposed by [`MigrationPolicy::propose`]: global slot
/// `slot` leaves device `from` for device `to`.
#[derive(Debug, Clone, PartialEq)]
pub struct MigrationProposal {
    /// Global tenant slot to move.
    pub slot: usize,
    /// Device the tenant currently occupies (the hottest device).
    pub from: usize,
    /// Destination device (the coolest device).
    pub to: usize,
    /// Max/min device-load ratio before the move.
    pub imbalance_before: f64,
    /// Predicted ratio after the move.
    pub imbalance_after: f64,
    /// Predicted per-window gain: the reduction of the bottleneck
    /// device's observed load (µs-weighted; for the interference-aware
    /// variant, of the max `load × slowdown` score).
    pub gain: f64,
    /// Predicted one-time migration cost ([`MigrationCost::total_us`];
    /// `0.0` under the classic ratio-threshold rule).
    pub cost: f64,
}

/// A migration the engine actually executed
/// ([`crate::engine::GacerEngine::maybe_migrate`]). Devices are named by
/// stable [`DeviceId`], not dense index: on an elastic pool the executed
/// move must stay meaningful even after a later scale-in shifts the
/// dense indices.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Migration {
    /// Stable id of the moved tenant (its global slot is unchanged —
    /// migration never compacts slots).
    pub tenant: TenantId,
    /// Stable id of the device the tenant left.
    pub from: DeviceId,
    /// Stable id of the device the tenant moved to.
    pub to: DeviceId,
}

impl MigrationPolicy {
    /// The cost/gain policy: the default trigger and cooldown, plus a
    /// [`MigrationCost`] gate — a triggered move is only proposed when
    /// its predicted gain pays the migration bill back within
    /// `cost.payback_windows` observe windows.
    ///
    /// ```
    /// use gacer::engine::{MigrationCost, MigrationPolicy};
    /// use gacer::plan::Placement;
    ///
    /// let placement = Placement::from_assignments(vec![vec![0, 1], vec![2]]);
    /// let cost = MigrationCost {
    ///     replan_us: 2.0,
    ///     swap_pause_us: 0.0,
    ///     payback_windows: 1.0,
    /// };
    /// let policy = MigrationPolicy::cost_aware(cost);
    ///
    /// // Marginal skew: the ratio (4.2 / 1.0) triggers and the classic
    /// // rule would chase it, but moving slot 1 only shaves 1.2 off the
    /// // bottleneck — less than the 2.0 bill, so cost/gain declines.
    /// let weights = [3.0, 1.2, 1.0];
    /// assert!(MigrationPolicy::default().propose(&weights, &placement).is_some());
    /// assert!(policy.propose(&weights, &placement).is_none());
    ///
    /// // Large skew: the same move now shaves 12.0 — it migrates, and
    /// // the proposal reports the predicted gain and cost.
    /// let p = policy.propose(&[30.0, 12.0, 1.0], &placement).unwrap();
    /// assert_eq!((p.slot, p.from, p.to), (1, 0, 1));
    /// assert_eq!(p.gain, 12.0);
    /// assert_eq!(p.cost, 2.0);
    /// ```
    pub fn cost_aware(cost: MigrationCost) -> Self {
        MigrationPolicy { cost: Some(cost), ..Default::default() }
    }

    /// Attach a [`MigrationCost`] gate to an existing policy.
    pub fn with_cost(mut self, cost: MigrationCost) -> Self {
        self.cost = Some(cost);
        self
    }

    /// Whether a predicted per-window `gain` pays for the configured
    /// migration cost (always true without a cost model).
    fn gain_pays(&self, gain: f64) -> bool {
        match &self.cost {
            None => true,
            Some(c) => gain * c.payback_windows.max(0.0) >= c.total_us(),
        }
    }

    fn bill(&self) -> f64 {
        self.cost.as_ref().map_or(0.0, MigrationCost::total_us)
    }

    /// Evaluate observed per-tenant load `weights` (slot order, e.g.
    /// [`crate::engine::GacerEngine::observed_tenant_weights`]) under
    /// `placement`. Returns the single tenant move onto the least loaded
    /// device that best shrinks `(max device load, imbalance ratio)` —
    /// candidates are drawn from *every* device tied at the maximum, so
    /// two saturated GPUs beside an idle one still rebalance. `None`
    /// when the imbalance is under threshold, the cluster has fewer than
    /// two devices, or no move strictly improves (moving a lone hot
    /// tenant around helps nobody).
    pub fn propose(
        &self,
        weights: &[f64],
        placement: &Placement,
    ) -> Option<MigrationProposal> {
        let n = placement.n_devices();
        if n < 2 || !covers_placement(weights.len(), placement) {
            return None;
        }
        let loads: Vec<f64> = (0..n)
            .map(|d| placement.tenants_on(d).iter().map(|&s| weights[s]).sum())
            .collect();
        let before = imbalance_ratio(&loads);
        if before <= self.max_imbalance {
            return None;
        }
        let old_max = loads.iter().copied().fold(0.0f64, f64::max);
        let to = (0..n)
            .reduce(|a, b| if loads[b] < loads[a] { b } else { a })
            .expect("n >= 2");

        // Best single move off any bottleneck-tied device: minimize
        // (new max load, new ratio), require a strict improvement on
        // that pair to be worth a re-search + swap.
        let mut best: Option<(f64, f64, usize, usize)> = None;
        for from in (0..n).filter(|&d| loads[d] >= old_max && d != to) {
            for &slot in placement.tenants_on(from) {
                let w = weights[slot];
                if w <= 0.0 {
                    continue;
                }
                let mut moved = loads.clone();
                moved[from] -= w;
                moved[to] += w;
                let new_max = moved.iter().copied().fold(0.0f64, f64::max);
                let new_ratio = imbalance_ratio(&moved);
                if new_max > old_max || (new_max == old_max && new_ratio >= before) {
                    continue;
                }
                let better = match &best {
                    None => true,
                    Some(&(m, r, _, _)) => new_max < m || (new_max == m && new_ratio < r),
                };
                if better {
                    best = Some((new_max, new_ratio, slot, from));
                }
            }
        }
        best.and_then(|(new_max, after, slot, from)| {
            // Cost/gain gate: the bottleneck reduction must pay the
            // re-plan + swap-pause bill back within the payback horizon.
            let gain = old_max - new_max;
            if !self.gain_pays(gain) {
                return None;
            }
            Some(MigrationProposal {
                slot,
                from,
                to,
                imbalance_before: before,
                imbalance_after: after,
                gain,
                cost: self.bill(),
            })
        })
    }

    /// Objective-consistent sibling of [`MigrationPolicy::propose`] for
    /// [`PlacementObjective::InterferenceAware`] deployments. The trigger
    /// is the same observed max/min load ratio, but candidate moves are
    /// scored by the predicted max per-device **interference score**
    /// (observed load × [`CostModel::colocation_slowdown`] over the
    /// co-located DFGs' occupancy curves), and destinations are drawn
    /// from *every* other device, not just the coolest — relieving
    /// SM-pool contention can beat raw load smoothing. Requires a strict
    /// improvement in the max score; declines on a weights/placement
    /// arity mismatch exactly like `propose`.
    ///
    /// [`PlacementObjective::InterferenceAware`]:
    ///     crate::plan::PlacementObjective::InterferenceAware
    /// [`CostModel::colocation_slowdown`]:
    ///     crate::profile::CostModel::colocation_slowdown
    pub fn propose_interference_aware(
        &self,
        weights: &[f64],
        placement: &Placement,
        set: &TenantSet,
    ) -> Option<MigrationProposal> {
        let n = placement.n_devices();
        if n < 2 || !covers_placement(weights.len().min(set.len()), placement) {
            return None;
        }
        let loads: Vec<f64> = (0..n)
            .map(|d| placement.tenants_on(d).iter().map(|&s| weights[s]).sum())
            .collect();
        let before = imbalance_ratio(&loads);
        if before <= self.max_imbalance {
            return None;
        }
        // Sample each tenant's occupancy timeline once; every candidate
        // group below scores by summing the pre-sampled profiles.
        let profiles: Vec<Vec<f64>> =
            set.tenants.iter().map(|d| set.cost.occupancy_profile(d)).collect();
        let slowdown_of = |slots: &[usize]| -> f64 {
            let refs: Vec<&[f64]> =
                slots.iter().map(|&s| profiles[s].as_slice()).collect();
            slowdown_from_phases(&refs)
        };
        let scores: Vec<f64> = (0..n)
            .map(|d| loads[d] * slowdown_of(placement.tenants_on(d)))
            .collect();
        let current_max = scores.iter().copied().fold(0.0f64, f64::max);

        // Best single move off any score-bottleneck device: minimize
        // (new max score, new load ratio), require a strict improvement
        // on the max score to be worth a re-search + swap.
        let mut best: Option<(f64, f64, usize, usize, usize)> = None;
        for from in (0..n).filter(|&d| scores[d] >= current_max) {
            for &slot in placement.tenants_on(from) {
                let w = weights[slot];
                if w <= 0.0 {
                    continue;
                }
                let src_slots: Vec<usize> = placement
                    .tenants_on(from)
                    .iter()
                    .copied()
                    .filter(|&s| s != slot)
                    .collect();
                for to in (0..n).filter(|&t| t != from) {
                    let mut dst_slots = placement.tenants_on(to).to_vec();
                    dst_slots.push(slot);
                    let mut moved = loads.clone();
                    moved[from] -= w;
                    moved[to] += w;
                    let src_score = moved[from].max(0.0) * slowdown_of(&src_slots);
                    let dst_score = moved[to] * slowdown_of(&dst_slots);
                    let new_max = scores
                        .iter()
                        .enumerate()
                        .map(|(d, &s)| {
                            if d == from {
                                src_score
                            } else if d == to {
                                dst_score
                            } else {
                                s
                            }
                        })
                        .fold(0.0f64, f64::max);
                    if new_max >= current_max * (1.0 - 1e-9) {
                        continue;
                    }
                    let new_ratio = imbalance_ratio(&moved);
                    let better = match &best {
                        None => true,
                        Some(&(m, r, ..)) => new_max < m || (new_max == m && new_ratio < r),
                    };
                    if better {
                        best = Some((new_max, new_ratio, slot, from, to));
                    }
                }
            }
        }
        best.and_then(|(new_max, after, slot, from, to)| {
            // Same cost/gain gate as `propose`, on the interference
            // score: relieving the bottleneck must out-earn the bill.
            let gain = current_max - new_max;
            if !self.gain_pays(gain) {
                return None;
            }
            Some(MigrationProposal {
                slot,
                from,
                to,
                imbalance_before: before,
                imbalance_after: after,
                gain,
                cost: self.bill(),
            })
        })
    }

    /// Objective-consistent sibling of
    /// [`MigrationPolicy::propose_interference_aware`] for
    /// [`PlacementObjective::MemoryAware`] deployments. Candidate groups
    /// are scored on the two-dimensional roofline
    /// ([`crate::profile::roofline_slowdown`]): a device is a bottleneck
    /// when either its summed SM demand *or* its summed bandwidth demand
    /// oversubscribes, so a move that separates two bandwidth hogs wins
    /// even when occupancy alone sees no contention. Destinations whose
    /// resident HBM footprint would overflow the platform's capacity
    /// ([`crate::profile::Platform::hbm_bytes`]) are never proposed —
    /// migration must not create a placement that admission would refuse.
    ///
    /// [`PlacementObjective::MemoryAware`]:
    ///     crate::plan::PlacementObjective::MemoryAware
    pub fn propose_memory_aware(
        &self,
        weights: &[f64],
        placement: &Placement,
        set: &TenantSet,
    ) -> Option<MigrationProposal> {
        let n = placement.n_devices();
        if n < 2 || !covers_placement(weights.len().min(set.len()), placement) {
            return None;
        }
        let loads: Vec<f64> = (0..n)
            .map(|d| placement.tenants_on(d).iter().map(|&s| weights[s]).sum())
            .collect();
        let before = imbalance_ratio(&loads);
        // Sample both demand timelines once per tenant; candidate groups
        // below score by summing the pre-sampled profiles.
        let occ: Vec<Vec<f64>> =
            set.tenants.iter().map(|d| set.cost.occupancy_profile(d)).collect();
        let mem: Vec<Vec<f64>> =
            set.tenants.iter().map(|d| set.cost.bandwidth_profile(d)).collect();
        let footprints: Vec<f64> =
            set.tenants.iter().map(|d| TenantSet::dfg_footprint(d, None)).collect();
        let capacity = set.cost.platform.hbm_bytes();
        let slowdown_of = |slots: &[usize]| -> f64 {
            let o: Vec<&[f64]> = slots.iter().map(|&s| occ[s].as_slice()).collect();
            let m: Vec<&[f64]> = slots.iter().map(|&s| mem[s].as_slice()).collect();
            roofline_slowdown(&o, &m)
        };
        let usage_of = |slots: &[usize]| -> f64 {
            slots.iter().map(|&s| footprints[s]).sum()
        };
        let scores: Vec<f64> = (0..n)
            .map(|d| loads[d] * slowdown_of(placement.tenants_on(d)))
            .collect();
        let current_max = scores.iter().copied().fold(0.0f64, f64::max);
        // Trigger on observed load skew *or* a predicted roofline
        // bottleneck: two bandwidth hogs paired on one device can be
        // perfectly load-balanced yet still worth separating.
        let contended = (0..n)
            .any(|d| slowdown_of(placement.tenants_on(d)) > 1.0 + 1e-9);
        if before <= self.max_imbalance && !contended {
            return None;
        }

        let mut best: Option<(f64, f64, usize, usize, usize)> = None;
        for from in (0..n).filter(|&d| scores[d] >= current_max) {
            for &slot in placement.tenants_on(from) {
                let w = weights[slot];
                if w <= 0.0 {
                    continue;
                }
                let src_slots: Vec<usize> = placement
                    .tenants_on(from)
                    .iter()
                    .copied()
                    .filter(|&s| s != slot)
                    .collect();
                for to in (0..n).filter(|&t| t != from) {
                    // Hard capacity gate on the destination.
                    if usage_of(placement.tenants_on(to)) + footprints[slot]
                        > capacity
                    {
                        continue;
                    }
                    let mut dst_slots = placement.tenants_on(to).to_vec();
                    dst_slots.push(slot);
                    let mut moved = loads.clone();
                    moved[from] -= w;
                    moved[to] += w;
                    let src_score = moved[from].max(0.0) * slowdown_of(&src_slots);
                    let dst_score = moved[to] * slowdown_of(&dst_slots);
                    let new_max = scores
                        .iter()
                        .enumerate()
                        .map(|(d, &s)| {
                            if d == from {
                                src_score
                            } else if d == to {
                                dst_score
                            } else {
                                s
                            }
                        })
                        .fold(0.0f64, f64::max);
                    if new_max >= current_max * (1.0 - 1e-9) {
                        continue;
                    }
                    let new_ratio = imbalance_ratio(&moved);
                    let better = match &best {
                        None => true,
                        Some(&(m, r, ..)) => new_max < m || (new_max == m && new_ratio < r),
                    };
                    if better {
                        best = Some((new_max, new_ratio, slot, from, to));
                    }
                }
            }
        }
        best.and_then(|(new_max, after, slot, from, to)| {
            let gain = current_max - new_max;
            if !self.gain_pays(gain) {
                return None;
            }
            Some(MigrationProposal {
                slot,
                from,
                to,
                imbalance_before: before,
                imbalance_after: after,
                gain,
                cost: self.bill(),
            })
        })
    }
}

/// Whether every slot the placement places is below `len` (the observed
/// weights' — and, for the interference variant, the tenant set's —
/// arity). A stale observation taken before an admission grew the slot
/// count must make the policy decline, not index out of bounds.
fn covers_placement(len: usize, placement: &Placement) -> bool {
    (0..placement.n_devices())
        .all(|d| placement.tenants_on(d).iter().all(|&s| s < len))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::Dfg;

    fn placement() -> Placement {
        // Device 0 = {0, 1}, device 1 = {2}, device 2 = {3}.
        Placement::from_assignments(vec![vec![0, 1], vec![2], vec![3]])
    }

    #[test]
    fn balanced_loads_propose_nothing() {
        let p = MigrationPolicy::default();
        assert!(p.propose(&[1.0, 1.0, 2.0, 1.9], &placement()).is_none());
        // All idle: ratio is defined as 1.0.
        assert!(p.propose(&[0.0, 0.0, 0.0, 0.0], &placement()).is_none());
        // Single device: nowhere to go.
        let single = Placement::single_device(2);
        assert!(p.propose(&[9.0, 1.0], &single).is_none());
    }

    #[test]
    fn skew_moves_the_best_tenant_to_the_coolest_device() {
        let p = MigrationPolicy::default();
        // Device 0 = 12, device 1 = 2, device 2 = 4: ratio 6.
        let prop = p.propose(&[8.0, 4.0, 2.0, 4.0], &placement()).unwrap();
        assert_eq!(prop.from, 0);
        assert_eq!(prop.to, 1);
        // Moving slot 1 (w=4): loads [8, 6, 4] (max 8). Moving slot 0
        // (w=8): loads [4, 10, 4] (max 10). Slot 1 wins.
        assert_eq!(prop.slot, 1);
        assert!(prop.imbalance_after < prop.imbalance_before);
    }

    #[test]
    fn idle_device_absorbs_skew_among_the_loaded_devices() {
        let p = MigrationPolicy::default();
        // Loads [12, 2, 0]: the skew among the loaded devices (12/2 = 6)
        // triggers, and the idle device is the preferred destination.
        let prop = p.propose(&[8.0, 4.0, 2.0, 0.0], &placement()).unwrap();
        assert_eq!(prop.imbalance_before, 6.0, "idle device excluded from the ratio");
        assert_eq!((prop.from, prop.to), (0, 2));
    }

    #[test]
    fn fresh_empty_device_does_not_fire_when_loaded_devices_are_balanced() {
        // Regression (elastic pools): loads [2, 2, 0] — e.g. right after
        // a scale-out added an empty device. The old INFINITY ratio
        // exceeded every threshold and churned a migration each window;
        // balanced loaded devices must stay put.
        let p = MigrationPolicy::default();
        assert!(p.propose(&[1.0, 1.0, 2.0, 0.0], &placement()).is_none());
        // The interference variant shares the trigger.
        let set = interference_set();
        assert!(p
            .propose_interference_aware(&[1.0, 1.0, 2.0, 0.0], &placement(), &set)
            .is_none());
    }

    #[test]
    fn tied_maxima_still_rebalance() {
        // Devices 0 and 1 both saturated at 5, device 2 nearly idle. A
        // strict-max-only criterion would refuse every move (the max
        // stays 5 because the *other* saturated device is untouched);
        // improving the ratio at an unchanged max is enough, and
        // candidates come from every bottleneck-tied device.
        let p = MigrationPolicy::default();
        let prop = p.propose(&[3.0, 2.0, 5.0, 1.0], &placement()).unwrap();
        assert_eq!((prop.from, prop.to), (0, 2));
        assert_eq!(prop.imbalance_before, 5.0);
        assert!(prop.imbalance_after < prop.imbalance_before);
    }

    #[test]
    fn lone_hot_tenant_stays_put() {
        // Device 1's singleton is the whole skew; moving it just
        // relocates the bottleneck.
        let p = MigrationPolicy::default();
        assert!(p.propose(&[0.5, 0.5, 9.0, 1.0], &placement()).is_none());
    }

    #[test]
    fn threshold_is_respected() {
        let lax = MigrationPolicy { max_imbalance: 10.0, ..Default::default() };
        assert!(lax.propose(&[8.0, 4.0, 2.0, 4.0], &placement()).is_none());
        let strict = MigrationPolicy { max_imbalance: 1.1, ..Default::default() };
        assert!(strict.propose(&[8.0, 4.0, 2.0, 4.0], &placement()).is_some());
    }

    #[test]
    fn cost_gain_declines_marginal_skew_that_ratio_rule_would_chase() {
        // Device 0 = {0, 1} carries 4.2 of 5.2 total load: ratio > 2
        // triggers, and the ratio-threshold policy proposes moving
        // slot 1 (shaving 1.2 off the bottleneck).
        let p = Placement::from_assignments(vec![vec![0, 1], vec![2]]);
        let weights = [3.0, 1.2, 1.0];
        let ratio_rule = MigrationPolicy::default();
        let chased = ratio_rule.propose(&weights, &p).unwrap();
        assert_eq!(chased.slot, 1);
        assert_eq!(chased.cost, 0.0, "classic rule prices nothing");

        // Cost/gain mode with a 2.0 bill: the 1.2 gain does not pay it
        // back within one window — declined.
        let cost = MigrationCost {
            replan_us: 1.5,
            swap_pause_us: 0.25,
            payback_windows: 1.0,
        };
        assert_eq!(cost.total_us(), 2.0);
        let priced = MigrationPolicy::cost_aware(cost);
        assert!(priced.propose(&weights, &p).is_none());

        // A longer payback horizon tolerates the same bill (2 windows of
        // 1.2 > 2.0).
        let patient = MigrationPolicy::cost_aware(MigrationCost {
            payback_windows: 2.0,
            ..cost
        });
        assert!(patient.propose(&weights, &p).is_some());

        // Large skew pays for itself immediately: still migrates, and
        // the proposal carries the gain/cost audit trail.
        let moved = priced.propose(&[30.0, 12.0, 1.0], &p).unwrap();
        assert_eq!((moved.slot, moved.from, moved.to), (1, 0, 1));
        assert_eq!(moved.gain, 12.0);
        assert_eq!(moved.cost, 2.0);
    }

    #[test]
    fn cost_gain_gate_applies_to_the_interference_variant() {
        let set = interference_set();
        let placement =
            Placement::from_assignments(vec![vec![0, 1], vec![2], vec![3]]);
        let weights = [6.0, 4.0, 1.0, 2.0];
        // The ungated interference policy proposes a move (see
        // interference_destination_avoids_the_saturated_device).
        let free = MigrationPolicy::default();
        let m = free.propose_interference_aware(&weights, &placement, &set).unwrap();
        assert!(m.gain > 0.0);
        // A bill larger than that gain vetoes the same move.
        let pricey = MigrationPolicy::cost_aware(MigrationCost {
            replan_us: m.gain * 10.0,
            swap_pause_us: 0.0,
            payback_windows: 1.0,
        });
        assert!(pricey
            .propose_interference_aware(&weights, &placement, &set)
            .is_none());
        // A bill the gain covers still migrates, with the bill recorded.
        let fair = MigrationPolicy::cost_aware(MigrationCost {
            replan_us: m.gain * 0.5,
            swap_pause_us: 0.0,
            payback_windows: 1.0,
        });
        let priced = fair
            .propose_interference_aware(&weights, &placement, &set)
            .unwrap();
        assert_eq!((priced.slot, priced.to), (m.slot, m.to));
        assert_eq!(priced.cost, m.gain * 0.5);
    }

    #[test]
    fn stale_short_weights_decline_instead_of_panicking() {
        // The placement knows 4 slots; the observation predates the last
        // two admissions. Indexing would panic — the policy must decline.
        let p = MigrationPolicy::default();
        assert!(p.propose(&[9.0, 0.5], &placement()).is_none());
        assert!(p.propose(&[], &placement()).is_none());
        // A matching observation still proposes.
        assert!(p.propose(&[8.0, 4.0, 2.0, 0.0], &placement()).is_some());
    }

    fn conv_net(name: &str, batch: usize, n: usize) -> Dfg {
        use crate::dfg::OpKind;
        let kind = OpKind::Conv { h: 56, w: 56, cin: 256, cout: 256, k: 3, stride: 1 };
        let mut d = Dfg::new(name);
        for i in 0..n {
            d.push(kind, batch, format!("conv{i}"));
        }
        d
    }

    fn interference_set() -> TenantSet {
        // Slots 0..=2 saturate the SM pool (batch-32 convs); slot 3 is a
        // low-occupancy tenant (batch-1 convs, ~10% of the pool).
        let cost = crate::profile::CostModel::new(crate::profile::Platform::titan_v());
        TenantSet::new(
            vec![
                conv_net("hi-a", 32, 2),
                conv_net("hi-b", 32, 2),
                conv_net("hi-c", 32, 2),
                conv_net("lo", 1, 16),
            ],
            cost,
        )
    }

    #[test]
    fn interference_destination_avoids_the_saturated_device() {
        // Device 0 runs hot with two saturating tenants; device 1 (the
        // coolest by load) holds another saturating tenant, device 2 a
        // low-occupancy one. Load-based propose picks the coolest device
        // — co-locating two saturating tenants; the interference-aware
        // variant pays the slowdown and routes to device 2 instead.
        let set = interference_set();
        let placement =
            Placement::from_assignments(vec![vec![0, 1], vec![2], vec![3]]);
        let weights = [6.0, 4.0, 1.0, 2.0];
        let policy = MigrationPolicy::default();

        let by_load = policy.propose(&weights, &placement).unwrap();
        assert_eq!((by_load.slot, by_load.from, by_load.to), (1, 0, 1));

        let by_score = policy
            .propose_interference_aware(&weights, &placement, &set)
            .unwrap();
        assert_eq!((by_score.slot, by_score.from), (1, 0));
        assert_eq!(by_score.to, 2, "destination scored by interference");
        assert!(by_score.imbalance_before > policy.max_imbalance);
    }

    #[test]
    fn interference_variant_shares_the_guards() {
        let set = interference_set();
        let placement =
            Placement::from_assignments(vec![vec![0, 1], vec![2], vec![3]]);
        let policy = MigrationPolicy::default();
        // Under-threshold skew stays put.
        assert!(policy
            .propose_interference_aware(&[1.0, 1.0, 1.5, 1.0], &placement, &set)
            .is_none());
        // Stale short weights decline.
        assert!(policy
            .propose_interference_aware(&[9.0, 0.5], &placement, &set)
            .is_none());
        // Fewer than two devices: nowhere to go.
        let single = Placement::single_device(4);
        assert!(policy
            .propose_interference_aware(&[9.0, 1.0, 1.0, 1.0], &single, &set)
            .is_none());
    }

    fn bn_net(name: &str, n: usize) -> Dfg {
        use crate::dfg::OpKind;
        // Batch-8 BatchNorm over 56×56×256: ~96% of peak bandwidth but
        // only ~1.5% SM occupancy — invisible to the occupancy model.
        let mut d = Dfg::new(name);
        for i in 0..n {
            d.push(OpKind::BatchNorm { elems: 56 * 56 * 256 }, 8, format!("bn{i}"));
        }
        d
    }

    #[test]
    fn memory_variant_separates_bandwidth_hogs_occupancy_cannot_see() {
        // Two bandwidth-saturating BN tenants share device 0; the loads
        // are perfectly balanced (ratio 2.0 == threshold), so both the
        // load rule and the interference rule decline. The roofline sees
        // the paired ~96% bandwidth demands oversubscribing HBM and
        // separates them.
        let cost = crate::profile::CostModel::new(crate::profile::Platform::titan_v());
        let set = TenantSet::new(
            vec![bn_net("hog-a", 24), bn_net("hog-b", 24), conv_net("lo", 1, 4)],
            cost,
        );
        let placement = Placement::from_assignments(vec![vec![0, 1], vec![2]]);
        let weights = [2.0, 2.0, 2.0];
        let policy = MigrationPolicy::default();
        assert!(policy.propose(&weights, &placement).is_none());
        assert!(policy
            .propose_interference_aware(&weights, &placement, &set)
            .is_none());
        let m = policy
            .propose_memory_aware(&weights, &placement, &set)
            .expect("roofline contention triggers without load skew");
        assert_eq!(m.from, 0);
        assert_eq!(m.to, 1);
        assert!(m.gain > 0.0);
    }

    #[test]
    fn memory_variant_never_overflows_the_destination() {
        // Device 1 already holds a ~14.4 GB tenant (over the Titan V's
        // 12 GB by itself); the only capacity-respecting destination is
        // the empty device 2.
        use crate::dfg::OpKind;
        let cost = crate::profile::CostModel::new(crate::profile::Platform::titan_v());
        let mut giant = Dfg::new("giant");
        giant.push(OpKind::Linear { fin: 60_000, fout: 60_000 }, 1, "g0");
        let set =
            TenantSet::new(vec![bn_net("hog-a", 24), bn_net("hog-b", 24), giant], cost);
        let placement =
            Placement::from_assignments(vec![vec![0, 1], vec![2], vec![]]);
        let weights = [2.0, 2.0, 2.0];
        let policy = MigrationPolicy::default();
        let m = policy
            .propose_memory_aware(&weights, &placement, &set)
            .expect("the empty device absorbs a hog");
        assert_eq!(m.from, 0);
        assert_eq!(m.to, 2, "full device is never a destination");

        // With the full device as the only alternative, no move at all.
        let two = Placement::from_assignments(vec![vec![0, 1], vec![2]]);
        assert!(policy.propose_memory_aware(&weights, &two, &set).is_none());
    }
}
