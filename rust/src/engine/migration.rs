//! Load-drift tenant migration policy.
//!
//! A tenant's device is chosen at admission from cost-model load — but
//! traffic drifts, and a placement that was balanced under assumed
//! uniform demand can leave one GPU saturated while another idles (the
//! online workload-drift problem of the multi-tenant serving
//! literature; VELTAIR makes the same argument for adaptive scheduling
//! decisions applied to live services). [`MigrationPolicy`] is the
//! decision rule: it watches the **observed** per-device loads
//! ([`GacerEngine::observed_device_loads`]) and, when the max/min
//! device-load ratio crosses a threshold, proposes moving one tenant
//! from the hottest device to the coolest — the single move that best
//! shrinks the bottleneck. Execution is the engine's job
//! ([`GacerEngine::maybe_migrate`] → [`GacerEngine::migrate`]: two-shard
//! re-search, then a cluster hot swap).
//!
//! [`GacerEngine::observed_device_loads`]: crate::engine::GacerEngine::observed_device_loads
//! [`GacerEngine::maybe_migrate`]: crate::engine::GacerEngine::maybe_migrate
//! [`GacerEngine::migrate`]: crate::engine::GacerEngine::migrate

use crate::engine::TenantId;
use crate::metrics::imbalance_ratio;
use crate::plan::{Placement, TenantSet};
use crate::profile::slowdown_from_phases;

/// Threshold rule for load-drift migration: act when the max/min
/// observed device-load ratio exceeds `max_imbalance`, and only when a
/// single tenant move strictly shrinks the bottleneck device's load.
///
/// ```
/// use gacer::engine::MigrationPolicy;
/// use gacer::plan::Placement;
///
/// let placement = Placement::from_assignments(vec![vec![0, 1], vec![2]]);
/// let policy = MigrationPolicy::default(); // max_imbalance = 2.0
///
/// // Device 0 carries 9.0 of 10.0 total load: ratio 9 > 2. The best
/// // single move is the *lighter* co-tenant (moving the 8.0 tenant
/// // would just flip the skew).
/// let p = policy.propose(&[8.0, 1.0, 1.0], &placement).unwrap();
/// assert_eq!((p.slot, p.from, p.to), (1, 0, 1));
/// assert!(p.imbalance_after < p.imbalance_before);
///
/// // Mild skew stays put.
/// assert!(policy.propose(&[1.0, 1.0, 1.5], &placement).is_none());
///
/// // A hot *singleton* tenant has no useful move: migrating it only
/// // relocates the bottleneck.
/// let lone = Placement::from_assignments(vec![vec![0], vec![1]]);
/// assert!(policy.propose(&[9.0, 1.0], &lone).is_none());
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct MigrationPolicy {
    /// Trigger threshold on the max/min device-load ratio
    /// ([`crate::metrics::imbalance_ratio`]); must be > 1. A ratio of
    /// `f64::INFINITY` (a loaded device next to an idle one) always
    /// triggers.
    pub max_imbalance: f64,
    /// Hysteresis against migration thrash: after an executed migration,
    /// proposals that would move the same tenant straight back onto the
    /// device it left are suppressed for this many observe windows (one
    /// window = one [`GacerEngine::maybe_migrate`] consultation). Under
    /// alternating skew this damps the A→B→A ping-pong: the reverse move
    /// only executes once the skew outlives the cooldown. `0` disables
    /// the cooldown.
    ///
    /// [`GacerEngine::maybe_migrate`]: crate::engine::GacerEngine::maybe_migrate
    pub cooldown_windows: usize,
}

impl Default for MigrationPolicy {
    fn default() -> Self {
        MigrationPolicy { max_imbalance: 2.0, cooldown_windows: 1 }
    }
}

/// A concrete move proposed by [`MigrationPolicy::propose`]: global slot
/// `slot` leaves device `from` for device `to`.
#[derive(Debug, Clone, PartialEq)]
pub struct MigrationProposal {
    /// Global tenant slot to move.
    pub slot: usize,
    /// Device the tenant currently occupies (the hottest device).
    pub from: usize,
    /// Destination device (the coolest device).
    pub to: usize,
    /// Max/min device-load ratio before the move.
    pub imbalance_before: f64,
    /// Predicted ratio after the move.
    pub imbalance_after: f64,
}

/// A migration the engine actually executed
/// ([`crate::engine::GacerEngine::maybe_migrate`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Migration {
    /// Stable id of the moved tenant (its global slot is unchanged —
    /// migration never compacts slots).
    pub tenant: TenantId,
    pub from: usize,
    pub to: usize,
}

impl MigrationPolicy {
    /// Evaluate observed per-tenant load `weights` (slot order, e.g.
    /// [`crate::engine::GacerEngine::observed_tenant_weights`]) under
    /// `placement`. Returns the single tenant move onto the least loaded
    /// device that best shrinks `(max device load, imbalance ratio)` —
    /// candidates are drawn from *every* device tied at the maximum, so
    /// two saturated GPUs beside an idle one still rebalance. `None`
    /// when the imbalance is under threshold, the cluster has fewer than
    /// two devices, or no move strictly improves (moving a lone hot
    /// tenant around helps nobody).
    pub fn propose(
        &self,
        weights: &[f64],
        placement: &Placement,
    ) -> Option<MigrationProposal> {
        let n = placement.n_devices();
        if n < 2 || !covers_placement(weights.len(), placement) {
            return None;
        }
        let loads: Vec<f64> = (0..n)
            .map(|d| placement.tenants_on(d).iter().map(|&s| weights[s]).sum())
            .collect();
        let before = imbalance_ratio(&loads);
        if before <= self.max_imbalance {
            return None;
        }
        let old_max = loads.iter().copied().fold(0.0f64, f64::max);
        let to = (0..n)
            .reduce(|a, b| if loads[b] < loads[a] { b } else { a })
            .expect("n >= 2");

        // Best single move off any bottleneck-tied device: minimize
        // (new max load, new ratio), require a strict improvement on
        // that pair to be worth a re-search + swap.
        let mut best: Option<(f64, f64, usize, usize)> = None;
        for from in (0..n).filter(|&d| loads[d] >= old_max && d != to) {
            for &slot in placement.tenants_on(from) {
                let w = weights[slot];
                if w <= 0.0 {
                    continue;
                }
                let mut moved = loads.clone();
                moved[from] -= w;
                moved[to] += w;
                let new_max = moved.iter().copied().fold(0.0f64, f64::max);
                let new_ratio = imbalance_ratio(&moved);
                if new_max > old_max || (new_max == old_max && new_ratio >= before) {
                    continue;
                }
                let better = match &best {
                    None => true,
                    Some(&(m, r, _, _)) => new_max < m || (new_max == m && new_ratio < r),
                };
                if better {
                    best = Some((new_max, new_ratio, slot, from));
                }
            }
        }
        best.map(|(_, after, slot, from)| MigrationProposal {
            slot,
            from,
            to,
            imbalance_before: before,
            imbalance_after: after,
        })
    }

    /// Objective-consistent sibling of [`MigrationPolicy::propose`] for
    /// [`PlacementObjective::InterferenceAware`] deployments. The trigger
    /// is the same observed max/min load ratio, but candidate moves are
    /// scored by the predicted max per-device **interference score**
    /// (observed load × [`CostModel::colocation_slowdown`] over the
    /// co-located DFGs' occupancy curves), and destinations are drawn
    /// from *every* other device, not just the coolest — relieving
    /// SM-pool contention can beat raw load smoothing. Requires a strict
    /// improvement in the max score; declines on a weights/placement
    /// arity mismatch exactly like `propose`.
    ///
    /// [`PlacementObjective::InterferenceAware`]:
    ///     crate::plan::PlacementObjective::InterferenceAware
    /// [`CostModel::colocation_slowdown`]:
    ///     crate::profile::CostModel::colocation_slowdown
    pub fn propose_interference_aware(
        &self,
        weights: &[f64],
        placement: &Placement,
        set: &TenantSet,
    ) -> Option<MigrationProposal> {
        let n = placement.n_devices();
        if n < 2 || !covers_placement(weights.len().min(set.len()), placement) {
            return None;
        }
        let loads: Vec<f64> = (0..n)
            .map(|d| placement.tenants_on(d).iter().map(|&s| weights[s]).sum())
            .collect();
        let before = imbalance_ratio(&loads);
        if before <= self.max_imbalance {
            return None;
        }
        // Sample each tenant's occupancy timeline once; every candidate
        // group below scores by summing the pre-sampled profiles.
        let profiles: Vec<Vec<f64>> =
            set.tenants.iter().map(|d| set.cost.occupancy_profile(d)).collect();
        let slowdown_of = |slots: &[usize]| -> f64 {
            let refs: Vec<&[f64]> =
                slots.iter().map(|&s| profiles[s].as_slice()).collect();
            slowdown_from_phases(&refs)
        };
        let scores: Vec<f64> = (0..n)
            .map(|d| loads[d] * slowdown_of(placement.tenants_on(d)))
            .collect();
        let current_max = scores.iter().copied().fold(0.0f64, f64::max);

        // Best single move off any score-bottleneck device: minimize
        // (new max score, new load ratio), require a strict improvement
        // on the max score to be worth a re-search + swap.
        let mut best: Option<(f64, f64, usize, usize, usize)> = None;
        for from in (0..n).filter(|&d| scores[d] >= current_max) {
            for &slot in placement.tenants_on(from) {
                let w = weights[slot];
                if w <= 0.0 {
                    continue;
                }
                let src_slots: Vec<usize> = placement
                    .tenants_on(from)
                    .iter()
                    .copied()
                    .filter(|&s| s != slot)
                    .collect();
                for to in (0..n).filter(|&t| t != from) {
                    let mut dst_slots = placement.tenants_on(to).to_vec();
                    dst_slots.push(slot);
                    let mut moved = loads.clone();
                    moved[from] -= w;
                    moved[to] += w;
                    let src_score = moved[from].max(0.0) * slowdown_of(&src_slots);
                    let dst_score = moved[to] * slowdown_of(&dst_slots);
                    let new_max = scores
                        .iter()
                        .enumerate()
                        .map(|(d, &s)| {
                            if d == from {
                                src_score
                            } else if d == to {
                                dst_score
                            } else {
                                s
                            }
                        })
                        .fold(0.0f64, f64::max);
                    if new_max >= current_max * (1.0 - 1e-9) {
                        continue;
                    }
                    let new_ratio = imbalance_ratio(&moved);
                    let better = match &best {
                        None => true,
                        Some(&(m, r, ..)) => new_max < m || (new_max == m && new_ratio < r),
                    };
                    if better {
                        best = Some((new_max, new_ratio, slot, from, to));
                    }
                }
            }
        }
        best.map(|(_, after, slot, from, to)| MigrationProposal {
            slot,
            from,
            to,
            imbalance_before: before,
            imbalance_after: after,
        })
    }
}

/// Whether every slot the placement places is below `len` (the observed
/// weights' — and, for the interference variant, the tenant set's —
/// arity). A stale observation taken before an admission grew the slot
/// count must make the policy decline, not index out of bounds.
fn covers_placement(len: usize, placement: &Placement) -> bool {
    (0..placement.n_devices())
        .all(|d| placement.tenants_on(d).iter().all(|&s| s < len))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::Dfg;

    fn placement() -> Placement {
        // Device 0 = {0, 1}, device 1 = {2}, device 2 = {3}.
        Placement::from_assignments(vec![vec![0, 1], vec![2], vec![3]])
    }

    #[test]
    fn balanced_loads_propose_nothing() {
        let p = MigrationPolicy::default();
        assert!(p.propose(&[1.0, 1.0, 2.0, 1.9], &placement()).is_none());
        // All idle: ratio is defined as 1.0.
        assert!(p.propose(&[0.0, 0.0, 0.0, 0.0], &placement()).is_none());
        // Single device: nowhere to go.
        let single = Placement::single_device(2);
        assert!(p.propose(&[9.0, 1.0], &single).is_none());
    }

    #[test]
    fn skew_moves_the_best_tenant_to_the_coolest_device() {
        let p = MigrationPolicy::default();
        // Device 0 = 12, device 1 = 2, device 2 = 4: ratio 6.
        let prop = p.propose(&[8.0, 4.0, 2.0, 4.0], &placement()).unwrap();
        assert_eq!(prop.from, 0);
        assert_eq!(prop.to, 1);
        // Moving slot 1 (w=4): loads [8, 6, 4] (max 8). Moving slot 0
        // (w=8): loads [4, 10, 4] (max 10). Slot 1 wins.
        assert_eq!(prop.slot, 1);
        assert!(prop.imbalance_after < prop.imbalance_before);
    }

    #[test]
    fn idle_device_always_triggers_and_absorbs() {
        let p = MigrationPolicy::default();
        // Device 2 idle: ratio infinite.
        let prop = p.propose(&[8.0, 4.0, 2.0, 0.0], &placement()).unwrap();
        assert_eq!(prop.imbalance_before, f64::INFINITY);
        assert_eq!((prop.from, prop.to), (0, 2));
    }

    #[test]
    fn tied_maxima_still_rebalance_onto_the_idle_device() {
        // Devices 0 and 1 both saturated at 5, device 2 idle. A
        // strict-max-only criterion would refuse every move (the max
        // stays 5 because the *other* saturated device is untouched);
        // improving the ratio at an unchanged max is enough, and
        // candidates come from every bottleneck-tied device.
        let p = MigrationPolicy::default();
        let prop = p.propose(&[3.0, 2.0, 5.0, 0.0], &placement()).unwrap();
        assert_eq!((prop.slot, prop.from, prop.to), (0, 0, 2));
        assert_eq!(prop.imbalance_before, f64::INFINITY);
        assert!(prop.imbalance_after.is_finite());
    }

    #[test]
    fn lone_hot_tenant_stays_put() {
        // Device 1's singleton is the whole skew; moving it just
        // relocates the bottleneck.
        let p = MigrationPolicy::default();
        assert!(p.propose(&[0.5, 0.5, 9.0, 1.0], &placement()).is_none());
    }

    #[test]
    fn threshold_is_respected() {
        let lax = MigrationPolicy { max_imbalance: 10.0, ..Default::default() };
        assert!(lax.propose(&[8.0, 4.0, 2.0, 4.0], &placement()).is_none());
        let strict = MigrationPolicy { max_imbalance: 1.1, ..Default::default() };
        assert!(strict.propose(&[8.0, 4.0, 2.0, 4.0], &placement()).is_some());
    }

    #[test]
    fn stale_short_weights_decline_instead_of_panicking() {
        // The placement knows 4 slots; the observation predates the last
        // two admissions. Indexing would panic — the policy must decline.
        let p = MigrationPolicy::default();
        assert!(p.propose(&[9.0, 0.5], &placement()).is_none());
        assert!(p.propose(&[], &placement()).is_none());
        // A matching observation still proposes.
        assert!(p.propose(&[8.0, 4.0, 2.0, 0.0], &placement()).is_some());
    }

    fn conv_net(name: &str, batch: usize, n: usize) -> Dfg {
        use crate::dfg::OpKind;
        let kind = OpKind::Conv { h: 56, w: 56, cin: 256, cout: 256, k: 3, stride: 1 };
        let mut d = Dfg::new(name);
        for i in 0..n {
            d.push(kind, batch, format!("conv{i}"));
        }
        d
    }

    fn interference_set() -> TenantSet {
        // Slots 0..=2 saturate the SM pool (batch-32 convs); slot 3 is a
        // low-occupancy tenant (batch-1 convs, ~10% of the pool).
        let cost = crate::profile::CostModel::new(crate::profile::Platform::titan_v());
        TenantSet::new(
            vec![
                conv_net("hi-a", 32, 2),
                conv_net("hi-b", 32, 2),
                conv_net("hi-c", 32, 2),
                conv_net("lo", 1, 16),
            ],
            cost,
        )
    }

    #[test]
    fn interference_destination_avoids_the_saturated_device() {
        // Device 0 runs hot with two saturating tenants; device 1 (the
        // coolest by load) holds another saturating tenant, device 2 a
        // low-occupancy one. Load-based propose picks the coolest device
        // — co-locating two saturating tenants; the interference-aware
        // variant pays the slowdown and routes to device 2 instead.
        let set = interference_set();
        let placement =
            Placement::from_assignments(vec![vec![0, 1], vec![2], vec![3]]);
        let weights = [6.0, 4.0, 1.0, 2.0];
        let policy = MigrationPolicy::default();

        let by_load = policy.propose(&weights, &placement).unwrap();
        assert_eq!((by_load.slot, by_load.from, by_load.to), (1, 0, 1));

        let by_score = policy
            .propose_interference_aware(&weights, &placement, &set)
            .unwrap();
        assert_eq!((by_score.slot, by_score.from), (1, 0));
        assert_eq!(by_score.to, 2, "destination scored by interference");
        assert!(by_score.imbalance_before > policy.max_imbalance);
    }

    #[test]
    fn interference_variant_shares_the_guards() {
        let set = interference_set();
        let placement =
            Placement::from_assignments(vec![vec![0, 1], vec![2], vec![3]]);
        let policy = MigrationPolicy::default();
        // Under-threshold skew stays put.
        assert!(policy
            .propose_interference_aware(&[1.0, 1.0, 1.5, 1.0], &placement, &set)
            .is_none());
        // Stale short weights decline.
        assert!(policy
            .propose_interference_aware(&[9.0, 0.5], &placement, &set)
            .is_none());
        // Fewer than two devices: nowhere to go.
        let single = Placement::single_device(4);
        assert!(policy
            .propose_interference_aware(&[9.0, 1.0, 1.0, 1.0], &single, &set)
            .is_none());
    }
}
