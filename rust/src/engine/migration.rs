//! Load-drift tenant migration policy.
//!
//! A tenant's device is chosen at admission from cost-model load — but
//! traffic drifts, and a placement that was balanced under assumed
//! uniform demand can leave one GPU saturated while another idles (the
//! online workload-drift problem of the multi-tenant serving
//! literature; VELTAIR makes the same argument for adaptive scheduling
//! decisions applied to live services). [`MigrationPolicy`] is the
//! decision rule: it watches the **observed** per-device loads
//! ([`GacerEngine::observed_device_loads`]) and, when the max/min
//! device-load ratio crosses a threshold, proposes moving one tenant
//! from the hottest device to the coolest — the single move that best
//! shrinks the bottleneck. Execution is the engine's job
//! ([`GacerEngine::maybe_migrate`] → [`GacerEngine::migrate`]: two-shard
//! re-search, then a cluster hot swap).
//!
//! [`GacerEngine::observed_device_loads`]: crate::engine::GacerEngine::observed_device_loads
//! [`GacerEngine::maybe_migrate`]: crate::engine::GacerEngine::maybe_migrate
//! [`GacerEngine::migrate`]: crate::engine::GacerEngine::migrate

use crate::engine::TenantId;
use crate::metrics::imbalance_ratio;
use crate::plan::Placement;

/// Threshold rule for load-drift migration: act when the max/min
/// observed device-load ratio exceeds `max_imbalance`, and only when a
/// single tenant move strictly shrinks the bottleneck device's load.
///
/// ```
/// use gacer::engine::MigrationPolicy;
/// use gacer::plan::Placement;
///
/// let placement = Placement::from_assignments(vec![vec![0, 1], vec![2]]);
/// let policy = MigrationPolicy::default(); // max_imbalance = 2.0
///
/// // Device 0 carries 9.0 of 10.0 total load: ratio 9 > 2. The best
/// // single move is the *lighter* co-tenant (moving the 8.0 tenant
/// // would just flip the skew).
/// let p = policy.propose(&[8.0, 1.0, 1.0], &placement).unwrap();
/// assert_eq!((p.slot, p.from, p.to), (1, 0, 1));
/// assert!(p.imbalance_after < p.imbalance_before);
///
/// // Mild skew stays put.
/// assert!(policy.propose(&[1.0, 1.0, 1.5], &placement).is_none());
///
/// // A hot *singleton* tenant has no useful move: migrating it only
/// // relocates the bottleneck.
/// let lone = Placement::from_assignments(vec![vec![0], vec![1]]);
/// assert!(policy.propose(&[9.0, 1.0], &lone).is_none());
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct MigrationPolicy {
    /// Trigger threshold on the max/min device-load ratio
    /// ([`crate::metrics::imbalance_ratio`]); must be > 1. A ratio of
    /// `f64::INFINITY` (a loaded device next to an idle one) always
    /// triggers.
    pub max_imbalance: f64,
}

impl Default for MigrationPolicy {
    fn default() -> Self {
        MigrationPolicy { max_imbalance: 2.0 }
    }
}

/// A concrete move proposed by [`MigrationPolicy::propose`]: global slot
/// `slot` leaves device `from` for device `to`.
#[derive(Debug, Clone, PartialEq)]
pub struct MigrationProposal {
    /// Global tenant slot to move.
    pub slot: usize,
    /// Device the tenant currently occupies (the hottest device).
    pub from: usize,
    /// Destination device (the coolest device).
    pub to: usize,
    /// Max/min device-load ratio before the move.
    pub imbalance_before: f64,
    /// Predicted ratio after the move.
    pub imbalance_after: f64,
}

/// A migration the engine actually executed
/// ([`crate::engine::GacerEngine::maybe_migrate`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Migration {
    /// Stable id of the moved tenant (its global slot is unchanged —
    /// migration never compacts slots).
    pub tenant: TenantId,
    pub from: usize,
    pub to: usize,
}

impl MigrationPolicy {
    /// Evaluate observed per-tenant load `weights` (slot order, e.g.
    /// [`crate::engine::GacerEngine::observed_tenant_weights`]) under
    /// `placement`. Returns the single tenant move onto the least loaded
    /// device that best shrinks `(max device load, imbalance ratio)` —
    /// candidates are drawn from *every* device tied at the maximum, so
    /// two saturated GPUs beside an idle one still rebalance. `None`
    /// when the imbalance is under threshold, the cluster has fewer than
    /// two devices, or no move strictly improves (moving a lone hot
    /// tenant around helps nobody).
    pub fn propose(
        &self,
        weights: &[f64],
        placement: &Placement,
    ) -> Option<MigrationProposal> {
        let n = placement.n_devices();
        if n < 2 {
            return None;
        }
        let loads: Vec<f64> = (0..n)
            .map(|d| placement.tenants_on(d).iter().map(|&s| weights[s]).sum())
            .collect();
        let before = imbalance_ratio(&loads);
        if before <= self.max_imbalance {
            return None;
        }
        let old_max = loads.iter().copied().fold(0.0f64, f64::max);
        let to = (0..n)
            .reduce(|a, b| if loads[b] < loads[a] { b } else { a })
            .expect("n >= 2");

        // Best single move off any bottleneck-tied device: minimize
        // (new max load, new ratio), require a strict improvement on
        // that pair to be worth a re-search + swap.
        let mut best: Option<(f64, f64, usize, usize)> = None;
        for from in (0..n).filter(|&d| loads[d] >= old_max && d != to) {
            for &slot in placement.tenants_on(from) {
                let w = weights[slot];
                if w <= 0.0 {
                    continue;
                }
                let mut moved = loads.clone();
                moved[from] -= w;
                moved[to] += w;
                let new_max = moved.iter().copied().fold(0.0f64, f64::max);
                let new_ratio = imbalance_ratio(&moved);
                if new_max > old_max || (new_max == old_max && new_ratio >= before) {
                    continue;
                }
                let better = match &best {
                    None => true,
                    Some(&(m, r, _, _)) => new_max < m || (new_max == m && new_ratio < r),
                };
                if better {
                    best = Some((new_max, new_ratio, slot, from));
                }
            }
        }
        best.map(|(_, after, slot, from)| MigrationProposal {
            slot,
            from,
            to,
            imbalance_before: before,
            imbalance_after: after,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn placement() -> Placement {
        // Device 0 = {0, 1}, device 1 = {2}, device 2 = {3}.
        Placement::from_assignments(vec![vec![0, 1], vec![2], vec![3]])
    }

    #[test]
    fn balanced_loads_propose_nothing() {
        let p = MigrationPolicy::default();
        assert!(p.propose(&[1.0, 1.0, 2.0, 1.9], &placement()).is_none());
        // All idle: ratio is defined as 1.0.
        assert!(p.propose(&[0.0, 0.0, 0.0, 0.0], &placement()).is_none());
        // Single device: nowhere to go.
        let single = Placement::single_device(2);
        assert!(p.propose(&[9.0, 1.0], &single).is_none());
    }

    #[test]
    fn skew_moves_the_best_tenant_to_the_coolest_device() {
        let p = MigrationPolicy::default();
        // Device 0 = 12, device 1 = 2, device 2 = 4: ratio 6.
        let prop = p.propose(&[8.0, 4.0, 2.0, 4.0], &placement()).unwrap();
        assert_eq!(prop.from, 0);
        assert_eq!(prop.to, 1);
        // Moving slot 1 (w=4): loads [8, 6, 4] (max 8). Moving slot 0
        // (w=8): loads [4, 10, 4] (max 10). Slot 1 wins.
        assert_eq!(prop.slot, 1);
        assert!(prop.imbalance_after < prop.imbalance_before);
    }

    #[test]
    fn idle_device_always_triggers_and_absorbs() {
        let p = MigrationPolicy::default();
        // Device 2 idle: ratio infinite.
        let prop = p.propose(&[8.0, 4.0, 2.0, 0.0], &placement()).unwrap();
        assert_eq!(prop.imbalance_before, f64::INFINITY);
        assert_eq!((prop.from, prop.to), (0, 2));
    }

    #[test]
    fn tied_maxima_still_rebalance_onto_the_idle_device() {
        // Devices 0 and 1 both saturated at 5, device 2 idle. A
        // strict-max-only criterion would refuse every move (the max
        // stays 5 because the *other* saturated device is untouched);
        // improving the ratio at an unchanged max is enough, and
        // candidates come from every bottleneck-tied device.
        let p = MigrationPolicy::default();
        let prop = p.propose(&[3.0, 2.0, 5.0, 0.0], &placement()).unwrap();
        assert_eq!((prop.slot, prop.from, prop.to), (0, 0, 2));
        assert_eq!(prop.imbalance_before, f64::INFINITY);
        assert!(prop.imbalance_after.is_finite());
    }

    #[test]
    fn lone_hot_tenant_stays_put() {
        // Device 1's singleton is the whole skew; moving it just
        // relocates the bottleneck.
        let p = MigrationPolicy::default();
        assert!(p.propose(&[0.5, 0.5, 9.0, 1.0], &placement()).is_none());
    }

    #[test]
    fn threshold_is_respected() {
        let lax = MigrationPolicy { max_imbalance: 10.0 };
        assert!(lax.propose(&[8.0, 4.0, 2.0, 4.0], &placement()).is_none());
        let strict = MigrationPolicy { max_imbalance: 1.1 };
        assert!(strict.propose(&[8.0, 4.0, 2.0, 4.0], &placement()).is_some());
    }
}
