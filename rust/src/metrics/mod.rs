//! Serving + evaluation metrics: latency histograms, throughput counters,
//! load-imbalance measures for migration decisions, and report-ready
//! summaries.

use std::time::Duration;

/// Max/min device-load ratio — the imbalance measure
/// [`crate::engine::MigrationPolicy`] thresholds on. `1.0` for an empty
/// or all-idle cluster (nothing to balance).
///
/// **Idle devices are excluded from the minimum.** A device with zero
/// observed load — freshly scaled out, just drained for removal, or
/// simply unassigned — used to drive the ratio to `f64::INFINITY`, which
/// exceeds every threshold and fired the migration policy on every
/// observe window even when the *loaded* devices were perfectly
/// balanced. The ratio now measures skew among devices that actually
/// carry load; with fewer than two loaded devices there is no skew to
/// measure and the ratio is `1.0`. (Elastic scale-out does not rely on
/// the infinity: [`crate::engine::GacerEngine::add_device`] re-shards
/// the placement onto the grown pool directly, and a genuinely skewed
/// loaded cluster still prefers an idle device as the migration
/// destination.)
///
/// ```
/// use gacer::metrics::imbalance_ratio;
///
/// assert_eq!(imbalance_ratio(&[4.0, 2.0]), 2.0);
/// // An idle device no longer makes balanced load look infinitely skewed.
/// assert_eq!(imbalance_ratio(&[3.0, 0.0]), 1.0);
/// assert_eq!(imbalance_ratio(&[12.0, 2.0, 0.0]), 6.0);
/// assert_eq!(imbalance_ratio(&[0.0, 0.0]), 1.0);
/// assert_eq!(imbalance_ratio(&[]), 1.0);
/// ```
pub fn imbalance_ratio(loads: &[f64]) -> f64 {
    let max = loads.iter().copied().fold(0.0f64, f64::max);
    if loads.is_empty() || max <= 0.0 {
        return 1.0;
    }
    let min_loaded = loads
        .iter()
        .copied()
        .filter(|&l| l > 0.0)
        .fold(f64::INFINITY, f64::min);
    max / min_loaded
}

/// Delta extractor over cumulative per-slot counters (e.g.
/// [`crate::coordinator::ClusterServer::served_counts`]): each call
/// returns the requests observed since the previous call — the per-window
/// demand signal an operations loop feeds into
/// [`crate::engine::GacerEngine::record_requests`].
///
/// Counters are tracked by a caller-supplied stable **key** per slot
/// (e.g. `TenantId.0`), not by slot position — so admissions, evictions
/// (which compact slot indices), and any combination of the two within
/// one window can never attribute one tenant's history to another. A
/// key seen for the first time contributes its full cumulative value
/// (everything it served since admission); a known key whose counter
/// went *backwards* (the server-side counter restarted, e.g. the tenant
/// migrated to a fresh device) contributes its new cumulative value.
/// That direction heuristic can under-count when a restarted counter
/// passes its old value within a single window — a caller that *knows*
/// a restart happened should [`DemandWindow::forget`] the key instead
/// of relying on it. Engine users can skip this type entirely:
/// [`crate::engine::GacerEngine::record_served`] wraps it keyed by
/// [`TenantId`], forgetting a tenant's baseline whenever the engine
/// itself migrates it.
///
/// [`TenantId`]: crate::engine::TenantId
#[derive(Debug, Clone, Default)]
pub struct DemandWindow {
    last: std::collections::BTreeMap<u64, u64>,
}

impl DemandWindow {
    pub fn new() -> Self {
        Self::default()
    }

    /// Requests per slot since the previous call. `keys[i]` is the
    /// stable identity of the tenant occupying slot `i`, parallel to
    /// `cumulative`. Keys absent from this call (evicted tenants) are
    /// forgotten.
    ///
    /// # Panics
    /// If `keys` and `cumulative` differ in length.
    pub fn delta(&mut self, keys: &[u64], cumulative: &[u64]) -> Vec<u64> {
        assert_eq!(keys.len(), cumulative.len(), "one key per counter");
        let out = keys
            .iter()
            .zip(cumulative)
            .map(|(&k, &c)| {
                let prev = self.last.get(&k).copied().unwrap_or(0);
                if c >= prev {
                    c - prev
                } else {
                    c
                }
            })
            .collect();
        self.last = keys.iter().copied().zip(cumulative.iter().copied()).collect();
        out
    }

    /// Drop a key's baseline: its next appearance is treated as
    /// first-seen (full cumulative value = the delta). Call when the
    /// underlying counter is known to restart — e.g. the engine forgets
    /// a tenant on migration, since its new device starts counting from
    /// zero.
    pub fn forget(&mut self, key: u64) {
        self.last.remove(&key);
    }

    /// Set a key's baseline explicitly: the key's next delta counts only
    /// requests *beyond* `cumulative`. The mirror of
    /// [`DemandWindow::forget`] — call it when a key is new to the window
    /// but the underlying counter is **not** (e.g. a tenant evicted and
    /// readmitted under the same `(name, family)` identity inherits the
    /// server-side counter of its predecessor across a hot swap; its
    /// history belongs to the predecessor, not to the newcomer).
    pub fn seed(&mut self, key: u64, cumulative: u64) {
        self.last.insert(key, cumulative);
    }
}

/// Latency sample recorder with percentile queries — **bounded memory**
/// regardless of how many samples are recorded.
///
/// Retains at most `cap` samples (default
/// [`LatencyHistogram::DEFAULT_CAP`]) as a uniform random **reservoir**
/// (Vitter's Algorithm R, driven by a fixed-seed deterministic
/// [`Rng`](crate::util::rng::Rng) so results reproduce): while fewer
/// than `cap` samples have been recorded every one is kept and all
/// statistics are exact; beyond that, each new sample replaces a
/// uniformly random retained one with probability `cap / count`, so the
/// reservoir stays a uniform sample of the whole stream and percentile
/// queries are unbiased estimates. **Count, mean, and max remain exact
/// at any scale** — they are tracked outside the reservoir. A long-lived
/// server recording millions of request latencies therefore holds a few
/// KB here, not an unbounded `Vec` (previously this grew by one `f64`
/// per request forever — an O(requests) leak on the serving path).
///
/// The reservoir is kept **sorted on insert** (binary search + `O(cap)`
/// memmove), so every percentile query is an `O(1)` index instead of a
/// clone-and-sort. Non-finite inputs (NaN, ±inf) are dropped on record —
/// they carry no latency information and a NaN would poison the ordering
/// invariant.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    /// Retained reservoir. Invariant: ascending order, all values
    /// finite, length ≤ `cap`.
    samples_us: Vec<f64>,
    /// Total samples ever recorded (exact, independent of the cap).
    count: u64,
    /// Exact running sum of every recorded sample.
    sum_us: f64,
    /// Exact maximum of every recorded sample.
    max_us: f64,
    cap: usize,
    rng: crate::util::rng::Rng,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::with_cap(Self::DEFAULT_CAP)
    }
}

impl LatencyHistogram {
    /// Default reservoir capacity: large enough that a p99 over the
    /// reservoir has ~1% relative rank error, small enough (64 KiB of
    /// `f64`s) to keep per-tenant recorders cheap.
    pub const DEFAULT_CAP: usize = 8192;

    pub fn new() -> Self {
        Self::default()
    }

    /// A histogram retaining at most `cap` samples (`cap >= 1`).
    pub fn with_cap(cap: usize) -> Self {
        assert!(cap >= 1, "reservoir capacity must be >= 1");
        LatencyHistogram {
            samples_us: Vec::new(),
            count: 0,
            sum_us: 0.0,
            max_us: 0.0,
            cap,
            // Fixed seed: recorded streams reproduce exactly; two
            // histograms fed the same stream retain the same reservoir.
            rng: crate::util::rng::Rng::new(0x1A7E_4C1),
        }
    }

    pub fn record(&mut self, d: Duration) {
        self.record_us(d.as_secs_f64() * 1e6);
    }

    /// Record one sample in microseconds. Non-finite values are ignored.
    pub fn record_us(&mut self, us: f64) {
        if !us.is_finite() {
            return;
        }
        self.count += 1;
        self.sum_us += us;
        if us > self.max_us || self.count == 1 {
            self.max_us = us;
        }
        if self.samples_us.len() < self.cap {
            let at = self.samples_us.partition_point(|&s| s <= us);
            self.samples_us.insert(at, us);
            return;
        }
        // Algorithm R: keep the newcomer with probability cap/count,
        // evicting a uniformly random retained sample.
        let j = self.rng.next_u64() % self.count;
        if (j as usize) < self.cap {
            self.samples_us.remove(j as usize);
            let at = self.samples_us.partition_point(|&s| s <= us);
            self.samples_us.insert(at, us);
        }
    }

    /// Total number of samples ever recorded (exact — not bounded by the
    /// reservoir capacity).
    pub fn len(&self) -> usize {
        self.count as usize
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Number of samples currently retained in the reservoir:
    /// `min(len, cap)`.
    pub fn retained(&self) -> usize {
        self.samples_us.len()
    }

    /// The retained samples in ascending order, microseconds — every
    /// recorded sample while under the cap, a uniform random subset
    /// beyond it. Feed these to [`crate::slo::SloMonitor::observe`] (or
    /// any consumer that wants raw samples rather than fixed quantiles).
    pub fn samples_us(&self) -> &[f64] {
        &self.samples_us
    }

    /// `q` in [0, 1]; nearest-rank percentile over the reservoir (exact
    /// while under the cap, an unbiased estimate beyond it — except
    /// `q = 1.0`, which returns the exact tracked maximum). `O(1)` —
    /// samples are already sorted.
    pub fn percentile_us(&self, q: f64) -> f64 {
        if self.samples_us.is_empty() {
            return 0.0;
        }
        if q >= 1.0 {
            return self.max_us;
        }
        let idx = ((q * (self.samples_us.len() - 1) as f64).round() as usize)
            .min(self.samples_us.len() - 1);
        self.samples_us[idx]
    }

    /// Exact mean over every recorded sample.
    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        self.sum_us / self.count as f64
    }

    /// Exact maximum over every recorded sample.
    pub fn max_us(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        self.max_us
    }

    /// Multi-quantile snapshot in one pass over the (already sorted)
    /// samples — the monitor-facing alternative to calling
    /// [`LatencyHistogram::percentile_us`] three times per window.
    ///
    /// Edge cases are part of the contract, not accidents of the
    /// reservoir:
    ///
    /// * **Empty histogram** — returns exactly [`Quantiles::default()`]
    ///   (`n == 0`, every statistic `0.0`). Consumers that must
    ///   distinguish "no traffic" from "all-zero latency" check
    ///   [`Quantiles::is_empty`], never a `0.0` percentile.
    /// * **Single sample** — every percentile, the mean, and the max
    ///   collapse to that one sample (nearest-rank over a one-element
    ///   reservoir), so `p50 == p99 == max` is expected, not a bug.
    pub fn quantiles(&self) -> Quantiles {
        Quantiles {
            n: self.len(),
            mean_us: self.mean_us(),
            p50_us: self.percentile_us(0.50),
            p95_us: self.percentile_us(0.95),
            p99_us: self.percentile_us(0.99),
            max_us: self.max_us(),
        }
    }

    /// One-line summary for logs and serving reports.
    pub fn summary(&self) -> String {
        let q = self.quantiles();
        format!(
            "n={} mean={:.1}us p50={:.1}us p99={:.1}us max={:.1}us",
            q.n, q.mean_us, q.p50_us, q.p99_us, q.max_us
        )
    }
}

/// Fixed multi-quantile snapshot of a [`LatencyHistogram`].
///
/// The all-zero [`Quantiles::default`] is the typed "no samples"
/// value — [`LatencyHistogram::quantiles`] returns it for an empty
/// histogram, and [`Quantiles::is_empty`] is the supported way to test
/// for it.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Quantiles {
    pub n: usize,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub max_us: f64,
}

impl Quantiles {
    /// Whether this snapshot summarizes zero samples (the statistics are
    /// then the `0.0` placeholders of [`Quantiles::default`], not
    /// measurements).
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }
}

/// Throughput window: completed items over elapsed wall time.
#[derive(Debug, Clone)]
pub struct Throughput {
    started: std::time::Instant,
    completed: u64,
}

impl Default for Throughput {
    fn default() -> Self {
        Self::new()
    }
}

impl Throughput {
    pub fn new() -> Self {
        Throughput { started: std::time::Instant::now(), completed: 0 }
    }

    pub fn inc(&mut self, n: u64) {
        self.completed += n;
    }

    pub fn completed(&self) -> u64 {
        self.completed
    }

    pub fn per_second(&self) -> f64 {
        let el = self.started.elapsed().as_secs_f64();
        if el <= 0.0 {
            0.0
        } else {
            self.completed as f64 / el
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let mut h = LatencyHistogram::new();
        for i in 1..=100 {
            h.record_us(i as f64);
        }
        assert!(h.percentile_us(0.5) <= h.percentile_us(0.99));
        assert_eq!(h.percentile_us(1.0), 100.0);
        assert_eq!(h.max_us(), 100.0);
        assert!((h.mean_us() - 50.5).abs() < 1e-9);
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = LatencyHistogram::new();
        assert_eq!(h.percentile_us(0.99), 0.0);
        assert_eq!(h.mean_us(), 0.0);
        assert!(h.is_empty());
    }

    #[test]
    fn summary_contains_counts() {
        let mut h = LatencyHistogram::new();
        h.record(Duration::from_micros(123));
        assert!(h.summary().contains("n=1"));
    }

    #[test]
    fn samples_stay_sorted_under_any_insert_order() {
        let mut h = LatencyHistogram::new();
        for us in [50.0, 10.0, 90.0, 10.0, 70.0, 30.0] {
            h.record_us(us);
        }
        assert_eq!(h.samples_us(), &[10.0, 10.0, 30.0, 50.0, 70.0, 90.0]);
        assert_eq!(h.percentile_us(0.0), 10.0);
        assert_eq!(h.percentile_us(1.0), 90.0);
    }

    #[test]
    fn non_finite_samples_are_dropped() {
        let mut h = LatencyHistogram::new();
        h.record_us(f64::NAN);
        h.record_us(f64::INFINITY);
        h.record_us(f64::NEG_INFINITY);
        assert!(h.is_empty());
        h.record_us(42.0);
        h.record_us(f64::NAN);
        assert_eq!(h.len(), 1);
        assert_eq!(h.percentile_us(0.99), 42.0);
    }

    #[test]
    fn empty_histogram_quantiles_are_the_typed_default() {
        let h = LatencyHistogram::new();
        let q = h.quantiles();
        assert_eq!(q, Quantiles::default(), "empty snapshot is the typed zero");
        assert!(q.is_empty());
        assert_eq!(q.n, 0);
        assert_eq!(q.mean_us, 0.0);
        assert_eq!(q.p50_us, 0.0);
        assert_eq!(q.p95_us, 0.0);
        assert_eq!(q.p99_us, 0.0);
        assert_eq!(q.max_us, 0.0);
    }

    #[test]
    fn single_sample_quantiles_collapse_to_that_sample() {
        let mut h = LatencyHistogram::new();
        h.record_us(123.5);
        let q = h.quantiles();
        assert!(!q.is_empty());
        assert_eq!(q.n, 1);
        assert_eq!(q.mean_us, 123.5);
        assert_eq!(q.p50_us, 123.5);
        assert_eq!(q.p95_us, 123.5);
        assert_eq!(q.p99_us, 123.5);
        assert_eq!(q.max_us, 123.5);
    }

    #[test]
    fn quantiles_snapshot_matches_individual_queries() {
        let mut h = LatencyHistogram::new();
        for i in 1..=200 {
            h.record_us(i as f64);
        }
        let q = h.quantiles();
        assert_eq!(q.n, 200);
        assert_eq!(q.p50_us, h.percentile_us(0.50));
        assert_eq!(q.p95_us, h.percentile_us(0.95));
        assert_eq!(q.p99_us, h.percentile_us(0.99));
        assert_eq!(q.max_us, 200.0);
        assert!(q.p50_us <= q.p95_us && q.p95_us <= q.p99_us);
    }

    #[test]
    fn reservoir_bounds_memory_over_a_million_samples() {
        // Regression: the histogram previously kept every sample in a
        // sorted Vec — one f64 per request, forever. Drive >1M samples
        // and assert memory stays capped while count/mean/max stay exact.
        let mut h = LatencyHistogram::new();
        let n: u64 = 1_200_000;
        for i in 0..n {
            // Deterministic spread over [0, 1000) with one late spike.
            h.record_us((i % 1000) as f64);
        }
        h.record_us(5000.0);
        assert_eq!(h.len(), n as usize + 1, "count is exact, not capped");
        assert!(!h.is_empty());
        assert_eq!(
            h.retained(),
            LatencyHistogram::DEFAULT_CAP,
            "reservoir never exceeds its capacity"
        );
        assert_eq!(h.samples_us().len(), h.retained());
        assert_eq!(h.max_us(), 5000.0, "max is tracked exactly outside the reservoir");
        assert_eq!(h.percentile_us(1.0), 5000.0);
        // Exact mean of 0..1000 repeated is 499.5; one 5000 barely moves it.
        assert!((h.mean_us() - 499.5).abs() < 0.1, "mean {}", h.mean_us());
        // The reservoir is a uniform sample of a uniform stream: p50
        // should land near 500 (generous tolerance — this is a sanity
        // bound, not a statistical test).
        let p50 = h.percentile_us(0.5);
        assert!((400.0..600.0).contains(&p50), "p50 {p50}");
        // Sorted invariant survives a million evictions.
        assert!(h.samples_us().windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn under_the_cap_every_sample_is_retained_exactly() {
        let mut h = LatencyHistogram::with_cap(100);
        for i in 1..=100 {
            h.record_us(i as f64);
        }
        assert_eq!(h.len(), 100);
        assert_eq!(h.retained(), 100);
        assert_eq!(h.percentile_us(0.5), 50.0, "exact while under the cap");
        assert_eq!(h.percentile_us(1.0), 100.0);
    }

    #[test]
    fn reservoir_histograms_are_deterministic() {
        let feed = |h: &mut LatencyHistogram| {
            for i in 0..10_000u32 {
                h.record_us((i % 777) as f64);
            }
        };
        let (mut a, mut b) = (LatencyHistogram::with_cap(64), LatencyHistogram::with_cap(64));
        feed(&mut a);
        feed(&mut b);
        assert_eq!(a.samples_us(), b.samples_us(), "fixed-seed reservoirs agree");
    }

    #[test]
    #[should_panic(expected = "capacity must be >= 1")]
    fn zero_capacity_is_rejected() {
        LatencyHistogram::with_cap(0);
    }

    #[test]
    fn imbalance_ignores_idle_devices() {
        // Regression (elastic pools): a fresh scale-out or a drained
        // device observes zero load; the ratio must stay finite so the
        // migration threshold keeps meaning "skew among loaded devices",
        // not "any idle device exists".
        assert_eq!(imbalance_ratio(&[1.0, 1.0, 0.0]), 1.0);
        assert_eq!(imbalance_ratio(&[12.0, 2.0, 0.0]), 6.0);
        assert_eq!(imbalance_ratio(&[5.0, 0.0, 0.0]), 1.0);
        assert!(imbalance_ratio(&[9.0, 3.0, 0.0]).is_finite());
        // No zeros: classic max/min is unchanged.
        assert_eq!(imbalance_ratio(&[4.0, 2.0]), 2.0);
    }

    #[test]
    fn demand_window_deltas() {
        let mut w = DemandWindow::new();
        // Tenants A=10, B=11 at slots 0, 1.
        assert_eq!(w.delta(&[10, 11], &[3, 5]), vec![3, 5], "first window = total");
        assert_eq!(w.delta(&[10, 11], &[4, 5]), vec![1, 0]);
        // Counter restart for a known key (migration to a fresh device).
        assert_eq!(w.delta(&[10, 11], &[6, 2]), vec![2, 2]);
        // Admission: C=12 appears, contributing its full count.
        assert_eq!(w.delta(&[10, 11, 12], &[6, 3, 7]), vec![0, 1, 7]);
        // Evict A + admit D in one window: B compacts to slot 0 keeping
        // its counter — tracked by key, nothing is misattributed.
        assert_eq!(w.delta(&[11, 13], &[3, 4]), vec![0, 4]);
    }

    #[test]
    fn demand_window_forget_rebaselines_a_key() {
        let mut w = DemandWindow::new();
        w.delta(&[10], &[5]);
        // The counter restarted and already caught up past its old
        // value: the direction heuristic alone would report 10-5=5.
        // Forgetting the key makes the restart explicit: all 10 count.
        w.forget(10);
        assert_eq!(w.delta(&[10], &[10]), vec![10]);
    }

    #[test]
    fn demand_window_restart_undercount_is_bounded_to_the_heuristic() {
        // Regression for the documented under-count: a restarted counter
        // that passes its old value within a single window looks like
        // forward progress to the direction heuristic.
        let mut w = DemandWindow::new();
        w.delta(&[7], &[5]);
        // Counter restarted at 0 and reached 10 before the next window
        // closed: the true demand is 10, the heuristic reports 10-5=5.
        // This test pins the heuristic's answer so the docs stay honest;
        // callers that *know* about the restart must forget() instead.
        assert_eq!(w.delta(&[7], &[10]), vec![5]);
    }

    #[test]
    fn demand_window_seed_sets_an_explicit_baseline() {
        // An evict→readmit under the same serving identity inherits the
        // predecessor's server-side counter across a hot swap. Seeding
        // attributes that inherited history to nobody: the readmitted
        // key's first delta counts only what it served itself.
        let mut w = DemandWindow::new();
        w.seed(9, 40);
        assert_eq!(w.delta(&[9], &[46]), vec![6], "only post-seed requests count");
        // Without the seed the same key would contribute its full
        // inherited cumulative value.
        let mut unseeded = DemandWindow::new();
        assert_eq!(unseeded.delta(&[9], &[46]), vec![46]);
    }

    #[test]
    #[should_panic(expected = "one key per counter")]
    fn demand_window_rejects_arity_mismatch() {
        DemandWindow::new().delta(&[1], &[2, 3]);
    }

    #[test]
    fn throughput_counts() {
        let mut t = Throughput::new();
        t.inc(5);
        t.inc(3);
        assert_eq!(t.completed(), 8);
        assert!(t.per_second() > 0.0);
    }
}
