//! Serving + evaluation metrics: latency histograms, throughput counters,
//! and report-ready summaries.

use std::time::Duration;

/// Latency sample recorder with percentile queries.
///
/// Stores raw microsecond samples; percentile queries sort a snapshot.
/// Intended for request-scale counts (thousands), not packet-scale.
#[derive(Debug, Clone, Default)]
pub struct LatencyHistogram {
    samples_us: Vec<f64>,
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, d: Duration) {
        self.samples_us.push(d.as_secs_f64() * 1e6);
    }

    pub fn record_us(&mut self, us: f64) {
        self.samples_us.push(us);
    }

    pub fn len(&self) -> usize {
        self.samples_us.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples_us.is_empty()
    }

    /// `q` in [0, 1]; nearest-rank percentile.
    pub fn percentile_us(&self, q: f64) -> f64 {
        if self.samples_us.is_empty() {
            return 0.0;
        }
        let mut s = self.samples_us.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((q * (s.len() - 1) as f64).round() as usize).min(s.len() - 1);
        s[idx]
    }

    pub fn mean_us(&self) -> f64 {
        if self.samples_us.is_empty() {
            return 0.0;
        }
        self.samples_us.iter().sum::<f64>() / self.samples_us.len() as f64
    }

    pub fn max_us(&self) -> f64 {
        self.samples_us.iter().copied().fold(0.0, f64::max)
    }

    /// One-line summary for logs and serving reports.
    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.1}us p50={:.1}us p99={:.1}us max={:.1}us",
            self.len(),
            self.mean_us(),
            self.percentile_us(0.50),
            self.percentile_us(0.99),
            self.max_us()
        )
    }
}

/// Throughput window: completed items over elapsed wall time.
#[derive(Debug, Clone)]
pub struct Throughput {
    started: std::time::Instant,
    completed: u64,
}

impl Default for Throughput {
    fn default() -> Self {
        Self::new()
    }
}

impl Throughput {
    pub fn new() -> Self {
        Throughput { started: std::time::Instant::now(), completed: 0 }
    }

    pub fn inc(&mut self, n: u64) {
        self.completed += n;
    }

    pub fn completed(&self) -> u64 {
        self.completed
    }

    pub fn per_second(&self) -> f64 {
        let el = self.started.elapsed().as_secs_f64();
        if el <= 0.0 {
            0.0
        } else {
            self.completed as f64 / el
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let mut h = LatencyHistogram::new();
        for i in 1..=100 {
            h.record_us(i as f64);
        }
        assert!(h.percentile_us(0.5) <= h.percentile_us(0.99));
        assert_eq!(h.percentile_us(1.0), 100.0);
        assert_eq!(h.max_us(), 100.0);
        assert!((h.mean_us() - 50.5).abs() < 1e-9);
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = LatencyHistogram::new();
        assert_eq!(h.percentile_us(0.99), 0.0);
        assert_eq!(h.mean_us(), 0.0);
        assert!(h.is_empty());
    }

    #[test]
    fn summary_contains_counts() {
        let mut h = LatencyHistogram::new();
        h.record(Duration::from_micros(123));
        assert!(h.summary().contains("n=1"));
    }

    #[test]
    fn throughput_counts() {
        let mut t = Throughput::new();
        t.inc(5);
        t.inc(3);
        assert_eq!(t.completed(), 8);
        assert!(t.per_second() > 0.0);
    }
}
